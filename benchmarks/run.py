"""Benchmark orchestrator — one function per paper table + roofline.

    PYTHONPATH=src python -m benchmarks.run            # quick (CPU scale)
    PYTHONPATH=src python -m benchmarks.run --full
    PYTHONPATH=src python -m benchmarks.run --only table2 table8

Prints ``name,us_per_call,derived`` CSV lines at the end (harness
contract) plus human-readable tables; JSON artifacts land in
benchmarks/results/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args(argv)

    from benchmarks import paper_tables as PT
    from benchmarks import graph_build_scaling as GBS
    from benchmarks import lifecycle_faults as LF
    from benchmarks import lifecycle_swap as LS
    from benchmarks import obs_overhead as OO
    from benchmarks import roofline as RL
    from benchmarks import serving_concurrency as SC
    from benchmarks import serving_kernels as SK
    from benchmarks import serving_scaleout as SSC
    from benchmarks import train_throughput as TT
    from benchmarks import vmem_report as VMR

    jobs = [
        ("table2_user_recall", PT.table2_user_recall),
        ("table3_item_recall", PT.table3_item_recall),
        ("table4_index_hitrate", PT.table4_index_hitrate),
        ("table5_edge_types", PT.table5_edge_types),
        ("table6_neighbors", PT.table6_neighbors),
        ("table7_popbias", PT.table7_popbias),
        ("table8_serving_cost", PT.table8_serving_cost),
        ("graph_build_scaling", GBS.run),
        ("serving_kernels", SK.run),
        ("train_throughput", TT.run),
        ("lifecycle_swap", LS.run),
        ("lifecycle_faults", LF.run),
        ("serving_concurrency", SC.run),
        ("serving_scaleout", SSC.run),
        ("obs_overhead", OO.run),
        ("roofline", RL.run),
        ("vmem_report", VMR.run),
    ]
    if args.only:
        jobs = [(n, f) for n, f in jobs
                if any(o in n for o in args.only)]

    csv_rows = []
    failures = []
    for name, fn in jobs:
        print(f"\n=== {name} ===")
        t0 = time.perf_counter()
        try:
            out = fn(full=args.full)
            dt = time.perf_counter() - t0
            derived = ""
            if isinstance(out, dict):
                if "thread_speedup" in out:
                    derived = (f"thread_speedup="
                               f"{out['thread_speedup']:.2f}x")
                elif "device_speedup_4t" in out:
                    derived = (f"device_speedup="
                               f"{out['device_speedup_4t']:.2f}x;"
                               f"shard_scaling="
                               + "/".join(f"{x:.2f}"
                                          for x in out["shard_scaling"]))
                elif "overhead_pct" in out:
                    derived = (f"obs_overhead="
                               f"{out['overhead_pct']:+.2f}%")
                elif "speedup_dedup_ids" in out:
                    derived = (f"train_speedup="
                               f"{out['speedup_dedup_ids']:.2f}x")
                elif "rankgraph2" in out:
                    derived = f"recall@100={out['rankgraph2'].get(100, 0):.3f}"
                elif "modeled_cost_reduction" in out:
                    derived = (f"cost_reduction="
                               f"{out['modeled_cost_reduction']*100:.0f}%")
                elif "max_recovery_cycles" in out:
                    derived = (f"recovery_cycles="
                               f"{out['max_recovery_cycles']};"
                               f"corrupt_serves={out['corrupt_serves']}")
                elif "n_over_budget" in out:
                    derived = (f"kernels={out['n_kernels']};over_budget="
                               f"{out['n_over_budget']}")
                elif "rows" in out and name == "roofline" and out["rows"]:
                    worst = min(out["rows"],
                                key=lambda r: r["projected_mfu"])
                    derived = (f"cells={len(out['rows'])};worst_mfu="
                               f"{worst['projected_mfu']*100:.1f}%")
            csv_rows.append(f"{name},{dt*1e6:.0f},{derived}")
        except Exception as e:
            traceback.print_exc()
            failures.append((name, repr(e)))
            csv_rows.append(f"{name},-1,FAILED")

    print("\nname,us_per_call,derived")
    for row in csv_rows:
        print(row)
    if failures:
        print(f"\n{len(failures)} benchmark(s) FAILED: {failures}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
