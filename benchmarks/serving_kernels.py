"""Kernel microbenchmarks on the serving/training hot paths.

Wall-times here are CPU-interpret-mode and NOT indicative of TPU
performance (the dry-run roofline covers that); what this benchmark
establishes is (a) the kernels run and agree with their oracles at
benchmark scale, and (b) the analytic VMEM/FLOP accounting per kernel
that backs the kernel-level roofline notes in EXPERIMENTS.md.
"""
from __future__ import annotations

import os
import time
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import write_result


def _time(fn, *args, n=3):
    fn(*args)                      # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run(full: bool = False) -> Dict:
    out = {}
    key = jax.random.key(0)

    # rq_assign: production codebooks (5000 x 50), batch tile 256
    from repro.kernels.rq_assign.ops import rq_assign
    from repro.kernels.rq_assign.ref import rq_assign_ref
    B, d = (1024, 256)
    x = jax.random.normal(key, (B, d))
    books = [jax.random.normal(jax.random.key(1), (5000, d)) * 0.3,
             jax.random.normal(jax.random.key(2), (50, d)) * 0.1]
    ck, rk = rq_assign(x, books, use_kernel=True)
    cr, rr = rq_assign_ref(x, books)
    agree = bool((np.asarray(ck) == np.asarray(cr)).all())
    t_ref = _time(jax.jit(lambda x: rq_assign_ref(x, books)), x)
    vmem = sum(c.size * 4 for c in books) + 256 * d * 4 * 3
    out["rq_assign"] = dict(
        agree=agree, ref_us=t_ref * 1e6,
        vmem_bytes=vmem, fits_vmem=vmem < 16 * 2**20,
        flops_per_row=2 * d * sum(c.shape[0] for c in books) * 2)

    # embedding_bag: DLRM-ish bag lookup
    from repro.kernels.embedding_bag.ops import embedding_bag
    from repro.kernels.embedding_bag.ref import embedding_bag_ref
    tbl = jax.random.normal(key, (200_000, 64))
    ids = jax.random.randint(jax.random.key(3), (512, 8), -1, 200_000)
    ok = np.allclose(np.asarray(embedding_bag(tbl, ids, None, "sum", True)),
                     np.asarray(embedding_bag_ref(tbl, ids)), atol=2e-5)
    t_ref = _time(jax.jit(lambda t, i: embedding_bag_ref(t, i)), tbl, ids)
    out["embedding_bag"] = dict(agree=bool(ok), ref_us=t_ref * 1e6,
                                bytes_gathered=512 * 8 * 64 * 4)

    # fused_contrastive: training hot loop tile
    from repro.kernels.fused_contrastive.fused_contrastive import (
        fused_contrastive)
    from repro.kernels.fused_contrastive.ref import contrastive_ref
    from repro.nn.core import l2_normalize
    src = l2_normalize(jax.random.normal(key, (512, 64)))
    dst = l2_normalize(jax.random.normal(jax.random.key(4), (512, 64)))
    negs = l2_normalize(jax.random.normal(jax.random.key(5),
                                          (512, 100, 64)))
    mk, ik = fused_contrastive(src, dst, negs)
    mr, ir = contrastive_ref(src, dst, negs)
    ok = (np.allclose(np.asarray(mk), np.asarray(mr), rtol=1e-3, atol=1e-4)
          and np.allclose(np.asarray(ik), np.asarray(ir), rtol=1e-3,
                          atol=1e-4))
    t_ref = _time(jax.jit(lambda a, b, c: contrastive_ref(a, b, c)),
                  src, dst, negs)
    out["fused_contrastive"] = dict(
        agree=bool(ok), ref_us=t_ref * 1e6,
        hbm_saved_bytes_unfused=512 * 101 * 4 * 2)

    # flash_attention: one prefill tile
    from repro.kernels.flash_attention.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    q = jax.random.normal(key, (1, 4, 256, 64))
    k = jax.random.normal(jax.random.key(6), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.key(7), (1, 2, 256, 64))
    ok = np.allclose(np.asarray(flash_attention(q, k, v)),
                     np.asarray(attention_ref(q, k, v)),
                     rtol=2e-4, atol=2e-4)
    t_ref = _time(jax.jit(lambda q, k, v: attention_ref(q, k, v)), q, k, v)
    out["flash_attention"] = dict(agree=bool(ok), ref_us=t_ref * 1e6,
                                  vmem_tile_bytes=(128 * 64 * 3 + 128 * 128)
                                  * 4)

    # queue_gather: fused serving gather-union, kernel vs oracle
    from repro.core.serving import ClusterQueueStore, u2i2i_retrieve
    from repro.kernels.queue_gather.ops import queue_gather
    from repro.kernels.queue_gather.ref import queue_gather_ref
    rng = np.random.default_rng(0)
    n_users, n_items, C, Q = 2000, 4000, 256, 256
    store = ClusterQueueStore(rng.integers(0, C, n_users), queue_len=Q,
                              recency_s=900.0)
    n_ev = 50_000 if not full else 200_000
    store.ingest(rng.integers(0, n_users, n_ev),
                 rng.integers(0, n_items, n_ev),
                 rng.integers(0, 1800, n_ev).astype(float))
    i2i = rng.integers(0, n_items, (n_items, 16))
    now, R, topk = 1800.0, 8, 32
    cutoff = store.rel_cutoff(now)
    users_small = rng.integers(0, n_users, 32)
    cl = store.user_clusters[users_small]
    sk, uk = queue_gather(store.items, store.times, store.cursor, cl, i2i,
                          cutoff=cutoff, n_recent=R, k=topk)
    sr, ur = queue_gather_ref(store.items, store.times, store.cursor, cl,
                              i2i, cutoff=cutoff, n_recent=R, k=topk)
    ok = bool((np.asarray(sk) == sr).all() and (np.asarray(uk) == ur).all())
    t_ref = _time(lambda c: queue_gather_ref(
        store.items, store.times, store.cursor, c, i2i,
        cutoff=cutoff, n_recent=R, k=topk), cl)
    out["queue_gather"] = dict(
        agree=ok, ref_us=t_ref * 1e6,
        vmem_bytes=2 * Q * 4 + i2i.size * 4 + R * topk * 4,
        bytes_gathered_per_req=Q * 12 + R * i2i.shape[1] * 4)

    # batched serving engine vs the per-request loop (the tentpole win):
    # the acceptance bar is >=10x at batch >= 1024 on CPU
    B = 1024
    users = rng.integers(0, n_users, B)
    store.retrieve_batch(users, now, topk)            # warm
    t_batched, t_loop = np.inf, np.inf
    for _ in range(3):                                # min-of-3: noise-proof
        t0 = time.perf_counter()
        batched = store.retrieve_batch(users, now, topk)
        t_batched = min(t_batched, time.perf_counter() - t0)
        t0 = time.perf_counter()
        looped = [store.retrieve(int(u), now, topk) for u in users]
        t_loop = min(t_loop, time.perf_counter() - t0)
    same = all([int(i) for i in row if i >= 0] == lo
               for row, lo in zip(batched, looped))
    speedup = t_loop / max(t_batched, 1e-9)
    out["batched_retrieve"] = dict(
        agree=bool(same), batch=B, batched_us_per_req=t_batched / B * 1e6,
        loop_us_per_req=t_loop / B * 1e6, speedup=speedup)

    seeds = store.retrieve_batch(users, now, R)
    from repro.core.serving import u2i2i_retrieve_batch
    u2i2i_retrieve_batch(i2i, seeds, topk)            # warm
    t_ub, t_ul = np.inf, np.inf
    for _ in range(3):
        t0 = time.perf_counter()
        ub = u2i2i_retrieve_batch(i2i, seeds, topk)
        t_ub = min(t_ub, time.perf_counter() - t0)
        t0 = time.perf_counter()
        ul = [u2i2i_retrieve(i2i, [int(i) for i in row if i >= 0], topk)
              for row in seeds]
        t_ul = min(t_ul, time.perf_counter() - t0)
    same = all([int(i) for i in row if i >= 0] == lo
               for row, lo in zip(ub, ul))
    out["batched_u2i2i"] = dict(
        agree=bool(same), batch=B, batched_us_per_req=t_ub / B * 1e6,
        loop_us_per_req=t_ul / B * 1e6,
        speedup=t_ul / max(t_ub, 1e-9))

    print("\nKernel microbenchmarks (interpret-mode agreement + footprint):")
    for name, r in out.items():
        print(f"  {name:<18s} agree={r['agree']} ref_us="
              f"{r.get('ref_us', 0):.0f}"
              + (f" speedup={r['speedup']:.1f}x" if "speedup" in r else ""))
    assert all(r["agree"] for r in out.values()), "kernel mismatch!"
    # acceptance bar: >= 10x locally; CI on noisy shared runners can
    # lower it via SERVING_MIN_SPEEDUP without losing the regression gate
    min_speedup = float(os.environ.get("SERVING_MIN_SPEEDUP", "10"))
    assert out["batched_retrieve"]["speedup"] >= min_speedup, \
        f"batched retrieve speedup {out['batched_retrieve']['speedup']:.1f}x"
    write_result("serving_kernels", out)
    return out


if __name__ == "__main__":
    run()
