"""Telemetry overhead gate: the serving hot path with telemetry ON must
stay within ``OBS_MAX_OVERHEAD_PCT`` (default 5%) of telemetry OFF.

What makes near-zero overhead plausible (and this gate keepable): the
disabled path is one attribute check per instrumentation site, and the
enabled path's counters/histograms write to per-thread shards with no
lock on the hot path.  The benchmark interleaves disabled/enabled
rounds over the same store and batch (so frequency scaling and cache
state hit both arms alike) and compares min-of-rounds per-batch times —
min, not mean, because the quantity under test is the instrumentation's
deterministic cost, not scheduler noise.

Results land in ``benchmarks/results/obs_overhead.json``.
"""
from __future__ import annotations

import os
import time
from typing import Dict

import numpy as np

from benchmarks.common import write_result
from repro import obs
from repro.core.serving import ClusterQueueStore

ROUNDS = 7
ITERS = 12


def _per_batch_s(fn, iters: int) -> float:
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def run(full: bool = False) -> Dict:
    rng = np.random.default_rng(0)
    n_users, n_items, C = 50_000, 20_000, 512
    store = ClusterQueueStore(rng.integers(0, C, n_users),
                              queue_len=256, recency_s=1e15)
    for _ in range(4):
        store.ingest(rng.integers(0, n_users, 100_000),
                     rng.integers(0, n_items, 100_000),
                     rng.integers(0, 10_000, 100_000).astype(float))
    B, k, now = 4096 if full else 2048, 32, 1e6
    users = rng.integers(0, n_users, B)
    fn = lambda: store.retrieve_batch(users, now, k)  # noqa: E731

    tel = obs.get_telemetry()
    was_enabled = tel.enabled
    best = {"off": np.inf, "on": np.inf}
    try:
        for arm in ("off", "on"):              # warm both arms
            tel.enabled = arm == "on"
            fn()
        for _ in range(ROUNDS):                # interleave: shared drift
            for arm in ("off", "on"):
                tel.enabled = arm == "on"
                best[arm] = min(best[arm], _per_batch_s(fn, ITERS))
    finally:
        tel.enabled = was_enabled
    overhead_pct = (best["on"] / best["off"] - 1.0) * 100.0

    out = dict(batch=B, k=k, rounds=ROUNDS, iters=ITERS,
               off_us_per_batch=best["off"] * 1e6,
               on_us_per_batch=best["on"] * 1e6,
               off_us_per_req=best["off"] / B * 1e6,
               on_us_per_req=best["on"] / B * 1e6,
               overhead_pct=overhead_pct)
    print(f"\nTelemetry overhead (retrieve_batch, B={B}):")
    print(f"  disabled: {out['off_us_per_batch']:.0f}us/batch "
          f"({out['off_us_per_req']:.3f}us/req)")
    print(f"  enabled:  {out['on_us_per_batch']:.0f}us/batch "
          f"({out['on_us_per_req']:.3f}us/req)")
    print(f"  overhead: {overhead_pct:+.2f}%")

    gate = float(os.environ.get("OBS_MAX_OVERHEAD_PCT", "5.0"))
    assert overhead_pct <= gate, \
        (f"telemetry overhead {overhead_pct:+.2f}% exceeds the "
         f"{gate:.1f}% budget")
    write_result("obs_overhead", out)
    return out


if __name__ == "__main__":
    run(full=os.environ.get("BENCH_FULL", "") == "1")
