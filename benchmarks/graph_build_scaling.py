"""§4.2 construction-stage benchmark (backs the <=1h refresh claim).

Three sections:

  1. build scaling: build_graph + PPR precompute throughput across
     corpus sizes, extrapolated to paper scale (embarrassingly-parallel
     batch job — wall-time scales ~1/workers);
  2. walker backends: the accelerated (jax) PPR walker vs the numpy
     reference at >= 100k nodes on the *same* uniform stream — asserts
     bit-identical traces and a >= PPR_MIN_SPEEDUP speedup (default 5x;
     CI's noisy shared runners lower it via the env var);
  3. incremental refresh: a trailing-window delta spliced by
     ``incremental_refresh`` vs a from-scratch rebuild on the merged
     window — asserts the refresh lands at <= REFRESH_MAX_FRACTION of
     the full-rebuild wall-clock (default 0.9).
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import write_result
from repro.core.graph_builder import EngagementLog, build_graph
from repro.core import ppr as P
from repro.data.edge_dataset import build_neighbor_tables, \
    incremental_refresh
from repro.data.synthetic import make_world


def _bench_build_scaling(full: bool) -> Dict:
    sizes = [(500, 800), (1000, 1600), (2000, 3200)]
    if full:
        sizes.append((4000, 6400))
    rows: List[Dict] = []
    for nu, ni in sizes:
        world = make_world(n_users=nu, n_items=ni, events_per_user=40.0,
                           seed=11)
        n_events = len(world.day0.user_id)
        t0 = time.perf_counter()
        g = build_graph(world.day0, k_cap=32)
        t_build = time.perf_counter() - t0
        t0 = time.perf_counter()
        build_neighbor_tables(g, k_imp=20, n_walks=32, walk_len=4)
        t_ppr = time.perf_counter() - t0
        rows.append(dict(n_users=nu, n_items=ni, n_events=n_events,
                         n_edges=g.n_edges, t_build=t_build, t_ppr=t_ppr,
                         events_per_s=n_events / t_build,
                         nodes_per_s=(nu + ni) / t_ppr))
    # extrapolation: paper scale = ~1e9 nodes, ~1e11 edges, 24h of events
    ev_rate = rows[-1]["events_per_s"]
    node_rate = rows[-1]["nodes_per_s"]
    paper_events = 5e10          # O(10^10) events/day
    paper_nodes = 2e9
    workers_for_1h = (paper_events / ev_rate + paper_nodes / node_rate) / 3600
    print("\nGraph construction scaling:")
    for r in rows:
        print(f"  {r['n_users']}u/{r['n_items']}i: build {r['t_build']:.2f}s"
              f" ({r['events_per_s']:.0f} ev/s), ppr {r['t_ppr']:.2f}s"
              f" ({r['nodes_per_s']:.0f} nodes/s)")
    print(f"  -> ~{workers_for_1h:.0f} cores for a 1h rebuild at paper "
          f"scale (embarrassingly parallel)")
    return dict(rows=rows, single_core_events_per_s=ev_rate,
                single_core_ppr_nodes_per_s=node_rate,
                workers_for_1h_rebuild=workers_for_1h)


def _bench_walker_backends(full: bool) -> Dict:
    """numpy vs jax walker on a synthetic padded adjacency (>= 100k
    nodes, the acceptance scale); both consume the same uniform stream
    so the traces must be bit-identical."""
    N = 1 << 18 if full else 1 << 17          # 131072 nodes minimum
    # degree 64 per edge type (the seed's K_CAP) -> 128-wide rows: the
    # linear-scan baseline pays the full row per step, the binary-search
    # jax path pays log2 scalar gathers
    D2, W, L = 128, 16, 4
    rng = np.random.default_rng(0)
    nbrs = rng.integers(0, N, (N, D2)).astype(np.int64)
    deg = rng.integers(4, D2 + 1, N)
    mask = np.arange(D2)[None, :] < deg[:, None]
    nbrs = np.where(mask, nbrs, -1)
    probs = np.where(mask, rng.random((N, D2)), 0.0)
    probs /= probs.sum(1, keepdims=True)
    adj = P.PaddedHeteroAdj(nbrs, np.cumsum(probs, 1).astype(np.float32),
                            N, 0)
    starts = np.arange(N, dtype=np.int64)
    kw = dict(n_walks=W, walk_len=L, restart=0.15, seed=0)

    t0 = time.perf_counter()
    vis_np, _ = P.ppr_visit_counts(adj, starts, backend="numpy", **kw)
    t_np = time.perf_counter() - t0
    P.ppr_visit_counts(adj, starts, backend="jax", **kw)   # compile warm
    t_jx = np.inf
    for _ in range(3):                                     # min-of-3
        t0 = time.perf_counter()
        vis_jx, _ = P.ppr_visit_counts(adj, starts, backend="jax", **kw)
        t_jx = min(t_jx, time.perf_counter() - t0)
    agree = bool(np.array_equal(vis_np, vis_jx))
    speedup = t_np / max(t_jx, 1e-9)
    print(f"\nPPR walker backends ({N} nodes, {W}x{L} walks):")
    print(f"  numpy {t_np:.2f}s  jax {t_jx:.2f}s  speedup "
          f"{speedup:.1f}x  bit-identical={agree}")
    return dict(n_nodes=N, d2=D2, n_walks=W, walk_len=L, agree=agree,
                numpy_s=t_np, jax_s=t_jx, speedup=speedup,
                numpy_walkers_per_s=N * W / t_np,
                jax_walkers_per_s=N * W / t_jx)


def _bench_incremental_refresh(full: bool) -> Dict:
    """Hour-level delta splice vs from-scratch rebuild on the merged
    window, same construction knobs and walker backend on both sides."""
    nu, ni = (40000, 80000) if full else (20000, 40000)
    world = make_world(n_users=nu, n_items=ni, events_per_user=4.0,
                       seed=11)
    log = world.day0
    delta_s = 1800.0                                # trailing 30 min
    m = log.timestamp <= 86400.0 - delta_s
    old = EngagementLog(log.user_id[m], log.item_id[m],
                        log.event_type[m], log.timestamp[m],
                        log.n_users, log.n_items)
    delta = log.window(86400.0, delta_s)
    kw = dict(k_cap=16, hub_cap=24)
    pw = dict(k_imp=10, n_walks=16, walk_len=2, seed=0)

    g_old = build_graph(old, keep_state=True, **kw)
    t_old = build_neighbor_tables(g_old, keep_state=True, **pw)
    t_refresh = t_full = np.inf
    for _ in range(2):                            # min-of-2: noise-proof
        t0 = time.perf_counter()
        _, _, rep = incremental_refresh(g_old, t_old, delta)
        t_refresh = min(t_refresh, time.perf_counter() - t0)
        t0 = time.perf_counter()
        g_full = build_graph(log, **kw)
        build_neighbor_tables(g_full, **pw)
        t_full = min(t_full, time.perf_counter() - t0)
    frac = t_refresh / max(t_full, 1e-9)
    n = nu + ni
    print(f"\nIncremental refresh ({nu}u/{ni}i, {len(delta.user_id)} "
          f"delta events):")
    print(f"  full rebuild {t_full:.2f}s  refresh {t_refresh:.2f}s "
          f"({frac:.2f}x, {len(rep['affected_nodes'])}/{n} nodes "
          f"re-walked)")
    return dict(n_users=nu, n_items=ni,
                delta_events=int(len(delta.user_id)),
                affected_nodes=int(len(rep["affected_nodes"])),
                n_nodes=n, full_rebuild_s=t_full, refresh_s=t_refresh,
                fraction=frac)


def run(full: bool = False) -> Dict:
    out = dict(scaling=_bench_build_scaling(full),
               walker=_bench_walker_backends(full),
               refresh=_bench_incremental_refresh(full))
    write_result("graph_build_scaling", out)

    assert out["walker"]["agree"], "jax walker diverged from numpy!"
    # acceptance bar: >= 5x locally at >= 100k nodes; CI's noisy shared
    # runners can lower it via PPR_MIN_SPEEDUP without losing the gate
    min_speedup = float(os.environ.get("PPR_MIN_SPEEDUP", "5"))
    assert out["walker"]["speedup"] >= min_speedup, \
        f"ppr walker speedup {out['walker']['speedup']:.1f}x"
    max_frac = float(os.environ.get("REFRESH_MAX_FRACTION", "0.9"))
    assert out["refresh"]["fraction"] <= max_frac, \
        f"refresh took {out['refresh']['fraction']:.2f}x of a full rebuild"
    return out


if __name__ == "__main__":
    run()
