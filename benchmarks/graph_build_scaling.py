"""§4.2 construction-throughput benchmark (backs the <=1h rebuild claim).

Measures build_graph + PPR precompute throughput (events/s, nodes/s)
across corpus sizes, then extrapolates to the paper's scale assuming the
embarrassingly-parallel structure (per-anchor co-engagement, per-node
walks) — the pipeline is a data-parallel batch job, so wall-time scales
~1/workers.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import write_result
from repro.core.graph_builder import build_graph
from repro.data.edge_dataset import build_neighbor_tables
from repro.data.synthetic import make_world


def run(full: bool = False) -> Dict:
    sizes = [(500, 800), (1000, 1600), (2000, 3200)]
    if full:
        sizes.append((4000, 6400))
    rows: List[Dict] = []
    for nu, ni in sizes:
        world = make_world(n_users=nu, n_items=ni, events_per_user=40.0,
                           seed=11)
        n_events = len(world.day0.user_id)
        t0 = time.perf_counter()
        g = build_graph(world.day0, k_cap=32)
        t_build = time.perf_counter() - t0
        t0 = time.perf_counter()
        build_neighbor_tables(g, k_imp=20, n_walks=32, walk_len=4)
        t_ppr = time.perf_counter() - t0
        rows.append(dict(n_users=nu, n_items=ni, n_events=n_events,
                         n_edges=g.n_edges, t_build=t_build, t_ppr=t_ppr,
                         events_per_s=n_events / t_build,
                         nodes_per_s=(nu + ni) / t_ppr))
    # extrapolation: paper scale = ~1e9 nodes, ~1e11 edges, 24h of events
    ev_rate = rows[-1]["events_per_s"]
    node_rate = rows[-1]["nodes_per_s"]
    paper_events = 5e10          # O(10^10) events/day
    paper_nodes = 2e9
    workers_for_1h = (paper_events / ev_rate + paper_nodes / node_rate) / 3600
    out = dict(rows=rows, single_core_events_per_s=ev_rate,
               single_core_ppr_nodes_per_s=node_rate,
               workers_for_1h_rebuild=workers_for_1h)
    print("\nGraph construction scaling:")
    for r in rows:
        print(f"  {r['n_users']}u/{r['n_items']}i: build {r['t_build']:.2f}s"
              f" ({r['events_per_s']:.0f} ev/s), ppr {r['t_ppr']:.2f}s"
              f" ({r['nodes_per_s']:.0f} nodes/s)")
    print(f"  -> ~{workers_for_1h:.0f} cores for a 1h rebuild at paper "
          f"scale (embarrassingly parallel)")
    write_result("graph_build_scaling", out)
    return out
