"""Re-run the perf-iterated cells into REPRO_DRYRUN_DIR (see §Perf)."""
import os
os.environ.setdefault("REPRO_DRYRUN_DIR",
                      os.path.join(os.path.dirname(__file__), "results",
                                   "dryrun_opt"))
import json
import traceback

from repro.launch import dryrun

CELLS = [
    ("dlrm-rm2", "train_batch"), ("dlrm-rm2", "serve_bulk"),
    ("dlrm-rm2", "serve_p99"), ("dlrm-rm2", "retrieval_cand"),
    ("wide-deep", "train_batch"), ("wide-deep", "serve_bulk"),
    ("sasrec", "train_batch"), ("sasrec", "serve_bulk"),
    ("bst", "train_batch"), ("bst", "serve_bulk"),
    ("equiformer-v2", "ogb_products"), ("equiformer-v2", "minibatch_lg"),
    ("rankgraph2", "train_batch"), ("rankgraph2", "serve_bulk"),
]

if __name__ == "__main__":
    fails = []
    for a, s in CELLS:
        path = dryrun.cell_path("singlepod", a, s)
        if os.path.exists(path):
            print(f"cached: {a} x {s}")
            continue
        try:
            rec = dryrun.run_cell(a, s, "singlepod")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
        except Exception as e:
            traceback.print_exc()
            fails.append((a, s, repr(e)))
    print("FAILS:", fails)
