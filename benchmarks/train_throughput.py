"""Train-step throughput: PR-3 baseline vs the reworked hot path.

    PYTHONPATH=src python -m benchmarks.train_throughput
    PYTHONPATH=src TRAIN_MIN_SPEEDUP=1.5 python -m benchmarks.train_throughput

Three configurations of the *same* model / edge draws:

  baseline   — PR-3 semantics: legacy per-endpoint batches (every
               endpoint occurrence host-gathered and re-encoded),
               double negative draws for L', undonated jit;
  dedup      — packed unique-node batches: every referenced node
               encoded once, negatives reused between L and L',
               donated step;
  dedup_ids  — dedup + id-only batches: features gathered inside the
               jitted step from a device-resident FeatureStore (host
               ships int32 ids + masks instead of (B, K, d) float32).

End-to-end per-step time is measured (host batch construction + device
step), since the host gather is exactly what the id-only path removes.
Asserts dedup_ids >= TRAIN_MIN_SPEEDUP x baseline (default 1.5).
"""
from __future__ import annotations

import os
import time
from typing import Dict

import numpy as np

from benchmarks.common import write_result


def _bench_cfg():
    from repro.configs.base import RankGraph2Config, RQConfig
    return RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=48, n_heads=2,
        d_hidden=128, k_imp=20, k_train=10, n_negatives=50, n_pool_neg=16,
        k_cap=32, ppr_walks=32, ppr_len=4, ppr_restart=0.3,
        rq=RQConfig(codebook_sizes=(64, 16), hist_len=100),
        dtype="float32")


def _time_mode(name: str, cfg, ds, fmt: str, *, steps: int,
               batch_per_type: int, features=None, donate: bool = True,
               seed: int = 0) -> Dict[str, float]:
    import jax
    import jax.numpy as jnp
    from repro.core import trainer as T

    state, _, opt = T.init_state(jax.random.key(seed), cfg, pool_size=2048)
    step_fn = T.make_train_step(cfg, opt, features=features, donate=donate)
    per_type = {et: batch_per_type for et in ("uu", "ui", "ii")}

    def one(t):
        batch = jax.tree.map(jnp.asarray,
                             ds.sample_batch(t, seed, per_type, format=fmt))
        return step_fn(state_box[0], batch, jax.random.key(1000 + t))

    # warmup pass over the *same* (seed, step) range the measurement
    # will replay: every pack-size bucket the measured pass can hit is
    # compiled here, so the timing contains no trace/compile events
    state_box = [state]
    m = None
    for t in range(steps):
        state_box[0], m = one(t)
    jax.block_until_ready(m["total"])

    t0 = time.perf_counter()
    for t in range(steps):
        state_box[0], m = one(t)
    jax.block_until_ready(m["total"])
    dt = time.perf_counter() - t0

    edges = 3 * batch_per_type
    out = dict(seconds_per_step=dt / steps,
               edges_per_second=edges * steps / dt,
               total=float(m["total"]))
    print(f"  {name:<10s} {out['seconds_per_step']*1e3:8.1f} ms/step  "
          f"{out['edges_per_second']:9.0f} edges/s  "
          f"(total={out['total']:.3f})")
    return out


def run(full: bool = False) -> Dict:
    import dataclasses
    from repro.core.graph_builder import build_graph
    from repro.core import trainer as T
    from repro.data.edge_dataset import EdgeDataset, build_neighbor_tables
    from repro.data.synthetic import make_world

    cfg = _bench_cfg()
    n_users, n_items = (1200, 3000) if full else (600, 1500)
    steps = 30 if full else 16
    batch_per_type = 256
    world = make_world(n_users=n_users, n_items=n_items,
                       events_per_user=14.0, pop_strength=0.7, seed=7)
    g = build_graph(world.day0, k_cap=cfg.k_cap, seed=7)
    tables = build_neighbor_tables(g, k_imp=cfg.k_imp,
                                   n_walks=cfg.ppr_walks,
                                   walk_len=cfg.ppr_len, seed=7)
    ds = EdgeDataset(g, tables, world.user_feat, world.item_feat,
                     k_train=cfg.k_train)
    feats = T.make_feature_store(world.user_feat, world.item_feat)

    # batch stats: how much work dedup actually removes
    b = ds.sample_batch(0, 7, {et: batch_per_type
                               for et in ("uu", "ui", "ii")})
    slots = 3 * batch_per_type          # endpoint slots per node type
    enc_rows_legacy = 2 * slots * (1 + 2 * cfg.k_train)
    enc_rows_dedup = sum(b["nodes"][t]["feat"].shape[0]
                         for t in ("user", "item"))
    print(f"  encoder rows/step: legacy={enc_rows_legacy} "
          f"dedup={enc_rows_dedup} "
          f"({enc_rows_legacy / enc_rows_dedup:.1f}x dedup)")

    cfg_pr3 = dataclasses.replace(cfg, reuse_lprime_negatives=False)
    kw = dict(steps=steps, batch_per_type=batch_per_type)
    res = {
        "baseline": _time_mode("baseline", cfg_pr3, ds, "legacy",
                               donate=False, **kw),
        "dedup": _time_mode("dedup", cfg, ds, "dedup", **kw),
        "dedup_ids": _time_mode("dedup_ids", cfg, ds, "dedup_ids",
                                features=feats, **kw),
    }
    base = res["baseline"]["seconds_per_step"]
    out = dict(
        config=dict(n_users=n_users, n_items=n_items, steps=steps,
                    batch_per_type=batch_per_type,
                    k_train=cfg.k_train, n_negatives=cfg.n_negatives),
        encoder_rows=dict(legacy=enc_rows_legacy, dedup=enc_rows_dedup),
        modes=res,
        speedup_dedup=base / res["dedup"]["seconds_per_step"],
        speedup_dedup_ids=base / res["dedup_ids"]["seconds_per_step"],
    )
    print(f"  speedup: dedup={out['speedup_dedup']:.2f}x  "
          f"dedup+id-only={out['speedup_dedup_ids']:.2f}x")
    write_result("train_throughput", out)

    # CI gate: the reworked hot path must beat the PR-3 baseline.
    # Shared runners are noisy — tune via TRAIN_MIN_SPEEDUP.
    min_speedup = float(os.environ.get("TRAIN_MIN_SPEEDUP", "1.5"))
    assert out["speedup_dedup_ids"] >= min_speedup, \
        (f"dedup+id-only step only {out['speedup_dedup_ids']:.2f}x over "
         f"baseline (< {min_speedup}x)")
    return out


if __name__ == "__main__":
    run(full=os.environ.get("FULL") == "1")
