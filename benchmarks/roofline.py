"""Roofline analysis over the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, from the compiled SPMD module (all
quantities below are per-chip: XLA's cost analysis describes the
partitioned per-device program, and collective bytes are parsed from the
per-device HLO):

  compute    = HLO_FLOPs / peak_FLOPs           (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw               (819 GB/s)
  collective = collective_bytes / link_bw       (~50 GB/s/link ICI)

Loop-corrected totals come from the dry-run's unrolled probe
extrapolation (XLA counts while bodies once).  The bottleneck is the max
term; projected MFU = ideal_compute_time / bottleneck_time where
ideal = MODEL_FLOPS / (chips * peak).  MODEL_FLOPS is 6*N*D (train) or
2*N*D (inference) for LMs and analytic counts elsewhere; the waste
ratio MODEL_FLOPS / (HLO_FLOPs * chips) flags remat / routing overhead.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def analyse_record(rec: Dict) -> Dict:
    chips = rec["n_chips"]
    c = rec.get("corrected") or {}
    flops = c.get("flops", rec["flops"])             # per-chip
    byts = c.get("bytes_accessed", rec["bytes_accessed"])
    coll = c.get("collective_total",
                 rec.get("collective", {}).get("total", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    t_star = terms[bottleneck]
    ideal = rec["model_flops"] / (chips * PEAK_FLOPS)
    mfu = ideal / t_star if t_star > 0 else 0.0
    waste = rec["model_flops"] / max(flops * chips, 1e-30)
    mem = rec.get("memory", {})
    hbm = (mem.get("argument_size_in_bytes", 0)
           + mem.get("temp_size_in_bytes", 0)
           + mem.get("output_size_in_bytes", 0))
    advice = {
        "compute": "compute-bound: raise useful-FLOP fraction (less "
                   "remat / routing waste) or shrink redundant compute",
        "memory": "HBM-bound: fuse/bf16-ify intermediates, improve "
                  "layouts, cut activation round-trips",
        "collective": "collective-bound: reshard to cut all-gathers, "
                      "overlap collectives with compute, compress "
                      "cross-pod gradients",
    }[bottleneck]
    return dict(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                chips=chips, t_compute=t_compute, t_memory=t_memory,
                t_collective=t_coll, bottleneck=bottleneck,
                projected_mfu=mfu, useful_flop_ratio=min(waste, 10.0),
                hbm_per_chip_gib=hbm / 2**30, ideal_s=ideal,
                step_s=t_star, advice=advice,
                method=c.get("method", "exact"))


def load_all(mesh: Optional[str] = None) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*", "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec["mesh"] != mesh:
            continue
        out.append(analyse_record(rec))
    return out


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | coll s | "
           "bottleneck | proj. MFU | useful/HLO | HBM GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']:.2e} | {r['t_memory']:.2e} "
            f"| {r['t_collective']:.2e} | **{r['bottleneck']}** "
            f"| {r['projected_mfu']*100:.1f}% "
            f"| {r['useful_flop_ratio']:.2f} "
            f"| {r['hbm_per_chip_gib']:.1f} |")
    return hdr + "\n".join(lines) + "\n"


def run(full: bool = False) -> Dict:
    rows = load_all()
    if not rows:
        print("\nRoofline: no dry-run artifacts yet "
              "(run python -m repro.launch.dryrun)")
        return {}
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    print(f"\nRoofline ({len(rows)} cells):")
    for r in rows:
        print(f"  {r['arch']:<18s} {r['shape']:<15s} {r['mesh']:<9s} "
              f"[{r['bottleneck']:<10s}] mfu={r['projected_mfu']*100:5.1f}% "
              f"c/m/x = {r['t_compute']:.1e}/{r['t_memory']:.1e}/"
              f"{r['t_collective']:.1e}s hbm={r['hbm_per_chip_gib']:.1f}GiB")
    out_path = os.path.join(os.path.dirname(__file__), "results",
                            "roofline.json")
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    with open(os.path.join(os.path.dirname(__file__), "results",
                           "roofline.md"), "w") as f:
        f.write(markdown_table(rows))
    return {"rows": rows}


if __name__ == "__main__":
    run()
