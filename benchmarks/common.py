"""Shared benchmark scaffolding: one synthetic world + cached pipelines."""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, Optional

import numpy as np

from repro.configs.base import RankGraph2Config, RQConfig
from repro.core.pipeline import PipelineResult, run_pipeline
from repro.data.synthetic import SyntheticWorld, make_world

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
os.makedirs(RESULTS_DIR, exist_ok=True)

# benchmark scale (CPU container); --full doubles it
# world difficulty: high feature noise (self features alone are weak —
# neighborhood aggregation must denoise them => the graph carries the
# signal) + sparse engagement over a larger item space (no recall
# saturation).
QUICK = dict(n_users=700, n_items=1800, events_per_user=14.0,
             steps=400, batch=96, feat_noise=1.8, pop_strength=0.5,
             temp=0.12, noise_frac=0.0)
FULL = dict(n_users=1600, n_items=4000, events_per_user=16.0,
            steps=700, batch=128, feat_noise=1.8, pop_strength=0.5,
            temp=0.12, noise_frac=0.0)


def bench_config(scale: Dict) -> RankGraph2Config:
    return RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=48, n_heads=2,
        d_hidden=128, k_imp=20, k_train=8, n_negatives=50, n_pool_neg=16,
        k_cap=32, ppr_walks=32, ppr_len=4, ppr_restart=0.3,
        rq=RQConfig(codebook_sizes=(64, 16), hist_len=100),
        dtype="float32")


@functools.lru_cache(maxsize=4)
def get_world(full: bool = False) -> SyntheticWorld:
    s = FULL if full else QUICK
    return make_world(n_users=s["n_users"], n_items=s["n_items"],
                      events_per_user=s["events_per_user"],
                      feat_noise=s["feat_noise"],
                      pop_strength=s["pop_strength"], temp=s["temp"],
                      noise_frac=s["noise_frac"], seed=7)


_PIPELINES: Dict[str, PipelineResult] = {}


def get_pipeline(tag: str, full: bool = False, **kw) -> PipelineResult:
    key = f"{tag}|{full}"
    if key not in _PIPELINES:
        s = FULL if full else QUICK
        world = get_world(full)
        cfg = kw.pop("cfg", bench_config(s))
        t0 = time.perf_counter()
        _PIPELINES[key] = run_pipeline(world, cfg, steps=s["steps"],
                                       batch_per_type=s["batch"], **kw)
        print(f"  [pipeline:{tag}] trained in "
              f"{time.perf_counter()-t0:.1f}s")
    return _PIPELINES[key]


def write_result(name: str, payload: Dict) -> str:
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def fmt_recall_row(name: str, r: Dict[int, float]) -> str:
    return (f"{name:<28s}" + "".join(
        f"  @{k}={r[k]:.3f}" for k in sorted(r)))
