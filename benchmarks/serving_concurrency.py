"""Multithreaded serving stress gate: R readers + W writers across
hot-swaps, with a thread-scaling throughput floor.

What this establishes (and CI gates):

  * **zero mixed-version responses** — while a swap storm flips
    versions under R concurrent reader threads and W concurrent ingest
    writers, every ``serve_batch`` response must be internally
    consistent with exactly the snapshot version it reports (every
    union candidate comes from that version's I2I rows of that
    response's own seeds);
  * **zero lost events** — after the storm quiesces, the live store is
    *bitwise* identical to a single-threaded oracle fed the same event
    stream (the post-flip ring drain means nothing ingested during a
    swap's catch-up/flip window can vanish);
  * the same properties hold with the swaps triggered through the
    lifecycle orchestrator (``LifecycleRuntime.run_cycle`` publishing
    real snapshots while traffic runs);
  * **thread scaling** — 4 reader threads sustain at least
    ``SERVE_MIN_THREAD_SPEEDUP`` x the single-thread ``retrieve_batch``
    throughput on one shared *host-engine* store (per-thread scratch
    pools + the lock-free seqlock read path are what make this
    possible; numpy releases the GIL inside the big gather/sort
    kernels).  The device engine's thread gate — uncapped, and held to
    a higher floor — lives in ``benchmarks/serving_scaleout.py``;
  * **telemetry under contention** — the whole run executes with the
    process telemetry enabled, and the contention counters the obs
    layer exists to surface (seqlock retries, ring drops, repair
    bursts) must actually be nonzero by the end.

Results land in ``benchmarks/results/serving_concurrency.json``; the
telemetry trace in ``benchmarks/results/serving_concurrency_obs.jsonl``.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import RESULTS_DIR, write_result
from repro import obs
from repro.core.serving import ClusterQueueStore, HostQueueStore
from repro.lifecycle.snapshot import IndexSnapshot, derive_members
from repro.lifecycle.swap import SwapServer

N_READERS = 4
N_WRITERS = 2
N_SWAPS = 3


# ---------------------------------------------------------------------------
# phase 1: synthetic-snapshot storm with a bitwise oracle
# ---------------------------------------------------------------------------

def _mk_snapshot(version: int, flip: int, n_users: int, n_items: int,
                 n_clusters: int, i2i_k: int) -> IndexSnapshot:
    """Version-distinct cluster layout + I2I table.  The layout keeps
    ``cluster % N_WRITERS == user % N_WRITERS`` in every version, so
    each writer thread owns a disjoint cluster set and the per-cluster
    event order is its timestamp order — which is what lets the oracle
    comparison below be bitwise rather than set-based."""
    flat = ((np.arange(n_users) + flip * 3 * N_WRITERS)
            % n_clusters).astype(np.int64)
    ptr, ids = derive_members(flat, n_clusters)
    codes = np.stack([flat // 2, flat % 2], axis=1).astype(np.int32)
    i2i = ((np.arange(n_items)[:, None]
            + 1 + flip * 7 + 13 * np.arange(i2i_k)[None, :])
           % n_items).astype(np.int64)
    return IndexSnapshot(
        user_codes=codes, item_codes=np.zeros((n_items, 2), np.int32),
        user_clusters=flat, member_ptr=ptr, member_ids=ids,
        coarse_codebook=np.zeros((4, 4), np.float32), i2i=i2i,
        version=version, n_users=n_users, n_items=n_items,
        codebook_sizes=(n_clusters // 2, 2))


def _count_mixed(responses: List, i2i_by_version: Dict[int, np.ndarray]
                 ) -> int:
    """A response mixes versions iff a union candidate is absent from
    the reported version's I2I rows of the response's own seeds."""
    mixed = 0
    for ver, seeds, union in responses:
        i2i = i2i_by_version[ver]
        allowed = i2i[np.where(seeds >= 0, seeds, 0)]      # (B, R, K)
        allowed = np.where(seeds[:, :, None] >= 0, allowed, -2)
        ok = ((union[:, :, None, None] == allowed[:, None, :, :])
              .any(axis=(2, 3)) | (union == -1))
        mixed += int((~ok).any(axis=1).sum())
    return mixed


def _storm(full: bool) -> Dict:
    n_users, n_items, n_clusters = 4000, 3000, 32
    n_iter = 240 if full else 120
    snaps = [_mk_snapshot(v, flip=v % 2, n_users=n_users,
                          n_items=n_items, n_clusters=n_clusters,
                          i2i_k=6) for v in range(1, N_SWAPS + 2)]
    i2i_by_version = {s.version: s.i2i for s in snaps}
    server = SwapServer(snaps[0], queue_len=64, recency_s=1e15,
                        ring_capacity=1 << 15)
    now = 1e9
    stop = threading.Event()
    errs: List = []
    per_writer: List[List] = [[] for _ in range(N_WRITERS)]
    responses: List = []
    resp_lock = threading.Lock()

    def writer(w: int):
        try:
            rng = np.random.default_rng(10 + w)
            for step in range(n_iter):
                n = int(rng.integers(1, 16))
                u = (rng.integers(0, n_users // N_WRITERS, n) * N_WRITERS
                     + w)
                it = rng.integers(0, n_items, n)
                ts = ((np.arange(n) + step * 32) * N_WRITERS
                      + w).astype(float)
                per_writer[w].append((u, it, ts))
                server.ingest(u, it, ts)
        except Exception as e:                 # pragma: no cover
            errs.append(e)

    def reader(r: int):
        try:
            rng = np.random.default_rng(20 + r)
            local = []
            while not stop.is_set():
                users = rng.integers(0, n_users, 64)
                seeds, union, ver = server.serve_batch(
                    users, now, n_recent=4, k=16)
                local.append((ver, seeds, union))
                res, ver2 = server.retrieve_batch(users, now, 8)
                assert ((res == -1)
                        | ((res >= 0) & (res < n_items))).all()
            with resp_lock:
                responses.extend(local)
        except Exception as e:                 # pragma: no cover
            errs.append(e)

    writers = [threading.Thread(target=writer, args=(w,))
               for w in range(N_WRITERS)]
    readers = [threading.Thread(target=reader, args=(r,))
               for r in range(N_READERS)]
    t0 = time.perf_counter()
    for t in writers + readers:
        t.start()
    stall_ms = []
    for snap in snaps[1:]:                     # >= N_SWAPS hot swaps
        time.sleep(0.05)
        rep = server.swap_to(snap, now)
        stall_ms.append(rep["stall_ms"])
    for t in writers:
        t.join()
    stop.set()
    for t in readers:
        t.join()
    storm_s = time.perf_counter() - t0
    if errs:
        raise errs[0]

    # quiesce + bitwise oracle for the final version
    server._drain_into(server.handle.acquire())
    final = server.handle.acquire()
    ev = [np.concatenate(x) for x in zip(
        *(e for w in per_writer for e in w))]
    order = np.argsort(ev[2], kind="stable")
    oracle = ClusterQueueStore(final.snapshot.user_clusters,
                               queue_len=64, recency_s=1e15,
                               n_clusters=final.snapshot.n_clusters)
    oracle.ingest(ev[0][order], ev[1][order], ev[2][order])
    lost = int(np.abs(final.store.cursor - oracle.cursor).sum())
    users = np.arange(n_users)
    got, ver = server.retrieve_batch(users, now, 32)
    assert ver == final.version
    bitwise_equal = bool(
        np.array_equal(got, oracle.retrieve_batch(users, now, 32))
        and np.array_equal(final.store.items, oracle.items))
    mixed = _count_mixed(responses, i2i_by_version)
    return dict(events=int(len(ev[0])), swaps=len(stall_ms),
                responses=len(responses), mixed_version=mixed,
                lost_events=lost, bitwise_equal=bitwise_equal,
                storm_s=storm_s, stall_ms_max=float(np.max(stall_ms)))


# ---------------------------------------------------------------------------
# phase 2: run_cycle-triggered swaps under live traffic
# ---------------------------------------------------------------------------

def _lifecycle_storm(full: bool) -> Dict:
    from repro.configs.base import RankGraph2Config, RQConfig
    from repro.core.graph_builder import build_graph
    from repro.data.edge_dataset import build_neighbor_tables
    from repro.data.synthetic import make_world
    from repro.lifecycle import LifecycleConfig, LifecycleRuntime

    world = make_world(n_users=400, n_items=600, events_per_user=15.0,
                       seed=3)
    cfg = RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=24, n_heads=2,
        d_hidden=48, k_imp=10, k_train=4, n_negatives=16, n_pool_neg=4,
        rq=RQConfig(codebook_sizes=(8, 4), hist_len=20), dtype="float32")
    # queue_len exceeds the bounded event budget below: with shared
    # clusters, eviction order would be schedule-dependent, so the
    # oracle check requires that no cluster ever evicts
    lcfg = LifecycleConfig(steps_per_cycle=8 if full else 4,
                           batch_per_type=16, recall_queries=40,
                           recall_k=20, queue_len=4096, recency_s=1e15,
                           repair_steps=2)
    g = build_graph(world.day0, k_cap=16, hub_cap=12, keep_state=True)
    tables = build_neighbor_tables(g, k_imp=10, n_walks=12, walk_len=3,
                                   keep_state=True)
    rt = LifecycleRuntime(cfg, lcfg, g, tables, world.user_feat,
                          world.item_feat, world=world, seed=0)
    rt.run_cycle(now=1e9)                      # brings serving up (v1)
    now = 1e9
    stop = threading.Event()
    errs: List = []
    pushed: List = []
    push_lock = threading.Lock()
    seen_versions = set()

    def writer(w: int):
        try:
            rng = np.random.default_rng(40 + w)
            for step in range(150):            # bounded: <= 2250 events
                if stop.is_set():              # per writer, < queue_len
                    break
                n = int(rng.integers(1, 16))
                u = rng.integers(0, world.n_users, n)
                it = rng.integers(0, world.n_items, n)
                ts = ((np.arange(n) + step * 32) * N_WRITERS
                      + w).astype(float)
                with push_lock:
                    pushed.append((u, it, ts))
                rt.server.ingest(u, it, ts)
                time.sleep(0.002)
        except Exception as e:                 # pragma: no cover
            errs.append(e)

    def reader(r: int):
        try:
            rng = np.random.default_rng(50 + r)
            while not stop.is_set():
                users = rng.integers(0, world.n_users, 32)
                res, ver = rt.server.retrieve_batch(users, now, 8)
                seen_versions.add(ver)
                assert ((res == -1)
                        | ((res >= 0) & (res < world.n_items))).all()
        except Exception as e:                 # pragma: no cover
            errs.append(e)

    ths = ([threading.Thread(target=writer, args=(w,))
            for w in range(N_WRITERS)]
           + [threading.Thread(target=reader, args=(r,))
              for r in range(N_READERS)])
    for t in ths:
        t.start()
    try:
        for _ in range(N_SWAPS):               # publish + swap live
            rt.run_cycle(now=now)
    finally:
        stop.set()
        for t in ths:
            t.join()
    if errs:
        raise errs[0]

    # set-based lost-event check (writers share clusters here, so slot
    # order is schedule-dependent — membership per cluster is not)
    rt.server._drain_into(rt.server.handle.acquire())
    final = rt.server.handle.acquire()
    ev = [np.concatenate(x) for x in zip(*pushed)]
    oracle = ClusterQueueStore(final.snapshot.user_clusters,
                               queue_len=4096, recency_s=1e15,
                               n_clusters=final.snapshot.n_clusters)
    oracle.ingest(*ev)
    lost = int(np.abs(final.store.cursor - oracle.cursor).sum())
    same_members = bool(np.array_equal(
        np.sort(final.store.items, axis=1),
        np.sort(oracle.items, axis=1)))
    # one explicit repair burst so its outcome counters/spans are part
    # of the stress trace (the healthy cycles above never trip a gate)
    repair = rt.repair_burst(rt.publish())
    return dict(events=int(len(ev[0])), cycles=N_SWAPS + 1,
                versions_seen=sorted(int(v) for v in seen_versions),
                final_version=int(final.version), lost_events=lost,
                same_members=same_members,
                repair_resets=int(sum(repair["resets"].values())))


# ---------------------------------------------------------------------------
# phase 3: reader-thread throughput scaling
# ---------------------------------------------------------------------------

def _thread_scaling_of(fn, n_iter: int, nthreads: int) -> float:
    """Aggregate-throughput speedup of ``nthreads`` threads each running
    ``fn`` ``n_iter`` times vs one thread doing the same."""
    fn()                                       # warm (pools, caches)
    t0 = time.perf_counter()
    for _ in range(n_iter):
        fn()
    t1 = time.perf_counter() - t0

    def loop():
        for _ in range(n_iter):
            fn()

    ths = [threading.Thread(target=loop) for _ in range(nthreads)]
    t0 = time.perf_counter()
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    tn = time.perf_counter() - t0
    return float(nthreads * t1 / tn), float(n_iter / t1)


def _scaling(full: bool) -> Dict:
    import sys
    rng = np.random.default_rng(0)
    n_users, n_items, C = 50_000, 20_000, 512
    store = HostQueueStore(rng.integers(0, C, n_users),
                           queue_len=256, recency_s=1e15)
    for _ in range(4):
        store.ingest(rng.integers(0, n_users, 100_000),
                     rng.integers(0, n_items, 100_000),
                     rng.integers(0, 10_000, 100_000).astype(float))
    B, k, now = 4096, 32, 1e6
    users = rng.integers(0, n_users, B)
    n_iter = 16 if full else 8

    # machine calibration: what 4-thread scaling does this box give a
    # *pure* GIL-releasing numpy workload of comparable shape?  On a
    # dedicated 4-core runner this lands near 3x; on throttled/shared
    # 2-core containers it can be barely above 1x, and retrieval cannot
    # be expected to beat the hardware.
    ref = rng.integers(0, 1 << 30, (B, store.queue_len)).astype(np.int64)

    def calib_fn():
        c = ref.copy()
        c.sort(axis=1)
        c.partition(31, axis=1)

    old_si = sys.getswitchinterval()
    sys.setswitchinterval(5e-4)   # soften the GIL convoy between ops
    try:
        calib, _ = _thread_scaling_of(calib_fn, n_iter, N_READERS)
        speedup, batches_s = _thread_scaling_of(
            lambda: store.retrieve_batch(users, now, k),
            n_iter, N_READERS)
    finally:
        sys.setswitchinterval(old_si)
    return dict(threads=N_READERS, batch=B,
                thr_1thread_req_s=float(batches_s * B),
                machine_calib_speedup=calib,
                thread_speedup=speedup,
                parallel_efficiency=float(speedup / calib))


# ---------------------------------------------------------------------------
# phase 4: deterministic contention probes for the obs counters
# ---------------------------------------------------------------------------

def _contention_probes() -> Dict:
    """Force the rare paths the storms only hit probabilistically, so
    the counter gate below is deterministic: a writer holding every
    cluster generation odd (the mid-scatter window) while readers
    retrieve — seqlock retries and fallbacks — and one push larger than
    a tiny ring — a ring drop."""
    tel = obs.get_telemetry()
    before = tel.snapshot()["counters"]
    rng = np.random.default_rng(0)
    n_users, C = 256, 16
    store = HostQueueStore(rng.integers(0, C, n_users), queue_len=32,
                           recency_s=1e15)
    store.ingest(rng.integers(0, n_users, 2000),
                 rng.integers(0, 1000, 2000),
                 rng.integers(0, 1000, 2000).astype(float))
    stop = threading.Event()
    errs: List = []

    def writer():
        try:
            while not stop.is_set():
                with store.write_lock:
                    store.gen += 1             # odd: readers must respin
                    time.sleep(2e-4)
                    store.gen += 1
                time.sleep(0)
        except Exception as e:                 # pragma: no cover
            errs.append(e)

    def reader():
        try:
            users = np.arange(n_users)
            for _ in range(100):
                store.retrieve_batch(users, 1e9, 8)
        except Exception as e:                 # pragma: no cover
            errs.append(e)

    wt = threading.Thread(target=writer)
    rts = [threading.Thread(target=reader) for _ in range(2)]
    wt.start()
    for t in rts:
        t.start()
    for t in rts:
        t.join()
    stop.set()
    wt.join()
    if errs:
        raise errs[0]

    server = SwapServer(
        _mk_snapshot(1, flip=0, n_users=64, n_items=64, n_clusters=8,
                     i2i_k=4),
        queue_len=16, recency_s=1e15, ring_capacity=256)
    big = 1024                                 # > the whole ring
    server.ingest(np.zeros(big, np.int64), np.zeros(big, np.int64),
                  np.arange(big, dtype=float))

    after = tel.snapshot()["counters"]
    return {k: after.get(k, 0.0) - before.get(k, 0.0)
            for k in ("serving.seqlock_retries",
                      "serving.seqlock_fallbacks", "swap.ring_dropped")}


def run(full: bool = False) -> Dict:
    # the whole stress run executes with telemetry on — the trace is a
    # benchmark artifact, and the counter gate below is the proof the
    # contention instrumentation fires outside unit-test conditions
    trace_path = os.path.join(RESULTS_DIR,
                              "serving_concurrency_obs.jsonl")
    if os.path.exists(trace_path):
        os.remove(trace_path)
    tel = obs.configure(path=trace_path)
    tel.reset_metrics()
    out: Dict = {}
    out["storm"] = _storm(full)
    out["lifecycle"] = _lifecycle_storm(full)
    out["scaling"] = _scaling(full)
    out["thread_speedup"] = out["scaling"]["thread_speedup"]
    out["probes"] = _contention_probes()

    s, lc, sc = out["storm"], out["lifecycle"], out["scaling"]
    print("\nServing concurrency stress:")
    print(f"  storm: {s['responses']} responses over {s['swaps']} swaps "
          f"+ {s['events']} events -> {s['mixed_version']} mixed-version, "
          f"{s['lost_events']} lost, bitwise_equal={s['bitwise_equal']}")
    print(f"  lifecycle: versions {lc['versions_seen']} live during "
          f"{lc['cycles']} run_cycle(s) -> {lc['lost_events']} lost, "
          f"same_members={lc['same_members']}")
    print(f"  scaling: {sc['thr_1thread_req_s']:.0f} req/s x1; "
          f"{sc['threads']}-thread speedup {sc['thread_speedup']:.2f}x "
          f"(machine ceiling {sc['machine_calib_speedup']:.2f}x, "
          f"efficiency {sc['parallel_efficiency']:.2f})")
    counters = tel.snapshot()["counters"]
    out["counters"] = counters
    print(f"  telemetry: retries={counters.get('serving.seqlock_retries', 0):.0f} "
          f"fallbacks={counters.get('serving.seqlock_fallbacks', 0):.0f} "
          f"ring_dropped={counters.get('swap.ring_dropped', 0):.0f} "
          f"repair_bursts={counters.get('lifecycle.repair_bursts', 0):.0f} "
          f"requests={counters.get('serving.retrieve_requests', 0):.0f}")

    # acceptance gates
    assert s["mixed_version"] == 0, "mixed-version responses observed"
    assert s["lost_events"] == 0 and s["bitwise_equal"], \
        "storm final state diverged from the single-threaded oracle"
    assert s["swaps"] >= N_SWAPS
    assert lc["lost_events"] == 0 and lc["same_members"], \
        "run_cycle storm lost events vs the single-threaded oracle"
    # the scaling floor is the configured speedup wherever the machine
    # demonstrably has that much parallel headroom (the calibration
    # kernel is pure GIL-releasing numpy); on throttled shared boxes
    # retrieval is instead held to a fraction of the measured ceiling
    gate = float(os.environ.get("SERVE_MIN_THREAD_SPEEDUP", "2.0"))
    eff_floor = float(os.environ.get("SERVE_MIN_THREAD_EFFICIENCY",
                                     "0.6"))
    floor = min(gate, eff_floor * sc["machine_calib_speedup"])
    assert out["thread_speedup"] >= floor, \
        (f"{sc['threads']}-thread retrieve speedup "
         f"{out['thread_speedup']:.2f}x < floor {floor:.2f}x "
         f"(gate {gate}x, machine ceiling "
         f"{sc['machine_calib_speedup']:.2f}x)")
    # the contention counters the obs layer exists for must have fired
    assert counters.get("serving.seqlock_retries", 0) > 0, \
        "no seqlock retries recorded under contention"
    assert counters.get("swap.ring_dropped", 0) > 0, \
        "no ring drops recorded (oversized-push probe)"
    assert counters.get("lifecycle.repair_bursts", 0) > 0, \
        "no repair bursts recorded"
    tel.flush()
    obs.configure(enabled=False)   # don't tax later benchmarks
    write_result("serving_concurrency", out)
    return out


if __name__ == "__main__":
    run(full=os.environ.get("BENCH_FULL", "") == "1")
