"""Static VMEM residency report for every Pallas kernel.

Thin benchmark-harness wrapper around the ``vmem-budget`` analysis rule:
re-derives each kernel's estimated VMEM working set at production dims
and writes ``benchmarks/results/vmem_report.json``.  Purely static — no
devices, no compilation — so it runs anywhere the repo imports.
"""
from __future__ import annotations

import os

from repro.analysis import DEFAULT_BUDGET_BYTES, vmem_report

RESULTS = os.path.join(os.path.dirname(__file__), "results",
                       "vmem_report.json")
KERNELS = os.path.join(os.path.dirname(__file__), os.pardir, "src",
                       "repro", "kernels")


def run(full: bool = False) -> dict:
    report = vmem_report(budget_bytes=DEFAULT_BUDGET_BYTES,
                         report_path=RESULTS,
                         kernels_path=os.path.normpath(KERNELS))
    print(f"{report['n_kernels']} kernels, "
          f"{report['n_over_budget']} over the "
          f"{report['budget_mib']:.0f} MiB budget")
    for k in report["kernels"]:
        flag = "  OVER (suppressed with reason)" if k["over_budget"] else ""
        print(f"  {k['kernel']:36s} {k['vmem_mib']:8.3f} MiB{flag}")
    return report


if __name__ == "__main__":
    run()
