"""Paper-table benchmarks (Tables 2-8), one function per table.

All run on the shared synthetic world (see DESIGN.md §6: the paper's
corpora are proprietary and public sets don't exhibit the scale
phenomena; we validate the *qualitative orderings* the paper claims and
report our absolute numbers).
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (bench_config, fmt_recall_row, get_pipeline,
                               get_world, write_result, QUICK, FULL)
from repro.core import evaluation as EV


# ---------------------------------------------------------------------------
# Table 2: user-embedding recall (RankGraph-2 vs GAT-DGI vs HSTU-proxy)
# ---------------------------------------------------------------------------

def table2_user_recall(full: bool = False) -> Dict:
    world = get_world(full)
    s = FULL if full else QUICK
    res = get_pipeline("main", full)
    rows = {}
    rows["rankgraph2"] = EV.user_recall(res.user_emb, world)

    from repro.baselines.gat_dgi import GATDGIConfig, train as gat_train
    ue, _ = gat_train(world, res.graph, GATDGIConfig(d_embed=48),
                      steps=max(s["steps"] // 2, 100))
    rows["gat_dgi (bipartite)"] = EV.user_recall(ue, world)

    from repro.baselines.seqrec import SeqRecConfig, train as seq_train
    ue, _ = seq_train(world.day0, SeqRecConfig(d_embed=48),
                      steps=max(s["steps"] // 2, 100))
    rows["seqrec (HSTU-proxy)"] = EV.user_recall(ue, world)

    print("\nTable 2 — user embedding Recall@K (U2U2I protocol):")
    for name, r in rows.items():
        print("  " + fmt_recall_row(name, r))
    write_result("table2_user_recall", rows)
    return rows


# ---------------------------------------------------------------------------
# Table 3: item-embedding recall (RankGraph-2 vs PBG vs HSTU-proxy)
# ---------------------------------------------------------------------------

def table3_item_recall(full: bool = False) -> Dict:
    world = get_world(full)
    s = FULL if full else QUICK
    res = get_pipeline("main", full)
    rows = {}
    rows["rankgraph2"] = EV.item_recall(res.item_emb, world)

    from repro.baselines.biggraph import PBGConfig, train as pbg_train
    _, ie = pbg_train(res.graph, PBGConfig(d_embed=48),
                      steps=max(s["steps"], 200))
    rows["pbg (translational)"] = EV.item_recall(ie, world)

    from repro.baselines.seqrec import SeqRecConfig, train as seq_train
    _, ie = seq_train(world.day0, SeqRecConfig(d_embed=48),
                      steps=max(s["steps"] // 2, 100))
    rows["seqrec (HSTU-proxy)"] = EV.item_recall(ie, world)

    print("\nTable 3 — item embedding Recall@K (next-day I-I protocol):")
    for name, r in rows.items():
        print("  " + fmt_recall_row(name, r))
    write_result("table3_item_recall", rows)
    return rows


# ---------------------------------------------------------------------------
# Table 4: learned-index hitrate, with vs without regularization
# ---------------------------------------------------------------------------

def table4_index_hitrate(full: bool = False) -> Dict:
    import dataclasses as dc
    from repro.core import rq_index as RQ
    world = get_world(full)
    res = get_pipeline("main", full)
    res_noreg = get_pipeline(
        "noreg", full,
        cfg=dc.replace(bench_config(QUICK),
                       rq=dc.replace(bench_config(QUICK).rq,
                                     regularize=False,
                                     biased_selection=False)))
    # positive pairs: day-0 U-I edges mapped into the shared embed space
    g = res.graph
    rng = np.random.default_rng(3)
    idx = rng.integers(0, len(g.ui), min(400, len(g.ui)))
    emb = np.concatenate([res.user_emb, res.item_emb], 0)
    pairs = np.stack([g.ui.src[idx], g.n_users + g.ui.dst[idx]], 1)

    def recon_of(r):
        e = np.concatenate([r.user_emb, r.item_emb], 0)
        codes = RQ.assign_codes(r.state.params["rq"], jnp.asarray(e),
                                r.cfg.rq)
        # reconstruct from codes
        resid_codes = []
        flat = np.asarray(codes)
        sizes = r.cfg.rq.codebook_sizes
        cs = []
        rem = flat
        for n in reversed(sizes):
            cs.append(rem % n)
            rem = rem // n
        layer_codes = np.stack(list(reversed(cs)), axis=1)
        return np.asarray(RQ.reconstruct(r.state.params["rq"],
                                         jnp.asarray(layer_codes),
                                         r.cfg.rq)), e

    recon, emb = recon_of(res)
    recon_nr, emb_nr = recon_of(res_noreg)
    nrange = (g.n_users, g.n_users + res.graph.n_items)
    hr_orig, hr_recon = EV.index_hitrate(emb, recon, pairs,
                                         neg_range=nrange)
    _, hr_recon_nr = EV.index_hitrate(emb_nr, recon_nr, pairs,
                                      neg_range=nrange)
    util = RQ.codebook_utilization(res.state.rq_state)
    util_nr = RQ.codebook_utilization(res_noreg.state.rq_state)

    rows = {"original": hr_orig, "recon (with reg)": hr_recon,
            "recon (no reg)": hr_recon_nr,
            "utilization": {1: util[0], 5: util[1] if len(util) > 1
                            else util[0], 10: float(np.mean(util))},
            "utilization_noreg": {1: util_nr[0],
                                  5: util_nr[1] if len(util_nr) > 1
                                  else util_nr[0],
                                  10: float(np.mean(util_nr))}}
    print("\nTable 4 — learned index Hitrate@K + codebook utilization:")
    for name in ("original", "recon (with reg)", "recon (no reg)"):
        print("  " + fmt_recall_row(name, rows[name]))
    print(f"  codebook utilization  with reg: {util}   "
          f"without reg: {util_nr}")
    write_result("table4_index_hitrate", rows)
    return rows


# ---------------------------------------------------------------------------
# Table 5: edge-type ablation
# ---------------------------------------------------------------------------

def table5_edge_types(full: bool = False) -> Dict:
    world = get_world(full)
    rows = {}
    for name, types in [("U-I only", ("ui",)),
                        ("U-I + I-I", ("ui", "ii")),
                        ("U-I + U-U", ("ui", "uu")),
                        ("U-I + U-U + I-I", ("ui", "uu", "ii"))]:
        tag = "main" if len(types) == 3 else f"edges_{'_'.join(types)}"
        res = get_pipeline(tag, full, edge_types=types)
        rows[name] = EV.user_recall(res.user_emb, world)
    print("\nTable 5 — edge-type ablation (user recall):")
    for name, r in rows.items():
        print("  " + fmt_recall_row(name, r))
    write_result("table5_edge_types", rows)
    return rows


# ---------------------------------------------------------------------------
# Table 6: neighbor-selection ablation
# ---------------------------------------------------------------------------

def table6_neighbors(full: bool = False) -> Dict:
    world = get_world(full)
    rows = {}
    for name, strat in [("Random", "random"), ("Top-weight", "topweight"),
                        ("PPR neighbors", "ppr")]:
        tag = "main" if strat == "ppr" else f"nbrs_{strat}"
        res = get_pipeline(tag, full, neighbor_strategy=strat)
        rows[name] = EV.user_recall(res.user_emb, world)
    print("\nTable 6 — neighbor-strategy ablation (user recall):")
    for name, r in rows.items():
        print("  " + fmt_recall_row(name, r))
    write_result("table6_neighbors", rows)
    return rows


# ---------------------------------------------------------------------------
# Table 7: popularity-bias correction ablation
# ---------------------------------------------------------------------------

def table7_popbias(full: bool = False) -> Dict:
    world = get_world(full)
    rows = {}
    res = get_pipeline("nopop", full, popbias=False)
    rows["w/o correction"] = EV.item_recall(res.item_emb, world)
    res = get_pipeline("main", full)
    rows["w/ correction"] = EV.item_recall(res.item_emb, world)
    print("\nTable 7 — popularity-bias correction (item recall):")
    for name, r in rows.items():
        print("  " + fmt_recall_row(name, r))
    write_result("table7_popbias", rows)
    return rows


# ---------------------------------------------------------------------------
# Table 8 / §5.4: serving cost — cluster index vs online KNN (83% claim)
# ---------------------------------------------------------------------------

def table8_serving_cost(full: bool = False) -> Dict:
    from repro.core.serving import (ClusterQueueStore, ServingCostModel,
                                    build_i2i_knn, u2i2i_retrieve)
    world = get_world(full)
    res = get_pipeline("main", full)

    # cost model at production scale (the paper's 83% claim)
    cm = ServingCostModel()
    reduction = cm.cost_reduction()

    # measured serving-path microbenchmark at our scale
    store = ClusterQueueStore(res.user_codes, recency_s=900.0)
    d1 = world.day1
    store.ingest(d1.user_id, d1.item_id, d1.timestamp)
    now = float(d1.timestamp.max())
    n_req = 2000
    req = np.arange(n_req) % world.n_users
    store.retrieve_batch(req, now, 32)              # warm the scratch pool
    t0 = time.perf_counter()
    store.retrieve_batch(req, now, 32)              # the production path
    t_cluster = (time.perf_counter() - t0) / n_req

    emb = res.user_emb / np.maximum(
        np.linalg.norm(res.user_emb, axis=1, keepdims=True), 1e-8)
    t0 = time.perf_counter()
    for u in range(200):
        sims = emb[u % world.n_users] @ emb.T       # online KNN per request
        np.argpartition(-sims, 32)[:32]
    t_knn = (time.perf_counter() - t0) / 200

    # retrieval quality sanity: cluster retrieval finds relevant items
    day1_items = EV._user_day1_items(world.day1)
    hits = total = 0
    for u in range(min(500, world.n_users)):
        got = set(store.retrieve(u, now, 64))
        if day1_items[u]:
            hits += len(got & day1_items[u])
            total += len(day1_items[u])
    cluster_recall = hits / max(total, 1)

    out = dict(
        modeled_cost_reduction=reduction,
        modeled_knn_bytes_per_req=cm.knn_bytes_per_req(),
        modeled_cluster_bytes_per_req=cm.cluster_bytes_per_req(),
        measured_us_cluster=t_cluster * 1e6,
        measured_us_knn=t_knn * 1e6,
        measured_speedup=t_knn / max(t_cluster, 1e-9),
        cluster_recall_vs_nextday=cluster_recall,
    )
    print("\nTable 8 proxy — serving cost (cluster index vs online KNN):")
    print(f"  modeled cost reduction at production scale: "
          f"{reduction*100:.1f}%  (paper: 83%)")
    print(f"  measured: cluster lookup {out['measured_us_cluster']:.1f}us "
          f"vs KNN {out['measured_us_knn']:.1f}us per request "
          f"({out['measured_speedup']:.0f}x)")
    print(f"  cluster-queue retrieval recall vs next-day: "
          f"{cluster_recall:.3f}")
    write_result("table8_serving_cost", out)
    return out
