"""Fault-recovery benchmark: how fast the lifecycle returns to a
healthy swap after injected failures — and that it never serves garbage
on the way.

Drives :func:`repro.faults.chaos.run_chaos` (the full-coverage seeded
schedule: transient train/gate/refresh faults, a torn leaf, a crash at
the atomic-rename point, bit-rot on recovery load, ring overload, a
flip failure, and a post-swap health regression) and gates on:

  * ``corrupt_serves == 0`` — no probe was ever answered by a version
    that did not pass its publication gate (torn/corrupt snapshots are
    quarantined, gate failures never persist);
  * every chaos invariant (recall floor, exactly-once events, every
    injection traced) holds;
  * ``max_recovery_cycles <= FAULT_MAX_RECOVERY_CYCLES`` (default 2) —
    after *any* disruption (crash, degraded cycle, rollback) the
    runtime is back to a clean, non-degraded swap within that many
    cycles.

Results land in ``benchmarks/results/lifecycle_faults.json``.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import Dict, List

from benchmarks.common import write_result


def _recovery_spans(cycle_log: List[Dict]) -> List[int]:
    """Cycles from each disruption to the next clean forward swap."""

    def clean(c: Dict) -> bool:
        swap = c.get("swap", {})
        return (not c.get("crashed") and not c.get("degraded")
                and not swap.get("skipped") and not swap.get("rolled_back")
                and "to_version" in swap)

    spans = []
    for i, c in enumerate(cycle_log):
        if clean(c):
            continue
        healthy = [j for j in range(i + 1, len(cycle_log))
                   if clean(cycle_log[j])]
        spans.append((healthy[0] - i) if healthy
                     else len(cycle_log) - i)  # never recovered: worst
    return spans


def run(full: bool = False) -> Dict:
    from repro.faults.chaos import REQUIRED_SITES, run_chaos

    max_recovery = int(os.environ.get("FAULT_MAX_RECOVERY_CYCLES", "2"))
    seeds = (0, 1, 2) if full else (0,)
    out: Dict = dict(seeds=list(seeds), gates={})
    worst_recovery = 0
    corrupt_serves = 0

    for seed in seeds:
        with tempfile.TemporaryDirectory() as d:
            t0 = time.perf_counter()
            rep = run_chaos(seed, snapshot_dir=os.path.join(d, "snaps"))
            wall = time.perf_counter() - t0
        spans = _recovery_spans(rep["cycle_log"])
        bad = [v for v in rep["served_versions"]
               if v not in rep["good_versions"]]
        corrupt_serves += len(bad)
        worst_recovery = max([worst_recovery] + spans)
        out[f"seed{seed}"] = dict(
            wall_s=wall,
            injected=len(rep["injected"]),
            sites=rep["sites_injected"],
            crashes=rep["crashes"],
            recoveries=rep["recoveries"],
            recovery_spans=spans,
            served_versions=rep["served_versions"],
            corrupt_serves=len(bad),
            duplicates=rep["duplicates"],
            invariants=rep["invariants"],
            counters=rep["counters"],
        )
        assert set(rep["sites_injected"]) >= set(REQUIRED_SITES), \
            f"seed {seed}: schedule missed required fault sites"
        assert all(rep["invariants"].values()), \
            f"seed {seed}: invariant violated: {rep['invariants']}"

    out["max_recovery_cycles"] = worst_recovery
    out["corrupt_serves"] = corrupt_serves
    out["gates"] = dict(fault_max_recovery_cycles=max_recovery,
                        corrupt_serves_allowed=0)
    print(f"  recovery spans (cycles to healthy swap): worst="
          f"{worst_recovery} (gate <= {max_recovery})")
    print(f"  corrupt serves: {corrupt_serves} (gate == 0)")
    assert corrupt_serves == 0, \
        f"{corrupt_serves} probe(s) answered by a non-gated version"
    assert worst_recovery <= max_recovery, \
        (f"recovery took {worst_recovery} cycles "
         f"(FAULT_MAX_RECOVERY_CYCLES={max_recovery})")
    write_result("lifecycle_faults", out)
    return out


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
