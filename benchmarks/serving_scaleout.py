"""Device-engine scale-out gates: threads and shards.

Two properties of the device-resident serving engine are measured and
CI-gated here (the host engine's own thread-scaling floor lives in
``benchmarks/serving_concurrency.py``):

  * **Gate A — thread fan-out**: 4 reader threads hammering one store
    (with a background writer ingesting ~50k events/s) must sustain at
    least ``SCALEOUT_MIN_SPEEDUP`` x (default 3x) the *host engine's*
    aggregate 4-thread ``retrieve_batch`` throughput.  Unlike the host
    gate, there is **no machine-calibration cap**: the device path does
    not depend on the box having parallel numpy headroom — each request
    is one fused XLA dispatch that releases the GIL for its whole
    duration, so the floor must hold even on a throttled single-core
    container (where it is expected to hold by the *widest* margin,
    since the host path is GIL-bound precisely there).
  * **Gate B — shard scale-out**: mixed ingest+retrieve cycles against
    a ``ShardedQueueStore`` in delta (LSM) write mode must get
    monotonically faster from 1 -> 2 -> 4 shards (each step within
    ``SCALEOUT_SHARD_TOL`` of monotone, default 0.95, absorbing
    scheduler noise).  Sharding cuts each ingest's scatter and each
    fold to 1/S of the cluster space; this gate is what keeps the
    router's scatter/gather overhead from eating that win.

Results land in ``benchmarks/results/serving_scaleout.json``.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict

import numpy as np

from benchmarks.common import write_result
from repro.core.serving import (ClusterQueueStore, HostQueueStore,
                                ShardedQueueStore)

N_THREADS = 4


def _agg_throughput(fn, n_iter: int, nthreads: int) -> float:
    """Aggregate calls/s of ``nthreads`` threads each running ``fn``
    ``n_iter`` times, released together off a barrier."""
    barrier = threading.Barrier(nthreads + 1)
    errs = []

    def loop():
        try:
            barrier.wait()
            for _ in range(n_iter):
                fn()
        except Exception as e:                 # pragma: no cover
            errs.append(e)

    ths = [threading.Thread(target=loop) for _ in range(nthreads)]
    for t in ths:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in ths:
        t.join()
    dt = time.perf_counter() - t0
    if errs:
        raise errs[0]
    return nthreads * n_iter / dt


# ---------------------------------------------------------------------------
# gate A: 4-thread retrieve throughput, device vs host, live writer
# ---------------------------------------------------------------------------

def _thread_gate(full: bool) -> Dict:
    rng = np.random.default_rng(0)
    n_users, n_items, C, Q = 50_000, 20_000, 512, 256
    uc = rng.integers(0, C, n_users)
    dev = ClusterQueueStore(uc, queue_len=Q, recency_s=1e15)
    host = HostQueueStore(uc, queue_len=Q, recency_s=1e15)
    for _ in range(4):
        u = rng.integers(0, n_users, 100_000)
        it = rng.integers(0, n_items, 100_000)
        ts = np.sort(rng.uniform(0, 10_000, 100_000))
        dev.ingest(u, it, ts)
        host.ingest(u, it, ts)
    B, k, now = 4096, 32, 1e6
    users = rng.integers(0, n_users, B)
    n_iter = 12 if full else 6
    out: Dict = {"threads": N_THREADS, "batch": B}

    for name, store in (("host", host), ("device", dev)):
        def fn(store=store):
            store.retrieve_batch(users, now, k)

        for _ in range(3):
            fn()                               # warm traces + pools
        t0 = time.perf_counter()
        for _ in range(2 * n_iter):
            fn()
        thr1 = 2 * n_iter * B / (time.perf_counter() - t0)

        # background writer: ~50k events/s into the store under test,
        # so the measured read path includes real writer interference
        stop = threading.Event()

        def writer(store=store):
            r = np.random.default_rng(99)
            tb = 2e4
            while not stop.is_set():
                e = 5000
                store.ingest(r.integers(0, n_users, e),
                             r.integers(0, n_items, e),
                             np.sort(r.uniform(0, 1.0, e)) + tb)
                tb += 1.0
                time.sleep(0.1)

        wt = threading.Thread(target=writer)
        wt.start()
        try:
            thr4 = _agg_throughput(fn, n_iter, N_THREADS) * B
        finally:
            stop.set()
            wt.join()
        out[name] = dict(thr_1thread_req_s=float(thr1),
                         thr_4thread_req_s=float(thr4))
    out["speedup_1thread"] = float(out["device"]["thr_1thread_req_s"]
                                   / out["host"]["thr_1thread_req_s"])
    out["speedup_4thread"] = float(out["device"]["thr_4thread_req_s"]
                                   / out["host"]["thr_4thread_req_s"])
    return out


# ---------------------------------------------------------------------------
# gate B: shard-count scaling of mixed ingest+retrieve cycles
# ---------------------------------------------------------------------------

def _shard_gate(full: bool) -> Dict:
    rng = np.random.default_rng(1)
    n_users, n_items = 200_000, 1_000_000
    C, Q, D, k, now = 4096, 256, 512, 32, 1e6
    E = 12_000                                 # events per mixed cycle
    uc = rng.integers(0, C, n_users)
    stores = {s: ShardedQueueStore(uc, n_shards=s, queue_len=Q,
                                   recency_s=1e15, n_clusters=C,
                                   delta_cap=D)
              for s in (1, 2, 4)}
    for _ in range(4):
        u = rng.integers(0, n_users, 100_000)
        it = rng.integers(0, n_items, 100_000)
        ts = np.sort(rng.uniform(0, 10_000, 100_000))
        for st in stores.values():
            st.ingest(u, it, ts)
    users = rng.integers(0, n_users, 2048)
    n_iter = 18 if full else 12

    tb = [3e6]

    def mixed_cycle(st):
        u = rng.integers(0, n_users, E)
        it = rng.integers(0, n_items, E)
        ts = np.sort(rng.uniform(0, 1.0, E)) + tb[0]
        tb[0] += 1.0
        t0 = time.perf_counter()
        st.ingest(u, it, ts)
        t1 = time.perf_counter()
        st.retrieve_batch(users, now, k)
        return t1 - t0, time.perf_counter() - t1

    for _ in range(4):                         # warm: traces incl. folds
        for st in stores.values():
            mixed_cycle(st)
    # rounds are interleaved across the three stores and scored
    # best-of: the container this runs in drifts by integer factors on
    # a scale of seconds, which sequential per-store means would alias
    # straight into the scaling ratios (external noise only ever adds
    # time, so per-store minima are comparable)
    samples = {s: [] for s in stores}
    for _ in range(n_iter):
        for s, st in stores.items():
            samples[s].append(mixed_cycle(st))
    rows: Dict = {}
    for s in stores:
        ti = min(a for a, _ in samples[s])
        tr = min(b for _, b in samples[s])
        best = min(a + b for a, b in samples[s])
        rows[s] = dict(ingest_ms=float(ti * 1e3),
                       retrieve_ms=float(tr * 1e3),
                       cycles_per_s=float(1.0 / best))
    base = rows[1]["cycles_per_s"]
    return dict(n_clusters=C, delta_cap=D, events_per_cycle=E,
                shards={str(s): r for s, r in rows.items()},
                scaling={str(s): float(rows[s]["cycles_per_s"] / base)
                         for s in rows})


def run(full: bool = False) -> Dict:
    out: Dict = {}
    out["threads"] = _thread_gate(full)
    out["shards"] = _shard_gate(full)

    t, s = out["threads"], out["shards"]
    out["device_speedup_4t"] = t["speedup_4thread"]
    out["shard_scaling"] = [s["scaling"][x] for x in ("1", "2", "4")]
    print("\nServing scale-out:")
    print(f"  threads: device {t['device']['thr_4thread_req_s']:.0f} "
          f"req/s x{N_THREADS} vs host "
          f"{t['host']['thr_4thread_req_s']:.0f} -> "
          f"{t['speedup_4thread']:.2f}x (1-thread "
          f"{t['speedup_1thread']:.2f}x)")
    for x in ("1", "2", "4"):
        r = s["shards"][x]
        print(f"  shards S={x}: ingest {r['ingest_ms']:6.1f}ms  "
              f"retrieve {r['retrieve_ms']:6.1f}ms  "
              f"-> {s['scaling'][x]:.2f}x vs S=1")

    # gate A: no calibration cap — see module docstring
    gate = float(os.environ.get("SCALEOUT_MIN_SPEEDUP", "3.0"))
    assert out["device_speedup_4t"] >= gate, \
        (f"device 4-thread retrieve throughput only "
         f"{out['device_speedup_4t']:.2f}x the host engine "
         f"(floor {gate}x)")
    # gate B: monotone shard scaling within tolerance
    tol = float(os.environ.get("SCALEOUT_SHARD_TOL", "0.95"))
    sc = out["shard_scaling"]
    assert sc[1] >= tol * sc[0] and sc[2] >= tol * sc[1], \
        f"shard scaling not monotone 1->2->4: {sc} (tol {tol})"
    write_result("serving_scaleout", out)
    return out


if __name__ == "__main__":
    run(full=os.environ.get("BENCH_FULL", "") == "1")
