"""Assemble the data-driven sections of EXPERIMENTS.md from artifacts."""
import glob
import json
import os

R = os.path.join(os.path.dirname(__file__), "results")


def _load(name):
    p = os.path.join(R, f"{name}.json")
    return json.load(open(p)) if os.path.exists(p) else None


def recall_table(rows, ks=(5, 10, 50, 100)):
    out = ["| method | " + " | ".join(f"R@{k}" for k in ks) + " |",
           "|---" * (len(ks) + 1) + "|"]
    for name, r in rows.items():
        if not isinstance(r, dict) or "5" not in {str(k) for k in r}:
            continue
        vals = " | ".join(f"{r.get(str(k), r.get(k, 0)):.3f}" for k in ks)
        out.append(f"| {name} | {vals} |")
    return "\n".join(out)


def paper_tables():
    s = []
    t2 = _load("table2_user_recall")
    if t2:
        s.append("### Table 2 — user-embedding Recall@K (U2U2I)\n\n"
                 + recall_table(t2))
    t3 = _load("table3_item_recall")
    if t3:
        s.append("### Table 3 — item-embedding Recall@K (next-day I-I)\n\n"
                 + recall_table(t3))
    t4 = _load("table4_index_hitrate")
    if t4:
        rows = {k: v for k, v in t4.items() if k in
                ("original", "recon (with reg)", "recon (no reg)")}
        s.append("### Table 4 — learned-index Hitrate@K\n\n"
                 + recall_table(rows, ks=(1, 5, 10))
                 + f"\n\nCodebook utilization: with regularization "
                 f"{t4['utilization'][ '1']*100 if isinstance(t4['utilization'], dict) and '1' in t4['utilization'] else t4['utilization'].get(1, 0)*100:.0f}%"
                 if False else
                 "### Table 4 — learned-index Hitrate@K\n\n"
                 + recall_table(rows, ks=(1, 5, 10)))
        u = t4.get("utilization", {})
        un = t4.get("utilization_noreg", {})
        s.append(f"Codebook utilization (layer0): **with reg "
                 f"{_g(u, 1)*100:.0f}%** vs **without reg "
                 f"{_g(un, 1)*100:.1f}%** — codebook collapse without the "
                 f"regularizer + biased selection, reproducing the paper's "
                 f"collapse finding (their util: 100% vs 'drops "
                 f"significantly').")
    for name, title in (("table5_edge_types", "Table 5 — edge types"),
                        ("table6_neighbors", "Table 6 — neighbor strategy"),
                        ("table7_popbias", "Table 7 — popularity-bias "
                                           "correction (item recall)")):
        t = _load(name)
        if t:
            s.append(f"### {title}\n\n" + recall_table(t))
    return "\n\n".join(s)


def _g(d, k):
    return d.get(str(k), d.get(k, 0.0))


def serving():
    t8 = _load("table8_serving_cost")
    if not t8:
        return ""
    return (f"Modeled production-scale serving-cost reduction "
            f"(bytes/request, 5M-user active pool): "
            f"**{t8['modeled_cost_reduction']*100:.1f}%** vs online ANN "
            f"(paper's measured reduction: 83% — theirs includes real "
            f"queue-infra overhead; ours is the compute/memory bound, an "
            f"upper limit consistent with >=83%).  Measured request path "
            f"at bench scale: cluster lookup "
            f"{t8['measured_us_cluster']:.1f}us vs brute KNN "
            f"{t8['measured_us_knn']:.1f}us "
            f"({t8['measured_speedup']:.0f}x); cluster-queue retrieval "
            f"recall vs next-day engagements "
            f"{t8['cluster_recall_vs_nextday']:.3f}.")


def perf_pairs():
    def load_dir(d):
        out = {}
        for p in glob.glob(os.path.join(R, d, "singlepod", "*.json")):
            r = json.load(open(p))
            out[(r["arch"], r["shape"])] = r
        return out

    base = load_dir("dryrun")
    opt = load_dir("dryrun_opt")
    rows = ["| cell | collective GiB (base → opt) | HBM GiB "
            "(base → opt) | bottleneck step s (base → opt) |",
            "|---|---|---|---|"]
    for k in sorted(opt):
        if k not in base:
            continue
        b, o = base[k], opt[k]

        def terms(r):
            c = r["corrected"]
            return max(c["flops"] / 197e12, c["bytes_accessed"] / 819e9,
                       c["collective_total"] / 50e9)

        def mem(r):
            m = r["memory"]
            return (m.get("temp_size_in_bytes", 0)
                    + m.get("argument_size_in_bytes", 0)) / 2**30

        cb = b["corrected"]["collective_total"] / 2**30
        co = o["corrected"]["collective_total"] / 2**30
        rows.append(
            f"| {k[0]} × {k[1]} | {cb:.1f} → {co:.2f} "
            f"(**{cb/max(co,1e-9):.0f}×**) | {mem(b):.1f} → {mem(o):.1f} "
            f"| {terms(b):.2e} → {terms(o):.2e} |")
    return "\n".join(rows)


if __name__ == "__main__":
    print("## PAPER TABLES\n")
    print(paper_tables())
    print("\n## SERVING\n")
    print(serving())
    print("\n## PERF PAIRS\n")
    print(perf_pairs())
