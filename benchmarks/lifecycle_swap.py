"""Lifecycle smoke benchmark: publication recall gate + swap stall.

What this establishes (and CI gates):

  * the published cluster index retains >= ``LIFECYCLE_MIN_RECALL`` of
    exact-KNN Recall@100 on held-out next-day engagements (the
    co-learned index is allowed to trade at most a bounded recall loss
    for its O(1) serving reads);
  * the published codebooks stay *balanced*: every layer's utilization
    holds >= ``LIFECYCLE_MIN_UTIL`` (0.5, vs the 0.0625 collapse floor
    this bench used to measure) — utilization-balancing co-training +
    in-burst dead-code resets keep it there, and the gate-triggered
    repair burst heals a publish that still trips;
  * an atomic hot-swap under live ingest stalls serving for at most
    ``SWAP_MAX_STALL_MS`` (the bulk store build + event-ring replay run
    off-path; only the catch-up + flip is a critical section);
  * every response during a swap storm is attributable to exactly one
    published version.

Results land in ``benchmarks/results/lifecycle_swap.json``.
"""
from __future__ import annotations

import os
import time
from typing import Dict

import numpy as np

from benchmarks.common import write_result
from repro.configs.base import RankGraph2Config, RQConfig
from repro.core.graph_builder import EngagementLog, build_graph
from repro.data.edge_dataset import build_neighbor_tables
from repro.data.synthetic import make_world
from repro.lifecycle import LifecycleConfig, LifecycleRuntime


def run(full: bool = False) -> Dict:
    out: Dict = {}
    n_users, n_items = (1000, 1600) if full else (500, 800)
    world = make_world(n_users=n_users, n_items=n_items,
                       events_per_user=20.0, seed=1)
    min_util = float(os.environ.get("LIFECYCLE_MIN_UTIL", "0.5"))
    cfg = RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=32, n_heads=2, d_hidden=96,
        k_imp=10, k_train=4, n_negatives=24, n_pool_neg=8,
        # usage_ema half-life must be well under reset_every or codes
        # that died mid-cadence still look live at the reset pass
        rq=RQConfig(codebook_sizes=(16, 4), hist_len=50,
                    util_coef=1.0, usage_ema=0.9, dead_floor=0.25,
                    reset_every=25), dtype="float32")
    lcfg = LifecycleConfig(steps_per_cycle=200 if full else 150,
                           batch_per_type=64, i2i_k=12,
                           recency_s=2 * 86400.0, recall_k=100,
                           recall_queries=300, min_recall_ratio=0.0,
                           min_codebook_util=min_util,
                           repair_attempts=2, repair_steps=50)

    log = world.day0
    m = log.timestamp <= 82800.0
    old = EngagementLog(log.user_id[m], log.item_id[m], log.event_type[m],
                        log.timestamp[m], log.n_users, log.n_items)
    t0 = time.perf_counter()
    g = build_graph(old, k_cap=16, hub_cap=24, keep_state=True)
    tables = build_neighbor_tables(g, k_imp=10, n_walks=16, walk_len=3,
                                   backend="jax", keep_state=True)
    out["construct_s"] = time.perf_counter() - t0

    rt = LifecycleRuntime(cfg, lcfg, g, tables, world.user_feat,
                          world.item_feat, world=world, seed=0)
    t0 = time.perf_counter()
    rep0 = rt.run_cycle(now=86400.0)
    out["cycle0_s"] = time.perf_counter() - t0
    out["publish_v1"] = rep0["publish"]
    if "repair" in rep0:
        out["repair_cycle0"] = dict(attempts=rep0["repair"]["attempts"],
                                    healed=rep0["repair"]["healed"])
    assert not rep0["swap"].get("skipped"), \
        f"cycle 0 never converged to a publishable index: {rep0['swap']}"
    v1 = rep0["publish"]["version"]

    # live traffic against v1
    d1 = world.day1
    rt.server.ingest(d1.user_id, d1.item_id, d1.timestamp)
    now = float(d1.timestamp.max())
    rng = np.random.default_rng(0)
    users = rng.integers(0, world.n_users, 1024)
    rt.server.retrieve_batch(users, now, 32)                  # warm
    t0 = time.perf_counter()
    _, v_before = rt.server.retrieve_batch(users, now, 32)
    out["retrieve_us_per_req"] = (time.perf_counter() - t0) / 1024 * 1e6
    assert v_before == v1

    # cycle 1: trailing-hour refresh + publish v2 + hot swap
    delta = log.window(86400.0, 3600.0)
    t0 = time.perf_counter()
    rep1 = rt.run_cycle(delta, now=now, backend="jax")
    out["cycle1_s"] = time.perf_counter() - t0
    out["publish_v2"] = rep1["publish"]
    out["swap"] = rep1["swap"]
    assert not rep1["swap"].get("skipped"), \
        f"cycle 1 never converged to a publishable index: {rep1['swap']}"

    # swap storm: repeated flips under interleaved serving; every
    # response must carry exactly the live version and the worst stall
    # must stay bounded
    import dataclasses as _dc
    snap2 = rt.server.handle.acquire().snapshot
    stalls = []
    for v in range(snap2.version + 1, snap2.version + 4):
        snap = _dc.replace(snap2, version=v)
        r = rt.server.swap_to(snap, now)
        stalls.append(r["stall_ms"])
        _, ver = rt.server.retrieve_batch(users[:128], now, 16)
        assert ver == snap.version, "response not from the live version"
    out["swap_stall_ms_max"] = float(np.max(stalls))
    out["swap_stall_ms_mean"] = float(np.mean(stalls))
    out["swap_build_ms"] = rep1["swap"]["build_ms"]

    ratio = min(out["publish_v1"]["recall_ratio"],
                out["publish_v2"]["recall_ratio"])
    out["recall_ratio_min"] = ratio
    util = min(out["publish_v1"]["codebook_util_min"],
               out["publish_v2"]["codebook_util_min"])
    out["codebook_util_min"] = util
    out["hitrate10_recon_min"] = min(
        out["publish_v1"]["hitrate10_recon"],
        out["publish_v2"]["hitrate10_recon"])

    print("\nLifecycle smoke:")
    print(f"  publish v1 recall@100 ratio: "
          f"{out['publish_v1']['recall_ratio']:.3f} "
          f"(index {out['publish_v1']['recall_index']:.3f} vs exact "
          f"{out['publish_v1']['recall_exact']:.3f})")
    print(f"  publish v2 recall@100 ratio: "
          f"{out['publish_v2']['recall_ratio']:.3f}")
    print(f"  index health: util_layer0 "
          f"{out['publish_v1']['util_layer0']:.3f} -> "
          f"{out['publish_v2']['util_layer0']:.3f}, "
          f"list balance {out['publish_v2']['coarse_list_balance']:.3f}, "
          f"hitrate10_recon "
          f"{out['publish_v1']['hitrate10_recon']:.3f} -> "
          f"{out['publish_v2']['hitrate10_recon']:.3f}")
    print(f"  swap: build {out['swap']['build_ms']:.2f}ms, "
          f"stall {out['swap']['stall_ms']:.3f}ms, "
          f"{int(out['swap']['replayed_events'])} events re-keyed")
    print(f"  swap storm: {len(stalls)} flips, max stall "
          f"{out['swap_stall_ms_max']:.3f}ms")

    # acceptance gates (CI overrides via env on noisy shared runners)
    min_recall = float(os.environ.get("LIFECYCLE_MIN_RECALL", "0.8"))
    max_stall = float(os.environ.get("SWAP_MAX_STALL_MS", "50"))
    assert ratio >= min_recall, \
        f"published index recall ratio {ratio:.3f} < {min_recall}"
    assert util >= min_util, \
        f"published codebook utilization {util:.4f} < {min_util} " \
        f"(collapse not healed)"
    assert out["swap_stall_ms_max"] <= max_stall, \
        f"swap stall {out['swap_stall_ms_max']:.2f}ms > {max_stall}ms"
    write_result("lifecycle_swap", out)
    return out


if __name__ == "__main__":
    run(full=os.environ.get("BENCH_FULL", "") == "1")
