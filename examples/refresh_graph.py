"""Hour-level incremental graph refresh (paper §4.2).

Builds the construction-stage artifacts on a 23h window, then splices
the trailing hour in with ``incremental_refresh`` — including items that
did not exist when the graph was built — instead of rebuilding from
scratch.  Fresh items without same-type co-engagement route through the
Group-2 KNN fallback over previous-run embeddings.

    PYTHONPATH=src python examples/refresh_graph.py
"""
import time

import numpy as np

from repro.core.graph_builder import EngagementLog, build_graph
from repro.data.edge_dataset import build_neighbor_tables, \
    incremental_refresh
from repro.data.synthetic import make_world


def main():
    world = make_world(n_users=2000, n_items=4000, events_per_user=6.0,
                       seed=0)
    log = world.day0

    # 1) the "yesterday" build: first 23 hours
    m = log.timestamp <= 82800.0
    old = EngagementLog(log.user_id[m], log.item_id[m], log.event_type[m],
                        log.timestamp[m], log.n_users, log.n_items)
    t0 = time.perf_counter()
    g = build_graph(old, k_cap=16, hub_cap=24, keep_state=True)
    tables = build_neighbor_tables(g, k_imp=10, n_walks=16, walk_len=3,
                                   backend="jax", keep_state=True)
    t_build = time.perf_counter() - t0
    print(f"initial build: {g.n_edges} edges in {t_build:.2f}s")

    # 2) the trailing hour, with 5 brand-new items joining the catalog
    delta = log.window(86400.0, 3600.0)
    ni_new = log.n_items + 5
    rng = np.random.default_rng(1)
    fresh_u = rng.integers(0, log.n_users, 5).astype(np.int64)
    fresh_i = (log.n_items + np.arange(5)).astype(np.int64)
    delta = EngagementLog(
        np.r_[delta.user_id, fresh_u], np.r_[delta.item_id, fresh_i],
        np.r_[delta.event_type, np.zeros(5, np.int32)],
        np.r_[delta.timestamp, np.full(5, 86400.0)],
        log.n_users, ni_new)

    # previous-run embeddings for the Group-2 KNN fallback (in a live
    # deployment: yesterday's trained embeddings + content embeddings
    # for never-seen items; features here)
    fresh_feat = rng.normal(0, 1, (5, world.item_feat.shape[1])
                            ).astype(np.float32)
    prev_emb = np.r_[world.user_feat, world.item_feat, fresh_feat]

    t0 = time.perf_counter()
    g2, tables2, report = incremental_refresh(g, tables, delta,
                                              prev_emb=prev_emb,
                                              backend="jax")
    t_refresh = time.perf_counter() - t0
    n = g2.n_users + g2.n_items
    print(f"refresh: {len(delta.user_id)} delta events, "
          f"{len(report['affected_nodes'])}/{n} nodes re-walked "
          f"in {t_refresh:.2f}s ({t_refresh / t_build:.2f}x of the "
          f"initial build)")

    # 3) the new items are fully served by the refreshed tables
    for i in fresh_i:
        gid = g2.n_users + int(i)
        nbrs = tables2.item_nbrs[gid]
        print(f"  new item {int(i)}: group1={bool(g2.group1_items[i])} "
              f"same-type neighbors {[int(x) - g2.n_users for x in nbrs[:5] if x >= 0]}")


if __name__ == "__main__":
    main()
