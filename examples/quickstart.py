"""Quickstart: the full RankGraph-2 lifecycle in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import RankGraph2Config, RQConfig
from repro.core import evaluation as EV
from repro.core.pipeline import run_pipeline
from repro.core.serving import ClusterQueueStore
from repro.data.synthetic import make_world


def main():
    # 1) a synthetic engagement world (stand-in for the production log)
    world = make_world(n_users=500, n_items=800, seed=0)

    # 2) lifecycle: construct -> PPR -> co-train model + RQ index -> embed
    cfg = RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=32, n_heads=2, d_hidden=96,
        k_imp=12, k_train=4, n_negatives=24, n_pool_neg=8, k_cap=24,
        rq=RQConfig(codebook_sizes=(32, 8), hist_len=50), dtype="float32")
    res = run_pipeline(world, cfg, steps=150, batch_per_type=64,
                       log_every=50)
    print(f"built graph: {res.graph.n_edges} edges "
          f"({res.seconds['construct']:.1f}s construct, "
          f"{res.seconds['ppr']:.1f}s PPR, {res.seconds['train']:.1f}s "
          f"train)")

    # 3) offline quality (paper §5.2 protocol)
    rec = EV.user_recall(res.user_emb, world, n_queries=200)
    print("user Recall@K:", {k: round(v, 3) for k, v in rec.items()})

    # 4) KNN-free serving: cluster queues keyed by the co-learned index
    store = ClusterQueueStore(res.user_codes, recency_s=86400.0)
    d1 = world.day1
    store.ingest(d1.user_id, d1.item_id, d1.timestamp)
    items = store.retrieve(user_id=7, now=float(d1.timestamp.max()), k=10)
    print(f"U2U2I retrieval for user 7 (cluster "
          f"{res.user_codes[7]}): {items}")


if __name__ == "__main__":
    main()
