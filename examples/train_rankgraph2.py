"""End-to-end training driver with production plumbing.

Continuous-training loop with everything a cluster deployment needs:
host-side prefetch overlap, periodic async checkpointing, preemption
(SIGTERM) handling, crash-resume from the latest checkpoint, periodic
graph rebuild (the 3h refresh cycle, scaled down), eval, and RQ-index
health monitoring.

    PYTHONPATH=src python examples/train_rankgraph2.py --steps 300
    PYTHONPATH=src python examples/train_rankgraph2.py --steps 600 \
        --ckpt-dir /tmp/rg2 --resume          # crash-resume
"""
import argparse
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import RankGraph2Config, RQConfig
from repro.core import evaluation as EV
from repro.core import rq_index as RQ
from repro.core import trainer as T
from repro.core.graph_builder import build_graph
from repro.data.edge_dataset import (EdgeDataset, Prefetcher,
                                     build_neighbor_tables)
from repro.data.synthetic import make_world


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--users", type=int, default=800)
    ap.add_argument("--items", type=int, default=1200)
    ap.add_argument("--batch", type=int, default=96)
    ap.add_argument("--ckpt-dir", default="/tmp/rankgraph2_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--rebuild-every", type=int, default=200,
                    help="graph-refresh cadence (the 3h cycle, scaled)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=48, n_heads=2,
        d_hidden=128, k_imp=16, k_train=6, n_negatives=32, n_pool_neg=8,
        rq=RQConfig(codebook_sizes=(64, 16), hist_len=100),
        dtype="float32")

    world = make_world(n_users=args.users, n_items=args.items, seed=0)

    def build(window_end):
        g = build_graph(world.day0.window(window_end, 86400.0),
                        k_cap=cfg.k_cap)
        tables = build_neighbor_tables(g, k_imp=cfg.k_imp,
                                       n_walks=cfg.ppr_walks,
                                       walk_len=cfg.ppr_len)
        # id-only batches: the prefetch thread ships ids + masks only;
        # features stay device-resident in the step's FeatureStore
        return EdgeDataset(g, tables, world.user_feat, world.item_feat,
                           k_train=cfg.k_train, batch_format="dedup_ids")

    ds = build(86400.0)
    state, specs, optimizer = T.init_state(jax.random.key(0), cfg,
                                           pool_size=4096)
    step_fn = T.make_train_step(
        cfg, optimizer,
        features=T.make_feature_store(world.user_feat, world.item_feat))

    ck = Checkpointer(args.ckpt_dir, keep=3)
    start = 0
    if args.resume and ck.latest_step() is not None:
        state, meta = ck.restore(state)
        start = int(meta["step"])
        print(f"resumed from step {start}")

    # preemption: cooperative SIGTERM — the step is donated, so while a
    # step is in flight the previous state's buffers are already gone
    # and a save from inside the signal handler could read dead memory.
    # The handler only sets a flag; the loop saves right after the next
    # step returns (a fully-materialized state) and exits 143.
    preempted = {"flag": False}
    signal.signal(signal.SIGTERM,
                  lambda *_: preempted.update(flag=True))

    per_type = {"uu": args.batch, "ui": args.batch, "ii": args.batch}
    prefetch = Prefetcher(ds.iter_batches(0, per_type, start_step=start),
                          depth=2)
    t0 = time.perf_counter()
    for t in range(start, args.steps):
        if t and t % args.rebuild_every == 0:
            # hour-level refresh: rebuild on the shifted window and swap
            # the dataset under the same model (self-contained data!)
            prefetch.close()
            ds = build(86400.0)
            prefetch = Prefetcher(ds.iter_batches(0, per_type,
                                                  start_step=t), depth=2)
            print(f"[{t}] graph rebuilt in {ds.g.build_seconds:.1f}s")
        batch = jax.tree.map(jnp.asarray, next(prefetch))
        state, m = step_fn(state, batch, jax.random.key(7000 + t))
        if preempted["flag"]:
            ck.save(int(state.step), state,
                    metadata={"data_seed": 0, "preempted": True,
                              "preempted_at": time.time()}, blocking=True)
            prefetch.close()
            raise SystemExit(143)
        if t % 50 == 0:
            util = RQ.codebook_utilization(state.rq_state)
            print(f"[{t}] total={float(m['total']):.3f} "
                  f"infonce_ui={float(m['infonce_ui']):.3f} "
                  f"codebook_util={[round(u, 2) for u in util]} "
                  f"({(t - start + 1) / (time.perf_counter() - t0):.1f} "
                  f"steps/s)")
        if t and t % args.ckpt_every == 0:
            ck.save(t, state, metadata={"data_seed": 0}, blocking=False)
    ck.save(args.steps, state,
            metadata={"data_seed": 0, "preempted": preempted["flag"]},
            blocking=True)
    prefetch.close()
    if preempted["flag"]:   # SIGTERM after the last in-loop check
        raise SystemExit(143)

    # embedding refresh + eval
    from repro.core import model as M
    user_emb = T.embed_all(state.params, cfg, ds, node_type=M.USER,
                           ids=np.arange(world.n_users))
    rec = EV.user_recall(user_emb, world, n_queries=300)
    print("final user Recall@K:", {k: round(v, 3) for k, v in rec.items()})
    print(f"checkpoints in {args.ckpt_dir}: steps {ck.all_steps()}")
    if preempted["flag"]:   # SIGTERM during embed/eval: still exit 143
        raise SystemExit(143)


if __name__ == "__main__":
    main()
