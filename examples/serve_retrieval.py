"""Serving example: KNN-free retrieval with the co-learned cluster index.

Simulates the production serving tier: a stream of engagement events
feeds per-cluster queues in real time; batched retrieval requests are
answered by (a) U2U2I cluster-queue lookups and (b) U2I2I via the
offline I2I KNN table — no online nearest-neighbor search anywhere.
Reports per-request latency and compares against brute-force KNN.

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import time

import numpy as np

from repro.configs.base import RankGraph2Config, RQConfig
from repro.core.pipeline import run_pipeline
from repro.core.serving import (ClusterQueueStore, ServingCostModel,
                                build_i2i_knn, u2i2i_retrieve)
from repro.data.synthetic import make_world


def main():
    world = make_world(n_users=600, n_items=900, seed=1)
    cfg = RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=32, n_heads=2, d_hidden=96,
        k_imp=12, k_train=4, n_negatives=24, n_pool_neg=8,
        rq=RQConfig(codebook_sizes=(32, 8), hist_len=50), dtype="float32")
    print("training (offline stage)...")
    res = run_pipeline(world, cfg, steps=150, batch_per_type=64)

    # --- offline artifacts the serving tier loads ---------------------------
    store = ClusterQueueStore(res.user_codes, queue_len=256,
                              recency_s=86400.0)
    i2i = build_i2i_knn(res.item_emb, k=20)    # refreshed per embed cycle

    # --- real-time ingestion -------------------------------------------------
    d1 = world.day1
    t0 = time.perf_counter()
    store.ingest(d1.user_id, d1.item_id, d1.timestamp)
    print(f"ingested {len(d1.user_id)} events in "
          f"{time.perf_counter()-t0:.2f}s; {store.stats()}")

    # --- batched request loop ------------------------------------------------
    now = float(d1.timestamp.max())
    rng = np.random.default_rng(0)
    users = rng.integers(0, world.n_users, 2000)
    recents = [store.retrieve(int(u), now, 4) for u in users]

    t0 = time.perf_counter()
    for u in users:
        store.retrieve(int(u), now, 32)                      # U2U2I
    t_u2u2i = (time.perf_counter() - t0) / len(users)

    t0 = time.perf_counter()
    for u, rec in zip(users, recents):
        u2i2i_retrieve(i2i, rec or [int(u) % world.n_items], 32)  # U2I2I
    t_u2i2i = (time.perf_counter() - t0) / len(users)

    # --- the system this replaces: online KNN per request -------------------
    emb = res.user_emb
    t0 = time.perf_counter()
    for u in users[:200]:
        sims = emb[int(u)] @ emb.T
        np.argpartition(-sims, 32)[:32]
    t_knn = (time.perf_counter() - t0) / 200

    cm = ServingCostModel()
    print(f"\nper-request latency:  U2U2I cluster {t_u2u2i*1e6:.0f}us | "
          f"U2I2I table {t_u2i2i*1e6:.0f}us | online-KNN {t_knn*1e6:.0f}us")
    print(f"modeled production-scale serving cost reduction: "
          f"{cm.cost_reduction()*100:.1f}% (paper: 83%)")


if __name__ == "__main__":
    main()
