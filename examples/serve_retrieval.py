"""Serving example: KNN-free batched retrieval with the cluster index.

Simulates the production serving tier: a stream of engagement events
feeds the array-backed cluster ring buffers in real time; retrieval
requests are answered in batches by (a) U2U2I cluster-queue lookups and
(b) U2I2I via the offline I2I KNN table — no online nearest-neighbor
search anywhere.  Reports batched vs per-request-loop throughput, the
fused Pallas queue_gather path, and the production-scale cost model.

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import time

import numpy as np

from repro.configs.base import RankGraph2Config, RQConfig
from repro.core.pipeline import run_pipeline
from repro.core.serving import (ClusterQueueStore, ServingCostModel,
                                build_i2i_knn, u2i2i_retrieve_batch)
from repro.data.synthetic import make_world


def main():
    world = make_world(n_users=600, n_items=900, seed=1)
    cfg = RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=32, n_heads=2, d_hidden=96,
        k_imp=12, k_train=4, n_negatives=24, n_pool_neg=8,
        rq=RQConfig(codebook_sizes=(32, 8), hist_len=50), dtype="float32")
    print("training (offline stage)...")
    res = run_pipeline(world, cfg, steps=150, batch_per_type=64)

    # --- offline artifacts the serving tier loads ---------------------------
    store = ClusterQueueStore(res.user_codes, queue_len=256,
                              recency_s=86400.0)
    i2i = build_i2i_knn(res.item_emb, k=20)    # refreshed per embed cycle

    # --- real-time ingestion (one vectorized pass) --------------------------
    d1 = world.day1
    t0 = time.perf_counter()
    store.ingest(d1.user_id, d1.item_id, d1.timestamp)
    print(f"ingested {len(d1.user_id)} events in "
          f"{time.perf_counter()-t0:.3f}s; {store.stats()}")

    # --- batched request path ------------------------------------------------
    now = float(d1.timestamp.max())
    rng = np.random.default_rng(0)
    users = rng.integers(0, world.n_users, 2048)

    store.retrieve_batch(users, now, 32)                     # warm
    t0 = time.perf_counter()
    seeds = store.retrieve_batch(users, now, 8)              # U2U2I
    u2u2i = store.retrieve_batch(users, now, 32)
    t_batch = (time.perf_counter() - t0) / len(users) / 2

    t0 = time.perf_counter()
    union = u2i2i_retrieve_batch(i2i, seeds, 32)             # U2I2I
    t_u2i2i = (time.perf_counter() - t0) / len(users)

    # same pass through the fused Pallas kernel (interpret mode on CPU)
    sk, uk = store.serve_batch(users[:64], now, n_recent=8, k=32, i2i=i2i,
                               use_kernel=True)
    sr, ur = store.serve_batch(users[:64], now, n_recent=8, k=32, i2i=i2i)
    assert (sk == sr).all() and (uk == ur).all(), "kernel disagrees"

    # --- the per-request loop this replaces ---------------------------------
    t0 = time.perf_counter()
    for u in users[:256]:
        store.retrieve(int(u), now, 32)
    t_loop = (time.perf_counter() - t0) / 256

    # --- and the system KNN-free serving replaces: online KNN ---------------
    emb = res.user_emb
    t0 = time.perf_counter()
    for u in users[:200]:
        sims = emb[int(u)] @ emb.T
        np.argpartition(-sims, 32)[:32]
    t_knn = (time.perf_counter() - t0) / 200

    cm = ServingCostModel(batch_size=len(users))
    print(f"\nper-request latency:  batched U2U2I {t_batch*1e6:.1f}us | "
          f"batched U2I2I {t_u2i2i*1e6:.1f}us | per-request loop "
          f"{t_loop*1e6:.0f}us | online-KNN {t_knn*1e6:.0f}us")
    print(f"batched-vs-loop speedup: {t_loop/max(t_batch, 1e-12):.1f}x   "
          f"(union served {int((union >= 0).sum())} candidates)")
    print(f"modeled production-scale serving cost reduction at batch="
          f"{cm.batch_size}: {cm.cost_reduction()*100:.1f}% (paper: 83%)")


if __name__ == "__main__":
    main()
