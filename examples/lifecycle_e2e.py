"""End-to-end lifecycle: construction -> training -> publish -> refresh
-> atomic hot-swap -> serving, on the synthetic world.

This is the paper's co-design loop closed for the first time: the graph
built from the engagement log feeds training; training co-learns the RQ
cluster index; publication pushes every embedding through the trained
codebooks into a versioned ``IndexSnapshot``; the serving tier flips to
the new version atomically while ingesting live events — no online KNN
anywhere.  The published index must retain >= 0.8x of exact-KNN
Recall@100 (the CI gate threshold), checked via ``core/evaluation``.

    PYTHONPATH=src python examples/lifecycle_e2e.py

Every stage emits telemetry (spans + counters + latency histograms) to
``$OBS_JSONL`` (default ``/tmp/rankgraph2_obs/lifecycle_e2e.jsonl``);
render the per-stage latency breakdown afterwards with

    PYTHONPATH=src python -m repro.obs.report \
        /tmp/rankgraph2_obs/lifecycle_e2e.jsonl
"""
import os

import numpy as np

from repro import obs
from repro.configs.base import RankGraph2Config, RQConfig
from repro.core.graph_builder import EngagementLog, build_graph
from repro.data.edge_dataset import build_neighbor_tables
from repro.data.synthetic import make_world
from repro.lifecycle import LifecycleConfig, LifecycleRuntime


def main(snapshot_dir="/tmp/rankgraph2_snapshots"):
    trace_path = os.environ.get(
        "OBS_JSONL", "/tmp/rankgraph2_obs/lifecycle_e2e.jsonl")
    os.makedirs(os.path.dirname(trace_path), exist_ok=True)
    if os.path.exists(trace_path):
        os.remove(trace_path)            # one run per trace file
    tel = obs.configure(path=trace_path)
    world = make_world(n_users=500, n_items=800, events_per_user=20.0,
                       seed=1)
    cfg = RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=32, n_heads=2, d_hidden=96,
        k_imp=10, k_train=4, n_negatives=24, n_pool_neg=8,
        rq=RQConfig(codebook_sizes=(16, 4), hist_len=50), dtype="float32")
    lcfg = LifecycleConfig(steps_per_cycle=150, batch_per_type=64,
                           i2i_k=12, recency_s=2 * 86400.0,
                           recall_k=100, recall_queries=300,
                           min_recall_ratio=0.8)

    # --- construction: the "yesterday" build on the first 23 hours ----------
    log = world.day0
    m = log.timestamp <= 82800.0
    old = EngagementLog(log.user_id[m], log.item_id[m], log.event_type[m],
                        log.timestamp[m], log.n_users, log.n_items)
    with tel.span("e2e.construct") as sp:
        g = build_graph(old, k_cap=16, hub_cap=24, keep_state=True)
        tables = build_neighbor_tables(g, k_imp=10, n_walks=16,
                                       walk_len=3, backend="jax",
                                       keep_state=True)
    print(f"construction: {g.n_edges} edges in {sp.elapsed():.2f}s")

    # --- cycle 0: train -> publish v1 -> bring serving up -------------------
    rt = LifecycleRuntime(cfg, lcfg, g, tables, world.user_feat,
                          world.item_feat, world=world,
                          snapshot_dir=snapshot_dir, seed=0)
    rep = rt.run_cycle(now=86400.0)
    pub = rep["publish"]
    print(f"cycle 0: published v{pub['version']}  "
          f"recall@100 index={pub['recall_index']:.3f} "
          f"exact={pub['recall_exact']:.3f} "
          f"(ratio {pub['recall_ratio']:.3f})")

    # --- live traffic against v1 --------------------------------------------
    d1 = world.day1
    with tel.span("e2e.serve", n_requests=512):
        rt.server.ingest(d1.user_id, d1.item_id, d1.timestamp)
        now = float(d1.timestamp.max())
        users = np.random.default_rng(0).integers(0, world.n_users, 512)
        seeds, union, ver = rt.server.serve_batch(users, now,
                                                  n_recent=8, k=32)
    print(f"serving v{ver}: {int((union >= 0).sum())} U2I2I candidates "
          f"for {len(users)} requests")

    # --- cycle 1: the trailing hour splices in, with brand-new users AND
    # --- items joining — both flow through publication into the index ------
    delta = log.window(86400.0, 3600.0)
    nu_new, ni_new = log.n_users + 5, log.n_items + 5
    rng = np.random.default_rng(2)
    du = np.r_[delta.user_id, np.arange(log.n_users, nu_new),
               rng.integers(0, log.n_users, 5)]
    di = np.r_[delta.item_id, rng.integers(0, log.n_items, 5),
               np.arange(log.n_items, ni_new)]
    delta = EngagementLog(du.astype(np.int64), di.astype(np.int64),
                          np.zeros(len(du), np.int32),
                          np.full(len(du), 86400.0), nu_new, ni_new)
    uf = np.r_[world.user_feat,
               rng.normal(0, 1, (5, 64)).astype(np.float32)]
    itf = np.r_[world.item_feat,
                rng.normal(0, 1, (5, 64)).astype(np.float32)]
    rep = rt.run_cycle(delta, now=now, user_feat=uf, item_feat=itf,
                       backend="jax")
    r, p, s = rep["refresh"], rep["publish"], rep["swap"]
    assert not s.get("skipped"), \
        f"published index lost too much recall: {p['recall_ratio']:.3f}"
    print(f"cycle 1: re-walked {r['affected_nodes']} nodes in "
          f"{r['refresh_seconds']:.2f}s; published v{p['version']} "
          f"(ratio {p['recall_ratio']:.3f}); swap stall "
          f"{s['stall_ms']:.3f}ms, {int(s['replayed_events'])} events "
          f"re-keyed")

    # --- the new version serves the users that did not exist at v1 ---------
    fresh = np.arange(log.n_users, nu_new)
    res, ver = rt.server.retrieve_batch(fresh, now, 16)
    snap = rt.store.load()
    print(f"v{ver} serves {len(fresh)} brand-new users; "
          f"their clusters: {snap.user_clusters[fresh].tolist()}")

    # --- the acceptance gate -------------------------------------------------
    assert p["recall_ratio"] >= 0.8, \
        f"published index lost too much recall: {p['recall_ratio']:.3f}"
    assert ver == p["version"]

    # --- telemetry out -------------------------------------------------------
    tel.flush()
    pct = tel.percentiles("serving.retrieve_latency_s")
    print(f"telemetry: {trace_path}  retrieve p50={pct['p50']*1e3:.2f}ms "
          f"p95={pct['p95']*1e3:.2f}ms")
    print("lifecycle e2e OK")


if __name__ == "__main__":
    main()
