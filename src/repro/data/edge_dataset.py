"""Edge-centric self-contained training data (paper §4.2 'Data format').

Each record = edge (n_i, n_j, w) + features and pre-sampled neighbors for
both endpoints, partitioned by edge type.  Training therefore needs *no*
online graph access — the dataset below materializes neighbor tables
once (construction output) and every batch is a pure gather.

Deterministic, resumable iteration: batch t of run (seed) is a pure
function of (seed, t), so a restored checkpoint resumes mid-epoch
exactly (fault-tolerance requirement).

A small prefetch thread overlaps host-side gather/negative-pool work
with device compute (paper 'Efficiency optimizations').
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.graph_builder import HeteroGraph
from repro.core import ppr as ppr_mod
from repro.obs import get_telemetry


@dataclasses.dataclass
class NeighborTables:
    """Pre-computed K_IMP neighbors per node, unified global id space
    (users [0, n_users), items [n_users, n_users+n_items))."""
    user_nbrs: np.ndarray    # (n_nodes, k_imp) global ids, -1 pad
    item_nbrs: np.ndarray    # (n_nodes, k_imp)
    n_users: int
    n_items: int
    ppr: Optional["ppr_mod.PPRState"] = None   # refresh splice state


def _fill_group2(g: HeteroGraph, user_nbrs: np.ndarray,
                 item_nbrs: np.ndarray, prev_emb: np.ndarray, k_imp: int,
                 only: Optional[np.ndarray] = None) -> None:
    """Group-2 fallback: same-type neighbors via previous-run KNN
    (in-place; ``only`` restricts to a node-id subset, e.g. the nodes an
    incremental refresh actually touched)."""
    nu = g.n_users
    g2u = np.flatnonzero(~g.group1_users)
    g1u = np.flatnonzero(g.group1_users)
    g2i = np.flatnonzero(~g.group1_items)
    g1i = np.flatnonzero(g.group1_items)
    if only is not None:
        g2u = g2u[np.isin(g2u, only)]
        g2i = g2i[np.isin(g2i + nu, only)]
    if len(g2u) and len(g1u):
        knn = ppr_mod.group2_neighbors(prev_emb[:nu], g1u, g2u, k_imp)
        user_nbrs[g2u] = np.where(knn >= 0, knn, user_nbrs[g2u])
    if len(g2i) and len(g1i):
        knn = ppr_mod.group2_neighbors(prev_emb[nu:], g1i, g2i, k_imp)
        item_nbrs[nu + g2i] = np.where(knn >= 0, nu + knn,
                                       item_nbrs[nu + g2i])


def build_neighbor_tables(g: HeteroGraph, *, k_imp: int = 50,
                          n_walks: int = 64, walk_len: int = 5,
                          restart: float = 0.15, seed: int = 0,
                          prev_emb: Optional[np.ndarray] = None,
                          backend: str = "numpy",
                          keep_state: bool = False) -> NeighborTables:
    """PPR tables on the backbone + Group-2 fallback (paper §4.2).

    ``backend`` selects the walker (numpy / jax / pallas — identical
    output); ``keep_state`` retains the visit traces that power
    ``incremental_refresh`` (opt-in: (n_nodes, n_walks*walk_len) int64
    plus an adjacency snapshot).
    """
    with get_telemetry().span("construction.ppr_walk", backend=backend,
                              n_walks=int(n_walks),
                              walk_len=int(walk_len)):
        user_nbrs, item_nbrs, state = ppr_mod.precompute_ppr_neighbors(
            g, k_imp=k_imp, n_walks=n_walks, walk_len=walk_len,
            restart=restart, seed=seed, backend=backend,
            return_state=True)
    # Group-2 fallback: same-type neighbors via previous-run KNN; item
    # neighbors from top-weight U-I edges (already what PPR finds for
    # 1-hop starts, but fill explicitly where PPR returned nothing).
    if prev_emb is not None:
        _fill_group2(g, user_nbrs, item_nbrs, prev_emb, k_imp)
    return NeighborTables(user_nbrs, item_nbrs, g.n_users, g.n_items,
                          ppr=state if keep_state else None)


def incremental_refresh(g: HeteroGraph, tables: NeighborTables,
                        new_log_window, *,
                        prev_emb: Optional[np.ndarray] = None,
                        backend: Optional[str] = None
                        ) -> Tuple[HeteroGraph, NeighborTables, Dict]:
    """Hour-level lifecycle refresh (paper §4.2): splice a trailing log
    window into an existing graph + PPR tables without a full rebuild.

    Edges are re-derived only for co-engagement pairs reachable from the
    delta (``graph_builder.refresh_graph``); walks re-run only for nodes
    whose walk-length neighborhood changed, and new nodes — *both* id
    spaces may grow — are spliced into the padded adjacencies and
    tables (``ppr.refresh_ppr_neighbors``; user growth additionally
    remaps the unified id space, shifting item global ids).  Fresh nodes
    that still lack same-type neighbors route through the Group-2 KNN
    fallback when ``prev_emb`` (previous-run embeddings sized for the
    *new* space, [users; items]) is given.

    Affected rows match a from-scratch build on the merged window
    bit-for-bit — including when ``hub_cap`` triggers: hub-subsample
    draws are keyed per anchor and persisted in ``RefreshState`` (see
    ``refresh_graph``).  Unaffected rows are left untouched (modulo the
    id remap).  Returns ``(new_graph, new_tables, report)``.
    """
    from repro.core.graph_builder import refresh_graph
    if tables.ppr is None:
        raise ValueError("tables were built without keep_state=True; "
                         "no refresh state retained")
    with get_telemetry().span("construction.refresh") as sp:
        g_new, report = refresh_graph(g, new_log_window)
        with get_telemetry().span("construction.ppr_refresh"):
            user_nbrs, item_nbrs, state, affected = \
                ppr_mod.refresh_ppr_neighbors(
                    g_new, tables.user_nbrs, tables.item_nbrs,
                    tables.ppr, backend=backend)
        if prev_emb is not None and len(affected):
            _fill_group2(g_new, user_nbrs, item_nbrs, prev_emb,
                         tables.ppr.k_imp, only=affected)
        report["affected_nodes"] = affected
        report["refresh_seconds"] = sp.elapsed()
    return (g_new,
            NeighborTables(user_nbrs, item_nbrs, g_new.n_users,
                           g_new.n_items, ppr=state),
            report)


EDGE_KEYS = ("uu", "ui", "ii")

# batch formats (see sample_batch):
#   legacy    — PR-3 layout: per (edge_type, side) feature tensors, every
#               endpoint occurrence re-materialized (and re-encoded);
#   dedup     — packed unique-node sub-batch per node type (features +
#               pack-relative sampled-neighbor indices) plus int32 gather
#               maps per (edge_type, side): each referenced node is
#               encoded exactly once;
#   dedup_ids — same packs but id-only (no feature tensors): the trainer
#               gathers features inside the jitted step from a
#               device-resident FeatureStore, so the host ships ~K*d
#               fewer bytes per row.
BATCH_FORMATS = ("legacy", "dedup", "dedup_ids")

# edge type -> (src, dst) node-type names
_ET_SIDES = {"uu": ("user", "user"), "ui": ("user", "item"),
             "ii": ("item", "item")}


def _round_up(n: int, m: int) -> int:
    """Bucket sizes to multiples of m (min m) so jit traces are reused
    across batches instead of recompiling per unique-node count."""
    return max(m, -(-n // m) * m)


@dataclasses.dataclass
class EdgeDataset:
    g: HeteroGraph
    tables: NeighborTables
    user_feat: np.ndarray
    item_feat: np.ndarray
    k_train: int = 10
    # importance-sample training edges proportionally to their Eq.1/2
    # weights (construction's premise: weight == relevance; uniform
    # sampling would train on the spurious-tie tail)
    sample_by_weight: bool = True
    batch_format: str = "dedup"
    pad_multiple: int = 64        # unique-pack size bucketing

    def _cumw(self, et):
        cache = getattr(self, "_cumw_cache", None)
        if cache is None:
            cache = {}
            object.__setattr__(self, "_cumw_cache", cache)
        if et not in cache:
            es = getattr(self.g, et)
            w = np.maximum(es.weight.astype(np.float64), 1e-9)
            cache[et] = np.cumsum(w) / w.sum()
        return cache[et]

    def _gather_side(self, gids: np.ndarray, rng: np.random.Generator
                     ) -> Dict[str, np.ndarray]:
        """Features + sampled neighbor features for global node ids."""
        nu = self.tables.n_users
        # batches are partitioned by edge type so each side is one type
        if (gids < nu).all():
            feat = self.user_feat[gids]
        else:
            feat = self.item_feat[gids - nu]
        # sample k_train of the K_IMP pre-computed neighbors (paper)
        k_imp = self.tables.user_nbrs.shape[1]
        k = self.k_train
        cols = rng.integers(0, k_imp, (len(gids), k))
        unbr = self.tables.user_nbrs[gids[:, None], cols]
        cols = rng.integers(0, k_imp, (len(gids), k))
        inbr = self.tables.item_nbrs[gids[:, None], cols]
        umask = unbr >= 0
        imask = inbr >= nu
        unbr_feat = self.user_feat[np.clip(unbr, 0, nu - 1)]
        inbr_feat = self.item_feat[np.clip(inbr - nu, 0,
                                           self.tables.n_items - 1)]
        unbr_feat = unbr_feat * umask[..., None]
        inbr_feat = inbr_feat * imask[..., None]
        return dict(feat=feat.astype(np.float32),
                    unbr_feat=unbr_feat.astype(np.float32),
                    unbr_mask=umask.astype(np.float32),
                    inbr_feat=inbr_feat.astype(np.float32),
                    inbr_mask=imask.astype(np.float32))

    def _draw_edges(self, rng: np.random.Generator, et: str, n: int
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Draw n (src_gid, dst_gid, weight) samples of one edge type."""
        nu = self.tables.n_users
        es = getattr(self.g, et)
        if len(es) == 0:   # degenerate graphs: self-pairs as fallback
            src = rng.integers(0, nu, n)
            dst = src.copy()
            w = np.ones(n, np.float32)
        else:
            if self.sample_by_weight:
                idx = np.searchsorted(self._cumw(et), rng.random(n))
                idx = np.minimum(idx, len(es) - 1)
            else:
                idx = rng.integers(0, len(es), n)
            src, dst, w = es.src[idx], es.dst[idx], es.weight[idx]
        if et == "uu":
            sg, dg = src, dst
        elif et == "ui":
            sg, dg = src, dst + nu
        else:  # ii
            sg, dg = src + nu, dst + nu
        return sg, dg, w.astype(np.float32)

    def sample_batch(self, step: int, seed: int, per_type: Dict[str, int],
                     format: Optional[str] = None) -> Dict[str, Dict]:
        """Batch t is a pure function of (seed, step, format) — resumable.

        ``format`` (default: ``self.batch_format``) selects the layout —
        see ``BATCH_FORMATS``.  The legacy path keeps PR-3's exact rng
        consumption order (edge draw, then src/dst neighbor draws, per
        edge type) so old runs stay reproducible bit-for-bit.
        """
        fmt = format or self.batch_format
        if fmt not in BATCH_FORMATS:
            raise ValueError(f"unknown batch format {fmt!r}")
        rng = np.random.default_rng((seed, step))
        if fmt == "legacy":
            batch: Dict[str, Dict] = {}
            for et in EDGE_KEYS:
                n = per_type.get(et, 0)
                if n == 0:
                    continue
                sg, dg, w = self._draw_edges(rng, et, n)
                batch[et] = dict(src=self._gather_side(sg, rng),
                                 dst=self._gather_side(dg, rng),
                                 weight=w,
                                 src_ids=sg.astype(np.int32),
                                 dst_ids=dg.astype(np.int32))
            return batch
        edges = {et: self._draw_edges(rng, et, n) for et in EDGE_KEYS
                 if (n := per_type.get(et, 0))}
        return self._dedup_batch(rng, edges, id_only=(fmt == "dedup_ids"))

    def _dedup_batch(self, rng: np.random.Generator, edges: Dict[str, Tuple],
                     id_only: bool) -> Dict[str, Dict]:
        """Packed unique-node batch: every node referenced by any
        endpoint or sampled neighbor appears exactly once per node type.

        Pack layout per type: ``[endpoint uniques (E, sorted) | pad to
        E_pad | neighbor-only extras (sorted) | pad to U_pad]``; sizes
        are bucketed to ``pad_multiple`` so jit traces are shared across
        batches.  Endpoint rows [0, E) are the only ones aggregated;
        extras exist only to be feature-encoded and gathered as
        neighbors.
        """
        nu, ni = self.tables.n_users, self.tables.n_items
        mult = self.pad_multiple
        k_imp = self.tables.user_nbrs.shape[1]
        k = self.k_train

        ep = {"user": [], "item": []}
        for et, (sg, dg, w) in edges.items():
            st, dt = _ET_SIDES[et]
            ep[st].append(sg)
            ep[dt].append(dg)

        sides: Dict[str, Dict[str, np.ndarray]] = {}
        uniq: Dict[str, np.ndarray] = {}
        nbr_gids: Dict[str, Dict[str, np.ndarray]] = {}
        for t in ("user", "item"):
            u = (np.unique(np.concatenate(ep[t])) if ep[t]
                 else np.zeros(0, np.int64))
            uniq[t] = u
            # one neighbor draw per unique endpoint node (the legacy
            # format draws per occurrence; dedup makes the draw — like
            # the encode — a per-node event)
            cols = rng.integers(0, k_imp, (len(u), k))
            unbr = self.tables.user_nbrs[u[:, None], cols] if len(u) else \
                np.zeros((0, k), np.int64)
            cols = rng.integers(0, k_imp, (len(u), k))
            inbr = self.tables.item_nbrs[u[:, None], cols] if len(u) else \
                np.zeros((0, k), np.int64)
            nbr_gids[t] = dict(
                unbr=np.clip(unbr, 0, nu - 1), umask=unbr >= 0,
                inbr=np.clip(inbr, nu, nu + ni - 1), imask=inbr >= nu)

        # neighbor-only extras per pack (valid neighbors not already
        # endpoint uniques of that type)
        extras, e_pad = {}, {}
        for t, key_m in (("user", "umask"), ("item", "imask")):
            key_g = "unbr" if t == "user" else "inbr"
            valid = [nbr_gids[s][key_g][nbr_gids[s][key_m]]
                     for s in ("user", "item")]
            allv = (np.unique(np.concatenate(valid)) if valid
                    else np.zeros(0, np.int64))
            extras[t] = np.setdiff1d(allv, uniq[t], assume_unique=True)
            e_pad[t] = _round_up(len(uniq[t]), mult)

        def pack_index(t: str, gids: np.ndarray, mask: np.ndarray
                       ) -> np.ndarray:
            """Pack-relative index of global ids (masked entries -> 0)."""
            u, ex = uniq[t], extras[t]
            if len(u) == 0:   # a type with no endpoints: extras only
                idx = e_pad[t] + np.searchsorted(ex, gids)
            else:
                pos = np.minimum(np.searchsorted(u, gids), len(u) - 1)
                idx = np.where(u[pos] == gids, pos,
                               e_pad[t] + np.searchsorted(ex, gids))
            return np.where(mask, idx, 0).astype(np.int32)

        for t in ("user", "item"):
            E, Ep = len(uniq[t]), e_pad[t]
            u_pad = _round_up(Ep + len(extras[t]), mult)
            local = np.zeros(u_pad, np.int64)
            off, hi = (0, nu - 1) if t == "user" else (nu, ni - 1)
            local[:E] = np.clip(uniq[t] - off, 0, hi)
            local[Ep:Ep + len(extras[t])] = np.clip(extras[t] - off, 0, hi)
            n = nbr_gids[t]
            unbr_idx = np.zeros((Ep, k), np.int32)
            inbr_idx = np.zeros((Ep, k), np.int32)
            umask = np.zeros((Ep, k), np.float32)
            imask = np.zeros((Ep, k), np.float32)
            unbr_idx[:E] = pack_index("user", n["unbr"], n["umask"])
            inbr_idx[:E] = pack_index("item", n["inbr"], n["imask"])
            umask[:E] = n["umask"].astype(np.float32)
            imask[:E] = n["imask"].astype(np.float32)
            side = dict(unbr_idx=unbr_idx, unbr_mask=umask,
                        inbr_idx=inbr_idx, inbr_mask=imask)
            if id_only:
                side["ids"] = local.astype(np.int32)
            else:
                table = self.user_feat if t == "user" else self.item_feat
                side["feat"] = table[local].astype(np.float32)
            sides[t] = side

        out_edges = {}
        for et, (sg, dg, w) in edges.items():
            st, dt = _ET_SIDES[et]
            out_edges[et] = dict(
                src_map=np.searchsorted(uniq[st], sg).astype(np.int32),
                dst_map=np.searchsorted(uniq[dt], dg).astype(np.int32),
                weight=w,
                src_ids=sg.astype(np.int32), dst_ids=dg.astype(np.int32))
        return {"nodes": sides, "edges": out_edges}

    def expand_batch(self, batch: Dict[str, Dict]) -> Dict[str, Dict]:
        """Re-materialize a dedup batch in the legacy per-endpoint layout
        (same neighbor draws — the dedup forward on ``batch`` and the
        legacy forward on the expansion must produce the same losses)."""
        if "nodes" not in batch:
            return batch
        nu = self.tables.n_users
        feats = {}
        for t, table in (("user", self.user_feat), ("item", self.item_feat)):
            side = batch["nodes"][t]
            feats[t] = (np.asarray(side["feat"]) if "feat" in side
                        else table[np.asarray(side["ids"])])
        out: Dict[str, Dict] = {}
        for et, e in batch["edges"].items():
            st, dt = _ET_SIDES[et]
            sub = {}
            for side_name, t, m in (("src", st, e["src_map"]),
                                    ("dst", dt, e["dst_map"])):
                nd = batch["nodes"][t]
                m = np.asarray(m)
                umask = np.asarray(nd["unbr_mask"])[m]
                imask = np.asarray(nd["inbr_mask"])[m]
                sub[side_name] = dict(
                    feat=feats[t][m],
                    unbr_feat=feats["user"][np.asarray(nd["unbr_idx"])[m]]
                    * umask[..., None],
                    unbr_mask=umask,
                    inbr_feat=feats["item"][np.asarray(nd["inbr_idx"])[m]]
                    * imask[..., None],
                    inbr_mask=imask)
            out[et] = dict(weight=np.asarray(e["weight"]),
                           src_ids=np.asarray(e["src_ids"]),
                           dst_ids=np.asarray(e["dst_ids"]), **sub)
        return out

    def iter_batches(self, seed: int, per_type: Dict[str, int],
                     start_step: int = 0) -> Iterator[Dict]:
        step = start_step
        while True:
            yield self.sample_batch(step, seed, per_type)
            step += 1

    def node_inference_batch(self, gids: np.ndarray, seed: int = 0
                             ) -> Dict[str, np.ndarray]:
        """Inference-side gather for embedding generation."""
        rng = np.random.default_rng(seed)
        return self._gather_side(gids, rng)


class Prefetcher:
    """Host-side pipeline overlap: data fetching / preprocessing runs in a
    background thread while the device executes train_step."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def work():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)

        self.t = threading.Thread(target=work, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
