"""Edge-centric self-contained training data (paper §4.2 'Data format').

Each record = edge (n_i, n_j, w) + features and pre-sampled neighbors for
both endpoints, partitioned by edge type.  Training therefore needs *no*
online graph access — the dataset below materializes neighbor tables
once (construction output) and every batch is a pure gather.

Deterministic, resumable iteration: batch t of run (seed) is a pure
function of (seed, t), so a restored checkpoint resumes mid-epoch
exactly (fault-tolerance requirement).

A small prefetch thread overlaps host-side gather/negative-pool work
with device compute (paper 'Efficiency optimizations').
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.graph_builder import HeteroGraph
from repro.core import ppr as ppr_mod


@dataclasses.dataclass
class NeighborTables:
    """Pre-computed K_IMP neighbors per node, unified global id space
    (users [0, n_users), items [n_users, n_users+n_items))."""
    user_nbrs: np.ndarray    # (n_nodes, k_imp) global ids, -1 pad
    item_nbrs: np.ndarray    # (n_nodes, k_imp)
    n_users: int
    n_items: int
    ppr: Optional["ppr_mod.PPRState"] = None   # refresh splice state


def _fill_group2(g: HeteroGraph, user_nbrs: np.ndarray,
                 item_nbrs: np.ndarray, prev_emb: np.ndarray, k_imp: int,
                 only: Optional[np.ndarray] = None) -> None:
    """Group-2 fallback: same-type neighbors via previous-run KNN
    (in-place; ``only`` restricts to a node-id subset, e.g. the nodes an
    incremental refresh actually touched)."""
    nu = g.n_users
    g2u = np.flatnonzero(~g.group1_users)
    g1u = np.flatnonzero(g.group1_users)
    g2i = np.flatnonzero(~g.group1_items)
    g1i = np.flatnonzero(g.group1_items)
    if only is not None:
        g2u = g2u[np.isin(g2u, only)]
        g2i = g2i[np.isin(g2i + nu, only)]
    if len(g2u) and len(g1u):
        knn = ppr_mod.group2_neighbors(prev_emb[:nu], g1u, g2u, k_imp)
        user_nbrs[g2u] = np.where(knn >= 0, knn, user_nbrs[g2u])
    if len(g2i) and len(g1i):
        knn = ppr_mod.group2_neighbors(prev_emb[nu:], g1i, g2i, k_imp)
        item_nbrs[nu + g2i] = np.where(knn >= 0, nu + knn,
                                       item_nbrs[nu + g2i])


def build_neighbor_tables(g: HeteroGraph, *, k_imp: int = 50,
                          n_walks: int = 64, walk_len: int = 5,
                          restart: float = 0.15, seed: int = 0,
                          prev_emb: Optional[np.ndarray] = None,
                          backend: str = "numpy",
                          keep_state: bool = False) -> NeighborTables:
    """PPR tables on the backbone + Group-2 fallback (paper §4.2).

    ``backend`` selects the walker (numpy / jax / pallas — identical
    output); ``keep_state`` retains the visit traces that power
    ``incremental_refresh`` (opt-in: (n_nodes, n_walks*walk_len) int64
    plus an adjacency snapshot).
    """
    user_nbrs, item_nbrs, state = ppr_mod.precompute_ppr_neighbors(
        g, k_imp=k_imp, n_walks=n_walks, walk_len=walk_len,
        restart=restart, seed=seed, backend=backend, return_state=True)
    # Group-2 fallback: same-type neighbors via previous-run KNN; item
    # neighbors from top-weight U-I edges (already what PPR finds for
    # 1-hop starts, but fill explicitly where PPR returned nothing).
    if prev_emb is not None:
        _fill_group2(g, user_nbrs, item_nbrs, prev_emb, k_imp)
    return NeighborTables(user_nbrs, item_nbrs, g.n_users, g.n_items,
                          ppr=state if keep_state else None)


def incremental_refresh(g: HeteroGraph, tables: NeighborTables,
                        new_log_window, *,
                        prev_emb: Optional[np.ndarray] = None,
                        backend: Optional[str] = None
                        ) -> Tuple[HeteroGraph, NeighborTables, Dict]:
    """Hour-level lifecycle refresh (paper §4.2): splice a trailing log
    window into an existing graph + PPR tables without a full rebuild.

    Edges are re-derived only for co-engagement pairs reachable from the
    delta (``graph_builder.refresh_graph``); walks re-run only for nodes
    whose walk-length neighborhood changed, and new nodes — *both* id
    spaces may grow — are spliced into the padded adjacencies and
    tables (``ppr.refresh_ppr_neighbors``; user growth additionally
    remaps the unified id space, shifting item global ids).  Fresh nodes
    that still lack same-type neighbors route through the Group-2 KNN
    fallback when ``prev_emb`` (previous-run embeddings sized for the
    *new* space, [users; items]) is given.

    Affected rows match a from-scratch build on the merged window
    bit-for-bit — including when ``hub_cap`` triggers: hub-subsample
    draws are keyed per anchor and persisted in ``RefreshState`` (see
    ``refresh_graph``).  Unaffected rows are left untouched (modulo the
    id remap).  Returns ``(new_graph, new_tables, report)``.
    """
    from repro.core.graph_builder import refresh_graph
    if tables.ppr is None:
        raise ValueError("tables were built without keep_state=True; "
                         "no refresh state retained")
    t0 = time.perf_counter()
    g_new, report = refresh_graph(g, new_log_window)
    user_nbrs, item_nbrs, state, affected = ppr_mod.refresh_ppr_neighbors(
        g_new, tables.user_nbrs, tables.item_nbrs, tables.ppr,
        backend=backend)
    if prev_emb is not None and len(affected):
        _fill_group2(g_new, user_nbrs, item_nbrs, prev_emb,
                     tables.ppr.k_imp, only=affected)
    report["affected_nodes"] = affected
    report["refresh_seconds"] = time.perf_counter() - t0
    return (g_new,
            NeighborTables(user_nbrs, item_nbrs, g_new.n_users,
                           g_new.n_items, ppr=state),
            report)


EDGE_KEYS = ("uu", "ui", "ii")


@dataclasses.dataclass
class EdgeDataset:
    g: HeteroGraph
    tables: NeighborTables
    user_feat: np.ndarray
    item_feat: np.ndarray
    k_train: int = 10
    # importance-sample training edges proportionally to their Eq.1/2
    # weights (construction's premise: weight == relevance; uniform
    # sampling would train on the spurious-tie tail)
    sample_by_weight: bool = True

    def _cumw(self, et):
        cache = getattr(self, "_cumw_cache", None)
        if cache is None:
            cache = {}
            object.__setattr__(self, "_cumw_cache", cache)
        if et not in cache:
            es = getattr(self.g, et)
            w = np.maximum(es.weight.astype(np.float64), 1e-9)
            cache[et] = np.cumsum(w) / w.sum()
        return cache[et]

    def _gather_side(self, gids: np.ndarray, rng: np.random.Generator
                     ) -> Dict[str, np.ndarray]:
        """Features + sampled neighbor features for global node ids."""
        nu = self.tables.n_users
        is_user = gids < nu
        d_uf = self.user_feat.shape[1]
        d_if = self.item_feat.shape[1]
        feat = np.zeros((len(gids), d_uf if is_user.all() else
                         (d_if if not is_user.any() else
                          max(d_uf, d_if))), np.float32)
        # batches are partitioned by edge type so each side is one type
        if is_user.all():
            feat = self.user_feat[gids]
        else:
            feat = self.item_feat[gids - nu]
        # sample k_train of the K_IMP pre-computed neighbors (paper)
        k_imp = self.tables.user_nbrs.shape[1]
        k = self.k_train
        cols = rng.integers(0, k_imp, (len(gids), k))
        unbr = self.tables.user_nbrs[gids[:, None], cols]
        cols = rng.integers(0, k_imp, (len(gids), k))
        inbr = self.tables.item_nbrs[gids[:, None], cols]
        umask = unbr >= 0
        imask = inbr >= nu
        unbr_feat = self.user_feat[np.clip(unbr, 0, nu - 1)]
        inbr_feat = self.item_feat[np.clip(inbr - nu, 0,
                                           self.tables.n_items - 1)]
        unbr_feat = unbr_feat * umask[..., None]
        inbr_feat = inbr_feat * imask[..., None]
        return dict(feat=feat.astype(np.float32),
                    unbr_feat=unbr_feat.astype(np.float32),
                    unbr_mask=umask.astype(np.float32),
                    inbr_feat=inbr_feat.astype(np.float32),
                    inbr_mask=imask.astype(np.float32))

    def sample_batch(self, step: int, seed: int,
                     per_type: Dict[str, int]) -> Dict[str, Dict]:
        """Batch t is a pure function of (seed, step) — resumable."""
        rng = np.random.default_rng((seed, step))
        nu = self.tables.n_users
        batch: Dict[str, Dict] = {}
        for et in EDGE_KEYS:
            n = per_type.get(et, 0)
            if n == 0:
                continue
            es = getattr(self.g, et)
            if len(es) == 0:   # degenerate graphs: self-pairs as fallback
                src = rng.integers(0, nu, n)
                dst = src.copy()
                w = np.ones(n, np.float32)
            else:
                if self.sample_by_weight:
                    idx = np.searchsorted(self._cumw(et), rng.random(n))
                    idx = np.minimum(idx, len(es) - 1)
                else:
                    idx = rng.integers(0, len(es), n)
                src, dst, w = es.src[idx], es.dst[idx], es.weight[idx]
            if et == "uu":
                sg, dg = src, dst
            elif et == "ui":
                sg, dg = src, dst + nu
            else:  # ii
                sg, dg = src + nu, dst + nu
            batch[et] = dict(
                src=self._gather_side(sg, rng),
                dst=self._gather_side(dg, rng),
                weight=w.astype(np.float32),
                src_ids=sg.astype(np.int32), dst_ids=dg.astype(np.int32))
        return batch

    def iter_batches(self, seed: int, per_type: Dict[str, int],
                     start_step: int = 0) -> Iterator[Dict]:
        step = start_step
        while True:
            yield self.sample_batch(step, seed, per_type)
            step += 1

    def node_inference_batch(self, gids: np.ndarray, seed: int = 0
                             ) -> Dict[str, np.ndarray]:
        """Inference-side gather for embedding generation."""
        rng = np.random.default_rng(seed)
        return self._gather_side(gids, rng)


class Prefetcher:
    """Host-side pipeline overlap: data fetching / preprocessing runs in a
    background thread while the device executes train_step."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def work():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)

        self.t = threading.Thread(target=work, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
