"""Synthetic engagement corpus with planted latent-interest structure.

Public benchmarks are orders of magnitude below the paper's scale (their
§5.1 argument), and the raw logs are proprietary — so offline evaluation
here uses a generative world model whose ground truth we control:

  * T latent topics; each user/item has a mixture over topics;
  * engagement probability ∝ exp(z_u · z_i / temp) with a popularity
    boost for head items (Zipf), which is exactly the bias Eq. 3 corrects;
  * day-N events are the training window, day-(N+1) events are the
    held-out future engagements used for Recall@K (paper §5.2 protocol);
  * node features are noisy linear views of the latents (inductive
    setting: the model must *learn* the structure from features+graph).

This makes the paper's qualitative claims testable at CPU scale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.graph_builder import EngagementLog


@dataclasses.dataclass
class SyntheticWorld:
    user_latent: np.ndarray     # (n_users, T)
    item_latent: np.ndarray     # (n_items, T)
    user_feat: np.ndarray       # (n_users, d_uf)
    item_feat: np.ndarray       # (n_items, d_if)
    item_pop: np.ndarray        # (n_items,) popularity boost
    day0: EngagementLog         # training window (24h)
    day1: EngagementLog         # next-day eval window

    @property
    def n_users(self) -> int:
        return len(self.user_latent)

    @property
    def n_items(self) -> int:
        return len(self.item_latent)


def make_world(n_users: int = 2000, n_items: int = 3000, *,
               n_topics: int = 16, d_user_feat: int = 64,
               d_item_feat: int = 64, events_per_user: float = 30.0,
               pop_zipf: float = 1.1, pop_strength: float = 1.0,
               feat_noise: float = 0.3, temp: float = 0.25,
               noise_frac: float = 0.0,
               seed: int = 0) -> SyntheticWorld:
    """``noise_frac``: fraction of events drawn uniformly at random —
    spurious engagements that create noisy co-engagement ties (the
    regime where multi-hop PPR consensus beats 1-hop sampling)."""
    rng = np.random.default_rng(seed)
    T = n_topics
    # sparse-ish topic mixtures
    zu = rng.dirichlet(np.full(T, 0.3), n_users).astype(np.float32)
    zi = rng.dirichlet(np.full(T, 0.3), n_items).astype(np.float32)
    zu /= np.linalg.norm(zu, axis=1, keepdims=True)
    zi /= np.linalg.norm(zi, axis=1, keepdims=True)
    # Zipf popularity boost (head items accumulate co-engagement that
    # reflects popularity, not interest -> the Eq.3 target)
    ranks = np.arange(1, n_items + 1, dtype=np.float64)
    pop = (1.0 / ranks ** pop_zipf)
    pop = pop[rng.permutation(n_items)]
    pop = (pop / pop.mean()).astype(np.float32)

    # noisy feature views (inductive signal)
    pu = rng.normal(0, 1, (T, d_user_feat)).astype(np.float32)
    pi = rng.normal(0, 1, (T, d_item_feat)).astype(np.float32)
    uf = zu @ pu + feat_noise * rng.normal(0, 1, (n_users, d_user_feat)
                                           ).astype(np.float32)
    itf = zi @ pi + feat_noise * rng.normal(0, 1, (n_items, d_item_feat)
                                            ).astype(np.float32)

    def sample_day(day: int, ts0: float) -> EngagementLog:
        # repro: disable=determinism — legacy arithmetic key; the stream is frozen by the calibrated benchmark gates (recall/util), so rekeying would invalidate them
        r = np.random.default_rng(seed + 1000 + day)
        n_ev = int(n_users * events_per_user)
        users = r.integers(0, n_users, n_ev)
        # score = affinity + popularity boost; Gumbel-max sampling over a
        # candidate subset (keeps this O(n_ev * C))
        C = min(256, n_items)
        cand = r.integers(0, n_items, (n_ev, C))
        aff = np.einsum("et,ect->ec", zu[users],
                        zi[cand]) / temp
        score = aff + pop_strength * np.log(pop[cand] + 1e-6) * 0.8
        g = r.gumbel(0, 1, score.shape)
        items = cand[np.arange(n_ev), np.argmax(score + g, axis=1)]
        if noise_frac > 0:
            spurious = r.random(n_ev) < noise_frac
            items = np.where(spurious, r.integers(0, n_items, n_ev), items)
        etype = r.choice(4, n_ev, p=[0.7, 0.15, 0.1, 0.05]).astype(np.int32)
        ts = ts0 + r.random(n_ev) * 86400.0
        return EngagementLog(users.astype(np.int64), items.astype(np.int64),
                             etype, ts, n_users, n_items)

    return SyntheticWorld(zu, zi, uf, itf, pop,
                          day0=sample_day(0, 0.0),
                          day1=sample_day(1, 86400.0))


def next_day_ground_truth(world: SyntheticWorld) -> Tuple[np.ndarray, ...]:
    """(user -> set of day-1 items) as a CSR-ish pair for recall eval."""
    order = np.argsort(world.day1.user_id, kind="stable")
    u = world.day1.user_id[order]
    it = world.day1.item_id[order]
    starts = np.searchsorted(u, np.arange(world.n_users))
    ends = np.searchsorted(u, np.arange(world.n_users) + 1)
    return u, it, starts, ends
