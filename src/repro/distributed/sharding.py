"""Logical-axis sharding system (MaxText-style, self-contained).

Every parameter / activation is annotated with *logical* axis names
(strings).  A rules table maps logical names -> mesh axes.  This keeps
model code mesh-agnostic: the dry-run, the single-pod mesh and the
multi-pod mesh all reuse the same annotations with different rules, and
perf hillclimbing = editing the rules table, not the model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# A logical spec is a tuple of logical axis names (or None for unsharded
# dims), e.g. ("batch", "seq", "embed").
LogicalSpec = Sequence[Optional[str]]

# Default rules for the production meshes.  ``pod`` is folded into the
# data-parallel dimension when present (see make_rules).
DEFAULT_RULES: dict[str, Union[None, str, tuple[str, ...]]] = {
    # data-parallel axes
    "batch": ("pod", "data"),
    "nodes": ("pod", "data"),
    "edges": ("pod", "data"),
    # sequence / context axes (unsharded by default; SP variants remap)
    "seq": None,
    "kv_seq": None,
    # model-parallel axes
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "expert": "model",
    "expert_mlp": None,
    "table_rows": "model",       # embedding-table row sharding (recsys)
    "table_dim": None,
    "candidates": ("pod", "data"),  # retrieval candidate sharding
    "channels": "model",          # GNN feature channels
    "irreps": None,
    "codes": None,                # RQ codebooks are small -> replicated
    "code_dim": None,
    "stack": None,                # scan-over-layers leading axis
}


def make_rules(mesh: Mesh, overrides: Optional[Mapping[str, Any]] = None
               ) -> dict[str, Any]:
    """Build a rules table valid for ``mesh`` (drops absent mesh axes)."""
    axes = set(mesh.axis_names)
    rules: dict[str, Any] = {}
    for name, target in {**DEFAULT_RULES, **(overrides or {})}.items():
        if target is None:
            rules[name] = None
        elif isinstance(target, str):
            rules[name] = target if target in axes else None
        else:  # tuple of axes -> keep the ones this mesh has
            kept = tuple(a for a in target if a in axes)
            rules[name] = kept if kept else None
    return rules


def logical_to_spec(logical: Optional[LogicalSpec],
                    rules: Mapping[str, Any]) -> P:
    """Map a tuple of logical names to a PartitionSpec under ``rules``."""
    if logical is None:
        return P()
    out = []
    used: set[str] = set()
    for name in logical:
        if name is None:
            out.append(None)
            continue
        target = rules.get(name, None)
        if target is None:
            out.append(None)
        elif isinstance(target, str):
            if target in used:   # a mesh axis may appear only once
                out.append(None)
            else:
                used.add(target)
                out.append(target)
        else:
            fresh = tuple(a for a in target if a not in used)
            if fresh:
                used.update(fresh)
                out.append(fresh if len(fresh) > 1 else fresh[0])
            else:
                out.append(None)
    return P(*out)


def tree_logical_to_spec(tree: Any, rules: Mapping[str, Any]) -> Any:
    """Convert a pytree of logical specs (tuples) into PartitionSpecs."""
    return jax.tree.map(
        lambda l: logical_to_spec(l, rules),
        tree,
        is_leaf=lambda x: x is None or (isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x)),
    )


def tree_shardings(tree: Any, mesh: Mesh, rules: Mapping[str, Any]) -> Any:
    specs = tree_logical_to_spec(tree, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x: jax.Array, logical: LogicalSpec,
              rules: Optional[Mapping[str, Any]]) -> jax.Array:
    """with_sharding_constraint by logical names (no-op without rules)."""
    if rules is None:
        return x
    spec = logical_to_spec(logical, rules)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # outside a mesh context (e.g. plain CPU tests)


@dataclasses.dataclass
class ShardingCtx:
    """Carried through model apply functions; rules=None disables all
    constraints (single-device tests).  ``mesh`` enables manual
    shard_map regions (e.g. the expert-parallel MoE dispatch)."""
    rules: Optional[Mapping[str, Any]] = None
    mesh: Optional[Mesh] = None

    def __call__(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        return constrain(x, logical, self.rules)

    def axis_size(self, logical: str) -> int:
        """Product of mesh-axis sizes a logical name maps to (1 if
        unmapped or no mesh)."""
        if self.mesh is None or self.rules is None:
            return 1
        target = self.rules.get(logical)
        if target is None:
            return 1
        axes = (target,) if isinstance(target, str) else tuple(target)
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        out = 1
        for a in axes:
            out *= sizes.get(a, 1)
        return out


NULL_CTX = ShardingCtx(rules=None)
