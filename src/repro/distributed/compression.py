"""Gradient compression for the cross-pod data-parallel axis.

At 2+ pods the DP all-reduce crosses the slow inter-pod links (~50 GB/s
per link vs 819 GB/s HBM); compressing gradients before the cross-pod
reduction shrinks the collective term of the roofline.  Two schemes, both
with error feedback (residual accumulation) so convergence is preserved:

  * int8: per-tensor scale quantization (8x over fp32 / 4x over bf16);
  * powersgd: rank-r factorization for matrices (Vogels et al. 2019),
    compression ratio ~ (n*m) / (r*(n+m)).

These are exposed as optimizer *wrappers*: grads are compressed,
(all-reduced in deployment — GSPMD inserts the reduction), decompressed,
and the quantization error is fed back into the next step.  The
compress->decompress round-trip runs under jit, so the dry-run shows the
reduced collective bytes when enabled on the pod axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer


class CompressionState(NamedTuple):
    error: Any        # error-feedback residual, same structure as grads
    inner: Any        # wrapped optimizer state
    rng: jax.Array    # for powersgd init


def _quant_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def int8_roundtrip(x: jnp.ndarray) -> jnp.ndarray:
    q, s = _quant_int8(x)
    return _dequant_int8(q, s)


def powersgd_roundtrip(x: jnp.ndarray, rank: int,
                       key: jax.Array) -> jnp.ndarray:
    """One power-iteration low-rank approximation (rank r)."""
    if x.ndim < 2 or min(x.shape[-2:], default=0) <= rank:
        return int8_roundtrip(x)
    shape = x.shape
    m = x.reshape(-1, shape[-1])
    q = jax.random.normal(key, (shape[-1], rank), jnp.float32)
    p = m @ q                       # (n, r)   <- all-reduced in PowerSGD
    p, _ = jnp.linalg.qr(p)
    q2 = m.T @ p                    # (m, r)   <- all-reduced
    return (p @ q2.T).reshape(shape)


def compressed(inner: Optimizer, *, scheme: str = "int8",
               rank: int = 4, seed: int = 0) -> Optimizer:
    """Wrap an optimizer with compress->decompress + error feedback."""

    def init(params):
        err = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return CompressionState(err, inner.init(params),
                                jax.random.key(seed))

    def update(grads, state: CompressionState, params):
        key, sub = jax.random.split(state.rng)
        # error feedback: compress (grad + residual)
        g_in = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                            grads, state.error)
        if scheme == "int8":
            g_hat = jax.tree.map(int8_roundtrip, g_in)
        elif scheme == "powersgd":
            leaves, treedef = jax.tree.flatten(g_in)
            keys = jax.random.split(sub, len(leaves))
            g_hat = treedef.unflatten(
                [powersgd_roundtrip(l, rank, k)
                 for l, k in zip(leaves, keys)])
        else:
            raise ValueError(f"unknown scheme {scheme!r}")
        new_err = jax.tree.map(lambda a, b: a - b, g_in, g_hat)
        upd, inner_state = inner.update(g_hat, state.inner, params)
        return upd, CompressionState(new_err, inner_state, key)

    return Optimizer(init, update)


def compression_ratio(params, scheme: str = "int8", rank: int = 4) -> float:
    """Bytes on the wire with / without compression (for the roofline)."""
    full = comp = 0.0
    for p in jax.tree.leaves(params):
        n = float(p.size)
        full += n * 4
        if scheme == "int8":
            comp += n * 1 + 4
        else:
            if p.ndim >= 2:
                rows = n / p.shape[-1]
                comp += 4 * rank * (rows + p.shape[-1])
            else:
                comp += n * 1 + 4
    return comp / max(full, 1.0)
