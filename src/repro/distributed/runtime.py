"""Cluster-runtime scaffolding: health, stragglers, elastic restarts.

At 1000+ nodes the failure model is: hosts vanish (preemption/hardware),
hosts straggle (thermal / network), and capacity changes between
restarts.  In a synchronous SPMD job the *mechanisms* live outside the
XLA program:

  * HeartbeatMonitor — per-host progress heartbeats with a deadline; a
    missed deadline marks the host suspect and triggers the restart
    policy (checkpoint-restore without it costs at most
    ``ckpt_every`` steps of work).
  * StragglerTracker — per-step host timing EWMA; hosts persistently
    slower than median x tolerance are reported for replacement.
    (Within a step, stragglers are bounded by the paper's deterministic
    batch shapes — no data-dependent shape spikes.)
  * ElasticPlan — maps a checkpoint written on N chips onto M chips:
    validates the new mesh, rebuilds shardings from logical specs, and
    the Checkpointer's unsharded-leaf format does the rest.

These are driven by the training driver (examples/train_rankgraph2.py)
and unit-tested by simulation; they do not depend on real transport.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class HostState:
    last_beat: float
    last_step: int
    ewma_step_s: float = 0.0


class HeartbeatMonitor:
    def __init__(self, hosts: List[str], *, deadline_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.deadline = deadline_s
        self.clock = clock
        now = clock()
        self.hosts: Dict[str, HostState] = {
            h: HostState(now, -1) for h in hosts}

    def beat(self, host: str, step: int) -> None:
        now = self.clock()
        st = self.hosts[host]
        if st.last_step >= 0 and step > st.last_step:
            dt = (now - st.last_beat) / max(step - st.last_step, 1)
            st.ewma_step_s = (0.8 * st.ewma_step_s + 0.2 * dt
                              if st.ewma_step_s else dt)
        st.last_beat = now
        st.last_step = step

    def suspects(self) -> List[str]:
        now = self.clock()
        return [h for h, st in self.hosts.items()
                if now - st.last_beat > self.deadline]

    def healthy(self) -> bool:
        return not self.suspects()


class StragglerTracker:
    """Flags hosts whose EWMA step time exceeds median x tolerance."""

    def __init__(self, monitor: HeartbeatMonitor, tolerance: float = 1.5):
        self.monitor = monitor
        self.tolerance = tolerance

    def stragglers(self) -> List[str]:
        times = {h: st.ewma_step_s for h, st in self.monitor.hosts.items()
                 if st.ewma_step_s > 0}
        if len(times) < 2:
            return []
        med = float(np.median(list(times.values())))
        return [h for h, t in times.items()
                if t > self.tolerance * max(med, 1e-9)]


@dataclasses.dataclass
class ElasticPlan:
    """Restart plan when capacity changes from n_old to n_new chips."""
    n_old: int
    n_new: int
    data_axis: int
    model_axis: int

    @staticmethod
    def plan(n_new: int, *, model_axis: int = 16,
             min_data: int = 1) -> "ElasticPlan":
        """Keep the model axis fixed (sharding of weights must still
        divide), flex the data axis; refuse meshes that cannot hold the
        model."""
        if n_new % model_axis != 0:
            # degrade model axis to the largest power-of-two divisor
            m = model_axis
            while m > 1 and n_new % m:
                m //= 2
            model_axis = m
        data = n_new // model_axis
        if data < min_data:
            raise ValueError(f"{n_new} chips cannot hold the job "
                             f"(need >= {min_data * model_axis})")
        return ElasticPlan(0, n_new, data, model_axis)

    def mesh_shape(self):
        return (self.data_axis, self.model_axis)


def recovery_cost_model(ckpt_every_steps: int, step_s: float,
                        restore_s: float, mtbf_hours: float,
                        n_hosts: int) -> Dict[str, float]:
    """Expected overhead of the checkpoint/restart policy at scale —
    the knob the driver exposes (ckpt_every) is chosen from this."""
    failures_per_hour = n_hosts / max(mtbf_hours, 1e-9)
    lost_per_failure = ckpt_every_steps / 2 * step_s + restore_s
    lost_frac = failures_per_hour * lost_per_failure / 3600.0
    ckpt_frac = 0.0  # async saves overlap compute; host IO off-path
    return dict(failures_per_hour=failures_per_hour,
                expected_lost_frac=lost_frac + ckpt_frac,
                lost_s_per_failure=lost_per_failure)
