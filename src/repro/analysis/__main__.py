"""CLI: ``python -m repro.analysis [paths] [--rules ...] [--format ...]``.

Exit status 0 when every finding is suppressed (with a reason), 1
otherwise — CI runs this over ``src/`` and fails on any unsuppressed
finding.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.analysis.rules import ALL_RULE_CLASSES
from repro.analysis.runner import (active, format_json, format_text,
                                   run_analysis, select_rules)

DEFAULT_VMEM_REPORT = "benchmarks/results/vmem_report.json"


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static checks for the repo's concurrency, "
                    "donation, determinism, and VMEM invariants.")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to analyze "
                             "(default: src)")
    parser.add_argument(
        "--rules", nargs="+", metavar="RULE",
        help="subset of rules to run: "
             + ", ".join(c.name for c in ALL_RULE_CLASSES))
    parser.add_argument("--format", choices=("text", "json"),
                        default="text")
    parser.add_argument("--budget-mib", type=float, default=16.0,
                        help="per-core VMEM budget (default 16 MiB)")
    parser.add_argument(
        "--vmem-report", default=DEFAULT_VMEM_REPORT,
        help="where the vmem-budget rule writes its residency table "
             "('' disables)")
    args = parser.parse_args(argv)

    vmem_kwargs = {
        "budget_bytes": int(args.budget_mib * 1024 * 1024),
        "report_path": args.vmem_report or None,
    }
    rules = select_rules(args.rules, **vmem_kwargs)
    findings = run_analysis(args.paths, rules=rules)

    fmt = format_json if args.format == "json" else format_text
    print(fmt(findings))
    return 1 if active(findings) else 0


if __name__ == "__main__":
    sys.exit(main())
