"""Core types for the repro static-analysis toolkit.

Everything here is stdlib-``ast`` only: the analyzer must be importable
(and runnable in CI) without jax/numpy so a broken environment can never
mask an invariant violation.

A *rule* is one pass over a parsed module that returns ``Finding``s.
Rules are pure: they may keep accumulation state for a ``finalize()``
report (the VMEM residency table) but never mutate the tree.

Suppressions are inline pragmas::

    some_call()   # repro: disable=determinism — benign stage timing

A pragma suppresses matching findings on its own line; a comment-only
pragma line also covers the next non-blank source line (so multi-line
statements can carry the pragma just above their anchor).  A pragma
without a written reason still suppresses, but emits a ``suppression``
finding of its own — the acceptance bar is that every disable carries a
reason.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Optional, Tuple

#: pragma grammar: `# repro: disable=rule-a,rule-b — reason text`
#: (em dash, en dash, one-or-more hyphens, or a colon may introduce the
#: reason)
_PRAGMA_RE = re.compile(
    r"#\s*repro:\s*disable=(?P<rules>[A-Za-z0-9_,\-]+)"
    r"(?:\s*(?:[—–:]|-{1,2})\s*(?P<reason>\S.*?))?\s*$")

SUPPRESSION_RULE = "suppression"


@dataclasses.dataclass
class Finding:
    """One diagnostic: a rule violation anchored at ``path:line:col``."""
    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False
    reason: str = ""

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def render(self) -> str:
        tag = " [suppressed]" if self.suppressed else ""
        return f"{self.location()}: {self.rule}: {self.message}{tag}"

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ModuleContext:
    """Parsed view of one source file handed to each rule."""
    path: str
    source: str
    tree: ast.Module
    lines: List[str]


class Rule:
    """Base pass.  Subclasses set ``name``/``description`` and implement
    ``check``; ``applies`` scopes the rule to a subtree (determinism is
    library-code only, VMEM is ``kernels/`` only)."""

    name: str = "rule"
    description: str = ""

    def applies(self, path: str) -> bool:
        return True

    def check(self, ctx: ModuleContext) -> List[Finding]:
        raise NotImplementedError

    def finalize(self) -> List[Finding]:
        """Called once after every file was checked (report emission)."""
        return []


@dataclasses.dataclass
class Pragma:
    line: int
    rules: Tuple[str, ...]       # ("all",) = every rule
    reason: str
    comment_only: bool           # line holds nothing but the pragma


def parse_pragmas(lines: List[str]) -> Dict[int, Pragma]:
    """Line number (1-based) -> pragma found on that line."""
    out: Dict[int, Pragma] = {}
    for i, raw in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(raw)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",")
                      if r.strip())
        reason = (m.group("reason") or "").strip()
        comment_only = raw.strip().startswith("#")
        out[i] = Pragma(i, rules, reason, comment_only)
    return out


def _covering_pragma(pragmas: Dict[int, Pragma], line: int
                     ) -> Optional[Pragma]:
    p = pragmas.get(line)
    if p is not None:
        return p
    prev = pragmas.get(line - 1)
    if prev is not None and prev.comment_only:
        return prev
    return None


def apply_suppressions(findings: List[Finding], pragmas: Dict[int, Pragma],
                       path: str) -> List[Finding]:
    """Mark suppressed findings in place and append ``suppression``
    findings for pragmas that lack a written reason."""
    for f in findings:
        p = _covering_pragma(pragmas, f.line)
        if p is not None and (f.rule in p.rules or "all" in p.rules):
            f.suppressed = True
            f.reason = p.reason
    extra = []
    for p in sorted(pragmas.values(), key=lambda p: p.line):
        if not p.reason:
            extra.append(Finding(
                SUPPRESSION_RULE, path, p.line, 0,
                f"suppression of {','.join(p.rules)} carries no written "
                f"reason (use `# repro: disable=RULE — reason`)"))
    return findings + extra


def dotted_name(node: ast.AST) -> str:
    """`a.b.c` for Attribute/Name chains, '' for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""
