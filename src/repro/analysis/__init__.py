"""repro.analysis — stdlib-``ast`` static checks for the repo's own
invariants: lock discipline in the serving tier, donation safety on the
training hot path, determinism/trace purity in library code, and the
per-kernel VMEM budget.

Run: ``PYTHONPATH=src python -m repro.analysis src/ [--format json]``.
"""
from repro.analysis.base import (Finding, ModuleContext, Pragma, Rule,
                                 apply_suppressions, parse_pragmas)
from repro.analysis.rules import (ALL_RULE_CLASSES, default_rules,
                                  rules_by_name)
from repro.analysis.runner import (active, analyze_file, format_json,
                                   format_text, iter_source_files,
                                   run_analysis, select_rules)
from repro.analysis.rules.vmem_budget import (DEFAULT_BUDGET_BYTES,
                                              VmemBudgetRule)

__all__ = [
    "Finding", "ModuleContext", "Pragma", "Rule",
    "apply_suppressions", "parse_pragmas",
    "ALL_RULE_CLASSES", "default_rules", "rules_by_name",
    "active", "analyze_file", "format_json", "format_text",
    "iter_source_files", "run_analysis", "select_rules",
    "DEFAULT_BUDGET_BYTES", "VmemBudgetRule", "vmem_report",
]


def vmem_report(budget_bytes: int = DEFAULT_BUDGET_BYTES,
                report_path: str = "benchmarks/results/vmem_report.json",
                kernels_path: str = "src/repro/kernels"):
    """Run only the VMEM pass and write the residency report; returns
    the parsed report dict (used by ``benchmarks/run.py``)."""
    import json

    rule = VmemBudgetRule(budget_bytes=budget_bytes,
                          report_path=report_path)
    run_analysis([kernels_path], rules=[rule])
    with open(report_path) as f:
        return json.load(f)
