"""Rule ``vmem-budget`` — static VMEM residency accounting for kernels.

A TPU core has ~16 MiB of VMEM.  Every Pallas kernel in
``src/repro/kernels/`` declares its working set statically: BlockSpec
block shapes (inputs/outputs) plus ``pltpu.VMEM`` scratch.  This pass
evaluates those shapes symbolically against the production config
(§5.1: d=256, 100 negatives, 5000/50 RQ codebooks, queue_len=256,
64k x 32 I2I table, 64x5 PPR walks) and fails any ``pallas_call`` whose
estimated residency exceeds the budget.

Accounting model (matches the double-buffering the Mosaic pipeline
actually does):

* a block whose ``index_map`` *references* a grid parameter changes per
  program -> it streams, double-buffered, **x2**;
* a block whose ``index_map`` is constant (``lambda b: (0, 0)``) — or
  absent — is fetched once and stays **resident, x1**;
* scratch is resident, sized by its declared dtype;
* elements default to 4 bytes (every kernel in-tree moves f32/int32
  blocks).

Dimension names resolve, in order: function-local constant assignments
(``S = n_walks * walk_len``) -> the per-kernel production table below ->
module-wide keyword defaults scraped from signatures (``block_b: int =
32``) -> the global table.  A spec that still doesn't resolve is counted
in the report as unresolved and never fails the budget.

``finalize`` writes the full residency table to
``benchmarks/results/vmem_report.json`` (see ``benchmarks/run.py``).
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.base import Finding, ModuleContext, Rule, dotted_name

DEFAULT_BUDGET_BYTES = 16 * 1024 * 1024

#: production dims (configs/rankgraph2.py §5.1), keyed by kernel package
MODULE_DIMS: Dict[str, Dict[str, int]] = {
    "queue_gather": {"Q": 256, "N": 65536, "K": 32, "n_recent": 8,
                     "k": 64, "B": 1024},
    "ppr_walk": {"N": 131072, "D2": 64, "n_walks": 64, "walk_len": 5},
    "rq_assign": {"d": 256, "L": 2},
    "embedding_bag": {"D": 256, "L": 32, "B": 32768},
    "fused_contrastive": {"d": 256, "N": 100},
    "flash_attention": {"D": 128},
}

GLOBAL_DIMS: Dict[str, int] = {"d": 256, "D": 256, "L": 2}

#: expression sequences a ListComp expands over — `in_specs += [
#: pl.BlockSpec(c.shape, ...) for c in codebooks]` binds `c.shape` to
#: the production codebook shapes
MODULE_EXPR_SEQS: Dict[str, Dict[str, List[Tuple[int, ...]]]] = {
    "rq_assign": {"c.shape": [(5000, 256), (50, 256)]},
}

DTYPE_BYTES = {"float64": 8, "int64": 8, "uint64": 8,
               "float32": 4, "int32": 4, "uint32": 4,
               "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
               "int8": 1, "uint8": 1, "bool_": 1, "bool": 1}


@dataclasses.dataclass
class SpecInfo:
    kind: str                 # "in" | "out" | "scratch"
    shape: Optional[Tuple[int, ...]]
    bytes: int
    streaming: bool
    resolved: bool

    def to_dict(self) -> Dict[str, object]:
        return {"kind": self.kind,
                "shape": list(self.shape) if self.shape else None,
                "bytes": self.bytes, "streaming": self.streaming,
                "resolved": self.resolved}


class _Unresolved(Exception):
    pass


class _Evaluator:
    """Integer-evaluate shape expressions against the dims env."""

    def __init__(self, local: Dict[str, int], *envs: Dict[str, int]):
        self.local = local
        self.envs = envs

    def lookup(self, name: str) -> int:
        if name in self.local:
            return self.local[name]
        for env in self.envs:
            if name in env:
                return env[name]
        raise _Unresolved(name)

    def eval(self, node: ast.AST) -> int:
        if isinstance(node, ast.Constant) and isinstance(node.value, int) \
                and not isinstance(node.value, bool):
            return node.value
        if isinstance(node, ast.Name):
            return self.lookup(node.id)
        if isinstance(node, ast.BinOp):
            a, b = self.eval(node.left), self.eval(node.right)
            if isinstance(node.op, ast.Add):
                return a + b
            if isinstance(node.op, ast.Sub):
                return a - b
            if isinstance(node.op, ast.Mult):
                return a * b
            if isinstance(node.op, (ast.FloorDiv, ast.Div)):
                return a // b
            if isinstance(node.op, ast.Mod):
                return a % b
            raise _Unresolved(ast.dump(node.op))
        if isinstance(node, ast.Call):
            fname = dotted_name(node.func)
            if fname in ("min", "max") and node.args and not node.keywords:
                vals = [self.eval(a) for a in node.args]
                return min(vals) if fname == "min" else max(vals)
        raise _Unresolved(ast.unparse(node))

    def eval_shape(self, node: ast.AST) -> Tuple[int, ...]:
        if isinstance(node, (ast.Tuple, ast.List)):
            return tuple(self.eval(e) for e in node.elts)
        raise _Unresolved(ast.unparse(node))


def _module_key(path: str) -> str:
    parts = path.replace("\\", "/").split("/")
    return parts[-2] if len(parts) >= 2 else ""


def _scrape_param_defaults(tree: ast.Module) -> Dict[str, int]:
    """``def f(..., block_b: int = 32)`` -> {"block_b": 32}; conflicting
    defaults keep the max (conservative for a budget check)."""
    out: Dict[str, int] = {}
    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        for args, defaults in ((fn.args.args, fn.args.defaults),
                               (fn.args.kwonlyargs, fn.args.kw_defaults)):
            pos = args[len(args) - len(defaults):] \
                if defaults is not fn.args.kw_defaults else args
            for arg, dflt in zip(pos, defaults):
                if isinstance(dflt, ast.Constant) and isinstance(
                        dflt.value, int) and not isinstance(
                            dflt.value, bool):
                    out[arg.arg] = max(out.get(arg.arg, 0), dflt.value)
    return out


def _index_map_streams(node: Optional[ast.AST]) -> bool:
    """True when the index_map output depends on a grid parameter."""
    if not isinstance(node, ast.Lambda):
        return node is not None       # non-lambda map: assume it varies
    params = {a.arg for a in node.args.args}
    return any(isinstance(n, ast.Name) and n.id in params
               for n in ast.walk(node.body))


class VmemBudgetRule(Rule):
    name = "vmem-budget"
    description = ("estimated VMEM residency of every pallas_call "
                   "(blocks x double-buffering + scratch) must fit the "
                   "per-core budget at production dims")

    def __init__(self, budget_bytes: int = DEFAULT_BUDGET_BYTES,
                 report_path: Optional[str] = None):
        self.budget_bytes = budget_bytes
        self.report_path = report_path
        self.entries: List[Dict[str, object]] = []

    def applies(self, path: str) -> bool:
        return "kernels" in path.replace("\\", "/").split("/")

    # -- entry point --------------------------------------------------------

    def check(self, ctx: ModuleContext) -> List[Finding]:
        findings: List[Finding] = []
        mod = _module_key(ctx.path)
        mod_dims = MODULE_DIMS.get(mod, {})
        sig_dims = _scrape_param_defaults(ctx.tree)
        expr_seqs = MODULE_EXPR_SEQS.get(mod, {})

        for fn in [n for n in ast.walk(ctx.tree)
                   if isinstance(n, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))]:
            assigns, lists = self._function_bindings(fn)
            local = self._const_locals(assigns, mod_dims, sig_dims)
            ev = _Evaluator(local, mod_dims, sig_dims, GLOBAL_DIMS)
            for call in [n for n in ast.walk(fn)
                         if isinstance(n, ast.Call)
                         and dotted_name(n.func).split(".")[-1]
                         == "pallas_call"]:
                self._check_call(ctx, fn, call, ev, assigns, lists,
                                 expr_seqs, findings)
        return findings

    # -- per-function binding collection ------------------------------------

    @staticmethod
    def _function_bindings(fn: ast.FunctionDef
                           ) -> Tuple[Dict[str, ast.expr],
                                      Dict[str, List[ast.expr]]]:
        assigns: Dict[str, ast.expr] = {}
        lists: Dict[str, List[ast.expr]] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if not isinstance(t, ast.Name):
                        continue
                    assigns[t.id] = node.value
                    if isinstance(node.value, (ast.List, ast.Tuple)):
                        lists[t.id] = list(node.value.elts)
                    elif isinstance(node.value, ast.ListComp):
                        lists[t.id] = [node.value]
            elif isinstance(node, ast.AugAssign) and isinstance(
                    node.op, ast.Add) and isinstance(node.target, ast.Name):
                ext = lists.setdefault(node.target.id, [])
                if isinstance(node.value, (ast.List, ast.Tuple)):
                    ext.extend(node.value.elts)
                else:
                    ext.append(node.value)
        return assigns, lists

    @staticmethod
    def _const_locals(assigns: Dict[str, ast.expr],
                      mod_dims: Dict[str, int],
                      sig_dims: Dict[str, int]) -> Dict[str, int]:
        """Fixed-point evaluation of constant local assignments
        (``S = n_walks * walk_len``) against the dims tables."""
        local: Dict[str, int] = {}
        for _ in range(4):
            progress = False
            ev = _Evaluator(local, mod_dims, sig_dims, GLOBAL_DIMS)
            for name, expr in assigns.items():
                if name in local:
                    continue
                try:
                    local[name] = ev.eval(expr)
                    progress = True
                except _Unresolved:
                    pass
            if not progress:
                break
        return local

    # -- per-call accounting ------------------------------------------------

    def _check_call(self, ctx: ModuleContext, fn: ast.FunctionDef,
                    call: ast.Call, ev: _Evaluator,
                    assigns: Dict[str, ast.expr],
                    lists: Dict[str, List[ast.expr]],
                    expr_seqs: Dict[str, List[Tuple[int, ...]]],
                    findings: List[Finding]) -> None:
        kw = {k.arg: k.value for k in call.keywords}
        in_specs, out_specs = kw.get("in_specs"), kw.get("out_specs")
        scratch = kw.get("scratch_shapes")
        grid_spec = kw.get("grid_spec")
        if isinstance(grid_spec, ast.Name):
            grid_spec = assigns.get(grid_spec.id)
        if isinstance(grid_spec, ast.Call):
            gkw = {k.arg: k.value for k in grid_spec.keywords}
            in_specs = in_specs or gkw.get("in_specs")
            out_specs = out_specs or gkw.get("out_specs")
            scratch = scratch or gkw.get("scratch_shapes")

        specs: List[SpecInfo] = []
        for kind, group in (("in", in_specs), ("out", out_specs)):
            for expr in self._iter_spec_exprs(group, assigns, lists):
                specs.append(self._eval_spec(kind, expr, ev, expr_seqs))
        for expr in self._iter_list(scratch, lists):
            specs.append(self._eval_scratch(expr, ev))
        # an expr-seq spec expands to several concrete specs
        flat: List[SpecInfo] = []
        for s in specs:
            flat.extend(s if isinstance(s, list) else [s])

        total = sum(s.bytes for s in flat)
        unresolved = sum(1 for s in flat if not s.resolved)
        entry = {
            "kernel": f"{_module_key(ctx.path)}:{fn.name}",
            "path": ctx.path, "line": call.lineno,
            "vmem_bytes": total,
            "vmem_mib": round(total / (1024 * 1024), 3),
            "budget_bytes": self.budget_bytes,
            "over_budget": total > self.budget_bytes,
            "unresolved_specs": unresolved,
            "specs": [s.to_dict() for s in flat],
        }
        self.entries.append(entry)
        if total > self.budget_bytes:
            findings.append(Finding(
                self.name, ctx.path, call.lineno, call.col_offset,
                f"pallas_call in `{fn.name}` needs ~"
                f"{entry['vmem_mib']} MiB of VMEM at production dims "
                f"(budget {self.budget_bytes // (1024 * 1024)} MiB) — "
                f"shrink the block tiles or stream the resident "
                f"operand from HBM"))

    def _iter_list(self, group: Optional[ast.AST],
                   lists: Dict[str, List[ast.expr]]) -> List[ast.expr]:
        if group is None:
            return []
        if isinstance(group, ast.Name):
            return lists.get(group.id, [])
        if isinstance(group, (ast.List, ast.Tuple)):
            return list(group.elts)
        return [group]

    def _iter_spec_exprs(self, group: Optional[ast.AST],
                         assigns: Dict[str, ast.expr],
                         lists: Dict[str, List[ast.expr]]
                         ) -> List[ast.expr]:
        out: List[ast.expr] = []
        for expr in self._iter_list(group, lists):
            if isinstance(expr, ast.Name):      # row/col/neg spec aliases
                expr = assigns.get(expr.id, expr)
            out.append(expr)
        return out

    def _eval_spec(self, kind: str, expr: ast.expr, ev: _Evaluator,
                   expr_seqs: Dict[str, List[Tuple[int, ...]]]):
        if isinstance(expr, ast.ListComp):
            return self._expand_comp(kind, expr, ev, expr_seqs)
        if not isinstance(expr, ast.Call):
            return SpecInfo(kind, None, 0, False, False)
        shape_arg = expr.args[0] if expr.args else None
        imap = expr.args[1] if len(expr.args) > 1 else None
        for k in expr.keywords:
            if k.arg == "index_map":
                imap = k.value
        streams = _index_map_streams(imap)
        if isinstance(shape_arg, (ast.Tuple, ast.List)):
            try:
                shape = ev.eval_shape(shape_arg)
            except _Unresolved:
                return SpecInfo(kind, None, 0, streams, False)
            nbytes = _prod(shape) * 4 * (2 if streams else 1)
            return SpecInfo(kind, shape, nbytes, streams, True)
        if shape_arg is not None:
            key = ast.unparse(shape_arg)
            if key in expr_seqs:               # rare: direct expr binding
                return [SpecInfo(kind, s, _prod(s) * 4 *
                                 (2 if streams else 1), streams, True)
                        for s in expr_seqs[key]]
        return SpecInfo(kind, None, 0, streams, False)

    def _expand_comp(self, kind: str, comp: ast.ListComp, ev: _Evaluator,
                     expr_seqs: Dict[str, List[Tuple[int, ...]]]
                     ) -> List[SpecInfo]:
        """``[pl.BlockSpec(c.shape, lambda i: (0, 0)) for c in cbs]`` —
        the loop expression's values come from MODULE_EXPR_SEQS."""
        elt = comp.elt
        if not isinstance(elt, ast.Call) or not elt.args:
            return [SpecInfo(kind, None, 0, False, False)]
        imap = elt.args[1] if len(elt.args) > 1 else None
        streams = _index_map_streams(imap)
        key = ast.unparse(elt.args[0])
        if key in expr_seqs:
            return [SpecInfo(kind, s, _prod(s) * 4 *
                             (2 if streams else 1), streams, True)
                    for s in expr_seqs[key]]
        try:
            shape = ev.eval_shape(elt.args[0])
        except _Unresolved:
            return [SpecInfo(kind, None, 0, streams, False)]
        return [SpecInfo(kind, shape, _prod(shape) * 4 *
                         (2 if streams else 1), streams, True)]

    def _eval_scratch(self, expr: ast.expr, ev: _Evaluator) -> SpecInfo:
        if not isinstance(expr, ast.Call) or not expr.args:
            return SpecInfo("scratch", None, 0, False, False)
        try:
            shape = ev.eval_shape(expr.args[0])
        except _Unresolved:
            return SpecInfo("scratch", None, 0, False, False)
        elem = 4
        if len(expr.args) > 1:
            dt = dotted_name(expr.args[1]).split(".")[-1]
            elem = DTYPE_BYTES.get(dt, 4)
        return SpecInfo("scratch", shape, _prod(shape) * elem, False, True)

    # -- report -------------------------------------------------------------

    def finalize(self) -> List[Finding]:
        if self.report_path and self.entries:
            os.makedirs(os.path.dirname(self.report_path) or ".",
                        exist_ok=True)
            report = {
                "budget_bytes": self.budget_bytes,
                "budget_mib": round(self.budget_bytes / (1024 * 1024), 3),
                "n_kernels": len(self.entries),
                "n_over_budget": sum(1 for e in self.entries
                                     if e["over_budget"]),
                "kernels": sorted(self.entries,
                                  key=lambda e: -int(e["vmem_bytes"])),
            }
            with open(self.report_path, "w") as f:
                json.dump(report, f, indent=2)
                f.write("\n")
        return []


def _prod(shape: Sequence[int]) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out
