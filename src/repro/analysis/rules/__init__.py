"""Rule registry: every invariant pass the analyzer knows about."""
from __future__ import annotations

from typing import Dict, List

from repro.analysis.base import Rule
from repro.analysis.rules.determinism import DeterminismRule
from repro.analysis.rules.donation import DonationSafetyRule
from repro.analysis.rules.error_handling import ErrorHandlingRule
from repro.analysis.rules.lock_discipline import LockDisciplineRule
from repro.analysis.rules.vmem_budget import VmemBudgetRule

ALL_RULE_CLASSES = (LockDisciplineRule, DonationSafetyRule,
                    DeterminismRule, ErrorHandlingRule, VmemBudgetRule)


def default_rules(**vmem_kwargs) -> List[Rule]:
    """One fresh instance of every registered rule.  ``vmem_kwargs``
    (``budget_bytes``, ``report_path``) parameterize the VMEM pass."""
    return [LockDisciplineRule(), DonationSafetyRule(), DeterminismRule(),
            ErrorHandlingRule(), VmemBudgetRule(**vmem_kwargs)]


def rules_by_name() -> Dict[str, type]:
    return {cls.name: cls for cls in ALL_RULE_CLASSES}
