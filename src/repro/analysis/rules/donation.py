"""Rule ``donation-safety`` — no reads of a donated ``TrainState``.

PR 4's jitted train step is built with ``donate_argnums=(0,)``: the
buffers of the state passed as argument 0 are reused for the outputs,
so *any* later read through the old reference observes freed/aliased
device memory (a real use-after-donation bug shipped in the train
example's SIGTERM handler before it was made cooperative).

The pass is a per-function lexical dataflow:

* a *step producer* is a call to ``make_train_step`` (any dotted
  prefix) without ``donate=False``/``jit=False``, or a direct
  ``jax.jit(..., donate_argnums=...)`` whose donated positions include
  0;
* names bound to a producer result in the same function — and ``self``
  attributes bound to a producer result anywhere in the same class —
  are *donated steps*;
* calling a donated step taints the expression passed as argument 0
  (a plain name or ``self`` attribute);
* any later read of the tainted expression is flagged;
* rebinding the name/attribute (assignment, tuple-unpack target, for
  target, ``with ... as``) clears the taint — the canonical
  ``state, m = step(state, batch, key)`` is clean.

Loop bodies are walked twice so a loop-carried taint (tainted on
iteration ``i``, read at the top of iteration ``i+1``) is caught.
Nested function bodies are skipped: their execution time is unknown
(the SIGTERM-handler class of bug is guarded by the cooperative-flag
convention, not this pass).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.base import Finding, ModuleContext, Rule, dotted_name


def _const_contains_zero(node: Optional[ast.AST]) -> bool:
    if node is None:
        return False
    if isinstance(node, ast.Constant):
        return node.value == 0
    if isinstance(node, (ast.Tuple, ast.List)):
        return any(isinstance(e, ast.Constant) and e.value == 0
                   for e in node.elts)
    return True      # dynamic donate_argnums: assume arg 0 is donated


def is_step_producer(call: ast.Call) -> bool:
    """Does this call build a step that donates its first argument?"""
    name = dotted_name(call.func)
    if name.split(".")[-1] == "make_train_step":
        for kw in call.keywords:
            if kw.arg in ("donate", "jit") and isinstance(
                    kw.value, ast.Constant) and kw.value.value is False:
                return False
        return True
    if name.split(".")[-1] == "jit":
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                return _const_contains_zero(kw.value)
    return False


def _taint_key(node: ast.AST) -> Optional[str]:
    """Taintable expressions: bare names and ``self.X`` attributes."""
    if isinstance(node, ast.Name):
        return node.id
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return f"self.{node.attr}"
    return None


def _donated_class_attrs(cls: ast.ClassDef) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and is_step_producer(node.value):
            for t in node.targets:
                key = _taint_key(t)
                if key and key.startswith("self."):
                    out.add(key[len("self."):])
    return out


class DonationSafetyRule(Rule):
    name = "donation-safety"
    description = ("no read of a state variable after it was passed as "
                   "argument 0 to a donated jitted train step")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        findings: List[Finding] = []
        # class name -> attrs holding donated steps (self._step_fn, ...)
        donated_attrs: Dict[ast.ClassDef, Set[str]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                donated_attrs[node] = _donated_class_attrs(node)

        def enclosing_attrs(fn: ast.FunctionDef) -> Set[str]:
            for cls, attrs in donated_attrs.items():
                if fn in cls.body:
                    return attrs
            return set()

        for fn in [n for n in ast.walk(ctx.tree)
                   if isinstance(n, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))]:
            self._check_function(ctx, fn, enclosing_attrs(fn), findings)
        return findings

    # -- per-function lexical dataflow --------------------------------------

    def _check_function(self, ctx: ModuleContext, fn: ast.FunctionDef,
                        class_step_attrs: Set[str],
                        findings: List[Finding]) -> None:
        step_names: Set[str] = set()
        tainted: Dict[str, int] = {}     # key -> line it was donated at

        def is_step_call(call: ast.Call) -> bool:
            f = call.func
            if isinstance(f, ast.Name) and f.id in step_names:
                return True
            if isinstance(f, ast.Attribute) and isinstance(
                    f.value, ast.Name) and f.value.id == "self" \
                    and f.attr in class_step_attrs:
                return True
            if isinstance(f, ast.Call) and is_step_producer(f):
                return True               # make_train_step(...)(state, ...)
            return False

        def eval_expr(node: Optional[ast.AST]) -> None:
            if node is None:
                return
            for sub in ast.walk(node):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.Lambda)):
                    continue              # unknown execution time
                key = _taint_key(sub)
                if key is None or key not in tainted:
                    continue
                if isinstance(sub, ast.Name) and not isinstance(
                        sub.ctx, ast.Load):
                    continue
                findings.append(Finding(
                    self.name, ctx.path, sub.lineno, sub.col_offset,
                    f"`{key}` is read after being donated to a jitted "
                    f"train step at line {tainted[key]} — its buffers "
                    f"were reused for the step's outputs (rebind the "
                    f"name from the step's return value instead)"))
                del tainted[key]          # one finding per donation event
            # taints fire *after* read checks: args are read pre-donation
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call) and is_step_call(sub) \
                        and sub.args:
                    key = _taint_key(sub.args[0])
                    if key is not None:
                        tainted[key] = sub.lineno

        def bind(target: ast.AST) -> None:
            if isinstance(target, (ast.Tuple, ast.List)):
                for e in target.elts:
                    bind(e)
                return
            if isinstance(target, ast.Starred):
                bind(target.value)
                return
            key = _taint_key(target)
            if key is not None:
                tainted.pop(key, None)

        def run(stmts: List[ast.stmt]) -> None:
            for s in stmts:
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                    continue
                if isinstance(s, ast.Assign):
                    eval_expr(s.value)
                    if isinstance(s.value, ast.Call) \
                            and is_step_producer(s.value):
                        for t in s.targets:
                            if isinstance(t, ast.Name):
                                step_names.add(t.id)
                    for t in s.targets:
                        bind(t)
                elif isinstance(s, ast.AnnAssign):
                    eval_expr(s.value)
                    bind(s.target)
                elif isinstance(s, ast.AugAssign):
                    eval_expr(s.target)   # augassign reads the target
                    eval_expr(s.value)
                    bind(s.target)
                elif isinstance(s, (ast.For, ast.AsyncFor)):
                    eval_expr(s.iter)
                    bind(s.target)
                    run(s.body)           # twice: catch loop-carried
                    bind(s.target)
                    run(s.body)
                    run(s.orelse)
                elif isinstance(s, ast.While):
                    eval_expr(s.test)
                    run(s.body)
                    eval_expr(s.test)
                    run(s.body)
                    run(s.orelse)
                elif isinstance(s, ast.If):
                    eval_expr(s.test)
                    run(s.body)
                    run(s.orelse)
                elif isinstance(s, (ast.With, ast.AsyncWith)):
                    for item in s.items:
                        eval_expr(item.context_expr)
                        if item.optional_vars is not None:
                            bind(item.optional_vars)
                    run(s.body)
                elif isinstance(s, ast.Try):
                    run(s.body)
                    for h in s.handlers:
                        run(h.body)
                    run(s.orelse)
                    run(s.finalbody)
                elif isinstance(s, ast.Return):
                    eval_expr(s.value)
                elif isinstance(s, (ast.Expr, ast.Assert, ast.Raise,
                                    ast.Delete)):
                    for v in ast.iter_child_nodes(s):
                        eval_expr(v)
                else:
                    eval_expr(getattr(s, "value", None))

        run(fn.body)
