"""Rule ``lock-discipline`` — the serving tier's locking contracts.

The serving tier is concurrent under three hand-enforced disciplines:

* **Device (MVCC) stores** (the device-resident ``ClusterQueueStore``
  shape: they own a ``write_lock`` *and* an ``_state`` snapshot dict).
  Readers take one GIL-atomic ``self._state`` reference and never lock;
  the safety argument is that *every writer-side mutation* — the
  ``_state`` rebind itself plus the host mirrors that must stay in sync
  with it (``epoch``, ``ring_seen``, ``d_count``, ``_cursor_host``) —
  happens lexically inside a ``with self.write_lock:`` block.  An
  unlocked write to any of these can publish a snapshot whose mirrors
  disagree with it (ingest prep would then compute wrong slots).

* **Seqlock stores** (the host ``HostQueueStore`` shape: they own a
  ``write_lock`` *and* a ``gen`` generation array).  Every write to the
  store's protected arrays (``items``/``times``/``buf``/``ts`` data,
  ``cursor``/``heads``/``gen`` metadata) must happen lexically inside a
  ``with self.write_lock:`` block, and the data-array scatter must be
  *bracketed* by generation bumps (``gen += 1`` enter-odd before the
  first scatter, ``gen += 1`` exit-even after the last) so lock-free
  readers can detect a torn read.

* **Event rings** (``EventRing``-shaped classes: they own a ``_lock``
  *and* a ``committed`` watermark).  Reservation/commit state
  (``cursor``/``committed``) must only move under the ring lock.  The
  slot arrays themselves are deliberately written lock-free (the
  reservation protocol makes them disjoint), so they are *not*
  protected here.

* **Acquisition order**: the swap engine nests ring reads inside
  ``store.write_lock`` (``SwapServer._drain_into``), so the canonical
  order is write-lock -> ring-lock.  Acquiring a ``write_lock`` (or
  calling a store write path such as ``ingest``/``_drain_into``) while
  holding a ring ``_lock`` is an inversion and flagged.

``__init__`` is exempt: construction happens before the object is
shared.  Purely lexical analysis — a write behind a helper call is not
seen (keep scatters inline, as the store does today).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.base import Finding, ModuleContext, Rule, dotted_name

# protected attribute names, per class kind
SEQLOCK_DATA = ("items", "times", "buf", "ts")
SEQLOCK_META = ("cursor", "heads", "gen")
RING_STATE = ("cursor", "committed")
# device store: the snapshot rebind + the host mirrors that must stay
# consistent with it
DEVICE_STATE = ("_state", "epoch", "ring_seen", "d_count", "_cursor_host")

# calls that take a store's write lock internally: invoking them while
# holding a ring lock inverts the canonical order
WRITE_PATH_CALLS = ("ingest", "_drain_into")

_WRITE_LOCK = "write_lock"
_RING_LOCK = "ring_lock"


def _self_attrs_assigned(cls: ast.ClassDef) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    out.add(t.attr)
    return out


def _write_target_attr(target: ast.AST) -> Optional[str]:
    """``self.X = ...`` / ``self.X[...] = ...`` -> ``X``."""
    if isinstance(target, ast.Subscript):
        target = target.value
    if (isinstance(target, ast.Attribute)
            and isinstance(target.value, ast.Name)
            and target.value.id == "self"):
        return target.attr
    return None


def _acquired_locks(node: ast.With) -> Set[str]:
    locks: Set[str] = set()
    for item in node.items:
        name = dotted_name(item.context_expr)
        if name.endswith(".write_lock"):
            locks.add(_WRITE_LOCK)
        elif name.endswith("._lock") or name == "_lock":
            locks.add(_RING_LOCK)
    return locks


class LockDisciplineRule(Rule):
    name = "lock-discipline"
    description = ("device-store / seqlock-store / event-ring writes "
                   "must hold their lock, seqlock scatters must be "
                   "gen-bracketed, and lock acquisition order must not "
                   "invert")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        findings: List[Finding] = []
        for cls in [n for n in ast.walk(ctx.tree)
                    if isinstance(n, ast.ClassDef)]:
            attrs = _self_attrs_assigned(cls)
            is_store = {"write_lock", "gen"} <= attrs
            is_device = {"write_lock", "_state"} <= attrs
            is_ring = {"_lock", "committed"} <= attrs
            if not (is_store or is_device or is_ring):
                continue
            protected: Dict[str, str] = {}
            if is_store:
                for a in SEQLOCK_DATA + SEQLOCK_META:
                    if a in attrs:
                        protected[a] = _WRITE_LOCK
            if is_device:
                for a in DEVICE_STATE:
                    if a in attrs:
                        protected[a] = _WRITE_LOCK
            if is_ring:
                for a in RING_STATE:
                    if a in attrs:
                        protected[a] = _RING_LOCK
            for fn in cls.body:
                if (isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and fn.name != "__init__"):
                    self._check_method(ctx, cls, fn, protected, is_store,
                                       findings)
        return findings

    # -- per-method walk ----------------------------------------------------

    def _check_method(self, ctx: ModuleContext, cls: ast.ClassDef,
                      fn: ast.FunctionDef, protected: Dict[str, str],
                      is_store: bool, findings: List[Finding]) -> None:

        def visit(stmts, held: Set[str]):
            for s in stmts:
                if isinstance(s, ast.With):
                    acquired = _acquired_locks(s)
                    if _RING_LOCK in held and _WRITE_LOCK in acquired:
                        findings.append(Finding(
                            self.name, ctx.path, s.lineno, s.col_offset,
                            "lock-order inversion: write_lock acquired "
                            "while holding the ring lock (canonical "
                            "order is write_lock -> ring lock, see "
                            "SwapServer._drain_into)"))
                    if is_store and _WRITE_LOCK in acquired:
                        self._check_gen_bracket(ctx, cls, s, findings)
                    visit(s.body, held | acquired)
                    continue
                if isinstance(s, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                    targets = (s.targets if isinstance(s, ast.Assign)
                               else [s.target])
                    for t in targets:
                        for leaf in (t.elts if isinstance(
                                t, (ast.Tuple, ast.List)) else [t]):
                            attr = _write_target_attr(leaf)
                            lock = protected.get(attr or "")
                            if lock and lock not in held:
                                what = ("with self.write_lock"
                                        if lock == _WRITE_LOCK
                                        else "with self._lock")
                                findings.append(Finding(
                                    self.name, ctx.path, s.lineno,
                                    s.col_offset,
                                    f"write to protected `self.{attr}` of "
                                    f"{cls.name} outside `{what}:` — "
                                    f"lock-free readers may observe a "
                                    f"torn state"))
                if _RING_LOCK in held and not isinstance(
                        s, (ast.If, ast.For, ast.While, ast.Try)):
                    for call in [n for n in ast.walk(s)
                                 if isinstance(n, ast.Call)]:
                        cname = dotted_name(call.func)
                        if cname.split(".")[-1] in WRITE_PATH_CALLS:
                            findings.append(Finding(
                                self.name, ctx.path, call.lineno,
                                call.col_offset,
                                f"`{cname}` (a store write path that "
                                f"takes write_lock) called while holding "
                                f"the ring lock — lock-order inversion"))
                for attr in ("body", "orelse", "finalbody"):
                    visit(getattr(s, attr, []) or [], held)
                for h in getattr(s, "handlers", []) or []:
                    visit(h.body, held)

        visit(fn.body, set())

    def _check_gen_bracket(self, ctx: ModuleContext, cls: ast.ClassDef,
                           with_node: ast.With,
                           findings: List[Finding]) -> None:
        """Inside one ``with self.write_lock`` block: every data-array
        subscript scatter must be preceded and followed by a ``gen``
        bump so readers started mid-write retry."""
        scatters: List[Tuple[int, str]] = []
        bumps: List[int] = []
        for node in ast.walk(with_node):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if not isinstance(t, ast.Subscript):
                        continue
                    attr = _write_target_attr(t)
                    if attr in SEQLOCK_DATA:
                        scatters.append((node.lineno, attr))
                    elif attr == "gen":
                        bumps.append(node.lineno)
        if not scatters:
            return
        first = min(ln for ln, _ in scatters)
        last = max(ln for ln, _ in scatters)
        if not (any(b < first for b in bumps)
                and any(b > last for b in bumps)):
            ln, attr = min(scatters)
            findings.append(Finding(
                self.name, ctx.path, ln, 0,
                f"scatter to `self.{attr}` in {cls.name} is not "
                f"bracketed by seqlock generation bumps (`self.gen[...] "
                f"+= 1` before the first and after the last array "
                f"write)"))
