"""Rule ``error-handling`` — no silent exception swallows in library code.

The fault-tolerance contract (PR 9) is that every failure is *visible*:
a stage either retries, degrades with a counter, or propagates.  A bare
``except:`` (which also eats ``KeyboardInterrupt``/``SystemExit`` — and
here, the chaos layer's ``InjectedCrash``) or an
``except Exception: pass`` swallow hides exactly the failures the
degradation machinery and the chaos tier exist to surface.

Two checks, scoped to library code
(``src/repro/{core,lifecycle,data,kernels}/``):

* **bare except** — ``except:`` with no exception type, flagged
  unconditionally: it cannot distinguish a recoverable failure from
  process-control exceptions.
* **broad swallow** — ``except Exception`` / ``except BaseException``
  (alone or inside a tuple) whose handler body does *nothing* (only
  ``pass``, ``...`` or a docstring).  Broad handlers that do real work
  — count, shed, quarantine, return a fallback — are legitimate
  degradation and are not flagged.

Escape hatch: the standard pragma,
``# repro: disable=error-handling — reason``.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.base import Finding, ModuleContext, Rule, dotted_name

SCOPE_DIRS = ("core", "lifecycle", "data", "kernels")

BROAD_NAMES = ("Exception", "BaseException",
               "builtins.Exception", "builtins.BaseException")


def _in_scope(path: str) -> bool:
    parts = path.replace("\\", "/").split("/")
    return "repro" in parts and any(d in parts for d in SCOPE_DIRS)


def _is_broad(expr: ast.expr) -> bool:
    """True when the except clause catches Exception/BaseException,
    directly or as a member of a tuple clause."""
    if isinstance(expr, ast.Tuple):
        return any(_is_broad(e) for e in expr.elts)
    return dotted_name(expr) in BROAD_NAMES


def _body_is_swallow(body: List[ast.stmt]) -> bool:
    """A handler body that does nothing: only pass / ``...`` / a bare
    string (docstring-style comment)."""
    for stmt in body:
        if isinstance(stmt, ast.Pass):
            continue
        if (isinstance(stmt, ast.Expr)
                and isinstance(stmt.value, ast.Constant)
                and (stmt.value.value is Ellipsis
                     or isinstance(stmt.value.value, str))):
            continue
        return False
    return True


class ErrorHandlingRule(Rule):
    name = "error-handling"
    description = ("bare `except:` and do-nothing `except Exception:` "
                   "swallows in library code hide failures the "
                   "degradation/chaos machinery must see")

    def applies(self, path: str) -> bool:
        return _in_scope(path)

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                out.append(Finding(
                    self.name, ctx.path, node.lineno, node.col_offset,
                    "bare `except:` also catches KeyboardInterrupt/"
                    "SystemExit (and InjectedCrash) — name the "
                    "exceptions, or `except Exception` with real "
                    "handling"))
            elif _is_broad(node.type) and _body_is_swallow(node.body):
                caught = (dotted_name(node.type)
                          if not isinstance(node.type, ast.Tuple)
                          else "Exception")
                out.append(Finding(
                    self.name, ctx.path, node.lineno, node.col_offset,
                    f"`except {caught}` swallows the failure silently — "
                    f"count it, degrade explicitly, or re-raise"))
        return out
