"""Rule ``determinism`` — keyed randomness, no wall clocks, pure traces.

The lifecycle contract (PR 3/6) is that ``refresh == rebuild`` holds
bit-exactly: every stochastic choice in library code must derive from an
explicit *tuple* key (``np.random.default_rng((seed, tag, block))`` —
the ``walk_uniforms``/``hub_uniforms`` convention), never from global
RNG state or the wall clock.  Traced code (jit / pallas) must stay pure:
host effects inside a trace either fail under jit or silently run once
at trace time, which is worse.

Three checks, scoped to library code
(``src/repro/{core,lifecycle,kernels,data,models,obs,faults}/``):

* **unkeyed RNG** — any ``np.random.<fn>()`` module-level call (global
  mutable RNG state), and any ``default_rng()`` whose seed is missing,
  a bare numeric constant, or seed arithmetic (``seed + day`` collides
  across streams; use a tuple key).
* **wall clock** — calls to ``time.time``/``perf_counter``/
  ``monotonic``/``datetime.now`` and friends.  Passing a clock
  *function* as a default (injectable clock) is fine — only calls are
  flagged.  ``src/repro/obs/`` is the single sanctioned clock module
  (``repro.obs.clock.SystemClock`` wraps the raw clocks behind the
  injectable ``Clock``); everything else must go through a telemetry
  span / injected clock, and a *new* raw clock call anywhere outside
  ``obs`` fails analysis.
* **trace purity** — ``print``, ``.item()``, ``np.asarray``/
  ``np.array`` and ``jax.device_get`` inside functions that are
  jit-wrapped (decorator or ``jax.jit(fn)`` call), handed to
  ``pl.pallas_call`` (directly or through ``functools.partial``), or
  named ``*_kernel``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.base import Finding, ModuleContext, Rule, dotted_name

SCOPE_DIRS = ("core", "lifecycle", "kernels", "data", "models", "obs",
              "faults")

#: the one module tree allowed to read the raw wall clock — everything
#: else injects ``repro.obs.clock.Clock`` (usually via a telemetry span)
CLOCK_ALLOWED_DIR = "obs"

#: np.random attributes that are keyed constructors, not global-state draws
ALLOWED_NP_RANDOM = ("default_rng", "Generator", "SeedSequence",
                     "PCG64", "Philox", "SFC64", "MT19937", "BitGenerator")

WALL_CLOCK_CALLS = (
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "datetime.now", "datetime.utcnow",
    "datetime.datetime.now", "datetime.datetime.utcnow",
)

HOST_EFFECT_CALLS = ("np.asarray", "numpy.asarray", "np.array",
                     "numpy.array", "jax.device_get")


def _is_module_path(path: str) -> bool:
    parts = path.replace("\\", "/").split("/")
    return "repro" in parts and any(d in parts for d in SCOPE_DIRS)


def _clock_sanctioned(path: str) -> bool:
    """True for ``.../repro/obs/...`` — the injectable-clock module."""
    parts = path.replace("\\", "/").split("/")
    return ("repro" in parts
            and CLOCK_ALLOWED_DIR in parts[parts.index("repro"):])


def _bad_seed(call: ast.Call) -> str:
    """Non-empty message when a ``default_rng`` seed isn't a tuple key."""
    if not call.args and not call.keywords:
        return "no seed: draws depend on OS entropy"
    seed = call.args[0] if call.args else call.keywords[0].value
    if isinstance(seed, ast.Constant):
        return (f"bare constant seed {seed.value!r}: use a tuple key "
                f"`(seed, stream_tag, ...)` so streams cannot collide")
    if isinstance(seed, ast.BinOp):
        return ("arithmetic seed: `seed + offset` streams can collide "
                "(use a tuple key `(seed, stream_tag, ...)`)")
    return ""           # tuple / variable / SeedSequence: assume keyed


class DeterminismRule(Rule):
    name = "determinism"
    description = ("library code must use tuple-keyed RNG, never the "
                   "wall clock; traced (jit/pallas) functions must be "
                   "free of host effects")

    def applies(self, path: str) -> bool:
        return _is_module_path(path)

    def check(self, ctx: ModuleContext) -> List[Finding]:
        findings: List[Finding] = []
        self._check_rng_and_clock(ctx, findings)
        for fn, how in self._traced_functions(ctx.tree).items():
            self._check_trace_purity(ctx, fn, how, findings)
        return findings

    # -- unkeyed RNG + wall clock -------------------------------------------

    def _check_rng_and_clock(self, ctx: ModuleContext,
                             findings: List[Finding]) -> None:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            parts = name.split(".")
            tail = parts[-1]
            if len(parts) >= 2 and parts[-2] == "random" \
                    and parts[0] in ("np", "numpy") \
                    and tail not in ALLOWED_NP_RANDOM:
                findings.append(Finding(
                    self.name, ctx.path, node.lineno, node.col_offset,
                    f"`{name}()` draws from the global numpy RNG — "
                    f"library code must thread an explicit "
                    f"`default_rng((seed, tag, ...))` generator"))
            elif tail == "default_rng":
                msg = _bad_seed(node)
                if msg:
                    findings.append(Finding(
                        self.name, ctx.path, node.lineno,
                        node.col_offset, f"`{name}(...)`: {msg}"))
            elif name in WALL_CLOCK_CALLS \
                    and not _clock_sanctioned(ctx.path):
                findings.append(Finding(
                    self.name, ctx.path, node.lineno, node.col_offset,
                    f"`{name}()` reads the wall clock in library code — "
                    f"route timing through `repro.obs` (spans / the "
                    f"injectable Clock); only `src/repro/obs/` may call "
                    f"the raw clock"))

    # -- traced-function discovery ------------------------------------------

    def _traced_functions(self, tree: ast.Module
                          ) -> Dict[ast.FunctionDef, str]:
        defs: Dict[str, ast.FunctionDef] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs[node.name] = node

        # name -> partial(F, ...) target, for `kern = partial(f, n)` then
        # `pl.pallas_call(kern, ...)`
        partial_of: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call):
                fname = dotted_name(node.value.func)
                if fname.split(".")[-1] == "partial" and node.value.args \
                        and isinstance(node.value.args[0], ast.Name):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            partial_of[t.id] = node.value.args[0].id

        traced: Dict[ast.FunctionDef, str] = {}

        def mark(name: str, how: str) -> None:
            name = partial_of.get(name, name)
            fn = defs.get(name)
            if fn is not None and fn not in traced:
                traced[fn] = how

        for fn in defs.values():
            for dec in fn.decorator_list:
                text = ast.unparse(dec)
                if "jit" in text.replace("(", " ").replace(".", " ").split():
                    traced.setdefault(fn, "jit-decorated")
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            fname = dotted_name(node.func)
            tail = fname.split(".")[-1]
            if tail == "jit":
                for a in node.args:
                    if isinstance(a, ast.Name):
                        mark(a.id, "jax.jit-wrapped")
            elif tail == "pallas_call" and node.args:
                a = node.args[0]
                if isinstance(a, ast.Name):
                    mark(a.id, "pallas kernel")
                elif isinstance(a, ast.Call) and a.args and isinstance(
                        a.args[0], ast.Name) and dotted_name(
                            a.func).split(".")[-1] == "partial":
                    mark(a.args[0].id, "pallas kernel")
        for name, fn in defs.items():
            if name.endswith("_kernel"):
                traced.setdefault(fn, "pallas kernel")
        return traced

    def _check_trace_purity(self, ctx: ModuleContext, fn: ast.FunctionDef,
                            how: str, findings: List[Finding]) -> None:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            bad = ""
            if name == "print":
                bad = "`print` runs on the host"
            elif name in HOST_EFFECT_CALLS:
                bad = f"`{name}` forces a device->host transfer"
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                bad = "`.item()` forces a device->host sync"
            if bad:
                findings.append(Finding(
                    self.name, ctx.path, node.lineno, node.col_offset,
                    f"{bad} inside `{fn.name}` ({how}) — traced code "
                    f"must be pure (use jax.debug.print / return the "
                    f"value instead)"))
