"""File discovery, rule execution, and output formatting."""
from __future__ import annotations

import ast
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence

from repro.analysis.base import (Finding, ModuleContext, Rule,
                                 apply_suppressions, parse_pragmas)
from repro.analysis.rules import default_rules, rules_by_name

PARSE_ERROR_RULE = "parse-error"


def iter_source_files(paths: Sequence[str]) -> Iterable[str]:
    """Expand files/directories into a sorted stream of ``.py`` paths."""
    seen = set()
    for p in paths:
        if os.path.isfile(p):
            if p not in seen:
                seen.add(p)
                yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d != "__pycache__"
                             and not d.startswith("."))
            for name in sorted(files):
                if name.endswith(".py"):
                    full = os.path.join(root, name)
                    if full not in seen:
                        seen.add(full)
                        yield full


def analyze_file(path: str, rules: Sequence[Rule]) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(PARSE_ERROR_RULE, path, e.lineno or 0, 0,
                        f"file does not parse: {e.msg}")]
    lines = source.splitlines()
    ctx = ModuleContext(path=path, source=source, tree=tree, lines=lines)
    findings: List[Finding] = []
    for rule in rules:
        if rule.applies(path):
            findings.extend(rule.check(ctx))
    pragmas = parse_pragmas(lines)
    return apply_suppressions(findings, pragmas, path)


def run_analysis(paths: Sequence[str],
                 rules: Optional[Sequence[Rule]] = None,
                 **vmem_kwargs) -> List[Finding]:
    """Run every rule over every file; findings sorted by location."""
    if rules is None:
        rules = default_rules(**vmem_kwargs)
    findings: List[Finding] = []
    for path in iter_source_files(paths):
        findings.extend(analyze_file(path, rules))
    for rule in rules:
        findings.extend(rule.finalize())
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def select_rules(names: Optional[Sequence[str]],
                 **vmem_kwargs) -> List[Rule]:
    if not names:
        return default_rules(**vmem_kwargs)
    registry = rules_by_name()
    unknown = [n for n in names if n not in registry]
    if unknown:
        raise SystemExit(
            f"unknown rule(s): {', '.join(unknown)} "
            f"(known: {', '.join(sorted(registry))})")
    out: List[Rule] = []
    for n in names:
        cls = registry[n]
        out.append(cls(**vmem_kwargs) if n == "vmem-budget" else cls())
    return out


def active(findings: Sequence[Finding]) -> List[Finding]:
    """Findings that should fail the run (not suppressed)."""
    return [f for f in findings if not f.suppressed]


def format_text(findings: Sequence[Finding]) -> str:
    out: List[str] = [f.render() for f in findings]
    n_active = len(active(findings))
    n_supp = len(findings) - n_active
    out.append(f"{n_active} finding(s), {n_supp} suppressed")
    return "\n".join(out)


def format_json(findings: Sequence[Finding]) -> str:
    by_rule: Dict[str, int] = {}
    for f in active(findings):
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    doc = {
        "findings": [f.to_dict() for f in findings],
        "summary": {
            "total": len(findings),
            "active": len(active(findings)),
            "suppressed": len(findings) - len(active(findings)),
            "by_rule": by_rule,
        },
    }
    return json.dumps(doc, indent=2)
