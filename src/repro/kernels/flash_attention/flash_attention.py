"""Pallas TPU flash attention (forward) — IO-aware online softmax.

Canonical 3-D grid (batch*head, q_block, kv_block): Q/K/V stream through
VMEM one (block_q x d) / (block_k x d) tile at a time; running max /
normalizer / accumulator live in VMEM scratch and never touch HBM; the
output tile is written once on the last kv step.  Causal blocks that are
fully masked are skipped via a whole-block predicate (the elementwise
mask still applies within diagonal blocks).  Ragged (non-block-aligned)
sequence lengths are handled with an explicit kv-length mask, so both
training (S == T) and decode (S == 1, T = cache length) shapes work.

Block sizes are MXU-aligned (multiples of 128 on the matmul dims).  On
this CPU container the kernel runs in interpret mode; on TPU it compiles
to Mosaic as-is.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, should_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            n_kv: int, valid_q: int, valid_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    offset = valid_k - valid_q          # decode: q row i is key position
                                        # offset + i

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip blocks that are entirely beyond the causal frontier
    q_last = (qi + 1) * block_q - 1 + offset
    live = (not causal) or (ki * block_k <= q_last)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (bq, d)
        k = k_ref[0].astype(jnp.float32)                 # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
            + qi * block_q + offset
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) \
            + ki * block_k
        mask = cols < valid_k
        if causal:
            mask = mask & (rows >= cols)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_cur

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "valid_q",
                                             "valid_k", "interpret"))
def _run(q, k, v, *, causal, scale, block_q, block_k, valid_q, valid_k,
         interpret):
    BH, S, D = q.shape
    T = k.shape[1]
    n_q, n_kv = cdiv(S, block_q), cdiv(T, block_k)
    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             block_q=block_q, block_k=block_k, n_kv=n_kv,
                             valid_q=valid_q, valid_k=valid_k)
    return pl.pallas_call(
        kern,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q (B, Hq, S, D); k/v (B, Hkv, T, D), GQA via head repeat.

    Returns (B, Hq, S, D).  Inputs are padded to block multiples; padded
    key columns are masked exactly inside the kernel.
    """
    if interpret is None:
        interpret = should_interpret()
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, max(S, 8))
    bk = min(block_k, max(T, 8))
    pad_s = (-S) % bq
    pad_t = (-T) % bk
    qf = q.reshape(B * Hq, S, D)
    kf = k.reshape(B * Hq, T, D)
    vf = v.reshape(B * Hq, T, D)
    if pad_s:
        qf = jnp.pad(qf, ((0, 0), (0, pad_s), (0, 0)))
    if pad_t:
        kf = jnp.pad(kf, ((0, 0), (0, pad_t), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_t), (0, 0)))
    out = _run(qf, kf, vf, causal=causal, scale=scale, block_q=bq,
               block_k=bk, valid_q=S, valid_k=T, interpret=bool(interpret))
    return out[:, :S].reshape(B, Hq, S, D)
