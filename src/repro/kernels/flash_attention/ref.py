"""Pure-jnp oracle: softmax attention with optional causal mask + GQA."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, scale: float = None) -> jnp.ndarray:
    """q (B, Hq, S, D), k/v (B, Hkv, T, D) with Hq % Hkv == 0."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p.astype(v.dtype), v)
