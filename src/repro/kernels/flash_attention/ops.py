"""Public attention op with kernel/reference dispatch."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def attention(q, k, v, *, causal: bool = True,
              scale: Optional[float] = None,
              use_kernel: bool = False, **kw) -> jnp.ndarray:
    if use_kernel:
        return flash_attention(q, k, v, causal=causal, scale=scale, **kw)
    return attention_ref(q, k, v, causal=causal, scale=scale)
