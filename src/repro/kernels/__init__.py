# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Kernel families (each: <name>.py Pallas kernel + ref.py oracle +
# ops.py dispatch):
#   rq_assign          fused residual-quantization code assignment
#   embedding_bag      scalar-prefetch gather + bag reduce
#   fused_contrastive  margin/InfoNCE training tile
#   flash_attention    online-softmax attention
#   queue_gather       serving: cluster-queue gather + U2I2I union
#   ppr_walk           construction: fused PPR walk + visit counting
