"""Pallas TPU kernel: fused residual-quantization code assignment.

The serving index assigns a cluster code to every user at every embedding
refresh (hundreds of millions of rows): per row, L sequential
nearest-code searches with residual subtraction.  The fusion win on TPU:

  * codebooks stay resident in VMEM across the whole batch tile
    (production 5000x256 fp32 = 5.1 MiB + 50x256 = 51 KiB, well under
    the ~16 MiB VMEM budget);
  * distances are computed with the MXU (||r||^2 - 2 r.C^T + ||C||^2 —
    the cross term is a (Bt,d)@(d,n) matmul);
  * the selected-code gather is a one-hot (Bt,n)@(n,d) matmul — again
    MXU — avoiding an HBM gather round-trip between layers;
  * codes + reconstruction leave the kernel in one pass (the pure-jnp
    version round-trips the residual through HBM per layer).

Block layout: grid over batch tiles; x tile (Bt, d) in VMEM, codebooks
replicated per tile (index_map -> block 0).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, pad_to, should_interpret


def _kernel(x_ref, *refs, n_layers: int, n_codes: Tuple[int, ...]):
    code_refs = refs[:n_layers]      # codebooks (n_l, d)
    codes_out = refs[n_layers]       # (Bt, L) int32
    recon_out = refs[n_layers + 1]   # (Bt, d) f32

    x = x_ref[...].astype(jnp.float32)
    resid = x
    recon = jnp.zeros_like(x)
    for l in range(n_layers):
        C = code_refs[l][...].astype(jnp.float32)            # (n, d)
        # squared distances via MXU: ||r||^2 - 2 rC^T + ||C||^2
        cross = jax.lax.dot_general(
            resid, C, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (Bt, n)
        d2 = (jnp.sum(resid * resid, axis=1, keepdims=True)
              - 2.0 * cross + jnp.sum(C * C, axis=1)[None, :])
        k = jnp.argmin(d2, axis=1).astype(jnp.int32)         # (Bt,)
        onehot = (k[:, None] ==
                  jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
                  ).astype(jnp.float32)
        sel = jax.lax.dot_general(                            # (Bt, d) MXU
            onehot, C, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        resid = resid - sel
        recon = recon + sel
        codes_out[:, l] = k
    recon_out[...] = recon


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def _run(x, codebooks, *, block_b: int, interpret: bool):
    B, d = x.shape
    L = len(codebooks)
    grid = (cdiv(B, block_b),)
    kernel = functools.partial(_kernel, n_layers=L,
                               n_codes=tuple(c.shape[0] for c in codebooks))
    out_shapes = (jax.ShapeDtypeStruct((B, L), jnp.int32),
                  jax.ShapeDtypeStruct((B, d), jnp.float32))
    in_specs = [pl.BlockSpec((block_b, d), lambda i: (i, 0))]
    in_specs += [pl.BlockSpec(c.shape, lambda i: (0, 0)) for c in codebooks]
    out_specs = (pl.BlockSpec((block_b, L), lambda i: (i, 0)),
                 pl.BlockSpec((block_b, d), lambda i: (i, 0)))
    return pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shapes, interpret=interpret)(x, *codebooks)


def rq_assign(x: jnp.ndarray, codebooks: Sequence[jnp.ndarray], *,
              block_b: int = 256, interpret: bool = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused RQ assignment.  x (B, d) -> (codes (B, L), recon (B, d))."""
    if interpret is None:
        interpret = should_interpret()
    B, d = x.shape
    xp, orig_b = pad_to(x, 0, block_b)
    codes, recon = _run(xp, tuple(codebooks), block_b=block_b,
                        interpret=bool(interpret))
    return codes[:orig_b], recon[:orig_b]
