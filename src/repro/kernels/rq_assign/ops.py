"""Public op: RQ assignment with kernel/reference dispatch."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

from repro.kernels.rq_assign.ref import rq_assign_ref
from repro.kernels.rq_assign.rq_assign import rq_assign as rq_assign_kernel


def rq_assign(x: jnp.ndarray, codebooks: Sequence[jnp.ndarray], *,
              use_kernel: bool = True, block_b: int = 256
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if use_kernel:
        return rq_assign_kernel(x, codebooks, block_b=block_b)
    return rq_assign_ref(x, codebooks)


def flat_codes(codes: jnp.ndarray, sizes: Sequence[int]) -> jnp.ndarray:
    """(B, L) layer codes -> flat cluster id."""
    flat = jnp.zeros(codes.shape[0], jnp.int32)
    for l, n in enumerate(sizes):
        flat = flat * n + codes[:, l]
    return flat
