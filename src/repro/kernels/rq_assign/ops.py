"""Public op: RQ assignment with kernel/reference dispatch, plus the
chunked full-corpus encode used at index publication."""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rq_assign.ref import rq_assign_ref
from repro.kernels.rq_assign.rq_assign import rq_assign as rq_assign_kernel


def rq_assign(x: jnp.ndarray, codebooks: Sequence[jnp.ndarray], *,
              use_kernel: bool = True, block_b: int = 256
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if use_kernel:
        return rq_assign_kernel(x, codebooks, block_b=block_b)
    return rq_assign_ref(x, codebooks)


@functools.lru_cache(maxsize=8)
def _corpus_step(use_kernel: bool, block_b: int):
    if use_kernel:
        # the kernel entry is jitted internally with static block shapes
        return functools.partial(rq_assign_kernel, block_b=block_b)
    return jax.jit(lambda x, books: rq_assign_ref(x, books))


def rq_assign_corpus(x: np.ndarray, codebooks: Sequence[np.ndarray], *,
                     chunk: int = 8192, use_kernel: bool = False,
                     block_b: int = 256
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Full-corpus RQ encode for index publication: every chunk is
    padded to one fixed shape, so the whole pass — hundreds of millions
    of rows at production scale — reuses a single jitted trace instead
    of round-tripping a fresh compile/dispatch per batch.

    Row results are bit-identical to per-batch ``rq_assign`` on any
    batch split (each row's distances depend only on that row and the
    codebooks), which is what lets publication be audited against the
    online assignment path.  Returns host ``(codes (N, L) int32,
    recon (N, d) float32)``.
    """
    x = np.asarray(x, np.float32)
    n, d = x.shape
    L = len(codebooks)
    books = tuple(jnp.asarray(np.asarray(c, np.float32))
                  for c in codebooks)
    codes = np.empty((n, L), np.int32)
    recon = np.empty((n, d), np.float32)
    if n == 0:
        return codes, recon
    chunk = max(min(chunk, n), 1)
    step = _corpus_step(bool(use_kernel), block_b)
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)
        blk = x[lo:hi]
        if hi - lo < chunk:                  # pad: keep one traced shape
            blk = np.pad(blk, ((0, chunk - (hi - lo)), (0, 0)))
        c, r = step(jnp.asarray(blk), books)
        codes[lo:hi] = np.asarray(c)[: hi - lo]
        recon[lo:hi] = np.asarray(r)[: hi - lo]
    return codes, recon


def flat_codes(codes: jnp.ndarray, sizes: Sequence[int]) -> jnp.ndarray:
    """(B, L) layer codes -> flat cluster id."""
    flat = jnp.zeros(codes.shape[0], jnp.int32)
    for l, n in enumerate(sizes):
        flat = flat * n + codes[:, l]
    return flat


def flat_codes_np(codes: np.ndarray, sizes: Sequence[int]) -> np.ndarray:
    """Host-side ``flat_codes`` for publication artifacts."""
    flat = np.zeros(codes.shape[0], np.int64)
    for l, n in enumerate(sizes):
        flat = flat * n + codes[:, l]
    return flat
