"""Pure-jnp oracle for residual-quantization assignment (Eq. 9/10)."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp


def rq_assign_ref(x: jnp.ndarray, codebooks: Sequence[jnp.ndarray]
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, d); codebooks list of (n_l, d).

    Returns (codes (B, L) int32, recon (B, d) float32).
    """
    resid = x.astype(jnp.float32)
    recon = jnp.zeros_like(resid)
    codes = []
    for C in codebooks:
        C = C.astype(jnp.float32)
        d2 = (jnp.sum(resid * resid, axis=1, keepdims=True)
              - 2.0 * resid @ C.T + jnp.sum(C * C, axis=1)[None, :])
        k = jnp.argmin(d2, axis=1).astype(jnp.int32)
        sel = jnp.take(C, k, axis=0)
        resid = resid - sel
        recon = recon + sel
        codes.append(k)
    return jnp.stack(codes, axis=1), recon
