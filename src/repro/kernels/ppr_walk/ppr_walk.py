"""Pallas TPU kernel: fused PPR Monte-Carlo walk + visit-count pass.

Construction's hot loop walks R restart-walks of length L from every
backbone node and then counts visits per start (paper §4.2).  Done
naively that is L round-trips through HBM for the (m, D2) adjacency-row
gathers plus a host-side sort/run-length pass.  The fusion keeps each
start's whole workload in VMEM:

  * the padded adjacency (``nbrs``/``cum``, (N, D2)) stays VMEM-resident
    across the whole grid — the same residency contract as
    ``queue_gather``'s I2I table (production shards starts over cores so
    the hot subgraph fits the ~16 MiB budget; node ids must stay below
    2^24 for the f32 MXU gather to be exact);
  * one grid program walks all R walkers of one start: the row gather is
    a one-hot (R, N) @ (N, D2) MXU matmul, the inverse-CDF draw is a
    compare/count over the gathered (R, D2) cumulative row, and the
    trailing-pad clamp (f32 cumsums can top out below 1.0) re-uses the
    same masked-iota machinery;
  * per-start visit counting is an (S, S) equality reduction on the
    finished (1, S) trace row — multiplicity at first occurrence, zero
    elsewhere — so the host goes straight to top-k selection with no
    sort or run-length pass;
  * the transition/restart draws stream in as a host-generated (R, 2L)
    f32 block: the uniform stream is the cross-backend contract (numpy /
    jax / pallas walk bit-identical traces), so the kernel consumes it
    rather than owning a PRNG.

grid = (n_starts,): one program per start node, mirroring
``queue_gather``'s one-program-per-request layout.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import should_interpret


def _kernel(starts_ref, u_ref, nbrs_ref, cum_ref, vis_ref, cnt_ref, *,
            n_walks: int, walk_len: int, restart: float):
    W, L = n_walks, walk_len
    N, D2 = cum_ref.shape
    home = starts_ref[0, 0]
    u = u_ref[...]                                 # (W, 2L) f32
    nbrs = nbrs_ref[...].astype(jnp.float32)       # ids < 2^24: f32-exact
    cum = cum_ref[...]

    col_n = jax.lax.broadcasted_iota(jnp.int32, (W, N), 1)
    col_d = jax.lax.broadcasted_iota(jnp.int32, (W, D2), 1)
    pos = jnp.full((W, 1), home, jnp.int32)
    trace = []
    for t in range(L):
        onehot = (col_n == pos).astype(jnp.float32)
        rc = jax.lax.dot_general(onehot, cum, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        rn = jax.lax.dot_general(onehot, nbrs, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        us = u[:, 2 * t:2 * t + 1]                 # (W, 1)
        col = jnp.sum((rc < us).astype(jnp.int32), axis=1, keepdims=True)
        # clamp overflow draws (f32 cum[-1] < 1) to the last column with
        # positive mass — never onto a trailing -1 pad
        inc = jnp.concatenate([rc[:, :1] > 0, rc[:, 1:] > rc[:, :-1]],
                              axis=1)
        lastc = jnp.max(jnp.where(inc, col_d, 0), axis=1, keepdims=True)
        col = jnp.minimum(col, lastc)
        nxt = jnp.sum(jnp.where(col_d == col, rn, 0.0), axis=1,
                      keepdims=True).astype(jnp.int32)
        dead = (nxt < 0) | (rc[:, D2 - 1:D2] <= 0)
        nxt = jnp.where(dead, pos, nxt)
        rst = u[:, 2 * t + 1:2 * t + 2] < jnp.float32(restart)
        pos = jnp.where(rst, home, nxt)
        trace.append(pos)

    row = jnp.concatenate(trace, axis=1).reshape(1, W * L)
    vis_ref[...] = row
    # fused visit counting: multiplicity at first occurrence, 0 at dups
    S = W * L
    eq = row.T == row                              # eq[i, j]: v_i == v_j
    mult = jnp.sum(eq.astype(jnp.int32), axis=0, keepdims=True)
    ri = jax.lax.broadcasted_iota(jnp.int32, (S, S), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (S, S), 1)
    earlier = jnp.any(eq & (ri < ci), axis=0, keepdims=True)
    cnt_ref[...] = jnp.where(earlier, 0, mult)


@functools.partial(jax.jit, static_argnames=("n_walks", "walk_len",
                                             "restart", "interpret"))
def _run(starts, u, nbrs, cum, *, n_walks: int, walk_len: int,
         restart: float, interpret: bool):
    n = starts.shape[0]
    N, D2 = nbrs.shape
    S = n_walks * walk_len
    kernel = functools.partial(_kernel, n_walks=n_walks,
                               walk_len=walk_len, restart=restart)
    out_shapes = (jax.ShapeDtypeStruct((n, S), jnp.int32),
                  jax.ShapeDtypeStruct((n, S), jnp.int32))
    # The (N, D2) adjacency is VMEM-resident by contract: production
    # shards starts over cores so the hot subgraph fits, and the HBM
    # double-buffered variant for larger subgraphs is a ROADMAP item.
    # repro: disable=vmem-budget — deliberate resident adjacency (sharded to fit); HBM double-buffer variant tracked in ROADMAP
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b: (b, 0)),            # start id
            pl.BlockSpec((n_walks, 2 * walk_len),
                         lambda b: (b, 0)),                    # uniforms
            pl.BlockSpec((N, D2), lambda b: (0, 0)),           # nbrs
            pl.BlockSpec((N, D2), lambda b: (0, 0)),           # cum
        ],
        out_specs=(pl.BlockSpec((1, S), lambda b: (b, 0)),
                   pl.BlockSpec((1, S), lambda b: (b, 0))),
        out_shape=out_shapes,
        interpret=interpret)(starts, u, nbrs, cum)


def ppr_walk(nbrs, cum, starts, uniforms, *, restart: float,
             interpret: bool = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused PPR walk.  ``nbrs``/``cum`` (N, D2) padded adjacency,
    ``starts`` (n,) node ids, ``uniforms`` (n, n_walks, 2*walk_len) f32
    (column 2t: step draw, 2t+1: restart draw).

    Returns (visited (n, S) int32, counts (n, S) int32) with
    S = n_walks*walk_len; counts holds each node's multiplicity at its
    first occurrence in the row, 0 elsewhere.
    """
    if interpret is None:
        interpret = should_interpret()
    n, n_walks, two_l = uniforms.shape
    walk_len = two_l // 2
    starts2 = jnp.asarray(starts, jnp.int32).reshape(n, 1)
    u = jnp.asarray(uniforms, jnp.float32).reshape(n * n_walks, two_l)
    return _run(starts2, u, jnp.asarray(nbrs, jnp.int32),
                jnp.asarray(cum, jnp.float32), n_walks=int(n_walks),
                walk_len=int(walk_len), restart=float(restart),
                interpret=bool(interpret))
