"""Per-start Python oracle for the fused PPR walk + visit-count pass.

Deliberately written as the obvious sequential algorithm (walker by
walker, step by step) so it doubles as the readable spec that the
Pallas kernel and the vectorized numpy/jax walkers in ``core/ppr.py``
are all tested against:

  1. inverse-CDF transition: the next column is the count of cumulative
     entries strictly below the draw; a draw past the row's total mass
     (f32 cumsums can top out below 1.0) clamps to the last column with
     positive mass — never a trailing ``-1`` pad;
  2. dangling rows (no transition mass) hold the walker in place;
  3. a restart draw below ``restart`` teleports the walker home;
  4. the visit trace is recorded walker-major (walker w's step t lands
     at column ``w*walk_len + t``), and each distinct node's visit count
     is reported at its first occurrence in the trace, 0 elsewhere.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def ppr_walk_ref(nbrs: np.ndarray, cum: np.ndarray, starts: np.ndarray,
                 uniforms: np.ndarray, *, restart: float
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """nbrs/cum (N, D2), starts (n,), uniforms (n, n_walks, 2*walk_len).
    Returns (visited (n, S), counts (n, S)) int64, S = n_walks*walk_len."""
    n, n_walks, two_l = uniforms.shape
    walk_len = two_l // 2
    D2 = cum.shape[1]
    r32 = np.float32(restart)
    S = n_walks * walk_len
    visited = np.empty((n, S), np.int64)
    counts = np.zeros((n, S), np.int64)
    for si, s in enumerate(np.asarray(starts, np.int64)):
        trace = []
        for w in range(n_walks):
            pos = int(s)
            for t in range(walk_len):
                u_step = uniforms[si, w, 2 * t]
                u_rst = uniforms[si, w, 2 * t + 1]
                row_c, row_n = cum[pos], nbrs[pos]
                col = 0
                while col < D2 and row_c[col] < u_step:
                    col += 1
                last, prev = 0, np.float32(0.0)
                for j in range(D2):
                    if row_c[j] > prev:
                        last = j
                    prev = row_c[j]
                col = min(col, last)
                nxt = int(row_n[col])
                if nxt < 0 or row_c[-1] <= 0:      # dangling -> stay
                    nxt = pos
                if u_rst < r32:                    # teleport home
                    nxt = int(s)
                pos = nxt
                trace.append(pos)
        visited[si] = trace
        first = {}
        for j, v in enumerate(trace):
            first.setdefault(v, j)
        for v, j in first.items():
            counts[si, j] = trace.count(v)
    return visited, counts
