"""Public op: fused PPR walk + visit counting with kernel/oracle dispatch."""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.kernels.ppr_walk.ppr_walk import ppr_walk as ppr_walk_kernel
from repro.kernels.ppr_walk.ref import ppr_walk_ref


def ppr_walk(nbrs, cum, starts, uniforms, *, restart: float,
             use_kernel: bool = True) -> Tuple[np.ndarray, np.ndarray]:
    """Fused construction walk: Monte-Carlo PPR steps + per-start visit
    counts in one pass.

    ``nbrs``/``cum`` (N, D2) padded adjacency (unified id space),
    ``starts`` (n,) start node ids, ``uniforms`` (n, n_walks,
    2*walk_len) f32 transition/restart draws (the shared cross-backend
    stream from ``core.ppr.walk_uniforms``).  Returns (visited, counts):
    (n, n_walks*walk_len) arrays; counts holds each node's multiplicity
    at its first trace occurrence, 0 elsewhere.
    """
    if use_kernel:
        return ppr_walk_kernel(nbrs, cum, starts, uniforms,
                               restart=restart)
    return ppr_walk_ref(np.asarray(nbrs), np.asarray(cum),
                        np.asarray(starts), np.asarray(uniforms),
                        restart=restart)
