"""Pure-jnp oracle: fused margin + InfoNCE contrastive losses (Eq. 5-6)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def contrastive_ref(src: jnp.ndarray, dst: jnp.ndarray, negs: jnp.ndarray,
                    *, margin: float = 0.1, tau: float = 0.06
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """src/dst (B, d) l2-normalized, negs (B, N, d) l2-normalized.

    Returns (margin_loss (B,), infonce_loss (B,)).
    """
    s_pos = jnp.sum(src * dst, axis=-1).astype(jnp.float32)
    s_neg = jnp.einsum("bd,bnd->bn", src, negs).astype(jnp.float32)
    marg = jnp.sum(jax.nn.relu(s_neg - s_pos[:, None] + margin), axis=-1)
    logits = jnp.concatenate([s_pos[:, None], s_neg], axis=1) / tau
    infonce = -jax.nn.log_softmax(logits, axis=-1)[:, 0]
    return marg, infonce
