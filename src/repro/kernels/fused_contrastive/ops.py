"""Public fused-contrastive op with kernel/reference dispatch."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels.fused_contrastive.fused_contrastive import (
    fused_contrastive)
from repro.kernels.fused_contrastive.ref import contrastive_ref


def contrastive(src, dst, negs, *, margin: float = 0.1, tau: float = 0.06,
                use_kernel: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if use_kernel:
        return fused_contrastive(src, dst, negs, margin=margin, tau=tau)
    return contrastive_ref(src, dst, negs, margin=margin, tau=tau)
