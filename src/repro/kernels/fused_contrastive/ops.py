"""Public fused-contrastive op with kernel/reference dispatch.

Both paths are differentiable: the reference is plain jnp (autodiff),
the kernel path routes through ``fused_contrastive_diff``'s custom VJP
(fused backward tile), so callers can flip ``use_kernel`` under
``jax.value_and_grad`` without changing anything else.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels.fused_contrastive.fused_contrastive import (
    fused_contrastive, fused_contrastive_diff)
from repro.kernels.fused_contrastive.ref import contrastive_ref


def contrastive(src, dst, negs, *, margin: float = 0.1, tau: float = 0.06,
                use_kernel: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if use_kernel:
        return fused_contrastive_diff(float(margin), float(tau), src, dst,
                                      negs)
    return contrastive_ref(src, dst, negs, margin=margin, tau=tau)
