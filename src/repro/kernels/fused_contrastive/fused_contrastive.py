"""Pallas TPU kernel: fused contrastive losses (margin + InfoNCE).

Training hot loop: every positive edge scores against ~100 negatives
(paper §4.3) at batch 32,768 — a (B, N) similarity matrix.  Unfused, XLA
materializes the logits in HBM twice (margin path + log-softmax path);
fused, the (Bt, N) tile lives only in VMEM and both reductions happen in
the same pass right after the MXU batched dot.

grid over batch tiles; per tile: sims via dot_general with a batched
contraction, then margin sum + numerically-stable logsumexp.

The op is differentiable: ``fused_contrastive_diff`` carries a
``jax.custom_vjp`` whose forward additionally emits the per-row positive
similarity and logsumexp (cheap (B, 1) columns) so the backward kernel
only recomputes the (Bt, N) similarity tile — both loss gradients
(margin indicator + softmax) are formed in the same VMEM pass and
contracted back onto src/dst/negs without the logits ever hitting HBM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, should_interpret


def _fwd_kernel(src_ref, dst_ref, neg_ref, marg_ref, info_ref, pos_ref,
                lse_ref, *, margin: float, tau: float):
    src = src_ref[...].astype(jnp.float32)          # (Bt, d)
    dst = dst_ref[...].astype(jnp.float32)          # (Bt, d)
    negs = neg_ref[...].astype(jnp.float32)         # (Bt, N, d)
    s_pos = jnp.sum(src * dst, axis=-1)             # (Bt,)
    # batched (1, d) x (N, d)^T via dot_general with batch dims
    s_neg = jax.lax.dot_general(
        src, negs, (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (Bt, N)
    marg_ref[...] = jnp.sum(
        jnp.maximum(s_neg - s_pos[:, None] + margin, 0.0), axis=-1,
        keepdims=True)
    # stable log-softmax over [pos, negs] picking the pos slot
    m = jnp.maximum(jnp.max(s_neg, axis=-1), s_pos) / tau
    lse = m + jnp.log(jnp.sum(jnp.exp(s_neg / tau - m[:, None]), axis=-1)
                      + jnp.exp(s_pos / tau - m))
    info_ref[...] = (lse - s_pos / tau)[:, None]
    pos_ref[...] = s_pos[:, None]
    lse_ref[...] = lse[:, None]


def _bwd_kernel(src_ref, dst_ref, neg_ref, gm_ref, gi_ref, pos_ref, lse_ref,
                dsrc_ref, ddst_ref, dneg_ref, *, margin: float, tau: float):
    """Fused backward tile: recompute s_neg, form both loss gradients.

    marg = sum_n relu(s_neg - s_pos + margin):
        d/ds_neg[n] = 1{active_n},   d/ds_pos = -sum_n 1{active_n}
    info = lse - s_pos / tau with softmax p = exp(s/tau - lse):
        d/ds_neg[n] = p_n / tau,     d/ds_pos = (p_pos - 1) / tau
    """
    src = src_ref[...].astype(jnp.float32)          # (Bt, d)
    dst = dst_ref[...].astype(jnp.float32)          # (Bt, d)
    negs = neg_ref[...].astype(jnp.float32)         # (Bt, N, d)
    gm = gm_ref[...].astype(jnp.float32)            # (Bt, 1)
    gi = gi_ref[...].astype(jnp.float32)            # (Bt, 1)
    s_pos = pos_ref[...].astype(jnp.float32)        # (Bt, 1)
    lse = lse_ref[...].astype(jnp.float32)          # (Bt, 1)
    s_neg = jax.lax.dot_general(
        src, negs, (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (Bt, N)
    active = (s_neg - s_pos + margin > 0.0).astype(jnp.float32)
    p_neg = jnp.exp(s_neg / tau - lse)
    a = gm * active + gi * (p_neg / tau)             # (Bt, N) dL/ds_neg
    p_pos = jnp.exp(s_pos / tau - lse)
    c = -gm * jnp.sum(active, axis=-1, keepdims=True) \
        + gi * (p_pos - 1.0) / tau                   # (Bt, 1) dL/ds_pos
    dsrc_ref[...] = c * dst + jax.lax.dot_general(
        a, negs, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (Bt, d)
    ddst_ref[...] = c * src
    dneg_ref[...] = a[:, :, None] * src[:, None, :]  # (Bt, N, d)


@functools.partial(jax.jit, static_argnames=("margin", "tau", "block_b",
                                             "interpret"))
def _run_fwd(src, dst, negs, *, margin, tau, block_b, interpret):
    B, d = src.shape
    N = negs.shape[1]
    grid = (cdiv(B, block_b),)
    kern = functools.partial(_fwd_kernel, margin=margin, tau=tau)
    col = pl.BlockSpec((block_b, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        kern, grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, N, d), lambda i: (i, 0, 0)),
        ],
        out_specs=(col, col, col, col),
        out_shape=tuple(jax.ShapeDtypeStruct((B, 1), jnp.float32)
                        for _ in range(4)),
        interpret=interpret)(src, dst, negs)
    return out


@functools.partial(jax.jit, static_argnames=("margin", "tau", "block_b",
                                             "interpret"))
def _run_bwd(src, dst, negs, gm, gi, s_pos, lse, *, margin, tau, block_b,
             interpret):
    B, d = src.shape
    N = negs.shape[1]
    grid = (cdiv(B, block_b),)
    kern = functools.partial(_bwd_kernel, margin=margin, tau=tau)
    row = pl.BlockSpec((block_b, d), lambda i: (i, 0))
    col = pl.BlockSpec((block_b, 1), lambda i: (i, 0))
    neg = pl.BlockSpec((block_b, N, d), lambda i: (i, 0, 0))
    out = pl.pallas_call(
        kern, grid=grid,
        in_specs=[row, row, neg, col, col, col, col],
        out_specs=(row, row, neg),
        out_shape=(jax.ShapeDtypeStruct((B, d), jnp.float32),
                   jax.ShapeDtypeStruct((B, d), jnp.float32),
                   jax.ShapeDtypeStruct((B, N, d), jnp.float32)),
        interpret=interpret)(src, dst, negs, gm, gi, s_pos, lse)
    return out


def _pad_rows(x, pad):
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))


# 32-row tiles: the (Bt, N, d) negative block is the VMEM driver, and at
# production dims (N=100, d=256) the backward pass double-buffers it both
# in and out — 128-row tiles blow the ~16 MiB budget (vmem-budget rule).


def _padded_fwd(src, dst, negs, margin, tau, interpret, block_b=32):
    if interpret is None:
        interpret = should_interpret()
    B = src.shape[0]
    bb = min(block_b, B)
    pad = (-B) % bb
    if pad:
        src, dst, negs = (_pad_rows(a, pad) for a in (src, dst, negs))
    marg, info, s_pos, lse = _run_fwd(src, dst, negs, margin=margin,
                                      tau=tau, block_b=bb,
                                      interpret=bool(interpret))
    return marg[:B, 0], info[:B, 0], s_pos[:B, 0], lse[:B, 0]


def fused_contrastive(src, dst, negs, *, margin: float = 0.1,
                      tau: float = 0.06, block_b: int = 32,
                      interpret=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Forward-only fused losses (no VJP); see ``fused_contrastive_diff``
    for the differentiable op used on the training path."""
    marg, info, _, _ = _padded_fwd(src, dst, negs, margin, tau, interpret,
                                   block_b=block_b)
    return marg, info


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def fused_contrastive_diff(margin: float, tau: float, src, dst, negs
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Differentiable fused (margin, infonce) losses, each (B,).

    margin/tau lead (nondiff static args); src/dst (B, d) and
    negs (B, N, d) are the differentiable operands.
    """
    marg, info, _, _ = _padded_fwd(src, dst, negs, margin, tau, None)
    return marg, info


def _diff_fwd(margin, tau, src, dst, negs):
    marg, info, s_pos, lse = _padded_fwd(src, dst, negs, margin, tau, None)
    return (marg, info), (src, dst, negs, s_pos, lse)


def _diff_bwd(margin, tau, res, g):
    src, dst, negs, s_pos, lse = res
    gm, gi = g
    interpret = should_interpret()
    B = src.shape[0]
    bb = min(32, B)
    pad = (-B) % bb
    cols = tuple(a[:, None].astype(jnp.float32)
                 for a in (gm, gi, s_pos, lse))
    if pad:
        src_p, dst_p, negs_p = (_pad_rows(a, pad)
                                for a in (src, dst, negs))
        cols = tuple(_pad_rows(a, pad) for a in cols)
    else:
        src_p, dst_p, negs_p = src, dst, negs
    d_src, d_dst, d_negs = _run_bwd(src_p, dst_p, negs_p, *cols,
                                    margin=margin, tau=tau, block_b=bb,
                                    interpret=bool(interpret))
    return (d_src[:B].astype(src.dtype), d_dst[:B].astype(dst.dtype),
            d_negs[:B].astype(negs.dtype))


fused_contrastive_diff.defvjp(_diff_fwd, _diff_bwd)
