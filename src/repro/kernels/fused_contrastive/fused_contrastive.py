"""Pallas TPU kernel: fused contrastive losses (margin + InfoNCE).

Training hot loop: every positive edge scores against ~100 negatives
(paper §4.3) at batch 32,768 — a (B, N) similarity matrix.  Unfused, XLA
materializes the logits in HBM twice (margin path + log-softmax path);
fused, the (Bt, N) tile lives only in VMEM and both reductions happen in
the same pass right after the MXU batched dot.

grid over batch tiles; per tile: sims via dot_general with a batched
contraction, then margin sum + numerically-stable logsumexp.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, should_interpret


def _kernel(src_ref, dst_ref, neg_ref, marg_ref, info_ref, *,
            margin: float, tau: float):
    src = src_ref[...].astype(jnp.float32)          # (Bt, d)
    dst = dst_ref[...].astype(jnp.float32)          # (Bt, d)
    negs = neg_ref[...].astype(jnp.float32)         # (Bt, N, d)
    s_pos = jnp.sum(src * dst, axis=-1)             # (Bt,)
    # batched (1, d) x (N, d)^T via dot_general with batch dims
    s_neg = jax.lax.dot_general(
        src, negs, (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (Bt, N)
    marg_ref[...] = jnp.sum(
        jnp.maximum(s_neg - s_pos[:, None] + margin, 0.0), axis=-1,
        keepdims=True)
    # stable log-softmax over [pos, negs] picking the pos slot
    m = jnp.maximum(jnp.max(s_neg, axis=-1), s_pos) / tau
    lse = m + jnp.log(jnp.sum(jnp.exp(s_neg / tau - m[:, None]), axis=-1)
                      + jnp.exp(s_pos / tau - m))
    info_ref[...] = (lse - s_pos / tau)[:, None]


@functools.partial(jax.jit, static_argnames=("margin", "tau", "block_b",
                                             "interpret"))
def _run(src, dst, negs, *, margin, tau, block_b, interpret):
    B, d = src.shape
    N = negs.shape[1]
    grid = (cdiv(B, block_b),)
    kern = functools.partial(_kernel, margin=margin, tau=tau)
    out = pl.pallas_call(
        kern, grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, N, d), lambda i: (i, 0, 0)),
        ],
        out_specs=(pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
                   pl.BlockSpec((block_b, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((B, 1), jnp.float32),
                   jax.ShapeDtypeStruct((B, 1), jnp.float32)),
        interpret=interpret)(src, dst, negs)
    return out


def fused_contrastive(src, dst, negs, *, margin: float = 0.1,
                      tau: float = 0.06, block_b: int = 128,
                      interpret=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if interpret is None:
        interpret = should_interpret()
    B = src.shape[0]
    bb = min(block_b, B)
    pad = (-B) % bb
    if pad:
        src = jnp.pad(src, ((0, pad), (0, 0)))
        dst = jnp.pad(dst, ((0, pad), (0, 0)))
        negs = jnp.pad(negs, ((0, pad), (0, 0), (0, 0)))
    marg, info = _run(src, dst, negs, margin=margin, tau=tau, block_b=bb,
                      interpret=bool(interpret))
    return marg[:B, 0], info[:B, 0]
