"""Shared kernel utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def should_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode off-TPU (CPU container);
    on real TPU they compile to Mosaic."""
    return jax.default_backend() != "tpu"


def pad_to(x: jnp.ndarray, axis: int, multiple: int, value=0.0):
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value), n


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b
