"""Per-request Python oracle for the fused queue-gather + I2I-union pass.

Deliberately written as the obvious sequential algorithm (the seed
implementation's deque scan + round-robin union) so it doubles as the
readable spec the Pallas kernel and the vectorized numpy engine are both
tested against:

  1. U2U2I seeds: scan the request's cluster ring buffer newest-first,
     drop entries older than ``cutoff``, dedup, keep the first
     ``n_recent``.
  2. U2I2I union: round-robin over ``i2i[seed]`` lists by rank
     (rank 0 of every seed, then rank 1, ...), skip ``-1`` pads and any
     item already a seed, dedup, keep the first ``k``.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def queue_gather_ref(items: np.ndarray, times: np.ndarray,
                     cursor: np.ndarray, clusters: np.ndarray,
                     i2i: np.ndarray, *, cutoff: float, n_recent: int,
                     k: int) -> Tuple[np.ndarray, np.ndarray]:
    """items/times (C, Q), cursor (C,) total writes, clusters (B,),
    i2i (N, K).  Returns (seeds (B, n_recent), union (B, k)), both
    ``-1``-padded int64."""
    Q = items.shape[1]
    K = i2i.shape[1]
    B = len(clusters)
    seeds = np.full((B, n_recent), -1, np.int64)
    union = np.full((B, k), -1, np.int64)
    for b, c in enumerate(np.asarray(clusters, np.int64)):
        total = int(cursor[c])
        row = []
        seen = set()
        for age in range(min(total, Q)):               # newest first
            pos = (total - 1 - age) % Q
            it, ts = int(items[c, pos]), float(times[c, pos])
            if ts < cutoff or it < 0 or it in seen:
                continue
            seen.add(it)
            row.append(it)
            if len(row) >= n_recent:
                break
        seeds[b, :len(row)] = row

        out = []
        seen = set(row)
        for rank in range(K):                          # round-robin
            for it in row:
                if it >= len(i2i):     # not yet covered by the I2I refresh
                    continue
                cand = int(i2i[it, rank])
                if cand >= 0 and cand not in seen:
                    seen.add(cand)
                    out.append(cand)
                    if len(out) >= k:
                        break
            if len(out) >= k:
                break
        union[b, :len(out)] = out
    return seeds, union
