"""Pallas TPU kernel: fused cluster-queue gather + U2I2I round-robin union.

The batched serving path answers each request by (1) reading the user's
cluster ring buffer newest-first with a recency filter and (2) unioning
the I2I lists of the surviving seed items.  Done naively that is two
HBM round-trips (queue rows out, seed list back in to drive the I2I
gather) plus host-side dedup.  The fusion keeps the whole request in
VMEM:

  * the request's queue row (Q items + timestamps) is DMA'd via scalar
    prefetch — the cluster id array lands in SMEM and the BlockSpec
    index_map picks row ``clusters[b]``, exactly the embedding_bag
    gather structure;
  * recency masking, newest-first ranking, and dedup are mask/compare
    ops on the (1, Q) row — selection is expressed as one-hot matmuls so
    ranking runs on the MXU instead of a serial scan;
  * the I2I table stays VMEM-resident across the whole batch (serving
    keeps the hot head of the table on-chip; production 64k rows x 32
    x int32 = 8 MiB under the ~16 MiB budget) and the seed gather is a
    one-hot (R, N) @ (N, K) matmul — item ids must stay below 2^24 for
    the f32 MXU pass to be exact;
  * the round-robin union (rank-major priority, seeds masked, first-k
    dedup) reuses the same priority-rank-scatter pattern on the (1, R*K)
    candidate row, and both outputs leave the kernel in one pass.

grid = (B,): one program per request; batch tiles of queue rows would
buy nothing because each row is already a single DMA.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import should_interpret


def _rank_select(vals, prio, big, n_out, out_len):
    """Shared priority machinery: given a (1, M) row of values with
    (1, M) priorities (``big`` = masked), return the ``n_out`` smallest-
    priority values as (1, n_out), -1-padded.  Rank = count of strictly
    smaller priorities (priorities are unique below ``big``); the
    scatter to output position is a one-hot reduction."""
    rank = jnp.sum((prio < prio.T).astype(jnp.int32), axis=1,
                   keepdims=True).T                       # (1, M)
    live = (prio < big) & (rank < n_out)
    sel = (jax.lax.broadcasted_iota(jnp.int32, (out_len, vals.shape[1]), 0)
           == rank) & live                                # (out_len, M)
    picked = jnp.sum(jnp.where(sel, vals, 0), axis=1, keepdims=True)
    has = jnp.any(sel, axis=1, keepdims=True)
    return jnp.where(has, picked, -1).T                   # (1, out_len)


def _dedup_prio(vals, prio, big):
    """Mask (set to ``big``) the priority of every entry whose value
    already appears with a strictly smaller priority."""
    eq = vals.T == vals                                   # (M, M)
    dup = jnp.any(eq & (prio < prio.T), axis=1, keepdims=True)
    return jnp.where(dup.T, big, prio)


def _kernel(clusters_ref, state_ref, cutoff_ref, items_ref, times_ref,
            i2i_ref, seeds_out, union_out, *, Q: int, R: int, k: int):
    total = state_ref[0, 0]
    fill = jnp.minimum(total, Q)
    cutoff = cutoff_ref[0, 0]
    items = items_ref[...]                                # (1, Q) int32
    ts = times_ref[...]                                   # (1, Q) f32

    # --- U2U2I seeds: newest-first recency-filtered dedup ------------------
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, Q), 1)
    age = jnp.mod(total - 1 - slot, Q)                    # newest slot = 0
    valid = (age < fill) & (ts >= cutoff) & (items >= 0)
    big = jnp.int32(Q + 1)
    prio = _dedup_prio(items, jnp.where(valid, age, big), big)
    seeds_row = _rank_select(items, prio, big, R, R)      # (1, R)
    seeds_out[...] = seeds_row

    # --- I2I gather: one-hot MXU matmul against the resident table ---------
    i2i = i2i_ref[...]                                    # (N, K) int32
    N, K = i2i.shape
    seeds = seeds_row.T                                   # (R, 1)
    seed_has = seeds >= 0
    # seeds past the table end gather nothing (new items can reach the
    # queues before the next offline I2I refresh covers them)
    gatherable = seed_has & (seeds < N)
    col = jax.lax.broadcasted_iota(jnp.int32, (R, N), 1)
    onehot = (col == jnp.where(gatherable, seeds, -1)).astype(jnp.float32)
    cand = jax.lax.dot_general(
        onehot, i2i.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(jnp.int32)
    cand = jnp.where(gatherable, cand, -1)                # (R, K)

    # --- round-robin union: rank-major priority, seeds masked, first k -----
    M = R * K
    rr_prio = (jax.lax.broadcasted_iota(jnp.int32, (R, K), 1) * R
               + jax.lax.broadcasted_iota(jnp.int32, (R, K), 0))
    flat = cand.reshape(1, M)
    seen = jnp.any((flat.T == seeds.T) & seed_has.T, axis=1,
                   keepdims=True)                         # (M, 1)
    bigm = jnp.int32(M + 1)
    cprio = jnp.where((flat >= 0) & ~seen.T, rr_prio.reshape(1, M), bigm)
    cprio = _dedup_prio(flat, cprio, bigm)
    union_out[...] = _rank_select(flat, cprio, bigm, k, k)


@functools.partial(jax.jit,
                   static_argnames=("n_recent", "k", "interpret"))
def _run(items, times, state, clusters, i2i, cutoff, *, n_recent: int,
         k: int, interpret: bool):
    C, Q = items.shape
    N, K = i2i.shape
    B = clusters.shape[0]
    kernel = functools.partial(_kernel, Q=Q, R=n_recent, k=k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, cl: (cl[b], 0)),   # cursor state
            pl.BlockSpec((1, 1), lambda b, cl: (0, 0)),       # cutoff
            pl.BlockSpec((1, Q), lambda b, cl: (cl[b], 0)),   # queue items
            pl.BlockSpec((1, Q), lambda b, cl: (cl[b], 0)),   # queue times
            pl.BlockSpec((N, K), lambda b, cl: (0, 0)),       # i2i table
        ],
        out_specs=(pl.BlockSpec((1, n_recent), lambda b, cl: (b, 0)),
                   pl.BlockSpec((1, k), lambda b, cl: (b, 0))),
    )
    out_shapes = (jax.ShapeDtypeStruct((B, n_recent), jnp.int32),
                  jax.ShapeDtypeStruct((B, k), jnp.int32))
    return pl.pallas_call(kernel, grid_spec=grid_spec,
                          out_shape=out_shapes,
                          interpret=interpret)(
        clusters, state, cutoff, items, times, i2i)


def queue_gather(items, times, cursor, clusters, i2i, *, cutoff: float,
                 n_recent: int, k: int, interpret: bool = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused serving gather.  items/times (C, Q) ring buffers, cursor
    (C,) total writes, clusters (B,) request cluster ids, i2i (N, K).

    Returns (seeds (B, n_recent) int32, union (B, k) int32), -1-padded.
    """
    if interpret is None:
        interpret = should_interpret()
    items = jnp.asarray(items, jnp.int32)
    times = jnp.asarray(times, jnp.float32)
    state = jnp.asarray(cursor, jnp.int32).reshape(-1, 1)
    clusters = jnp.asarray(clusters, jnp.int32)
    i2i = jnp.asarray(i2i, jnp.int32)
    cutoff_arr = jnp.full((1, 1), cutoff, jnp.float32)
    return _run(items, times, state, clusters, i2i, cutoff_arr,
                n_recent=int(n_recent), k=int(k),
                interpret=bool(interpret))
