"""Public op: fused queue-gather + I2I-union with kernel/oracle dispatch."""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.kernels.queue_gather.queue_gather import (
    queue_gather as queue_gather_kernel)
from repro.kernels.queue_gather.ref import queue_gather_ref


def queue_gather(items, times, cursor, clusters, i2i, *, cutoff: float,
                 n_recent: int, k: int, use_kernel: bool = True
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Batched serving gather: U2U2I seeds + U2I2I round-robin union.

    items/times (C, Q) ring buffers, cursor (C,) total writes, clusters
    (B,) per-request cluster ids, i2i (N, K) offline KNN table.  Returns
    (seeds (B, n_recent), union (B, k)), both ``-1``-padded.
    """
    if use_kernel:
        return queue_gather_kernel(items, times, cursor, clusters, i2i,
                                   cutoff=cutoff, n_recent=n_recent, k=k)
    return queue_gather_ref(np.asarray(items), np.asarray(times),
                            np.asarray(cursor), np.asarray(clusters),
                            np.asarray(i2i), cutoff=cutoff,
                            n_recent=n_recent, k=k)
