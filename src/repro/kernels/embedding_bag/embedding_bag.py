"""Pallas TPU kernel: EmbeddingBag via scalar-prefetch-driven gather.

The recsys hot path: B bags x L ids each gather rows of a huge HBM
table (10^6-10^9 rows) and reduce.  A naive jnp.take materializes a
(B, L, D) tensor in HBM; on TPU the right structure is to *stream* the
needed rows HBM->VMEM, which Pallas expresses with scalar prefetch: the
id array is prefetched to SMEM, and the table's BlockSpec index_map
reads it to choose which (1, D) row block the DMA engine fetches next —
the gather never materializes and the row lands directly in VMEM where
it is weighted and accumulated into the output block.

grid = (B, L): step (b, l) fetches table row ids[b, l] and accumulates
w[b, l] * row into out[b].  Padding ids (< 0) are clamped to row 0 and
handled with weight 0 by the wrapper.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import should_interpret


def _kernel(ids_ref, w_ref, row_ref, out_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[0, 0]
    out_ref[...] += row_ref[...].astype(out_ref.dtype) * w


@functools.partial(jax.jit, static_argnames=("interpret",))
def _run(table, ids, weights, *, interpret: bool):
    B, L = ids.shape
    V, D = table.shape
    flat_ids = ids.reshape(-1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, L),
        in_specs=[
            # per-step effective weight (1,1) block
            pl.BlockSpec((1, 1), lambda b, l, ids: (b, l)),
            # the gathered table row: index_map consults prefetched ids
            pl.BlockSpec((1, D), lambda b, l, ids: (ids[b * L + l], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, l, ids: (b, 0)),
    )
    return pl.pallas_call(
        _kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(flat_ids, weights, table)


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  weights: Optional[jnp.ndarray] = None,
                  mode: str = "sum", *, interpret: Optional[bool] = None
                  ) -> jnp.ndarray:
    """Kernel-backed EmbeddingBag.  table (V, D), ids (B, L) -> (B, D)."""
    if interpret is None:
        interpret = should_interpret()
    mask = ids >= 0
    safe = jnp.where(mask, ids, 0).astype(jnp.int32)
    w = mask.astype(jnp.float32)
    if weights is not None:
        w = w * weights.astype(jnp.float32)
    out = _run(table, safe, w, interpret=bool(interpret))
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
        out = out / cnt
    return out.astype(table.dtype)
