"""Public EmbeddingBag op with kernel/reference dispatch + custom VJP.

The backward of an embedding bag is a scatter-add into the table
(jax.ops.segment_sum) — defined explicitly so training works with either
forward implementation (the Pallas kernel has no autodiff rule).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import (
    embedding_bag as embedding_bag_kernel)
from repro.kernels.embedding_bag.ref import embedding_bag_ref


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def embedding_bag(table, ids, weights=None, mode: str = "sum",
                  use_kernel: bool = False):
    """table (V, D), ids (B, L) int (-1 pad), weights (B, L) -> (B, D)."""
    if use_kernel:
        return embedding_bag_kernel(table, ids, weights, mode)
    return embedding_bag_ref(table, ids, weights, mode)


def _fwd(table, ids, weights, mode, use_kernel):
    out = embedding_bag(table, ids, weights, mode, use_kernel)
    return out, (table, ids, weights)


def _bwd(mode, use_kernel, res, g):
    table, ids, weights = res
    V = table.shape[0]
    B, L = ids.shape
    mask = ids >= 0
    w = mask.astype(jnp.float32)
    if weights is not None:
        w = w * weights.astype(jnp.float32)
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
        w_eff = w / cnt
    else:
        w_eff = w
    g32 = g.astype(jnp.float32)
    # d table: scatter-add of per-(b,l) weighted upstream grads
    contrib = (g32[:, None, :] * w_eff[:, :, None]).reshape(B * L, -1)
    flat = jnp.where(mask, ids, V).reshape(-1)       # pads -> dropped row V
    dtab = jax.ops.segment_sum(contrib, flat, num_segments=V + 1)[:-1]
    dw = None
    if weights is not None:
        rows = jnp.take(table, jnp.where(mask, ids, 0), axis=0
                        ).astype(jnp.float32)        # (B, L, D)
        if mode == "mean":
            # d/dw of (sum w_l r_l / sum w_l): (r_l - out) / cnt
            out = jnp.sum(rows * w_eff[..., None], axis=1)
            dw = jnp.einsum("bd,bld->bl", g32,
                            (rows - out[:, None, :]) / cnt[..., None])
        else:
            dw = jnp.einsum("bd,bld->bl", g32, rows)
        dw = (dw * mask).astype(weights.dtype)
    return dtab.astype(table.dtype), None, dw


embedding_bag.defvjp(_fwd, _bwd)
