"""Pure-jnp oracle for EmbeddingBag (gather + weighted segment reduce).

JAX has no native EmbeddingBag; the reference is the canonical
jnp.take + weighted-sum formulation (ids < 0 are padding).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def embedding_bag_ref(table: jnp.ndarray, ids: jnp.ndarray,
                      weights: Optional[jnp.ndarray] = None,
                      mode: str = "sum") -> jnp.ndarray:
    """table (V, D), ids (B, L) int (-1 = pad), weights (B, L) optional.

    Returns (B, D): per-bag weighted sum (or mean over valid entries).
    """
    mask = (ids >= 0)
    safe = jnp.where(mask, ids, 0)
    rows = jnp.take(table, safe, axis=0)              # (B, L, D)
    w = mask.astype(table.dtype)
    if weights is not None:
        w = w * weights.astype(table.dtype)
    out = jnp.sum(rows * w[..., None], axis=1)
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
        out = out / cnt
    return out
