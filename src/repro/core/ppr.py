"""Personalized-PageRank neighbor pre-computation (paper §4.2).

Monte-Carlo approximation: R walks of length L with restart prob 0.15
from every backbone node, over the *subsampled* heterogeneous graph
(out-degree is bounded by K_CAP per edge type, so a padded adjacency is
the natural representation).  Edge-type transition mass is balanced so
no type dominates PPR output.

Three backends with bit-identical semantics, selected via ``backend=``:

  * ``numpy``   chunked, vectorized; the offline-pipeline reference
  * ``jax``     jitted ``lax.scan`` with a binary-search inverse-CDF
                step (log2(D) scalar gathers instead of full-row
                gathers — the accelerated construction path)
  * ``pallas``  ``kernels/ppr_walk``: the walk fused with per-start
                visit-count accumulation in one kernel pass

All backends consume the *same* host-generated uniform stream (keyed by
start node id in fixed-size blocks, see ``walk_uniforms``), so their
visit traces are exactly equal and — crucially — an incremental refresh
that re-walks only the affected nodes reproduces the exact trace a full
rebuild would have produced (``refresh_ppr_neighbors``).

Group-2 handling (nodes without same-type neighbors) lives in
``group2_neighbors``: KNN over previous-run Group-1 embeddings + top
-weight U-I edges, per the paper.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.graph_builder import HeteroGraph, padded_adjacency


@dataclasses.dataclass
class PaddedHeteroAdj:
    """Per-node fixed-width neighbor tables in a unified id space.

    Global ids: users are [0, n_users), items are [n_users, n_users+n_items).
    ``nbrs`` (n, D) int64 (-1 pad), ``cum`` (n, D) float32 cumulative
    transition probabilities (type-balanced), row-normalized.
    """
    nbrs: np.ndarray
    cum: np.ndarray
    n_users: int
    n_items: int

    @property
    def n_nodes(self) -> int:
        return self.n_users + self.n_items


def build_padded_hetero_adj(g: HeteroGraph, max_deg_per_type: int = 32
                            ) -> PaddedHeteroAdj:
    nu, ni = g.n_users, g.n_items
    D = max_deg_per_type
    # per-type padded adjacencies
    uu_n, uu_w = padded_adjacency(g.uu, nu, D)
    ii_n, ii_w = padded_adjacency(g.ii, ni, D)
    ui_n, ui_w = padded_adjacency(g.ui, nu, D)
    # reverse U-I (item -> engaging users), built from the same edges
    from repro.core.graph_builder import EdgeSet
    iu = EdgeSet(g.ui.dst, g.ui.src, g.ui.weight)
    iu_n, iu_w = padded_adjacency(iu, ni, D)

    n = nu + ni
    nbrs = np.full((n, 2 * D), -1, np.int64)
    probs = np.zeros((n, 2 * D), np.float64)

    def _fill(rows_off, block, nb, wt, id_off):
        nbrs[rows_off:rows_off + len(nb), block * D:(block + 1) * D] = \
            np.where(nb >= 0, nb + id_off, -1)
        probs[rows_off:rows_off + len(nb), block * D:(block + 1) * D] = wt

    # users: block0 = U-U (user ids), block1 = U-I (item ids)
    _fill(0, 0, uu_n, uu_w, 0)
    _fill(0, 1, ui_n, ui_w, nu)
    # items: block0 = I-I (item ids), block1 = I-U (user ids)
    _fill(nu, 0, ii_n, ii_w, nu)
    _fill(nu, 1, iu_n, iu_w, 0)

    # type-balanced normalization: each present type gets equal mass
    for blk in (0, 1):
        sl = slice(blk * D, (blk + 1) * D)
        tot = probs[:, sl].sum(axis=1, keepdims=True)
        probs[:, sl] = np.where(tot > 0, probs[:, sl] / np.maximum(tot, 1e-12),
                                0.0)
    ntypes = ((probs[:, :D].sum(1) > 0).astype(np.float64)
              + (probs[:, D:].sum(1) > 0).astype(np.float64))
    ntypes = np.maximum(ntypes, 1.0)
    probs /= ntypes[:, None]
    # rows with no out-edges: self-loop semantics handled at walk time
    cum = np.cumsum(probs, axis=1).astype(np.float32)
    return PaddedHeteroAdj(nbrs, cum, nu, ni)


# ---------------------------------------------------------------------------
# shared uniform stream (all backends + incremental refresh)
# ---------------------------------------------------------------------------

U_BLOCK = 4096       # starts per RNG block — the refresh regeneration unit


def walk_uniforms(seed: int, ids: np.ndarray, n_walks: int, walk_len: int,
                  n_users: int = 0) -> np.ndarray:
    """f32 uniforms for the given start node ids: (len(ids), n_walks,
    2*walk_len); column 2t drives step t's transition draw, column 2t+1
    its restart draw.

    The stream is keyed by *node id within its type* — users by user id,
    items by item-local id (global id minus ``n_users``) — in fixed
    ``U_BLOCK``-sized blocks, not by position in ``ids`` or by chunk
    layout.  A refresh that re-walks an arbitrary subset of nodes
    therefore regenerates exactly the draws a full run over ``arange(n)``
    would have consumed for them, and growth of *either* id space leaves
    every pre-existing node's draws unchanged (user growth shifts item
    global ids, but not their item-local stream keys).
    """
    ids = np.asarray(ids, np.int64)
    out = np.empty((len(ids), n_walks, 2 * walk_len), np.float32)
    side = (ids >= n_users).astype(np.int64)       # 0 = user, 1 = item
    local = ids - side * n_users
    blocks = local // U_BLOCK
    for s, b in {(int(s), int(b)) for s, b in zip(side, blocks)}:
        m = (side == s) & (blocks == b)
        rng = np.random.default_rng((seed, s, b))
        blk = rng.random((U_BLOCK, n_walks, 2 * walk_len),
                         dtype=np.float32)
        out[m] = blk[local[m] - b * U_BLOCK]
    return out


def last_valid_cols(cum: np.ndarray) -> np.ndarray:
    """Per row, the last column carrying positive transition mass (0 for
    dangling rows — the dead-row check stops those walkers anyway)."""
    inc = np.empty(cum.shape, bool)
    inc[:, 0] = cum[:, 0] > 0
    inc[:, 1:] = cum[:, 1:] > cum[:, :-1]
    return np.where(inc, np.arange(cum.shape[1])[None, :], 0).max(axis=1)


# ---------------------------------------------------------------------------
# numpy Monte-Carlo walker
# ---------------------------------------------------------------------------

def _step(nbrs: np.ndarray, cum: np.ndarray, last: np.ndarray,
          pos: np.ndarray, u: np.ndarray) -> np.ndarray:
    c = cum[pos]                                   # (m, D2)
    col = (c < u[:, None]).sum(axis=1)
    # f32 rounding can leave cum[-1] slightly below 1.0; an overflowing
    # draw must land on the last *valid* neighbor column, not a trailing
    # -1 pad (which would silently stall the walker at `pos` and bias
    # visit counts toward the start node).
    col = np.minimum(col, last[pos])
    nxt = nbrs[pos, col]
    dead = (nxt < 0) | (c[:, -1] <= 0)             # dangling -> stay
    return np.where(dead, pos, nxt)


def _walk_numpy(adj: PaddedHeteroAdj, starts: np.ndarray, *, n_walks: int,
                walk_len: int, restart: float, seed: int,
                chunk: int) -> np.ndarray:
    last = last_valid_cols(adj.cum)
    r32 = np.float32(restart)
    n_start = len(starts)
    S = n_walks * walk_len
    visited = np.empty((n_start, S), np.int64)
    step_rows = max(1, chunk // n_walks)
    for lo in range(0, n_start, step_rows):
        hi = min(n_start, lo + step_rows)
        home = np.repeat(starts[lo:hi], n_walks)
        u = walk_uniforms(seed, starts[lo:hi], n_walks, walk_len,
                          adj.n_users).reshape(len(home), 2 * walk_len)
        pos = home.copy()
        block = np.empty((len(home), walk_len), np.int64)
        for t in range(walk_len):
            pos = _step(adj.nbrs, adj.cum, last, pos, u[:, 2 * t])
            pos = np.where(u[:, 2 * t + 1] < r32, home, pos)
            block[:, t] = pos
        visited[lo:hi] = block.reshape(hi - lo, S)
    return visited


# ---------------------------------------------------------------------------
# JAX walker (accelerated construction; bit-identical to numpy)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("walk_len",))
def _walk_jax_impl(nbrs2d, cum2d, home, u, restart, *, walk_len: int):
    """Binary-search inverse-CDF walk: log2(D2) scalar gathers per step
    instead of a full (m, D2) row gather — ~8x less memory traffic, and
    the lower bound it finds equals ``sum(cum_row < u)`` exactly."""
    d2 = cum2d.shape[1]
    nbrs_flat = nbrs2d.reshape(-1)
    cum_flat = cum2d.reshape(-1)
    # last positive-mass column per row (pad-stall clamp), fused in-jit
    inc = jnp.concatenate([cum2d[:, :1] > 0, cum2d[:, 1:] > cum2d[:, :-1]],
                          axis=1)
    last = jnp.max(jnp.where(inc, jnp.arange(d2, dtype=jnp.int32)[None, :],
                             0), axis=1)
    m = home.shape[0]
    xs = u.reshape(m, walk_len, 2).transpose(1, 0, 2)

    def body(pos, uu):
        us, ur = uu[:, 0], uu[:, 1]
        base = pos * d2
        # lower bound over the d2-wide row == sum(cum_row < u) exactly;
        # the span starts at the next power of two and every probe is
        # bounds-guarded so non-power-of-two widths (odd
        # max_deg_per_type) search correctly and never read off-row
        p = jnp.zeros_like(pos)
        w = 1 << max(0, (d2 - 1).bit_length())
        while w > 1:
            w //= 2
            cand = p + w
            ok = cand <= d2
            probe = cum_flat[base + jnp.minimum(cand, d2) - 1]
            p = jnp.where(ok & (probe < us), cand, p)
        probe = cum_flat[base + jnp.minimum(p, d2 - 1)]
        p = jnp.where((p < d2) & (probe < us), p + 1, p)
        col = jnp.minimum(p, last[pos])
        nxt = nbrs_flat[base + col]
        dead = (nxt < 0) | (cum_flat[base + d2 - 1] <= 0)
        nxt = jnp.where(dead, pos, nxt)
        nxt = jnp.where(ur < restart, home, nxt)
        return nxt, nxt

    _, trace = jax.lax.scan(body, home, xs)
    return jnp.transpose(trace, (1, 0))            # (m, walk_len)


def ppr_walk_jax(nbrs: np.ndarray, cum: np.ndarray, starts: np.ndarray,
                 uniforms: np.ndarray, *, n_walks: int, walk_len: int,
                 restart: float) -> np.ndarray:
    """Vectorized Monte-Carlo walks; returns (n_starts, n_walks*walk_len)
    int64, bit-identical to the numpy walker on the same uniforms."""
    home = jnp.asarray(np.repeat(np.asarray(starts, np.int32), n_walks))
    trace = _walk_jax_impl(
        jnp.asarray(np.asarray(nbrs).astype(np.int32)),
        jnp.asarray(np.asarray(cum, np.float32)),
        home,
        jnp.asarray(np.asarray(uniforms, np.float32).reshape(
            len(home), 2 * walk_len)),
        jnp.float32(restart), walk_len=walk_len)
    return np.asarray(trace, np.int64).reshape(len(starts),
                                               n_walks * walk_len)


def _walk_jax(adj: PaddedHeteroAdj, starts: np.ndarray, *, n_walks: int,
              walk_len: int, restart: float, seed: int,
              chunk: int) -> np.ndarray:
    """Memory-chunked jax walk: the adjacency converts to device arrays
    once; only the per-chunk walkers + uniforms are materialized."""
    nbrs_d = jnp.asarray(adj.nbrs.astype(np.int32))
    cum_d = jnp.asarray(np.asarray(adj.cum, np.float32))
    r32 = jnp.float32(restart)
    n = len(starts)
    S = n_walks * walk_len
    visited = np.empty((n, S), np.int64)
    step_rows = max(1, chunk // n_walks)
    for lo in range(0, n, step_rows):
        hi = min(n, lo + step_rows)
        ids = starts[lo:hi]
        home = jnp.asarray(np.repeat(ids.astype(np.int32), n_walks))
        u = jnp.asarray(walk_uniforms(seed, ids, n_walks, walk_len,
                                      adj.n_users
                                      ).reshape(len(ids) * n_walks,
                                                2 * walk_len))
        trace = _walk_jax_impl(nbrs_d, cum_d, home, u, r32,
                               walk_len=walk_len)
        visited[lo:hi] = np.asarray(trace, np.int64).reshape(hi - lo, S)
    return visited


def _walk_pallas(adj_nbrs: np.ndarray, adj_cum: np.ndarray,
                 starts: np.ndarray, *, n_walks: int, walk_len: int,
                 restart: float, seed: int, chunk: int,
                 n_users: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Fused walk + per-start visit counting via ``kernels/ppr_walk``.
    Returns (visited, counts): counts holds each node's multiplicity at
    its first occurrence in the row, 0 elsewhere."""
    from repro.kernels.ppr_walk.ops import ppr_walk
    n = len(starts)
    S = n_walks * walk_len
    visited = np.empty((n, S), np.int64)
    counts = np.empty((n, S), np.int64)
    step_rows = max(1, chunk // n_walks)
    for lo in range(0, n, step_rows):
        hi = min(n, lo + step_rows)
        u = walk_uniforms(seed, starts[lo:hi], n_walks, walk_len, n_users)
        v, c = ppr_walk(adj_nbrs, adj_cum, starts[lo:hi], u,
                        restart=restart)
        visited[lo:hi] = np.asarray(v, np.int64)
        counts[lo:hi] = np.asarray(c, np.int64)
    return visited, counts


BACKENDS = ("numpy", "jax", "pallas")


def ppr_visit_counts(adj: PaddedHeteroAdj, starts: np.ndarray, *,
                     n_walks: int = 64, walk_len: int = 5,
                     restart: float = 0.15, seed: int = 0,
                     chunk: int = 1 << 18, backend: str = "numpy"
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (visited, starts): (n_starts, n_walks*walk_len) node ids
    per start.  Memory-chunked over starts; all backends are
    bit-identical (shared uniform stream, see ``walk_uniforms``)."""
    starts = np.asarray(starts, np.int64)
    if backend == "numpy":
        visited = _walk_numpy(adj, starts, n_walks=n_walks,
                              walk_len=walk_len, restart=restart,
                              seed=seed, chunk=chunk)
    elif backend == "jax":
        visited = _walk_jax(adj, starts, n_walks=n_walks,
                            walk_len=walk_len, restart=restart,
                            seed=seed, chunk=chunk)
    elif backend == "pallas":
        visited, _ = _walk_pallas(adj.nbrs, adj.cum, starts,
                                  n_walks=n_walks, walk_len=walk_len,
                                  restart=restart, seed=seed, chunk=chunk,
                                  n_users=adj.n_users)
    else:
        raise ValueError(f"unknown backend {backend!r}; want {BACKENDS}")
    return visited, starts


# ---------------------------------------------------------------------------
# visit counting + top-k (vectorized; shared by all backends)
# ---------------------------------------------------------------------------

def _run_length_counts(srt: np.ndarray) -> np.ndarray:
    """Per-row run-length counts over row-sorted visit lists: the count
    of each run at its first position, 0 elsewhere.  Fully vectorized
    (suffix-min of run-start indices), no per-column Python loop."""
    n, S = srt.shape
    newrun = np.ones_like(srt, bool)
    newrun[:, 1:] = srt[:, 1:] != srt[:, :-1]
    idx = np.arange(S)[None, :]
    # index of this-or-next run start at each position (suffix minimum)
    run_idx = np.where(newrun, idx, S)
    nxt_incl = np.minimum.accumulate(run_idx[:, ::-1], axis=1)[:, ::-1]
    # next run start strictly after j = suffix min over k > j
    nxt = np.concatenate([nxt_incl[:, 1:], np.full((n, 1), S)], axis=1)
    return np.where(newrun, nxt - idx, 0)


def _topk_from_counts(vals: np.ndarray, counts: np.ndarray,
                      starts: np.ndarray, k: int, type_boundary: int,
                      hub_alpha: float, glob: Optional[np.ndarray]
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k selection given per-position visit counts (count at the
    first occurrence of each distinct node, 0 elsewhere).  Ties break by
    node id, so the result is independent of visit order — the fused
    pallas counts (visit order) and the host run-length counts (sorted
    order) select identical neighbors."""
    n, S = vals.shape
    scores = counts.astype(np.float64)
    scores[vals == starts[:, None]] = 0.0          # drop self visits
    if hub_alpha > 0.0:
        if glob is None:
            glob = np.bincount(vals.reshape(-1),
                               weights=counts.reshape(-1).astype(
                                   np.float64))
        scores = scores / np.maximum(glob[vals], 1.0) ** hub_alpha

    def _top(side_mask):
        c = np.where(side_mask, scores, 0.0)
        kk = min(k, S)
        order = np.lexsort((vals, -c), axis=-1)[:, :kk]
        rows = np.arange(n)[:, None]
        top_c = c[rows, order]
        out = np.where(top_c > 0, vals[rows, order], -1)
        if kk < k:
            out = np.pad(out, ((0, 0), (0, k - kk)), constant_values=-1)
        return out

    users = _top(vals < type_boundary)
    items = _top(vals >= type_boundary)
    return users, items


def global_visit_mass(visited: np.ndarray, n_nodes: int) -> np.ndarray:
    """Total visit count per node across all starts (hub correction)."""
    return np.bincount(visited.reshape(-1), minlength=n_nodes
                       ).astype(np.float64)


def topk_by_count(visited: np.ndarray, starts: np.ndarray, k: int,
                  type_boundary: int, n_users: int,
                  hub_alpha: float = 0.0,
                  glob: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k most-visited user and item neighbors per start node.

    Vectorized run-length counting over row-sorted visit lists.
    Returns (user_nbrs, item_nbrs): (n, k) global-id arrays, -1 padded.
    ``type_boundary`` == n_users splits the unified id space.

    ``hub_alpha`` > 0 ranks by *relative* PPR: per-start visit counts
    divided by each node's global visit mass**alpha (personalized score
    relative to global PageRank).  On small dense graphs raw counts are
    dominated by hubs that carry no personalized signal; the same
    correction is implicit at billion-scale via the popularity-corrected
    edge weights (Eq. 3), and explicit here.  ``glob`` overrides the
    global mass (incremental refresh passes the spliced-trace mass so
    re-ranked rows match a full rebuild).
    """
    srt = np.sort(visited, axis=1)
    counts = _run_length_counts(srt)
    if hub_alpha > 0.0 and glob is None:
        glob = global_visit_mass(visited, int(visited.max()) + 1)
    return _topk_from_counts(srt, counts, starts, k, type_boundary,
                             hub_alpha, glob)


# ---------------------------------------------------------------------------
# precompute + incremental refresh
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PPRState:
    """Everything ``refresh_ppr_neighbors`` needs to splice new walks
    into an existing run: the visit traces, the adjacency snapshot the
    traces were walked on (for change detection), the user/item split of
    its unified id space (user growth shifts item global ids — the
    remap pass needs the old boundary), and the walk knobs."""
    visited: np.ndarray          # (n_nodes, n_walks*walk_len) int64
    nbrs: np.ndarray             # padded adjacency at build time
    cum: np.ndarray
    n_walks: int
    walk_len: int
    restart: float
    seed: int
    max_deg_per_type: int
    hub_alpha: float
    k_imp: int
    backend: str
    n_users: int = 0             # unified-id boundary at build time


def precompute_ppr_neighbors(g: HeteroGraph, *, k_imp: int = 50,
                             n_walks: int = 64, walk_len: int = 5,
                             restart: float = 0.15, seed: int = 0,
                             max_deg_per_type: int = 32,
                             hub_alpha: float = 0.5,
                             backend: str = "numpy",
                             return_state: bool = False):
    """(user_nbrs, item_nbrs): (n_users+n_items, k_imp) global ids, -1
    pad; identical for every ``backend``.  ``return_state`` additionally
    returns the ``PPRState`` that powers incremental refresh."""
    adj = build_padded_hetero_adj(g, max_deg_per_type)
    starts = np.arange(adj.n_nodes, dtype=np.int64)
    if backend == "pallas":
        visited, counts = _walk_pallas(adj.nbrs, adj.cum, starts,
                                       n_walks=n_walks, walk_len=walk_len,
                                       restart=restart, seed=seed,
                                       chunk=1 << 18, n_users=g.n_users)
        glob = global_visit_mass(visited, adj.n_nodes)
        users, items = _topk_from_counts(visited, counts, starts, k_imp,
                                         g.n_users, hub_alpha, glob)
    else:
        visited, _ = ppr_visit_counts(adj, starts, n_walks=n_walks,
                                      walk_len=walk_len, restart=restart,
                                      seed=seed, backend=backend)
        users, items = topk_by_count(
            visited, starts, k_imp, g.n_users, g.n_users,
            hub_alpha=hub_alpha,
            glob=global_visit_mass(visited, adj.n_nodes))
    if return_state:
        state = PPRState(visited, adj.nbrs, adj.cum, n_walks, walk_len,
                         restart, seed, max_deg_per_type, hub_alpha,
                         k_imp, backend, n_users=g.n_users)
        return users, items, state
    return users, items


def _expand_affected(nbrs: np.ndarray, changed: np.ndarray, hops: int
                     ) -> np.ndarray:
    """Nodes whose visit trace can differ: anything that reaches a
    changed adjacency row within ``hops`` steps (reverse BFS).  A walk
    diverges only after stepping *from* a changed row, and the identical
    prefix up to that row exists in the new adjacency, so BFS over the
    new adjacency is sufficient."""
    n, _ = nbrs.shape
    src = np.repeat(np.arange(n), nbrs.shape[1])
    dst = nbrs.reshape(-1)
    m = dst >= 0
    src, dst = src[m], dst[m]
    affected = changed.copy()
    frontier = changed
    for _ in range(max(0, hops)):
        newf = np.zeros(n, bool)
        newf[src[frontier[dst]]] = True
        newf &= ~affected
        if not newf.any():
            break
        affected |= newf
        frontier = newf
    return affected


def refresh_ppr_neighbors(g_new: HeteroGraph, user_nbrs: np.ndarray,
                          item_nbrs: np.ndarray, state: PPRState, *,
                          backend: Optional[str] = None
                          ) -> Tuple[np.ndarray, np.ndarray, PPRState,
                                     np.ndarray]:
    """Splice an incremental graph refresh into existing PPR tables.

    Re-walks only the nodes whose ``walk_len``-hop neighborhoods saw an
    adjacency change (plus brand-new user/item rows), regenerates
    exactly the uniform draws a full run would have used for them, and
    re-ranks those rows against the spliced global visit mass — so every
    affected row is bit-identical to a from-scratch
    ``precompute_ppr_neighbors`` on the refreshed graph, and every
    unaffected row is left untouched (modulo the unified-id remap).

    Either id space may have grown.  Item growth appends rows; *user*
    growth shifts every item's global id by the number of new users, so
    carried-over rows first go through a remap pass: row ``r`` of the
    old layout moves to ``r + shift`` when ``r`` was an item row, and
    every item id stored *inside* a trace or neighbor table shifts the
    same way (-1 pads and user ids are fixed points).  The type-keyed
    uniform stream (``walk_uniforms``) makes the old traces valid
    verbatim after the remap.

    Returns (user_nbrs, item_nbrs, new_state, affected_ids) — ids in the
    *new* unified space.
    """
    backend = backend or state.backend
    adj = build_padded_hetero_adj(g_new, state.max_deg_per_type)
    n_old = state.nbrs.shape[0]
    n_new = adj.n_nodes
    nu = g_new.n_users
    old_nu = state.n_users
    shift = nu - old_nu
    S = state.n_walks * state.walk_len

    # remap pass: old row positions + stored ids in the new unified space
    old_pos = np.arange(n_old)
    if shift:
        old_pos = np.where(old_pos >= old_nu, old_pos + shift, old_pos)

    def _remap(a: np.ndarray) -> np.ndarray:
        if not shift:
            return a
        return np.where(a >= old_nu, a + shift, a)   # -1 pads: fixed points

    changed = np.ones(n_new, bool)                 # inserted rows: changed
    changed[old_pos] = (np.any(adj.nbrs[old_pos] != _remap(state.nbrs),
                               axis=1)
                        | np.any(adj.cum[old_pos] != state.cum, axis=1))
    affected = _expand_affected(adj.nbrs, changed, state.walk_len - 1)
    ids = np.flatnonzero(affected)

    visited = np.empty((n_new, S), np.int64)
    visited[old_pos] = _remap(state.visited)
    if len(ids):
        if backend == "pallas":
            vis_new, cnt_new = _walk_pallas(
                adj.nbrs, adj.cum, ids, n_walks=state.n_walks,
                walk_len=state.walk_len, restart=state.restart,
                seed=state.seed, chunk=1 << 18, n_users=nu)
        else:
            vis_new, _ = ppr_visit_counts(
                adj, ids, n_walks=state.n_walks, walk_len=state.walk_len,
                restart=state.restart, seed=state.seed, backend=backend)
            cnt_new = None
        visited[ids] = vis_new

    glob = global_visit_mass(visited, n_new)
    u_rows = np.full((n_new, state.k_imp), -1, np.int64)
    i_rows = np.full((n_new, state.k_imp), -1, np.int64)
    u_rows[old_pos] = _remap(user_nbrs)
    i_rows[old_pos] = _remap(item_nbrs)
    if len(ids):
        if cnt_new is not None:
            u_new, i_new = _topk_from_counts(vis_new, cnt_new, ids,
                                             state.k_imp, nu,
                                             state.hub_alpha, glob)
        else:
            u_new, i_new = topk_by_count(vis_new, ids, state.k_imp, nu,
                                         nu, hub_alpha=state.hub_alpha,
                                         glob=glob)
        u_rows[ids] = u_new
        i_rows[ids] = i_new

    new_state = dataclasses.replace(state, visited=visited,
                                    nbrs=adj.nbrs, cum=adj.cum,
                                    backend=backend, n_users=nu)
    return u_rows, i_rows, new_state, ids


# ---------------------------------------------------------------------------
# Group 2 fallback (paper: KNN over previous Group-1 embeddings)
# ---------------------------------------------------------------------------

def group2_neighbors(prev_emb: np.ndarray, group1_ids: np.ndarray,
                     group2_ids: np.ndarray, k: int,
                     chunk: int = 4096) -> np.ndarray:
    """Same-type neighbors for Group-2 nodes = KNN (cosine) over Group-1
    embeddings from the previous training run (refreshed daily)."""
    if len(group1_ids) == 0 or len(group2_ids) == 0:
        return np.full((len(group2_ids), k), -1, np.int64)
    e1 = prev_emb[group1_ids]
    e1 = e1 / np.maximum(np.linalg.norm(e1, axis=1, keepdims=True), 1e-8)
    out = np.empty((len(group2_ids), k), np.int64)
    kk = min(k, len(group1_ids))
    for lo in range(0, len(group2_ids), chunk):
        hi = min(len(group2_ids), lo + chunk)
        q = prev_emb[group2_ids[lo:hi]]
        q = q / np.maximum(np.linalg.norm(q, axis=1, keepdims=True), 1e-8)
        sims = q @ e1.T
        top = np.argpartition(-sims, kk - 1, axis=1)[:, :kk]
        rows = np.arange(hi - lo)[:, None]
        o = np.argsort(-sims[rows, top], axis=1, kind="stable")
        sel = group1_ids[top[rows, o]]
        if kk < k:
            sel = np.pad(sel, ((0, 0), (0, k - kk)), constant_values=-1)
        out[lo:hi] = sel
    return out
