"""Personalized-PageRank neighbor pre-computation (paper §4.2).

Monte-Carlo approximation: R walks of length L with restart prob 0.15
from every backbone node, over the *subsampled* heterogeneous graph
(out-degree is bounded by K_CAP per edge type, so a padded adjacency is
the natural representation).  Edge-type transition mass is balanced so
no type dominates PPR output.

Two implementations with identical semantics:
  * numpy  (production offline pipeline; chunked, vectorized)
  * jax    (used by benchmarks + property tests; also demonstrates that
            the walk itself is expressible as a lax.scan if one wanted
            accelerator-side construction)

Group-2 handling (nodes without same-type neighbors) lives in
``group2_neighbors``: KNN over previous-run Group-1 embeddings + top
-weight U-I edges, per the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.graph_builder import HeteroGraph, padded_adjacency


@dataclasses.dataclass
class PaddedHeteroAdj:
    """Per-node fixed-width neighbor tables in a unified id space.

    Global ids: users are [0, n_users), items are [n_users, n_users+n_items).
    ``nbrs`` (n, D) int64 (-1 pad), ``cum`` (n, D) float32 cumulative
    transition probabilities (type-balanced), row-normalized.
    """
    nbrs: np.ndarray
    cum: np.ndarray
    n_users: int
    n_items: int

    @property
    def n_nodes(self) -> int:
        return self.n_users + self.n_items


def build_padded_hetero_adj(g: HeteroGraph, max_deg_per_type: int = 32
                            ) -> PaddedHeteroAdj:
    nu, ni = g.n_users, g.n_items
    D = max_deg_per_type
    # per-type padded adjacencies
    uu_n, uu_w = padded_adjacency(g.uu, nu, D)
    ii_n, ii_w = padded_adjacency(g.ii, ni, D)
    ui_n, ui_w = padded_adjacency(g.ui, nu, D)
    # reverse U-I (item -> engaging users), built from the same edges
    from repro.core.graph_builder import EdgeSet
    iu = EdgeSet(g.ui.dst, g.ui.src, g.ui.weight)
    iu_n, iu_w = padded_adjacency(iu, ni, D)

    n = nu + ni
    nbrs = np.full((n, 2 * D), -1, np.int64)
    probs = np.zeros((n, 2 * D), np.float64)

    def _fill(rows_off, block, nb, wt, id_off):
        nbrs[rows_off:rows_off + len(nb), block * D:(block + 1) * D] = \
            np.where(nb >= 0, nb + id_off, -1)
        probs[rows_off:rows_off + len(nb), block * D:(block + 1) * D] = wt

    # users: block0 = U-U (user ids), block1 = U-I (item ids)
    _fill(0, 0, uu_n, uu_w, 0)
    _fill(0, 1, ui_n, ui_w, nu)
    # items: block0 = I-I (item ids), block1 = I-U (user ids)
    _fill(nu, 0, ii_n, ii_w, nu)
    _fill(nu, 1, iu_n, iu_w, 0)

    # type-balanced normalization: each present type gets equal mass
    for blk in (0, 1):
        sl = slice(blk * D, (blk + 1) * D)
        tot = probs[:, sl].sum(axis=1, keepdims=True)
        probs[:, sl] = np.where(tot > 0, probs[:, sl] / np.maximum(tot, 1e-12),
                                0.0)
    ntypes = ((probs[:, :D].sum(1) > 0).astype(np.float64)
              + (probs[:, D:].sum(1) > 0).astype(np.float64))
    ntypes = np.maximum(ntypes, 1.0)
    probs /= ntypes[:, None]
    # rows with no out-edges: self-loop semantics handled at walk time
    cum = np.cumsum(probs, axis=1).astype(np.float32)
    return PaddedHeteroAdj(nbrs, cum, nu, ni)


# ---------------------------------------------------------------------------
# numpy Monte-Carlo walker
# ---------------------------------------------------------------------------

def _step(adj: PaddedHeteroAdj, pos: np.ndarray, rng) -> np.ndarray:
    u = rng.random(len(pos)).astype(np.float32)
    cum = adj.cum[pos]                             # (m, D2)
    col = (cum < u[:, None]).sum(axis=1)
    col = np.minimum(col, adj.nbrs.shape[1] - 1)
    nxt = adj.nbrs[pos, col]
    dead = (nxt < 0) | (cum[:, -1] <= 0)           # dangling -> stay
    return np.where(dead, pos, nxt)


def ppr_visit_counts(adj: PaddedHeteroAdj, starts: np.ndarray, *,
                     n_walks: int = 64, walk_len: int = 5,
                     restart: float = 0.15, seed: int = 0,
                     chunk: int = 1 << 18) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (visited, counts): (n_starts, n_walks*walk_len) node ids and
    per-start sorted visit arrays.  Memory-chunked over starts."""
    rng = np.random.default_rng(seed)
    n_start = len(starts)
    S = n_walks * walk_len
    visited = np.empty((n_start, S), np.int64)
    for lo in range(0, n_start, max(1, chunk // n_walks)):
        hi = min(n_start, lo + max(1, chunk // n_walks))
        home = np.repeat(starts[lo:hi], n_walks)
        pos = home.copy()
        block = np.empty((len(home), walk_len), np.int64)
        for t in range(walk_len):
            pos = _step(adj, pos, rng)
            rst = rng.random(len(pos)) < restart
            pos = np.where(rst, home, pos)
            block[:, t] = pos
        visited[lo:hi] = block.reshape(hi - lo, S)
    return visited, starts


def topk_by_count(visited: np.ndarray, starts: np.ndarray, k: int,
                  type_boundary: int, n_users: int,
                  hub_alpha: float = 0.0
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k most-visited user and item neighbors per start node.

    Vectorized run-length counting over row-sorted visit lists.
    Returns (user_nbrs, item_nbrs): (n, k) global-id arrays, -1 padded.
    ``type_boundary`` == n_users splits the unified id space.

    ``hub_alpha`` > 0 ranks by *relative* PPR: per-start visit counts
    divided by each node's global visit mass**alpha (personalized score
    relative to global PageRank).  On small dense graphs raw counts are
    dominated by hubs that carry no personalized signal; the same
    correction is implicit at billion-scale via the popularity-corrected
    edge weights (Eq. 3), and explicit here.
    """
    n, S = visited.shape
    srt = np.sort(visited, axis=1)
    newrun = np.ones_like(srt, bool)
    newrun[:, 1:] = srt[:, 1:] != srt[:, :-1]
    # run lengths: distance to next run start
    idx = np.arange(S)[None, :].repeat(n, 0)
    run_start_idx = np.where(newrun, idx, 0)
    run_start_idx = np.maximum.accumulate(run_start_idx, axis=1)
    # count for a run start = next_run_start - this index
    next_start = np.full((n, S + 1), S, np.int64)
    rev = newrun[:, ::-1]
    # compute, for each position, the index of the next run start strictly after
    nxt = np.full((n, S), S, np.int64)
    last = np.full(n, S, np.int64)
    for j in range(S - 1, -1, -1):       # S is small (R*L ~ a few hundred)
        nxt[:, j] = last
        last = np.where(newrun[:, j], j, last)
    counts = np.where(newrun, nxt - idx, 0)
    # drop self visits
    counts = np.where(srt == starts[:, None], 0, counts)
    vals = srt

    scores = counts.astype(np.float64)
    if hub_alpha > 0.0:
        n_all = int(visited.max()) + 1
        glob = np.bincount(visited.reshape(-1), minlength=n_all
                           ).astype(np.float64)
        scores = scores / np.maximum(glob[srt], 1.0) ** hub_alpha

    def _top(side_mask):
        c = np.where(side_mask & newrun, scores, 0.0)
        kk = min(k, S)
        top_idx = np.argpartition(-c, kk - 1, axis=1)[:, :kk]
        rows = np.arange(n)[:, None]
        top_c = c[rows, top_idx]
        top_v = np.where(top_c > 0, vals[rows, top_idx], -1)
        # order by count desc for determinism
        o = np.argsort(-top_c, axis=1, kind="stable")
        out = top_v[rows, o]
        if kk < k:
            out = np.pad(out, ((0, 0), (0, k - kk)), constant_values=-1)
        return out

    users = _top(vals < type_boundary)
    items = _top(vals >= type_boundary)
    return users, items


def precompute_ppr_neighbors(g: HeteroGraph, *, k_imp: int = 50,
                             n_walks: int = 64, walk_len: int = 5,
                             restart: float = 0.15, seed: int = 0,
                             max_deg_per_type: int = 32,
                             hub_alpha: float = 0.5
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """(user_nbrs, item_nbrs): (n_users+n_items, k_imp) global ids, -1 pad."""
    adj = build_padded_hetero_adj(g, max_deg_per_type)
    starts = np.arange(adj.n_nodes, dtype=np.int64)
    visited, _ = ppr_visit_counts(adj, starts, n_walks=n_walks,
                                  walk_len=walk_len, restart=restart,
                                  seed=seed)
    return topk_by_count(visited, starts, k_imp, g.n_users, g.n_users,
                         hub_alpha=hub_alpha)


# ---------------------------------------------------------------------------
# Group 2 fallback (paper: KNN over previous Group-1 embeddings)
# ---------------------------------------------------------------------------

def group2_neighbors(prev_emb: np.ndarray, group1_ids: np.ndarray,
                     group2_ids: np.ndarray, k: int,
                     chunk: int = 4096) -> np.ndarray:
    """Same-type neighbors for Group-2 nodes = KNN (cosine) over Group-1
    embeddings from the previous training run (refreshed daily)."""
    if len(group1_ids) == 0 or len(group2_ids) == 0:
        return np.full((len(group2_ids), k), -1, np.int64)
    e1 = prev_emb[group1_ids]
    e1 = e1 / np.maximum(np.linalg.norm(e1, axis=1, keepdims=True), 1e-8)
    out = np.empty((len(group2_ids), k), np.int64)
    kk = min(k, len(group1_ids))
    for lo in range(0, len(group2_ids), chunk):
        hi = min(len(group2_ids), lo + chunk)
        q = prev_emb[group2_ids[lo:hi]]
        q = q / np.maximum(np.linalg.norm(q, axis=1, keepdims=True), 1e-8)
        sims = q @ e1.T
        top = np.argpartition(-sims, kk - 1, axis=1)[:, :kk]
        rows = np.arange(hi - lo)[:, None]
        o = np.argsort(-sims[rows, top], axis=1, kind="stable")
        sel = group1_ids[top[rows, o]]
        if kk < k:
            sel = np.pad(sel, ((0, 0), (0, k - kk)), constant_values=-1)
        out[lo:hi] = sel
    return out


# ---------------------------------------------------------------------------
# JAX walker (benchmark / property-test path; identical semantics)
# ---------------------------------------------------------------------------

def ppr_walk_jax(nbrs: jnp.ndarray, cum: jnp.ndarray, starts: jnp.ndarray,
                 *, n_walks: int, walk_len: int, restart: float,
                 key: jax.Array) -> jnp.ndarray:
    """Vectorized Monte-Carlo walks; returns (n_starts, n_walks*walk_len)."""
    home = jnp.repeat(starts, n_walks)
    d2 = nbrs.shape[1]

    def step(pos, k):
        ku, kr = jax.random.split(k)
        u = jax.random.uniform(ku, (pos.shape[0],), jnp.float32)
        c = cum[pos]
        col = jnp.minimum(jnp.sum(c < u[:, None], axis=1), d2 - 1)
        nxt = nbrs[pos, col]
        dead = (nxt < 0) | (c[:, -1] <= 0)
        nxt = jnp.where(dead, pos, nxt)
        rst = jax.random.uniform(kr, (pos.shape[0],)) < restart
        return jnp.where(rst, home, nxt)

    def body(pos, k):
        nxt = step(pos, k)
        return nxt, nxt

    keys = jax.random.split(key, walk_len)
    _, trace = jax.lax.scan(body, home, keys)
    return jnp.transpose(trace, (1, 0)).reshape(len(starts),
                                                n_walks * walk_len)
