"""Negative sampling (paper §4.3): in-batch + out-of-batch rolling pool
+ multi-head negative augmentation.  100 negatives per positive, same
node type as the positive's destination.

The out-of-batch pool is device-resident state (one per node type): a
FIFO ring of recent destination embeddings approximating the global
distribution across batches.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class NegPoolState:
    user: jnp.ndarray      # (P, d)
    item: jnp.ndarray      # (P, d)
    user_ptr: jnp.ndarray  # ()
    item_ptr: jnp.ndarray  # ()
    user_fill: jnp.ndarray
    item_fill: jnp.ndarray


def init_pool(pool_size: int, d: int, dtype=jnp.float32) -> NegPoolState:
    # distinct buffers: the train state is donated, and XLA rejects
    # donating the same buffer twice
    return NegPoolState(jnp.zeros((pool_size, d), dtype),
                        jnp.zeros((pool_size, d), dtype),
                        jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                        jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))


jax.tree_util.register_dataclass(
    NegPoolState,
    data_fields=["user", "item", "user_ptr", "item_ptr", "user_fill",
                 "item_fill"],
    meta_fields=[])


def _push(buf: jnp.ndarray, ptr: jnp.ndarray, fill: jnp.ndarray,
          emb: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    P = buf.shape[0]
    B = emb.shape[0]
    idx = (ptr + jnp.arange(B)) % P
    buf = buf.at[idx].set(jax.lax.stop_gradient(emb.astype(buf.dtype)))
    return buf, (ptr + B) % P, jnp.minimum(fill + B, P)


def update_pool(state: NegPoolState, user_emb: Optional[jnp.ndarray],
                item_emb: Optional[jnp.ndarray]) -> NegPoolState:
    """None embeddings (a batch with no endpoints of that type — e.g. a
    uu-only ablation) leave that type's ring untouched."""
    ub, up, uf = (state.user, state.user_ptr, state.user_fill) \
        if user_emb is None else \
        _push(state.user, state.user_ptr, state.user_fill, user_emb)
    ib, ip, if_ = (state.item, state.item_ptr, state.item_fill) \
        if item_emb is None else \
        _push(state.item, state.item_ptr, state.item_fill, item_emb)
    return NegPoolState(ub, ib, up, ip, uf, if_)


def sample_negatives(key: jax.Array,
                     dst_primary: jnp.ndarray,    # (B, d) positives' dst
                     dst_heads: jnp.ndarray,      # (B, H, d)
                     pool: jnp.ndarray,           # (P, d) same type as dst
                     pool_fill: jnp.ndarray,      # ()
                     n_neg: int, n_pool: int,
                     shard_block: int = 0) -> jnp.ndarray:
    """Build the (B, n_neg, d) negative bank for each positive edge.

    Composition per the paper: (1) in-batch negatives = other edges' dst
    embeddings, (2) out-of-batch = rolling pool, (3) augmentation =
    individual head embeddings of in-batch dst nodes (hard negatives
    close to, but distinct from, the averaged positives).

    ``shard_block`` > 0 keeps in-batch indices within blocks of that
    size (the per-DP-shard rows): cross-shard random gathers force GSPMD
    to all-gather the whole batch tensor — the dominant collective in
    the distributed train step.  Shard-local in-batch negatives are the
    standard large-scale practice and statistically equivalent here
    (rows are i.i.d. across shards).
    """
    B, d = dst_primary.shape
    H = dst_heads.shape[1]
    n_aug = max(n_neg // 8, 1) if H > 1 else 0
    n_pool = min(n_pool, n_neg - n_aug)
    n_inb = n_neg - n_pool - n_aug
    blk = shard_block if 0 < shard_block <= B and B % shard_block == 0 \
        else B

    def local_other_rows(k, n):
        # row i -> (base of i's block) + (i + off) % blk : never crosses
        # the block boundary, never equals i (off in [1, blk))
        off = jax.random.randint(k, (B, n), 1, jnp.maximum(blk, 2))
        i = jnp.arange(B)[:, None]
        return (i // blk) * blk + (i + off) % blk

    k1, k2, k3 = jax.random.split(key, 3)
    # (1) in-batch: random other rows within the shard block
    neg_inb = dst_primary[local_other_rows(k1, n_inb)]   # (B, n_inb, d)

    # (2) pool: uniform over filled region (fallback to in-batch when empty)
    fill = jnp.maximum(pool_fill, 1)
    idx_pool = jax.random.randint(k2, (B, n_pool), 0, fill)
    neg_pool_ = pool[idx_pool].astype(dst_primary.dtype)
    have_pool = (pool_fill > 0)
    neg_pool_ = jnp.where(have_pool, neg_pool_,
                          dst_primary[local_other_rows(k3, n_pool)])

    parts = [neg_inb, neg_pool_]
    # (3) augmentation: per-head embeddings of *other* in-batch dst nodes
    if n_aug:
        ka = jax.random.fold_in(key, 7)
        rows = local_other_rows(ka, n_aug)
        heads = jax.random.randint(jax.random.fold_in(key, 8),
                                   (B, n_aug), 0, H)
        parts.append(dst_heads[rows, heads])             # (B, n_aug, d)
    return jnp.concatenate(parts, axis=1)
