"""Offline evaluation protocols (paper §5.2).

User embeddings (§5.2.1 / Table 2): for each sampled user, retrieve the
top-K nearest users by cosine; predicted items = the next-day
engagements of those neighbor users; Recall@K against the user's own
next-day engagements (the U2U2I retrieval quality).

Item embeddings (§5.2.2 / Table 3): strict temporal split — rank all
items against item i from a day-(N+1) co-engagement edge (i, j);
Recall@K = fraction of edges with j ranked in the top K.

Learned index (§5.2.3 / Table 4): Hitrate@K — whether the positive edge
similarity ranks in the top K against sampled negatives, for original
vs RQ-reconstructed embeddings.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph_builder import EngagementLog
from repro.data.synthetic import SyntheticWorld


def _topk_neighbors(emb: np.ndarray, queries: np.ndarray, k: int,
                    chunk: int = 1024) -> np.ndarray:
    e = emb / np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-8)
    out = np.empty((len(queries), k), np.int64)
    for lo in range(0, len(queries), chunk):
        hi = min(len(queries), lo + chunk)
        sims = e[queries[lo:hi]] @ e.T
        sims[np.arange(hi - lo), queries[lo:hi]] = -np.inf
        kk = min(k, e.shape[0] - 1)
        top = np.argpartition(-sims, kk - 1, axis=1)[:, :kk]
        rows = np.arange(hi - lo)[:, None]
        o = np.argsort(-sims[rows, top], axis=1, kind="stable")
        out[lo:hi, :kk] = top[rows, o]
    return out


def _user_day1_items(log: EngagementLog,
                     n_users: Optional[int] = None) -> list:
    """Per-user next-day item sets; ``n_users`` may exceed the log's
    user space (hour-level refreshes mint users after the eval window —
    they simply have empty ground truth)."""
    items = [set() for _ in range(max(log.n_users, n_users or 0))]
    for u, i in zip(log.user_id, log.item_id):
        items[u].add(int(i))
    return items


def user_recall(user_emb: np.ndarray, world: SyntheticWorld, *,
                ks: Sequence[int] = (5, 10, 50, 100),
                n_queries: int = 500, seed: int = 0) -> Dict[int, float]:
    """U2U2I Recall@K via top-K neighbor users' next-day engagements."""
    day1 = _user_day1_items(world.day1, len(user_emb))
    rng = np.random.default_rng(seed)
    active = np.flatnonzero([len(s) > 0 for s in day1])
    if len(active) == 0:
        return {k: 0.0 for k in ks}
    queries = rng.choice(active, min(n_queries, len(active)), replace=False)
    kmax = max(ks)
    nbrs = _topk_neighbors(user_emb, queries, kmax)
    out = {}
    for k in ks:
        recs = []
        for qi, u in enumerate(queries):
            truth = day1[u]
            pred = set()
            for v in nbrs[qi, :k]:
                pred |= day1[v]
            recs.append(len(pred & truth) / max(len(truth), 1))
        out[k] = float(np.mean(recs))
    return out


def day1_co_pairs(log: EngagementLog, *, n_edges: int = 500,
                  seed: int = 0) -> np.ndarray:
    """Sampled next-day I-I co-engagement pairs ``(n, 2)`` — the §5.2.2
    evaluation unit, shared by the offline ``item_recall`` protocol and
    the publication gate's index-side variant (identical sampling, so
    the two numbers are directly comparable)."""
    rng = np.random.default_rng(seed)
    order = np.argsort(log.user_id, kind="stable")
    u, it = log.user_id[order], log.item_id[order]
    starts = np.flatnonzero(np.r_[True, u[1:] != u[:-1]])
    ends = np.r_[starts[1:], len(u)]
    pairs = []
    for s, e in zip(starts, ends):
        its = np.unique(it[s:e])
        if len(its) >= 2:
            a = rng.choice(its, min(len(its), 4), replace=False)
            for x in range(len(a) - 1):
                pairs.append((a[x], a[x + 1]))
    if not pairs:
        return np.zeros((0, 2), np.int64)
    pairs = np.asarray(pairs)
    idx = rng.choice(len(pairs), min(n_edges, len(pairs)), replace=False)
    return pairs[idx]


def item_recall(item_emb: np.ndarray, world: SyntheticWorld, *,
                ks: Sequence[int] = (5, 10, 50, 100),
                n_edges: int = 500, seed: int = 0) -> Dict[int, float]:
    """Next-day I-I co-engagement ranking recall (temporal split)."""
    pairs = day1_co_pairs(world.day1, n_edges=n_edges, seed=seed)
    if not len(pairs):
        return {k: 0.0 for k in ks}
    e = item_emb / np.maximum(
        np.linalg.norm(item_emb, axis=1, keepdims=True), 1e-8)
    sims = e[pairs[:, 0]] @ e.T
    sims[np.arange(len(pairs)), pairs[:, 0]] = -np.inf
    ranks = (sims > sims[np.arange(len(pairs)), pairs[:, 1]][:, None]
             ).sum(axis=1)
    return {k: float(np.mean(ranks < k)) for k in ks}


def index_hitrate(emb: np.ndarray, recon: np.ndarray,
                  pos_pairs: np.ndarray, *, n_neg: int = 100,
                  ks: Sequence[int] = (1, 5, 10), seed: int = 0,
                  neg_range: Optional[Tuple[int, int]] = None
                  ) -> Tuple[Dict[int, float], Dict[int, float]]:
    """Hitrate@K for original and reconstructed embeddings on the same
    positive pairs + shared sampled negatives.  ``neg_range`` restricts
    negatives to the dst node type (paper: same type as n_j)."""
    rng = np.random.default_rng(seed)
    lo, hi = neg_range if neg_range is not None else (0, len(emb))
    neg = rng.integers(lo, hi, (len(pos_pairs), n_neg))

    def hr(e):
        e = e / np.maximum(np.linalg.norm(e, axis=1, keepdims=True), 1e-8)
        s_pos = np.sum(e[pos_pairs[:, 0]] * e[pos_pairs[:, 1]], axis=1)
        s_neg = np.einsum("nd,nkd->nk", e[pos_pairs[:, 0]], e[neg])
        # ties count half a rank (quantized/reconstructed embeddings can
        # collide exactly; strict '>' would otherwise inflate hitrate)
        rank = ((s_neg > s_pos[:, None] + 1e-7).sum(axis=1)
                + 0.5 * (np.abs(s_neg - s_pos[:, None]) <= 1e-7
                         ).sum(axis=1))
        return {k: float(np.mean(rank < k)) for k in ks}

    return hr(emb), hr(recon)
