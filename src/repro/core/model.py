"""RankGraph-2 model (paper §4.3, Figure 2B).

Multi-head type-aware feature encoders ``f_U``, ``f_I`` + heterogeneous
aggregator ``AGG_t`` over exactly K pre-computed user and item neighbors
(Eq. 4).  Inductive: all parameters are shared encoders over real-valued
features; no per-node parameters.

Multi-head embeddings: ``f_t`` and ``AGG_t`` produce H independent heads;
heads are extra negatives during training (negative augmentation) and
averaged at inference.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RankGraph2Config
from repro.distributed.sharding import ShardingCtx, NULL_CTX
from repro.nn import core as nn

USER, ITEM = 0, 1


def _encoder_init(key, d_in: int, d_hidden: int, n_heads: int, d_embed: int,
                  dtype):
    k1, k2 = jax.random.split(key)
    p1, s1 = nn.linear_init(k1, d_in, d_hidden, in_name="embed",
                            out_name="mlp", dtype=dtype)
    p2, s2 = nn.linear_init(k2, d_hidden, n_heads * d_embed,
                            in_name="mlp", out_name="heads_embed", dtype=dtype)
    return {"l1": p1, "l2": p2}, {"l1": s1, "l2": s2}


def _encoder_apply(params, x: jax.Array, n_heads: int, d_embed: int,
                   ctx: ShardingCtx) -> jax.Array:
    """(..., d_in) -> (..., H, d_embed)

    The hidden constraint keeps the leading (batch) dim sharded: an
    explicit None there *unshards* it, and GSPMD then all-gathers the
    (B, K, d_hidden) activations in the backward pass — measured as the
    dominant collective of the distributed train step (EXPERIMENTS.md
    §Perf/rankgraph2)."""
    h = jax.nn.gelu(nn.linear_apply(params["l1"], x))
    h = ctx(h, "batch", *((None,) * (h.ndim - 2)), "mlp")
    h = nn.linear_apply(params["l2"], h)
    return h.reshape(*x.shape[:-1], n_heads, d_embed)


def _agg_init(key, n_heads: int, d_embed: int, dtype):
    # per-head combine of [self, user-nbr-mean, item-nbr-mean]
    w = nn.variance_scaling(1.0, "fan_in", "normal")(
        key, (n_heads, 3 * d_embed, d_embed), dtype,
        in_axes=(1,), out_axes=(2,))
    return ({"w": w, "b": jnp.zeros((n_heads, d_embed), dtype)},
            {"w": ("heads", None, "embed"), "b": ("heads", "embed")})


def _agg_apply(params, self_e, unbr_e, inbr_e) -> jax.Array:
    """All inputs (B, H, d); output (B, H, d), l2-normalized per head."""
    x = jnp.concatenate([self_e, unbr_e, inbr_e], axis=-1)    # (B,H,3d)
    y = jnp.einsum("bhk,hkd->bhd", x, params["w"].astype(x.dtype))
    y = y + params["b"].astype(x.dtype)
    y = jax.nn.gelu(y)
    return nn.l2_normalize(y, axis=-1)


def init_params(key, cfg: RankGraph2Config) -> Tuple[Any, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    pu, su = _encoder_init(ks[0], cfg.d_user_feat, cfg.d_hidden, cfg.n_heads,
                           cfg.d_embed, dtype)
    pi, si = _encoder_init(ks[1], cfg.d_item_feat, cfg.d_hidden, cfg.n_heads,
                           cfg.d_embed, dtype)
    au, asu = _agg_init(ks[2], cfg.n_heads, cfg.d_embed, dtype)
    ai, asi = _agg_init(ks[3], cfg.n_heads, cfg.d_embed, dtype)
    params = {"f_user": pu, "f_item": pi, "agg_user": au, "agg_item": ai}
    specs = {"f_user": su, "f_item": si, "agg_user": asu, "agg_item": asi}
    return params, specs


def _masked_mean(e: jax.Array, mask: jax.Array) -> jax.Array:
    """e: (B, K, H, d), mask: (B, K) -> (B, H, d)"""
    m = mask.astype(e.dtype)[:, :, None, None]
    tot = jnp.sum(e * m, axis=1)
    cnt = jnp.maximum(jnp.sum(m, axis=1), 1.0)
    return tot / cnt


def encode_nodes(params, cfg: RankGraph2Config, node_type: int,
                 feat: jax.Array, ctx: ShardingCtx = NULL_CTX) -> jax.Array:
    """Type encoder f_t only: (..., d_feat) -> (..., H, d_embed).

    The deduplicated training forward encodes each unique node exactly
    once through this and shares the result between its self-role and
    every neighbor-role via gathers (see ``aggregate_nodes``)."""
    f = params["f_user"] if node_type == USER else params["f_item"]
    return _encoder_apply(f, feat.astype(jnp.dtype(cfg.dtype)),
                          cfg.n_heads, cfg.d_embed, ctx)


def aggregate_nodes(params, cfg: RankGraph2Config, node_type: int,
                    self_e: jax.Array,
                    unbr_e: jax.Array, unbr_mask: jax.Array,
                    inbr_e: jax.Array, inbr_mask: jax.Array,
                    ctx: ShardingCtx = NULL_CTX) -> jax.Array:
    """AGG_t over pre-encoded heads: self_e (B, H, d), neighbor heads
    (B, K, H, d) + masks -> (B, H, d) l2-normalized."""
    agg = params["agg_user"] if node_type == USER else params["agg_item"]
    u_agg = _masked_mean(unbr_e, unbr_mask)
    i_agg = _masked_mean(inbr_e, inbr_mask)
    out = _agg_apply(agg, self_e, u_agg, i_agg)
    return ctx(out, "batch", None, None)


def embed_nodes(params, cfg: RankGraph2Config, node_type: int,
                feat: jax.Array,
                unbr_feat: jax.Array, unbr_mask: jax.Array,
                inbr_feat: jax.Array, inbr_mask: jax.Array,
                ctx: ShardingCtx = NULL_CTX) -> jax.Array:
    """Eq. 4.  Returns per-head embeddings (B, H, d_embed), l2-normalized.

    feat: (B, d_feat) raw features of the node itself.
    unbr_feat/inbr_feat: (B, K, d_*) features of pre-computed user/item
    neighbors; masks flag padding (-1 neighbors).
    """
    self_e = encode_nodes(params, cfg, node_type, feat, ctx)
    u_e = encode_nodes(params, cfg, USER, unbr_feat, ctx)
    i_e = encode_nodes(params, cfg, ITEM, inbr_feat, ctx)
    return aggregate_nodes(params, cfg, node_type, self_e, u_e, unbr_mask,
                           i_e, inbr_mask, ctx)


def primary_embedding(head_emb: jax.Array) -> jax.Array:
    """Inference embedding = l2-normalized mean over heads."""
    return nn.l2_normalize(jnp.mean(head_emb, axis=-2), axis=-1)


def embed_side(params, cfg: RankGraph2Config, side: Dict[str, jax.Array],
               node_type: int, ctx: ShardingCtx = NULL_CTX
               ) -> Tuple[jax.Array, jax.Array]:
    """Convenience: returns (heads (B,H,d), primary (B,d)) for one endpoint
    sub-batch with keys feat / unbr_feat / unbr_mask / inbr_feat / inbr_mask."""
    heads = embed_nodes(params, cfg, node_type, side["feat"],
                        side["unbr_feat"], side["unbr_mask"],
                        side["inbr_feat"], side["inbr_mask"], ctx)
    return heads, primary_embedding(heads)
