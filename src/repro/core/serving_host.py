"""Host (numpy) serving engine — the PR-5 seqlock path, preserved.

This module holds the original multithreaded *host* implementation of
the cluster-queue store: per-thread ``BufPool`` scratch, the composite-
key ``dedup_topk_rows`` pass, and ``HostQueueStore`` — the seqlock-
guarded ``(n_clusters, queue_len)`` ring-buffer store whose readers run
lock-free against a concurrently-ingesting writer.

``repro.core.serving`` now serves from **device-resident** ring buffers
behind a single jitted dispatch (``ClusterQueueStore`` there); this
module remains for three reasons:

* it is the **bitwise equivalence oracle** for the jitted retrieve path
  (``tests/test_serving_device.py`` holds the two engines equal across
  dedup/recency/top-k edge cases);
* it is the **baseline** the ``serving_scaleout`` benchmark gate is
  measured against (the jitted path must beat the 4-thread host
  aggregate by the configured factor, with no calibration cap);
* the seqlock discipline it implements is still checked by
  ``repro.analysis`` (rule ``lock-discipline``) and exercised by the
  concurrency tests — it is reference material for any future host
  fallback, not dead code.

Threading contract (unchanged from PR 5): one store serves N reader
threads concurrently.  Request scratch comes from a per-thread
``BufPool`` registry, and the retrieve path is lock-free — a
per-cluster seqlock (generation counter, odd while a write is in
flight) lets readers run against a concurrently-ingesting store and
retry the gather on the rare torn read.  Writers serialize on the
store's write lock.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import get_telemetry

_OBS = get_telemetry()   # process singleton; configure() mutates in place


# ---------------------------------------------------------------------------
# batched row utilities (shared by U2U2I and U2I2I paths)
# ---------------------------------------------------------------------------

class BufPool:
    """Named scratch-buffer cache so the steady-state serving path runs
    allocation-free (fresh multi-MB temporaries each request batch cost
    more in page faults than the actual compute).

    Single-threaded by design — the buffers are reused in place, so one
    pool must never be shared across concurrent requests.  Concurrent
    callers go through ``ThreadLocalPools`` (one pool per thread) rather
    than holding a pool directly."""

    def __init__(self):
        self._bufs: Dict[str, np.ndarray] = {}

    def get(self, name: str, shape: Tuple[int, ...], dtype) -> np.ndarray:
        buf = self._bufs.get(name)
        if buf is None or buf.shape != shape or buf.dtype != dtype:
            buf = np.empty(shape, dtype)
            self._bufs[name] = buf
            if _OBS.enabled:   # steady state should stop allocating
                _OBS.counter("serving.pool_allocs")
        return buf


class ThreadLocalPools:
    """Per-thread ``BufPool`` registry: ``get()`` hands each thread its
    own pool, so N serving threads can share one immutable store without
    aliasing each other's ``rows``/``ts``/``key`` scratch.  Buffers die
    with their thread (``threading.local`` storage)."""

    def __init__(self):
        self._tls = threading.local()

    def get(self) -> BufPool:
        pool = getattr(self._tls, "pool", None)
        if pool is None:
            pool = self._tls.pool = BufPool()
        return pool


_POOLS = ThreadLocalPools()   # default pools for module-level entry points


def dedup_topk_rows(cand: np.ndarray, prio: np.ndarray, valid: np.ndarray,
                    k: int, prio_bound: int,
                    pool: Optional[BufPool] = None) -> np.ndarray:
    """Per row: among ``valid`` entries, dedup items keeping the
    lowest-priority occurrence, then emit the ``k`` lowest-priority
    survivors in priority order as ``(B, k)`` int64, ``-1``-padded.

    ``prio`` must be unique per row and ``< prio_bound`` wherever valid.
    One unstable composite-key sort (item * P + priority packs both the
    dedup grouping and the within-item winner into a single ordered
    pass) plus an O(Q) top-k partition — no stable sorts, no scatters,
    no allocations beyond the (B, k) result.
    """
    pool = pool if pool is not None else _POOLS.get()
    B, M = cand.shape
    pshift = max(int(prio_bound - 1).bit_length(), 1)  # P = 2^pshift
    P = 1 << pshift
    ishift = max(int(cand.max(initial=0)).bit_length(), 1)
    dt = np.int32 if pshift + ishift < 31 else np.int64
    big = np.iinfo(dt).max
    # pass 1: sort on (item, prio) — groups duplicates, winner first.
    # Value sorts throughout: the original column is never needed again,
    # so no argsort/gather round-trips; key assembly is in-place.
    key = pool.get("key", (B, M), dt)
    scrap = pool.get("scrap", (B, M), bool)
    np.left_shift(cand, pshift, out=key, dtype=dt)
    np.add(key, prio, out=key)
    np.logical_not(valid, out=scrap)
    np.copyto(key, big, where=scrap)
    key.sort(axis=1)
    item = pool.get("item", (B, M), dt)
    np.right_shift(key, pshift, out=item)
    alive = pool.get("alive", (B, M), bool)
    alive[:, 0] = True
    np.not_equal(item[:, 1:], item[:, :-1], out=alive[:, 1:])  # dedup
    # pass 2: re-pack winners as (prio, item) and select the k smallest
    np.not_equal(key, big, out=scrap)
    alive &= scrap
    key2 = pool.get("key2", (B, M), dt)
    np.bitwise_and(key, P - 1, out=key2)
    np.left_shift(key2, ishift, out=key2)
    np.bitwise_or(key2, item, out=key2)
    np.logical_not(alive, out=alive)
    np.copyto(key2, big, where=alive)
    kk = min(k, M)
    if kk < M:
        key2.partition(kk - 1, axis=1)
        key2 = key2[:, :kk]
    key2.sort(axis=1)
    out = np.where(key2 != big,
                   key2 & ((1 << ishift) - 1), -1).astype(np.int64)
    if out.shape[1] < k:
        out = np.pad(out, ((0, 0), (0, k - out.shape[1])),
                     constant_values=-1)
    return out


# ---------------------------------------------------------------------------
# host cluster-queue store (U2U2I) — the PR-5 seqlock engine
# ---------------------------------------------------------------------------

class HostQueueStore:
    """Real-time per-cluster item queues with recency filtering — host
    arrays, seqlock readers.

    Flat ring-buffer layout: ``items``/``times`` are dense
    ``(n_clusters, queue_len)`` arrays and ``cursor[c]`` counts total
    writes into cluster ``c`` (write position = ``cursor % queue_len``,
    fill level = ``min(cursor, queue_len)``) — O(1) eviction, no Python
    containers anywhere on the serving path.

    Concurrency: writers serialize on ``write_lock`` (an RLock — the
    swap engine's ring drain wraps ``ingest`` in the same lock);
    readers are lock-free via a per-cluster seqlock, ``gen[c]``, which
    is odd exactly while a write to cluster ``c`` is in flight.  A
    reader gathers its rows, then re-checks the generations it started
    from and retries on mismatch; after ``_SEQLOCK_SPINS`` failed
    attempts it falls back to one gather under ``write_lock``.
    """

    _SEQLOCK_SPINS = 32

    def __init__(self, user_clusters: np.ndarray, *, queue_len: int = 256,
                 recency_s: float = 900.0, n_clusters: Optional[int] = None,
                 telemetry=None):
        self.tel = telemetry if telemetry is not None else get_telemetry()
        self.user_clusters = np.asarray(user_clusters, np.int64)
        self.queue_len = int(queue_len)
        self.recency_s = float(recency_s)
        if n_clusters is None:
            n_clusters = int(self.user_clusters.max()) + 1 \
                if self.user_clusters.size else 1
        self.n_clusters = int(n_clusters)
        self.items = np.full((self.n_clusters, self.queue_len), -1, np.int32)
        # timestamps are stored float32 relative to the first-seen event
        # (absolute unix-epoch seconds lose ~100s of precision in f32)
        self.times = np.full((self.n_clusters, self.queue_len), -np.inf,
                             np.float32)
        self.cursor = np.zeros(self.n_clusters, np.int64)
        self.epoch: Optional[float] = None
        self.pools = ThreadLocalPools()  # per-thread request scratch
        self.gen = np.zeros(self.n_clusters, np.int64)   # seqlock, odd=busy
        self.write_lock = threading.RLock()
        self.ring_seen = 0     # EventRing watermark (maintained by swap)

    # -- cluster assignment lookup ------------------------------------------

    def clusters_of(self, user_ids: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Cluster ids for a batch of users plus a known-user mask.

        Users outside the assignment table — ids minted *after* the
        snapshot this store serves was published (the id space grows at
        every lifecycle refresh) — map to cluster 0 with ``known=False``;
        callers must mask their rows out rather than crash or serve
        another user's cluster.
        """
        user_ids = np.asarray(user_ids, np.int64).ravel()
        known = (user_ids >= 0) & (user_ids < self.user_clusters.shape[0])
        cl = self.user_clusters[np.where(known, user_ids, 0)]
        known = known & (cl >= 0)       # -1 = unassigned (out-of-shard)
        return np.where(known, cl, 0), known

    # -- ingestion ----------------------------------------------------------

    def ingest(self, user_ids: np.ndarray, item_ids: np.ndarray,
               timestamps: np.ndarray) -> None:
        """Stream a batch of engagement events into their users' cluster
        ring buffers (vectorized; oldest-to-newest so the ring order is
        the time order within the batch).  Events from users unknown to
        this snapshot's assignment table are dropped (they enter queues
        once the next publication assigns them a cluster).

        Thread-safe vs concurrent writers (``write_lock``) and vs
        lock-free readers: all array writes happen inside the touched
        clusters' seqlock window (``gen`` odd), so a reader overlapping
        the scatter retries instead of returning a torn row."""
        user_ids = np.asarray(user_ids, np.int64).ravel()
        item_ids = np.asarray(item_ids, np.int64).ravel()
        ts64 = np.asarray(timestamps, np.float64).ravel()
        cl_all, known = self.clusters_of(user_ids)
        if not known.all():
            # graceful degradation: post-snapshot users are shed, not
            # errored — the drop is surfaced as a counter so staleness
            # between publications is observable
            if self.tel.enabled:
                self.tel.counter("serving.unknown_user_events",
                                 float((~known).sum()))
            cl_all = cl_all[known]
            item_ids = item_ids[known]
            ts64 = ts64[known]
        if cl_all.size == 0:
            return
        with self.write_lock:
            if self.epoch is None:
                self.epoch = float(ts64.min())
            ts = (ts64 - self.epoch).astype(np.float32)
            order = np.argsort(ts, kind="stable")
            cl = cl_all[order]
            it = item_ids[order]
            ts = ts[order]

            # per-cluster arrival rank (stable sort by cluster keeps
            # time order)
            by_cl = np.argsort(cl, kind="stable")
            cl_sorted = cl[by_cl]
            boundary = np.r_[True, cl_sorted[1:] != cl_sorted[:-1]]
            group_start = np.maximum.accumulate(
                np.where(boundary, np.arange(cl.size), 0))
            rank = np.empty(cl.size, np.int64)
            rank[by_cl] = np.arange(cl.size) - group_start

            slot = (self.cursor[cl] + rank) % self.queue_len
            # keep only the final write per (cluster, slot): with more
            # events than queue_len for one cluster in a single batch,
            # older events fall straight through the ring
            key = cl * self.queue_len + slot
            _, last = np.unique(key[::-1], return_index=True)
            last = cl.size - 1 - last
            uniq, counts = np.unique(cl, return_counts=True)
            self.gen[uniq] += 1                # enter: odd -> readers spin
            self.items[cl[last], slot[last]] = it[last]
            self.times[cl[last], slot[last]] = ts[last]
            self.cursor[uniq] += counts
            self.gen[uniq] += 1                # exit: even -> consistent
        tel = self.tel
        if tel.enabled:
            tel.counter("serving.ingest_events", float(cl.size))
            fill = np.minimum(self.cursor[uniq], self.queue_len)
            tel.gauge("serving.queue_depth_max", float(fill.max()))
            tel.gauge("serving.queue_depth_mean", float(fill.mean()))

    # -- retrieval ----------------------------------------------------------

    def rel_cutoff(self, now: float) -> float:
        """Recency cutoff in the store's internal (epoch-relative) time."""
        return now - self.recency_s - (self.epoch or 0.0)

    def _seqlock_read(self, cl: np.ndarray, fn):
        """Run ``fn()`` (which reads this store's arrays for clusters
        ``cl``) under the seqlock discipline: skip while any touched
        generation is odd, re-check the generations the read started
        from, and retry on mismatch (a writer scattered into one of our
        clusters mid-read).  Lock-free on the happy path; after
        ``_SEQLOCK_SPINS`` collisions, one run under ``write_lock``
        guarantees progress.

        Every collision (odd generation seen, or generation moved under
        the read) counts as a ``serving.seqlock_retries`` tick; taking
        the locked path counts as ``serving.seqlock_fallbacks``."""
        tel = self.tel
        retries = 0
        for _ in range(self._SEQLOCK_SPINS):
            g0 = self.gen[cl]            # fancy index -> private copy
            if (g0 & 1).any():           # a write is mid-flight: respin
                retries += 1
                continue
            out = fn()
            if np.array_equal(self.gen[cl], g0):
                if retries and tel.enabled:
                    tel.counter("serving.seqlock_retries", float(retries))
                return out
            retries += 1
        if tel.enabled:
            if retries:
                tel.counter("serving.seqlock_retries", float(retries))
            tel.counter("serving.seqlock_fallbacks")
        with self.write_lock:            # bounded fallback: quiesced read
            return fn()

    def _consistent_gather(self, cl: np.ndarray, pool: BufPool
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Seqlock gather of ``(items, times, cursor)`` rows for
        clusters ``cl`` into per-thread scratch."""
        B, Q = cl.shape[0], self.queue_len
        rows = pool.get("rows", (B, Q), np.int32)
        ts = pool.get("ts", (B, Q), np.float32)

        def gather():
            np.take(self.items, cl, axis=0, out=rows)
            np.take(self.times, cl, axis=0, out=ts)
            return rows, ts, self.cursor[cl]

        return self._seqlock_read(cl, gather)

    def retrieve_batch(self, user_ids: np.ndarray, now: float,
                       k: int) -> np.ndarray:
        """Batched U2U2I: ``(B,)`` user ids -> ``(B, k)`` item ids,
        newest-first, recency-filtered, deduped, ``-1``-padded.  One
        vectorized pass over the whole request batch.  Safe to call from
        many threads at once (per-thread scratch, seqlock-guarded
        gather)."""
        tel = self.tel
        t0 = tel.clock.perf() if tel.enabled else 0.0
        user_ids = np.asarray(user_ids, np.int64).ravel()
        Q = self.queue_len
        B = user_ids.shape[0]
        pool = self.pools.get()
        cl, known = self.clusters_of(user_ids)
        rows, ts, total = self._consistent_gather(cl, pool)
        head = (total % Q).astype(np.int32)
        slot = np.arange(Q, dtype=np.int32)[None, :]
        age = pool.get("age", (B, Q), np.int32)
        np.subtract(head[:, None], slot + 1, out=age)
        if Q & (Q - 1) == 0:                                 # pow2 fast path
            np.bitwise_and(age, Q - 1, out=age)              # newest = 0
        else:
            np.mod(age, Q, out=age)
        valid = pool.get("valid", (B, Q), bool)
        mask = pool.get("mask", (B, Q), bool)
        np.greater_equal(ts, np.float32(self.rel_cutoff(now)), out=valid)
        np.less(age, np.minimum(total, Q)[:, None], out=mask)
        valid &= mask
        np.greater_equal(rows, 0, out=mask)
        valid &= mask
        if not known.all():
            valid &= known[:, None]          # unknown users: empty rows
            if tel.enabled:
                tel.counter("serving.unknown_user_requests",
                            float((~known).sum()))
        out = dedup_topk_rows(rows, age, valid, k, Q, pool)
        if tel.enabled:
            tel.observe("serving.retrieve_latency_s",
                        tel.clock.perf() - t0)
            tel.counter("serving.retrieve_requests")
        return out

    def retrieve(self, user_id: int, now: float, k: int) -> List[int]:
        """Legacy single-request U2U2I — a batch of one."""
        row = self.retrieve_batch(np.array([user_id]), now, k)[0]
        return [int(i) for i in row if i >= 0]

    def serve_batch(self, user_ids: np.ndarray, now: float, *,
                    n_recent: int = 8, k: int = 32,
                    i2i: Optional[np.ndarray] = None,
                    use_kernel: bool = False
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Full serving pass: U2U2I seeds ``(B, n_recent)`` plus — when an
        ``i2i`` table is given — the U2I2I round-robin union ``(B, k)``.
        ``use_kernel=True`` routes through the fused Pallas
        ``queue_gather`` kernel instead of the numpy path."""
        # late imports: the U2I2I functions live in repro.core.serving,
        # which imports this module
        from repro.core.serving import u2i2i_retrieve_batch
        if i2i is not None and use_kernel:
            from repro.kernels.queue_gather.ops import queue_gather
            cl, known = self.clusters_of(user_ids)

            def _run():
                s, u = queue_gather(
                    self.items, self.times, self.cursor, cl, i2i,
                    cutoff=self.rel_cutoff(now), n_recent=n_recent, k=k)
                return np.asarray(s, np.int64), np.asarray(u, np.int64)

            # same seqlock discipline as the numpy path: the kernel
            # snapshots the store arrays at launch, so relaunch on a
            # torn read
            seeds, union = self._seqlock_read(cl, _run)
            if not known.all():
                seeds[~known] = -1           # unknown users: empty rows
                union[~known] = -1
                if self.tel.enabled:
                    self.tel.counter("serving.unknown_user_requests",
                                     float((~known).sum()))
            return seeds, union
        seeds = self.retrieve_batch(user_ids, now, n_recent)
        if i2i is None:
            return seeds, np.full((seeds.shape[0], k), -1, np.int64)
        return seeds, u2i2i_retrieve_batch(i2i, seeds, k)

    def partitions(self) -> Tuple["HostQueueStore", ...]:
        """Shard polymorphism: a host store is its own single shard."""
        return (self,)

    def stats(self) -> Dict[str, float]:
        fill = np.minimum(self.cursor, self.queue_len)
        active = fill > 0
        return dict(n_shards=1, n_clusters_active=int(active.sum()),
                    mean_queue=float(fill[active].mean())
                    if active.any() else 0.0)
