"""RankGraph-2 losses (paper Eq. 5-8).

Margin ranking (Eq. 5, margin=0.1) + InfoNCE (Eq. 6, tau=0.06) per edge;
per-edge-type losses combined with *learned* uncertainty weighting
(Kendall et al. 2018).  The paper learns lambda (margin vs infoNCE) and
beta_1..3 (edge types) via uncertainty weighting; we flatten this to one
learned log-variance per (loss kind x edge type) task plus the RQ-index
tasks (recon / contrastive-on-recon / regularizer), which subsumes both
levels of weighting.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

EDGE_TYPES = ("uu", "ui", "iu", "ii")
TASKS = tuple(f"{k}_{et}" for k in ("margin", "infonce") for et in EDGE_TYPES
              ) + ("rq_recon", "rq_contrastive", "rq_reg", "rq_util")


def init_uncertainty(dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    """Learned log-variances s_k; loss = sum exp(-s_k) L_k + s_k."""
    return {t: jnp.zeros((), dtype) for t in TASKS}


def pair_losses(src: jnp.ndarray,            # (B, d) l2-normalized
                dst: jnp.ndarray,            # (B, d) l2-normalized
                negs: jnp.ndarray,           # (B, N, d) l2-normalized
                *, margin: float = 0.1, tau: float = 0.06,
                use_kernel: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (margin_loss, infonce_loss), each (B,).

    ``use_kernel`` routes through the fused Pallas kernel (forward and
    backward both single-pass over the (B, N) logits tile); the default
    jnp path is the autodiff reference.
    """
    from repro.kernels.fused_contrastive.ops import contrastive
    return contrastive(src, dst, negs, margin=margin, tau=tau,
                       use_kernel=use_kernel)


def uncertainty_combine(task_losses: Dict[str, jnp.ndarray],
                        log_vars: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Kendall et al.: sum_k exp(-s_k) L_k + s_k (missing tasks skipped)."""
    total = jnp.zeros((), jnp.float32)
    for name, loss in task_losses.items():
        s = log_vars[name].astype(jnp.float32)
        total = total + jnp.exp(-s) * loss.astype(jnp.float32) + s
    return total


def effective_weights(log_vars: Dict[str, jnp.ndarray]) -> Dict[str, float]:
    """exp(-s_k): the learned equivalents of lambda / beta (for logging)."""
    return {k: float(jnp.exp(-v)) for k, v in log_vars.items()}
