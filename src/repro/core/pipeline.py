"""End-to-end RankGraph-2 pipeline: log -> graph -> PPR -> train -> embed.

One entry point used by the examples, the paper-table benchmarks and the
ablations; every ablation knob of §5.3 is a parameter:

    edge_types         subset of ("uu", "ui", "ii")          (Table 5)
    neighbor_strategy  "ppr" | "topweight" | "random"        (Table 6)
    popbias            Eq. 3 correction on/off               (Table 7)
    rq_regularize      RQ balance regularizer on/off         (Table 4)
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RankGraph2Config, RQConfig
from repro.core import graph_builder as GB
from repro.core import trainer as T
from repro.core import rq_index as RQ
from repro.data.edge_dataset import (EdgeDataset, NeighborTables,
                                     build_neighbor_tables)
from repro.data.synthetic import SyntheticWorld
from repro.obs import get_telemetry


@contextlib.contextmanager
def _timed(times: Dict[str, float], name: str):
    """Record a stage's duration in the run report via an obs span
    (``pipeline.<stage>``) — the pipeline never reads the clock raw."""
    with get_telemetry().span(f"pipeline.{name}") as sp:
        yield
    times[name] = sp.duration_s


@dataclasses.dataclass
class PipelineResult:
    user_emb: np.ndarray
    item_emb: np.ndarray
    user_codes: np.ndarray
    state: T.TrainState
    cfg: RankGraph2Config
    graph: GB.HeteroGraph
    tables: NeighborTables
    metrics: Dict[str, float]
    seconds: Dict[str, float]


def _strip_edge_types(g: GB.HeteroGraph, keep: Sequence[str]
                      ) -> GB.HeteroGraph:
    empty = GB.EdgeSet(np.zeros(0, np.int64), np.zeros(0, np.int64),
                       np.zeros(0, np.float32))
    return GB.HeteroGraph(
        g.n_users, g.n_items,
        ui=g.ui if "ui" in keep else empty,
        uu=g.uu if "uu" in keep else empty,
        ii=g.ii if "ii" in keep else empty,
        group1_users=g.group1_users, group1_items=g.group1_items,
        build_seconds=g.build_seconds)


def _fallback_tables(g: GB.HeteroGraph, k_imp: int, strategy: str,
                     seed: int) -> NeighborTables:
    """Table 6 alternatives: per-node neighbors by random sampling or
    top edge weight (single hop), in PPR-table format."""
    rng = np.random.default_rng(seed)
    nu, ni = g.n_users, g.n_items
    n = nu + ni
    user_nbrs = np.full((n, k_imp), -1, np.int64)
    item_nbrs = np.full((n, k_imp), -1, np.int64)

    def fill(edges, src_off, dst_off, table):
        if len(edges) == 0:
            return
        if strategy == "topweight":
            nbrs, _ = GB.padded_adjacency(edges, (nu if src_off == 0 else ni),
                                          k_imp)
            rows = np.flatnonzero((nbrs >= 0).any(axis=1))
            table[rows + src_off] = np.where(nbrs[rows] >= 0,
                                             nbrs[rows] + dst_off, -1)
        else:  # random: uniform neighbors among all edges of the node
            order = np.argsort(edges.src, kind="stable")
            s, d = edges.src[order], edges.dst[order]
            starts = np.searchsorted(s, np.arange(
                nu if src_off == 0 else ni))
            ends = np.searchsorted(s, np.arange(
                nu if src_off == 0 else ni) + 1)
            deg = ends - starts
            rows = np.flatnonzero(deg > 0)
            pick = (rng.random((len(rows), k_imp))
                    * deg[rows][:, None]).astype(np.int64)
            table[rows + src_off] = d[starts[rows][:, None] + pick] + dst_off

    fill(g.uu, 0, 0, user_nbrs)
    fill(g.ui, 0, nu, item_nbrs)
    iu = GB.EdgeSet(g.ui.dst, g.ui.src, g.ui.weight)
    fill(iu, nu, 0, user_nbrs)
    fill(g.ii, nu, nu, item_nbrs)
    return NeighborTables(user_nbrs, item_nbrs, nu, ni)


def run_pipeline(world: SyntheticWorld, cfg: RankGraph2Config, *,
                 edge_types: Sequence[str] = ("uu", "ui", "ii"),
                 neighbor_strategy: str = "ppr",
                 popbias: bool = True,
                 steps: int = 300,
                 batch_per_type: int = 128,
                 pool_size: int = 2048,
                 seed: int = 0,
                 ppr_backend: str = "numpy",
                 log_every: int = 0) -> PipelineResult:
    times: Dict[str, float] = {}
    with _timed(times, "construct"):
        g = GB.build_graph(world.day0, alpha_pop=cfg.alpha_pop if popbias
                           else 0.0, c_u=cfg.c_u, c_i=cfg.c_i,
                           k_cap=cfg.k_cap, seed=seed)
        g = _strip_edge_types(g, edge_types)

    with _timed(times, "ppr"):
        if neighbor_strategy == "ppr":
            tables = build_neighbor_tables(
                g, k_imp=cfg.k_imp, n_walks=cfg.ppr_walks,
                walk_len=cfg.ppr_len, restart=cfg.ppr_restart, seed=seed,
                backend=ppr_backend)
        else:
            tables = _fallback_tables(g, cfg.k_imp, neighbor_strategy,
                                      seed)

    # id-only batches: features live on device in a FeatureStore and the
    # jitted step gathers them; the host ships ids + masks only
    ds = EdgeDataset(g, tables, world.user_feat, world.item_feat,
                     k_train=cfg.k_train, batch_format="dedup_ids")
    state, specs, optimizer = T.init_state(jax.random.key(seed), cfg,
                                           pool_size=pool_size)
    step_fn = T.make_train_step(
        cfg, optimizer,
        features=T.make_feature_store(world.user_feat, world.item_feat))

    per_type = {et: batch_per_type for et in ("uu", "ui", "ii")
                if et in edge_types or et == "ui"}
    with _timed(times, "train"):
        m = None
        for t in range(steps):
            batch = jax.tree.map(jnp.asarray,
                                 ds.sample_batch(t, seed, per_type))
            state, m = step_fn(state, batch, jax.random.key(1000 + t))
            if log_every and t % log_every == 0:
                print(f"  step {t}: total={float(m['total']):.3f} "
                      f"infonce_ui={float(m.get('infonce_ui', 0.0)):.3f}")
        # steps=0 (embed-only runs): no train metrics, not an
        # UnboundLocalError
        metrics = {} if m is None else {k: float(v) for k, v in m.items()}

    with _timed(times, "embed"):
        from repro.core import model as M
        nu = g.n_users
        user_emb = T.embed_all(state.params, cfg, ds, node_type=M.USER,
                               ids=np.arange(nu), batch=2048)
        item_emb = T.embed_all(state.params, cfg, ds, node_type=M.ITEM,
                               ids=np.arange(nu, nu + g.n_items),
                               batch=2048)
        codes = np.asarray(RQ.assign_codes(
            state.params["rq"], jnp.asarray(user_emb), cfg.rq))

    return PipelineResult(user_emb, item_emb, codes, state, cfg, g, tables,
                          metrics, times)
