"""Co-learned residual-quantization cluster index (paper §4.4).

Two-layer residual quantization (production: 5000 x 50 = 250k clusters)
trained jointly with the graph model:

  * hard assignment (Eq. 9) with *biased code selection* (Eq. 13) that
    favors under-used codes (anti-collapse under continuous training);
  * reconstruction loss ||h - h'||^2 (Eq. 10) split VQ-VAE style:
    codebook term + commitment term;
  * contrastive loss on reconstructed embeddings (straight-through to
    the encoder; codebook learns via the reconstruction term);
  * code-balance regularizer  L_reg = p_hat . p_batch  (Eq. 11-12) with
    soft assignment p(h,C)[j] = softmax_j( zeta1 / (zeta2 + d_j) ) and a
    rolling 1000-batch empirical code histogram p_hat;
  * utilization-balancing regularizer ``l_util``: a load-balance gap
    coupling the *hard* (Eq. 9 argmin, stop-grad) batch fractions with
    the mean soft assignment, ``(K * <f_hard, p_soft_mean> - 1)/(K-1)``
    — 0 when usage is flat, -> 1 at collapse.  Unlike an entropy-max
    term (which equalizes soft mass by dragging every centroid toward
    the data mean, *hardening* argmin collapse) its gradient pushes
    over-used codes off the mass they hoard, so losers start winning;
  * per-code EMA usage counters (``RQState.usage``) tracking the
    *unbiased* argmin assignment — Eq. 13 keeps routed histograms flat
    even while argmin collapses, so routed counts cannot detect death —
    feeding a **dead-code reset** pass: codes below a usage floor are
    re-seeded from high-load clusters' residuals, deterministically
    under the repo's keyed-uniform discipline (cf. ``ppr.walk_uniforms``).

State (the rolling histograms + EMA usage) is device-resident and
carried through train_step like optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RQConfig


@dataclasses.dataclass
class RQState:
    """Ring buffers of per-batch code counts plus EMA usage, per layer."""
    hists: Tuple[jnp.ndarray, ...]     # (hist_len, n_codes_l) float32
    usage: Tuple[jnp.ndarray, ...]     # (n_codes_l,) f32 EMA batch freq
    ptr: jnp.ndarray                   # ()
    filled: jnp.ndarray                # ()


jax.tree_util.register_dataclass(
    RQState, data_fields=["hists", "usage", "ptr", "filled"],
    meta_fields=[])


def init_rq(key, cfg: RQConfig, d: int, dtype=jnp.float32
            ) -> Tuple[Dict[str, Any], Dict[str, Any], RQState]:
    keys = jax.random.split(key, len(cfg.codebook_sizes))
    books, specs = {}, {}
    for l, n in enumerate(cfg.codebook_sizes):
        # small init: residuals shrink per layer
        scale = 0.1 / (l + 1)
        books[f"layer{l}"] = jax.random.normal(keys[l], (n, d), dtype) * scale
        specs[f"layer{l}"] = ("codes", "code_dim")
    hists = tuple(jnp.zeros((cfg.hist_len, n), jnp.float32)
                  for n in cfg.codebook_sizes)
    # uniform prior: no code is born dead
    usage = tuple(jnp.full((n,), 1.0 / n, jnp.float32)
                  for n in cfg.codebook_sizes)
    state = RQState(hists, usage, jnp.zeros((), jnp.int32),
                    jnp.zeros((), jnp.int32))
    return {"codebooks": books}, {"codebooks": specs}, state


def _phat(hist: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    tot = jnp.sum(hist, axis=0)
    return (tot + eps) / (jnp.sum(tot) + eps * hist.shape[1])


def _soft_assign(dist: jnp.ndarray, zeta1: float, zeta2: float) -> jnp.ndarray:
    """Eq. 11: p[j] = softmax_j( zeta1 / (zeta2 + d_j) )."""
    return jax.nn.softmax(zeta1 / (zeta2 + dist), axis=-1)


def rq_forward(params: Dict[str, Any], state: RQState, h: jnp.ndarray,
               cfg: RQConfig, *, train: bool = True
               ) -> Dict[str, jnp.ndarray]:
    """Quantize h (B, d).  Returns codes, recon, losses and new state.

    Differentiability: code *selection* is discrete; the reconstruction
    h' = sum_l C_l[k_l] is differentiable w.r.t. the codebooks, and the
    straight-through output ``recon_st`` is differentiable w.r.t. h.
    """
    h32 = h.astype(jnp.float32)
    resid = h32
    recon = jnp.zeros_like(h32)
    codes: List[jnp.ndarray] = []
    reg_terms: List[jnp.ndarray] = []
    util_terms: List[jnp.ndarray] = []
    new_counts: List[jnp.ndarray] = []
    hard_counts: List[jnp.ndarray] = []
    books = params["codebooks"]
    biased = cfg.biased_selection and train

    for l in range(len(cfg.codebook_sizes)):
        C = books[f"layer{l}"].astype(jnp.float32)          # (n, d)
        r = jax.lax.stop_gradient(resid)
        d2 = (jnp.sum(r * r, axis=1, keepdims=True)
              - 2.0 * r @ C.T + jnp.sum(C * C, axis=1)[None, :])
        dist = jnp.sqrt(jnp.maximum(d2, 0.0) + 1e-12)       # (B, n)
        p_soft = _soft_assign(dist, cfg.zeta1, cfg.zeta2)
        phat = _phat(state.hists[l])
        k_hard = jnp.argmin(dist, axis=1)                   # Eq. 9
        if biased:
            k = jnp.argmax(p_soft / phat[None, :], axis=1)  # Eq. 13
        else:
            k = k_hard
        codes.append(k)
        sel = jnp.take(C, k, axis=0)                        # diff w.r.t. C
        recon = recon + sel
        resid = resid - sel
        # regularizer (Eq. 12): batch soft frequency . rolling histogram
        p_batch = jnp.sum(p_soft, axis=0)
        p_batch = p_batch / jnp.maximum(jnp.sum(p_batch), 1e-12)
        reg_terms.append(jnp.dot(jax.lax.stop_gradient(phat), p_batch)
                         * cfg.codebook_sizes[l])
        # utilization balance: load-balance gap between the hard (Eq. 9)
        # batch fractions and the mean soft assignment, normalized so a
        # flat codebook scores 0 and full collapse -> 1.  The hard
        # fractions carry no gradient (argmin); the soft factor does, and
        # its gradient *raises* the distance of over-used codes to the
        # batch — spreading centroids instead of crowding them onto the
        # data mean the way an entropy-max term does.
        n_l = cfg.codebook_sizes[l]
        f_hard = jnp.zeros(n_l, jnp.float32).at[k_hard].add(1.0)
        f_hard = f_hard / jnp.maximum(jnp.sum(f_hard), 1.0)
        if n_l > 1:
            p_mean = jnp.mean(p_soft, axis=0)
            p_mean = p_mean / jnp.maximum(jnp.sum(p_mean), 1e-12)
            gap = (n_l * jnp.dot(jax.lax.stop_gradient(f_hard), p_mean)
                   - 1.0) / (n_l - 1.0)
            util_terms.append(jnp.maximum(gap, 0.0))
        hard_counts.append(f_hard * h32.shape[0])
        # routed counts for the rolling histogram (Eq. 12/13 operate on
        # the selection actually taken, biased or not)
        new_counts.append(
            jnp.zeros(cfg.codebook_sizes[l], jnp.float32).at[k].add(1.0))

    # losses
    sg = jax.lax.stop_gradient
    recon_loss = jnp.mean(jnp.sum((sg(h32) - recon) ** 2, axis=1))
    commit = jnp.mean(jnp.sum((h32 - sg(recon)) ** 2, axis=1))
    l_recon = recon_loss + cfg.commit_coef * commit
    l_reg = (jnp.mean(jnp.stack(reg_terms)) if cfg.regularize
             else jnp.zeros((), jnp.float32))
    l_util = (cfg.util_coef * jnp.mean(jnp.stack(util_terms))
              if cfg.util_coef > 0 and util_terms
              else jnp.zeros((), jnp.float32))
    recon_st = h32 + sg(recon - h32)                        # encoder path

    # state update (ring buffer push + EMA usage)
    if train:
        p = state.ptr % cfg.hist_len
        hists = tuple(hh.at[p].set(c) for hh, c in zip(state.hists,
                                                       new_counts))
        # deadness tracks the *argmin* assignment: under Eq. 13 the
        # routed counts stay flat by construction even at full argmin
        # collapse, so only hard counts can detect a dead code
        B = max(h32.shape[0], 1)
        usage = tuple(
            cfg.usage_ema * u + (1.0 - cfg.usage_ema) * (c / B)
            for u, c in zip(state.usage, hard_counts))
        new_state = RQState(hists, usage, state.ptr + 1,
                            jnp.minimum(state.filled + 1, cfg.hist_len))
    else:
        new_state = state

    return dict(codes=jnp.stack(codes, axis=1),             # (B, L)
                recon=recon, recon_st=recon_st.astype(h.dtype),
                l_recon=l_recon, l_reg=l_reg, l_util=l_util,
                state=new_state)


def assign_codes(params: Dict[str, Any], h: jnp.ndarray,
                 cfg: RQConfig) -> jnp.ndarray:
    """Inference-time hard assignment (Eq. 9).  (B,) flat cluster ids."""
    resid = h.astype(jnp.float32)
    flat = jnp.zeros(h.shape[0], jnp.int32)
    for l in range(len(cfg.codebook_sizes)):
        C = params["codebooks"][f"layer{l}"].astype(jnp.float32)
        d2 = (jnp.sum(resid * resid, axis=1, keepdims=True)
              - 2.0 * resid @ C.T + jnp.sum(C * C, axis=1)[None, :])
        k = jnp.argmin(d2, axis=1)
        resid = resid - jnp.take(C, k, axis=0)
        flat = flat * cfg.codebook_sizes[l] + k.astype(jnp.int32)
    return flat


def codebook_utilization(state: RQState) -> List[float]:
    """Fraction of codes used at least once in the rolling window —
    the paper's collapse diagnostic (100% with regularization)."""
    out = []
    for hist in state.hists:
        tot = jnp.sum(hist, axis=0)
        out.append(float(jnp.mean((tot > 0).astype(jnp.float32))))
    return out


def codes_utilization(codes, codebook_sizes) -> List[float]:
    """``codebook_utilization`` measured on actual assignments: fraction
    of each layer's codebook hit at least once by ``codes`` ``(N, L)``.
    This is what the publication gate floors — a collapsed layer shows
    up as ~``1/size`` no matter how healthy the training-window
    histogram once looked.

    Edge cases are first-class (mirroring the ``build_i2i_knn`` n<=1
    fix): an empty corpus yields exactly 0.0 per layer, a 1-D ``codes``
    vector is treated as single-layer ``(N, 1)``, and degenerate
    ``codebook_sizes`` entries (< 1) yield 0.0 instead of dividing by
    zero.  Values are always in ``[0, 1]`` and are 0 only when no code
    of that layer is used at all.
    """
    codes = np.asarray(codes)
    if codes.ndim == 1:
        codes = codes[:, None]
    out = []
    for l, size in enumerate(codebook_sizes):
        if size < 1 or len(codes) == 0:
            out.append(0.0)
            continue
        used = np.unique(codes[:, l])
        out.append(min(float(len(used)) / float(size), 1.0))
    return out


def per_code_counts(codes, codebook_sizes) -> List[np.ndarray]:
    """Per-layer code occupancy of ``codes`` ``(N, L)``: how many rows
    land on each code.  The corpus-side usage signal the repair path
    feeds to ``dead_code_reset`` (EMA usage can look healthy long after
    the published assignments collapsed)."""
    codes = np.asarray(codes)
    if codes.ndim == 1:
        codes = codes[:, None]
    out = []
    for l, size in enumerate(codebook_sizes):
        if size < 1:
            out.append(np.zeros(0, np.float32))
        elif len(codes) == 0:
            out.append(np.zeros(size, np.float32))
        else:
            out.append(np.bincount(codes[:, l].astype(np.int64),
                                   minlength=size).astype(np.float32))
    return out


def dead_code_reset(params: Dict[str, Any], state: RQState,
                    h: np.ndarray, cfg: RQConfig, *, seed: int,
                    step: int = 0, usage=None
                    ) -> Tuple[Dict[str, Any], RQState, Dict[str, int]]:
    """Re-seed dead codes from high-load clusters' residuals.

    A code of layer ``l`` is *dead* when its usage share falls below
    ``cfg.dead_floor / n_codes_l``.  Usage defaults to the EMA counters
    carried in ``state``; the repair path overrides it with the
    published corpus occupancy (``per_code_counts``), which is what
    actually collapsed.  Each dead code is re-seeded at the layer-``l``
    residual of a member of a high-load (donor) cluster — donors are
    cycled in usage-descending order, the member pick and a tiny
    de-duplicating jitter are drawn from ``default_rng((seed, step, l,
    code))``, the same keyed-uniform discipline as ``walk_uniforms`` /
    ``hub_uniforms``, so the pass is bit-deterministic and independent
    of probe chunking.

    Guarantees: live rows are bit-unchanged, so with the pre-reset
    residuals any assignment that moves can only move *to* a revived
    code (the intended split of an overloaded cluster) — members are
    never reshuffled between two live codes by the reset itself.
    Revived codes' EMA usage restarts at the live mean (not instantly
    dead again); their rolling-histogram columns stay ~0, so Eq. 13
    biased selection immediately favors routing traffic into them.

    Returns ``(new_params, new_state, report)`` with
    ``report['reset_layer{l}']`` = number of codes re-seeded.
    """
    h = np.asarray(h, np.float32)
    L = len(cfg.codebook_sizes)
    books = [np.array(params["codebooks"][f"layer{l}"], np.float32)
             for l in range(L)]

    def _argmin(resid: np.ndarray, C: np.ndarray) -> np.ndarray:
        if not len(resid):
            return np.zeros(0, np.int64)
        d2 = (np.sum(resid * resid, axis=1, keepdims=True)
              - 2.0 * resid @ C.T + np.sum(C * C, axis=1)[None, :])
        return d2.argmin(axis=1)

    usage_in = usage if usage is not None else state.usage
    report: Dict[str, int] = {}
    new_usage: List[np.ndarray] = []
    # the eval-mode (Eq. 9) residual cascade is recomputed layer by
    # layer *after* each layer's reseed: a revived coarse code changes
    # the residuals the next layer quantizes, and seeding layer l+1
    # from pre-reset residuals would plant rows the published cascade
    # never produces
    resid = h.copy()
    for l in range(L):
        K = cfg.codebook_sizes[l]
        u = np.asarray(usage_in[l], np.float32).copy()
        u = u / max(float(u.sum()), 1e-12)
        dead = np.flatnonzero(u < cfg.dead_floor / K)
        live = np.flatnonzero(u >= cfg.dead_floor / K)
        if len(dead) == 0 or len(live) == 0 or len(h) == 0:
            report[f"reset_layer{l}"] = 0
            new_usage.append(u)
            resid = resid - books[l][_argmin(resid, books[l])]
            continue
        # donors: live codes, heaviest first (stable ties by index)
        donors = live[np.argsort(-u[live], kind="stable")]
        a = _argmin(resid, books[l])       # pre-reset donor membership
        rms = float(np.sqrt(np.mean(resid * resid))) or 1.0
        for j_i, j in enumerate(np.sort(dead)):
            donor = int(donors[j_i % len(donors)])
            members = np.flatnonzero(a == donor)
            pool = members if len(members) else np.arange(len(resid))
            rng = np.random.default_rng((seed, step, l, int(j)))
            pick = int(pool[min(int(rng.random() * len(pool)),
                                len(pool) - 1)])
            jitter = rng.normal(size=resid.shape[1]).astype(np.float32)
            books[l][j] = resid[pick] + jitter * (1e-3 * rms)
        u[dead] = float(u[live].mean())
        new_usage.append(u / max(float(u.sum()), 1e-12))
        report[f"reset_layer{l}"] = int(len(dead))
        resid = resid - books[l][_argmin(resid, books[l])]

    new_params = dict(params)
    new_params["codebooks"] = {
        f"layer{l}": jnp.asarray(books[l]) for l in range(L)}
    new_state = RQState(state.hists,
                        tuple(jnp.asarray(u) for u in new_usage),
                        state.ptr, state.filled)
    return new_params, new_state, report


def reconstruct(params: Dict[str, Any], codes: jnp.ndarray,
                cfg: RQConfig) -> jnp.ndarray:
    """codes (B, L) -> reconstructed embeddings (Eq. 10)."""
    out = None
    for l in range(len(cfg.codebook_sizes)):
        sel = jnp.take(params["codebooks"][f"layer{l}"], codes[:, l], axis=0)
        out = sel if out is None else out + sel
    return out
