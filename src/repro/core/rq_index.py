"""Co-learned residual-quantization cluster index (paper §4.4).

Two-layer residual quantization (production: 5000 x 50 = 250k clusters)
trained jointly with the graph model:

  * hard assignment (Eq. 9) with *biased code selection* (Eq. 13) that
    favors under-used codes (anti-collapse under continuous training);
  * reconstruction loss ||h - h'||^2 (Eq. 10) split VQ-VAE style:
    codebook term + commitment term;
  * contrastive loss on reconstructed embeddings (straight-through to
    the encoder; codebook learns via the reconstruction term);
  * code-balance regularizer  L_reg = p_hat . p_batch  (Eq. 11-12) with
    soft assignment p(h,C)[j] = softmax_j( zeta1 / (zeta2 + d_j) ) and a
    rolling 1000-batch empirical code histogram p_hat.

State (the rolling histograms) is device-resident and carried through
train_step like optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RQConfig


@dataclasses.dataclass
class RQState:
    """Ring buffers of per-batch code counts, one per codebook layer."""
    hists: Tuple[jnp.ndarray, ...]     # (hist_len, n_codes_l) float32
    ptr: jnp.ndarray                   # ()
    filled: jnp.ndarray                # ()


jax.tree_util.register_dataclass(
    RQState, data_fields=["hists", "ptr", "filled"], meta_fields=[])


def init_rq(key, cfg: RQConfig, d: int, dtype=jnp.float32
            ) -> Tuple[Dict[str, Any], Dict[str, Any], RQState]:
    keys = jax.random.split(key, len(cfg.codebook_sizes))
    books, specs = {}, {}
    for l, n in enumerate(cfg.codebook_sizes):
        # small init: residuals shrink per layer
        scale = 0.1 / (l + 1)
        books[f"layer{l}"] = jax.random.normal(keys[l], (n, d), dtype) * scale
        specs[f"layer{l}"] = ("codes", "code_dim")
    hists = tuple(jnp.zeros((cfg.hist_len, n), jnp.float32)
                  for n in cfg.codebook_sizes)
    state = RQState(hists, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    return {"codebooks": books}, {"codebooks": specs}, state


def _phat(hist: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    tot = jnp.sum(hist, axis=0)
    return (tot + eps) / (jnp.sum(tot) + eps * hist.shape[1])


def _soft_assign(dist: jnp.ndarray, zeta1: float, zeta2: float) -> jnp.ndarray:
    """Eq. 11: p[j] = softmax_j( zeta1 / (zeta2 + d_j) )."""
    return jax.nn.softmax(zeta1 / (zeta2 + dist), axis=-1)


def rq_forward(params: Dict[str, Any], state: RQState, h: jnp.ndarray,
               cfg: RQConfig, *, train: bool = True
               ) -> Dict[str, jnp.ndarray]:
    """Quantize h (B, d).  Returns codes, recon, losses and new state.

    Differentiability: code *selection* is discrete; the reconstruction
    h' = sum_l C_l[k_l] is differentiable w.r.t. the codebooks, and the
    straight-through output ``recon_st`` is differentiable w.r.t. h.
    """
    h32 = h.astype(jnp.float32)
    resid = h32
    recon = jnp.zeros_like(h32)
    codes: List[jnp.ndarray] = []
    reg_terms: List[jnp.ndarray] = []
    new_counts: List[jnp.ndarray] = []
    books = params["codebooks"]
    biased = cfg.biased_selection and train

    for l in range(len(cfg.codebook_sizes)):
        C = books[f"layer{l}"].astype(jnp.float32)          # (n, d)
        r = jax.lax.stop_gradient(resid)
        d2 = (jnp.sum(r * r, axis=1, keepdims=True)
              - 2.0 * r @ C.T + jnp.sum(C * C, axis=1)[None, :])
        dist = jnp.sqrt(jnp.maximum(d2, 0.0) + 1e-12)       # (B, n)
        p_soft = _soft_assign(dist, cfg.zeta1, cfg.zeta2)
        phat = _phat(state.hists[l])
        if biased:
            k = jnp.argmax(p_soft / phat[None, :], axis=1)  # Eq. 13
        else:
            k = jnp.argmin(dist, axis=1)                    # Eq. 9
        codes.append(k)
        sel = jnp.take(C, k, axis=0)                        # diff w.r.t. C
        recon = recon + sel
        resid = resid - sel
        # regularizer (Eq. 12): batch soft frequency . rolling histogram
        p_batch = jnp.sum(p_soft, axis=0)
        p_batch = p_batch / jnp.maximum(jnp.sum(p_batch), 1e-12)
        reg_terms.append(jnp.dot(jax.lax.stop_gradient(phat), p_batch)
                         * cfg.codebook_sizes[l])
        # hard counts for the rolling histogram
        new_counts.append(
            jnp.zeros(cfg.codebook_sizes[l], jnp.float32).at[k].add(1.0))

    # losses
    sg = jax.lax.stop_gradient
    recon_loss = jnp.mean(jnp.sum((sg(h32) - recon) ** 2, axis=1))
    commit = jnp.mean(jnp.sum((h32 - sg(recon)) ** 2, axis=1))
    l_recon = recon_loss + cfg.commit_coef * commit
    l_reg = (jnp.mean(jnp.stack(reg_terms)) if cfg.regularize
             else jnp.zeros((), jnp.float32))
    recon_st = h32 + sg(recon - h32)                        # encoder path

    # state update (ring buffer push)
    if train:
        p = state.ptr % cfg.hist_len
        hists = tuple(hh.at[p].set(c) for hh, c in zip(state.hists,
                                                       new_counts))
        new_state = RQState(hists, state.ptr + 1,
                            jnp.minimum(state.filled + 1, cfg.hist_len))
    else:
        new_state = state

    return dict(codes=jnp.stack(codes, axis=1),             # (B, L)
                recon=recon, recon_st=recon_st.astype(h.dtype),
                l_recon=l_recon, l_reg=l_reg, state=new_state)


def assign_codes(params: Dict[str, Any], h: jnp.ndarray,
                 cfg: RQConfig) -> jnp.ndarray:
    """Inference-time hard assignment (Eq. 9).  (B,) flat cluster ids."""
    resid = h.astype(jnp.float32)
    flat = jnp.zeros(h.shape[0], jnp.int32)
    for l in range(len(cfg.codebook_sizes)):
        C = params["codebooks"][f"layer{l}"].astype(jnp.float32)
        d2 = (jnp.sum(resid * resid, axis=1, keepdims=True)
              - 2.0 * resid @ C.T + jnp.sum(C * C, axis=1)[None, :])
        k = jnp.argmin(d2, axis=1)
        resid = resid - jnp.take(C, k, axis=0)
        flat = flat * cfg.codebook_sizes[l] + k.astype(jnp.int32)
    return flat


def codebook_utilization(state: RQState) -> List[float]:
    """Fraction of codes used at least once in the rolling window —
    the paper's collapse diagnostic (100% with regularization)."""
    out = []
    for hist in state.hists:
        tot = jnp.sum(hist, axis=0)
        out.append(float(jnp.mean((tot > 0).astype(jnp.float32))))
    return out


def codes_utilization(codes, codebook_sizes) -> List[float]:
    """``codebook_utilization`` measured on actual assignments: fraction
    of each layer's codebook hit at least once by ``codes`` ``(N, L)``.
    This is what the publication gate floors — a collapsed layer shows
    up as ~``1/size`` no matter how healthy the training-window
    histogram once looked."""
    codes = np.asarray(codes)
    out = []
    for l, size in enumerate(codebook_sizes):
        used = np.unique(codes[:, l]) if len(codes) else np.zeros(0)
        out.append(float(len(used)) / float(size))
    return out


def reconstruct(params: Dict[str, Any], codes: jnp.ndarray,
                cfg: RQConfig) -> jnp.ndarray:
    """codes (B, L) -> reconstructed embeddings (Eq. 10)."""
    out = None
    for l in range(len(cfg.codebook_sizes)):
        sel = jnp.take(params["codebooks"][f"layer{l}"], codes[:, l], axis=0)
        out = sel if out is None else out + sel
    return out
