"""RankGraph-2 training loop (paper §4.3 + §4.4 co-learning).

One jit'd ``train_step`` consumes an edge-centric batch (all edge types),
computes per-type contrastive losses in both U-I directions, co-learns
the RQ index (reconstruction + contrastive-on-recon + balance
regularizer) and combines everything with learned uncertainty weights.
State (params, optimizer, RQ histograms, negative pool) is one pytree —
checkpointable and donated into the step.

Two batch layouts are supported (see ``data.edge_dataset``):

* **legacy** — per-(edge_type, side) feature tensors; each endpoint
  occurrence is re-encoded (the PR-3 reference path);
* **dedup / dedup_ids** — packed unique-node sub-batches per node type:
  every referenced node (endpoint *or* sampled neighbor) runs through
  the type encoder exactly once, endpoints are aggregated once, and
  per-edge heads/primaries are pure gathers.  With ``dedup_ids`` the
  batch is id-only and raw features are gathered inside the jitted step
  from a device-resident ``FeatureStore`` — the host ships int32 ids
  and masks instead of (B, K, d) float32 neighbor features.

Both layouts produce the same losses (up to float reduction order) on
the same edge draws; ``EdgeDataset.expand_batch`` materializes the
legacy view of a dedup batch for the equivalence tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RankGraph2Config
from repro.core import losses as L
from repro.core import model as M
from repro.core import negatives as N
from repro.core import rq_index as RQ
from repro.distributed.sharding import ShardingCtx, NULL_CTX
from repro.optim import optimizers as opt_lib


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    rq_state: RQ.RQState
    pool: N.NegPoolState
    step: jnp.ndarray


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt_state", "rq_state", "pool",
                             "step"], meta_fields=[])


@dataclasses.dataclass(frozen=True)
class FeatureStore:
    """Device-resident raw feature tables for id-only batches.

    Registered once and closed over by the jitted step: XLA keeps the
    tables on device, so per-step host->device traffic is just the id /
    mask integers of the batch."""
    user_feat: jnp.ndarray     # (n_users, d_user_feat) float32
    item_feat: jnp.ndarray     # (n_items, d_item_feat) float32


def make_feature_store(user_feat: np.ndarray, item_feat: np.ndarray
                       ) -> FeatureStore:
    return FeatureStore(jnp.asarray(user_feat, jnp.float32),
                        jnp.asarray(item_feat, jnp.float32))


def init_state(key, cfg: RankGraph2Config, *, pool_size: int = 8192,
               optimizer: Optional[opt_lib.Optimizer] = None
               ) -> Tuple[TrainState, Any, opt_lib.Optimizer]:
    k1, k2 = jax.random.split(key)
    params, specs = M.init_params(k1, cfg)
    rq_params, rq_specs, rq_state = RQ.init_rq(k2, cfg.rq, cfg.d_embed)
    params["rq"] = rq_params
    specs["rq"] = rq_specs
    params["uncertainty"] = L.init_uncertainty()
    specs["uncertainty"] = {k: None for k in params["uncertainty"]}
    optimizer = optimizer or opt_lib.rankgraph2_optimizer()
    opt_state = optimizer.init(params)
    pool = N.init_pool(pool_size, cfg.d_embed)
    state = TrainState(params, opt_state, rq_state, pool,
                       jnp.zeros((), jnp.int32))
    # the step is donated: jax's constant cache can alias identical
    # zero-init leaves and XLA rejects donating one buffer twice, so
    # give every leaf its own buffer once at init
    return jax.tree.map(jnp.copy, state), specs, optimizer


# edge type -> (src node type, dst node type)
_ET_TYPES = {"uu": (M.USER, M.USER), "ui": (M.USER, M.ITEM),
             "ii": (M.ITEM, M.ITEM)}
_NODE_TYPES = (("user", M.USER), ("item", M.ITEM))


def _dedup_per_type(params, cfg: RankGraph2Config, batch,
                    ctx: ShardingCtx, features: Optional[FeatureStore]):
    """Unique-node forward: encode each pack row once, aggregate each
    endpoint-unique node once, gather per-(edge_type, side) views.

    Returns {et: (src_heads, src_prim, dst_heads, dst_prim)} exactly as
    the legacy per-endpoint forward would."""
    nodes, edges = batch["nodes"], batch["edges"]
    enc: Dict[str, jnp.ndarray] = {}
    for tname, ntype in _NODE_TYPES:
        side = nodes[tname]
        if "feat" in side:
            feat = side["feat"]
        else:
            if features is None:
                raise ValueError(
                    "id-only batch but no FeatureStore; pass features= "
                    "to make_train_step / make_eval_step")
            table = (features.user_feat if ntype == M.USER
                     else features.item_feat)
            feat = jnp.take(table, side["ids"], axis=0)
        enc[tname] = M.encode_nodes(params, cfg, ntype, feat, ctx)

    heads, prims = {}, {}
    for tname, ntype in _NODE_TYPES:
        side = nodes[tname]
        e_pad = side["unbr_idx"].shape[0]    # endpoint-unique rows first
        h = M.aggregate_nodes(
            params, cfg, ntype, enc[tname][:e_pad],
            jnp.take(enc["user"], side["unbr_idx"], axis=0),
            side["unbr_mask"],
            jnp.take(enc["item"], side["inbr_idx"], axis=0),
            side["inbr_mask"], ctx)
        heads[tname] = h
        prims[tname] = M.primary_embedding(h)

    per_type = {}
    for et, e in edges.items():
        st, dt = _ET_TYPES[et]
        sn = "user" if st == M.USER else "item"
        dn = "user" if dt == M.USER else "item"
        per_type[et] = (jnp.take(heads[sn], e["src_map"], axis=0),
                        jnp.take(prims[sn], e["src_map"], axis=0),
                        jnp.take(heads[dn], e["dst_map"], axis=0),
                        jnp.take(prims[dn], e["dst_map"], axis=0))
    return per_type


def _forward_losses(params, cfg: RankGraph2Config, batch, pool, rq_state,
                    key, ctx: ShardingCtx, train: bool,
                    features: Optional[FeatureStore] = None):
    """Returns (task_losses, aux) where aux carries pool/rq updates."""
    tasks: Dict[str, jnp.ndarray] = {}

    if "nodes" in batch:   # dedup layout
        per_type = _dedup_per_type(params, cfg, batch, ctx, features)
    else:                  # legacy layout: re-encode every endpoint
        per_type = {}
        for et, sub in batch.items():
            st, dt = _ET_TYPES[et]
            src_heads, src_prim = M.embed_side(params, cfg, sub["src"],
                                               st, ctx)
            dst_heads, dst_prim = M.embed_side(params, cfg, sub["dst"],
                                               dt, ctx)
            per_type[et] = (src_heads, src_prim, dst_heads, dst_prim)

    user_embs, item_embs = [], []
    endpoint_prims, endpoint_splits = [], []
    for et, (sh, sp, dh, dp) in per_type.items():
        st, dt = _ET_TYPES[et]
        (user_embs if st == M.USER else item_embs).append(sp)
        (user_embs if dt == M.USER else item_embs).append(dp)
        endpoint_prims += [sp, dp]
        endpoint_splits += [(et, "src"), (et, "dst")]

    dp_size = ctx.axis_size("batch")

    def _neg(k, prim, heads, node_type):
        buf = pool.user if node_type == M.USER else pool.item
        fill = pool.user_fill if node_type == M.USER else pool.item_fill
        blk = prim.shape[0] // dp_size if dp_size > 1 and \
            prim.shape[0] % dp_size == 0 else 0
        return N.sample_negatives(k, prim, heads, buf, fill,
                                  cfg.n_negatives, cfg.n_pool_neg,
                                  shard_block=blk)

    def _pair(src, dst, negs):
        return L.pair_losses(src, dst, negs, margin=cfg.margin,
                             tau=cfg.tau,
                             use_kernel=cfg.use_fused_contrastive)

    keys = jax.random.split(key, 8)
    ki = 0
    loss_dirs = []   # (task_suffix, src_prim, dst_prim, dst_heads, dst_type)
    for et, (sh, sp, dh, dp) in per_type.items():
        st, dt = _ET_TYPES[et]
        loss_dirs.append((et, sp, dp, dh, dt))
        if et == "ui":  # bidirectional U-I (paper computes L_UI and L_IU)
            loss_dirs.append(("iu", dp, sp, sh, st))

    dir_negs = {}
    for suffix, sp_, dp_, dh_, dt_ in loss_dirs:
        negs = _neg(keys[ki], dp_, dh_, dt_)
        ki += 1
        dir_negs[suffix] = negs
        marg, info = _pair(sp_, dp_, negs)
        tasks[f"margin_{suffix}"] = jnp.mean(marg)
        tasks[f"infonce_{suffix}"] = jnp.mean(info)

    # --- RQ co-learning on all endpoint embeddings -------------------------
    all_prim = jnp.concatenate(endpoint_prims, axis=0)
    rq_out = RQ.rq_forward(params["rq"], rq_state, all_prim, cfg.rq,
                           train=train)
    tasks["rq_recon"] = rq_out["l_recon"]
    tasks["rq_reg"] = rq_out["l_reg"]
    if cfg.rq.util_coef > 0:
        # utilization balance rides as its own uncertainty-weighted task
        # (a constant-zero task would drive its learned log-var to -inf)
        tasks["rq_util"] = rq_out["l_util"]
    # contrastive on reconstructed embeddings (L'): recompute the positive
    # pair similarity with straight-through recon endpoints.
    recon_st = rq_out["recon_st"]
    sizes = [p.shape[0] for p in endpoint_prims]
    offs = np.cumsum([0] + sizes)
    recon_parts = {}
    for (et, side), lo, hi in zip(endpoint_splits, offs[:-1], offs[1:]):
        recon_parts[(et, side)] = recon_st[lo:hi]
    lprime = []
    for et, (sh, sp, dh, dp) in per_type.items():
        st, dt = _ET_TYPES[et]
        rs = recon_parts[(et, "src")]
        rd = recon_parts[(et, "dst")]
        # the per-direction negative bank is i.i.d. of the recon
        # endpoints — reuse it for L' instead of a second pool gather
        # (reuse_lprime_negatives=False restores the PR-3 double draw)
        if cfg.reuse_lprime_negatives:
            negs = dir_negs[et]
        else:
            negs = _neg(keys[ki], dp, dh, dt)
            ki += 1
        marg, info = _pair(rs, rd, negs)
        lprime.append(jnp.mean(0.5 * marg + 0.5 * info))
    tasks["rq_contrastive"] = jnp.mean(jnp.stack(lprime))

    aux = dict(rq_state=rq_out["state"],
               user_emb=jnp.concatenate(user_embs, axis=0)
               if user_embs else None,
               item_emb=jnp.concatenate(item_embs, axis=0)
               if item_embs else None,
               codes=rq_out["codes"])
    return tasks, aux


def make_train_step(cfg: RankGraph2Config, optimizer: opt_lib.Optimizer,
                    ctx: ShardingCtx = NULL_CTX, *,
                    grad_clip: float = 1.0,
                    features: Optional[FeatureStore] = None,
                    jit: bool = True, donate: bool = True):
    """Builds train_step(state, batch, key) -> (state, metrics).

    By default the step comes back jitted with ``donate_argnums=0`` —
    the incoming ``TrainState`` buffers are reused for the outgoing
    state, halving peak state memory.  Callers that lower/compile the
    raw function themselves (dry-run, roofline) pass ``jit=False``.
    ``features`` supplies the device-resident ``FeatureStore`` required
    by id-only (``dedup_ids``) batches.
    """

    def train_step(state: TrainState, batch, key):
        def loss_fn(params):
            tasks, aux = _forward_losses(params, cfg, batch, state.pool,
                                         state.rq_state, key, ctx, True,
                                         features)
            total = L.uncertainty_combine(tasks, params["uncertainty"])
            return total, (tasks, aux)

        (total, (tasks, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        grads, gnorm = opt_lib.clip_by_global_norm(grads, grad_clip)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = opt_lib.apply_updates(state.params, updates)
        pool = N.update_pool(state.pool, aux["user_emb"], aux["item_emb"])
        new_state = TrainState(params, opt_state, aux["rq_state"], pool,
                               state.step + 1)
        metrics = {k: v for k, v in tasks.items()}
        metrics["total"] = total
        metrics["grad_norm"] = gnorm
        return new_state, metrics

    if not jit:
        return train_step
    return jax.jit(train_step, donate_argnums=(0,) if donate else ())


def make_eval_step(cfg: RankGraph2Config, ctx: ShardingCtx = NULL_CTX, *,
                   features: Optional[FeatureStore] = None):
    def eval_step(state: TrainState, batch, key):
        tasks, _ = _forward_losses(state.params, cfg, batch, state.pool,
                                   state.rq_state, key, ctx, False,
                                   features)
        return tasks

    return eval_step


# ---------------------------------------------------------------------------
# self-healing: dead-code reset over the whole TrainState
# ---------------------------------------------------------------------------

def reset_dead_codes(state: TrainState, probe_emb: np.ndarray,
                     cfg: RankGraph2Config, *, seed: int, step: int = 0,
                     usage=None) -> Tuple[TrainState, Dict[str, int]]:
    """Run ``rq_index.dead_code_reset`` against a TrainState.

    Host-side and functional: only the dead codebook rows and the RQ
    usage counters change, the rest of the state (optimizer moments,
    histograms, pool, step) is carried through untouched, so the
    donated jitted step keeps its compiled trace.  ``probe_emb`` is a
    (P, d_embed) sample of current embeddings supplying the donor
    residuals; ``usage`` optionally overrides the EMA counters with
    published corpus occupancy (the repair path).
    """
    new_rq, new_rq_state, report = RQ.dead_code_reset(
        state.params["rq"], state.rq_state, probe_emb, cfg.rq,
        seed=seed, step=step, usage=usage)
    params = dict(state.params)
    params["rq"] = new_rq
    return (TrainState(params, state.opt_state, new_rq_state,
                       state.pool, state.step), report)


# ---------------------------------------------------------------------------
# embedding generation (paper: embeddings regenerated after each rebuild)
# ---------------------------------------------------------------------------

def embed_all(params, cfg: RankGraph2Config, dataset, *, node_type: int,
              ids: np.ndarray, batch: int = 4096,
              ctx: ShardingCtx = NULL_CTX) -> np.ndarray:
    """Generate primary embeddings for nodes (global ids)."""
    fn = jax.jit(functools.partial(_embed_batch, cfg=cfg,
                                   node_type=node_type, ctx=ctx))
    out = []
    for lo in range(0, len(ids), batch):
        chunk = ids[lo:lo + batch]
        # always pad to the fixed batch size: a ragged tail (or a corpus
        # smaller than one batch) would otherwise retrace per size
        pad = batch - len(chunk)
        if pad:
            chunk = np.r_[chunk, np.repeat(chunk[-1:], pad)]
        side = dataset.node_inference_batch(chunk)
        emb = np.asarray(fn(params, {k: jnp.asarray(v)
                                     for k, v in side.items()}))
        out.append(emb[: len(emb) - pad] if pad else emb)
    return np.concatenate(out, axis=0)


def _embed_batch(params, side, *, cfg, node_type, ctx):
    _, prim = M.embed_side(params, cfg, side, node_type, ctx)
    return prim
