"""RankGraph-2 graph construction (paper §4.2).

Offline pipeline (numpy): engagement log -> heterogeneous co-engagement
graph with U-I / U-U / I-I edges (Eq. 1-2), popularity bias correction on
I-I edges (Eq. 3), per-node top-K edge subsampling, backbone/extended
split (Group 1 / Group 2).  Hour-level rebuild in production maps to
"re-run build() on the trailing window"; `benchmarks/graph_build_scaling`
measures throughput to back the paper's <=1h claim by extrapolation.

Everything here is vectorized numpy — this stage is explicitly *not* on
the accelerator (the paper's point: no online graph infra; construction
is a batch job).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

# engagement type -> business-value weight (paper: "predefined values
# that reflect business value")
DEFAULT_EVENT_WEIGHTS = {0: 1.0, 1: 2.0, 2: 3.0, 3: 5.0}  # click/like/share/buy


@dataclasses.dataclass
class EngagementLog:
    """Columnar interaction log D = {(user, item, interaction, ts)}."""
    user_id: np.ndarray      # int64 [n]
    item_id: np.ndarray      # int64 [n]
    event_type: np.ndarray   # int32 [n]
    timestamp: np.ndarray    # float64 [n] (seconds)
    n_users: int
    n_items: int

    def window(self, t_end: float, horizon_s: float) -> "EngagementLog":
        m = (self.timestamp <= t_end) & (self.timestamp > t_end - horizon_s)
        return EngagementLog(self.user_id[m], self.item_id[m],
                             self.event_type[m], self.timestamp[m],
                             self.n_users, self.n_items)


@dataclasses.dataclass
class EdgeSet:
    """Directed weighted edges of one type."""
    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray

    def __len__(self) -> int:
        return len(self.src)


@dataclasses.dataclass
class HeteroGraph:
    n_users: int
    n_items: int
    ui: EdgeSet                  # user -> item
    uu: EdgeSet                  # user -> user (both directions present)
    ii: EdgeSet                  # item -> item (both directions present)
    group1_users: np.ndarray     # bool [n_users]: has same-type neighbors
    group1_items: np.ndarray     # bool [n_items]
    build_seconds: float = 0.0

    @property
    def n_edges(self) -> int:
        return len(self.ui) + len(self.uu) + len(self.ii)


# ---------------------------------------------------------------------------
# U-I edges
# ---------------------------------------------------------------------------

def build_ui_edges(log: EngagementLog,
                   event_weights: Optional[Dict[int, float]] = None
                   ) -> EdgeSet:
    """Aggregate engagement events into weighted U-I edges."""
    ew = event_weights or DEFAULT_EVENT_WEIGHTS
    wtab = np.zeros(max(ew) + 1, np.float64)
    for k, v in ew.items():
        wtab[k] = v
    w = wtab[np.clip(log.event_type, 0, len(wtab) - 1)]
    key = log.user_id.astype(np.int64) * log.n_items + log.item_id
    uniq, inv = np.unique(key, return_inverse=True)
    agg = np.zeros(len(uniq), np.float64)
    np.add.at(agg, inv, w)
    return EdgeSet(src=(uniq // log.n_items).astype(np.int64),
                   dst=(uniq % log.n_items).astype(np.int64),
                   weight=agg.astype(np.float32))


# ---------------------------------------------------------------------------
# co-engagement edges (Eq. 1 / Eq. 2)
# ---------------------------------------------------------------------------

def _co_engagement(anchor: np.ndarray, other: np.ndarray, w: np.ndarray,
                   n_other: int, min_common: int, hub_cap: int,
                   rng: np.random.Generator
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pairs of ``other`` nodes co-engaged via the same ``anchor`` node.

    For U-U edges: anchor=item, other=user.  For I-I: anchor=user,
    other=item.  ``hub_cap`` caps the fan-out per anchor (the paper's
    defence against hundreds-of-trillions of raw pairs: popular anchors
    contribute a bounded sample of pairs; with bias correction +
    top-K subsampling this preserves retrieval-relevant structure).

    Returns (src, dst, weight) of *undirected* co-edges with
    weight = ln(sum_e w_src,e * w_dst,e) and |common| >= min_common.
    """
    order = np.argsort(anchor, kind="stable")
    a, o, ww = anchor[order], other[order], w[order]
    # segment boundaries per anchor
    starts = np.flatnonzero(np.r_[True, a[1:] != a[:-1]])
    ends = np.r_[starts[1:], len(a)]
    lens = ends - starts
    keep = lens >= 2
    starts, ends, lens = starts[keep], ends[keep], lens[keep]
    if len(starts) == 0:
        z = np.zeros(0)
        return z.astype(np.int64), z.astype(np.int64), z.astype(np.float32)
    cap = hub_cap
    # pad each anchor's engagers to a (n_anchor, cap) matrix (random subset
    # for anchors above cap)
    nseg = len(starts)
    mat = np.full((nseg, cap), -1, np.int64)
    wmat = np.zeros((nseg, cap), np.float64)
    clens = np.minimum(lens, cap)
    # vectorized gather: column j of row r takes element starts[r]+pick[r,j]
    pick = np.arange(cap)[None, :].repeat(nseg, 0)
    big = lens > cap
    if big.any():
        # random offsets (w/ replacement) for hub anchors; duplicates only
        # shrink the sample slightly -- this is a subsample step anyway.
        offs = (rng.random((int(big.sum()), cap)) * lens[big][:, None]
                ).astype(np.int64)
        pick[big] = offs
    valid = pick < lens[:, None]
    idx = np.minimum(starts[:, None] + pick, len(a) - 1)
    mat = np.where(valid, o[idx], -1)
    wmat = np.where(valid, ww[idx], 0.0)
    # all within-row pairs
    iu, ju = np.triu_indices(cap, k=1)
    s = mat[:, iu].ravel()
    d = mat[:, ju].ravel()
    pw = (wmat[:, iu] * wmat[:, ju]).ravel()
    m = (s >= 0) & (d >= 0) & (s != d)
    s, d, pw = s[m], d[m], pw[m]
    # canonical order for undirected aggregation
    lo = np.minimum(s, d)
    hi = np.maximum(s, d)
    key = lo * n_other + hi
    uniq, inv, cnt = np.unique(key, return_inverse=True, return_counts=True)
    wsum = np.zeros(len(uniq), np.float64)
    np.add.at(wsum, inv, pw)
    ok = cnt >= min_common
    uniq, wsum = uniq[ok], wsum[ok]
    lo = (uniq // n_other).astype(np.int64)
    hi = (uniq % n_other).astype(np.int64)
    wlog = np.log(np.maximum(wsum, 1e-12)).astype(np.float32)
    # Eq.1/2: w = ln(sum w*w); clamp at small positive so weights stay usable
    wlog = np.maximum(wlog, 1e-3)
    return lo, hi, wlog


def build_uu_edges(ui: EdgeSet, n_users: int, *, min_common: int = 2,
                   hub_cap: int = 32, rng=None) -> EdgeSet:
    rng = rng or np.random.default_rng(0)
    lo, hi, w = _co_engagement(ui.dst, ui.src, ui.weight, n_users,
                               min_common, hub_cap, rng)
    # undirected: materialize both directions
    return EdgeSet(np.r_[lo, hi], np.r_[hi, lo], np.r_[w, w])


def build_ii_edges(ui: EdgeSet, n_items: int, *, min_common: int = 2,
                   hub_cap: int = 32, rng=None) -> EdgeSet:
    rng = rng or np.random.default_rng(1)
    lo, hi, w = _co_engagement(ui.src, ui.dst, ui.weight, n_items,
                               min_common, hub_cap, rng)
    return EdgeSet(np.r_[lo, hi], np.r_[hi, lo], np.r_[w, w])


# ---------------------------------------------------------------------------
# popularity bias correction (Eq. 3)
# ---------------------------------------------------------------------------

def popularity_bias_correction(edges: EdgeSet, n_nodes: int,
                               alpha: float = 0.3) -> EdgeSet:
    """w'_{i,j} = w_{i,j} * (w_{j,i} / sum_k w_{j,k})**alpha.

    After correction (i,j) and (j,i) carry different weights; the input
    must already contain both directions.
    """
    deg_w = np.zeros(n_nodes, np.float64)
    np.add.at(deg_w, edges.src, edges.weight.astype(np.float64))
    # w_{j,i}: weight of the reverse edge == weight of (i,j) pre-correction
    # (undirected input), so ratio uses this edge's own weight with the
    # *destination's* out-mass.
    ratio = edges.weight / np.maximum(deg_w[edges.dst], 1e-12)
    w = edges.weight * np.power(np.clip(ratio, 1e-12, 1.0), alpha)
    return EdgeSet(edges.src, edges.dst, w.astype(np.float32))


# ---------------------------------------------------------------------------
# subsampling
# ---------------------------------------------------------------------------

def topk_per_node(edges: EdgeSet, n_nodes: int, k_cap: int) -> EdgeSet:
    """Keep each source node's top-k_cap edges by weight."""
    if len(edges) == 0:
        return edges
    # sort by (src, -weight) then take first k per segment
    order = np.lexsort((-edges.weight, edges.src))
    s, d, w = edges.src[order], edges.dst[order], edges.weight[order]
    starts = np.flatnonzero(np.r_[True, s[1:] != s[:-1]])
    seg_id = np.cumsum(np.r_[True, s[1:] != s[:-1]]) - 1
    rank = np.arange(len(s)) - starts[seg_id]
    keep = rank < k_cap
    return EdgeSet(s[keep], d[keep], w[keep])


def retain_users_by_value(ui: EdgeSet, n_users: int, budget: int) -> np.ndarray:
    """Paper: 'retain ~0.1B nodes prioritized by business value'.

    Business value proxy = total engagement weight.  Returns a bool mask
    of retained users (used for U-U construction only; *all* users stay
    in U-I edges, per the paper).
    """
    val = np.zeros(n_users, np.float64)
    np.add.at(val, ui.src, ui.weight.astype(np.float64))
    if budget >= n_users:
        return np.ones(n_users, bool)
    thresh = np.partition(val, n_users - budget)[n_users - budget]
    mask = val >= thresh
    # ties may overshoot; trim deterministically
    if mask.sum() > budget:
        idx = np.flatnonzero(mask)
        mask = np.zeros(n_users, bool)
        mask[idx[np.argsort(-val[idx], kind="stable")[:budget]]] = True
    return mask


def filter_edges(edges: EdgeSet, keep_src: np.ndarray,
                 keep_dst: np.ndarray) -> EdgeSet:
    m = keep_src[edges.src] & keep_dst[edges.dst]
    return EdgeSet(edges.src[m], edges.dst[m], edges.weight[m])


# ---------------------------------------------------------------------------
# full pipeline
# ---------------------------------------------------------------------------

def build_graph(log: EngagementLog, *,
                alpha_pop: float = 0.3,
                c_u: int = 2, c_i: int = 2,
                k_cap: int = 64,
                hub_cap: int = 32,
                user_budget: Optional[int] = None,
                event_weights: Optional[Dict[int, float]] = None,
                seed: int = 0) -> HeteroGraph:
    """End-to-end construction (paper Figure 2A)."""
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    ui = build_ui_edges(log, event_weights)

    # (1) user retention by business value for the U-U side
    keep_u = retain_users_by_value(ui, log.n_users,
                                   user_budget or log.n_users)
    ui_for_uu = filter_edges(ui, keep_u, np.ones(log.n_items, bool))

    uu = build_uu_edges(ui_for_uu, log.n_users, min_common=c_u,
                        hub_cap=hub_cap, rng=rng)
    ii = build_ii_edges(ui, log.n_items, min_common=c_i,
                        hub_cap=hub_cap, rng=rng)
    # popularity bias correction on I-I (Eq. 3)
    ii = popularity_bias_correction(ii, log.n_items, alpha=alpha_pop)

    # (2) per-node top-K_CAP subsampling
    ui_s = topk_per_node(ui, log.n_users, k_cap)
    uu_s = topk_per_node(uu, log.n_users, k_cap)
    ii_s = topk_per_node(ii, log.n_items, k_cap)

    g1u = np.zeros(log.n_users, bool)
    g1u[uu_s.src] = True
    g1i = np.zeros(log.n_items, bool)
    g1i[ii_s.src] = True

    return HeteroGraph(log.n_users, log.n_items, ui_s, uu_s, ii_s,
                       group1_users=g1u, group1_items=g1i,
                       build_seconds=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# padded adjacency (feeds PPR + training data)
# ---------------------------------------------------------------------------

def padded_adjacency(edges: EdgeSet, n_src: int, max_deg: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """(n_src, max_deg) neighbor ids (-1 pad) + weights, top-weight order."""
    nbrs = np.full((n_src, max_deg), -1, np.int64)
    wts = np.zeros((n_src, max_deg), np.float32)
    if len(edges) == 0:
        return nbrs, wts
    order = np.lexsort((-edges.weight, edges.src))
    s, d, w = edges.src[order], edges.dst[order], edges.weight[order]
    starts = np.flatnonzero(np.r_[True, s[1:] != s[:-1]])
    seg_id = np.cumsum(np.r_[True, s[1:] != s[:-1]]) - 1
    rank = np.arange(len(s)) - starts[seg_id]
    keep = rank < max_deg
    nbrs[s[keep], rank[keep]] = d[keep]
    wts[s[keep], rank[keep]] = w[keep]
    return nbrs, wts
