"""RankGraph-2 graph construction (paper §4.2).

Offline pipeline (numpy): engagement log -> heterogeneous co-engagement
graph with U-I / U-U / I-I edges (Eq. 1-2), popularity bias correction on
I-I edges (Eq. 3), per-node top-K edge subsampling, backbone/extended
split (Group 1 / Group 2).

Hour-level freshness is incremental: ``build_graph`` retains the
pre-subsample aggregates in a ``RefreshState`` and ``refresh_graph``
re-derives only the co-engagement pairs reachable from the trailing
window's delta (everything else is carried over unchanged).  The walk
stage itself dispatches to numpy/jax/pallas in ``core/ppr.py``;
`benchmarks/graph_build_scaling` measures both paths to back the
paper's <=1h claim.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.obs import get_telemetry

# engagement type -> business-value weight (paper: "predefined values
# that reflect business value")
DEFAULT_EVENT_WEIGHTS = {0: 1.0, 1: 2.0, 2: 3.0, 3: 5.0}  # click/like/share/buy


@dataclasses.dataclass
class EngagementLog:
    """Columnar interaction log D = {(user, item, interaction, ts)}."""
    user_id: np.ndarray      # int64 [n]
    item_id: np.ndarray      # int64 [n]
    event_type: np.ndarray   # int32 [n]
    timestamp: np.ndarray    # float64 [n] (seconds)
    n_users: int
    n_items: int

    def window(self, t_end: float, horizon_s: float) -> "EngagementLog":
        m = (self.timestamp <= t_end) & (self.timestamp > t_end - horizon_s)
        return EngagementLog(self.user_id[m], self.item_id[m],
                             self.event_type[m], self.timestamp[m],
                             self.n_users, self.n_items)


@dataclasses.dataclass
class EdgeSet:
    """Directed weighted edges of one type."""
    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray

    def __len__(self) -> int:
        return len(self.src)


@dataclasses.dataclass
class HubDraws:
    """Per-anchor hub-subsample offsets actually drawn by a build
    (``_co_engagement``): one row of ``hub_cap`` sorted offsets (-1 =
    deduped slot) per anchor whose degree exceeded ``hub_cap``.

    Draws are a pure function of ``(seed, tag, anchor id, degree)`` via
    ``hub_uniforms`` — persisting them lets an incremental refresh skip
    regeneration for untouched hub anchors, and regeneration for touched
    anchors reproduces exactly the offsets a from-scratch rebuild on the
    merged window would draw (the refresh-vs-rebuild bitwise guarantee
    holds *even when* ``hub_cap`` triggers)."""
    anchor_ids: np.ndarray       # (n_hub,) ascending anchor node ids
    offsets: np.ndarray          # (n_hub, hub_cap) int64, -1 = dropped dup
    lens: np.ndarray             # (n_hub,) anchor degree at draw time


def _empty_hub_draws(cap: int) -> HubDraws:
    return HubDraws(np.zeros(0, np.int64), np.zeros((0, cap), np.int64),
                    np.zeros(0, np.int64))


@dataclasses.dataclass
class RefreshState:
    """Pre-subsample construction aggregates retained for hour-level
    incremental refresh (``refresh_graph``).  At production scale these
    live in the offline store alongside the log, not in RAM."""
    ui_full: EdgeSet             # aggregated per-(u, i) weights, pre-top-K
    uu_raw: EdgeSet              # canonical (lo < hi) co-pairs, pre-subsample
    ii_raw: EdgeSet              # canonical co-pairs, pre-Eq.3 correction
    params: Dict                 # build knobs a refresh must reuse
    hub_draws: Optional[Dict[str, HubDraws]] = None  # per-anchor offsets


@dataclasses.dataclass
class HeteroGraph:
    n_users: int
    n_items: int
    ui: EdgeSet                  # user -> item
    uu: EdgeSet                  # user -> user (both directions present)
    ii: EdgeSet                  # item -> item (both directions present)
    group1_users: np.ndarray     # bool [n_users]: has same-type neighbors
    group1_items: np.ndarray     # bool [n_items]
    build_seconds: float = 0.0
    refresh: Optional[RefreshState] = None

    @property
    def n_edges(self) -> int:
        return len(self.ui) + len(self.uu) + len(self.ii)


# ---------------------------------------------------------------------------
# U-I edges
# ---------------------------------------------------------------------------

def build_ui_edges(log: EngagementLog,
                   event_weights: Optional[Dict[int, float]] = None
                   ) -> EdgeSet:
    """Aggregate engagement events into weighted U-I edges."""
    ew = event_weights or DEFAULT_EVENT_WEIGHTS
    wtab = np.zeros(max(ew) + 1, np.float64)
    for k, v in ew.items():
        wtab[k] = v
    et = log.event_type
    # unknown / out-of-range event types carry no business value: weight 0.
    # (clipping instead would alias them onto the boundary buckets — a
    # corrupt type id would silently count as a max-weight "buy").
    known = (et >= 0) & (et < len(wtab))
    w = np.where(known, wtab[np.clip(et, 0, len(wtab) - 1)], 0.0)
    key = log.user_id.astype(np.int64) * log.n_items + log.item_id
    uniq, inv = np.unique(key, return_inverse=True)
    agg = np.zeros(len(uniq), np.float64)
    np.add.at(agg, inv, w)
    keep = agg > 0           # all-zero-weight pairs are not engagements
    uniq, agg = uniq[keep], agg[keep]
    # weights stay float64: the refresh merge re-accumulates them, and a
    # premature f32 rounding would double-round vs a from-scratch build
    return EdgeSet(src=(uniq // log.n_items).astype(np.int64),
                   dst=(uniq % log.n_items).astype(np.int64),
                   weight=agg)


# ---------------------------------------------------------------------------
# co-engagement edges (Eq. 1 / Eq. 2)
# ---------------------------------------------------------------------------

HUB_BLOCK = 4096     # anchors per hub-subsample RNG block (keyed stream)


def hub_uniforms(seed: int, tag: str, anchor_ids: np.ndarray,
                 cap: int) -> np.ndarray:
    """(len(anchor_ids), cap) f32 uniforms for hub subsampling, keyed by
    *anchor node id* in fixed ``HUB_BLOCK``-sized blocks (mirroring
    ``ppr.walk_uniforms``) — not by stream position.  An incremental
    refresh that re-expands only the delta-reachable anchors therefore
    regenerates exactly the draws a from-scratch rebuild on the merged
    window would consume for them.  ``tag`` separates the U-U and I-I
    streams (their anchor id spaces overlap)."""
    anchor_ids = np.asarray(anchor_ids, np.int64)
    out = np.empty((len(anchor_ids), cap), np.float64)
    blocks = anchor_ids // HUB_BLOCK
    for b in np.unique(blocks):
        rng = np.random.default_rng((seed, tag.encode(), int(b)))
        blk = rng.random((HUB_BLOCK, cap))
        m = blocks == b
        out[m] = blk[anchor_ids[m] - b * HUB_BLOCK]
    return out


def _hub_offsets(seed: int, tag: str, hub_ids: np.ndarray,
                 hub_lens: np.ndarray, cap: int,
                 prev: Optional[HubDraws]) -> np.ndarray:
    """Sorted, per-row-deduped subsample offsets for hub anchors: a draw
    with replacement can emit the same engager slot — and hence the same
    (src, dst) pair — several times from one anchor, inflating wsum and
    letting a single common anchor satisfy ``cnt >= min_common`` (Eq.
    1/2 count *distinct* common anchors).  Duplicate picks are dropped
    (-1), shrinking the sample slightly — this is a subsample step
    anyway.  Rows persisted in ``prev`` with an unchanged degree are
    reused verbatim; the rest regenerate from the keyed stream (same
    result, just not free)."""
    offs = np.empty((len(hub_ids), cap), np.int64)
    need = np.ones(len(hub_ids), bool)
    if prev is not None and len(prev.anchor_ids):
        pos = np.searchsorted(prev.anchor_ids, hub_ids)
        pos = np.minimum(pos, len(prev.anchor_ids) - 1)
        hit = (prev.anchor_ids[pos] == hub_ids) & (prev.lens[pos] == hub_lens)
        offs[hit] = prev.offsets[pos[hit]]
        need = ~hit
    if need.any():
        u = hub_uniforms(seed, tag, hub_ids[need], cap)
        o = (u * hub_lens[need][:, None]).astype(np.int64)
        o.sort(axis=1)
        dup = np.zeros_like(o, bool)
        dup[:, 1:] = o[:, 1:] == o[:, :-1]
        o[dup] = -1
        offs[need] = o
    return offs


def _co_engagement(anchor: np.ndarray, other: np.ndarray, w: np.ndarray,
                   n_other: int, min_common: int, hub_cap: int,
                   seed: int, tag: str,
                   prev_draws: Optional[HubDraws] = None
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, HubDraws]:
    """Pairs of ``other`` nodes co-engaged via the same ``anchor`` node.

    For U-U edges: anchor=item, other=user.  For I-I: anchor=user,
    other=item.  ``hub_cap`` caps the fan-out per anchor (the paper's
    defence against hundreds-of-trillions of raw pairs: popular anchors
    contribute a bounded sample of pairs; with bias correction +
    top-K subsampling this preserves retrieval-relevant structure).
    Hub draws come from the anchor-keyed ``hub_uniforms`` stream
    (reusing ``prev_draws`` rows where the degree is unchanged), so the
    output is a pure function of the aggregated input — independent of
    whether it is reached by a full build or an incremental refresh.

    Returns (src, dst, weight, draws) with *undirected* co-edges,
    weight = ln(sum_e w_src,e * w_dst,e) and |common| >= min_common.
    """
    order = np.argsort(anchor, kind="stable")
    a, o, ww = anchor[order], other[order], w[order]
    # segment boundaries per anchor
    starts = np.flatnonzero(np.r_[True, a[1:] != a[:-1]])
    ends = np.r_[starts[1:], len(a)]
    lens = ends - starts
    keep = lens >= 2
    starts, ends, lens = starts[keep], ends[keep], lens[keep]
    cap = hub_cap
    if len(starts) == 0:
        z = np.zeros(0)
        return (z.astype(np.int64), z.astype(np.int64),
                z.astype(np.float32), _empty_hub_draws(cap))
    # pad each anchor's engagers to a (n_anchor, cap) matrix (random subset
    # for anchors above cap)
    nseg = len(starts)
    mat = np.full((nseg, cap), -1, np.int64)
    wmat = np.zeros((nseg, cap), np.float64)
    clens = np.minimum(lens, cap)
    # vectorized gather: column j of row r takes element starts[r]+pick[r,j]
    pick = np.arange(cap)[None, :].repeat(nseg, 0)
    big = lens > cap
    if big.any():
        hub_ids = a[starts[big]]
        offs = _hub_offsets(seed, tag, hub_ids, lens[big], cap, prev_draws)
        pick[big] = offs
        draws = HubDraws(hub_ids, offs, lens[big].copy())
    else:
        draws = _empty_hub_draws(cap)
    valid = (pick >= 0) & (pick < lens[:, None])
    idx = np.clip(starts[:, None] + pick, 0, len(a) - 1)
    mat = np.where(valid, o[idx], -1)
    wmat = np.where(valid, ww[idx], 0.0)
    # all within-row pairs
    iu, ju = np.triu_indices(cap, k=1)
    s = mat[:, iu].ravel()
    d = mat[:, ju].ravel()
    pw = (wmat[:, iu] * wmat[:, ju]).ravel()
    m = (s >= 0) & (d >= 0) & (s != d)
    s, d, pw = s[m], d[m], pw[m]
    # canonical order for undirected aggregation
    lo = np.minimum(s, d)
    hi = np.maximum(s, d)
    key = lo * n_other + hi
    uniq, inv, cnt = np.unique(key, return_inverse=True, return_counts=True)
    wsum = np.zeros(len(uniq), np.float64)
    np.add.at(wsum, inv, pw)
    ok = cnt >= min_common
    uniq, wsum = uniq[ok], wsum[ok]
    lo = (uniq // n_other).astype(np.int64)
    hi = (uniq % n_other).astype(np.int64)
    wlog = np.log(np.maximum(wsum, 1e-12)).astype(np.float32)
    # Eq.1/2: w = ln(sum w*w); clamp at small positive so weights stay usable
    wlog = np.maximum(wlog, 1e-3)
    return lo, hi, wlog, draws


def _mirror(e: EdgeSet) -> EdgeSet:
    """Materialize both directions of a canonical undirected edge set."""
    return EdgeSet(np.r_[e.src, e.dst], np.r_[e.dst, e.src],
                   np.r_[e.weight, e.weight])


def build_uu_edges(ui: EdgeSet, n_users: int, *, min_common: int = 2,
                   hub_cap: int = 32, seed: int = 0) -> EdgeSet:
    lo, hi, w, _ = _co_engagement(ui.dst, ui.src, ui.weight, n_users,
                                  min_common, hub_cap, seed, "uu")
    # undirected: materialize both directions
    return _mirror(EdgeSet(lo, hi, w))


def build_ii_edges(ui: EdgeSet, n_items: int, *, min_common: int = 2,
                   hub_cap: int = 32, seed: int = 0) -> EdgeSet:
    lo, hi, w, _ = _co_engagement(ui.src, ui.dst, ui.weight, n_items,
                                  min_common, hub_cap, seed, "ii")
    return _mirror(EdgeSet(lo, hi, w))


# ---------------------------------------------------------------------------
# popularity bias correction (Eq. 3)
# ---------------------------------------------------------------------------

def popularity_bias_correction(edges: EdgeSet, n_nodes: int,
                               alpha: float = 0.3) -> EdgeSet:
    """w'_{i,j} = w_{i,j} * (w_{j,i} / sum_k w_{j,k})**alpha.

    After correction (i,j) and (j,i) carry different weights; the input
    must already contain both directions.
    """
    deg_w = np.zeros(n_nodes, np.float64)
    np.add.at(deg_w, edges.src, edges.weight.astype(np.float64))
    # w_{j,i}: weight of the reverse edge == weight of (i,j) pre-correction
    # (undirected input), so ratio uses this edge's own weight with the
    # *destination's* out-mass.
    ratio = edges.weight / np.maximum(deg_w[edges.dst], 1e-12)
    w = edges.weight * np.power(np.clip(ratio, 1e-12, 1.0), alpha)
    return EdgeSet(edges.src, edges.dst, w.astype(np.float32))


# ---------------------------------------------------------------------------
# subsampling
# ---------------------------------------------------------------------------

def topk_per_node(edges: EdgeSet, n_nodes: int, k_cap: int) -> EdgeSet:
    """Keep each source node's top-k_cap edges by weight."""
    if len(edges) == 0:
        return edges
    # sort by (src, -weight, dst): the dst tiebreak makes the cut
    # independent of input edge order (incremental refresh produces the
    # same edge *set* as a full rebuild but in a different order)
    order = np.lexsort((edges.dst, -edges.weight, edges.src))
    s, d, w = edges.src[order], edges.dst[order], edges.weight[order]
    starts = np.flatnonzero(np.r_[True, s[1:] != s[:-1]])
    seg_id = np.cumsum(np.r_[True, s[1:] != s[:-1]]) - 1
    rank = np.arange(len(s)) - starts[seg_id]
    keep = rank < k_cap
    return EdgeSet(s[keep], d[keep], w[keep])


def retain_users_by_value(ui: EdgeSet, n_users: int, budget: int) -> np.ndarray:
    """Paper: 'retain ~0.1B nodes prioritized by business value'.

    Business value proxy = total engagement weight.  Returns a bool mask
    of retained users (used for U-U construction only; *all* users stay
    in U-I edges, per the paper).
    """
    val = np.zeros(n_users, np.float64)
    np.add.at(val, ui.src, ui.weight.astype(np.float64))
    if budget >= n_users:
        return np.ones(n_users, bool)
    thresh = np.partition(val, n_users - budget)[n_users - budget]
    mask = val >= thresh
    # ties may overshoot; trim deterministically
    if mask.sum() > budget:
        idx = np.flatnonzero(mask)
        mask = np.zeros(n_users, bool)
        mask[idx[np.argsort(-val[idx], kind="stable")[:budget]]] = True
    return mask


def filter_edges(edges: EdgeSet, keep_src: np.ndarray,
                 keep_dst: np.ndarray) -> EdgeSet:
    m = keep_src[edges.src] & keep_dst[edges.dst]
    return EdgeSet(edges.src[m], edges.dst[m], edges.weight[m])


# ---------------------------------------------------------------------------
# full pipeline
# ---------------------------------------------------------------------------

def _finalize_graph(n_users: int, n_items: int, ui_full: EdgeSet,
                    uu_raw: EdgeSet, ii_raw: EdgeSet, *, alpha_pop: float,
                    k_cap: int, state_params: Dict, keep_state: bool,
                    started,
                    hub_draws: Optional[Dict[str, HubDraws]] = None
                    ) -> HeteroGraph:
    """Shared tail of full build and incremental refresh: Eq.3 correction,
    top-K_CAP subsampling, group split, state retention."""
    uu = _mirror(uu_raw)
    ii = popularity_bias_correction(_mirror(ii_raw), n_items,
                                    alpha=alpha_pop)
    # the published graph carries f32 weights; rounding happens HERE
    # (once, from the exact f64 aggregate) in both build and refresh
    ui_f32 = EdgeSet(ui_full.src, ui_full.dst,
                     ui_full.weight.astype(np.float32))
    ui_s = topk_per_node(ui_f32, n_users, k_cap)
    uu_s = topk_per_node(uu, n_users, k_cap)
    ii_s = topk_per_node(ii, n_items, k_cap)

    g1u = np.zeros(n_users, bool)
    g1u[uu_s.src] = True
    g1i = np.zeros(n_items, bool)
    g1i[ii_s.src] = True

    state = (RefreshState(ui_full, uu_raw, ii_raw, dict(state_params),
                          hub_draws=hub_draws)
             if keep_state else None)
    return HeteroGraph(n_users, n_items, ui_s, uu_s, ii_s,
                       group1_users=g1u, group1_items=g1i,
                       # duration of the enclosing construction span
                       build_seconds=started.elapsed(),
                       refresh=state)


def build_graph(log: EngagementLog, *,
                alpha_pop: float = 0.3,
                c_u: int = 2, c_i: int = 2,
                k_cap: int = 64,
                hub_cap: int = 32,
                user_budget: Optional[int] = None,
                event_weights: Optional[Dict[int, float]] = None,
                seed: int = 0,
                keep_state: bool = False) -> HeteroGraph:
    """End-to-end construction (paper Figure 2A).

    ``keep_state`` retains the pre-subsample aggregates on the returned
    graph so ``refresh_graph`` can splice in an hour-level delta later
    (opt-in: the raw co-pair sets can dwarf the subsampled graph).
    """
    with get_telemetry().span("construction.build_graph",
                              n_events=int(len(log.user_id))) as sp:
        ui = build_ui_edges(log, event_weights)

        # (1) user retention by business value for the U-U side
        keep_u = retain_users_by_value(ui, log.n_users,
                                       user_budget or log.n_users)
        ui_for_uu = filter_edges(ui, keep_u, np.ones(log.n_items, bool))

        lo, hi, w, uu_draws = _co_engagement(ui_for_uu.dst, ui_for_uu.src,
                                             ui_for_uu.weight, log.n_users,
                                             c_u, hub_cap, seed, "uu")
        uu_raw = EdgeSet(lo, hi, w)
        lo, hi, w, ii_draws = _co_engagement(ui.src, ui.dst, ui.weight,
                                             log.n_items, c_i, hub_cap,
                                             seed, "ii")
        ii_raw = EdgeSet(lo, hi, w)
        params = dict(alpha_pop=alpha_pop, c_u=c_u, c_i=c_i, k_cap=k_cap,
                      hub_cap=hub_cap, user_budget=user_budget,
                      event_weights=event_weights, seed=seed)
        return _finalize_graph(log.n_users, log.n_items, ui, uu_raw,
                               ii_raw, alpha_pop=alpha_pop, k_cap=k_cap,
                               state_params=params,
                               keep_state=keep_state, started=sp,
                               hub_draws={"uu": uu_draws,
                                          "ii": ii_draws})


# ---------------------------------------------------------------------------
# padded adjacency (feeds PPR + training data)
# ---------------------------------------------------------------------------

def padded_adjacency(edges: EdgeSet, n_src: int, max_deg: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """(n_src, max_deg) neighbor ids (-1 pad) + weights, top-weight order."""
    nbrs = np.full((n_src, max_deg), -1, np.int64)
    wts = np.zeros((n_src, max_deg), np.float32)
    if len(edges) == 0:
        return nbrs, wts
    # dst tiebreak: row content independent of input edge order
    order = np.lexsort((edges.dst, -edges.weight, edges.src))
    s, d, w = edges.src[order], edges.dst[order], edges.weight[order]
    starts = np.flatnonzero(np.r_[True, s[1:] != s[:-1]])
    seg_id = np.cumsum(np.r_[True, s[1:] != s[:-1]]) - 1
    rank = np.arange(len(s)) - starts[seg_id]
    keep = rank < max_deg
    nbrs[s[keep], rank[keep]] = d[keep]
    wts[s[keep], rank[keep]] = w[keep]
    return nbrs, wts


# ---------------------------------------------------------------------------
# hour-level incremental refresh (paper §4.2 "hourly rebuild", done as a
# delta splice instead of a from-scratch batch job)
# ---------------------------------------------------------------------------

def merge_edge_aggregates(a: EdgeSet, b: EdgeSet, n_dst: int) -> EdgeSet:
    """Sum two per-(src, dst) aggregated edge sets; canonical key order.
    Weights accumulate in float64 end-to-end (see ``build_ui_edges``)."""
    key = np.concatenate([a.src.astype(np.int64) * n_dst + a.dst,
                          b.src.astype(np.int64) * n_dst + b.dst])
    w = np.concatenate([a.weight, b.weight]).astype(np.float64)
    uniq, inv = np.unique(key, return_inverse=True)
    agg = np.zeros(len(uniq), np.float64)
    np.add.at(agg, inv, w)
    keep = agg > 0
    uniq, agg = uniq[keep], agg[keep]
    return EdgeSet((uniq // n_dst).astype(np.int64),
                   (uniq % n_dst).astype(np.int64),
                   agg)


def _canonical_pair_order(e: EdgeSet, n_other: int) -> EdgeSet:
    """Sort canonical (lo < hi) pairs by packed key — the order
    ``_co_engagement`` emits, so refreshed raws are bitwise comparable
    (and bitwise *accumulable*, e.g. in Eq. 3) to a full rebuild's."""
    order = np.argsort(e.src.astype(np.int64) * n_other + e.dst,
                       kind="stable")
    return EdgeSet(e.src[order], e.dst[order], e.weight[order])


def _merge_hub_draws(prev: Optional[HubDraws], new: HubDraws,
                     recomputed: np.ndarray, cap: int) -> HubDraws:
    """Carry forward persisted hub draws: rows for anchors outside the
    recomputed set survive from ``prev``; recomputed anchors take their
    fresh rows from ``new`` (which already reused matching prev rows)."""
    if prev is None or len(prev.anchor_ids) == 0:
        return new
    keep = ~np.isin(prev.anchor_ids, recomputed)
    ids = np.concatenate([prev.anchor_ids[keep], new.anchor_ids])
    offs = np.concatenate([prev.offsets[keep], new.offsets]) \
        if len(ids) else np.zeros((0, cap), np.int64)
    lens = np.concatenate([prev.lens[keep], new.lens])
    order = np.argsort(ids, kind="stable")
    return HubDraws(ids[order], offs[order], lens[order])


def _recompute_touching_pairs(anchor: np.ndarray, other: np.ndarray,
                              w: np.ndarray, touched_other: np.ndarray,
                              n_other: int, min_common: int, hub_cap: int,
                              seed: int, tag: str,
                              prev_draws: Optional[HubDraws]
                              ) -> Tuple[np.ndarray, ...]:
    """Re-derive all co-engagement pairs with >= 1 touched endpoint.

    Every anchor adjacent to a touched ``other`` node is re-expanded in
    full (a touched pair's common anchors are all adjacent to its touched
    endpoint, so the recomputed weights/counts are complete); pairs whose
    endpoints are both untouched are discarded — their old values stand.

    Returns ``(lo, hi, w, draws, recomputed_anchor_ids)``.
    """
    if len(anchor):
        a_mask = np.zeros(int(anchor.max()) + 1, bool)
        a_mask[anchor[touched_other[other]]] = True
        sel = a_mask[anchor]
        recomputed = np.flatnonzero(a_mask)
    else:
        sel = np.zeros(0, bool)
        recomputed = np.zeros(0, np.int64)
    lo, hi, pw, draws = _co_engagement(anchor[sel], other[sel], w[sel],
                                       n_other, min_common, hub_cap,
                                       seed, tag, prev_draws)
    touching = touched_other[lo] | touched_other[hi]
    return lo[touching], hi[touching], pw[touching], draws, recomputed


def _hub_resample_members(old_ui: EdgeSet, new_ui: EdgeSet,
                          anchor_of, other_of, n_anchor: int,
                          cap: int) -> np.ndarray:
    """Other-side members of anchors whose *degree* changed past the hub
    cap.  A hub anchor's subsample draw is keyed by (anchor id, degree)
    — a degree change redraws it, which can add or drop co-pairs between
    endpoints the delta never touched.  Marking every member of such an
    anchor as touched routes all its pairs through the full
    re-expansion, preserving refresh == rebuild bitwise."""
    old_deg = np.bincount(anchor_of(old_ui), minlength=n_anchor)
    new_deg = np.bincount(anchor_of(new_ui), minlength=n_anchor)
    changed = ((old_deg != new_deg)
               & (np.maximum(old_deg, new_deg) > cap))
    if not changed.any():
        return np.zeros(0, np.int64)
    sel_old = changed[anchor_of(old_ui)]
    sel_new = changed[anchor_of(new_ui)]
    return np.union1d(other_of(old_ui)[sel_old], other_of(new_ui)[sel_new])


def refresh_graph(g: HeteroGraph, delta_log: EngagementLog
                  ) -> Tuple[HeteroGraph, Dict[str, np.ndarray]]:
    """Splice a trailing-window delta into an existing graph (paper's
    hour-level item-coverage path: no from-scratch rebuild).

    Only co-engagement pairs reachable from the delta are re-derived;
    the cheap O(E) tails (Eq. 3 correction, top-K subsampling) run in
    full.  Every retained edge matches a from-scratch build on the
    merged window bit-for-bit — including when ``hub_cap`` triggers:
    hub-subsample offsets are keyed by (anchor id, degree)
    (``hub_uniforms``) and persisted per anchor in ``RefreshState``, so
    untouched anchors reuse their draws and re-expanded anchors
    regenerate exactly the draws a full rebuild would consume.  Both id
    spaces may grow (``delta_log.n_users >= g.n_users``,
    ``delta_log.n_items >= g.n_items``); grown tails count as touched.

    Returns ``(new_graph, report)`` with ``report['touched_users'] /
    ['touched_items']`` — the nodes whose edge sets may have changed.
    """
    st = g.refresh
    if st is None:
        raise ValueError("graph was built without keep_state=True; "
                         "no refresh aggregates retained")
    p = st.params
    if p.get("user_budget"):
        raise ValueError("incremental refresh with a user retention "
                         "budget is not supported (retention is a "
                         "global decision; re-run build_graph)")
    if delta_log.n_users < g.n_users:
        raise ValueError("user space may only grow")
    if delta_log.n_items < g.n_items:
        raise ValueError("item space may only grow")
    with get_telemetry().span(
            "construction.refresh_graph",
            delta_events=int(len(delta_log.user_id))) as sp:
        nu, ni = delta_log.n_users, delta_log.n_items
        seed = p.get("seed", 0)
        cap = p["hub_cap"]
        draws = st.hub_draws or {}

        # 1) merge the delta's aggregated U-I engagements
        d_ui = build_ui_edges(delta_log, p.get("event_weights"))
        ui_full = merge_edge_aggregates(st.ui_full, d_ui, ni)
        touched_u = np.unique(delta_log.user_id)
        touched_i = np.unique(delta_log.item_id)
        if nu > g.n_users:       # grown tail = brand-new users
            touched_u = np.union1d(touched_u, np.arange(g.n_users, nu))
        if ni > g.n_items:       # grown tail = brand-new items
            touched_i = np.union1d(touched_i, np.arange(g.n_items, ni))
        # degree-changed hub anchors redraw their subsample: their
        # members' co-pairs must be recomputed even if the delta never
        # touched them
        touched_u = np.union1d(touched_u, _hub_resample_members(
            st.ui_full, ui_full, lambda e: e.dst, lambda e: e.src, ni,
            cap))
        touched_i = np.union1d(touched_i, _hub_resample_members(
            st.ui_full, ui_full, lambda e: e.src, lambda e: e.dst, nu,
            cap))
        um = np.zeros(nu, bool)
        um[touched_u] = True
        im = np.zeros(ni, bool)
        im[touched_i] = True

        # 2) re-derive co-engagement pairs touching the delta
        lo, hi, w, uu_new, uu_rec = _recompute_touching_pairs(
            ui_full.dst, ui_full.src, ui_full.weight, um, nu,
            p["c_u"], cap, seed, "uu", draws.get("uu"))
        keep = ~(um[st.uu_raw.src] | um[st.uu_raw.dst])
        uu_raw = _canonical_pair_order(
            EdgeSet(np.r_[st.uu_raw.src[keep], lo],
                    np.r_[st.uu_raw.dst[keep], hi],
                    np.r_[st.uu_raw.weight[keep], w]), nu)
        uu_draws = _merge_hub_draws(draws.get("uu"), uu_new, uu_rec, cap)

        lo, hi, w, ii_new, ii_rec = _recompute_touching_pairs(
            ui_full.src, ui_full.dst, ui_full.weight, im, ni,
            p["c_i"], cap, seed, "ii", draws.get("ii"))
        keep = ~(im[st.ii_raw.src] | im[st.ii_raw.dst])
        ii_raw = _canonical_pair_order(
            EdgeSet(np.r_[st.ii_raw.src[keep], lo],
                    np.r_[st.ii_raw.dst[keep], hi],
                    np.r_[st.ii_raw.weight[keep], w]), ni)
        ii_draws = _merge_hub_draws(draws.get("ii"), ii_new, ii_rec, cap)

        # 3) cheap O(E) tails in full (Eq. 3, top-K, groups)
        g_new = _finalize_graph(nu, ni, ui_full, uu_raw, ii_raw,
                                alpha_pop=p["alpha_pop"],
                                k_cap=p["k_cap"], state_params=p,
                                keep_state=True, started=sp,
                                hub_draws={"uu": uu_draws,
                                           "ii": ii_draws})
    report = dict(touched_users=touched_u, touched_items=touched_i)
    return g_new, report
