"""KNN-free serving (paper §4.4) — batched, array-backed engine.

U2U2I: each user carries a hierarchical cluster code (k1, k2) from the
co-learned RQ index; each cluster keeps a recency-filtered queue of items
engaged by its recently-active members.  Serving = read the target
user's cluster queue (a lookup), instead of online KNN over the active
user pool.

U2I2I: item embeddings change slowly, so I2I KNN is pre-computed offline;
serving unions the similar-item lists of the user's recent items.

The store is a flat ring buffer: preallocated ``(n_clusters, queue_len)``
item/timestamp arrays plus a per-cluster write cursor.  ``ingest`` and
``retrieve_batch`` are fully vectorized over events/requests — the
per-request ``retrieve`` of the seed implementation survives as a thin
wrapper over a batch of one.  The fused cluster-gather + I2I-union pass
also exists as a Pallas kernel (``repro.kernels.queue_gather``) driven
by ``serve_batch(..., use_kernel=True)``.

Threading contract: one store serves N reader threads concurrently.
Request scratch comes from a per-thread ``BufPool`` registry (readers
never alias each other's buffers), and the retrieve path is lock-free —
a per-cluster seqlock (generation counter, odd while a write is in
flight) lets readers run against a concurrently-ingesting store and
retry the gather on the rare torn read.  Writers (``ingest``) serialize
on the store's write lock.

``ServingCostModel`` quantifies the paper's 83% claim: FLOPs + bytes per
request for online-KNN vs cluster-lookup serving at a given active-pool
size, traffic, and request batch size.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import get_telemetry

_OBS = get_telemetry()   # process singleton; configure() mutates in place


# ---------------------------------------------------------------------------
# batched row utilities (shared by U2U2I and U2I2I paths)
# ---------------------------------------------------------------------------

class BufPool:
    """Named scratch-buffer cache so the steady-state serving path runs
    allocation-free (fresh multi-MB temporaries each request batch cost
    more in page faults than the actual compute).

    Single-threaded by design — the buffers are reused in place, so one
    pool must never be shared across concurrent requests.  Concurrent
    callers go through ``ThreadLocalPools`` (one pool per thread) rather
    than holding a pool directly."""

    def __init__(self):
        self._bufs: Dict[str, np.ndarray] = {}

    def get(self, name: str, shape: Tuple[int, ...], dtype) -> np.ndarray:
        buf = self._bufs.get(name)
        if buf is None or buf.shape != shape or buf.dtype != dtype:
            buf = np.empty(shape, dtype)
            self._bufs[name] = buf
            if _OBS.enabled:   # steady state should stop allocating
                _OBS.counter("serving.pool_allocs")
        return buf


class ThreadLocalPools:
    """Per-thread ``BufPool`` registry: ``get()`` hands each thread its
    own pool, so N serving threads can share one immutable store without
    aliasing each other's ``rows``/``ts``/``key`` scratch.  Buffers die
    with their thread (``threading.local`` storage)."""

    def __init__(self):
        self._tls = threading.local()

    def get(self) -> BufPool:
        pool = getattr(self._tls, "pool", None)
        if pool is None:
            pool = self._tls.pool = BufPool()
        return pool


_POOLS = ThreadLocalPools()   # default pools for module-level entry points


def dedup_topk_rows(cand: np.ndarray, prio: np.ndarray, valid: np.ndarray,
                    k: int, prio_bound: int,
                    pool: Optional[BufPool] = None) -> np.ndarray:
    """Per row: among ``valid`` entries, dedup items keeping the
    lowest-priority occurrence, then emit the ``k`` lowest-priority
    survivors in priority order as ``(B, k)`` int64, ``-1``-padded.

    ``prio`` must be unique per row and ``< prio_bound`` wherever valid.
    One unstable composite-key sort (item * P + priority packs both the
    dedup grouping and the within-item winner into a single ordered
    pass) plus an O(Q) top-k partition — no stable sorts, no scatters,
    no allocations beyond the (B, k) result.
    """
    pool = pool if pool is not None else _POOLS.get()
    B, M = cand.shape
    pshift = max(int(prio_bound - 1).bit_length(), 1)  # P = 2^pshift
    P = 1 << pshift
    ishift = max(int(cand.max(initial=0)).bit_length(), 1)
    dt = np.int32 if pshift + ishift < 31 else np.int64
    big = np.iinfo(dt).max
    # pass 1: sort on (item, prio) — groups duplicates, winner first.
    # Value sorts throughout: the original column is never needed again,
    # so no argsort/gather round-trips; key assembly is in-place.
    key = pool.get("key", (B, M), dt)
    scrap = pool.get("scrap", (B, M), bool)
    np.left_shift(cand, pshift, out=key, dtype=dt)
    np.add(key, prio, out=key)
    np.logical_not(valid, out=scrap)
    np.copyto(key, big, where=scrap)
    key.sort(axis=1)
    item = pool.get("item", (B, M), dt)
    np.right_shift(key, pshift, out=item)
    alive = pool.get("alive", (B, M), bool)
    alive[:, 0] = True
    np.not_equal(item[:, 1:], item[:, :-1], out=alive[:, 1:])  # dedup
    # pass 2: re-pack winners as (prio, item) and select the k smallest
    np.not_equal(key, big, out=scrap)
    alive &= scrap
    key2 = pool.get("key2", (B, M), dt)
    np.bitwise_and(key, P - 1, out=key2)
    np.left_shift(key2, ishift, out=key2)
    np.bitwise_or(key2, item, out=key2)
    np.logical_not(alive, out=alive)
    np.copyto(key2, big, where=alive)
    kk = min(k, M)
    if kk < M:
        key2.partition(kk - 1, axis=1)
        key2 = key2[:, :kk]
    key2.sort(axis=1)
    out = np.where(key2 != big,
                   key2 & ((1 << ishift) - 1), -1).astype(np.int64)
    if out.shape[1] < k:
        out = np.pad(out, ((0, 0), (0, k - out.shape[1])),
                     constant_values=-1)
    return out


# ---------------------------------------------------------------------------
# cluster-queue store (U2U2I)
# ---------------------------------------------------------------------------

class ClusterQueueStore:
    """Real-time per-cluster item queues with recency filtering.

    Flat ring-buffer layout: ``items``/``times`` are dense
    ``(n_clusters, queue_len)`` arrays and ``cursor[c]`` counts total
    writes into cluster ``c`` (write position = ``cursor % queue_len``,
    fill level = ``min(cursor, queue_len)``) — O(1) eviction, no Python
    containers anywhere on the serving path.

    Concurrency: writers serialize on ``write_lock`` (an RLock — the
    swap engine's ring drain wraps ``ingest`` in the same lock);
    readers are lock-free via a per-cluster seqlock, ``gen[c]``, which
    is odd exactly while a write to cluster ``c`` is in flight.  A
    reader gathers its rows, then re-checks the generations it started
    from and retries on mismatch; after ``_SEQLOCK_SPINS`` failed
    attempts it falls back to one gather under ``write_lock``.
    """

    _SEQLOCK_SPINS = 32

    def __init__(self, user_clusters: np.ndarray, *, queue_len: int = 256,
                 recency_s: float = 900.0, n_clusters: Optional[int] = None,
                 telemetry=None):
        self.tel = telemetry if telemetry is not None else get_telemetry()
        self.user_clusters = np.asarray(user_clusters, np.int64)
        self.queue_len = int(queue_len)
        self.recency_s = float(recency_s)
        if n_clusters is None:
            n_clusters = int(self.user_clusters.max()) + 1 \
                if self.user_clusters.size else 1
        self.n_clusters = int(n_clusters)
        self.items = np.full((self.n_clusters, self.queue_len), -1, np.int32)
        # timestamps are stored float32 relative to the first-seen event
        # (absolute unix-epoch seconds lose ~100s of precision in f32)
        self.times = np.full((self.n_clusters, self.queue_len), -np.inf,
                             np.float32)
        self.cursor = np.zeros(self.n_clusters, np.int64)
        self.epoch: Optional[float] = None
        self.pools = ThreadLocalPools()  # per-thread request scratch
        self.gen = np.zeros(self.n_clusters, np.int64)   # seqlock, odd=busy
        self.write_lock = threading.RLock()
        self.ring_seen = 0     # EventRing watermark (maintained by swap)

    # -- cluster assignment lookup ------------------------------------------

    def clusters_of(self, user_ids: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Cluster ids for a batch of users plus a known-user mask.

        Users outside the assignment table — ids minted *after* the
        snapshot this store serves was published (the id space grows at
        every lifecycle refresh) — map to cluster 0 with ``known=False``;
        callers must mask their rows out rather than crash or serve
        another user's cluster.
        """
        user_ids = np.asarray(user_ids, np.int64).ravel()
        known = (user_ids >= 0) & (user_ids < self.user_clusters.shape[0])
        cl = self.user_clusters[np.where(known, user_ids, 0)]
        return cl, known

    # -- ingestion ----------------------------------------------------------

    def ingest(self, user_ids: np.ndarray, item_ids: np.ndarray,
               timestamps: np.ndarray) -> None:
        """Stream a batch of engagement events into their users' cluster
        ring buffers (vectorized; oldest-to-newest so the ring order is
        the time order within the batch).  Events from users unknown to
        this snapshot's assignment table are dropped (they enter queues
        once the next publication assigns them a cluster).

        Thread-safe vs concurrent writers (``write_lock``) and vs
        lock-free readers: all array writes happen inside the touched
        clusters' seqlock window (``gen`` odd), so a reader overlapping
        the scatter retries instead of returning a torn row."""
        user_ids = np.asarray(user_ids, np.int64).ravel()
        item_ids = np.asarray(item_ids, np.int64).ravel()
        ts64 = np.asarray(timestamps, np.float64).ravel()
        cl_all, known = self.clusters_of(user_ids)
        if not known.all():
            # graceful degradation: post-snapshot users are shed, not
            # errored — the drop is surfaced as a counter so staleness
            # between publications is observable
            if self.tel.enabled:
                self.tel.counter("serving.unknown_user_events",
                                 float((~known).sum()))
            cl_all = cl_all[known]
            item_ids = item_ids[known]
            ts64 = ts64[known]
        if cl_all.size == 0:
            return
        with self.write_lock:
            if self.epoch is None:
                self.epoch = float(ts64.min())
            ts = (ts64 - self.epoch).astype(np.float32)
            order = np.argsort(ts, kind="stable")
            cl = cl_all[order]
            it = item_ids[order]
            ts = ts[order]

            # per-cluster arrival rank (stable sort by cluster keeps
            # time order)
            by_cl = np.argsort(cl, kind="stable")
            cl_sorted = cl[by_cl]
            boundary = np.r_[True, cl_sorted[1:] != cl_sorted[:-1]]
            group_start = np.maximum.accumulate(
                np.where(boundary, np.arange(cl.size), 0))
            rank = np.empty(cl.size, np.int64)
            rank[by_cl] = np.arange(cl.size) - group_start

            slot = (self.cursor[cl] + rank) % self.queue_len
            # keep only the final write per (cluster, slot): with more
            # events than queue_len for one cluster in a single batch,
            # older events fall straight through the ring
            key = cl * self.queue_len + slot
            _, last = np.unique(key[::-1], return_index=True)
            last = cl.size - 1 - last
            uniq, counts = np.unique(cl, return_counts=True)
            self.gen[uniq] += 1                # enter: odd -> readers spin
            self.items[cl[last], slot[last]] = it[last]
            self.times[cl[last], slot[last]] = ts[last]
            self.cursor[uniq] += counts
            self.gen[uniq] += 1                # exit: even -> consistent
        tel = self.tel
        if tel.enabled:
            tel.counter("serving.ingest_events", float(cl.size))
            fill = np.minimum(self.cursor[uniq], self.queue_len)
            tel.gauge("serving.queue_depth_max", float(fill.max()))
            tel.gauge("serving.queue_depth_mean", float(fill.mean()))

    # -- retrieval ----------------------------------------------------------

    def rel_cutoff(self, now: float) -> float:
        """Recency cutoff in the store's internal (epoch-relative) time."""
        return now - self.recency_s - (self.epoch or 0.0)

    def _seqlock_read(self, cl: np.ndarray, fn):
        """Run ``fn()`` (which reads this store's arrays for clusters
        ``cl``) under the seqlock discipline: skip while any touched
        generation is odd, re-check the generations the read started
        from, and retry on mismatch (a writer scattered into one of our
        clusters mid-read).  Lock-free on the happy path; after
        ``_SEQLOCK_SPINS`` collisions, one run under ``write_lock``
        guarantees progress.

        Every collision (odd generation seen, or generation moved under
        the read) counts as a ``serving.seqlock_retries`` tick; taking
        the locked path counts as ``serving.seqlock_fallbacks``."""
        tel = self.tel
        retries = 0
        for _ in range(self._SEQLOCK_SPINS):
            g0 = self.gen[cl]            # fancy index -> private copy
            if (g0 & 1).any():           # a write is mid-flight: respin
                retries += 1
                continue
            out = fn()
            if np.array_equal(self.gen[cl], g0):
                if retries and tel.enabled:
                    tel.counter("serving.seqlock_retries", float(retries))
                return out
            retries += 1
        if tel.enabled:
            if retries:
                tel.counter("serving.seqlock_retries", float(retries))
            tel.counter("serving.seqlock_fallbacks")
        with self.write_lock:            # bounded fallback: quiesced read
            return fn()

    def _consistent_gather(self, cl: np.ndarray, pool: BufPool
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Seqlock gather of ``(items, times, cursor)`` rows for
        clusters ``cl`` into per-thread scratch."""
        B, Q = cl.shape[0], self.queue_len
        rows = pool.get("rows", (B, Q), np.int32)
        ts = pool.get("ts", (B, Q), np.float32)

        def gather():
            np.take(self.items, cl, axis=0, out=rows)
            np.take(self.times, cl, axis=0, out=ts)
            return rows, ts, self.cursor[cl]

        return self._seqlock_read(cl, gather)

    def retrieve_batch(self, user_ids: np.ndarray, now: float,
                       k: int) -> np.ndarray:
        """Batched U2U2I: ``(B,)`` user ids -> ``(B, k)`` item ids,
        newest-first, recency-filtered, deduped, ``-1``-padded.  One
        vectorized pass over the whole request batch.  Safe to call from
        many threads at once (per-thread scratch, seqlock-guarded
        gather)."""
        tel = self.tel
        t0 = tel.clock.perf() if tel.enabled else 0.0
        user_ids = np.asarray(user_ids, np.int64).ravel()
        Q = self.queue_len
        B = user_ids.shape[0]
        pool = self.pools.get()
        cl, known = self.clusters_of(user_ids)
        rows, ts, total = self._consistent_gather(cl, pool)
        head = (total % Q).astype(np.int32)
        slot = np.arange(Q, dtype=np.int32)[None, :]
        age = pool.get("age", (B, Q), np.int32)
        np.subtract(head[:, None], slot + 1, out=age)
        if Q & (Q - 1) == 0:                                 # pow2 fast path
            np.bitwise_and(age, Q - 1, out=age)              # newest = 0
        else:
            np.mod(age, Q, out=age)
        valid = pool.get("valid", (B, Q), bool)
        mask = pool.get("mask", (B, Q), bool)
        np.greater_equal(ts, np.float32(self.rel_cutoff(now)), out=valid)
        np.less(age, np.minimum(total, Q)[:, None], out=mask)
        valid &= mask
        np.greater_equal(rows, 0, out=mask)
        valid &= mask
        if not known.all():
            valid &= known[:, None]          # unknown users: empty rows
            if tel.enabled:
                tel.counter("serving.unknown_user_requests",
                            float((~known).sum()))
        out = dedup_topk_rows(rows, age, valid, k, Q, pool)
        if tel.enabled:
            tel.observe("serving.retrieve_latency_s",
                        tel.clock.perf() - t0)
            tel.counter("serving.retrieve_requests")
        return out

    def retrieve(self, user_id: int, now: float, k: int) -> List[int]:
        """Legacy single-request U2U2I — a batch of one."""
        row = self.retrieve_batch(np.array([user_id]), now, k)[0]
        return [int(i) for i in row if i >= 0]

    def serve_batch(self, user_ids: np.ndarray, now: float, *,
                    n_recent: int = 8, k: int = 32,
                    i2i: Optional[np.ndarray] = None,
                    use_kernel: bool = False
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Full serving pass: U2U2I seeds ``(B, n_recent)`` plus — when an
        ``i2i`` table is given — the U2I2I round-robin union ``(B, k)``.
        ``use_kernel=True`` routes through the fused Pallas
        ``queue_gather`` kernel instead of the numpy path."""
        if i2i is not None and use_kernel:
            from repro.kernels.queue_gather.ops import queue_gather
            cl, known = self.clusters_of(user_ids)

            def _run():
                s, u = queue_gather(
                    self.items, self.times, self.cursor, cl, i2i,
                    cutoff=self.rel_cutoff(now), n_recent=n_recent, k=k)
                return np.asarray(s, np.int64), np.asarray(u, np.int64)

            # same seqlock discipline as the numpy path: the kernel
            # snapshots the store arrays at launch, so relaunch on a
            # torn read
            seeds, union = self._seqlock_read(cl, _run)
            if not known.all():
                seeds[~known] = -1           # unknown users: empty rows
                union[~known] = -1
                if self.tel.enabled:
                    self.tel.counter("serving.unknown_user_requests",
                                     float((~known).sum()))
            return seeds, union
        seeds = self.retrieve_batch(user_ids, now, n_recent)
        if i2i is None:
            return seeds, np.full((seeds.shape[0], k), -1, np.int64)
        return seeds, u2i2i_retrieve_batch(i2i, seeds, k)

    def stats(self) -> Dict[str, float]:
        fill = np.minimum(self.cursor, self.queue_len)
        active = fill > 0
        return dict(n_clusters_active=int(active.sum()),
                    mean_queue=float(fill[active].mean())
                    if active.any() else 0.0)


# ---------------------------------------------------------------------------
# offline I2I KNN (U2I2I)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _topk_scorer(kk: int, exclude_self: bool):
    """Jitted chunk scorer: cosine top-k against the full item set with
    the diagonal masked.  One compile per (k, exclude_self); chunk rows
    are padded to a fixed shape so every chunk hits the same trace."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def score(chunk_e, all_e, row0):
        sims = chunk_e @ all_e.T                             # (C, n)
        if exclude_self:
            cols = jnp.arange(sims.shape[1])[None, :]
            own = row0 + jnp.arange(sims.shape[0])[:, None]
            sims = jnp.where(cols == own, -jnp.inf, sims)
        _, idx = jax.lax.top_k(sims, kk)
        return idx

    return score


def build_i2i_knn(item_emb: np.ndarray, k: int, *, chunk: int = 2048,
                  exclude_self: bool = True) -> np.ndarray:
    """(n_items, k) most-similar items by cosine; computed offline after
    each embedding refresh (cheap: item embeddings update infrequently).
    The chunk loop runs a single jitted top-k scorer — no per-row numpy
    argpartition/argsort passes."""
    e = item_emb / np.maximum(
        np.linalg.norm(item_emb, axis=1, keepdims=True), 1e-8)
    e = e.astype(np.float32)
    n = len(e)
    kk = min(k, n - 1)
    if kk <= 0:      # 0- or 1-item corpus: no neighbors exist at all
        return np.full((n, k), -1, np.int64)
    chunk = min(chunk, n)
    score = _topk_scorer(kk, exclude_self)
    out = np.empty((n, kk), np.int64)
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)
        rows = e[lo:hi]
        if hi - lo < chunk:                      # pad: keep one traced shape
            rows = np.pad(rows, ((0, chunk - (hi - lo)), (0, 0)))
        out[lo:hi] = np.asarray(score(rows, e, lo))[: hi - lo]
    if kk < k:
        out = np.pad(out, ((0, 0), (0, k - kk)), constant_values=-1)
    return out


def u2i2i_retrieve_batch(i2i: np.ndarray, recent_items: np.ndarray,
                         k: int) -> np.ndarray:
    """Batched U2I2I: union the similar-item lists of each row's recent
    items ``(B, R)`` (``-1`` = padding), round-robin across ranks to
    preserve per-seed ordering, mask the seeds themselves, dedup, and
    return ``(B, k)`` ``-1``-padded candidates."""
    recent = np.asarray(recent_items, np.int64)
    B, R = recent.shape
    K = i2i.shape[1]
    nonneg = recent >= 0
    # seeds past the end of the table contribute no neighbors (queues see
    # brand-new items before the next offline I2I refresh covers them)
    seeded = nonneg & (recent < i2i.shape[0])
    cand = np.asarray(i2i, np.int32)[np.where(seeded, recent, 0)]  # (B,R,K)
    cand = np.where(seeded[:, :, None], cand, -1)
    flat = cand.reshape(B, R * K)                        # seed-major layout
    # round-robin emission priority of the seed per-request loop (rank 0
    # of every seed, then rank 1, ...) as a per-column key — no need to
    # physically transpose into rank-major order
    col = np.arange(R * K, dtype=np.int32)
    prio = (col % K) * R + col // K
    # every non-negative seed is masked from the union, including ones
    # the table does not cover (a candidate may still equal them)
    seen = (flat[:, :, None] ==
            np.where(nonneg, recent, -2)[:, None, :]).any(axis=2)
    valid = (flat >= 0) & ~seen
    return dedup_topk_rows(flat, prio[None, :], valid, k, R * K)


def u2i2i_retrieve(i2i: np.ndarray, recent_items: Sequence[int],
                   k: int) -> List[int]:
    """Legacy single-request U2I2I — a batch of one."""
    recent = np.asarray(list(recent_items), np.int64).reshape(1, -1)
    if recent.size == 0:
        return []
    row = u2i2i_retrieve_batch(i2i, recent, k)[0]
    return [int(i) for i in row if i >= 0]


# ---------------------------------------------------------------------------
# serving cost model (the 83% claim)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServingCostModel:
    """Per-request compute/memory cost of U2U2I serving strategies.

    Online KNN: every request scores the query user against the active
    pool (exact or IVF-style approximate with n_probe fraction scanned).
    Cluster index: assign-once per embedding refresh (amortized ~0) +
    O(1) queue read per request.  ``batch_size`` models the batched
    engine: per-launch fixed costs (cursor/metadata reads, dispatch) are
    amortized across the request batch.
    """
    d: int = 256
    active_pool: int = 5_000_000       # recently-active users (15 min)
    qps: float = 1e6
    n_probe_frac: float = 0.05         # ANN scans ~5% of the pool
    queue_read_items: int = 64
    rq_codes: Tuple[int, ...] = (5000, 50)
    batch_size: int = 1
    launch_bytes: float = 64 * 1024.0  # per-launch metadata + dispatch
    launch_flops: float = 4 * 1024.0

    def _batch(self, batch_size: Optional[int]) -> int:
        return max(int(batch_size if batch_size is not None
                       else self.batch_size), 1)

    def knn_flops_per_req(self, exact: bool = False) -> float:
        frac = 1.0 if exact else self.n_probe_frac
        return 2.0 * self.d * self.active_pool * frac

    def knn_bytes_per_req(self, exact: bool = False) -> float:
        frac = 1.0 if exact else self.n_probe_frac
        return 4.0 * self.d * self.active_pool * frac

    def cluster_flops_per_req(self, batch_size: Optional[int] = None
                              ) -> float:
        # queue read: no dot products at request time; assignment cost is
        # amortized into the embedding-refresh batch job:
        assign = 2.0 * self.d * sum(self.rq_codes)      # per refresh
        refresh_period_s = 3 * 3600.0
        amortized = assign / max(self.qps * refresh_period_s /
                                 max(self.active_pool, 1), 1e-9)
        return amortized + self.launch_flops / self._batch(batch_size)

    def cluster_bytes_per_req(self, batch_size: Optional[int] = None
                              ) -> float:
        # queue read + code read per request; launch cost amortized over
        # the batch the vectorized engine serves per dispatch
        return (8.0 * self.queue_read_items + 8.0
                + self.launch_bytes / self._batch(batch_size))

    def cost_reduction(self, batch_size: Optional[int] = None) -> float:
        """Fractional serving-cost reduction (bytes+flops weighted by a
        machine-cost proxy: memory-bandwidth bound at serving tier)."""
        knn = self.knn_bytes_per_req()
        cl = self.cluster_bytes_per_req(batch_size)
        return 1.0 - cl / max(knn, 1e-9)
