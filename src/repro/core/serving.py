"""KNN-free serving (paper §4.4) — device-resident, single-dispatch engine.

U2U2I: each user carries a hierarchical cluster code (k1, k2) from the
co-learned RQ index; each cluster keeps a recency-filtered queue of items
engaged by its recently-active members.  Serving = read the target
user's cluster queue (a lookup), instead of online KNN over the active
user pool.

U2I2I: item embeddings change slowly, so I2I KNN is pre-computed offline;
serving unions the similar-item lists of the user's recent items.

``ClusterQueueStore`` keeps its ring buffers as **jax device arrays** and
collapses the whole retrieve pass — recency cutoff, validity masking,
top-k selection, and (in ``serve_batch``) the U2I2I union — into a
single jitted dispatch.  The jit releases the GIL while XLA runs, so N
serving threads scale past the interpreter wall that bounded the old
host-array engine (preserved as ``HostQueueStore`` in
``repro.core.serving_host``; it remains the bitwise oracle and the
scale-out baseline).

Design notes:

* **MVCC, not seqlocks.**  ``_state`` is a dict of immutable device
  arrays.  ``ingest`` rebinds it functionally under ``write_lock``;
  a reader grabs one GIL-atomic reference and dispatches against that
  consistent snapshot.  No generation counters, no retries, no torn
  reads — and no donation, so an in-flight reader's snapshot stays
  alive until its dispatch returns.
* **Sort-free kernels.**  Candidates are materialised newest-first by
  construction (ring order), validity is a mask, and the j-th valid
  entry is found with a cumsum prefix + unrolled binary search —
  XLA CPU sorts are an order of magnitude slower than the equivalent
  numpy sort, so the traced graph contains none.
* **Dedup at ingest.**  The ring is kept duplicate-free per
  ``(cluster, item)``: ingest tombstones the prior ring occurrence of
  each incoming item, so retrieve needs no dedup stage.  Cursor
  arithmetic still advances for *every* event, which keeps slot ages
  bitwise-identical to the host engine.
* **Two write modes.** ``delta_cap=0`` (default) scatters every ingest
  batch straight into the ring.  ``delta_cap=D`` appends to a small
  delta buffer and folds into the ring only when full (an LSM level of
  exactly one run) — retrieve scans delta-then-ring.  Delta mode makes
  per-shard ingest work scale as 1/S in ``ShardedQueueStore``.
* **Stable traces.**  Batch dims are padded to power-of-two buckets and
  ``k``/``Q``/``C``/``D`` are static, so steady state replays a handful
  of compiled traces.

``ShardedQueueStore`` partitions the cluster space into N contiguous
ranges behind the same API: ingest is split once by shard and scattered,
retrieve routes each request to its owning shard and merges.  With a
``jax.sharding.Mesh`` available, shard states are placed round-robin
across mesh devices (see ``repro.distributed.sharding``).

``ServingCostModel`` quantifies the paper's 83% claim: FLOPs + bytes per
request for online-KNN vs cluster-lookup serving at a given active-pool
size, traffic, request batch size, and shard count.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.obs import get_telemetry
from repro.core.serving_host import (   # noqa: F401  (compat re-exports)
    BufPool,
    HostQueueStore,
    ThreadLocalPools,
    _POOLS,
    dedup_topk_rows,
)


def _bucket(n: int, lo: int = 8) -> int:
    """Smallest power-of-two >= n (>= lo): pads dynamic batch dims onto a
    handful of stable jit traces."""
    b = lo
    while b < n:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# traced building blocks (composed inside the jitted entry points below)
# ---------------------------------------------------------------------------

def _candidate_window(st, cl, cutoff, C: int, Q: int, Deff: int):
    """Newest-first candidate window + validity mask for one row per
    (padded) cluster id.  ``cl < 0`` rows are fully invalid.  With
    ``Deff > 0`` the delta buffer (newest-first) is prepended to the
    ring window so selection order equals arrival order."""
    B = cl.shape[0]
    known = cl >= 0
    cl0 = jnp.where(known, cl, 0)
    total = st["total"][cl0]
    rtot = st["ring_total"][cl0] if Deff > 0 else total
    a = jnp.arange(Q, dtype=jnp.int32)[None, :]
    slot = jnp.mod(rtot[:, None] - 1 - a, Q)
    r_item = jnp.take_along_axis(st["items"][cl0], slot, axis=1)
    r_ts = jnp.take_along_axis(st["times"][cl0], slot, axis=1)
    r_valid = ((a < jnp.minimum(rtot, Q)[:, None])
               & (r_item >= 0) & (r_ts >= cutoff) & known[:, None])
    if Deff == 0:
        return r_item, r_valid
    r_shadow = jnp.take_along_axis(st["shadow"][cl0], slot, axis=1)
    r_age = a + (total - rtot)[:, None]        # age incl. pending deltas
    r_valid = r_valid & ~r_shadow & (r_age < Q)
    d_cl = st["d_cl"][:Deff][::-1][None, :]
    d_item = jnp.broadcast_to(st["d_item"][:Deff][::-1][None, :], (B, Deff))
    d_ts = st["d_ts"][:Deff][::-1][None, :]
    d_idx = st["d_idx"][:Deff][::-1][None, :]
    d_sh = st["d_shadow"][:Deff][::-1][None, :]
    mine = (d_cl == cl0[:, None]) & known[:, None]
    d_age = total[:, None] - 1 - d_idx
    d_valid = (mine & ~d_sh & (d_item >= 0) & (d_ts >= cutoff)
               & (d_age >= 0) & (d_age < Q))
    return (jnp.concatenate([d_item, r_item], axis=1),
            jnp.concatenate([d_valid, r_valid], axis=1))


def _select_topk(cand, valid, k: int):
    """First ``k`` valid candidates per row, in window order, ``-1``
    padded.  Sort-free: cumsum prefix + unrolled binary search for the
    j-th valid position."""
    B, W = cand.shape
    pref = jnp.cumsum(valid.astype(jnp.int32), axis=1)
    j = jnp.arange(k, dtype=jnp.int32)[None, :]
    lo = jnp.zeros((B, k), jnp.int32)
    step = 1
    while step < W:
        step *= 2
    step //= 2
    while step >= 1:
        mid = jnp.minimum(lo + step, W - 1)
        go = jnp.take_along_axis(pref, mid, axis=1) < j + 1
        lo = jnp.where(go, jnp.minimum(lo + step, W - 1), lo)
        step //= 2
    at0 = jnp.take_along_axis(pref, jnp.zeros_like(lo), axis=1) >= j + 1
    src = jnp.where(at0, 0, jnp.minimum(lo + 1, W - 1))
    got = jnp.take_along_axis(pref, src, axis=1) == j + 1
    out = jnp.where(got, jnp.take_along_axis(cand, src, axis=1), -1)
    return jnp.where(j < pref[:, -1][:, None], out, -1)


def _union_topk(seeds, i2i, k: int):
    """Traced U2I2I union: rank-major round-robin over the seeds'
    neighbor lists, seed + duplicate masking, first-k select.  Bitwise
    equal to the host ``u2i2i_retrieve_batch`` for identical seeds."""
    B, R = seeds.shape
    n, K = i2i.shape
    W = R * K
    seeded = (seeds >= 0) & (seeds < n)
    rows = jnp.take(i2i, jnp.clip(seeds, 0, n - 1), axis=0)     # (B,R,K)
    cand = jnp.where(seeded[:, :, None], rows, -1)
    flat = cand.transpose(0, 2, 1).reshape(B, W)                # rank-major
    seen = ((flat[:, :, None] == seeds[:, None, :])
            & (seeds >= 0)[:, None, :]).any(axis=2)
    valid = (flat >= 0) & ~seen
    tri = jnp.tril(jnp.ones((W, W), bool), -1)
    dup = ((flat[:, :, None] == flat[:, None, :])
           & valid[:, None, :] & tri[None]).any(axis=2)
    return _select_topk(flat, valid & ~dup, k)


# ---------------------------------------------------------------------------
# jitted entry points (one dispatch each)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("C", "Q"))
def _direct_ingest_jit(st, w_cl, slot, w_item, raw_item, rel, t_cl,
                       ucl, cnt, C, Q):
    """Direct mode: tombstone prior ring occurrences of incoming items,
    then scatter the batch's surviving writes and advance cursors.  Pad
    rows carry cluster ``C`` and fall out via ``mode="drop"``."""
    ring_rows = st["items"][jnp.clip(t_cl, 0, C - 1)]
    m = ((ring_rows == raw_item[:, None])
         & (raw_item >= 0)[:, None] & (t_cl < C)[:, None])
    q_hit = jnp.argmax(m, axis=1).astype(jnp.int32)
    has = m.any(axis=1)
    items = st["items"].at[jnp.where(has, t_cl, C), q_hit].set(-1,
                                                               mode="drop")
    items = items.at[w_cl, slot].set(w_item, mode="drop")
    times = st["times"].at[w_cl, slot].set(rel, mode="drop")
    total = st["total"].at[ucl].add(cnt, mode="drop")
    return dict(items=items, times=times, total=total)


@functools.partial(jax.jit, static_argnames=("C", "Q", "D", "Deff"))
def _append_jit(st, cl, w_item, raw_item, rel, d_idx, d0, n_real,
                C, Q, D, Deff):
    """Delta mode: append the batch to the delta buffer at ``d0``,
    shadowing prior occurrences in both the ring (bitmap) and the delta
    run (``d_shadow``)."""
    Ep = cl.shape[0]
    ar = jnp.arange(Ep, dtype=jnp.int32)
    is_real = ar < n_real
    dst = jnp.where(is_real, d0 + ar, D)
    ring_rows = st["items"][jnp.clip(cl, 0, C - 1)]
    m = ((ring_rows == raw_item[:, None])
         & (raw_item >= 0)[:, None] & is_real[:, None])
    q_hit = jnp.argmax(m, axis=1).astype(jnp.int32)
    has = m.any(axis=1)
    shadow = st["shadow"].at[jnp.where(has, cl, C), q_hit].set(True,
                                                               mode="drop")
    dm = ((st["d_cl"][:Deff][None, :] == cl[:, None])
          & (st["d_item"][:Deff][None, :] == raw_item[:, None])
          & (raw_item >= 0)[:, None] & is_real[:, None])
    d_shadow = st["d_shadow"].at[:Deff].set(st["d_shadow"][:Deff]
                                            | dm.any(axis=0))
    # return ONLY the keys this pass writes: a jitted pass-through of
    # the untouched (C, Q) ring arrays is a full device copy of them
    # per call (no donation), which would erase the 1/S sharding win
    return dict(
        shadow=shadow,
        d_shadow=d_shadow.at[dst].set(False, mode="drop"),
        d_cl=st["d_cl"].at[dst].set(cl, mode="drop"),
        d_item=st["d_item"].at[dst].set(w_item, mode="drop"),
        d_ts=st["d_ts"].at[dst].set(rel, mode="drop"),
        d_idx=st["d_idx"].at[dst].set(d_idx, mode="drop"),
        total=st["total"].at[jnp.where(is_real, cl, C)].add(
            1, mode="drop"))


@functools.partial(jax.jit, static_argnames=("C", "Q", "D"))
def _fold_jit(st, C, Q, D):
    """Fold the delta run into the ring: apply shadow tombstones, write
    each delta event to its slot (slot-LWW via a pairwise later-matrix),
    drop already-evicted events, and reset the delta buffer."""
    items = jnp.where(st["shadow"], -1, st["items"])
    times = st["times"]
    d_cl, d_item = st["d_cl"], st["d_item"]
    d_ts, d_idx = st["d_ts"], st["d_idx"]
    live = d_cl < C
    slot = jnp.where(live, d_idx % Q, 0)
    later = ((d_cl[None, :] == d_cl[:, None])
             & (slot[None, :] == slot[:, None])
             & (d_idx[None, :] > d_idx[:, None]) & live[None, :])
    wins = live & ~later.any(axis=1)
    age = st["total"][jnp.clip(d_cl, 0, C - 1)] - 1 - d_idx
    dead = ~wins | (age >= Q)
    w_item = jnp.where(st["d_shadow"], -1, d_item)
    row = jnp.where(dead, C, d_cl)
    # modified keys only (see _append_jit): `total` passes through
    return dict(
        items=items.at[row, slot].set(w_item, mode="drop"),
        times=times.at[row, slot].set(d_ts, mode="drop"),
        shadow=jnp.zeros_like(st["shadow"]),
        ring_total=st["total"],
        d_cl=jnp.full((D,), C, jnp.int32),
        d_item=jnp.full((D,), -1, jnp.int32),
        d_ts=jnp.full((D,), -jnp.inf, jnp.float32),
        d_idx=jnp.zeros((D,), jnp.int32),
        d_shadow=jnp.zeros((D,), jnp.bool_))


@functools.partial(jax.jit, static_argnames=("k", "C", "Q", "Deff"))
def _retrieve_jit(st, cl, cutoff, k, C, Q, Deff):
    cand, valid = _candidate_window(st, cl, cutoff, C, Q, Deff)
    return _select_topk(cand, valid, k)


@functools.partial(jax.jit,
                   static_argnames=("n_recent", "k", "C", "Q", "Deff"))
def _serve_jit(st, cl, i2i, cutoff, n_recent, k, C, Q, Deff):
    cand, valid = _candidate_window(st, cl, cutoff, C, Q, Deff)
    seeds = _select_topk(cand, valid, n_recent)
    return seeds, _union_topk(seeds, i2i, k)


# ---------------------------------------------------------------------------
# cluster-queue store (U2U2I) — device-resident
# ---------------------------------------------------------------------------

class ClusterQueueStore:
    """Real-time per-cluster item queues with recency filtering, resident
    on a jax device.

    Layout: ``_state`` holds dense ``(n_clusters, queue_len)``
    item/timestamp rings plus a per-cluster write counter ``total``
    (write position = ``total % queue_len``); ``delta_cap > 0`` adds a
    flat delta run that folds into the ring when full.  The ring is kept
    duplicate-free per ``(cluster, item)`` by tombstoning at ingest.

    Concurrency (MVCC): ``_state`` is immutable; writers rebind it under
    ``write_lock`` (an RLock — the swap engine's ring drain wraps
    ``ingest`` in the same lock), readers take one snapshot reference
    and dispatch a single jit against it.  The dispatch releases the
    GIL, so reader threads scale with cores.

    ``_cursor_host`` mirrors ``total`` on the host (writer-maintained, so
    ingest prep and telemetry never synchronise with the device).
    """

    def __init__(self, user_clusters: np.ndarray, *, queue_len: int = 256,
                 recency_s: float = 900.0, n_clusters: Optional[int] = None,
                 telemetry=None, delta_cap: int = 0, shard_tag: str = "",
                 device=None):
        self.tel = telemetry if telemetry is not None else get_telemetry()
        self.user_clusters = np.asarray(user_clusters, np.int64)
        self.queue_len = int(queue_len)
        self.recency_s = float(recency_s)
        if n_clusters is None:
            n_clusters = max(int(self.user_clusters.max()) + 1, 1) \
                if self.user_clusters.size else 1
        self.n_clusters = max(int(n_clusters), 1)
        self.delta_cap = int(delta_cap)
        C, Q, D = self.n_clusters, self.queue_len, self.delta_cap
        state = dict(
            items=jnp.full((C, Q), -1, jnp.int32),
            # timestamps are stored float32 relative to the first-seen
            # event (absolute unix-epoch seconds lose ~100s of precision
            # in f32)
            times=jnp.full((C, Q), -np.inf, jnp.float32),
            total=jnp.zeros((C,), jnp.int32),
        )
        if D > 0:
            state.update(
                shadow=jnp.zeros((C, Q), jnp.bool_),
                ring_total=jnp.zeros((C,), jnp.int32),
                d_cl=jnp.full((D,), C, jnp.int32),
                d_item=jnp.full((D,), -1, jnp.int32),
                d_ts=jnp.full((D,), -np.inf, jnp.float32),
                d_idx=jnp.zeros((D,), jnp.int32),
                d_shadow=jnp.zeros((D,), jnp.bool_),
            )
        if device is not None:
            state = jax.device_put(state, device)
        self._state = state
        self._cursor_host = np.zeros(C, np.int64)
        self.d_count = 0               # filled delta slots (writer-only)
        self.epoch: Optional[float] = None
        self.write_lock = threading.RLock()
        self.ring_seen = 0     # EventRing watermark (maintained by swap)
        self.shard_tag = shard_tag
        self._m_ingest = "serving.ingest_events" + shard_tag
        self._m_requests = "serving.retrieve_requests" + shard_tag
        self._m_latency = "serving.retrieve_latency_s" + shard_tag
        self._m_depth_max = "serving.queue_depth_max" + shard_tag
        self._m_depth_mean = "serving.queue_depth_mean" + shard_tag
        self._m_unknown_ev = "serving.unknown_user_events" + shard_tag
        self._m_unknown_rq = "serving.unknown_user_requests" + shard_tag
        self._i2i_cache: Optional[Tuple[int, jnp.ndarray]] = None

    # -- cluster assignment lookup ------------------------------------------

    def clusters_of(self, user_ids: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Cluster ids for a batch of users plus a known-user mask.

        Users outside the assignment table — ids minted *after* the
        snapshot this store serves was published — and users whose table
        entry is negative (clusters owned by a different shard) map to
        cluster 0 with ``known=False``; callers must mask their rows out
        rather than crash or serve another user's cluster.
        """
        user_ids = np.asarray(user_ids, np.int64).ravel()
        known = (user_ids >= 0) & (user_ids < self.user_clusters.shape[0])
        cl = self.user_clusters[np.where(known, user_ids, 0)]
        known = known & (cl >= 0)
        return np.where(known, cl, 0), known

    # -- ingestion ----------------------------------------------------------

    def ingest(self, user_ids: np.ndarray, item_ids: np.ndarray,
               timestamps: np.ndarray, *, _presorted: bool = False) -> None:
        """Stream a batch of engagement events into their users' cluster
        ring buffers (oldest-to-newest so ring order is time order within
        the batch).  Events from users unknown to this snapshot's
        assignment table are dropped (they enter queues once the next
        publication assigns them a cluster).

        The device scatter happens behind ``write_lock``; readers keep
        dispatching against the previous ``_state`` snapshot and observe
        the batch atomically when the rebind lands."""
        user_ids = np.asarray(user_ids, np.int64).ravel()
        item_ids = np.asarray(item_ids, np.int64).ravel()
        ts64 = np.asarray(timestamps, np.float64).ravel()
        cl_all, known = self.clusters_of(user_ids)
        if not known.all():
            # graceful degradation: post-snapshot users are shed, not
            # errored — the drop is surfaced as a counter so staleness
            # between publications is observable
            if self.tel.enabled:
                self.tel.counter(self._m_unknown_ev, float((~known).sum()))
            cl_all = cl_all[known]
            item_ids = item_ids[known]
            ts64 = ts64[known]
        if cl_all.size == 0:
            return
        with self.write_lock:
            if self.epoch is None:
                self.epoch = float(ts64.min())
            rel = (ts64 - self.epoch).astype(np.float32)
            cl = cl_all.astype(np.int32)
            it = item_ids.astype(np.int32)
            if not _presorted:
                order = np.argsort(rel, kind="stable")
                cl, it, rel = cl[order], it[order], rel[order]
            if self.delta_cap:
                n, done = cl.size, 0
                while done < n:
                    take = min(n - done, self.delta_cap - self.d_count)
                    if take == 0:
                        self._fold()
                        continue
                    self._append(cl[done:done + take],
                                 it[done:done + take],
                                 rel[done:done + take])
                    done += take
            else:
                self._direct_ingest(cl, it, rel)
        tel = self.tel
        if tel.enabled:
            tel.counter(self._m_ingest, float(cl.size))
            fill = np.minimum(self._cursor_host[np.unique(cl)],
                              self.queue_len)
            tel.gauge(self._m_depth_max, float(fill.max()))
            tel.gauge(self._m_depth_mean, float(fill.mean()))

    def _direct_ingest(self, cl: np.ndarray, it: np.ndarray,
                       rel: np.ndarray) -> None:
        """Direct mode: host-side batch prep (slot assignment, in-batch
        LWW) then one jitted scatter.  Reentrant under ``ingest``'s
        lock."""
        with self.write_lock:
            E = cl.size
            C, Q = self.n_clusters, self.queue_len
            # per-event sequence index within its cluster (vectorized):
            # stable sort by cluster keeps time order inside each group
            o = np.argsort(cl, kind="stable")
            sc = cl[o]
            start = np.zeros(E, np.int64)
            if E > 1:
                idx = np.arange(1, E)
                start[1:] = np.where(sc[1:] == sc[:-1], 0, idx)
                np.maximum.accumulate(start, out=start)
            rank = np.arange(E) - start
            seq = np.empty(E, np.int64)
            seq[o] = self._cursor_host[sc] + rank
            slot = (seq % Q).astype(np.int32)
            # slot LWW (in-batch ring wrap): last event per (cl, slot)
            skey = cl.astype(np.int64) * Q + slot
            _, li = np.unique(skey[::-1], return_index=True)
            keep = np.zeros(E, bool)
            keep[E - 1 - li] = True
            # in-batch item LWW: earlier duplicate of (cl, item) becomes
            # a tombstone so the ring stays duplicate-free
            ikey = cl.astype(np.int64) << 32 | it.astype(np.int64)
            _, li2 = np.unique(ikey[::-1], return_index=True)
            w_item = np.full(E, -1, np.int32)
            last = E - 1 - li2
            w_item[last] = it[last]
            ucl, cnt = np.unique(cl, return_counts=True)
            pad = _bucket(E) - E
            Cp = _bucket(ucl.size)
            self._state = _direct_ingest_jit(
                self._state,
                jnp.asarray(np.pad(np.where(keep, cl, C), (0, pad),
                                   constant_values=C).astype(np.int32)),
                jnp.asarray(np.pad(slot, (0, pad))),
                jnp.asarray(np.pad(w_item, (0, pad), constant_values=-1)),
                jnp.asarray(np.pad(it, (0, pad), constant_values=-1)),
                jnp.asarray(np.pad(rel, (0, pad),
                                   constant_values=-np.inf)),
                jnp.asarray(np.pad(cl, (0, pad), constant_values=C)),
                jnp.asarray(np.pad(ucl, (0, Cp - ucl.size),
                                   constant_values=C).astype(np.int32)),
                jnp.asarray(np.pad(cnt, (0, Cp - ucl.size)
                                   ).astype(np.int32)),
                C, Q)
            self._cursor_host[ucl] += cnt

    def _append(self, cl: np.ndarray, it: np.ndarray,
                rel: np.ndarray) -> None:
        """Delta mode: append ``E <= delta_cap - d_count`` events to the
        delta run.  Reentrant under ``ingest``'s lock."""
        with self.write_lock:
            E = cl.size
            C, Q, D = self.n_clusters, self.queue_len, self.delta_cap
            o = np.argsort(cl, kind="stable")
            sc = cl[o]
            start = np.zeros(E, np.int64)
            if E > 1:
                idx = np.arange(1, E)
                start[1:] = np.where(sc[1:] == sc[:-1], 0, idx)
                np.maximum.accumulate(start, out=start)
            rank = np.arange(E) - start
            d_idx = np.empty(E, np.int64)
            d_idx[o] = self._cursor_host[sc] + rank
            key = cl.astype(np.int64) << 32 | it.astype(np.int64)
            _, li = np.unique(key[::-1], return_index=True)
            last = E - 1 - li
            w_item = np.full(E, -1, np.int32)
            w_item[last] = it[last]
            ucl, cnt = np.unique(cl, return_counts=True)
            pad = _bucket(E) - E
            self._state = {**self._state, **_append_jit(
                self._state,
                jnp.asarray(np.pad(cl, (0, pad), constant_values=C)),
                jnp.asarray(np.pad(w_item, (0, pad), constant_values=-1)),
                jnp.asarray(np.pad(it, (0, pad), constant_values=-1)),
                jnp.asarray(np.pad(rel, (0, pad),
                                   constant_values=-np.inf)),
                jnp.asarray(np.pad(d_idx, (0, pad)).astype(np.int32)),
                jnp.int32(self.d_count), jnp.int32(E),
                C, Q, D, D)}
            self.d_count += E
            self._cursor_host[ucl] += cnt

    def _fold(self) -> None:
        """Fold the pending delta run into the ring (no-op when empty).
        Reentrant under ``ingest``'s lock."""
        with self.write_lock:
            if self.d_count == 0:
                return
            self._state = {**self._state,
                           **_fold_jit(self._state, self.n_clusters,
                                       self.queue_len, self.delta_cap)}
            self.d_count = 0

    # -- retrieval ----------------------------------------------------------

    def rel_cutoff(self, now: float) -> float:
        """Recency cutoff in the store's internal (epoch-relative) time."""
        return now - self.recency_s - (self.epoch or 0.0)

    def _padded_clusters(self, user_ids: np.ndarray
                         ) -> Tuple[np.ndarray, np.ndarray, int,
                                    np.ndarray, np.ndarray]:
        """Dedup a request batch down to its unique cluster rows (padded
        to a power-of-two bucket) — most of a production batch shares
        clusters, and broadcasting rows back via the inverse is exact."""
        cl, known = self.clusters_of(user_ids)
        cl = np.where(known, cl, -1)
        ucl, inv = np.unique(cl, return_inverse=True)
        Bu = ucl.size
        cl_p = np.pad(ucl, (0, _bucket(Bu) - Bu),
                      constant_values=-1).astype(np.int32)
        return cl_p, inv, Bu, cl, known

    def retrieve_batch(self, user_ids: np.ndarray, now: float,
                       k: int) -> np.ndarray:
        """Batched U2U2I: ``(B,)`` user ids -> ``(B, k)`` item ids,
        newest-first, recency-filtered, deduped, ``-1``-padded.  One
        snapshot read + one jitted dispatch; safe to call from many
        threads at once (MVCC — no locks on this path)."""
        tel = self.tel
        t0 = tel.clock.perf() if tel.enabled else 0.0
        user_ids = np.asarray(user_ids, np.int64).ravel()
        cl_p, inv, Bu, _, known = self._padded_clusters(user_ids)
        st = self._state                 # one GIL-atomic snapshot read
        out = _retrieve_jit(st, jnp.asarray(cl_p),
                            jnp.float32(self.rel_cutoff(now)), int(k),
                            self.n_clusters, self.queue_len,
                            self.delta_cap)
        res = np.asarray(out)[:Bu][inv].astype(np.int64)
        if tel.enabled:
            tel.observe(self._m_latency, tel.clock.perf() - t0)
            tel.counter(self._m_requests)
            if not known.all():
                tel.counter(self._m_unknown_rq, float((~known).sum()))
        return res

    def retrieve(self, user_id: int, now: float, k: int) -> List[int]:
        """Legacy single-request U2U2I — a batch of one."""
        row = self.retrieve_batch(np.array([user_id]), now, k)[0]
        return [int(i) for i in row if i >= 0]

    def _i2i_device(self, i2i: np.ndarray):
        """Device copy of the I2I table, cached by identity (the table is
        rebuilt only at embedding refresh, so one transfer per swap)."""
        cached = self._i2i_cache
        if cached is not None and cached[0] == id(i2i):
            return cached[1]
        dev = jnp.asarray(np.asarray(i2i, np.int32))
        self._i2i_cache = (id(i2i), dev)
        return dev

    def _ring_view(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Consistent host view ``(items, times, cursor)`` of the ring
        for the Pallas kernel path (delta mode folds first so the ring
        is complete)."""
        if self.delta_cap:
            with self.write_lock:
                self._fold()
                st = self._state
        else:
            st = self._state
        return (np.asarray(st["items"]), np.asarray(st["times"]),
                np.asarray(st["total"]).astype(np.int64))

    @property
    def items(self) -> np.ndarray:
        return self._ring_view()[0]

    @property
    def times(self) -> np.ndarray:
        return self._ring_view()[1]

    @property
    def cursor(self) -> np.ndarray:
        return self._cursor_host

    def serve_batch(self, user_ids: np.ndarray, now: float, *,
                    n_recent: int = 8, k: int = 32,
                    i2i: Optional[np.ndarray] = None,
                    use_kernel: bool = False
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Full serving pass: U2U2I seeds ``(B, n_recent)`` plus — when an
        ``i2i`` table is given — the U2I2I round-robin union ``(B, k)``.
        The default path fuses both stages into a single jitted dispatch;
        ``use_kernel=True`` routes through the fused Pallas
        ``queue_gather`` kernel on a host snapshot of the ring."""
        if i2i is not None and use_kernel:
            from repro.kernels.queue_gather.ops import queue_gather
            user_ids = np.asarray(user_ids, np.int64).ravel()
            cl, known = self.clusters_of(user_ids)
            items, times, cursor = self._ring_view()
            s, u = queue_gather(items, times, cursor, cl, i2i,
                                cutoff=self.rel_cutoff(now),
                                n_recent=n_recent, k=k)
            seeds = np.asarray(s, np.int64)
            union = np.asarray(u, np.int64)
            if not known.all():
                seeds[~known] = -1       # unknown users: empty rows
                union[~known] = -1
                if self.tel.enabled:
                    self.tel.counter(self._m_unknown_rq,
                                     float((~known).sum()))
            return seeds, union
        if i2i is None:
            seeds = self.retrieve_batch(user_ids, now, n_recent)
            return seeds, np.full((seeds.shape[0], k), -1, np.int64)
        tel = self.tel
        t0 = tel.clock.perf() if tel.enabled else 0.0
        user_ids = np.asarray(user_ids, np.int64).ravel()
        cl_p, inv, Bu, _, known = self._padded_clusters(user_ids)
        st = self._state
        s, u = _serve_jit(st, jnp.asarray(cl_p), self._i2i_device(i2i),
                          jnp.float32(self.rel_cutoff(now)),
                          int(n_recent), int(k),
                          self.n_clusters, self.queue_len, self.delta_cap)
        seeds = np.asarray(s)[:Bu][inv].astype(np.int64)
        union = np.asarray(u)[:Bu][inv].astype(np.int64)
        if tel.enabled:
            tel.observe(self._m_latency, tel.clock.perf() - t0)
            tel.counter(self._m_requests)
            if not known.all():
                tel.counter(self._m_unknown_rq, float((~known).sum()))
        return seeds, union

    # -- introspection ------------------------------------------------------

    def partitions(self) -> Tuple["ClusterQueueStore", ...]:
        """Uniform shard view: an unsharded store is its own single
        partition."""
        return (self,)

    def stats(self) -> Dict[str, float]:
        fill = np.minimum(self._cursor_host, self.queue_len)
        active = fill > 0
        return dict(n_shards=1,
                    n_clusters_active=int(active.sum()),
                    mean_queue=float(fill[active].mean())
                    if active.any() else 0.0,
                    delta_pending=float(self.d_count))


# ---------------------------------------------------------------------------
# sharded store: N contiguous cluster ranges behind one router
# ---------------------------------------------------------------------------

class ShardedQueueStore:
    """``ClusterQueueStore`` partitioned into ``n_shards`` contiguous
    cluster ranges behind the same API.

    Routing is by cluster id: ingest sorts the batch by time once, splits
    it by owning shard, and scatters; retrieve routes each request to its
    shard and merges rows back in request order.  Each shard holds a
    full-length user->cluster sub-table (out-of-range users map to
    ``-1`` = unknown), so a shard can never serve another shard's
    cluster.  The relative-time epoch is global — fixed from the first
    ingested batch and broadcast to every shard before any shard sees an
    event — so timestamps, and therefore retrieve results, are bitwise
    identical to an unsharded store over the same stream.

    With a ``jax.sharding.Mesh``, shard states are placed round-robin
    over ``mesh.devices``; on a single-device host the win comes from
    ``delta_cap``: per-shard ingest work (delta scans, fold matrices)
    shrinks as 1/S.

    Telemetry: each shard reports under a ``.shard{i}`` suffix; the
    facade emits the untagged aggregate series.
    """

    def __init__(self, user_clusters: np.ndarray, *, n_shards: int,
                 queue_len: int = 256, recency_s: float = 900.0,
                 n_clusters: Optional[int] = None, delta_cap: int = 0,
                 telemetry=None, mesh=None):
        self.tel = telemetry if telemetry is not None else get_telemetry()
        self.user_clusters = np.asarray(user_clusters, np.int64)
        if n_clusters is None:
            n_clusters = max(int(self.user_clusters.max()) + 1, 1) \
                if self.user_clusters.size else 1
        self.n_clusters = max(int(n_clusters), 1)
        self.n_shards = max(int(n_shards), 1)
        self.queue_len = int(queue_len)
        self.recency_s = float(recency_s)
        self.delta_cap = int(delta_cap)
        self.bounds = np.linspace(0, self.n_clusters,
                                  self.n_shards + 1).astype(np.int64)
        devices = None
        if mesh is not None:
            devices = list(np.asarray(mesh.devices).ravel())
        shards = []
        spans = []
        uc = self.user_clusters
        for s in range(self.n_shards):
            lo, hi = int(self.bounds[s]), int(self.bounds[s + 1])
            sub = np.where((uc >= lo) & (uc < hi), uc - lo, -1)
            shards.append(ClusterQueueStore(
                sub, queue_len=self.queue_len, recency_s=self.recency_s,
                n_clusters=max(hi - lo, 1), telemetry=self.tel,
                delta_cap=self.delta_cap, shard_tag=f".shard{s}",
                device=devices[s % len(devices)] if devices else None))
            spans.append((lo, hi))
        self.shards: Tuple[ClusterQueueStore, ...] = tuple(shards)
        self._spans = tuple(spans)
        self.epoch: Optional[float] = None
        self.write_lock = threading.RLock()
        self.ring_seen = 0     # EventRing watermark (maintained by swap)

    # -- routing ------------------------------------------------------------

    def clusters_of(self, user_ids: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Global cluster ids + known mask (same contract as the
        unsharded store)."""
        user_ids = np.asarray(user_ids, np.int64).ravel()
        known = (user_ids >= 0) & (user_ids < self.user_clusters.shape[0])
        cl = self.user_clusters[np.where(known, user_ids, 0)]
        known = known & (cl >= 0)
        return np.where(known, cl, 0), known

    def _shard_of(self, cl: np.ndarray, known: np.ndarray) -> np.ndarray:
        sid = np.searchsorted(self.bounds, cl, side="right") - 1
        return np.where(known, sid, -1)

    # -- ingestion ----------------------------------------------------------

    def ingest(self, user_ids: np.ndarray, item_ids: np.ndarray,
               timestamps: np.ndarray) -> None:
        """Sort the batch by time once, split by owning shard, scatter.
        Per-shard ingests skip their own sort (``_presorted``)."""
        user_ids = np.asarray(user_ids, np.int64).ravel()
        item_ids = np.asarray(item_ids, np.int64).ravel()
        ts64 = np.asarray(timestamps, np.float64).ravel()
        cl, known = self.clusters_of(user_ids)
        if not known.all():
            if self.tel.enabled:
                self.tel.counter("serving.unknown_user_events",
                                 float((~known).sum()))
            user_ids = user_ids[known]
            item_ids = item_ids[known]
            ts64 = ts64[known]
            cl = cl[known]
        if cl.size == 0:
            return
        with self.write_lock:
            if self.epoch is None:
                # fix the global epoch before ANY shard ingests so every
                # shard stores identical relative timestamps
                self.epoch = float(ts64.min())
                for sh in self.shards:
                    with sh.write_lock:
                        sh.epoch = self.epoch
            # sort by the same f32 relative key the unsharded store uses
            # (stable), so per-shard ring order is bitwise-identical
            rel = (ts64 - self.epoch).astype(np.float32)
            order = np.argsort(rel, kind="stable")
            user_ids, item_ids = user_ids[order], item_ids[order]
            ts64, cl = ts64[order], cl[order]
            sid = np.searchsorted(self.bounds, cl, side="right") - 1
            for s, sh in enumerate(self.shards):
                m = sid == s
                if m.any():
                    sh.ingest(user_ids[m], item_ids[m], ts64[m],
                              _presorted=True)
        tel = self.tel
        if tel.enabled:
            tel.counter("serving.ingest_events", float(cl.size))
            fill = np.minimum(self.cursor[np.unique(cl)], self.queue_len)
            tel.gauge("serving.queue_depth_max", float(fill.max()))
            tel.gauge("serving.queue_depth_mean", float(fill.mean()))

    # -- retrieval ----------------------------------------------------------

    def rel_cutoff(self, now: float) -> float:
        return now - self.recency_s - (self.epoch or 0.0)

    def retrieve_batch(self, user_ids: np.ndarray, now: float,
                       k: int) -> np.ndarray:
        """Route each request to its owning shard, gather, merge back in
        request order.  Unknown users get ``-1`` rows without touching
        any shard."""
        tel = self.tel
        t0 = tel.clock.perf() if tel.enabled else 0.0
        user_ids = np.asarray(user_ids, np.int64).ravel()
        cl, known = self.clusters_of(user_ids)
        sid = self._shard_of(cl, known)
        out = np.full((user_ids.size, int(k)), -1, np.int64)
        for s, sh in enumerate(self.shards):
            m = sid == s
            if m.any():
                out[m] = sh.retrieve_batch(user_ids[m], now, k)
        if tel.enabled:
            tel.observe("serving.retrieve_latency_s", tel.clock.perf() - t0)
            tel.counter("serving.retrieve_requests")
            if not known.all():
                tel.counter("serving.unknown_user_requests",
                            float((~known).sum()))
        return out

    def retrieve(self, user_id: int, now: float, k: int) -> List[int]:
        row = self.retrieve_batch(np.array([user_id]), now, k)[0]
        return [int(i) for i in row if i >= 0]

    def serve_batch(self, user_ids: np.ndarray, now: float, *,
                    n_recent: int = 8, k: int = 32,
                    i2i: Optional[np.ndarray] = None,
                    use_kernel: bool = False
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Scatter the serve pass across shards and merge both outputs."""
        user_ids = np.asarray(user_ids, np.int64).ravel()
        cl, known = self.clusters_of(user_ids)
        sid = self._shard_of(cl, known)
        seeds = np.full((user_ids.size, int(n_recent)), -1, np.int64)
        union = np.full((user_ids.size, int(k)), -1, np.int64)
        for s, sh in enumerate(self.shards):
            m = sid == s
            if m.any():
                s_out, u_out = sh.serve_batch(user_ids[m], now,
                                              n_recent=n_recent, k=k,
                                              i2i=i2i,
                                              use_kernel=use_kernel)
                seeds[m] = s_out
                union[m] = u_out
        return seeds, union

    # -- introspection ------------------------------------------------------

    @property
    def cursor(self) -> np.ndarray:
        """Global per-cluster write counts (shard ranges are contiguous,
        so shard cursors concatenate into the global table)."""
        return np.concatenate(
            [sh._cursor_host[:hi - lo]
             for sh, (lo, hi) in zip(self.shards, self._spans)])

    @property
    def items(self) -> np.ndarray:
        return np.concatenate(
            [sh.items[:hi - lo]
             for sh, (lo, hi) in zip(self.shards, self._spans)], axis=0)

    @property
    def times(self) -> np.ndarray:
        return np.concatenate(
            [sh.times[:hi - lo]
             for sh, (lo, hi) in zip(self.shards, self._spans)], axis=0)

    def partitions(self) -> Tuple[ClusterQueueStore, ...]:
        return self.shards

    def stats(self) -> Dict[str, float]:
        fill = np.minimum(self.cursor, self.queue_len)
        active = fill > 0
        out = dict(n_shards=self.n_shards,
                   n_clusters_active=int(active.sum()),
                   mean_queue=float(fill[active].mean())
                   if active.any() else 0.0,
                   delta_pending=float(sum(sh.d_count
                                           for sh in self.shards)))
        for s, sh in enumerate(self.shards):
            for key, v in sh.stats().items():
                if key != "n_shards":
                    out[f"shard{s}.{key}"] = v
        return out


# ---------------------------------------------------------------------------
# offline I2I KNN (U2I2I)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _topk_scorer(kk: int, exclude_self: bool):
    """Jitted chunk scorer: cosine top-k against the full item set with
    the diagonal masked.  One compile per (k, exclude_self); chunk rows
    are padded to a fixed shape so every chunk hits the same trace."""

    @jax.jit
    def score(chunk_e, all_e, row0):
        sims = chunk_e @ all_e.T                             # (C, n)
        if exclude_self:
            cols = jnp.arange(sims.shape[1])[None, :]
            own = row0 + jnp.arange(sims.shape[0])[:, None]
            sims = jnp.where(cols == own, -jnp.inf, sims)
        _, idx = jax.lax.top_k(sims, kk)
        return idx

    return score


def build_i2i_knn(item_emb: np.ndarray, k: int, *, chunk: int = 2048,
                  exclude_self: bool = True) -> np.ndarray:
    """(n_items, k) most-similar items by cosine; computed offline after
    each embedding refresh (cheap: item embeddings update infrequently).
    The chunk loop runs a single jitted top-k scorer — no per-row numpy
    argpartition/argsort passes."""
    e = item_emb / np.maximum(
        np.linalg.norm(item_emb, axis=1, keepdims=True), 1e-8)
    e = e.astype(np.float32)
    n = len(e)
    kk = min(k, n - 1)
    if kk <= 0:      # 0- or 1-item corpus: no neighbors exist at all
        return np.full((n, k), -1, np.int64)
    chunk = min(chunk, n)
    score = _topk_scorer(kk, exclude_self)
    out = np.empty((n, kk), np.int64)
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)
        rows = e[lo:hi]
        if hi - lo < chunk:                      # pad: keep one traced shape
            rows = np.pad(rows, ((0, chunk - (hi - lo)), (0, 0)))
        out[lo:hi] = np.asarray(score(rows, e, lo))[: hi - lo]
    if kk < k:
        out = np.pad(out, ((0, 0), (0, k - kk)), constant_values=-1)
    return out


def u2i2i_retrieve_batch(i2i: np.ndarray, recent_items: np.ndarray,
                         k: int) -> np.ndarray:
    """Batched U2I2I: union the similar-item lists of each row's recent
    items ``(B, R)`` (``-1`` = padding), round-robin across ranks to
    preserve per-seed ordering, mask the seeds themselves, dedup, and
    return ``(B, k)`` ``-1``-padded candidates."""
    recent = np.asarray(recent_items, np.int64)
    B, R = recent.shape
    K = i2i.shape[1]
    nonneg = recent >= 0
    # seeds past the end of the table contribute no neighbors (queues see
    # brand-new items before the next offline I2I refresh covers them)
    seeded = nonneg & (recent < i2i.shape[0])
    cand = np.asarray(i2i, np.int32)[np.where(seeded, recent, 0)]  # (B,R,K)
    cand = np.where(seeded[:, :, None], cand, -1)
    flat = cand.reshape(B, R * K)                        # seed-major layout
    # round-robin emission priority of the seed per-request loop (rank 0
    # of every seed, then rank 1, ...) as a per-column key — no need to
    # physically transpose into rank-major order
    col = np.arange(R * K, dtype=np.int32)
    prio = (col % K) * R + col // K
    # every non-negative seed is masked from the union, including ones
    # the table does not cover (a candidate may still equal them)
    seen = (flat[:, :, None] ==
            np.where(nonneg, recent, -2)[:, None, :]).any(axis=2)
    valid = (flat >= 0) & ~seen
    return dedup_topk_rows(flat, prio[None, :], valid, k, R * K)


def u2i2i_retrieve(i2i: np.ndarray, recent_items: Sequence[int],
                   k: int) -> List[int]:
    """Legacy single-request U2I2I — a batch of one."""
    recent = np.asarray(list(recent_items), np.int64).reshape(1, -1)
    if recent.size == 0:
        return []
    row = u2i2i_retrieve_batch(i2i, recent, k)[0]
    return [int(i) for i in row if i >= 0]


# ---------------------------------------------------------------------------
# serving cost model (the 83% claim)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServingCostModel:
    """Per-request compute/memory cost of U2U2I serving strategies.

    Online KNN: every request scores the query user against the active
    pool (exact or IVF-style approximate with n_probe fraction scanned).
    Cluster index: assign-once per embedding refresh (amortized ~0) +
    O(1) queue read per request.  ``batch_size`` models the batched
    engine: per-launch fixed costs (cursor/metadata reads, dispatch) are
    amortized across the request batch.  ``n_shards`` models the sharded
    router: the single-dispatch retrieve becomes one dispatch per shard
    touched by the batch, so launch overheads scale with the shard
    count while per-request work does not.
    """
    d: int = 256
    active_pool: int = 5_000_000       # recently-active users (15 min)
    qps: float = 1e6
    n_probe_frac: float = 0.05         # ANN scans ~5% of the pool
    queue_read_items: int = 64
    rq_codes: Tuple[int, ...] = (5000, 50)
    batch_size: int = 1
    n_shards: int = 1
    launch_bytes: float = 64 * 1024.0  # per-launch metadata + dispatch
    launch_flops: float = 4 * 1024.0

    def _batch(self, batch_size: Optional[int]) -> int:
        return max(int(batch_size if batch_size is not None
                       else self.batch_size), 1)

    def knn_flops_per_req(self, exact: bool = False) -> float:
        frac = 1.0 if exact else self.n_probe_frac
        return 2.0 * self.d * self.active_pool * frac

    def knn_bytes_per_req(self, exact: bool = False) -> float:
        frac = 1.0 if exact else self.n_probe_frac
        return 4.0 * self.d * self.active_pool * frac

    def cluster_flops_per_req(self, batch_size: Optional[int] = None
                              ) -> float:
        # queue read: no dot products at request time; assignment cost is
        # amortized into the embedding-refresh batch job:
        assign = 2.0 * self.d * sum(self.rq_codes)      # per refresh
        refresh_period_s = 3 * 3600.0
        amortized = assign / max(self.qps * refresh_period_s /
                                 max(self.active_pool, 1), 1e-9)
        return amortized + (max(self.n_shards, 1) * self.launch_flops
                            / self._batch(batch_size))

    def cluster_bytes_per_req(self, batch_size: Optional[int] = None
                              ) -> float:
        # queue read + code read per request; launch cost (one dispatch
        # per shard) amortized over the batch served per dispatch
        return (8.0 * self.queue_read_items + 8.0
                + (max(self.n_shards, 1) * self.launch_bytes
                   / self._batch(batch_size)))

    def cost_reduction(self, batch_size: Optional[int] = None) -> float:
        """Fractional serving-cost reduction (bytes+flops weighted by a
        machine-cost proxy: memory-bandwidth bound at serving tier)."""
        knn = self.knn_bytes_per_req()
        cl = self.cluster_bytes_per_req(batch_size)
        return 1.0 - cl / max(knn, 1e-9)
