"""KNN-free serving (paper §4.4).

U2U2I: each user carries a hierarchical cluster code (k1, k2) from the
co-learned RQ index; each cluster keeps a recency-filtered queue of items
engaged by its recently-active members.  Serving = read the target
user's cluster queue (a lookup), instead of online KNN over the active
user pool.

U2I2I: item embeddings change slowly, so I2I KNN is pre-computed offline;
serving unions the similar-item lists of the user's recent items.

``ServingCostModel`` quantifies the paper's 83% claim: FLOPs + bytes per
request for online-KNN vs cluster-lookup serving at a given active-pool
size and traffic.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# cluster-queue store (U2U2I)
# ---------------------------------------------------------------------------

class ClusterQueueStore:
    """Real-time per-cluster item queues with recency filtering."""

    def __init__(self, user_clusters: np.ndarray, *, queue_len: int = 256,
                 recency_s: float = 900.0):
        self.user_clusters = user_clusters        # (n_users,) flat codes
        self.queue_len = queue_len
        self.recency_s = recency_s
        self.queues: Dict[int, deque] = {}

    def ingest(self, user_ids: np.ndarray, item_ids: np.ndarray,
               timestamps: np.ndarray) -> None:
        """Stream engagement events into their users' cluster queues."""
        cl = self.user_clusters[user_ids]
        order = np.argsort(timestamps, kind="stable")
        for c, it, ts in zip(cl[order], item_ids[order], timestamps[order]):
            q = self.queues.get(int(c))
            if q is None:
                q = deque(maxlen=self.queue_len)
                self.queues[int(c)] = q
            q.append((float(ts), int(it)))

    def retrieve(self, user_id: int, now: float, k: int) -> List[int]:
        """U2U2I = read latest recency-filtered items of the user's cluster."""
        q = self.queues.get(int(self.user_clusters[user_id]))
        if not q:
            return []
        cutoff = now - self.recency_s
        out: List[int] = []
        seen = set()
        for ts, it in reversed(q):            # newest first
            if ts < cutoff:
                break
            if it not in seen:
                seen.add(it)
                out.append(it)
            if len(out) >= k:
                break
        return out

    def stats(self) -> Dict[str, float]:
        sizes = [len(q) for q in self.queues.values()]
        return dict(n_clusters_active=len(sizes),
                    mean_queue=float(np.mean(sizes)) if sizes else 0.0)


# ---------------------------------------------------------------------------
# offline I2I KNN (U2I2I)
# ---------------------------------------------------------------------------

def build_i2i_knn(item_emb: np.ndarray, k: int, *, chunk: int = 2048,
                  exclude_self: bool = True) -> np.ndarray:
    """(n_items, k) most-similar items by cosine; computed offline after
    each embedding refresh (cheap: item embeddings update infrequently)."""
    e = item_emb / np.maximum(
        np.linalg.norm(item_emb, axis=1, keepdims=True), 1e-8)
    n = len(e)
    kk = min(k, n - 1)
    out = np.empty((n, kk), np.int64)
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)
        sims = e[lo:hi] @ e.T
        if exclude_self:
            sims[np.arange(hi - lo), np.arange(lo, hi)] = -np.inf
        top = np.argpartition(-sims, kk - 1, axis=1)[:, :kk]
        rows = np.arange(hi - lo)[:, None]
        o = np.argsort(-sims[rows, top], axis=1, kind="stable")
        out[lo:hi] = top[rows, o]
    if kk < k:
        out = np.pad(out, ((0, 0), (0, k - kk)), constant_values=-1)
    return out


def u2i2i_retrieve(i2i: np.ndarray, recent_items: Sequence[int],
                   k: int) -> List[int]:
    """Union of similar-item lists over the user's engaged items,
    round-robin to preserve per-seed ranking."""
    out: List[int] = []
    seen = set(int(i) for i in recent_items)
    for rank in range(i2i.shape[1]):
        for it in recent_items:
            cand = int(i2i[int(it), rank])
            if cand >= 0 and cand not in seen:
                seen.add(cand)
                out.append(cand)
                if len(out) >= k:
                    return out
    return out


# ---------------------------------------------------------------------------
# serving cost model (the 83% claim)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServingCostModel:
    """Per-request compute/memory cost of U2U2I serving strategies.

    Online KNN: every request scores the query user against the active
    pool (exact or IVF-style approximate with n_probe fraction scanned).
    Cluster index: assign-once per embedding refresh (amortized ~0) +
    O(1) queue read per request.
    """
    d: int = 256
    active_pool: int = 5_000_000       # recently-active users (15 min)
    qps: float = 1e6
    n_probe_frac: float = 0.05         # ANN scans ~5% of the pool
    queue_read_items: int = 64
    rq_codes: Tuple[int, ...] = (5000, 50)

    def knn_flops_per_req(self, exact: bool = False) -> float:
        frac = 1.0 if exact else self.n_probe_frac
        return 2.0 * self.d * self.active_pool * frac

    def knn_bytes_per_req(self, exact: bool = False) -> float:
        frac = 1.0 if exact else self.n_probe_frac
        return 4.0 * self.d * self.active_pool * frac

    def cluster_flops_per_req(self) -> float:
        # queue read: no dot products at request time; assignment cost is
        # amortized into the embedding-refresh batch job:
        assign = 2.0 * self.d * sum(self.rq_codes)      # per refresh
        refresh_period_s = 3 * 3600.0
        amortized = assign / max(self.qps * refresh_period_s /
                                 max(self.active_pool, 1), 1e-9)
        return amortized

    def cluster_bytes_per_req(self) -> float:
        return 8.0 * self.queue_read_items + 8.0        # queue read + code

    def cost_reduction(self) -> float:
        """Fractional serving-cost reduction (bytes+flops weighted by a
        machine-cost proxy: memory-bandwidth bound at serving tier)."""
        knn = self.knn_bytes_per_req()
        cl = self.cluster_bytes_per_req()
        return 1.0 - cl / max(knn, 1e-9)
