"""repro.faults — deterministic, seeded fault injection for the lifecycle.

See :mod:`repro.faults.plan` for the schedule semantics and
:mod:`repro.faults.chaos` for the full-lifecycle chaos harness used by
the ``pytest -m chaos`` tier and ``benchmarks/lifecycle_faults.py``.
"""
from repro.faults.plan import (
    ACTIONS,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    InjectedCrash,
    InjectedFault,
    clear_plan,
    corrupt_file,
    get_faults,
    install_plan,
)

__all__ = [
    "ACTIONS",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "InjectedCrash",
    "InjectedFault",
    "clear_plan",
    "corrupt_file",
    "get_faults",
    "install_plan",
]
