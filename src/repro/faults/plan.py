"""Deterministic, seeded fault injection (the chaos substrate).

A :class:`FaultPlan` is a *schedule*, not a dice roll at runtime: the
decision for occurrence ``n`` of site ``s`` under seed ``k`` is a pure
function of ``(k, s, n)`` (explicit occurrence lists, or a Bernoulli
draw from ``default_rng((seed, crc32(site), spec_idx, occurrence))``),
so every chaos run is bit-reproducible — two runs with the same seed
inject the same faults at the same points, and a failure found in CI
replays locally from nothing but the seed.

Injection *sites* are named call points threaded through the lifecycle
(``snapshot.write_leaf``, ``snapshot.load``, ``ring.push``,
``swap.flip``, ``train.step``, ``gate.eval``, ...).  Instrumented code
holds a :class:`FaultInjector` (the process singleton by default,
mirroring ``repro.obs``: disabled = one attribute check per site) and
calls :meth:`FaultInjector.fire` at each site.  Four actions:

* ``raise``   raise :class:`InjectedFault` — an ordinary stage failure
              the retry/degradation machinery must absorb;
* ``crash``   raise :class:`InjectedCrash` — simulated process death.
              Retry wrappers MUST NOT catch it; only a top-level chaos
              harness may, modelling a restart;
* ``delay``   sleep ``delay_s`` (injectable sleeper) — exercises stage
              deadlines and gives subprocess-kill tests a window;
* ``corrupt`` flip bytes of the file passed as ``path=`` with a keyed
              RNG — exercises checksum verification and fallback.

Every injection is recorded in :attr:`FaultPlan.log` and emitted as a
``fault.injected`` obs span (+ ``faults.injected`` counter), so a chaos
run can assert its whole schedule is visible in the trace.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import zlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import get_telemetry

ACTIONS = ("raise", "crash", "delay", "corrupt")


class InjectedFault(RuntimeError):
    """A scheduled failure: recoverable, retry/degrade machinery owns it."""

    def __init__(self, site: str, occurrence: int, action: str = "raise"):
        super().__init__(f"injected {action} at {site}#{occurrence}")
        self.site = site
        self.occurrence = occurrence
        self.action = action


class InjectedCrash(InjectedFault):
    """Simulated process death.  Never caught by retries — only a chaos
    harness may catch it, at the point that models a process restart."""

    def __init__(self, site: str, occurrence: int):
        super().__init__(site, occurrence, action="crash")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled failure mode at one site.

    ``occurrences``  explicit 0-based occurrence indices to inject at
                     (deterministic targeting — the usual mode);
    ``prob``         else: keyed Bernoulli per occurrence (seeded sweep
                     mode; still bit-reproducible);
    ``max_injections``  cap on how many times this spec may fire;
    ``delay_s``      sleep length for ``action="delay"``.
    """
    site: str
    action: str
    occurrences: Tuple[int, ...] = ()
    prob: float = 0.0
    max_injections: int = 1 << 30
    delay_s: float = 0.0

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r} "
                             f"(known: {ACTIONS})")


def _site_key(site: str) -> int:
    return zlib.crc32(site.encode("utf-8"))


def corrupt_file(path: str, key: Tuple[int, ...], n_bytes: int = 8) -> int:
    """Deterministically flip up to ``n_bytes`` bytes of ``path`` (keyed
    offsets, each byte XOR 0xFF so the value always changes).  Offsets
    skip the first 128 bytes when the file is larger (the ``.npy``
    header region), so the corruption lands in payload data; checksum
    verification catches it either way.  Returns bytes flipped."""
    size = os.path.getsize(path)
    if size == 0:
        return 0
    rng = np.random.default_rng(key)
    lo = 128 if size > 256 else 0
    offs = np.unique(rng.integers(lo, size, size=min(n_bytes, size)))
    with open(path, "r+b") as f:
        for o in offs:
            f.seek(int(o))
            b = f.read(1)
            f.seek(int(o))
            f.write(bytes([b[0] ^ 0xFF]))
    return len(offs)


class FaultPlan:
    """The seeded schedule: per-site occurrence counters plus the spec
    list, deciding (and executing) an action at every ``fire``.

    Thread-safe: the counter bump + decision + log append run under one
    lock (``ring.push`` sites fire from concurrent writers).  ``sleep``
    is injectable so delay faults are free in tests; ``on_inject`` is a
    test seam called with each injection record (subprocess-kill tests
    touch a sentinel file from it)."""

    def __init__(self, seed: int, specs, *, telemetry=None,
                 sleep: Optional[Callable[[float], None]] = None,
                 on_inject: Optional[Callable[[Dict], None]] = None):
        self.seed = int(seed)
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.tel = telemetry if telemetry is not None else get_telemetry()
        self._sleep = sleep if sleep is not None else time.sleep
        self.on_inject = on_inject
        self._lock = threading.Lock()
        self._occ: Dict[str, int] = {}
        self._fired = [0] * len(self.specs)
        self.log: List[Dict] = []

    # -- the schedule -------------------------------------------------------

    def occurrence(self, site: str) -> int:
        """How many times ``site`` has fired so far."""
        with self._lock:
            return self._occ.get(site, 0)

    def _decide(self, site: str, occ: int
                ) -> Tuple[Optional[FaultSpec], int]:
        for si, spec in enumerate(self.specs):
            if spec.site != site or self._fired[si] >= spec.max_injections:
                continue
            if spec.occurrences:
                if occ in spec.occurrences:
                    return spec, si
            elif spec.prob > 0.0:
                r = np.random.default_rng(
                    (self.seed, _site_key(site), si, occ)).random()
                if r < spec.prob:
                    return spec, si
        return None, -1

    # -- the injection point ------------------------------------------------

    def fire(self, site: str, path: Optional[str] = None, **ctx):
        """Advance ``site``'s occurrence counter and act on any spec the
        schedule selects.  Returns the selected :class:`FaultSpec` (or
        ``None``) for ``delay``/``corrupt``; raises for ``raise`` and
        ``crash``."""
        with self._lock:
            occ = self._occ.get(site, 0)
            self._occ[site] = occ + 1
            spec, si = self._decide(site, occ)
            if spec is not None:
                self._fired[si] += 1
                rec = dict(site=site, occurrence=occ, action=spec.action,
                           seed=self.seed)
                self.log.append(rec)
        if spec is None:
            return None
        tel = self.tel
        with tel.span("fault.injected", site=site, occurrence=occ,
                      action=spec.action):
            pass                      # zero-work span: the trace record
        tel.counter("faults.injected")
        tel.counter(f"faults.{spec.action}")
        if self.on_inject is not None:
            self.on_inject(rec)
        if spec.action == "delay":
            self._sleep(spec.delay_s)
            return spec
        if spec.action == "corrupt":
            if path is not None and os.path.exists(path):
                corrupt_file(path, (self.seed, _site_key(site), occ))
            return spec
        if spec.action == "crash":
            raise InjectedCrash(site, occ)
        raise InjectedFault(site, occ)


class FaultInjector:
    """Process façade instrumented code holds a reference to.  With no
    plan installed (the default, always in production) every site costs
    one attribute check; ``install``/``clear`` mutate in place so
    references captured at construction time observe the change —
    exactly the ``repro.obs`` singleton contract."""

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan

    @property
    def active(self) -> bool:
        return self.plan is not None

    def fire(self, site: str, path: Optional[str] = None, **ctx):
        plan = self.plan
        if plan is None:
            return None
        return plan.fire(site, path=path, **ctx)

    def install(self, plan: FaultPlan) -> FaultPlan:
        self.plan = plan
        return plan

    def clear(self) -> None:
        self.plan = None


_GLOBAL = FaultInjector()


def get_faults() -> FaultInjector:
    return _GLOBAL


def install_plan(plan: FaultPlan) -> FaultPlan:
    """Install ``plan`` on the process-wide injector (tests/harnesses
    prefer a private :class:`FaultInjector` threaded through ctors)."""
    return _GLOBAL.install(plan)


def clear_plan() -> None:
    _GLOBAL.clear()
