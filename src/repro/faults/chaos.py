"""The chaos harness: seeded fault schedules against the full lifecycle.

``run_chaos`` drives a small synthetic world through ``cycles`` full
lifecycle cycles (refresh -> train -> publish -> swap -> serve) with a
:class:`~repro.faults.plan.FaultPlan` installed at every injection site,
modelling crash-restart on :class:`InjectedCrash` (serving is rebuilt
from the newest on-disk snapshot that verifies), and checks the four
fault-tolerance invariants end to end:

* **no_bad_serve** — every snapshot version that ever answered a
  request passed its publication gate (torn/corrupt versions are
  quarantined on load, gate-failed ones are never persisted);
* **recall_floor** — the served version's gated recall ratio never
  drops below the configured floor, across degradation and rollback;
* **exactly_once** — synthetic traffic uses globally unique item ids,
  so any double-applied ring event shows up as a duplicate in the live
  store (swap replay + crash recovery must never double-deliver);
* **all_faults_traced** — every injection in ``FaultPlan.log`` has a
  matching ``fault.injected`` span in the telemetry trace.

Everything is deterministic: a private ``Telemetry`` on ``FixedClock``
+ ``MemorySink``, tuple-keyed RNG for traffic/deltas, and delay faults
advance the fixed clock instead of sleeping.  Two runs with the same
seed return byte-identical reports (``json.dumps`` equal) — the
bit-reproducibility bar the chaos tier asserts.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.faults.plan import (FaultInjector, FaultPlan, FaultSpec,
                               InjectedCrash)
from repro.obs import FixedClock, MemorySink, Telemetry

#: the acceptance-criteria site list: a full chaos schedule must inject
#: at every one of these
REQUIRED_SITES = ("snapshot.write_leaf", "snapshot.load", "ring.push",
                  "swap.flip", "train.step", "gate.eval")

#: unique synthetic item-id base for the exactly-once check (int32-safe:
#: the serving store's item queues are int32)
UNIQUE_ITEM_BASE = 1_000_000_000


def default_specs() -> Tuple[FaultSpec, ...]:
    """The standard full-coverage schedule: one injection at every
    required site plus the stage/health sites, with occurrences placed
    so each fires within a 6-cycle run under ``stage_retries=1``."""
    return (
        # cycle 0's train burst fails at step 3 -> stage retry succeeds
        FaultSpec("train.step", "raise", occurrences=(3,),
                  max_injections=1),
        # cycle 1's gate eval errors -> publish stage retries (the
        # retried publish re-embeds and re-evaluates)
        FaultSpec("gate.eval", "raise", occurrences=(1,),
                  max_injections=1),
        # a leaf of the third on-disk publish is corrupted after its
        # checksum is recorded -> detectable on any later load
        FaultSpec("snapshot.write_leaf", "corrupt", occurrences=(16,),
                  max_injections=1),
        # a later publish crashes before the atomic rename -> partial
        # .tmp dir; restart sweeps it and recovery falls back through
        # the corrupt version to the last good one
        FaultSpec("snapshot.finalize", "crash", occurrences=(3,),
                  max_injections=1),
        # the first post-restart load finds bit-rot -> quarantine + walk
        FaultSpec("snapshot.load", "corrupt", occurrences=(0,),
                  max_injections=1),
        # one traffic ingest hits ring overload -> batch shed, counted
        FaultSpec("ring.push", "raise", occurrences=(2,),
                  max_injections=1),
        # one swap fails right before the flip -> old version keeps
        # serving; stage retry re-runs swap_to and flips cleanly
        FaultSpec("swap.flip", "raise", occurrences=(1,),
                  max_injections=1),
        # one post-swap health probe regresses -> rollback to last good
        FaultSpec("health.post_swap", "raise", occurrences=(3,),
                  max_injections=1),
        # one refresh fails upstream (log fetch) -> retried
        FaultSpec("stage.refresh", "raise", occurrences=(1,),
                  max_injections=1),
    )


def _make_delta(seed: int, cycle: int, now: float, n_users: int,
                n_items: int, n_events: int = 250):
    """A keyed synthetic trailing-hour engagement window."""
    from repro.core.graph_builder import EngagementLog
    rng = np.random.default_rng((seed, 11, cycle))
    du = rng.integers(0, n_users, n_events).astype(np.int64)
    di = rng.integers(0, n_items, n_events).astype(np.int64)
    ts = np.sort(now - 3600.0 * rng.random(n_events))
    return EngagementLog(du, di, np.zeros(n_events, np.int32), ts,
                         n_users, n_items)


def run_chaos(seed: int = 0, *, snapshot_dir: str, cycles: int = 6,
              specs: Optional[Tuple[FaultSpec, ...]] = None,
              steps_per_cycle: int = 30, n_users: int = 200,
              n_items: int = 260, min_recall_ratio: float = 0.5,
              stage_retries: int = 1) -> Dict[str, Any]:
    """Run one seeded chaos schedule; returns the invariant report.

    The report is JSON-serializable and fully deterministic in
    ``seed`` — the bit-reproducibility acceptance check is
    ``json.dumps(run_chaos(s)) == json.dumps(run_chaos(s))`` (with two
    distinct ``snapshot_dir``\\ s).
    """
    from repro.configs.base import RankGraph2Config, RQConfig
    from repro.core.graph_builder import build_graph
    from repro.data.edge_dataset import build_neighbor_tables
    from repro.data.synthetic import make_world
    from repro.lifecycle import LifecycleConfig, LifecycleRuntime
    from repro.lifecycle.runtime import StageFailed

    sink = MemorySink()
    clock = FixedClock()
    tel = Telemetry(sink=sink, clock=clock)
    plan = FaultPlan(seed, specs if specs is not None else default_specs(),
                     telemetry=tel, sleep=clock.advance)
    faults = FaultInjector(plan)

    world = make_world(n_users=n_users, n_items=n_items,
                       events_per_user=20.0, seed=seed)
    cfg = RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=24, n_heads=2, d_hidden=48,
        k_imp=10, k_train=4, n_negatives=16, n_pool_neg=4,
        rq=RQConfig(codebook_sizes=(16, 8), hist_len=20), dtype="float32")
    lcfg = LifecycleConfig(
        steps_per_cycle=steps_per_cycle, batch_per_type=32,
        recall_k=50, recall_queries=100,
        min_recall_ratio=min_recall_ratio,
        stage_retries=stage_retries, retry_backoff_s=0.01,
        rollback_on_regression=True)
    g = build_graph(world.day0, k_cap=16, hub_cap=12, keep_state=True)
    tables = build_neighbor_tables(g, k_imp=10, n_walks=12, walk_len=3,
                                   keep_state=True)
    rt = LifecycleRuntime(cfg, lcfg, g, tables, world.user_feat,
                          world.item_feat, world=world,
                          snapshot_dir=snapshot_dir, seed=seed,
                          telemetry=tel, faults=faults,
                          sleep=clock.advance)

    served: List[int] = []          # version answering each probe
    good: Dict[int, float] = {}     # gate-passed version -> recall ratio
    cycle_log: List[Dict[str, Any]] = []
    crashes = recoveries = 0
    next_uid = 0                    # unique item-id counter

    def probe(now: float) -> None:
        if rt.server is None:
            return
        rng = np.random.default_rng((seed, 23, len(served)))
        uids = rng.integers(0, n_users, 32)
        res, ver = rt.server.retrieve_batch(uids, now, 16)
        assert res.shape == (32, 16)
        served.append(int(ver))

    def traffic(cycle: int, now: float) -> int:
        """Ingest a batch of uniquely-item-id'd events; returns count."""
        nonlocal next_uid
        if rt.server is None:
            return 0
        rng = np.random.default_rng((seed, 29, cycle))
        n = 200
        du = rng.integers(0, n_users, n).astype(np.int64)
        di = (UNIQUE_ITEM_BASE + next_uid + np.arange(n)).astype(np.int64)
        next_uid += n
        ts = now - 60.0 + 60.0 * rng.random(n)
        rt.server.ingest(du, di, np.sort(ts))
        return n

    def note_good(rep: Dict[str, Any]) -> None:
        pub, swap = rep.get("publish"), rep.get("swap")
        if not isinstance(pub, dict) or "version" not in pub:
            return
        if not isinstance(swap, dict):
            return
        if swap.get("skipped") or swap.get("rolled_back"):
            return
        good[int(pub["version"])] = float(pub.get("recall_ratio", 1.0))

    for c in range(cycles):
        now = 86400.0 + 3600.0 * (c + 1)
        try:
            traffic(c, now)
            if c == 0:
                rep = rt.run_cycle(now=now)
            else:
                delta = _make_delta(seed, c, now, n_users, n_items)
                rep = rt.run_cycle(delta, now=now, backend="numpy")
            note_good(rep)
            cycle_log.append(dict(
                cycle=c, degraded=bool(rep.get("degraded")),
                stale_cycles=int(rep.get("stale_cycles", 0)),
                swap={k: v for k, v in rep.get("swap", {}).items()
                      if k in ("skipped", "degraded", "failed_stage",
                               "to_version", "rolled_back")}))
        except InjectedCrash as e:
            # simulated process death: restart = a fresh serving tier
            # from the newest on-disk snapshot that verifies
            crashes += 1
            v = rt.recover_serving(now)
            if v is not None:
                recoveries += 1
                good.setdefault(
                    int(v),
                    float(dict(rt._last_good.gate_metrics)
                          .get("recall_ratio", 1.0)))
            cycle_log.append(dict(cycle=c, crashed=True, site=e.site,
                                  recovered_version=v))
        except StageFailed as e:
            # only reachable before serving exists (bring-up)
            cycle_log.append(dict(cycle=c, failed_stage=e.stage))
        probe(now)

    # -- invariants ---------------------------------------------------------
    served_set = sorted(set(served))
    no_bad_serve = all(v in good for v in served_set)
    recall_by_served = {str(v): good[v] for v in served_set if v in good}
    recall_floor_ok = all(r >= min_recall_ratio
                          for r in recall_by_served.values())

    # exactly-once: unique synthetic item ids must appear at most once
    # in the live store (double-applied ring events would duplicate)
    duplicates = 0
    if rt.server is not None:
        items = rt.server.handle.acquire().store.items
        uniq_ids = items[items >= UNIQUE_ITEM_BASE - 10]
        duplicates = int(uniq_ids.size - np.unique(uniq_ids).size)
    exactly_once = duplicates == 0

    # every injection must be visible as a fault.injected span
    traced = []
    for line in sink.lines:
        rec = json.loads(line)
        if rec.get("type") == "span" and rec.get("name") == "fault.injected":
            a = rec.get("attrs", {})
            traced.append((a.get("site"), a.get("occurrence"),
                           a.get("action")))
    injected = [(r["site"], r["occurrence"], r["action"])
                for r in plan.log]
    all_faults_traced = all(t in traced for t in injected)

    counters = {k: v for k, v in sorted(tel.snapshot()
                                        .get("counters", {}).items())
                if k.startswith(("faults.", "lifecycle.", "snapshot.",
                                 "publish.gate", "swap.ring_dropped",
                                 "swap.ingest_shed"))}
    return dict(
        seed=seed,
        cycles=cycles,
        injected=list(plan.log),
        sites_injected=sorted({r["site"] for r in plan.log}),
        crashes=crashes,
        recoveries=recoveries,
        served_versions=served_set,
        good_versions=sorted(good),
        recall_by_served=recall_by_served,
        duplicates=duplicates,
        cycle_log=cycle_log,
        counters=counters,
        invariants=dict(no_bad_serve=no_bad_serve,
                        recall_floor=recall_floor_ok,
                        exactly_once=exactly_once,
                        all_faults_traced=all_faults_traced),
    )
