"""Minimal functional NN layer library with logical sharding specs.

Design: every ``*_init`` returns ``(params, specs)`` where ``specs`` is a
parallel pytree whose leaves are tuples of logical axis names (consumed
by repro.distributed.sharding).  ``*_apply`` are pure functions.  No
framework dependency (flax is unavailable offline); this keeps parameter
layout and sharding fully explicit, which the dry-run and roofline work
rely on.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

Params = Any
Specs = Any


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def variance_scaling(scale: float, mode: str, distribution: str):
    def init(key, shape, dtype, in_axes=(0,), out_axes=(1,)):
        fan_in = math.prod(shape[a] for a in in_axes) or 1
        fan_out = math.prod(shape[a] for a in out_axes) or 1
        if mode == "fan_in":
            denom = fan_in
        elif mode == "fan_out":
            denom = fan_out
        else:
            denom = (fan_in + fan_out) / 2
        var = scale / denom
        if distribution == "normal":
            return jax.random.normal(key, shape, dtype) * jnp.asarray(
                math.sqrt(var), dtype)
        lim = math.sqrt(3 * var)
        return jax.random.uniform(key, shape, dtype, -lim, lim)
    return init


lecun_normal = variance_scaling(1.0, "fan_in", "normal")
he_normal = variance_scaling(2.0, "fan_in", "normal")
xavier_uniform = variance_scaling(1.0, "fan_avg", "uniform")


def normal_init(stddev: float):
    def init(key, shape, dtype, **_):
        return jax.random.normal(key, shape, dtype) * jnp.asarray(stddev, dtype)
    return init


# ---------------------------------------------------------------------------
# linear / mlp
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, *,
                in_name: Optional[str] = "embed",
                out_name: Optional[str] = "mlp",
                use_bias: bool = True,
                dtype=jnp.float32,
                init: Callable = xavier_uniform):
    kw, _ = jax.random.split(key)
    params = {"w": init(kw, (d_in, d_out), dtype)}
    specs = {"w": (in_name, out_name)}
    if use_bias:
        params["b"] = jnp.zeros((d_out,), dtype)
        specs["b"] = (out_name,)
    return params, specs


def linear_apply(params, x: jax.Array) -> jax.Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def mlp_init(key, dims: Sequence[int], *, use_bias=True, dtype=jnp.float32,
             final_name: Optional[str] = "mlp", init=he_normal):
    """Plain MLP: dims = [d_in, h1, ..., d_out]."""
    params, specs = [], []
    keys = jax.random.split(key, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        last = i == len(dims) - 2
        out_name = final_name if last else "mlp"
        in_name = "embed" if i == 0 else "mlp"
        p, s = linear_init(keys[i], a, b, in_name=in_name, out_name=out_name,
                           use_bias=use_bias, dtype=dtype, init=init)
        params.append(p)
        specs.append(s)
    return params, specs


def mlp_apply(params, x: jax.Array, *, act=jax.nn.relu,
              final_act: Optional[Callable] = None) -> jax.Array:
    n = len(params)
    for i, p in enumerate(params):
        x = linear_apply(p, x)
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}


def rmsnorm_apply(params, x: jax.Array, *, eps: float = 1e-6,
                  plus_one: bool = False) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    if plus_one:   # gemma convention: weight is (1 + scale)
        scale = 1.0 + scale
    return (y * scale).astype(dt)


def layernorm_apply(params: Optional[Params], x: jax.Array, *,
                    eps: float = 1e-5) -> jax.Array:
    """LayerNorm; with params=None it is non-parametric (OLMo style)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if params is not None:
        y = y * params["scale"].astype(jnp.float32)
        if "bias" in params:
            y = y + params["bias"].astype(jnp.float32)
    return y.astype(dt)


def layernorm_init(d: int, *, bias: bool = True, dtype=jnp.float32):
    params = {"scale": jnp.ones((d,), dtype)}
    specs = {"scale": ("embed",)}
    if bias:
        params["bias"] = jnp.zeros((d,), dtype)
        specs["bias"] = ("embed",)
    return params, specs


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int, *, dtype=jnp.float32,
                   stddev: float = 0.02,
                   row_name: str = "vocab", col_name: Optional[str] = "embed"):
    tbl = jax.random.normal(key, (vocab, d), dtype) * stddev
    return {"table": tbl}, {"table": (row_name, col_name)}


def embedding_lookup(params, ids: jax.Array, dtype=None) -> jax.Array:
    tbl = params["table"]
    if dtype is not None:
        tbl = tbl.astype(dtype)
    return jnp.take(tbl, ids, axis=0)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(int(x.size * x.dtype.itemsize) for x in jax.tree.leaves(params))


def cosine_similarity(a: jax.Array, b: jax.Array, axis: int = -1,
                      eps: float = 1e-8) -> jax.Array:
    an = a / (jnp.linalg.norm(a, axis=axis, keepdims=True) + eps)
    bn = b / (jnp.linalg.norm(b, axis=axis, keepdims=True) + eps)
    return jnp.sum(an * bn, axis=axis)


def l2_normalize(x: jax.Array, axis: int = -1, eps: float = 1e-8) -> jax.Array:
    return x / (jnp.linalg.norm(x, axis=axis, keepdims=True) + eps)
