"""Baseline: sequential-transducer retrieval (HSTU protocol proxy).

Paper §5.2 compares against HSTU — a trillion-parameter sequential
foundation model with retrieval-contrastive embeddings.  The trillion-
parameter part is out of scope offline; the *protocol* is not: encode
each user's engagement sequence with a causal transformer, learn item
embeddings jointly with an in-batch contrastive objective, retrieve by
dot product.  This captures what sequential models capture (temporal
co-occurrence) and misses what they miss (multi-hop graph structure) —
which is exactly the comparison the paper draws.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph_builder import EngagementLog
from repro.nn import core as nn
from repro.optim.optimizers import adamw, apply_updates


@dataclasses.dataclass(frozen=True)
class SeqRecConfig:
    d_embed: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 20
    lr: float = 1e-3
    batch: int = 512
    tau: float = 0.08


def build_sequences(log: EngagementLog, seq_len: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-user chronological item sequences, left-padded with -1."""
    order = np.lexsort((log.timestamp, log.user_id))
    u, it = log.user_id[order], log.item_id[order]
    seqs = np.full((log.n_users, seq_len), -1, np.int64)
    starts = np.searchsorted(u, np.arange(log.n_users))
    ends = np.searchsorted(u, np.arange(log.n_users) + 1)
    for uid in range(log.n_users):          # ragged tail-slice per user
        s, e = starts[uid], ends[uid]
        tail = it[max(s, e - seq_len):e]
        if len(tail):
            seqs[uid, -len(tail):] = tail
    return seqs, (seqs >= 0)


def init_params(key, cfg: SeqRecConfig, n_items: int):
    from repro.models.recsys.models import _tx_block_init
    ks = jax.random.split(key, 2 + cfg.n_blocks)
    p = {"items": jax.random.normal(ks[0], (n_items, cfg.d_embed)) * 0.05,
         "pos": jax.random.normal(ks[1], (cfg.seq_len, cfg.d_embed)) * 0.05,
         "blocks": [_tx_block_init(ks[2 + i], cfg.d_embed, cfg.n_heads,
                                   4 * cfg.d_embed, jnp.float32)[0]
                    for i in range(cfg.n_blocks)]}
    return p


def encode_users(params, cfg: SeqRecConfig, seqs: jnp.ndarray):
    from repro.models.recsys.models import _tx_block_apply
    from repro.distributed.sharding import NULL_CTX
    n_items = params["items"].shape[0]
    x = jnp.take(params["items"], jnp.where(seqs >= 0, seqs, 0), axis=0)
    x = x * (seqs >= 0)[..., None] + params["pos"][None]
    for blk in params["blocks"]:
        x = _tx_block_apply(blk, x, cfg.n_heads, causal=True, ctx=NULL_CTX)
    return nn.l2_normalize(x[:, -1])


def train(log: EngagementLog, cfg: SeqRecConfig, *, steps: int = 200,
          seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (user_emb, item_emb)."""
    seqs, mask = build_sequences(log, cfg.seq_len + 1)
    inputs, targets = seqs[:, :-1], seqs[:, -1]
    valid = np.flatnonzero(targets >= 0)
    params = init_params(jax.random.key(seed), cfg, log.n_items)
    opt = adamw(cfg.lr, weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, seq_b, tgt_b):
        def loss_fn(p):
            u = encode_users(p, cfg, seq_b)
            items = nn.l2_normalize(p["items"])
            logits = (u @ items[tgt_b].T) / cfg.tau   # in-batch softmax
            return -jnp.mean(jax.nn.log_softmax(logits, axis=1)
                             [jnp.arange(u.shape[0]), jnp.arange(u.shape[0])])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        upd, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    rng = np.random.default_rng(seed)
    for t in range(steps):
        idx = valid[rng.integers(0, len(valid), cfg.batch)]
        params, opt_state, loss = step(params, opt_state,
                                       jnp.asarray(inputs[idx]),
                                       jnp.asarray(targets[idx]))
    user_emb = np.asarray(encode_users(params, cfg, jnp.asarray(inputs)))
    item_emb = np.asarray(nn.l2_normalize(params["items"]))
    return user_emb, item_emb
