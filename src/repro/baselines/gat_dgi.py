"""Baseline: GAT + Deep Graph Infomax on a bipartite U-I graph.

Paper §5.2.1: "a more complex model on a simpler graph" — a Graph
Attention Network (Velickovic et al. 2018) with DGI self-supervised
pre-training (Velickovic et al. 2019), trained on the *bipartite*
user-item graph only (no U-U / I-I co-engagement edges, no PPR
neighborhoods).  The contrast isolates the paper's co-design claim:
RankGraph-2's gains come from construction quality, not model
expressiveness.

Implementation: padded bipartite neighbor tables (top-weight), 2-layer
GAT with per-edge attention, DGI objective = BCE(discriminator(h, s))
with row-shuffled corruption.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph_builder import EdgeSet, HeteroGraph, padded_adjacency
from repro.nn import core as nn


@dataclasses.dataclass(frozen=True)
class GATDGIConfig:
    d_embed: int = 64
    n_heads: int = 4
    n_layers: int = 2
    max_deg: int = 16
    lr: float = 1e-3


def _gat_layer_init(key, d_in: int, d_out: int, n_heads: int, dtype):
    ks = jax.random.split(key, 3)
    dh = d_out // n_heads
    return {
        "w": nn.xavier_uniform(ks[0], (d_in, n_heads * dh), dtype),
        "a_self": nn.xavier_uniform(ks[1], (n_heads, dh), dtype,
                                    in_axes=(1,), out_axes=(0,)),
        "a_nbr": nn.xavier_uniform(ks[2], (n_heads, dh), dtype,
                                   in_axes=(1,), out_axes=(0,)),
    }


def _gat_layer(p, h_self, h_nbrs, mask, n_heads):
    """h_self (N, d_in); h_nbrs (N, K, d_in); mask (N, K)."""
    N, K, _ = h_nbrs.shape
    dh = p["w"].shape[1] // n_heads
    z_self = (h_self @ p["w"]).reshape(N, n_heads, dh)
    z_nbr = (h_nbrs @ p["w"]).reshape(N, K, n_heads, dh)
    att = (jnp.einsum("nhd,hd->nh", z_self, p["a_self"])[:, None, :]
           + jnp.einsum("nkhd,hd->nkh", z_nbr, p["a_nbr"]))
    att = jax.nn.leaky_relu(att, 0.2)
    att = jnp.where(mask[..., None] > 0, att, -1e30)
    att = jax.nn.softmax(att, axis=1)
    att = jnp.where(mask[..., None] > 0, att, 0.0)
    out = jnp.einsum("nkh,nkhd->nhd", att, z_nbr)
    return jax.nn.elu(out.reshape(N, n_heads * dh)
                      + z_self.reshape(N, n_heads * dh))


def init_params(key, cfg: GATDGIConfig, d_uf: int, d_if: int):
    ks = jax.random.split(key, 6)
    d = cfg.d_embed
    return {
        "proj_u": nn.xavier_uniform(ks[0], (d_uf, d), jnp.float32),
        "proj_i": nn.xavier_uniform(ks[1], (d_if, d), jnp.float32),
        "gat1_u": _gat_layer_init(ks[2], d, d, cfg.n_heads, jnp.float32),
        "gat1_i": _gat_layer_init(ks[3], d, d, cfg.n_heads, jnp.float32),
        "gat2_u": _gat_layer_init(ks[4], d, d, cfg.n_heads, jnp.float32),
        "gat2_i": _gat_layer_init(ks[5], d, d, cfg.n_heads, jnp.float32),
        "dgi_w": jnp.eye(d, dtype=jnp.float32),
    }


def encode(params, cfg: GATDGIConfig, user_feat, item_feat,
           ui_nbrs, ui_mask, iu_nbrs, iu_mask):
    """Bipartite 2-layer GAT.  ui_nbrs: per-user item neighbors (global
    item-local ids); iu_nbrs: per-item user neighbors."""
    hu = user_feat @ params["proj_u"]
    hi = item_feat @ params["proj_i"]
    # layer 1: users attend over item nbrs, items over user nbrs
    hu1 = _gat_layer(params["gat1_u"], hu, hi[ui_nbrs], ui_mask,
                     cfg.n_heads)
    hi1 = _gat_layer(params["gat1_i"], hi, hu[iu_nbrs], iu_mask,
                     cfg.n_heads)
    hu2 = _gat_layer(params["gat2_u"], hu1, hi1[ui_nbrs], ui_mask,
                     cfg.n_heads)
    hi2 = _gat_layer(params["gat2_i"], hi1, hu1[iu_nbrs], iu_mask,
                     cfg.n_heads)
    return nn.l2_normalize(hu2), nn.l2_normalize(hi2)


def dgi_loss(params, cfg: GATDGIConfig, key, user_feat, item_feat,
             ui_nbrs, ui_mask, iu_nbrs, iu_mask):
    """Deep Graph Infomax: positives = (node, summary), negatives =
    corrupted (feature-shuffled) nodes vs the same summary."""
    hu, hi = encode(params, cfg, user_feat, item_feat, ui_nbrs, ui_mask,
                    iu_nbrs, iu_mask)
    h = jnp.concatenate([hu, hi], axis=0)
    s = jnp.tanh(jnp.mean(h, axis=0))
    ku, ki = jax.random.split(key)
    uf_c = user_feat[jax.random.permutation(ku, user_feat.shape[0])]
    if_c = item_feat[jax.random.permutation(ki, item_feat.shape[0])]
    hu_c, hi_c = encode(params, cfg, uf_c, if_c, ui_nbrs, ui_mask,
                        iu_nbrs, iu_mask)
    h_c = jnp.concatenate([hu_c, hi_c], axis=0)
    pos = jnp.einsum("nd,de,e->n", h, params["dgi_w"], s)
    neg = jnp.einsum("nd,de,e->n", h_c, params["dgi_w"], s)
    return (jnp.mean(jax.nn.softplus(-pos))
            + jnp.mean(jax.nn.softplus(neg)))


def train(world, g: HeteroGraph, cfg: GATDGIConfig, *, steps: int = 120,
          seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Train on the bipartite U-I graph; returns (user_emb, item_emb)."""
    ui_nbrs, ui_w = padded_adjacency(g.ui, g.n_users, cfg.max_deg)
    iu = EdgeSet(g.ui.dst, g.ui.src, g.ui.weight)
    iu_nbrs, iu_w = padded_adjacency(iu, g.n_items, cfg.max_deg)
    ui_mask = (ui_nbrs >= 0).astype(np.float32)
    iu_mask = (iu_nbrs >= 0).astype(np.float32)
    ui_nbrs = np.maximum(ui_nbrs, 0)
    iu_nbrs = np.maximum(iu_nbrs, 0)

    params = init_params(jax.random.key(seed), cfg,
                         world.user_feat.shape[1], world.item_feat.shape[1])
    from repro.optim.optimizers import adamw, apply_updates
    opt = adamw(cfg.lr, weight_decay=0.0)
    opt_state = opt.init(params)
    args = tuple(jnp.asarray(a) for a in
                 (world.user_feat, world.item_feat, ui_nbrs, ui_mask,
                  iu_nbrs, iu_mask))

    @jax.jit
    def step(params, opt_state, key):
        loss, grads = jax.value_and_grad(dgi_loss)(params, cfg, key, *args)
        upd, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    key = jax.random.key(seed + 1)
    for t in range(steps):
        key, sub = jax.random.split(key)
        params, opt_state, loss = step(params, opt_state, sub)
    hu, hi = jax.jit(lambda p: encode(p, cfg, *args))(params)
    return np.asarray(hu), np.asarray(hi)
