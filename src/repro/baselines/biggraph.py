"""Baseline: PyTorch-BigGraph-style translational graph embeddings.

Paper §5.2.2: PBG (Lerer et al. 2019) trains *transductive* per-node
embeddings with a relation operator (translation) and margin ranking
loss against sampled negatives — training optimized in isolation, no
feature encoders, no PPR neighborhoods, no co-learned index.

We implement the PBG objective faithfully at our scale: one embedding
row per node, per-edge-type translation vectors, margin loss with
uniform negatives, mini-batched AdaGrad (PBG's optimizer).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph_builder import HeteroGraph
from repro.nn import core as nn
from repro.optim.optimizers import adagrad, apply_updates


@dataclasses.dataclass(frozen=True)
class PBGConfig:
    d_embed: int = 64
    margin: float = 0.1
    n_neg: int = 32
    lr: float = 0.1
    batch: int = 4096


def init_params(key, cfg: PBGConfig, n_users: int, n_items: int):
    ku, ki, kr = jax.random.split(key, 3)
    return {
        "user": jax.random.normal(ku, (n_users, cfg.d_embed)) * 0.1,
        "item": jax.random.normal(ki, (n_items, cfg.d_embed)) * 0.1,
        "rel": jax.random.normal(kr, (3, cfg.d_embed)) * 0.01,  # uu/ui/ii
    }


def _margin_loss(src_e, rel, dst_e, neg_e, margin):
    s_pos = nn.cosine_similarity(src_e + rel, dst_e)
    s_neg = nn.cosine_similarity((src_e + rel)[:, None, :], neg_e)
    return jnp.mean(jnp.sum(
        jax.nn.relu(s_neg - s_pos[:, None] + margin), axis=1))


def train(g: HeteroGraph, cfg: PBGConfig, *, steps: int = 300,
          seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (user_emb, item_emb) trained on all three edge types."""
    params = init_params(jax.random.key(seed), cfg, g.n_users, g.n_items)
    opt = adagrad(cfg.lr)
    opt_state = opt.init(params)

    edges = {
        "uu": (np.stack([g.uu.src, g.uu.dst], 1) if len(g.uu) else None),
        "ui": (np.stack([g.ui.src, g.ui.dst], 1) if len(g.ui) else None),
        "ii": (np.stack([g.ii.src, g.ii.dst], 1) if len(g.ii) else None),
    }

    @jax.jit
    def step(params, opt_state, batch, key):
        def loss_fn(p):
            total = jnp.zeros(())
            for ri, et in enumerate(("uu", "ui", "ii")):
                if et not in batch:
                    continue
                src, dst = batch[et][:, 0], batch[et][:, 1]
                st = p["user"] if et[0] == "u" else p["item"]
                dt = p["user"] if et[1] == "u" else p["item"]
                ke = jax.random.fold_in(key, ri)
                neg_idx = jax.random.randint(
                    ke, (src.shape[0], cfg.n_neg), 0, dt.shape[0])
                total = total + _margin_loss(
                    st[src], p["rel"][ri], dt[dst], dt[neg_idx], cfg.margin)
            return total

        loss, grads = jax.value_and_grad(loss_fn)(params)
        upd, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    rng = np.random.default_rng(seed)
    key = jax.random.key(seed + 1)
    for t in range(steps):
        batch = {}
        for et, arr in edges.items():
            if arr is not None and len(arr):
                idx = rng.integers(0, len(arr), min(cfg.batch, len(arr)))
                batch[et] = jnp.asarray(arr[idx])
        key, sub = jax.random.split(key)
        params, opt_state, loss = step(params, opt_state, batch, sub)
    ue = np.asarray(nn.l2_normalize(params["user"]))
    ie = np.asarray(nn.l2_normalize(params["item"]))
    return ue, ie
