"""Fault-tolerant checkpointing (no orbax/tensorstore offline).

Features needed at 1000+ node scale, implemented host-side:
  * atomic checkpoints: write to ``step_N.tmp`` then rename;
  * async save (background thread) so the train loop never blocks on IO;
  * keep-last-N retention + a persistent ``latest`` pointer;
  * elastic restore: arrays are saved *unsharded per-leaf* (addressable
    shards are gathered on save), so a checkpoint written on a 512-chip
    mesh restores onto any other mesh — ``restore(..., mesh, shardings)``
    re-shards on load (elastic up/down-scaling);
  * resumable data iterator: (seed, step) round-trips via metadata, and
    batch t is a pure function of (seed, t) in the dataset layer;
  * preemption hook: SIGTERM triggers a final synchronous save.

Layout:  <dir>/step_<N>/{manifest.json, 000000.npy, 000001.npy, ...}

Crash safety (PR 9): every leaf's SHA-256 and byte count are recorded
in the manifest before the atomic rename, ``verify_step`` re-hashes a
finished checkpoint against them, and opening a ``Checkpointer`` sweeps
``*.tmp`` partials left by a crash mid-write — a torn write is either
invisible (still ``.tmp``) or detectable (checksum mismatch), never
silently loadable.  Named fault-injection sites (``snapshot.write_leaf``
per leaf, ``snapshot.finalize`` just before the rename) let the chaos
tier prove it.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import signal
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.faults import get_faults


class CheckpointCorruptError(RuntimeError):
    """A finished checkpoint failed checksum / completeness verification."""


def _flatten(tree) -> Tuple[List[Any], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _host_array(x) -> np.ndarray:
    """Gather a (possibly sharded) jax.Array to host."""
    if isinstance(x, jax.Array):
        if not x.is_fully_addressable:
            # multi-host: each process gathers its addressable shards and
            # the lead writes; single-process here, so this path is moot.
            x = jax.device_get(x)
        return np.asarray(jax.device_get(x))
    return np.asarray(x)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3, faults=None):
        self.dir = directory
        self.keep = keep
        self.faults = faults if faults is not None else get_faults()
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self.sweep_partials()

    def sweep_partials(self) -> List[str]:
        """Remove ``step_*.tmp`` partial dirs (and a stale ``latest.tmp``
        pointer) left behind by a crash mid-publish.  A partial is never
        loadable — ``all_steps`` skips ``.tmp`` — but sweeping keeps the
        store clean and reclaims the space.  Returns swept names."""
        swept = []
        for name in os.listdir(self.dir):
            p = os.path.join(self.dir, name)
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(p, ignore_errors=True)
                swept.append(name)
            elif name == "latest.tmp":
                os.unlink(p)
                swept.append(name)
        return swept

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any,
             metadata: Optional[Dict[str, Any]] = None,
             blocking: bool = True) -> None:
        leaves, treedef = _flatten(tree)
        host_leaves = [_host_array(l) for l in leaves]
        meta = dict(metadata or {})
        meta["step"] = int(step)
        meta["treedef"] = str(treedef)
        meta["n_leaves"] = len(host_leaves)
        if blocking:
            self._write(step, host_leaves, meta)
        else:
            self.wait()
            t = threading.Thread(target=self._write,
                                 args=(step, host_leaves, meta), daemon=True)
            self._thread = t
            t.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves, meta) -> None:
        with self._lock:
            final = os.path.join(self.dir, f"step_{step}")
            tmp = final + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            shas, sizes = [], []
            for i, arr in enumerate(host_leaves):
                leaf = os.path.join(tmp, f"{i:06d}.npy")
                np.save(leaf, arr, allow_pickle=False)
                shas.append(_sha256_file(leaf))
                sizes.append(os.path.getsize(leaf))
                # corrupt lands after the checksum is taken, so a flipped
                # byte is a detectable mismatch; crash leaves a .tmp dir
                self.faults.fire("snapshot.write_leaf", path=leaf,
                                 step=step, leaf=i)
            meta = dict(meta, leaf_sha256=shas, leaf_bytes=sizes)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            self.faults.fire("snapshot.finalize", step=step)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
                f.write(str(step))
            os.replace(os.path.join(self.dir, "latest.tmp"),
                       os.path.join(self.dir, "latest"))
            self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "latest")
        if os.path.exists(p):
            with open(p) as f:
                s = int(f.read().strip())
            if os.path.isdir(os.path.join(self.dir, f"step_{s}")):
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None, *,
                shardings: Any = None
                ) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``like``.  ``shardings`` (a
        matching pytree of NamedSharding / None) re-shards each leaf —
        the elastic-rescale path: the target mesh may differ from the
        mesh that wrote the checkpoint."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        leaves, treedef = _flatten(like)
        if meta["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {meta['n_leaves']} leaves, target "
                f"structure has {len(leaves)} — incompatible trees")
        sleaves = (jax.tree.leaves(shardings,
                                   is_leaf=lambda x: x is None)
                   if shardings is not None else [None] * len(leaves))
        out = []
        for i, (ref, shd) in enumerate(zip(leaves, sleaves)):
            arr = np.load(os.path.join(d, f"{i:06d}.npy"))
            if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
                arr = arr.astype(ref.dtype)
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr))
        return treedef.unflatten(out), meta

    # -- verification ----------------------------------------------------------

    def verify_step(self, step: int) -> Dict[str, Any]:
        """Re-hash every leaf of a finished checkpoint against the
        checksums recorded at write time.  Raises
        :class:`CheckpointCorruptError` on any missing leaf, size or
        digest mismatch; returns the manifest on success.  Manifests
        written before checksums existed (no ``leaf_sha256``) verify
        leaf *presence* only."""
        d = os.path.join(self.dir, f"step_{step}")
        mpath = os.path.join(d, "manifest.json")
        if not os.path.exists(mpath):
            raise CheckpointCorruptError(f"step {step}: manifest missing")
        try:
            with open(mpath) as f:
                meta = json.load(f)
        except ValueError as e:
            raise CheckpointCorruptError(
                f"step {step}: manifest unreadable ({e})") from e
        n = int(meta.get("n_leaves", 0))
        shas = meta.get("leaf_sha256")
        sizes = meta.get("leaf_bytes")
        for i in range(n):
            leaf = os.path.join(d, f"{i:06d}.npy")
            if not os.path.exists(leaf):
                raise CheckpointCorruptError(
                    f"step {step}: leaf {i} missing")
            if sizes is not None and os.path.getsize(leaf) != sizes[i]:
                raise CheckpointCorruptError(
                    f"step {step}: leaf {i} size mismatch")
            if shas is not None and _sha256_file(leaf) != shas[i]:
                raise CheckpointCorruptError(
                    f"step {step}: leaf {i} checksum mismatch")
        return meta

    # -- preemption ------------------------------------------------------------

    def install_preemption_handler(self, get_state: Callable[[], Tuple[int,
                                   Any, Dict]], sig=signal.SIGTERM) -> None:
        """On SIGTERM (preemption notice), write a final checkpoint before
        the process dies — nodes are revocable at cluster scale."""

        def handler(signum, frame):
            step, tree, meta = get_state()
            meta = dict(meta, preempted=True, wall=time.time())
            self.save(step, tree, metadata=meta, blocking=True)
            raise SystemExit(143)

        signal.signal(sig, handler)
