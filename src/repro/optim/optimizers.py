"""Self-contained optimizer library (optax is unavailable offline).

All optimizers are (init, update) pairs over pytrees:
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Includes the paper's production recipe (§5.1): AdaGrad lr=0.02 for
"sparse" (embedding-ish) parameters and AdamW lr=0.004 for dense ones,
via ``partition`` — plus Adafactor (factored second moments) which the
MoE giants (grok-314B / kimi-1T) need to fit optimizer state in HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]   # (grads, state, params)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype))
                        if u is not None else p, params, updates,
                        is_leaf=lambda x: x is None)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# sgd / adagrad / adamw
# ---------------------------------------------------------------------------

def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                params)
        return ()

    def update(grads, state, params=None):
        if momentum:
            state = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state, grads)
            upd = jax.tree.map(lambda m: -lr * m, state)
        else:
            upd = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        return upd, state

    return Optimizer(init, update)


def adagrad(lr: float = 0.02, eps: float = 1e-8) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params=None):
        state = jax.tree.map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)), state, grads)
        upd = jax.tree.map(
            lambda a, g: -lr * g.astype(jnp.float32)
            / (jnp.sqrt(a) + eps), state, grads)
        return upd, state

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def adamw(lr: float = 0.004, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01) -> Optimizer:
    def init(params):
        z = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                 params)
        return AdamState(z(), z(), jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        c = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1)
                          * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(m, v, p):
            step = m / bc1 / (jnp.sqrt(v / bc2) + eps)
            return -lr * (step + weight_decay * p.astype(jnp.float32))

        return (jax.tree.map(upd, mu, nu, params),
                AdamState(mu, nu, c))

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# adafactor (Shazeer & Stern) — factored second moments, O(n+m) state
# ---------------------------------------------------------------------------

class FactorState(NamedTuple):
    vr: Any       # row stats  (or full v for <2D params)
    vc: Any       # col stats
    count: jnp.ndarray


def adafactor(lr: float = 0.01, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              lr_schedule: bool = True) -> Optimizer:
    """Factored AdaGrad-style stats over the last two dims; params with
    ndim < 2 keep full stats (they are tiny).  ``lr_schedule`` applies
    the standard Shazeer-Stern 1/sqrt(t) relative-step decay (without it
    the update clipping makes constant-lr Adafactor oscillate)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def row(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                    else jnp.zeros_like(p, jnp.float32))

        def col(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((), jnp.float32))

        return FactorState(jax.tree.map(row, params),
                           jax.tree.map(col, params),
                           jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        c = state.count + 1
        beta = 1.0 - c.astype(jnp.float32) ** -decay
        step_lr = lr * (jax.lax.rsqrt(c.astype(jnp.float32))
                        if lr_schedule else 1.0)

        def upd(g, vr, vc, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _factored(p):
                nvr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                nvc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    nvr / jnp.mean(nvr, axis=-1, keepdims=True) + eps)
                cfac = jax.lax.rsqrt(nvc + eps)
                step = g32 * rfac[..., None] * cfac[..., None, :]
            else:
                nvr = beta * vr + (1 - beta) * g2
                nvc = vc
                step = g32 * jax.lax.rsqrt(nvr + eps)
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + 1e-12)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            return -step_lr * step, nvr, nvc

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        treedef = jax.tree.structure(grads)
        flat = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple))
        upds = treedef.unflatten([t[0] for t in flat])
        vrs = treedef.unflatten([t[1] for t in flat])
        vcs = treedef.unflatten([t[2] for t in flat])
        return upds, FactorState(vrs, vcs, c)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# partitioned optimizer (paper: adagrad for sparse + adamw for dense)
# ---------------------------------------------------------------------------

def partition(predicate: Callable[[Tuple[Any, ...], Any], bool],
              opt_true: Optimizer, opt_false: Optimizer) -> Optimizer:
    """Route each leaf to one of two optimizers by (path, leaf).

    The routing mask is recomputed from the (static) tree structure at
    trace time, so the returned state is jit-friendly.
    """

    def _mask(params):
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        return [bool(predicate(path, leaf)) for path, leaf in flat]

    def _split(tree, mask):
        leaves, treedef = jax.tree.flatten(tree)
        # routed-away leaves become 0-d zeros: uniform trees for the
        # sub-optimizers; their updates are discarded at merge.
        t = treedef.unflatten([l if m else jnp.zeros(())
                               for l, m in zip(leaves, mask)])
        f = treedef.unflatten([jnp.zeros(()) if m else l
                               for l, m in zip(leaves, mask)])
        return t, f, treedef

    def _merge(a, b, mask, treedef):
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        return treedef.unflatten([x if m else y
                                  for x, y, m in zip(la, lb, mask)])

    def init(params):
        mask = _mask(params)
        pt, pf, _ = _split(params, mask)
        return {"true": opt_true.init(pt), "false": opt_false.init(pf)}

    def update(grads, state, params):
        mask = _mask(params)
        gt, gf, treedef = _split(grads, mask)
        pt, pf, _ = _split(params, mask)
        ut, st = opt_true.update(gt, state["true"], pt)
        uf, sf = opt_false.update(gf, state["false"], pf)
        upd = _merge(ut, uf, mask, treedef)
        return upd, {"true": st, "false": sf}

    return Optimizer(init, update)


def rankgraph2_optimizer(lr_sparse: float = 0.02, lr_dense: float = 0.004
                         ) -> Optimizer:
    """Paper §5.1: AdaGrad for sparse/embedding-like params, AdamW for
    dense.  'Sparse' = any path containing 'table' or 'codebooks'."""
    def is_sparse(path, leaf) -> bool:
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        return ("table" in keys) or ("codebooks" in keys)

    return partition(is_sparse, adagrad(lr_sparse), adamw(lr_dense))


def make_optimizer(name: str, lr: Optional[float] = None) -> Optimizer:
    if name == "adamw":
        return adamw(lr or 3e-4)
    if name == "adagrad":
        return adagrad(lr or 0.02)
    if name == "adafactor":
        return adafactor(lr or 0.01)
    if name == "sgd":
        return sgd(lr or 0.1)
    if name == "rankgraph2":
        return rankgraph2_optimizer()
    raise ValueError(f"unknown optimizer {name!r}")
