"""Per-(arch x shape) step builders for the dry-run and roofline.

For every cell this module produces:
  * ``fn``            — the exact function a production job would jit
                        (full train step incl. optimizer update, or the
                        serving step);
  * ``args``          — ShapeDtypeStruct stand-ins for every input
                        (params, optimizer state, batch) — *no device
                        allocation*;
  * ``in_shardings``  — NamedShardings resolved from the model's logical
                        specs under the mesh's rules;
  * ``model_flops``   — the useful-FLOPs estimate (6*N*D train / 2*N*D
                        inference for LMs; analytic counts elsewhere)
                        used by the roofline's waste ratio.

Optimizer state shardings are derived structurally: a state leaf with
the same (shape, dtype) as a parameter inherits that parameter's
sharding (mu/nu/accumulators); everything else (scalars, factored
stats) replicates — a baseline the perf pass can iterate on.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeSpec, get_arch
from repro.distributed import sharding as shd
from repro.optim import optimizers as opt_lib

f32 = jnp.float32
bf16 = jnp.bfloat16
i32 = jnp.int32


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    model_flops: float
    notes: str = ""
    # cost probe: rebuild this cell with n_layers=L, all loops unrolled.
    # XLA's cost analysis counts while-loop bodies ONCE, so scanned models
    # are measured via two unrolled probe lowerings (L=1,2) and linear
    # extrapolation F(L) = F1 + (L-1)(F2-F1) — exact for layer-linear
    # architectures.  None => the cell has no loops (counts are exact).
    probe: Optional[Callable[[int], "Cell"]] = None


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _safe(mesh: Mesh, spec: P, sds) -> NamedSharding:
    """pjit *arguments* need sharded dims divisible by the axis size;
    drop (replicate) any axis that does not divide its dim."""
    sizes = _axis_sizes(mesh)
    shape = tuple(getattr(sds, "shape", ()) or ())
    new = []
    for i, s in enumerate(spec):
        if s is None or i >= len(shape):
            new.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        n = int(np.prod([sizes[a] for a in axes]))
        new.append(s if shape[i] % n == 0 else None)
    return NamedSharding(mesh, P(*new))


def _param_shardings(specs, rules, mesh, shapes=None):
    pspecs = shd.tree_logical_to_spec(specs, rules)
    if shapes is None:
        return jax.tree.map(lambda s: _named(mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(lambda s, sds: _safe(mesh, s, sds), pspecs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def _state_shardings(state_shapes, params_shapes, params_shardings, mesh):
    """Shape-matching inheritance of param shardings.

    Full-shape matches (mu/nu/accumulators) inherit the param sharding.
    Adafactor's factored stats match a param's shape minus its last
    (vr) or second-to-last (vc) dim and inherit the corresponding spec
    prefix; anything else replicates."""
    rep = _named(mesh, P())
    table: Dict[Tuple, Any] = {}
    row_table: Dict[Tuple, Any] = {}
    col_table: Dict[Tuple, Any] = {}
    for p, s in zip(jax.tree.leaves(params_shapes),
                    jax.tree.leaves(params_shardings)):
        spec = tuple(s.spec) + (None,) * (len(p.shape) - len(s.spec))
        table.setdefault(tuple(p.shape), s)
        if len(p.shape) >= 2:
            row_table.setdefault(tuple(p.shape[:-1]),
                                 NamedSharding(mesh, P(*spec[:-1])))
            col_table.setdefault(tuple(p.shape[:-2] + p.shape[-1:]),
                                 NamedSharding(
                                     mesh, P(*(spec[:-2] + spec[-1:]))))

    def pick(leaf):
        shp = tuple(leaf.shape)
        for t in (table, row_table, col_table):
            if shp in t:
                return t[shp]
        return rep

    return jax.tree.map(pick, state_shapes)


def _batch_spec(rules) -> P:
    return shd.logical_to_spec(("batch",), rules)


# REPRO_BASELINE=1 reverts the post-baseline perf iterations (sharding
# rules below + the shard_map embedding lookup) so the EXPERIMENTS.md
# before/after numbers stay reproducible.
BASELINE = os.environ.get("REPRO_BASELINE") == "1"


def _rules_for(arch_id: str, shape: ShapeSpec, mesh: Mesh,
               overrides: Optional[dict] = None) -> dict:
    ov = dict(overrides or {})
    if arch_id == "grok-1-314b":
        from repro.configs.grok_1_314b import RULES_OVERRIDE
        ov.update(RULES_OVERRIDE)
    fam = get_arch(arch_id).family
    if not BASELINE:
        if fam == "gnn":
            # perf iteration (EXPERIMENTS.md §Perf/equiformer): replicate
            # the node dim, shard feature channels — per-edge gathers
            # become device-local; aggregation is one psum per layer.
            ov.setdefault("nodes", None)
        # (rankgraph2 DP-only rules were tried and REFUTED — the
        # dominant all-gather is cross-shard in-batch negative indexing,
        # not encoder TP; see EXPERIMENTS.md §Perf. Fixed instead by
        # shard-local negative sampling in core/negatives.py.)
    if shape.step == "train" and get_arch(arch_id).family == "lm":
        # FSDP: weights shard over the data axis too (gathered per use);
        # mandatory for the MoE giants, harmless for the small LMs.
        ov.setdefault("embed", "data")
        # sequence parallelism: residual-stream activations (the per-layer
        # remat saves) shard over the model axis as well.
        ov.setdefault("seq", "model")
    if shape.step == "decode":
        # decode: shard the KV cache over sequence; heads replicate
        ov.setdefault("kv_seq", ("model",) if shape.dims.get(
            "global_batch", 2) > 1 else ("data", "model"))
        ov.setdefault("heads", None)
        ov.setdefault("kv_heads", None)
        if shape.dims.get("global_batch", 2) == 1:
            ov.setdefault("batch", None)
    return shd.make_rules(mesh, ov)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh,
             cfg=None) -> Cell:
    from repro.models.lm import model as LM
    import dataclasses as dc
    is_probe = cfg is not None
    cfg = cfg or arch.config

    def probe(L: int) -> Cell:
        pcfg = dc.replace(arch.config, n_layers=L, scan_layers=False,
                          unroll_chunks=True)
        return _lm_cell(arch, shape, mesh, cfg=pcfg)

    probe = None if is_probe else probe
    rules = _rules_for(arch.arch_id, shape, mesh)
    ctx = shd.ShardingCtx(rules, mesh)
    B = shape.dims["global_batch"]
    S = shape.dims["seq_len"]

    params_shapes = jax.eval_shape(
        lambda: LM.init_params(jax.random.key(0), cfg)[0])
    specs = _lm_specs(cfg)   # static python data; built from a 1L clone
    pshard = _param_shardings(specs, rules, mesh, params_shapes)
    bspec = _batch_spec(rules)

    n = cfg.n_params()
    if shape.step == "train":
        optimizer = opt_lib.make_optimizer(cfg.optimizer)
        opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
        oshard = _state_shardings(opt_shapes, params_shapes, pshard, mesh)
        tokens = _sds((B, S), i32)

        # (a tree-wide cast-before-gather of fp32 params to bf16 was
        # tried and REFUTED: XLA's convert motion already gathers most
        # weights post-cast — llama unchanged, olmo -17% collective but
        # +20% HBM from double-precision residency.  See §Perf.)
        def step(params, opt_state, tokens):
            loss, grads = jax.value_and_grad(
                lambda p: LM.lm_loss(p, cfg, tokens, ctx=ctx))(params)
            grads, gnorm = opt_lib.clip_by_global_norm(grads, 1.0)
            upd, opt_state = optimizer.update(grads, opt_state, params)
            params = opt_lib.apply_updates(params, upd)
            return loss, params, opt_state

        flops = 6.0 * cfg.n_active_params() * B * S
        return Cell(arch.arch_id, shape.name, step,
                    (params_shapes, opt_shapes, tokens),
                    (pshard, oshard, _safe(mesh, bspec, tokens)), flops,
                    probe=probe)

    if shape.step == "prefill":
        tokens = _sds((B, S), i32)

        def step(params, tokens):
            return LM.prefill(params, cfg, tokens, ctx=ctx)

        flops = 2.0 * cfg.n_active_params() * B * S
        return Cell(arch.arch_id, shape.name, step,
                    (params_shapes, tokens),
                    (pshard, _safe(mesh, bspec, tokens)), flops,
                    probe=probe)

    # decode
    hd = cfg.resolved_head_dim
    cache_sds = {
        "k": _sds((cfg.n_layers, B, S, cfg.n_kv_heads, hd), bf16),
        "v": _sds((cfg.n_layers, B, S, cfg.n_kv_heads, hd), bf16)}
    cache_spec = shd.logical_to_spec(
        (None, "batch", "kv_seq", "kv_heads", None), rules)
    cshard = jax.tree.map(lambda c: _safe(mesh, cache_spec, c), cache_sds)
    tokens = _sds((B, 1), i32)

    def step(params, caches, tokens):
        return LM.decode_step(params, cfg, tokens, caches, S - 1, ctx=ctx)

    flops = 2.0 * cfg.n_active_params() * B * 1
    return Cell(arch.arch_id, shape.name, step,
                (params_shapes, cache_sds, tokens),
                (pshard, cshard, _safe(mesh, bspec, tokens)), flops,
                notes="decode: 1 new token against a filled KV cache",
                probe=probe)


def _lm_specs(cfg):
    """Spec tree from a tiny clone (specs are plain python data).
    Scanned params: layer-count-agnostic stacked tree.  Unrolled params
    (probe mode): a list with one entry per layer — keep the count."""
    from repro.models.lm import model as LM
    import dataclasses as dc
    n_layers = 1 if cfg.scan_layers else cfg.n_layers
    tiny = dc.replace(cfg, n_layers=n_layers, vocab_size=8, d_model=8,
                      n_heads=2,
                      n_kv_heads=max(1, min(2, cfg.n_kv_heads)), head_dim=4,
                      d_ff=8, moe_d_ff=8 if cfg.n_experts else None,
                      n_experts=min(cfg.n_experts, 2))
    _, specs = LM.init_params(jax.random.key(0), tiny)
    return specs


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def _recsys_cell(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> Cell:
    from repro.models.recsys import models as R
    cfg = arch.config
    rules = _rules_for(arch.arch_id, shape, mesh)
    ctx = shd.ShardingCtx(rules, mesh)
    B = shape.dims["batch"]
    kind = cfg.kind

    inits = {"dlrm": R.dlrm_init, "wide_deep": R.wide_deep_init,
             "sasrec": R.sasrec_init, "bst": R.bst_init}[kind]
    params_shapes = jax.eval_shape(
        lambda: inits(jax.random.key(0), cfg)[0])
    specs = inits(jax.random.key(0), dataclasses_replace_small(cfg))[1]
    pshard = _param_shardings(specs, rules, mesh, params_shapes)
    bspec = _batch_spec(rules)

    def batch_sds():
        if kind == "dlrm":
            return {"dense": _sds((B, cfg.n_dense), f32),
                    "sparse": _sds((B, cfg.n_sparse), i32),
                    "labels": _sds((B,), f32)}
        if kind == "wide_deep":
            return {"sparse": _sds((B, cfg.n_sparse), i32),
                    "labels": _sds((B,), f32)}
        if kind == "sasrec":
            return {"seq": _sds((B, cfg.seq_len), i32),
                    "pos": _sds((B,), i32),
                    "neg": _sds((B, 100), i32)}
        return {"seq": _sds((B, cfg.seq_len), i32),
                "target": _sds((B,), i32),
                "other": _sds((B, cfg.n_sparse), i32),
                "labels": _sds((B,), f32)}

    def fwd(params, batch):
        if kind == "dlrm":
            return R.dlrm_forward(params, cfg, batch["dense"],
                                  batch["sparse"], ctx)
        if kind == "wide_deep":
            return R.wide_deep_forward(params, cfg, None, batch["sparse"],
                                       ctx)
        if kind == "sasrec":
            u = R.sasrec_user_repr(params, cfg, batch["seq"], ctx)
            return u
        return R.bst_forward(params, cfg, batch["seq"], batch["target"],
                             batch["other"], ctx)

    flops = _recsys_flops(cfg, B)

    if shape.step == "train":
        optimizer = opt_lib.rankgraph2_optimizer()
        opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
        oshard = _state_shardings(opt_shapes, params_shapes, pshard, mesh)
        batch = batch_sds()
        bsh = jax.tree.map(lambda v: _safe(mesh, bspec, v), batch)

        def step(params, opt_state, batch):
            def loss_fn(p):
                if kind == "sasrec":
                    return R.sasrec_loss(p, cfg, batch["seq"], batch["pos"],
                                         batch["neg"], ctx)
                return R.bce_loss(fwd(p, batch), batch["labels"])
            loss, grads = jax.value_and_grad(loss_fn)(params)
            grads, _ = opt_lib.clip_by_global_norm(grads, 1.0)
            upd, opt_state = optimizer.update(grads, opt_state, params)
            return loss, opt_lib.apply_updates(params, upd), opt_state

        return Cell(arch.arch_id, shape.name, step,
                    (params_shapes, opt_shapes, batch),
                    (pshard, oshard, bsh), 3.0 * flops)

    if shape.name == "retrieval_cand":
        N = shape.dims["n_candidates"]
        cand = _sds((N,), i32)
        cshard = _safe(mesh, shd.logical_to_spec(("candidates",), rules),
                       cand)
        user_batch = {k: v for k, v in batch_sds().items()
                      if k not in ("labels", "pos", "neg")}
        ushard = jax.tree.map(lambda _: _named(mesh, P()), user_batch)

        def step(params, batch, cand_ids):
            if kind == "sasrec":
                u = R.sasrec_user_repr(params, cfg, batch["seq"], ctx)
            elif kind == "bst":
                V = params["items"].shape[0]
                e = R.take_rows(params["items"], batch["seq"][0] % V, ctx)
                u = jnp.mean(e, axis=0, keepdims=True).astype(
                    jnp.dtype(cfg.dtype))
            else:
                tab = params["tables"]
                e = R.take_rows(tab[0], batch["sparse"][0] % tab.shape[1],
                                ctx)
                u = jnp.mean(e, axis=0, keepdims=True).astype(
                    jnp.dtype(cfg.dtype))
            key = "items" if kind in ("sasrec", "bst") else "tables"
            table = params[key] if kind in ("sasrec", "bst") \
                else params[key][0]
            cvec = R.take_rows(table, cand_ids % table.shape[0], ctx)
            cvec = ctx(cvec.astype(u.dtype), "candidates", None)
            scores = (u @ cvec.T)[0]
            return jax.lax.top_k(scores, 100)

        flops_r = 2.0 * N * cfg.embed_dim
        return Cell(arch.arch_id, shape.name, step,
                    (params_shapes, user_batch, cand),
                    (pshard, ushard, cshard), flops_r,
                    notes="retrieval: query embedding vs 1M candidates, "
                          "sharded dot + distributed top-k")

    # serve_p99 / serve_bulk
    batch = {k: v for k, v in batch_sds().items() if k != "labels"}
    if kind == "sasrec":
        batch = {"seq": batch["seq"]}
    bsh = jax.tree.map(lambda v: _safe(mesh, bspec, v), batch)

    def step(params, batch):
        return fwd(params, batch)

    return Cell(arch.arch_id, shape.name, step, (params_shapes, batch),
                (pshard, bsh), flops)


def dataclasses_replace_small(cfg):
    """Clone a recsys config with a tiny vocab (specs are vocab-agnostic;
    avoids allocating 10M-row tables just to read the spec tree)."""
    import dataclasses as dc
    return dc.replace(cfg, default_vocab=8)


def _recsys_flops(cfg, B: int) -> float:
    D = cfg.embed_dim
    if cfg.kind == "dlrm":
        mlp = 0
        dims = [cfg.n_dense, *cfg.bot_mlp]
        mlp += sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
        n_vec = cfg.n_sparse + 1
        inter = n_vec * n_vec * D * 2
        dims = [n_vec * (n_vec - 1) // 2 + cfg.bot_mlp[-1], *cfg.top_mlp]
        mlp += sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
        return float(B) * (mlp + inter)
    if cfg.kind == "wide_deep":
        dims = [cfg.n_sparse * D, *cfg.bot_mlp, 1]
        return float(B) * sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    if cfg.kind == "sasrec":
        S = cfg.seq_len
        per_block = 2 * S * (4 * D * D) + 2 * 2 * S * S * D + 2 * S * 8 * D * D
        return float(B) * cfg.n_blocks * per_block
    S = cfg.seq_len + 1
    per_block = 2 * S * (4 * D * D) + 2 * 2 * S * S * D + 2 * S * 8 * D * D
    dims = [S * D + cfg.n_sparse * D, *cfg.top_mlp]
    mlp = sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    return float(B) * (cfg.n_blocks * per_block + mlp)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_cell(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh,
              cfg=None) -> Cell:
    from repro.models.gnn import equiformer as EQ
    import dataclasses as dc
    is_probe = cfg is not None
    cfg = cfg or arch.config
    rules = _rules_for(arch.arch_id, shape, mesh)
    ctx = shd.ShardingCtx(rules, mesh)
    d = shape.dims
    DF = d.get("d_feat", cfg.d_feat)

    if shape.name == "minibatch_lg":
        B, f1, f2 = d["batch_nodes"], d["fanout1"], d["fanout2"]
        N = B + B * f1 + B * f1 * f2
        E = B * f1 + B * f1 * f2
    elif shape.name == "molecule":
        N = d["n_nodes"] * d["batch"]
        E = d["n_edges"] * d["batch"]
    else:
        N, E = d["n_nodes"], d["n_edges"]
    # pad to /32 (pod x data): pjit argument divisibility; pads are masked
    N = -(-N // 32) * 32
    E = -(-E // 32) * 32

    def probe(L: int) -> Cell:
        pcfg = dc.replace(arch.config, n_layers=L, unroll=True,
                          edge_chunk=max(E // 2, 1), d_feat=DF)
        if not BASELINE:
            pcfg = dc.replace(pcfg, edge_chunk=max(E // 2, pcfg.edge_chunk))
        return _gnn_cell(arch, shape, mesh, cfg=pcfg)

    probe = None if is_probe else probe
    cfg = dc.replace(cfg, d_feat=DF)
    if not is_probe and not BASELINE:
        # perf iteration (§Perf/equiformer #2): with the node accumulator
        # replicated over data, GSPMD all-reduces it once per edge chunk;
        # bound the chunk COUNT (<= ~24) instead of the chunk size so the
        # per-layer reduction traffic shrinks ~chunks/24 x.
        cfg = dc.replace(cfg, edge_chunk=max(cfg.edge_chunk, -(-E // 24)))
    params_shapes = jax.eval_shape(
        lambda: EQ.init_params(jax.random.key(0), cfg, DF)[0])
    specs = EQ.init_params(jax.random.key(0),
                           dc.replace(cfg, n_layers=1), DF)[1]
    pshard = _param_shardings(specs, rules, mesh)
    nspec = shd.logical_to_spec(("nodes",), rules)
    espec = shd.logical_to_spec(("edges",), rules)

    batch = {"feats": _sds((N, DF), f32), "src": _sds((E,), i32),
             "dst": _sds((E,), i32), "pos": _sds((N, 3), f32),
             "targets": _sds((N,), f32),
             "edge_mask": _sds((E,), jnp.bool_)}
    n2spec = shd.logical_to_spec(("nodes", None), rules)
    bsh = {"feats": _safe(mesh, n2spec, batch["feats"]),
           "src": _safe(mesh, espec, batch["src"]),
           "dst": _safe(mesh, espec, batch["dst"]),
           "pos": _safe(mesh, n2spec, batch["pos"]),
           "targets": _safe(mesh, nspec, batch["targets"]),
           "edge_mask": _safe(mesh, espec, batch["edge_mask"])}

    optimizer = opt_lib.make_optimizer("adamw", 1e-3)
    opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
    oshard = _state_shardings(opt_shapes, params_shapes, pshard, mesh)

    def step(params, opt_state, batch):
        def loss_fn(p):
            return EQ.node_mse_loss(
                p, cfg, batch["feats"], batch["src"], batch["dst"],
                batch["pos"], batch["targets"],
                edge_mask=batch["edge_mask"], ctx=ctx)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, _ = opt_lib.clip_by_global_norm(grads, 1.0)
        upd, opt_state = optimizer.update(grads, opt_state, params)
        return loss, opt_lib.apply_updates(params, upd), opt_state

    flops = _gnn_flops(cfg, N, E) * 3.0
    return Cell(arch.arch_id, shape.name, step,
                (params_shapes, opt_shapes, batch),
                (pshard, oshard, bsh), flops, probe=probe)


def _gnn_flops(cfg, N: int, E: int) -> float:
    C, L, M = cfg.d_hidden, cfg.l_max, cfg.m_max
    S = (L + 1) ** 2
    # wigner apply fwd+bwd rotate: 2 x sum (2l+1)^2 C
    rot = 2 * sum((2 * l + 1) ** 2 for l in range(L + 1)) * C * 2
    n0 = L + 1
    so2 = 2 * (n0 * C) ** 2 + sum(4 * 2 * ((L + 1 - m) * C) ** 2
                                  for m in range(1, M + 1))
    per_edge = rot + so2
    per_node = 2 * (L + 1) * C * C * 2 + 2 * C * 2 * C * 2 * 2
    return float(cfg.n_layers) * (E * per_edge + N * per_node)


# ---------------------------------------------------------------------------
# RankGraph-2 cells
# ---------------------------------------------------------------------------

def _rankgraph2_cell(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> Cell:
    from repro.core import trainer as T
    from repro.core import model as M
    from repro.core import rq_index as RQ
    cfg = arch.config
    rules = _rules_for(arch.arch_id, shape, mesh)
    ctx = shd.ShardingCtx(rules, mesh)

    def side_sds(B, d_feat):
        K = cfg.k_train
        return {"feat": _sds((B, d_feat), f32),
                "unbr_feat": _sds((B, K, cfg.d_user_feat), f32),
                "unbr_mask": _sds((B, K), f32),
                "inbr_feat": _sds((B, K, cfg.d_item_feat), f32),
                "inbr_mask": _sds((B, K), f32)}

    # specs are static python data: build from a tiny-RQ clone
    _, specs, optimizer = T.init_state(jax.random.key(0), cfg_small(cfg))
    params_shapes = jax.eval_shape(
        lambda: T.init_state(jax.random.key(0), cfg)[0].params)
    pshard = _param_shardings(specs, rules, mesh, params_shapes)
    bspec = _batch_spec(rules)
    rep = _named(mesh, P())

    if shape.step == "train":
        B = shape.dims["batch"] // 3
        K = cfg.k_train

        # dedup-format batch (the production train hot path): packed
        # unique-node sub-batches per node type + per-(edge_type, side)
        # gather maps.  Sizes assume the duplicate rates measured by
        # benchmarks/train_throughput.py: ~0.6 unique endpoints per
        # endpoint slot and ~2 neighbor-only pack rows per endpoint row.
        # (feat-mode rather than id-only: lowering closes over no
        # concrete FeatureStore; the compute structure is identical up
        # to two device-side gathers.)
        from repro.data.edge_dataset import _round_up
        slots = 3 * B                 # endpoint slots per type
        # 128 (not the dataset's pad_multiple) for pjit divisibility on
        # the production meshes; the cell is a shape model either way
        E = _round_up(slots * 6 // 10, 128)    # endpoint-unique rows
        U = 3 * E                              # + neighbor-only extras

        def pack_sds(d_feat):
            return {"feat": _sds((U, d_feat), f32),
                    "unbr_idx": _sds((E, K), i32),
                    "unbr_mask": _sds((E, K), f32),
                    "inbr_idx": _sds((E, K), i32),
                    "inbr_mask": _sds((E, K), f32)}

        def edge_sds():
            return {"src_map": _sds((B,), i32), "dst_map": _sds((B,), i32),
                    "weight": _sds((B,), f32),
                    "src_ids": _sds((B,), i32), "dst_ids": _sds((B,), i32)}

        batch = {
            "nodes": {"user": pack_sds(cfg.d_user_feat),
                      "item": pack_sds(cfg.d_item_feat)},
            "edges": {et: edge_sds() for et in ("uu", "ui", "ii")},
        }
        bsh = jax.tree.map(lambda v: _safe(mesh, bspec, v), batch)
        full_state = jax.eval_shape(
            lambda: T.init_state(jax.random.key(0), cfg)[0])
        sshard = dataclasses_set(full_state, pshard, rep, mesh, specs)

        # jit=False: the dry-run lowers/compiles the raw step itself
        # (with in_shardings); production call sites take the default
        # donated jit from make_train_step
        step = T.make_train_step(cfg, optimizer, ctx, jit=False)
        key = jax.eval_shape(lambda: jax.random.key(0))
        flops = 3.0 * _rg2_dedup_train_flops(cfg, 3 * B, E, U)
        return Cell(arch.arch_id, shape.name, step,
                    (full_state, batch, key),
                    (sshard, bsh, rep), flops)

    if shape.name == "retrieval_cand":
        # the online-KNN cost this system replaces: 1 query vs 1M users
        N = shape.dims["n_candidates"]
        q = _sds((1, cfg.d_embed), f32)
        pool = _sds((N, cfg.d_embed), f32)
        cshard = _safe(mesh, shd.logical_to_spec(("candidates", None),
                                                 rules), pool)

        def step(q, pool):
            scores = (q @ pool.T)[0]
            return jax.lax.top_k(scores, 100)

        return Cell(arch.arch_id, shape.name, step, (q, pool),
                    (rep, cshard), 2.0 * N * cfg.d_embed,
                    notes="online-KNN baseline the cluster index replaces")

    # serve_*: embedding generation + fused RQ cluster assignment
    B = shape.dims["batch"]
    side = side_sds(B, cfg.d_user_feat)
    ssh = jax.tree.map(lambda v: _safe(mesh, bspec, v), side)

    def step(params, side):
        _, prim = M.embed_side(params, cfg, side, M.USER, ctx)
        codes = RQ.assign_codes(params["rq"], prim, cfg.rq)
        return prim, codes

    flops = _rg2_flops(cfg, B) / 3.0 \
        + 2.0 * B * cfg.d_embed * sum(cfg.rq.codebook_sizes)
    return Cell(arch.arch_id, shape.name, step, (params_shapes, side),
                (pshard, ssh), flops,
                notes="embedding refresh + RQ cluster assignment")


def cfg_small(cfg):
    import dataclasses as dc
    return dc.replace(cfg, rq=dc.replace(cfg.rq, codebook_sizes=(8, 4),
                                         hist_len=4))


def dataclasses_set(full_state, pshard, rep, mesh, specs):
    """TrainState shardings: params from specs, rest replicated/matched."""
    from repro.core import trainer as T
    opt = jax.tree.map(lambda _: rep, full_state.opt_state)
    rq = jax.tree.map(lambda _: rep, full_state.rq_state)
    pool = jax.tree.map(lambda _: rep, full_state.pool)
    return T.TrainState(pshard, opt, rq, pool, rep)


def _rg2_dedup_train_flops(cfg, n_edges: int, E: int, U: int) -> float:
    """Useful FLOPs of the dedup train forward: each pack row runs the
    type encoder once, each endpoint-unique row aggregates once, and the
    contrastive + RQ terms stay per-edge (the legacy per-endpoint model
    in ``_rg2_flops`` would overstate encoder work by the dedup factor)."""
    de, h, H = cfg.d_embed, cfg.d_hidden, cfg.n_heads
    enc_u = 2 * cfg.d_user_feat * h + 2 * h * H * de
    enc_i = 2 * cfg.d_item_feat * h + 2 * h * H * de
    agg = H * 2 * 3 * de * de
    contrastive = 2 * cfg.n_negatives * de + 2 * de
    rq = 2 * de * sum(cfg.rq.codebook_sizes)
    return float(U * (enc_u + enc_i) + 2 * E * agg
                 + n_edges * (4 * contrastive + 2 * rq))


def _rg2_flops(cfg, B: int) -> float:
    d, h, de, K = (cfg.d_user_feat, cfg.d_hidden, cfg.d_embed, cfg.k_train)
    H = cfg.n_heads
    enc = 2 * d * h + 2 * h * H * de
    per_node = (1 + 2 * K) * enc + H * 2 * 3 * de * de
    contrastive = 2 * cfg.n_negatives * de + 2 * de
    rq = 2 * de * sum(cfg.rq.codebook_sizes)
    return float(B) * (2 * per_node + 4 * contrastive + 2 * rq)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_name: str, mesh: Mesh) -> Cell:
    arch = get_arch(arch_id)
    shape = arch.shape(shape_name)
    if arch.family == "lm":
        return _lm_cell(arch, shape, mesh)
    if arch.family == "recsys":
        return _recsys_cell(arch, shape, mesh)
    if arch.family == "gnn":
        return _gnn_cell(arch, shape, mesh)
    if arch.family == "rankgraph2":
        return _rankgraph2_cell(arch, shape, mesh)
    raise ValueError(arch.family)


def all_cells() -> list[Tuple[str, str]]:
    from repro.configs.base import list_archs
    out = []
    for a in list_archs():
        arch = get_arch(a)
        for s in arch.shapes:
            out.append((a, s.name))
    return out
