"""Generic architecture launcher (``--arch <id>``).

Runs a reduced-size training (or serving) loop for any registered
architecture on the host devices — the single-process development entry
point; the production meshes are exercised via dryrun.py.

    PYTHONPATH=src python -m repro.launch.train --arch dlrm-rm2 --steps 20
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 10
    PYTHONPATH=src python -m repro.launch.train --arch equiformer-v2
    PYTHONPATH=src python -m repro.launch.train --arch rankgraph2
"""
import argparse
import dataclasses as dc
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch, list_archs
from repro.optim import optimizers as opt_lib


def _reduced(cfg):
    from repro.configs.base import LMConfig, GNNConfig, RecsysConfig
    if isinstance(cfg, LMConfig):
        return dc.replace(cfg, n_layers=2, d_model=128, n_heads=4,
                          n_kv_heads=min(cfg.n_kv_heads, 4), head_dim=32,
                          d_ff=256, moe_d_ff=256 if cfg.n_experts else None,
                          n_experts=min(cfg.n_experts, 4), vocab_size=512,
                          dtype="float32", param_dtype="float32")
    if isinstance(cfg, GNNConfig):
        return dc.replace(cfg, n_layers=2, d_hidden=32, l_max=2,
                          edge_chunk=256, dtype="float32",
                          param_dtype="float32", remat=False)
    if isinstance(cfg, RecsysConfig):
        return dc.replace(cfg, default_vocab=5000, dtype="float32",
                          param_dtype="float32")
    return cfg


def run_lm(cfg, steps, batch=4, seq=64):
    from repro.models.lm import model as LM
    params, _ = LM.init_params(jax.random.key(0), cfg)
    opt = opt_lib.make_optimizer("adamw", 1e-3)
    st = opt.init(params)

    @jax.jit
    def step(params, st, toks):
        loss, g = jax.value_and_grad(
            lambda p: LM.lm_loss(p, cfg, toks, block_q=32))(params)
        upd, st = opt.update(g, st, params)
        return opt_lib.apply_updates(params, upd), st, loss

    rng = np.random.default_rng(0)
    for t in range(steps):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)))
        params, st, loss = step(params, st, toks)
        if t % max(steps // 5, 1) == 0:
            print(f"[{t}] lm loss {float(loss):.3f}")
    return float(loss)


def run_recsys(cfg, steps, batch=256):
    from repro.models.recsys import models as R
    rng = np.random.default_rng(0)
    key = jax.random.key(0)
    opt = opt_lib.rankgraph2_optimizer()
    if cfg.kind == "dlrm":
        params, _ = R.dlrm_init(key, cfg)
        fwd = lambda p, b: R.dlrm_forward(p, cfg, b["dense"], b["sparse"])
        mk = lambda: {"dense": jnp.asarray(rng.normal(
            size=(batch, cfg.n_dense)).astype(np.float32)),
            "sparse": jnp.asarray(rng.integers(
                0, cfg.default_vocab, (batch, cfg.n_sparse))),
            "labels": jnp.asarray((rng.random(batch) > .5
                                   ).astype(np.float32))}
    elif cfg.kind == "wide_deep":
        params, _ = R.wide_deep_init(key, cfg)
        fwd = lambda p, b: R.wide_deep_forward(p, cfg, None, b["sparse"])
        mk = lambda: {"sparse": jnp.asarray(rng.integers(
            0, cfg.default_vocab, (batch, cfg.n_sparse))),
            "labels": jnp.asarray((rng.random(batch) > .5
                                   ).astype(np.float32))}
    elif cfg.kind == "bst":
        params, _ = R.bst_init(key, cfg)
        fwd = lambda p, b: R.bst_forward(p, cfg, b["seq"], b["tgt"],
                                         b["other"])
        mk = lambda: {"seq": jnp.asarray(rng.integers(
            -1, cfg.default_vocab, (batch, cfg.seq_len))),
            "tgt": jnp.asarray(rng.integers(0, cfg.default_vocab, batch)),
            "other": jnp.asarray(rng.integers(
                0, cfg.default_vocab, (batch, cfg.n_sparse))),
            "labels": jnp.asarray((rng.random(batch) > .5
                                   ).astype(np.float32))}
    else:  # sasrec
        params, _ = R.sasrec_init(key, cfg)
        st = opt.init(params)

        @jax.jit
        def step(params, st, seq, pos, neg):
            loss, g = jax.value_and_grad(
                lambda p: R.sasrec_loss(p, cfg, seq, pos, neg))(params)
            upd, st = opt.update(g, st, params)
            return opt_lib.apply_updates(params, upd), st, loss

        for t in range(steps):
            seq = jnp.asarray(rng.integers(-1, cfg.default_vocab,
                                           (batch, cfg.seq_len)))
            pos = jnp.asarray(rng.integers(0, cfg.default_vocab, batch))
            neg = jnp.asarray(rng.integers(0, cfg.default_vocab,
                                           (batch, 20)))
            params, st, loss = step(params, st, seq, pos, neg)
            if t % max(steps // 5, 1) == 0:
                print(f"[{t}] sasrec loss {float(loss):.3f}")
        return float(loss)

    st = opt.init(params)

    @jax.jit
    def step(params, st, b):
        loss, g = jax.value_and_grad(
            lambda p: R.bce_loss(fwd(p, b), b["labels"]))(params)
        upd, st = opt.update(g, st, params)
        return opt_lib.apply_updates(params, upd), st, loss

    for t in range(steps):
        params, st, loss = step(params, st, mk())
        if t % max(steps // 5, 1) == 0:
            print(f"[{t}] {cfg.kind} bce {float(loss):.3f}")
    return float(loss)


def run_gnn(cfg, steps):
    from repro.models.gnn import equiformer as EQ
    from repro.models.gnn.sampler import make_random_graph
    rng = np.random.default_rng(0)
    N, E, DF = 200, 800, 16
    cfg = dc.replace(cfg, d_feat=DF)
    params, _ = EQ.init_params(jax.random.key(0), cfg, DF)
    feats = jnp.asarray(rng.normal(size=(N, DF)).astype(np.float32))
    pos = jnp.asarray(rng.normal(size=(N, 3)).astype(np.float32))
    src, dst = make_random_graph(N, E, seed=0)
    targets = jnp.asarray(rng.normal(size=N).astype(np.float32))
    opt = opt_lib.make_optimizer("adamw", 1e-3)
    st = opt.init(params)

    @jax.jit
    def step(params, st):
        loss, g = jax.value_and_grad(
            lambda p: EQ.node_mse_loss(p, cfg, feats, jnp.asarray(src),
                                       jnp.asarray(dst), pos, targets)
        )(params)
        upd, st = opt.update(g, st, params)
        return opt_lib.apply_updates(params, upd), st, loss

    for t in range(steps):
        params, st, loss = step(params, st)
        if t % max(steps // 5, 1) == 0:
            print(f"[{t}] equiformer mse {float(loss):.3f}")
    return float(loss)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=False, default="rankgraph2",
                    choices=list_archs())
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args(argv)
    arch = get_arch(args.arch)
    cfg = _reduced(arch.config)
    t0 = time.perf_counter()
    if arch.family == "lm":
        run_lm(cfg, args.steps)
    elif arch.family == "recsys":
        run_recsys(cfg, args.steps)
    elif arch.family == "gnn":
        run_gnn(cfg, args.steps)
    else:
        print("rankgraph2: see examples/train_rankgraph2.py (full driver)")
    print(f"done in {time.perf_counter()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
