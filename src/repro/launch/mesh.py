"""Production mesh builders.

Functions (not module-level constants) so importing never touches jax
device state.  The dry-run sets XLA_FLAGS host-device-count=512 *before*
importing jax; everything else sees the real device count.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None) -> Mesh:
    """Best-effort mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    model = model or 1
    data = max(1, n // model)
    devs = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def mesh_chip_count(mesh: Mesh) -> int:
    return int(np.prod(mesh.devices.shape))
