import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and record memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b  # one arch
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \
        --shape train_4k --mesh multipod

Results are cached in benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json
(one file per cell, so the sweep is resumable on this 1-core container).
The 512 placeholder host devices exist ONLY here — set before any jax
import, since jax locks the device count on first init.
"""
import argparse
import json
import re
import sys
import traceback
from typing import Any, Dict, Optional

import jax

from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, all_cells
from repro.obs import get_telemetry

RESULTS_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "benchmarks", "results", "dryrun"))

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8,
                "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred|c64|c128|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        # match '<shape> <op-name>(' on instruction lines, not metadata
        for coll in _COLLECTIVES:
            if f" {coll}(" in ls or f"{coll}-start(" in ls:
                lhs = ls.split("=", 1)
                if len(lhs) != 2:
                    continue
                shape_part = lhs[1].strip().split(coll)[0]
                b = _shape_bytes(shape_part)
                out[coll] += b
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def _mem_analysis(compiled) -> Dict[str, Any]:
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             verbose: bool = True) -> Dict[str, Any]:
    tel = get_telemetry()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = int(mesh.devices.size)
    with tel.span("dryrun.build", arch=arch_id, shape=shape_name,
                  mesh=mesh_kind) as sp:
        cell = build_cell(arch_id, shape_name, mesh)
    t_build = sp.duration_s

    with mesh:
        with tel.span("dryrun.lower", arch=arch_id,
                      shape=shape_name) as sp:
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings)
            lowered = jitted.lower(*cell.args)
        t_lower = sp.duration_s
        with tel.span("dryrun.compile", arch=arch_id,
                      shape=shape_name) as sp:
            compiled = lowered.compile()
        t_compile = sp.duration_s

    cost = compiled.cost_analysis() or {}
    mem = _mem_analysis(compiled)
    print_mem = {k: f"{v/2**30:.3f}GiB" for k, v in mem.items()
                 if "size" in k}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo)

    # loop-corrected totals: XLA cost analysis counts while bodies once,
    # so scanned cells are re-measured via two unrolled probe lowerings
    # (n_layers = 1, 2) and extrapolated linearly over layers.
    corrected = dict(flops=float(cost.get("flops", 0.0)),
                     bytes_accessed=float(cost.get("bytes accessed", 0.0)),
                     collective_total=float(coll["total"]),
                     method="exact (no loops)")
    t_probe = 0.0
    if cell.probe is not None:
        with tel.span("dryrun.probe", arch=arch_id,
                      shape=shape_name) as sp:
            samples = {}
            for L in (1, 2):
                pcell = cell.probe(L)
                with mesh:
                    pc = jax.jit(pcell.fn,
                                 in_shardings=pcell.in_shardings
                                 ).lower(*pcell.args).compile()
                pcost = pc.cost_analysis() or {}
                pcoll = collective_bytes(pc.as_text())
                samples[L] = (float(pcost.get("flops", 0.0)),
                              float(pcost.get("bytes accessed", 0.0)),
                              float(pcoll["total"]))
        t_probe = sp.duration_s
        from repro.configs.base import get_arch
        n_layers = get_arch(arch_id).config.n_layers
        f1, f2 = samples[1], samples[2]
        corrected = dict(
            flops=f1[0] + (n_layers - 1) * (f2[0] - f1[0]),
            bytes_accessed=f1[1] + (n_layers - 1) * (f2[1] - f1[1]),
            collective_total=f1[2] + (n_layers - 1) * (f2[2] - f1[2]),
            method="probe-extrapolated (unrolled L=1,2)",
            probe_samples={str(k): v for k, v in samples.items()})

    rec = dict(
        arch=arch_id, shape=shape_name, mesh=mesh_kind, n_chips=n_chips,
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        model_flops=cell.model_flops,
        collective=coll,
        corrected=corrected,
        memory=mem,
        hlo_lines=len(hlo.splitlines()),
        seconds=dict(build=t_build, lower=t_lower, compile=t_compile,
                     probe=t_probe),
        notes=cell.notes,
    )
    if verbose:
        print(f"[dryrun] {arch_id} x {shape_name} @ {mesh_kind} "
              f"({n_chips} chips)")
        print(f"  memory_analysis: {print_mem}")
        print(f"  cost_analysis: flops={rec['flops']:.3e} "
              f"bytes={rec['bytes_accessed']:.3e} "
              f"(model_flops={cell.model_flops:.3e})")
        print(f"  corrected [{corrected['method']}]: "
              f"flops={corrected['flops']:.3e} "
              f"bytes={corrected['bytes_accessed']:.3e} "
              f"coll={corrected['collective_total']/2**30:.3f}GiB")
        print(f"  collectives(raw): total={coll['total']/2**30:.3f}GiB "
              f"over {coll['count']} ops")
        print(f"  t: build {t_build:.1f}s lower {t_lower:.1f}s "
              f"compile {t_compile:.1f}s probe {t_probe:.1f}s")
    return rec


def cell_path(mesh_kind: str, arch_id: str, shape_name: str) -> str:
    d = os.path.join(RESULTS_DIR, mesh_kind)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch_id}__{shape_name}.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="singlepod",
                    choices=["singlepod", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    cells = all_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    if args.list:
        for a, s in cells:
            print(f"{a} x {s}")
        return 0
    meshes = (["singlepod", "multipod"] if args.mesh == "both"
              else [args.mesh])

    failures = []
    for mesh_kind in meshes:
        for a, s in cells:
            path = cell_path(mesh_kind, a, s)
            if os.path.exists(path) and not args.force:
                print(f"[dryrun] cached: {a} x {s} @ {mesh_kind}")
                continue
            try:
                rec = run_cell(a, s, mesh_kind)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception as e:
                traceback.print_exc()
                failures.append((mesh_kind, a, s, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f4 in failures:
            print("  ", f4)
        return 1
    print("\nall requested dry-run cells compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
