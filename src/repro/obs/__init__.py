"""repro.obs — stdlib-only lifecycle telemetry.

Three primitives behind one facade:

* **spans** — context-manager timers emitting JSONL trace events with
  name, parent, wall time, duration, and free-form attrs;
* **counters / gauges** — thread-safe registry with per-thread shards
  merged on read, so the serving hot path never takes a contended lock;
* **histograms** — fixed log-spaced buckets (1 µs base, √2 growth) with
  p50/p95/p99 extraction.

All clock access flows through the injectable :class:`Clock`;
:class:`SystemClock` in :mod:`repro.obs.clock` is the single sanctioned
raw-clock site enforced by the ``determinism`` analysis rule.

Module-level conveniences delegate to the process-wide singleton:

    from repro import obs
    obs.configure(path="run.jsonl")
    with obs.span("construct", stage="graph"):
        ...
    obs.counter("serving.seqlock_retries")
    obs.flush()

Render with ``python -m repro.obs.report run.jsonl``.
"""
from __future__ import annotations

from .clock import Clock, FixedClock, SystemClock
from .metrics import Histogram, MetricsRegistry
from .sink import JsonlSink, MemorySink, NullSink, Sink
from .telemetry import Span, Telemetry, configure, get_telemetry

__all__ = [
    "Clock", "FixedClock", "SystemClock",
    "Histogram", "MetricsRegistry",
    "Sink", "NullSink", "MemorySink", "JsonlSink",
    "Span", "Telemetry", "configure", "get_telemetry",
    "span", "counter", "gauge", "observe", "flush", "snapshot",
]


def span(name: str, **attrs) -> Span:
    return get_telemetry().span(name, **attrs)


def counter(name: str, delta: float = 1.0) -> None:
    get_telemetry().counter(name, delta)


def gauge(name: str, value: float) -> None:
    get_telemetry().gauge(name, value)


def observe(name: str, value: float) -> None:
    get_telemetry().observe(name, value)


def flush() -> None:
    get_telemetry().flush()


def snapshot() -> dict:
    return get_telemetry().snapshot()
