"""Injectable clock — the single sanctioned raw-clock site in the tree.

Every other module obtains time through a :class:`Clock` (usually via
the :class:`~repro.obs.telemetry.Telemetry` facade), so the
``determinism`` analysis rule can flag any *new* raw ``time.time()`` /
``time.perf_counter()`` call outside ``src/repro/obs/`` while this one
module stays exempt.

Two implementations:

* :class:`SystemClock` — wraps the real wall/monotonic clocks.
* :class:`FixedClock` — fully deterministic; ``perf()`` auto-advances by
  a fixed tick so spans get stable nonzero durations, which makes
  telemetry JSONL byte-reproducible in tests.
"""
from __future__ import annotations

import time


class Clock:
    """Time source interface: ``wall()`` epoch seconds, ``perf()`` monotonic."""

    def wall(self) -> float:
        raise NotImplementedError

    def perf(self) -> float:
        raise NotImplementedError


class SystemClock(Clock):
    """Real clocks. The only place in the tree that calls ``time.*`` raw."""

    def wall(self) -> float:
        return time.time()

    def perf(self) -> float:
        return time.perf_counter()


class FixedClock(Clock):
    """Deterministic clock for tests.

    ``wall()`` returns a constant; ``perf()`` returns a monotonically
    increasing value that advances by ``tick`` on every call, so code
    that measures ``perf() - perf()`` deltas sees stable, nonzero
    durations regardless of host speed.
    """

    def __init__(self, wall: float = 1_700_000_000.0,
                 perf: float = 0.0, tick: float = 1e-3) -> None:
        self._wall = float(wall)
        self._perf = float(perf)
        self._tick = float(tick)

    def wall(self) -> float:
        return self._wall

    def perf(self) -> float:
        self._perf += self._tick
        return self._perf

    def advance(self, dt: float) -> None:
        """Jump both clocks forward by ``dt`` seconds."""
        self._wall += dt
        self._perf += dt
