"""Telemetry sinks — where serialized JSONL records go.

Sinks receive *pre-serialized* lines (no trailing newline) so the hot
path pays the ``json.dumps`` cost exactly once and a sink never has to
understand record schemas. :class:`JsonlSink` is bounded: when the
active file would exceed ``max_bytes`` it shift-rotates
(``f.jsonl.1`` → ``f.jsonl.2`` …, oldest dropped past ``max_files``),
so a long-running process can emit forever without unbounded disk use.
"""
from __future__ import annotations

import os
import threading
from typing import List


class Sink:
    """Destination for serialized telemetry lines."""

    def write_line(self, line: str) -> None:
        raise NotImplementedError

    def flush(self) -> None:  # pragma: no cover - trivial default
        pass

    def close(self) -> None:  # pragma: no cover - trivial default
        pass


class NullSink(Sink):
    """Discards everything. Used when telemetry is disabled."""

    def write_line(self, line: str) -> None:
        pass


class MemorySink(Sink):
    """Accumulates lines in memory — the workhorse for tests."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.lines: List[str] = []

    def write_line(self, line: str) -> None:
        with self._lock:
            self.lines.append(line)

    def text(self) -> str:
        with self._lock:
            return "".join(ln + "\n" for ln in self.lines)


class JsonlSink(Sink):
    """Rotating JSONL file sink with explicit flush.

    Writes are buffered by the underlying file object; callers that need
    durability (benchmarks before reading the file back, examples before
    exit) call :meth:`flush`. Rotation keeps at most ``max_files``
    historical files of roughly ``max_bytes`` each.
    """

    def __init__(self, path: str, *, max_bytes: int = 64 * 1024 * 1024,
                 max_files: int = 4) -> None:
        if max_bytes <= 0:
            raise ValueError("max_bytes must be positive")
        self.path = str(path)
        self.max_bytes = int(max_bytes)
        self.max_files = int(max_files)
        self._lock = threading.Lock()
        self._fh = None
        self._size = 0

    def _open(self) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._fh = open(self.path, "a", encoding="utf-8")
        self._size = self._fh.tell()

    def _rotate(self) -> None:
        self._fh.close()
        self._fh = None
        if self.max_files <= 1:
            os.remove(self.path)
        else:
            oldest = f"{self.path}.{self.max_files - 1}"
            if os.path.exists(oldest):
                os.remove(oldest)
            for i in range(self.max_files - 1, 0, -1):
                src = self.path if i == 1 else f"{self.path}.{i - 1}"
                if os.path.exists(src):
                    os.replace(src, f"{self.path}.{i}")
        self._open()

    def write_line(self, line: str) -> None:
        data = line + "\n"
        with self._lock:
            if self._fh is None:
                self._open()
            if self._size + len(data) > self.max_bytes and self._size > 0:
                self._rotate()
            self._fh.write(data)
            self._size += len(data)

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None
