"""Render telemetry JSONL into a latency-breakdown tree + metric summary.

    PYTHONPATH=src python -m repro.obs.report run.jsonl [more.jsonl ...]

Spans are aggregated by their full name path (root → leaf, resolved via
``parent_id``) across all input files; counters take the *last*
cumulative record per file and sum across files; gauges take the last
record overall; histograms take the last cumulative record per file and
merge, then print n / mean / p50 / p95 / p99 / max.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

from .metrics import Histogram


def fmt_s(v: float) -> str:
    """Human duration: 1.23us / 4.56ms / 7.89s."""
    a = abs(v)
    if a < 1e-3:
        return f"{v * 1e6:.2f}us"
    if a < 1.0:
        return f"{v * 1e3:.2f}ms"
    return f"{v:.3f}s"


def load_records(paths: List[str]) -> List[List[dict]]:
    """One list of parsed records per input file; bad lines are skipped."""
    out = []
    for p in paths:
        recs = []
        with open(p, encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    recs.append(json.loads(line))
                except ValueError:
                    continue
        out.append(recs)
    return out


def span_paths(per_file: List[List[dict]]
               ) -> Dict[Tuple[str, ...], List[float]]:
    """Aggregate spans by name path → [count, total_s, max_s]."""
    agg: Dict[Tuple[str, ...], List[float]] = {}
    for recs in per_file:
        spans = {r["span_id"]: r for r in recs if r.get("type") == "span"}
        for r in spans.values():
            path = [r["name"]]
            pid = r.get("parent_id")
            hops = 0
            while pid is not None and pid in spans and hops < 64:
                parent = spans[pid]
                path.append(parent["name"])
                pid = parent.get("parent_id")
                hops += 1
            key = tuple(reversed(path))
            ent = agg.setdefault(key, [0, 0.0, 0.0])
            ent[0] += 1
            ent[1] += r.get("dur_s", 0.0)
            ent[2] = max(ent[2], r.get("dur_s", 0.0))
    return agg


def render_span_tree(agg: Dict[Tuple[str, ...], List[float]]) -> List[str]:
    lines = [f"{'span':<44} {'count':>6} {'total':>10} "
             f"{'mean':>10} {'max':>10}"]

    def children_of(prefix: Tuple[str, ...]) -> List[Tuple[str, ...]]:
        kids = [k for k in agg
                if len(k) == len(prefix) + 1 and k[:len(prefix)] == prefix]
        return sorted(kids, key=lambda k: -agg[k][1])

    def walk(prefix: Tuple[str, ...], depth: int) -> None:
        for key in children_of(prefix):
            count, total, mx = agg[key]
            label = "  " * depth + key[-1]
            lines.append(f"{label:<44} {int(count):>6} {fmt_s(total):>10} "
                         f"{fmt_s(total / count):>10} {fmt_s(mx):>10}")
            walk(key, depth + 1)

    walk((), 0)
    return lines


def metric_summary(per_file: List[List[dict]]) -> Tuple[
        Dict[str, float], Dict[str, float], Dict[str, Histogram]]:
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    hists: Dict[str, Histogram] = {}
    for recs in per_file:
        last_c: Dict[str, float] = {}
        last_h: Dict[str, dict] = {}
        for r in recs:
            t = r.get("type")
            if t == "counter":
                last_c[r["name"]] = r["value"]
            elif t == "gauge":
                gauges[r["name"]] = r["value"]
            elif t == "hist":
                last_h[r["name"]] = r
        for name, v in last_c.items():
            counters[name] = counters.get(name, 0.0) + v
        for name, d in last_h.items():
            h = hists.setdefault(name, Histogram())
            h.merge(Histogram.from_dict(d))
    return counters, gauges, hists


def render(paths: List[str]) -> str:
    per_file = load_records(paths)
    out = [f"telemetry report — {len(paths)} file(s), "
           f"{sum(len(r) for r in per_file)} records", ""]

    agg = span_paths(per_file)
    if agg:
        out.append("== span tree ==")
        out.extend(render_span_tree(agg))
        out.append("")

    counters, gauges, hists = metric_summary(per_file)
    if counters:
        out.append("== counters ==")
        for name in sorted(counters):
            v = counters[name]
            out.append(f"{name:<44} {v:>12g}")
        out.append("")
    if gauges:
        out.append("== gauges ==")
        for name in sorted(gauges):
            out.append(f"{name:<44} {gauges[name]:>12g}")
        out.append("")
    if hists:
        out.append("== histograms ==")
        for name in sorted(hists):
            h = hists[name]
            out.append(
                f"{name:<36} n={h.n:<8d} mean={fmt_s(h.mean):<9} "
                f"p50={fmt_s(h.percentile(0.5)):<9} "
                f"p95={fmt_s(h.percentile(0.95)):<9} "
                f"p99={fmt_s(h.percentile(0.99)):<9} "
                f"max={fmt_s(h.max if h.n else 0.0)}")
        out.append("")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render telemetry JSONL files.")
    ap.add_argument("paths", nargs="+", help="telemetry JSONL file(s)")
    args = ap.parse_args(argv)
    print(render(args.paths))
    return 0


if __name__ == "__main__":
    sys.exit(main())
