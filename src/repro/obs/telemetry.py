"""Telemetry facade: spans + metrics + sink behind one object.

One :class:`Telemetry` instance owns a clock, a metrics registry, and a
sink. Spans are context managers that always *measure* (callers rely on
``span.elapsed()`` for report fields like ``build_seconds``) but only
*emit* JSONL when the instance is enabled. Counters/gauges/histograms
write to per-thread shards (see :mod:`repro.obs.metrics`) and are
serialized cumulatively on :meth:`Telemetry.flush`.

A module-level singleton (:func:`get_telemetry` / :func:`configure`)
lets instrumented library code default to the process-wide instance
while tests inject private ones. ``configure`` mutates the singleton
*in place* so references captured at construction time (e.g. a store
built before the benchmark configured telemetry) observe the change.

JSONL schema (one object per line, sorted keys, compact separators):

* ``{"type": "span", "name", "span_id", "parent_id", "thread",
  "t_wall", "dur_s", "attrs"}``
* ``{"type": "counter"|"gauge", "name", "value", "t_wall"}``
* ``{"type": "hist", "name", "t_wall", "n", "sum", "min", "max",
  "counts", "base", "growth"}`` — cumulative at flush time.
"""
from __future__ import annotations

import itertools
import json
import threading
from typing import Dict, Optional, Tuple

from .clock import Clock, SystemClock
from .metrics import Histogram, MetricsRegistry
from .sink import JsonlSink, NullSink, Sink


def _dumps(obj: dict) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=float)


class Span:
    """Context-manager timer. Measures always; emits only when enabled."""

    __slots__ = ("name", "attrs", "span_id", "parent_id",
                 "t_wall", "duration_s", "_tel", "_t0")

    def __init__(self, tel: "Telemetry", name: str,
                 attrs: Optional[dict] = None) -> None:
        self._tel = tel
        self.name = name
        self.attrs = dict(attrs) if attrs else {}
        self.span_id: Optional[int] = None
        self.parent_id: Optional[int] = None
        self.t_wall = 0.0
        self.duration_s = 0.0
        self._t0 = 0.0

    def set(self, key: str, value) -> "Span":
        self.attrs[key] = value
        return self

    def elapsed(self) -> float:
        """Seconds since span entry (usable before and after exit)."""
        if self.duration_s:
            return self.duration_s
        return self._tel._clock.perf() - self._t0

    def __enter__(self) -> "Span":
        tel = self._tel
        self.span_id = next(tel._span_ids)
        self.t_wall = tel._clock.wall()
        stack = tel._span_stack()
        self.parent_id = stack[-1].span_id if stack else None
        stack.append(self)
        self._t0 = tel._clock.perf()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        tel = self._tel
        self.duration_s = tel._clock.perf() - self._t0
        stack = tel._span_stack()
        if stack and stack[-1] is self:
            stack.pop()
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        tel._emit_span(self)


class Telemetry:
    """Facade over clock + metrics registry + sink."""

    def __init__(self, *, sink: Optional[Sink] = None,
                 clock: Optional[Clock] = None,
                 enabled: bool = True) -> None:
        self._sink: Sink = sink if sink is not None else NullSink()
        self._clock: Clock = clock if clock is not None else SystemClock()
        self.enabled = bool(enabled)
        self.metrics = MetricsRegistry()
        self._span_ids = itertools.count(1)
        self._tls = threading.local()
        self._ti_lock = threading.Lock()
        self._thread_ids: Dict[int, int] = {}

    # -- internals -------------------------------------------------------
    def _span_stack(self):
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _thread_index(self) -> int:
        ti = getattr(self._tls, "ti", None)
        if ti is None:
            ident = threading.get_ident()
            with self._ti_lock:
                ti = self._thread_ids.setdefault(ident,
                                                 len(self._thread_ids))
            self._tls.ti = ti
        return ti

    def _emit_span(self, sp: Span) -> None:
        if not self.enabled:
            return
        self._sink.write_line(_dumps({
            "type": "span",
            "name": sp.name,
            "span_id": sp.span_id,
            "parent_id": sp.parent_id,
            "thread": self._thread_index(),
            "t_wall": sp.t_wall,
            "dur_s": sp.duration_s,
            "attrs": sp.attrs,
        }))

    # -- public API ------------------------------------------------------
    @property
    def clock(self) -> Clock:
        return self._clock

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs or None)

    def counter(self, name: str, delta: float = 1.0) -> None:
        if self.enabled:
            self.metrics.counter(name, delta)

    def gauge(self, name: str, value: float) -> None:
        if self.enabled:
            self.metrics.gauge(name, float(value))

    def observe(self, name: str, value: float) -> None:
        if self.enabled:
            self.metrics.observe(name, float(value))

    def snapshot(self) -> dict:
        """Merged metric state: counters, gauges, histogram summaries."""
        counters, gauges, hists = self.metrics.merged()
        return {
            "counters": dict(sorted(counters.items())),
            "gauges": dict(sorted(gauges.items())),
            "hists": {k: h.to_dict() for k, h in sorted(hists.items())},
        }

    def percentiles(self, name: str,
                    qs: Tuple[float, ...] = (0.5, 0.95, 0.99)
                    ) -> Dict[str, float]:
        _, _, hists = self.metrics.merged()
        h = hists.get(name, Histogram())
        return {f"p{int(q * 100)}": h.percentile(q) for q in qs}

    def flush(self) -> None:
        """Serialize cumulative metric state to the sink, then flush it."""
        if self.enabled:
            counters, gauges, hists = self.metrics.merged()
            t = self._clock.wall()
            for name in sorted(counters):
                self._sink.write_line(_dumps({
                    "type": "counter", "name": name,
                    "value": counters[name], "t_wall": t}))
            for name in sorted(gauges):
                self._sink.write_line(_dumps({
                    "type": "gauge", "name": name,
                    "value": gauges[name], "t_wall": t}))
            for name in sorted(hists):
                rec = {"type": "hist", "name": name, "t_wall": t}
                rec.update(hists[name].to_dict())
                self._sink.write_line(_dumps(rec))
        self._sink.flush()

    def reset_metrics(self) -> None:
        self.metrics.reset()

    def reconfigure(self, *, sink: Optional[Sink] = None,
                    clock: Optional[Clock] = None,
                    enabled: Optional[bool] = None) -> "Telemetry":
        """Mutate this instance in place (late-bound refs see the change)."""
        if sink is not None:
            old = self._sink
            self._sink = sink
            old.close()
        if clock is not None:
            self._clock = clock
        if enabled is not None:
            self.enabled = bool(enabled)
        return self


# Process-wide singleton. Disabled by default: library code is
# instrumented unconditionally and pays ~one attribute check until an
# entry point (benchmark, example, test) calls ``configure``.
_GLOBAL = Telemetry(enabled=False)


def get_telemetry() -> Telemetry:
    return _GLOBAL


def configure(*, path: Optional[str] = None, sink: Optional[Sink] = None,
              clock: Optional[Clock] = None, enabled: bool = True,
              max_bytes: int = 64 * 1024 * 1024,
              max_files: int = 4) -> Telemetry:
    """(Re)configure the process-wide telemetry singleton in place."""
    if sink is None and path is not None:
        sink = JsonlSink(path, max_bytes=max_bytes, max_files=max_files)
    if sink is None and not enabled:
        sink = NullSink()
    return _GLOBAL.reconfigure(sink=sink, clock=clock, enabled=enabled)
