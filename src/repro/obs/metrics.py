"""Counters, gauges, and log-bucket histograms with per-thread shards.

Hot-path writes (``counter``/``gauge``/``observe``) touch only the
calling thread's private shard — a plain dict update under the GIL, no
shared lock — so the serving retrieve path never contends with other
readers or with a scraper. Reads (:meth:`MetricsRegistry.merged`)
take the registry lock once to snapshot the shard list, then merge.
Shards are registered at first use and kept for the life of the
registry so no samples are lost when a thread exits.

Histograms use fixed log-spaced buckets: bucket ``i`` covers
``[BASE * GROWTH**i, BASE * GROWTH**(i+1))`` with ``BASE = 1e-6`` s and
``GROWTH = sqrt(2)``, i.e. ~10% relative resolution from 1 µs to
~45 min in 64 buckets. Percentiles are read back at the geometric
bucket midpoint.
"""
from __future__ import annotations

import itertools
import math
import threading
from typing import Dict, Iterable, List, Optional, Tuple

HIST_BASE = 1e-6
HIST_GROWTH = math.sqrt(2.0)
HIST_BUCKETS = 64

_LOG_GROWTH = math.log(HIST_GROWTH)

# Global sequence for gauge last-write-wins merge across shards.
_gauge_seq = itertools.count()


def bucket_index(value: float) -> int:
    """Bucket for ``value`` (seconds or any nonnegative quantity)."""
    if value < HIST_BASE:
        return 0
    i = int(math.log(value / HIST_BASE) / _LOG_GROWTH)
    return min(max(i, 0), HIST_BUCKETS - 1)


def bucket_mid(i: int) -> float:
    """Geometric midpoint of bucket ``i`` — the read-back value."""
    lo = HIST_BASE * HIST_GROWTH ** i
    return lo * math.sqrt(HIST_GROWTH)


class Histogram:
    """Fixed log-bucket histogram; single-writer, merged on read."""

    __slots__ = ("counts", "n", "total", "min", "max")

    def __init__(self) -> None:
        self.counts: Dict[int, int] = {}
        self.n = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        i = bucket_index(v)
        self.counts[i] = self.counts.get(i, 0) + 1
        self.n += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def merge(self, other: "Histogram") -> None:
        for i, c in other.counts.items():
            self.counts[i] = self.counts.get(i, 0) + c
        self.n += other.n
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def percentile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1], clamped to observed min/max."""
        if self.n == 0:
            return 0.0
        target = q * self.n
        seen = 0
        for i in sorted(self.counts):
            seen += self.counts[i]
            if seen >= target:
                return min(max(bucket_mid(i), self.min), self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def to_dict(self) -> dict:
        return {
            "n": self.n,
            "sum": self.total,
            "min": self.min if self.n else 0.0,
            "max": self.max if self.n else 0.0,
            "counts": {str(i): c for i, c in sorted(self.counts.items())},
            "base": HIST_BASE,
            "growth": HIST_GROWTH,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Histogram":
        h = cls()
        h.counts = {int(i): int(c) for i, c in d.get("counts", {}).items()}
        h.n = int(d.get("n", 0))
        h.total = float(d.get("sum", 0.0))
        if h.n:
            h.min = float(d.get("min", 0.0))
            h.max = float(d.get("max", 0.0))
        return h


class _Shard:
    """One thread's private metric storage. Never shared for writing."""

    __slots__ = ("counters", "gauges", "hists")

    def __init__(self) -> None:
        self.counters: Dict[str, float] = {}
        # name -> (seq, value); highest seq wins across shards.
        self.gauges: Dict[str, Tuple[int, float]] = {}
        self.hists: Dict[str, Histogram] = {}


class MetricsRegistry:
    """Thread-sharded metrics: lock-free writes, locked merge on read."""

    def __init__(self) -> None:
        self._tls = threading.local()
        self._lock = threading.Lock()
        self._shards: List[_Shard] = []

    def _shard(self) -> _Shard:
        sh = getattr(self._tls, "shard", None)
        if sh is None:
            sh = _Shard()
            with self._lock:
                self._shards.append(sh)
            self._tls.shard = sh
        return sh

    # -- hot-path writes ------------------------------------------------
    def counter(self, name: str, delta: float = 1.0) -> None:
        c = self._shard().counters
        c[name] = c.get(name, 0.0) + delta

    def gauge(self, name: str, value: float) -> None:
        self._shard().gauges[name] = (next(_gauge_seq), float(value))

    def observe(self, name: str, value: float) -> None:
        h = self._shard().hists
        hist = h.get(name)
        if hist is None:
            hist = h[name] = Histogram()
        hist.observe(value)

    # -- reads ----------------------------------------------------------
    def merged(self) -> Tuple[Dict[str, float], Dict[str, float],
                              Dict[str, Histogram]]:
        """Merge all shards: (counters, gauges, histograms)."""
        with self._lock:
            shards = list(self._shards)
        counters: Dict[str, float] = {}
        gauges: Dict[str, Tuple[int, float]] = {}
        hists: Dict[str, Histogram] = {}
        for sh in shards:
            for name, v in list(sh.counters.items()):
                counters[name] = counters.get(name, 0.0) + v
            for name, (seq, v) in list(sh.gauges.items()):
                prev = gauges.get(name)
                if prev is None or seq > prev[0]:
                    gauges[name] = (seq, v)
            for name, h in list(sh.hists.items()):
                tgt = hists.get(name)
                if tgt is None:
                    tgt = hists[name] = Histogram()
                tgt.merge(h)
        return counters, {k: v for k, (_, v) in gauges.items()}, hists

    def reset(self) -> None:
        """Drop all shards. Existing threads re-register on next write."""
        with self._lock:
            self._shards = []
        # Threads that still hold a stale shard in their TLS would write
        # into a detached dict; rebind lazily by clearing our own TLS and
        # marking via a generation check is overkill here — reset() is a
        # test/benchmark affordance, callers quiesce writers first.
        self._tls = threading.local()
