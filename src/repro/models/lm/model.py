"""Shared LM transformer family: dense + MoE, GQA, RoPE, scan-over-layers.

Covers the five assigned LM architectures via LMConfig:
  olmo-1b      non-parametric LayerNorm, SwiGLU, GQA kv=16
  llama3.2-3b  RMSNorm, SwiGLU, GQA kv=8
  gemma-2b     RMSNorm(+1), GeGLU, MQA (kv=1), head_dim 256, embed scaling
  grok-1-314b  MoE 8e top-2 (d_ff 32768), GQA kv=8
  kimi-k2-1t   MoE 384e top-8 (expert d_ff 2048), GQA kv=8

Execution paths:
  train    : causal-LM step (tokens -> loss), chunked attention for long
             sequences, remat + lax.scan over stacked layer params;
  prefill  : forward that also fills a KV cache, returns last logits;
  decode   : single-token step against a pre-filled KV cache (linear in
             cache length — this is why long_500k decode is tractable
             with full attention; see DESIGN.md).

Sharding is expressed through logical axis names only (see
repro.distributed.sharding); the same model lowers on 1 CPU device, the
16x16 pod and the 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.distributed.sharding import ShardingCtx, NULL_CTX
from repro.nn import core as nn


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x (B, S, H, D), positions (B, S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# parameter init (stacked per layer for lax.scan)
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: LMConfig, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    init = nn.variance_scaling(1.0, "fan_in", "normal")
    p = {
        "wq": init(ks[0], (cfg.d_model, cfg.n_heads * hd), dtype),
        "wk": init(ks[1], (cfg.d_model, cfg.n_kv_heads * hd), dtype),
        "wv": init(ks[2], (cfg.d_model, cfg.n_kv_heads * hd), dtype),
        "wo": init(ks[3], (cfg.n_heads * hd, cfg.d_model), dtype,
                   in_axes=(0,), out_axes=(1,)),
    }
    s = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.norm != "layernorm_np":     # olmo: non-parametric -> no params
        p["ln1"] = jnp.ones((cfg.d_model,), dtype) * (
            0.0 if cfg.norm == "rmsnorm_p1" else 1.0)
        p["ln2"] = jnp.ones((cfg.d_model,), dtype) * (
            0.0 if cfg.norm == "rmsnorm_p1" else 1.0)
        s["ln1"] = ("embed",)
        s["ln2"] = ("embed",)
    if cfg.n_experts:
        ff = cfg.moe_d_ff or cfg.d_ff
        E = cfg.n_experts
        p["router"] = init(ks[4], (cfg.d_model, E), dtype)
        s["router"] = ("embed", None)
        p["w_gate"] = init(ks[5], (E, cfg.d_model, ff), dtype,
                           in_axes=(1,), out_axes=(2,))
        p["w_up"] = init(ks[6], (E, cfg.d_model, ff), dtype,
                         in_axes=(1,), out_axes=(2,))
        p["w_down"] = init(ks[7], (E, ff, cfg.d_model), dtype,
                           in_axes=(1,), out_axes=(2,))
        s["w_gate"] = ("expert", "embed", "expert_mlp")
        s["w_up"] = ("expert", "embed", "expert_mlp")
        s["w_down"] = ("expert", "expert_mlp", "embed")
    else:
        p["w_gate"] = init(ks[4], (cfg.d_model, cfg.d_ff), dtype)
        p["w_up"] = init(ks[5], (cfg.d_model, cfg.d_ff), dtype)
        p["w_down"] = init(ks[6], (cfg.d_ff, cfg.d_model), dtype,
                           in_axes=(0,), out_axes=(1,))
        s["w_gate"] = ("embed", "mlp")
        s["w_up"] = ("embed", "mlp")
        s["w_down"] = ("mlp", "embed")
    return p, s


def init_params(key, cfg: LMConfig) -> Tuple[Any, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    if cfg.scan_layers:
        layer_params = jax.vmap(
            lambda k: _layer_init(k, cfg, dtype)[0])(layer_keys)
        layer_specs = jax.tree.map(lambda s: ("stack",) + s,
                                   _layer_init(key, cfg, dtype)[1],
                                   is_leaf=lambda x: isinstance(x, tuple))
    else:
        ps, ss = zip(*[_layer_init(k, cfg, dtype) for k in layer_keys])
        layer_params = list(ps)
        layer_specs = list(ss)
    emb = jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model),
                            dtype) * 0.02
    params = {"embed": emb, "layers": layer_params,
              "final_norm": jnp.ones((cfg.d_model,), dtype)}
    specs = {"embed": ("vocab", "embed"), "layers": layer_specs,
             "final_norm": ("embed",)}
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            k_out, (cfg.d_model, cfg.vocab_size), dtype) * 0.02
        specs["lm_head"] = ("embed", "vocab")
    return params, specs


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def _norm(cfg: LMConfig, x: jnp.ndarray, scale: Optional[jnp.ndarray]
          ) -> jnp.ndarray:
    if cfg.norm == "layernorm_np":
        return nn.layernorm_apply(None, x)
    if cfg.norm == "rmsnorm_p1":      # gemma (weights stored as delta)
        return nn.rmsnorm_apply({"scale": scale}, x, plus_one=True)
    return nn.rmsnorm_apply({"scale": scale}, x)


def _chunked_attention(q, k, v, *, causal: bool, q_offset: int,
                       kv_len: Optional[jnp.ndarray], block_q: int,
                       scale: float, ctx: ShardingCtx,
                       unroll: bool = False) -> jnp.ndarray:
    """Memory-bounded attention: lax.scan over q blocks; scores never
    exceed (B, H, block_q, T).  Equivalent to softmax attention.

    q (B, S, H, D); k/v (B, T, Hkv, D).  kv_len: optional (B,) valid kv
    length (decode); q_offset: absolute position of q[0].
    """
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    nb = max(1, (S + block_q - 1) // block_q)
    pad = nb * block_q - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(B, nb, block_q, H, D).transpose(1, 0, 2, 3, 4)

    kT = k.astype(jnp.float32)
    vT = v.astype(jnp.float32)

    def block(carry, inp):
        qi, idx = inp
        s = jnp.einsum("bqhd,bthd->bhqt", qi.astype(jnp.float32), kT,
                       preferred_element_type=jnp.float32) * scale
        s = ctx(s, "batch", "heads", None, "kv_seq")
        rows = (idx * block_q + q_offset
                + jnp.arange(block_q))[None, None, :, None]
        cols = jnp.arange(T)[None, None, None, :]
        mask = jnp.ones_like(s, bool)
        if causal:
            mask = mask & (cols <= rows)
        if kv_len is not None:
            mask = mask & (cols < kv_len[:, None, None, None])
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqt,bthd->bqhd", p, vT)
        return carry, o.astype(q.dtype)

    if nb == 1:
        _, out = block(None, (qb[0], jnp.int32(0)))
        out = out[:, :S]
    elif unroll:
        outs = [block(None, (qb[i], jnp.int32(i)))[1] for i in range(nb)]
        out = jnp.stack(outs, 1).reshape(B, nb * block_q, H, D)[:, :S]
    else:
        _, outs = jax.lax.scan(block, None,
                               (qb, jnp.arange(nb, dtype=jnp.int32)))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nb * block_q, H, D)
        out = out[:, :S]
    return out


def _router(p, cfg: LMConfig, xt: jnp.ndarray):
    """Shared router: returns (gate (T,k), eid (T,k), aux scalar)."""
    E, k = cfg.n_experts, cfg.n_experts_per_tok
    T = xt.shape[0]
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    # aux load-balancing loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros(E, jnp.float32).at[eid.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef
    return gate, eid, aux


def _pos_in_group(flat_e: jnp.ndarray) -> jnp.ndarray:
    """Rank of each slot within its expert group — sort-based
    (argsort + searchsorted), avoiding a (T*k, E) one-hot cumsum."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    grp_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(n, dtype=jnp.int32) - grp_start.astype(jnp.int32)
    return jnp.zeros(n, jnp.int32).at[order].set(rank)


def _moe_dense(p, cfg: LMConfig, x: jnp.ndarray, ctx: ShardingCtx
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Masked loop-over-experts MoE (no dropping, no dispatch).

    The right structure when E is small relative to the model axis
    (grok: 8 experts under 16-way TP): each expert is a dense TP matmul
    over all tokens with a gate mask — E/k x extra FLOPs but no
    scatter / all-to-all, and trivially shardable.
    """
    B, S, d = x.shape
    E = cfg.n_experts
    T = B * S
    xt = x.reshape(T, d)
    gate, eid, aux = _router(p, cfg, xt)
    w = jnp.zeros((T, E), x.dtype)
    w = w.at[jnp.arange(T)[:, None], eid].add(gate.astype(x.dtype))
    out = jnp.zeros_like(xt)
    for e in range(E):
        g = xt @ p["w_gate"][e].astype(x.dtype)
        u = xt @ p["w_up"][e].astype(x.dtype)
        h = (jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)) * u
        h = ctx(h, "batch", "expert_mlp")
        out = out + (h @ p["w_down"][e].astype(x.dtype)) * w[:, e:e + 1]
    return out.reshape(B, S, d), aux


def _moe_shard_map(p, cfg: LMConfig, x: jnp.ndarray, ctx: ShardingCtx
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Manual expert-parallel dispatch (production path for big-E MoE).

    Per device: take a 1/nm slice of this DP shard's tokens, route
    locally, pack a (nm, E_loc, cap, d) send buffer, all_to_all over the
    model axis (each peer owns E/nm experts), run the expert FFNs on the
    received tokens, all_to_all back, combine, all_gather the token
    slices.  Avoids the GSPMD global-scatter pathology entirely: every
    scatter/gather is device-local; cross-device traffic is exactly two
    all_to_alls + one all_gather (+ FSDP weight gathers).
    """
    mesh = ctx.mesh
    rules = ctx.rules or {}
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.n_experts_per_tok
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nm = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    E_loc = E // nm
    fsdp = rules.get("embed")
    fsdp_axes = (fsdp,) if isinstance(fsdp, str) else tuple(fsdp or ())

    P_ = jax.sharding.PartitionSpec
    wspec2 = P_("model", fsdp, None)                 # w_gate / w_up
    wspec3 = P_("model", None, fsdp)                 # w_down
    rspec = P_(fsdp, None)                           # router

    def body(xb, router_w, wg, wu, wd):
        T_dp = xb.shape[0] * xb.shape[1]
        xt = xb.reshape(T_dp, d)
        mi = jax.lax.axis_index("model")
        T_my = T_dp // nm
        x_my = jax.lax.dynamic_slice_in_dim(xt, mi * T_my, T_my, 0)
        # FSDP weight gathers (the traffic GSPMD would emit anyway)
        for ax in fsdp_axes:
            router_w = jax.lax.all_gather(router_w, ax, axis=0, tiled=True)
            wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, ax, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, ax, axis=2, tiled=True)

        gate, eid, aux = _router({"router": router_w}, cfg, x_my)
        flat_e = eid.reshape(-1)                      # (T_my*k,)
        owner = flat_e // E_loc
        e_loc = flat_e % E_loc
        pos = _pos_in_group(flat_e)
        cap = max(8, -(-int(k * T_my / E * cfg.capacity_factor) // 8) * 8)
        keep = pos < cap
        slot_x = jnp.repeat(x_my, k, axis=0)          # (T_my*k, d)
        send = jnp.zeros((nm, E_loc, cap, d), x.dtype)
        send = send.at[jnp.where(keep, owner, 0),
                       jnp.where(keep, e_loc, 0),
                       jnp.where(keep, pos, cap - 1)].add(
            slot_x * keep[:, None].astype(x.dtype))
        recv = jax.lax.all_to_all(send, "model", 0, 0, tiled=True)
        tok = recv.reshape(nm, E_loc, cap, d).transpose(1, 0, 2, 3)
        tok = tok.reshape(E_loc, nm * cap, d)         # my experts' tokens
        g = jnp.einsum("ecd,edf->ecf", tok, wg.astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", tok, wu.astype(x.dtype))
        h = (jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)) * u
        eout = jnp.einsum("ecf,efd->ecd", h, wd.astype(x.dtype))
        back = eout.reshape(E_loc, nm, cap, d).transpose(1, 0, 2, 3)
        ret = jax.lax.all_to_all(
            back.reshape(nm, E_loc, cap, d), "model", 0, 0, tiled=True)
        got = ret[jnp.where(keep, owner, 0),
                  jnp.where(keep, e_loc, 0),
                  jnp.where(keep, pos, cap - 1)]
        got = got * (keep[:, None] * gate.reshape(-1)[:, None]
                     ).astype(x.dtype)
        out_my = jnp.sum(got.reshape(T_my, k, d), axis=1)
        out = jax.lax.all_gather(out_my, "model", axis=0, tiled=True)
        aux = jax.lax.pmean(aux, "model")
        return out.reshape(xb.shape), aux

    xspec = P_(dp_axes if dp_axes else None, None, None)
    out, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=(xspec, rspec, wspec2, wspec2, wspec3),
        out_specs=(xspec, P_()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux


def _moe_block(p, cfg: LMConfig, x: jnp.ndarray, ctx: ShardingCtx
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-choice top-k MoE.  Implementation dispatch:

      * shard_map expert parallelism — big E divisible by the model
        axis with enough tokens to split (train / prefill);
      * dense masked loop — small E (grok: 8 experts, 16-way TP);
      * GSPMD scatter with capacity — small token counts (decode) and
        meshless unit tests, where the buffers are tiny.
    """
    B, S, d = x.shape
    E = cfg.n_experts
    T = B * S
    if ctx.mesh is not None and "model" in ctx.mesh.axis_names:
        sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
        nm = sizes.get("model", 1)
        dp = 1
        for a in ("pod", "data"):
            dp *= sizes.get(a, 1)
        expert_sharded = (ctx.rules or {}).get("expert") == "model"
        if (expert_sharded and E % nm == 0 and T % (dp * nm) == 0
                and T // dp >= nm):
            return _moe_shard_map(p, cfg, x, ctx)
        if E <= 16 and T // max(dp, 1) >= 1024:
            return _moe_dense(p, cfg, x, ctx)
    return _moe_scatter(p, cfg, x, ctx)


def _moe_scatter(p, cfg: LMConfig, x: jnp.ndarray, ctx: ShardingCtx
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-based scatter dispatch (decode / unit-test path).

    x (B, S, d) -> (out, aux_loss).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.n_experts_per_tok
    T = B * S
    xt = x.reshape(T, d)
    gate, eid, aux = _router(p, cfg, xt)
    flat_e = eid.reshape(-1)                             # (T*k,)
    pos = _pos_in_group(flat_e)
    cap = max(int(k * T / E * cfg.capacity_factor) + 1, 8)
    keep = pos < cap

    src = jnp.repeat(xt, k, axis=0)                      # (T*k, d)
    buf = jnp.zeros((E, cap, d), x.dtype)
    buf = buf.at[jnp.where(keep, flat_e, E - 1),
                 jnp.where(keep, pos, cap - 1)].add(
        src * keep[:, None].astype(x.dtype))
    buf = ctx(buf, "expert", None, None)

    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    h = ctx(act * u, "expert", None, "expert_mlp")
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    eout = ctx(eout, "expert", None, None)

    # combine: gather per (token, slot), weight by gate, sum slots
    got = eout[jnp.where(keep, flat_e, 0), jnp.where(keep, pos, 0)]
    got = got * (keep[:, None] * gate.reshape(-1)[:, None]).astype(x.dtype)
    out = jnp.sum(got.reshape(T, k, d), axis=1)
    return out.reshape(B, S, d), aux


def _dense_mlp(p, cfg: LMConfig, x: jnp.ndarray, ctx: ShardingCtx
               ) -> jnp.ndarray:
    g = x @ p["w_gate"].astype(x.dtype)
    u = x @ p["w_up"].astype(x.dtype)
    act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    h = ctx(act * u, "batch", None, "mlp")
    return h @ p["w_down"].astype(x.dtype)


def _attn_block(p, cfg: LMConfig, x, positions, kv_cache, cache_len,
                causal, block_q, ctx: ShardingCtx):
    """Returns (out, new_kv).  kv_cache: None (train/prefill from scratch)
    or dict(k=(B,T,Hkv,D), v=...) pre-allocated cache (decode)."""
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, Hkv, hd)
    q = ctx(q, "batch", None, "heads", None)
    k = ctx(k, "batch", None, "kv_heads", None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is not None:
        # decode: write new k/v at cache_len, attend over the full cache
        ck = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), cache_len, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), cache_len, axis=1)
        ck = ctx(ck, "batch", "kv_seq", "kv_heads", None)
        cv = ctx(cv, "batch", "kv_seq", "kv_heads", None)
        kv_len = jnp.full((B,), cache_len + S, jnp.int32)
        out = _chunked_attention(q, ck, cv, causal=False, q_offset=0,
                                 kv_len=kv_len, block_q=block_q,
                                 scale=hd ** -0.5, ctx=ctx,
                                 unroll=cfg.unroll_chunks)
        new_kv = {"k": ck, "v": cv}
    else:
        out = _chunked_attention(q, k, v, causal=causal, q_offset=0,
                                 kv_len=None, block_q=block_q,
                                 scale=hd ** -0.5, ctx=ctx,
                                 unroll=cfg.unroll_chunks)
        new_kv = {"k": k, "v": v}
    out = out.reshape(B, S, H * hd)
    out = ctx(out, "batch", None, "heads")
    return out @ p["wo"].astype(x.dtype), new_kv


def _layer(p, cfg: LMConfig, x, positions, kv_cache, cache_len, causal,
           block_q, ctx: ShardingCtx):
    # residual stream layout (sequence-parallel when rules map seq->model):
    # the per-layer saved activations shard over BOTH batch and seq.
    x = ctx(x, "batch", "seq", None)
    ln1 = p.get("ln1")
    ln2 = p.get("ln2")
    h = _norm(cfg, x, ln1)
    attn, new_kv = _attn_block(p, cfg, h, positions, kv_cache, cache_len,
                               causal, block_q, ctx)
    x = x + attn
    h = _norm(cfg, x, ln2)
    if cfg.n_experts:
        mlp, aux = _moe_block(p, cfg, h, ctx)
    else:
        mlp, aux = _dense_mlp(p, cfg, h, ctx), jnp.zeros((), jnp.float32)
    return x + mlp, new_kv, aux


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def forward(params, cfg: LMConfig, tokens: jnp.ndarray, *,
            positions: Optional[jnp.ndarray] = None,
            kv_caches: Optional[Dict[str, jnp.ndarray]] = None,
            cache_len: int = 0, causal: bool = True,
            block_q: int = 1024, ctx: ShardingCtx = NULL_CTX,
            return_cache: bool = False):
    """tokens (B, S) -> logits (B, S, V) [+ caches (L, B, T, Hkv, D)]."""
    compute = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute)
    if cfg.norm == "rmsnorm_p1":     # gemma scales embeddings by sqrt(d)
        x = x * (cfg.d_model ** 0.5)
    x = ctx(x, "batch", "seq", None)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.scan_layers:
        def body(carry, layer_p_and_cache):
            xx, aux = carry
            lp, kvc = layer_p_and_cache
            out, new_kv, a = _layer(lp, cfg, xx, positions, kvc, cache_len,
                                    causal, block_q, ctx)
            # don't stack caches through scan unless the caller needs them
            return (out, aux + a), (new_kv if return_cache else None)

        body_fn = body
        if cfg.remat:
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux_total), new_caches = jax.lax.scan(
            body_fn, (x, aux_total), (params["layers"], kv_caches))
    else:
        new_caches = []
        for i, lp in enumerate(params["layers"]):
            kvc = None if kv_caches is None else jax.tree.map(
                lambda c: c[i], kv_caches)
            x, nkv, a = _layer(lp, cfg, x, positions, kvc, cache_len,
                               causal, block_q, ctx)
            aux_total = aux_total + a
            new_caches.append(nkv)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)

    x = nn.rmsnorm_apply({"scale": params["final_norm"]}, x)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(compute)
    logits = x @ head
    logits = ctx(logits, "batch", "seq", "vocab")
    if return_cache:
        return logits, new_caches, aux_total
    return logits, aux_total


def lm_loss(params, cfg: LMConfig, tokens: jnp.ndarray, *,
            block_q: int = 1024, ctx: ShardingCtx = NULL_CTX) -> jnp.ndarray:
    logits, aux = forward(params, cfg, tokens, causal=True,
                          block_q=block_q, ctx=ctx)
    tgt = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold) + aux


def init_kv_cache(cfg: LMConfig, batch: int, max_len: int,
                  dtype=None) -> Dict[str, jnp.ndarray]:
    """Stacked (L, B, T, Hkv, D) caches (scan-compatible)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params, cfg: LMConfig, tokens: jnp.ndarray,
                kv_caches, cache_len, *, ctx: ShardingCtx = NULL_CTX):
    """One decode step: tokens (B, 1) + caches filled to cache_len.

    Cost is linear in cache length (one query row); attention runs
    chunked over the cache so the (B, H, 1, T) score tensor is the peak.
    """
    B = tokens.shape[0]
    positions = jnp.full((B, 1), cache_len, jnp.int32)
    logits, new_caches, _ = forward(
        params, cfg, tokens, positions=positions, kv_caches=kv_caches,
        cache_len=cache_len, causal=False, block_q=1,
        ctx=ctx, return_cache=True)
    return logits[:, -1], new_caches


def prefill(params, cfg: LMConfig, tokens: jnp.ndarray, *,
            block_q: int = 1024, ctx: ShardingCtx = NULL_CTX):
    """Prefill: returns (last-token logits, caches of shape (L,B,S,...))."""
    logits, caches, _ = forward(params, cfg, tokens, causal=True,
                                block_q=block_q, ctx=ctx, return_cache=True)
    return logits[:, -1], caches
