"""Neighbor sampling for minibatch GNN training (GraphSAGE-style).

``minibatch_lg`` (232,965 nodes / 114.6M edges, batch 1024, fanout
15-10) needs a *real* sampler: host-side CSR with per-seed uniform
neighbor sampling, emitting fixed-size padded arrays (JAX needs static
shapes; invalid slots are masked, never silently reused).

The padded subgraph layout for fanouts (f1, f2):
  nodes:  [seeds (B)] + [hop1 (B*f1)] + [hop2 (B*f1*f2)]   (local ids)
  edges:  hop1->seed (B*f1) + hop2->hop1 (B*f1*f2), masked where the
          CSR ran out of neighbors.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray
    indices: np.ndarray
    n_nodes: int

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int
                   ) -> "CSRGraph":
        order = np.argsort(src, kind="stable")
        s, d = src[order], dst[order]
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(indptr, s + 1, 1)
        np.cumsum(indptr, out=indptr)
        return CSRGraph(indptr, d.copy(), n_nodes)

    def sample_neighbors(self, nodes: np.ndarray, fanout: int,
                         rng: np.random.Generator
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """Uniform with-replacement sampling; returns ((n, fanout) ids,
        mask) — mask False where a node has no neighbors."""
        deg = self.indptr[nodes + 1] - self.indptr[nodes]
        has = deg > 0
        offs = (rng.random((len(nodes), fanout))
                * np.maximum(deg, 1)[:, None]).astype(np.int64)
        idx = self.indptr[nodes][:, None] + offs
        nbrs = self.indices[np.minimum(idx, len(self.indices) - 1)]
        mask = np.broadcast_to(has[:, None], nbrs.shape)
        return np.where(mask, nbrs, -1), mask.copy()


@dataclasses.dataclass
class SampledSubgraph:
    """Fixed-size padded 2-hop computation graph."""
    node_ids: np.ndarray       # (n_total,) global ids (-1 pad)
    feats_idx: np.ndarray      # == node_ids clipped for feature gather
    src: np.ndarray            # (n_edges,) local ids
    dst: np.ndarray            # (n_edges,)
    edge_mask: np.ndarray      # (n_edges,)
    seed_mask: np.ndarray      # (n_total,) True for seed slots
    n_seeds: int


#: stream tag for the sampler's default generator — keeps its draws
#: disjoint from every other `(seed, tag, ...)`-keyed stream in the repo
_SAMPLER_STREAM = 0x2B0             # "two-hop"


def sample_two_hop(g: CSRGraph, seeds: np.ndarray, fanout1: int,
                   fanout2: int, rng: Optional[np.random.Generator] = None,
                   *, seed: int = 0) -> SampledSubgraph:
    if rng is None:
        rng = np.random.default_rng((seed, _SAMPLER_STREAM))
    B = len(seeds)
    h1, m1 = g.sample_neighbors(seeds, fanout1, rng)          # (B, f1)
    h1f = h1.reshape(-1)
    h2, m2 = g.sample_neighbors(np.maximum(h1f, 0), fanout2, rng)
    m2 = m2 & (h1f >= 0)[:, None]                              # (B*f1, f2)

    n_seed, n_h1, n_h2 = B, B * fanout1, B * fanout1 * fanout2
    node_ids = np.concatenate([seeds, h1f, h2.reshape(-1)])
    # edges: hop1 -> seeds
    src1 = n_seed + np.arange(n_h1)
    dst1 = np.repeat(np.arange(B), fanout1)
    em1 = m1.reshape(-1)
    # edges: hop2 -> hop1
    src2 = n_seed + n_h1 + np.arange(n_h2)
    dst2 = n_seed + np.repeat(np.arange(n_h1), fanout2)
    em2 = m2.reshape(-1)
    return SampledSubgraph(
        node_ids=node_ids,
        feats_idx=np.maximum(node_ids, 0),
        src=np.concatenate([src1, src2]).astype(np.int64),
        dst=np.concatenate([dst1, dst2]).astype(np.int64),
        edge_mask=np.concatenate([em1, em2]),
        seed_mask=np.r_[np.ones(B, bool),
                        np.zeros(n_h1 + n_h2, bool)],
        n_seeds=B)


def make_random_graph(n_nodes: int, n_edges: int, seed: int = 0,
                      power_law: bool = True
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Synthetic edge list with (optionally) power-law degree skew —
    stand-in for ogbn-* at dry-run scale (topology only matters here)."""
    rng = np.random.default_rng(seed)
    if power_law:
        w = 1.0 / np.arange(1, n_nodes + 1) ** 0.8
        w /= w.sum()
        src = rng.choice(n_nodes, n_edges, p=w)
    else:
        src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    return src.astype(np.int64), dst.astype(np.int64)
