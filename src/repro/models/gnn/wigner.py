"""Real spherical-harmonic rotation matrices (Wigner D, real basis).

Ivanic & Ruedenberg (1996, + 1998 errata) recursion: given a 3x3
rotation matrix R, build the block-diagonal representation
D(R) = diag(D^0, D^1, ..., D^L) acting on real-SH vectors with
per-l component order m = -l..l (l=1 order corresponds to (y, z, x)).

Vectorized over a leading batch of rotations (the per-edge case in eSCN
message passing: one rotation per edge aligning the edge with +y).

All loops below run at *trace* time over (l, m, n) index triples — the
generated program is pure vectorized arithmetic over the edge batch.
"""
from __future__ import annotations

import functools
import math
from typing import List

import jax
import jax.numpy as jnp


def _p(i: int, l: int, mu: int, m_: int, r1, rlm1):
    """Helper P_i(l; mu, m') — batch-shaped (...)."""
    # r1: (..., 3, 3) with index offset 1; rlm1: (..., 2l-1, 2l-1) offset l-1
    if m_ == l:
        return (r1[..., i + 1, 2] * rlm1[..., mu + l - 1, 2 * l - 2]
                - r1[..., i + 1, 0] * rlm1[..., mu + l - 1, 0])
    if m_ == -l:
        return (r1[..., i + 1, 2] * rlm1[..., mu + l - 1, 0]
                + r1[..., i + 1, 0] * rlm1[..., mu + l - 1, 2 * l - 2])
    return r1[..., i + 1, 1] * rlm1[..., mu + l - 1, m_ + l - 1]


def _u_fn(l, m, n, r1, rlm1):
    return _p(0, l, m, n, r1, rlm1)


def _v_fn(l, m, n, r1, rlm1):
    if m == 0:
        return _p(1, l, 1, n, r1, rlm1) + _p(-1, l, -1, n, r1, rlm1)
    if m > 0:
        a = _p(1, l, m - 1, n, r1, rlm1)
        if m == 1:
            return a * math.sqrt(2.0)
        return a - _p(-1, l, -m + 1, n, r1, rlm1)
    # m < 0
    a = _p(-1, l, -m - 1, n, r1, rlm1)
    if m == -1:
        return a * math.sqrt(2.0)
    return _p(1, l, m + 1, n, r1, rlm1) + a


def _w_fn(l, m, n, r1, rlm1):
    if m == 0:
        return None
    if m > 0:
        return (_p(1, l, m + 1, n, r1, rlm1)
                + _p(-1, l, -m - 1, n, r1, rlm1))
    return (_p(1, l, m - 1, n, r1, rlm1)
            - _p(-1, l, -m + 1, n, r1, rlm1))


def _uvw_coeff(l: int, m: int, n: int):
    d = 1.0 if m == 0 else 0.0
    if abs(n) < l:
        denom = float((l + n) * (l - n))
    else:
        denom = float((2 * l) * (2 * l - 1))
    u = math.sqrt((l + m) * (l - m) / denom)
    v = 0.5 * math.sqrt((1 + d) * (l + abs(m) - 1) * (l + abs(m))
                        / denom) * (1 - 2 * d)
    w = -0.5 * math.sqrt((l - abs(m) - 1) * (l - abs(m)) / denom) * (1 - d)
    return u, v, w


def sh_rotation_blocks(R: jnp.ndarray, l_max: int) -> List[jnp.ndarray]:
    """R (..., 3, 3) -> list of per-l blocks [(...,1,1), (...,3,3), ...]."""
    batch = R.shape[:-2]
    blocks = [jnp.ones(batch + (1, 1), R.dtype)]
    if l_max == 0:
        return blocks
    # l=1: real-SH order (-1,0,1) = (y,z,x)
    perm = jnp.array([1, 2, 0])
    r1 = R[..., perm[:, None], perm[None, :]]
    blocks.append(r1)
    rlm1 = r1
    for l in range(2, l_max + 1):
        rows = []
        for m in range(-l, l + 1):
            row = []
            for n in range(-l, l + 1):
                u, v, w = _uvw_coeff(l, m, n)
                val = 0.0
                if u != 0.0:
                    val = val + u * _u_fn(l, m, n, r1, rlm1)
                if v != 0.0:
                    val = val + v * _v_fn(l, m, n, r1, rlm1)
                if w != 0.0:
                    wt = _w_fn(l, m, n, r1, rlm1)
                    if wt is not None:
                        val = val + w * wt
                if isinstance(val, float):
                    val = jnp.zeros(batch, R.dtype)
                row.append(val)
            rows.append(jnp.stack(row, axis=-1))
        blk = jnp.stack(rows, axis=-2)
        blocks.append(blk)
        rlm1 = blk
    return blocks


def rotation_to_z(r_hat: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Rotation matrix mapping unit vectors r_hat (..., 3) onto +z.

    +z is the real-SH polar axis in this basis: rotations about z act as
    2x2 rotations within each (+m, -m) pair, which is exactly the gauge
    freedom the SO(2) convolution must commute with (eSCN requirement).
    Rodrigues' formula about axis = r_hat x z; degenerate (anti)parallel
    cases handled explicitly.
    """
    x, y, z = r_hat[..., 0], r_hat[..., 1], r_hat[..., 2]
    c = z                               # cos(theta) = r . z
    axis = jnp.stack([y, -x, jnp.zeros_like(z)], axis=-1)  # r x z
    s = jnp.linalg.norm(axis, axis=-1)
    safe_s = jnp.maximum(s, eps)
    k = axis / safe_s[..., None]
    kx, ky, kz = k[..., 0], k[..., 1], k[..., 2]
    zero = jnp.zeros_like(kx)
    K = jnp.stack([
        jnp.stack([zero, -kz, ky], -1),
        jnp.stack([kz, zero, -kx], -1),
        jnp.stack([-ky, kx, zero], -1)], -2)
    I = jnp.broadcast_to(jnp.eye(3, dtype=r_hat.dtype), K.shape)
    R = I + s[..., None, None] * K + (1 - c)[..., None, None] * (K @ K)
    # degenerate: r ~ +z -> I ; r ~ -z -> rotate pi about x
    flip = jnp.broadcast_to(jnp.array(
        [[1., 0., 0.], [0., -1., 0.], [0., 0., -1.]], r_hat.dtype), K.shape)
    R = jnp.where((s < eps)[..., None, None],
                  jnp.where((c > 0)[..., None, None], I, flip), R)
    return R


def block_apply(blocks: List[jnp.ndarray], x: jnp.ndarray,
                transpose: bool = False) -> jnp.ndarray:
    """Apply block-diagonal D to x (..., S, C) with S = (l_max+1)^2."""
    outs = []
    off = 0
    for l, blk in enumerate(blocks):
        w = 2 * l + 1
        seg = x[..., off:off + w, :]
        if transpose:
            outs.append(jnp.einsum("...ji,...jc->...ic", blk, seg))
        else:
            outs.append(jnp.einsum("...ij,...jc->...ic", blk, seg))
        off += w
    return jnp.concatenate(outs, axis=-2)


@functools.lru_cache(maxsize=None)
def m_order_indices(l_max: int):
    """Component indices grouped by m: returns dict m -> list of flat
    indices (l, m) with l >= |m| (flat index = l^2 + l + m)."""
    out = {}
    for m in range(-l_max, l_max + 1):
        out[m] = [l * l + l + m for l in range(abs(m), l_max + 1)]
    return out
