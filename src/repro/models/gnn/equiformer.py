"""EquiformerV2-style equivariant graph attention via eSCN convolutions
[arXiv:2306.12059].

Node features are real-SH irreps: x (N, S, C) with S = (l_max+1)^2
(per-l blocks of 2l+1 components) and C channels.  Per edge:

  1. rotate source irreps into the edge-aligned frame (Wigner D from the
     rotation mapping the edge vector onto +z, the real-SH polar axis,
     so the residual gauge is a z-rotation that the SO(2) maps commute
     with) — per-l dense blocks;
  2. eSCN SO(2) convolution: per-|m| linear maps (the O(L^6) -> O(L^3)
     trick) with radial (RBF) channel modulation; m > m_max dropped;
  3. graph attention from the invariant (m=0) part of the message, with
     *bounded-logit* weights  w = exp(a_max * tanh(logit))  so the
     segment-softmax normalizer accumulates in the same single pass over
     edges as the messages (one edge sweep instead of two at 10^8-edge
     scale; see DESIGN.md);
  4. rotate back, scatter-add numerator/denominator into nodes
     (jax.ops.segment-style .at[].add — message passing IS the
     gather/scatter substrate on TPU).

Memory is bounded by lax.scan over fixed-size edge chunks: the
(chunk, S, C) message tensor is the peak, never (E, S, C).

Node update: per-l linear projection + equivariant gated FFN (scalars
gate the l>0 channels), pre-RMS-norm per l.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.distributed.sharding import ShardingCtx, NULL_CTX
from repro.models.gnn import wigner
from repro.nn import core as nn


def _n_sph(l_max: int) -> int:
    return (l_max + 1) ** 2


def rbf(dist: jnp.ndarray, n: int, r_max: float = 6.0) -> jnp.ndarray:
    """Gaussian radial basis (Ec,) -> (Ec, n)."""
    mu = jnp.linspace(0.0, r_max, n)
    beta = (n / r_max) ** 2
    return jnp.exp(-beta * (dist[..., None] - mu) ** 2)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: GNNConfig, dtype):
    C, L, M = cfg.d_hidden, cfg.l_max, cfg.m_max
    ks = jax.random.split(key, 16)
    init = nn.variance_scaling(1.0, "fan_in", "normal")
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    # SO(2) per-m weights
    n0 = L + 1
    p["so2_m0"] = init(ks[0], (n0 * C, n0 * C), dtype)
    s["so2_m0"] = (None, "channels")
    for m in range(1, M + 1):
        nm = L + 1 - m
        p[f"so2_m{m}_r"] = init(ks[2 * m], (nm * C, nm * C), dtype)
        p[f"so2_m{m}_i"] = init(ks[2 * m + 1], (nm * C, nm * C), dtype)
        s[f"so2_m{m}_r"] = (None, "channels")
        s[f"so2_m{m}_i"] = (None, "channels")
    # radial modulation: rbf -> per-(l,channel) scale for m<=M comps
    n_mod = sum(L + 1 - m for m in range(0, M + 1))
    p["radial"], s["radial"] = nn.mlp_init(
        ks[7], [cfg.n_radial, 2 * C, n_mod * C], dtype=dtype,
        final_name="channels")
    # attention
    p["w_att"] = init(ks[8], (C, cfg.n_heads), dtype)
    s["w_att"] = ("channels", None)
    p["w_inv"] = init(ks[9], ((L + 1) * C, C), dtype)
    s["w_inv"] = (None, "channels")
    # output per-l projection
    p["w_out"] = init(ks[10], (L + 1, C, C), dtype,
                      in_axes=(1,), out_axes=(2,))
    s["w_out"] = (None, None, "channels")
    # FFN with equivariant gating
    p["ffn1"], s["ffn1"] = nn.linear_init(ks[11], C, 2 * C,
                                          in_name="channels",
                                          out_name="mlp", dtype=dtype)
    p["ffn2"], s["ffn2"] = nn.linear_init(ks[12], 2 * C, C, in_name="mlp",
                                          out_name="channels", dtype=dtype)
    p["w_gate"] = init(ks[13], (C, L * C), dtype)
    s["w_gate"] = ("channels", None)
    p["norm1"] = jnp.ones((L + 1, C), dtype)
    p["norm2"] = jnp.ones((L + 1, C), dtype)
    s["norm1"] = (None, "channels")
    s["norm2"] = (None, "channels")
    return p, s


def init_params(key, cfg: GNNConfig, d_feat: int, n_out: int = 1):
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    emb, emb_s = nn.linear_init(k_emb, d_feat, cfg.d_hidden,
                                in_name="embed", out_name="channels",
                                dtype=dtype)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg, dtype)[0])(layer_keys)
    _, lspec = _layer_init(key, cfg, dtype)
    lspec = jax.tree.map(lambda t: ("stack",) + t, lspec,
                         is_leaf=lambda x: isinstance(x, tuple))
    head, head_s = nn.linear_init(k_out, cfg.d_hidden, n_out,
                                  in_name="channels", out_name=None,
                                  dtype=dtype)
    params = {"embed": emb, "layers": stacked, "head": head}
    specs = {"embed": emb_s, "layers": lspec, "head": head_s}
    return params, specs


# ---------------------------------------------------------------------------
# layer
# ---------------------------------------------------------------------------

def _per_l_norm(x: jnp.ndarray, scale: jnp.ndarray, l_max: int,
                eps: float = 1e-6) -> jnp.ndarray:
    """RMS over each l-block's components+channels, learned (l, C) scale."""
    outs = []
    off = 0
    for l in range(l_max + 1):
        w = 2 * l + 1
        seg = x[..., off:off + w, :].astype(jnp.float32)
        rms = jnp.sqrt(jnp.mean(seg * seg, axis=(-2, -1), keepdims=True)
                       + eps)
        outs.append((seg / rms) * scale[l].astype(jnp.float32))
        off += w
    return jnp.concatenate(outs, axis=-2).astype(x.dtype)


def _so2_conv(p, xr: jnp.ndarray, radial_scale: jnp.ndarray,
              cfg: GNNConfig) -> jnp.ndarray:
    """SO(2) convolution in the edge-aligned frame.

    xr (Ec, S, C); radial_scale (Ec, n_mod, C) channel modulation for the
    kept m components.  Components with |m| > m_max are dropped (eSCN).
    """
    Ec, S, C = xr.shape
    L, M = cfg.l_max, cfg.m_max
    idx = wigner.m_order_indices(L)
    out = jnp.zeros_like(xr)
    mod_off = 0
    # m = 0
    rows = jnp.asarray(idx[0])                          # (L+1,)
    x0 = xr[:, rows, :]                                 # (Ec, L+1, C)
    scale0 = radial_scale[:, mod_off:mod_off + L + 1, :]
    mod_off += L + 1
    y0 = ((x0 * scale0).reshape(Ec, -1)
          @ p["so2_m0"].astype(xr.dtype)).reshape(Ec, L + 1, C)
    out = out.at[:, rows, :].set(y0)
    # m > 0: SO(2)-equivariant 2x2 mixing of (+m, -m) with shared radial
    for m in range(1, M + 1):
        nm = L + 1 - m
        rp = jnp.asarray(idx[m])
        rm = jnp.asarray(idx[-m])
        sc = radial_scale[:, mod_off:mod_off + nm, :]
        mod_off += nm
        xp = (xr[:, rp, :] * sc).reshape(Ec, -1)
        xm = (xr[:, rm, :] * sc).reshape(Ec, -1)
        wr = p[f"so2_m{m}_r"].astype(xr.dtype)
        wi = p[f"so2_m{m}_i"].astype(xr.dtype)
        yp = (xp @ wr - xm @ wi).reshape(Ec, nm, C)
        ym = (xp @ wi + xm @ wr).reshape(Ec, nm, C)
        out = out.at[:, rp, :].set(yp)
        out = out.at[:, rm, :].set(ym)
    return out


def _layer_apply(p, cfg: GNNConfig, x: jnp.ndarray, src: jnp.ndarray,
                 dst: jnp.ndarray, vec: jnp.ndarray, dist: jnp.ndarray,
                 edge_mask: jnp.ndarray, ctx: ShardingCtx) -> jnp.ndarray:
    """One equivariant attention block.  Edges pre-split into chunks by
    the caller; this processes the full (chunked) edge set via scan."""
    N, S, C = x.shape
    L, H = cfg.l_max, cfg.n_heads
    Ch = C // H
    xn = _per_l_norm(x, p["norm1"], L)

    n_chunks = src.shape[0]

    def edge_chunk(carry, inp):
        num, den = carry
        s_idx, d_idx, v, dd, msk = inp
        Ec = s_idx.shape[0]
        xs = jnp.take(xn, s_idx, axis=0)                 # (Ec, S, C) gather
        R = wigner.rotation_to_z(v)
        blocks = wigner.sh_rotation_blocks(R, L)
        xr = wigner.block_apply(blocks, xs)              # -> edge frame
        rs = nn.mlp_apply(p["radial"], rbf(dd, cfg.n_radial).astype(x.dtype),
                          act=jax.nn.silu)
        n_mod = rs.shape[-1] // C
        rs = rs.reshape(Ec, n_mod, C)
        y = _so2_conv(p, xr, rs, cfg)
        # attention logits from the invariant (m=0) components
        rows0 = jnp.asarray(wigner.m_order_indices(L)[0])
        inv = y[:, rows0, :].reshape(Ec, -1) @ p["w_inv"].astype(x.dtype)
        logits = jax.nn.leaky_relu(inv) @ p["w_att"].astype(x.dtype)
        w = jnp.exp(4.0 * jnp.tanh(logits / 4.0))        # bounded-logit
        w = w * msk[:, None].astype(w.dtype)             # (Ec, H)
        msg = wigner.block_apply(blocks, y, transpose=True)  # back-rotate
        msg = msg.reshape(Ec, S, H, Ch) * w[:, None, :, None]
        d_safe = jnp.where(msk, d_idx, N - 1)
        num = num.at[d_safe].add(
            msg.reshape(Ec, S, C) * msk[:, None, None].astype(msg.dtype))
        den = den.at[d_safe].add(w)
        return (num, den), None

    num0 = jnp.zeros((N, S, C), x.dtype)
    den0 = jnp.zeros((N, H), jnp.float32)
    if n_chunks == 1:
        (num, den), _ = edge_chunk((num0, den0),
                                   (src[0], dst[0], vec[0], dist[0],
                                    edge_mask[0]))
    elif cfg.unroll:
        carry = (num0, den0)
        for i in range(n_chunks):
            carry, _ = edge_chunk(carry, (src[i], dst[i], vec[i], dist[i],
                                          edge_mask[i]))
        num, den = carry
    else:
        (num, den), _ = jax.lax.scan(edge_chunk, (num0, den0),
                                     (src, dst, vec, dist, edge_mask))
    den = jnp.maximum(den, 1e-6)
    agg = (num.reshape(N, S, H, Ch)
           / den[:, None, :, None].astype(num.dtype)).reshape(N, S, C)
    agg = ctx(agg, "nodes", None, "channels")
    # per-l output projection
    outs = []
    off = 0
    for l in range(L + 1):
        wl = 2 * l + 1
        outs.append(jnp.einsum("nwc,cd->nwd", agg[:, off:off + wl, :],
                               p["w_out"][l].astype(x.dtype)))
        off += wl
    x = x + jnp.concatenate(outs, axis=-2)
    # gated FFN
    h = _per_l_norm(x, p["norm2"], L)
    h0 = h[:, 0, :]
    f = jax.nn.silu(nn.linear_apply(p["ffn1"], h0))
    f = ctx(f, "nodes", "mlp")
    f = nn.linear_apply(p["ffn2"], f)
    gate = jax.nn.sigmoid(h0 @ p["w_gate"].astype(x.dtype)
                          ).reshape(N, L, C)
    upd = jnp.zeros_like(x)
    upd = upd.at[:, 0, :].set(f)
    off = 1
    for l in range(1, L + 1):
        wl = 2 * l + 1
        upd = upd.at[:, off:off + wl, :].set(
            h[:, off:off + wl, :] * gate[:, None, l - 1, :])
        off += wl
    return x + upd


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def _chunk_edges(src, dst, vec, dist, mask, chunk: int):
    E = src.shape[0]
    n = max(1, -(-E // chunk))
    pad = n * chunk - E
    def pz(a, fill=0):
        return jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1),
                       constant_values=fill)
    src = pz(src).reshape(n, chunk)
    dst = pz(dst).reshape(n, chunk)
    vec = pz(vec).reshape(n, chunk, 3)
    dist = pz(dist).reshape(n, chunk)
    mask = pz(mask).reshape(n, chunk) if mask is not None else \
        jnp.pad(jnp.ones(E, bool), (0, pad)).reshape(n, chunk)
    return src, dst, vec, dist, mask


def forward(params, cfg: GNNConfig, feats: jnp.ndarray, src: jnp.ndarray,
            dst: jnp.ndarray, pos: jnp.ndarray, *,
            edge_mask: Optional[jnp.ndarray] = None,
            ctx: ShardingCtx = NULL_CTX) -> jnp.ndarray:
    """feats (N, d_feat); edges src/dst (E,); pos (N, 3) node coords.

    Returns node outputs (N, n_out).
    """
    compute = jnp.dtype(cfg.dtype)
    N = feats.shape[0]
    x0 = nn.linear_apply(params["embed"], feats.astype(compute))
    x = jnp.zeros((N, _n_sph(cfg.l_max), cfg.d_hidden), compute)
    x = x.at[:, 0, :].set(x0)
    x = ctx(x, "nodes", None, "channels")

    rel = jnp.take(pos, dst, axis=0) - jnp.take(pos, src, axis=0)
    dist = jnp.linalg.norm(rel.astype(jnp.float32), axis=-1)
    vec = rel.astype(jnp.float32) / jnp.maximum(dist, 1e-9)[:, None]
    # zero-length edges (self-loops / padded coincident nodes) carry no
    # direction -> no equivariant message; mask them out.
    nz = dist > 1e-6
    edge_mask = nz if edge_mask is None else (edge_mask & nz)
    cs, cd, cv, cdist, cmask = _chunk_edges(src, dst, vec, dist, edge_mask,
                                            cfg.edge_chunk)

    def body(xx, lp):
        out = _layer_apply(lp, cfg, xx, cs, cd, cv, cdist, cmask, ctx)
        return out, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.unroll:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, _ = body_fn(x, lp)
    else:
        x, _ = jax.lax.scan(body_fn, x, params["layers"])
    return nn.linear_apply(params["head"], x[:, 0, :])


def node_mse_loss(params, cfg: GNNConfig, feats, src, dst, pos, targets,
                  *, node_mask=None, edge_mask=None,
                  ctx: ShardingCtx = NULL_CTX) -> jnp.ndarray:
    out = forward(params, cfg, feats, src, dst, pos, edge_mask=edge_mask,
                  ctx=ctx)
    err = (out[:, 0].astype(jnp.float32)
           - targets.astype(jnp.float32)) ** 2
    if node_mask is not None:
        m = node_mask.astype(jnp.float32)
        return jnp.sum(err * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(err)


def equivariance_check(params, cfg: GNNConfig, feats, src, dst, pos, R
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scalar outputs must be invariant to a global rotation R."""
    a = forward(params, cfg, feats, src, dst, pos)
    b = forward(params, cfg, feats, src, dst, pos @ R.T)
    return a, b
