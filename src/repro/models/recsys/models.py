"""RecSys architecture family: dlrm-rm2, wide-deep, sasrec, bst.

Shared substrate: huge sparse embedding tables (row-sharded over the
model axis) accessed through the EmbeddingBag op (jnp.take +
segment-sum semantics; Pallas kernel on TPU) — JAX has no native
EmbeddingBag, so this *is* part of the system (see kernels/embedding_bag).

Steps per the assigned shape table:
  train_batch    train_step: CTR binary cross-entropy (dlrm / wide_deep /
                 bst) or sampled-softmax next-item (sasrec);
  serve_p99 /    serve_step: forward scoring of a request batch;
  serve_bulk
  retrieval_cand retrieval_step: one query representation against 10^6
                 candidate item embeddings — a sharded batched dot +
                 distributed top-k, never a loop.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.distributed.sharding import ShardingCtx, NULL_CTX
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.nn import core as nn


# ---------------------------------------------------------------------------
# shared sparse-embedding substrate
# ---------------------------------------------------------------------------

def _tables_init(key, n_fields: int, vocab: int, dim: int, dtype):
    tbl = jax.random.normal(key, (n_fields, vocab, dim), dtype) * 0.01
    return tbl, (None, "table_rows", "table_dim")


def _lookup_local(tables, ids, ctx):
    """Per-field gather (single-device / replicated-table path)."""
    V = tables.shape[1]
    flat = (jnp.arange(tables.shape[0])[None, :] * V + ids % V)   # (B, F)
    out = jnp.take(tables.reshape(-1, tables.shape[-1]), flat, axis=0)
    return ctx(out, "batch", None, "table_dim")


def _lookup_sharded(tables, ids, ctx):
    """Distributed embedding lookup over row-sharded tables (shard_map).

    The GSPMD gather from a row-sharded table replicates the whole table
    (tens of GB for production vocabs) — the dominant collective in the
    recsys baseline roofline.  Instead: each model-axis peer gathers the
    rows it *owns* (ids outside its range contribute zeros) and a psum
    over the model axis assembles the embeddings — communication drops
    from O(F*V*D) table bytes to O(B*F*D) activation bytes per step.
    """
    mesh = ctx.mesh
    F, V, D = tables.shape
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    nm = sizes.get("model", 1)
    v_loc = V // nm
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in dp_axes:
        dp *= sizes[a]
    P_ = jax.sharding.PartitionSpec

    n = ids.shape[0]
    pad = (-n) % max(dp, 1)
    if pad:  # e.g. a single request's short id list vs 16 DP shards
        ids = jnp.pad(ids, ((0, pad), (0, 0)))

    def body(tab, ids_loc):
        mi = jax.lax.axis_index("model")
        rel = ids_loc % V - mi * v_loc                   # (B_loc, F)
        ok = (rel >= 0) & (rel < v_loc)
        safe = jnp.clip(rel, 0, v_loc - 1)
        flat = jnp.arange(F)[None, :] * v_loc + safe
        rows = jnp.take(tab.reshape(F * v_loc, D), flat, axis=0)
        rows = rows * ok[..., None].astype(rows.dtype)
        return jax.lax.psum(rows, "model")

    out = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P_(None, "model", None), P_(dp_axes or None, None)),
        out_specs=P_(dp_axes or None, None, None),
        check_vma=False)(tables, ids)
    if pad:
        out = out[:n]
    return ctx(out, "batch", None, "table_dim")


def _lookup_simple(tables, ids, ctx):
    """Embedding lookup with implementation dispatch: shard_map
    distributed lookup when a mesh with a model axis is present and the
    vocab divides it; local gather otherwise (tests / single device)."""
    if (ctx.mesh is not None and "model" in ctx.mesh.axis_names
            and os.environ.get("REPRO_BASELINE") != "1"):
        nm = dict(zip(ctx.mesh.axis_names,
                      ctx.mesh.devices.shape)).get("model", 1)
        if tables.shape[1] % nm == 0 and nm > 1 and \
                (ctx.rules or {}).get("table_rows") == "model":
            return _lookup_sharded(tables, ids, ctx)
    return _lookup_local(tables, ids, ctx)


def take_rows(table, ids, ctx):
    """(V, D) table row gather with distributed dispatch; ids any shape.
    Callers sanitize negative ids (padding) before/after."""
    shape = ids.shape
    out = _lookup_simple(table[None], ids.reshape(-1, 1), ctx)
    return out.reshape(*shape, table.shape[-1])


def _bag_lookup(tables, ids, ctx):
    """Multi-hot bags: tables (F, V, D), ids (B, F, L) -> (B, F, D) via
    the EmbeddingBag op (segment-sum semantics, kernel on TPU)."""
    B, F, L = ids.shape
    V, D = tables.shape[1], tables.shape[2]
    flat_tab = tables.reshape(F * V, D)
    offs = (jnp.arange(F) * V)[None, :, None]
    # one bag per (b, f): reshape to (B*F, L)
    bag_ids = jnp.where(ids >= 0, ids % V + offs, -1).reshape(B * F, L)
    out = embedding_bag(flat_tab, bag_ids, None, "sum", False)
    return ctx(out.reshape(B, F, D), "batch", None, "table_dim")


# ---------------------------------------------------------------------------
# DLRM  [arXiv:1906.00091]
# ---------------------------------------------------------------------------

def dlrm_init(key, cfg: RecsysConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    tbl, tspec = _tables_init(ks[0], cfg.n_sparse, cfg.default_vocab,
                              cfg.embed_dim, dtype)
    bot, bspec = nn.mlp_init(ks[1], [cfg.n_dense, *cfg.bot_mlp], dtype=dtype)
    n_vec = cfg.n_sparse + 1
    d_inter = n_vec * (n_vec - 1) // 2 + cfg.bot_mlp[-1]
    top, tpspec = nn.mlp_init(ks[2], [d_inter, *cfg.top_mlp], dtype=dtype,
                              final_name=None)
    return ({"tables": tbl, "bot": bot, "top": top},
            {"tables": tspec, "bot": bspec, "top": tpspec})


def dlrm_forward(params, cfg: RecsysConfig, dense: jnp.ndarray,
                 sparse_ids: jnp.ndarray, ctx: ShardingCtx = NULL_CTX
                 ) -> jnp.ndarray:
    compute = jnp.dtype(cfg.dtype)
    if sparse_ids.ndim == 3:          # multi-hot bags
        emb = _bag_lookup(params["tables"].astype(compute), sparse_ids, ctx)
    else:
        emb = _lookup_simple(params["tables"].astype(compute), sparse_ids,
                             ctx)
    bot = nn.mlp_apply(params["bot"], dense.astype(compute),
                       act=jax.nn.relu, final_act=jax.nn.relu)   # (B, D)
    vecs = jnp.concatenate([bot[:, None, :], emb], axis=1)       # (B, F+1, D)
    # dot interaction: upper triangle of (F+1)x(F+1) gram matrix
    gram = jnp.einsum("bfd,bgd->bfg", vecs, vecs)
    n = vecs.shape[1]
    iu, ju = jnp.triu_indices(n, k=1)
    inter = gram[:, iu, ju]                                      # (B, nC2)
    x = jnp.concatenate([bot, inter], axis=1)
    logit = nn.mlp_apply(params["top"], x, act=jax.nn.relu)
    return logit[:, 0]


# ---------------------------------------------------------------------------
# Wide & Deep  [arXiv:1606.07792]
# ---------------------------------------------------------------------------

def wide_deep_init(key, cfg: RecsysConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    tbl, tspec = _tables_init(ks[0], cfg.n_sparse, cfg.default_vocab,
                              cfg.embed_dim, dtype)
    wide, wspec = _tables_init(ks[1], cfg.n_sparse, cfg.default_vocab, 1,
                               dtype)
    deep, dspec = nn.mlp_init(
        ks[2], [cfg.n_sparse * cfg.embed_dim, *cfg.bot_mlp, 1], dtype=dtype,
        final_name=None)
    return ({"tables": tbl, "wide": wide, "deep": deep},
            {"tables": tspec, "wide": wspec, "deep": dspec})


def wide_deep_forward(params, cfg: RecsysConfig, dense, sparse_ids,
                      ctx: ShardingCtx = NULL_CTX) -> jnp.ndarray:
    compute = jnp.dtype(cfg.dtype)
    emb = _lookup_simple(params["tables"].astype(compute), sparse_ids, ctx)
    deep_in = emb.reshape(emb.shape[0], -1)                # concat interaction
    deep = nn.mlp_apply(params["deep"], deep_in, act=jax.nn.relu)[:, 0]
    # wide: sum of per-field scalar weights (embedding-bag with dim 1)
    wide_e = _lookup_simple(params["wide"].astype(compute), sparse_ids, ctx)
    wide = jnp.sum(wide_e[..., 0], axis=1)
    return deep + wide


# ---------------------------------------------------------------------------
# small transformer encoder shared by sasrec / bst
# ---------------------------------------------------------------------------

def _tx_block_init(key, d: int, n_heads: int, d_ff: int, dtype):
    ks = jax.random.split(key, 6)
    init = nn.variance_scaling(1.0, "fan_in", "normal")
    hd = max(d // n_heads, 1)
    p = {"wq": init(ks[0], (d, n_heads * hd), dtype),
         "wk": init(ks[1], (d, n_heads * hd), dtype),
         "wv": init(ks[2], (d, n_heads * hd), dtype),
         "wo": init(ks[3], (n_heads * hd, d), dtype),
         "ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype)}
    s = {"wq": ("embed", "heads"), "wk": ("embed", "heads"),
         "wv": ("embed", "heads"), "wo": ("heads", "embed"),
         "ln1": ("embed",), "ln2": ("embed",)}
    p["ff1"], s["ff1"] = nn.linear_init(ks[4], d, d_ff, out_name="mlp",
                                        dtype=dtype)
    p["ff2"], s["ff2"] = nn.linear_init(ks[5], d_ff, d, in_name="mlp",
                                        out_name="embed", dtype=dtype)
    return p, s


def _tx_block_apply(p, x, n_heads: int, causal: bool, ctx: ShardingCtx):
    B, S, d = x.shape
    hd = max(d // n_heads, 1)
    h = nn.rmsnorm_apply({"scale": p["ln1"]}, x)
    q = (h @ p["wq"].astype(x.dtype)).reshape(B, S, n_heads, hd)
    k = (h @ p["wk"].astype(x.dtype)).reshape(B, S, n_heads, hd)
    v = (h @ p["wv"].astype(x.dtype)).reshape(B, S, n_heads, hd)
    s = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    att = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhst,bthd->bshd", att, v).reshape(B, S, n_heads * hd)
    x = x + o @ p["wo"].astype(x.dtype)
    h = nn.rmsnorm_apply({"scale": p["ln2"]}, x)
    h = jax.nn.relu(nn.linear_apply(p["ff1"], h))
    h = ctx(h, "batch", None, "mlp")
    return x + nn.linear_apply(p["ff2"], h)


# ---------------------------------------------------------------------------
# SASRec  [arXiv:1808.09781]
# ---------------------------------------------------------------------------

def sasrec_init(key, cfg: RecsysConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2 + cfg.n_blocks)
    d = cfg.embed_dim
    items = jax.random.normal(ks[0], (cfg.default_vocab, d), dtype) * 0.01
    pos = jax.random.normal(ks[1], (cfg.seq_len, d), dtype) * 0.01
    blocks, bspecs = [], []
    for i in range(cfg.n_blocks):
        p, s = _tx_block_init(ks[2 + i], d, cfg.n_heads, 4 * d, dtype)
        blocks.append(p)
        bspecs.append(s)
    return ({"items": items, "pos": pos, "blocks": blocks},
            {"items": ("table_rows", "table_dim"), "pos": (None, None),
             "blocks": bspecs})


def sasrec_user_repr(params, cfg: RecsysConfig, seq_ids: jnp.ndarray,
                     ctx: ShardingCtx = NULL_CTX) -> jnp.ndarray:
    """seq_ids (B, S) item history (-1 pad) -> (B, D) user representation
    (hidden state at the last position)."""
    compute = jnp.dtype(cfg.dtype)
    V = params["items"].shape[0]
    x = take_rows(params["items"].astype(compute),
                  jnp.where(seq_ids >= 0, seq_ids, 0) % V, ctx)
    x = x * (seq_ids >= 0).astype(compute)[..., None]
    x = x + params["pos"].astype(compute)[None, : x.shape[1]]
    x = ctx(x, "batch", "seq", None)
    for p in params["blocks"]:
        x = _tx_block_apply(p, x, cfg.n_heads, causal=True, ctx=ctx)
    return x[:, -1]


def sasrec_scores(params, cfg: RecsysConfig, user_repr: jnp.ndarray,
                  cand_ids: jnp.ndarray, ctx: ShardingCtx = NULL_CTX
                  ) -> jnp.ndarray:
    """(B, D) x (N,) candidate ids -> (B, N) dot scores (retrieval)."""
    compute = user_repr.dtype
    V = params["items"].shape[0]
    cand = take_rows(params["items"].astype(compute), cand_ids % V, ctx)
    cand = ctx(cand, "candidates", None)
    return user_repr @ cand.T


# ---------------------------------------------------------------------------
# BST  [arXiv:1905.06874]
# ---------------------------------------------------------------------------

def bst_init(key, cfg: RecsysConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4 + cfg.n_blocks)
    d = cfg.embed_dim
    items = jax.random.normal(ks[0], (cfg.default_vocab, d), dtype) * 0.01
    pos = jax.random.normal(ks[1], (cfg.seq_len + 1, d), dtype) * 0.01
    other, ospec = _tables_init(ks[2], cfg.n_sparse, cfg.default_vocab, d,
                                dtype)
    blocks, bspecs = [], []
    for i in range(cfg.n_blocks):
        p, s = _tx_block_init(ks[3 + i], d, cfg.n_heads, 4 * d, dtype)
        blocks.append(p)
        bspecs.append(s)
    d_in = (cfg.seq_len + 1) * d + cfg.n_sparse * d
    mlp, mspec = nn.mlp_init(ks[-1], [d_in, *cfg.top_mlp], dtype=dtype,
                             final_name=None)
    return ({"items": items, "pos": pos, "other": other, "blocks": blocks,
             "mlp": mlp},
            {"items": ("table_rows", "table_dim"), "pos": (None, None),
             "other": ospec, "blocks": bspecs, "mlp": mspec})


def bst_forward(params, cfg: RecsysConfig, seq_ids: jnp.ndarray,
                target_id: jnp.ndarray, other_ids: jnp.ndarray,
                ctx: ShardingCtx = NULL_CTX) -> jnp.ndarray:
    """Behavior sequence (B, S) + target item (B,) + profile fields
    (B, F) -> CTR logit (B,)."""
    compute = jnp.dtype(cfg.dtype)
    V = params["items"].shape[0]
    B, S = seq_ids.shape
    seq = jnp.concatenate([seq_ids, target_id[:, None]], axis=1)
    x = take_rows(params["items"].astype(compute),
                  jnp.where(seq >= 0, seq, 0) % V, ctx)
    x = x * (seq >= 0).astype(compute)[..., None]
    x = x + params["pos"].astype(compute)[None, : S + 1]
    x = ctx(x, "batch", "seq", None)
    for p in params["blocks"]:
        x = _tx_block_apply(p, x, cfg.n_heads, causal=False, ctx=ctx)
    other = _lookup_simple(params["other"].astype(compute), other_ids, ctx)
    feats = jnp.concatenate([x.reshape(B, -1), other.reshape(B, -1)], axis=1)
    logit = nn.mlp_apply(params["mlp"], feats, act=jax.nn.relu)
    return logit[:, 0]


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------

def bce_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    l32 = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(l32, 0) - l32 * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(l32))))


def sasrec_loss(params, cfg: RecsysConfig, seq_ids, pos_ids, neg_ids,
                ctx: ShardingCtx = NULL_CTX) -> jnp.ndarray:
    """BPR-style: positive next item vs sampled negatives."""
    u = sasrec_user_repr(params, cfg, seq_ids, ctx)
    compute = u.dtype
    V = params["items"].shape[0]
    pos = take_rows(params["items"].astype(compute), pos_ids % V, ctx)
    neg = take_rows(params["items"].astype(compute), neg_ids % V, ctx)
    s_pos = jnp.sum(u * pos, axis=-1, keepdims=True)        # (B, 1)
    s_neg = jnp.einsum("bd,bnd->bn", u, neg)                # (B, N)
    logits = jnp.concatenate([s_pos, s_neg], axis=1).astype(jnp.float32)
    return -jnp.mean(jax.nn.log_softmax(logits, axis=-1)[:, 0])
