"""Lifecycle runtime (paper §4 end-to-end): versioned index publication
with atomic hot-swap into serving.

The three stages of the paper — construction, training, serving — meet
here for the first time:

  * ``snapshot``  immutable, versioned ``IndexSnapshot`` artifacts and a
                  checkpointer-compatible on-disk store;
  * ``publish``   materialize a snapshot from a ``TrainState``
                  (full-corpus RQ encode, inverted lists, I2I KNN) and
                  gate it on retrieval recall vs exact KNN;
  * ``swap``      double-buffered ``SnapshotHandle`` + ``SwapServer``:
                  atomic version flips under live traffic, queue
                  re-keying via a retained event ring;
  * ``runtime``   the hour-level orchestrator chaining incremental
                  graph refresh -> training burst -> publish -> swap.
"""
from repro.lifecycle.snapshot import (IndexSnapshot, SnapshotCorruptError,
                                      SnapshotStore)
from repro.lifecycle.publish import build_snapshot, evaluate_snapshot
from repro.lifecycle.swap import SnapshotHandle, SwapServer
from repro.lifecycle.runtime import (LifecycleConfig, LifecycleRuntime,
                                     StageFailed)

__all__ = [
    "IndexSnapshot", "SnapshotCorruptError", "SnapshotStore",
    "build_snapshot", "evaluate_snapshot", "SnapshotHandle", "SwapServer",
    "LifecycleConfig", "LifecycleRuntime", "StageFailed",
]
