"""Step-driven lifecycle orchestrator (the paper's co-design loop).

One ``run_cycle`` = one simulated hour of the production lifecycle:

    1. **refresh**   splice the trailing engagement window into the
                     graph + PPR tables (``edge_dataset
                     .incremental_refresh``; both id spaces may grow);
    2. **train**     a burst of ``steps_per_cycle`` co-training steps on
                     the refreshed edge dataset (``core.trainer``);
    3. **publish**   regenerate all embeddings, encode them through the
                     co-learned RQ codebooks and materialize a versioned
                     ``IndexSnapshot`` (``lifecycle.publish``), gated on
                     cluster-index recall vs exact KNN;
    4. **swap**      atomically flip the serving tier to the new
                     version (``lifecycle.swap``) — or keep the old one
                     when the gate fails.

Cadence knobs live on ``LifecycleConfig``; the runtime owns the mutable
stage state (graph, tables, dataset, train state, serving engine) and
reports one dict per cycle.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RankGraph2Config
from repro.core import model as M
from repro.core import trainer as T
from repro.core.graph_builder import EngagementLog, HeteroGraph
from repro.data.edge_dataset import (EdgeDataset, NeighborTables,
                                     incremental_refresh)
from repro.faults import InjectedCrash, get_faults
from repro.lifecycle.publish import (build_snapshot, encode_corpus,
                                     evaluate_snapshot, snapshot_health)
from repro.lifecycle.snapshot import (IndexSnapshot, SnapshotCorruptError,
                                      SnapshotStore)
from repro.lifecycle.swap import SwapServer
from repro.obs import get_telemetry


class StageFailed(RuntimeError):
    """A lifecycle stage exhausted its retry budget.  ``run_cycle``
    absorbs this into degraded serving when a live server exists;
    without one (bring-up) it propagates to the caller."""

    def __init__(self, stage: str, attempts: int, cause: BaseException):
        super().__init__(f"stage {stage!r} failed after {attempts} "
                         f"attempt(s): {cause}")
        self.stage = stage
        self.attempts = attempts
        self.cause = cause


@dataclasses.dataclass(frozen=True)
class LifecycleConfig:
    """Cadence + serving knobs for the lifecycle runtime.

    ``steps_per_cycle``   training-burst length per hour-level cycle —
                          the compute budget that trades index freshness
                          against step throughput;
    ``publish_every``     cycles between publications (1 = publish every
                          cycle; the graph still refreshes each cycle);
    ``min_recall_ratio``  swap gate: a snapshot must retain at least
                          this fraction of exact-KNN Recall@``recall_k``
                          or the engine keeps serving the old version
                          (0 disables the gate);
    ``min_item_recall_ratio``
                          §5.2.2 gate breadth: the published I2I table
                          must retain this fraction of exact item-
                          ranking recall at its own width (0 disables);
    ``min_codebook_util`` publication-side collapse floor: every RQ
                          layer's published-code utilization must stay
                          above this fraction or the snapshot is
                          rejected (0 disables);
    ``min_hitrate_recon`` §5.2.3 reconstruction-health floor: the RQ
                          reconstruction's hitrate@10 must stay above
                          this value (0 disables) — catches the
                          1.0 -> 0.0 flapping a collapse causes;
    ``repair_attempts``   self-healing: when a gate trips, run up to
                          this many bounded repair bursts (dead-code
                          reset from published occupancy + short
                          re-train + re-publish) instead of only
                          refusing to publish (0 = refuse-only);
    ``repair_steps``      training-burst length of one repair attempt;
    ``i2i_k``             offline I2I KNN width published per item;
    ``queue_len`` / ``recency_s`` / ``ring_capacity``
                          serving-store geometry: cluster ring-buffer
                          depth, recency horizon, and how many raw
                          events are retained for swap-time re-keying;
    ``n_shards``          serving scale-out: partition the cluster space
                          into this many contiguous ranges, each backed
                          by its own device-resident store behind the
                          swap server's router (1 = unsharded);
    ``serving_delta_cap`` per-shard delta-buffer depth (0 = direct
                          scatter per ingest; >0 = LSM-style append +
                          fold, the mode whose ingest cost shrinks as
                          1/n_shards);
    ``use_kernel``        route the publication encode through the
                          Pallas ``rq_assign`` kernel (TPU) instead of
                          the jitted reference (CPU);
    ``snapshot_keep``     on-disk snapshot retention (when a
                          ``SnapshotStore`` directory is attached);
    ``stage_retries``     fault tolerance: how many times a failed
                          refresh/train/publish/swap stage is retried
                          before the cycle degrades (0 = fail fast);
    ``retry_backoff_s``   base of the exponential retry backoff; the
                          jitter is a tuple-keyed RNG draw, so a seeded
                          run's sleep schedule is bit-reproducible
                          (0 disables sleeping between retries);
    ``stage_deadline_s``  per-stage deadline: an overrun is *detected*
                          (counter + degraded mark) but the result is
                          kept — re-running a completed refresh would
                          merge its delta twice (0 disables);
    ``rollback_on_regression``
                          post-swap health probe: after every flip a
                          small live retrieve must answer from the new
                          version; on regression the server is rolled
                          back to the previous good snapshot;
    ``post_swap_probe``   how many users the post-swap probe retrieves
                          (0 disables the probe).
    """
    steps_per_cycle: int = 50
    batch_per_type: int = 64
    publish_every: int = 1
    min_recall_ratio: float = 0.0
    min_item_recall_ratio: float = 0.0
    min_codebook_util: float = 0.0
    min_hitrate_recon: float = 0.0
    repair_attempts: int = 0
    repair_steps: int = 30
    recall_k: int = 100
    recall_queries: int = 400
    n_probe_factor: int = 4
    i2i_k: int = 16
    queue_len: int = 256
    recency_s: float = 3600.0
    ring_capacity: int = 1 << 16
    n_shards: int = 1
    serving_delta_cap: int = 0
    embed_batch: int = 2048
    encode_chunk: int = 8192
    use_kernel: bool = False
    snapshot_keep: int = 3
    stage_retries: int = 0
    retry_backoff_s: float = 0.0
    stage_deadline_s: float = 0.0
    rollback_on_regression: bool = True
    post_swap_probe: int = 8


class LifecycleRuntime:
    """Owns the mutable stage state and drives refresh -> train ->
    publish -> swap cycles.  ``world`` (a ``SyntheticWorld`` or anything
    with ``day1`` next-day ground truth) is only needed for the recall
    gate; pass ``None`` to publish ungated."""

    def __init__(self, cfg: RankGraph2Config, lcfg: LifecycleConfig,
                 g: HeteroGraph, tables: NeighborTables,
                 user_feat: np.ndarray, item_feat: np.ndarray, *,
                 world: Any = None, snapshot_dir: Optional[str] = None,
                 seed: int = 0, telemetry=None, faults=None,
                 sleep: Optional[Callable[[float], None]] = None):
        self.tel = telemetry if telemetry is not None else get_telemetry()
        self.faults = faults if faults is not None else get_faults()
        self._sleep = sleep if sleep is not None else time.sleep
        self.cfg = cfg
        self.lcfg = lcfg
        self.world = world
        self.seed = seed
        self.g = g
        self.tables = tables
        self.user_feat = np.asarray(user_feat, np.float32)
        self.item_feat = np.asarray(item_feat, np.float32)
        self.state, self.specs, self.optimizer = T.init_state(
            jax.random.key(seed), cfg)
        self._step_fn = None         # built by _rebuild_dataset below
        self._features_stale = True
        self.store = (SnapshotStore(snapshot_dir,
                                    keep=lcfg.snapshot_keep,
                                    faults=self.faults,
                                    telemetry=self.tel)
                      if snapshot_dir else None)
        self.server: Optional[SwapServer] = None
        self.cycle = 0
        self.version = 0
        self._last_user_emb: Optional[np.ndarray] = None
        self._last_item_emb: Optional[np.ndarray] = None
        # degradation bookkeeping: serving pinned on _last_good while
        # degraded; stale_cycles counts publish-eligible cycles served
        # from an old version
        self.degraded = False
        self.stale_cycles = 0
        self._last_good: Optional[IndexSnapshot] = None
        self._rebuild_dataset()

    # -- stage isolation ----------------------------------------------------

    def _backoff_s(self, stage: str, attempt: int) -> float:
        """Exponential backoff with *deterministic* jitter: the jitter
        factor is a tuple-keyed RNG draw (seed, stage, attempt), so a
        seeded run's retry schedule replays bit-identically."""
        base = self.lcfg.retry_backoff_s
        if base <= 0:
            return 0.0
        j = np.random.default_rng(
            (self.seed, zlib.crc32(stage.encode()), attempt)).random()
        return base * (2.0 ** attempt) * (1.0 + 0.5 * j)

    def _run_stage(self, stage: str, fn: Callable[[], Any]) -> Any:
        """Run one lifecycle stage under the fault-tolerance contract:
        up to ``stage_retries`` keyed-backoff retries on failure, then
        :class:`StageFailed`; a deadline overrun is counted and marks
        the runtime degraded but the completed result is KEPT (re-running
        a refresh that finished late would merge its delta twice).
        :class:`InjectedCrash` (simulated process death) is never
        retried or absorbed."""
        retries = max(self.lcfg.stage_retries, 0)
        deadline = self.lcfg.stage_deadline_s
        tel = self.tel
        for attempt in range(retries + 1):
            t0 = tel.clock.perf() if deadline > 0 else 0.0
            try:
                out = fn()
            except InjectedCrash:
                raise
            except Exception as e:
                tel.counter("lifecycle.stage_failures")
                with tel.span("lifecycle.stage_failure", stage=stage,
                              attempt=attempt, error=str(e)):
                    pass
                if attempt >= retries:
                    raise StageFailed(stage, attempt + 1, e) from e
                wait = self._backoff_s(stage, attempt)
                tel.counter("lifecycle.stage_retries")
                if wait > 0:
                    self._sleep(wait)
                continue
            if deadline > 0 and tel.clock.perf() - t0 > deadline:
                tel.counter("lifecycle.deadline_overruns")
                self._mark_degraded(f"{stage}_deadline")
            return out

    def _mark_degraded(self, reason: str) -> None:
        self.degraded = True
        self.tel.gauge("lifecycle.degraded", 1.0)
        self.tel.counter("lifecycle.degraded_events")
        with self.tel.span("lifecycle.degraded", reason=reason):
            pass

    def _mark_healthy(self) -> None:
        if self.degraded:
            self.tel.counter("lifecycle.recoveries")
        self.degraded = False
        self.stale_cycles = 0
        self.tel.gauge("lifecycle.degraded", 0.0)
        self.tel.gauge("lifecycle.stale_cycles", 0.0)

    def _count_stale_cycle(self) -> None:
        """A publish-eligible cycle ended still serving an old version."""
        if self.server is None:
            return
        self.stale_cycles += 1
        self.tel.counter("lifecycle.stale_cycles")
        self.tel.gauge("lifecycle.stale_cycles", float(self.stale_cycles))

    # -- stage plumbing -----------------------------------------------------

    def _rebuild_dataset(self) -> None:
        self.dataset = EdgeDataset(self.g, self.tables, self.user_feat,
                                   self.item_feat,
                                   k_train=self.cfg.k_train,
                                   batch_format="dedup_ids")
        # id-only batches gather features inside the jitted step from a
        # device-resident store; the donated step only needs rebuilding
        # when the feature tables themselves change (id-space growth or
        # in-place edits) — graph/table refreshes alone keep the
        # compiled step warm
        if self._step_fn is None or self._features_stale:
            self._step_fn = T.make_train_step(
                self.cfg, self.optimizer,
                features=T.make_feature_store(self.user_feat,
                                              self.item_feat))
            self._features_stale = False

    def refresh(self, delta_log: EngagementLog, *,
                user_feat: Optional[np.ndarray] = None,
                item_feat: Optional[np.ndarray] = None,
                backend: Optional[str] = None) -> Dict:
        """Stage 1: splice the trailing window in.  Grown id spaces must
        come with grown feature tables."""
        # models an upstream log-fetch failure: fires before any state
        # mutates, so a retried refresh replays the same delta cleanly
        self.faults.fire("stage.refresh", cycle=self.cycle)
        prev_emb = (np.concatenate([self._last_user_emb,
                                    self._last_item_emb], axis=0)
                    if self._last_user_emb is not None else None)
        if user_feat is not None:
            self.user_feat = np.asarray(user_feat, np.float32)
        if item_feat is not None:
            self.item_feat = np.asarray(item_feat, np.float32)
        if user_feat is not None or item_feat is not None:
            # explicit tables may be the same ndarray object mutated in
            # place — always refresh the device-resident FeatureStore
            self._features_stale = True
        # validate BEFORE mutating graph/tables: a failed refresh must
        # leave the runtime consistent (retrying after the error would
        # otherwise merge the same delta's aggregates twice)
        if self.user_feat.shape[0] < delta_log.n_users:
            raise ValueError("user space grew without new user features")
        if self.item_feat.shape[0] < delta_log.n_items:
            raise ValueError("item space grew without new item features")
        if prev_emb is not None and len(prev_emb) != (
                delta_log.n_users + delta_log.n_items):
            prev_emb = None            # id space grew past the last embed
        with self.tel.span("lifecycle.refresh",
                           delta_events=int(len(delta_log.user_id))):
            self.g, self.tables, report = incremental_refresh(
                self.g, self.tables, delta_log, prev_emb=prev_emb,
                backend=backend)
            self._rebuild_dataset()
        return report

    def train_burst(self, steps: Optional[int] = None) -> Dict[str, float]:
        """Stage 2: co-train model + RQ index on the current dataset.

        When ``cfg.rq.reset_every > 0`` the burst interleaves dead-code
        reset passes: every ``reset_every`` steps *and after the final
        step*, codes whose EMA usage fell below the floor are re-seeded
        from high-load clusters' residuals (``rq_index
        .dead_code_reset``).  Each pass embeds a fresh probe — the whole
        embedding cloud translates under contrastive training, so rows
        planted from a stale probe are born dead — and the closing pass
        means a publish right after the burst encodes with a codebook
        adapted to the *current* cloud, not one ``reset_every`` steps
        stale."""
        steps = steps if steps is not None else self.lcfg.steps_per_cycle
        per_type = {et: self.lcfg.batch_per_type
                    for et in ("uu", "ui", "ii")}
        m: Dict[str, Any] = {}
        base = int(self.state.step)
        every = self.cfg.rq.reset_every
        resets = 0
        tel = self.tel
        with tel.span("lifecycle.train", steps=int(steps)):
            for t in range(steps):
                t_step = tel.clock.perf() if tel.enabled else 0.0
                self.faults.fire("train.step", step=base + t)
                batch = jax.tree.map(
                    jnp.asarray, self.dataset.sample_batch(
                        base + t, self.seed, per_type))
                self.state, m = self._step_fn(
                    self.state, batch, jax.random.key(1000 + base + t))
                if every > 0 and ((t + 1) % every == 0 or t + 1 == steps):
                    self.state, rep = T.reset_dead_codes(
                        self.state, self._probe_embeddings(base + t + 1),
                        self.cfg, seed=self.seed, step=base + t + 1)
                    resets += sum(rep.values())
                if tel.enabled:
                    tel.observe("train.step_latency_s",
                                tel.clock.perf() - t_step)
            if tel.enabled:
                tel.counter("train.steps", float(steps))
                if resets:
                    tel.counter("train.dead_code_resets", float(resets))
        out = {k: float(v) for k, v in m.items()}
        if every > 0:
            out["dead_code_resets"] = float(resets)
        return out

    def _probe_embeddings(self, step: int) -> np.ndarray:
        """A keyed-uniform sample of *freshly embedded* nodes for the
        reset pass.  Freshness is load-bearing: the embedding cloud
        drifts coherently under contrastive training (it is rotation-
        invariant; nothing anchors absolute positions), so re-seeding
        from cached corpus embeddings plants rows where the data no
        longer is."""
        n_probe = self.cfg.rq.reset_probe
        nu, ni = self.g.n_users, self.g.n_items
        rng = np.random.default_rng((self.seed, 91, step))
        ids = np.sort(rng.choice(nu + ni, min(n_probe, nu + ni),
                                 replace=False))
        parts = []
        for node_type, sel in ((M.USER, ids[ids < nu]),
                               (M.ITEM, ids[ids >= nu])):
            if len(sel):
                parts.append(T.embed_all(
                    self.state.params, self.cfg, self.dataset,
                    node_type=node_type, ids=sel,
                    batch=min(self.lcfg.embed_batch, len(sel))))
        return np.concatenate(parts, axis=0)

    def embed_corpus(self) -> None:
        nu, ni = self.g.n_users, self.g.n_items
        self._last_user_emb = T.embed_all(
            self.state.params, self.cfg, self.dataset, node_type=M.USER,
            ids=np.arange(nu), batch=self.lcfg.embed_batch)
        self._last_item_emb = T.embed_all(
            self.state.params, self.cfg, self.dataset, node_type=M.ITEM,
            ids=np.arange(nu, nu + ni), batch=self.lcfg.embed_batch)

    def gate_passes(self, snap: IndexSnapshot) -> bool:
        """The swap/persist gate: every enabled floor must hold —
        user-side recall ratio, §5.2.2 item-side recall ratio, the
        published-code utilization (collapse) floor, and the §5.2.3
        reconstruction-hitrate floor."""
        m = snap.metrics
        for gate, key in ((self.lcfg.min_recall_ratio, "recall_ratio"),
                          (self.lcfg.min_item_recall_ratio,
                           "item_recall_ratio"),
                          (self.lcfg.min_codebook_util,
                           "codebook_util_min"),
                          (self.lcfg.min_hitrate_recon,
                           "hitrate10_recon")):
            val = m.get(key)
            if gate > 0 and val is not None and val < gate:
                return False
        return True

    def _failing_gates(self, snap: IndexSnapshot) -> list:
        """The gate keys currently below their floors (repair triggers).

        Mirrors ``gate_passes`` (kept self-contained: tests call it
        unbound against a bare-``lcfg`` namespace)."""
        m = snap.metrics
        failing = []
        for gate, key in ((self.lcfg.min_recall_ratio, "recall_ratio"),
                          (self.lcfg.min_item_recall_ratio,
                           "item_recall_ratio"),
                          (self.lcfg.min_codebook_util,
                           "codebook_util_min"),
                          (self.lcfg.min_hitrate_recon,
                           "hitrate10_recon")):
            val = m.get(key)
            if gate > 0 and val is not None and val < gate:
                failing.append(key)
        return failing

    def repair_burst(self, snap: IndexSnapshot) -> Dict[str, Any]:
        """Self-healing: one bounded repair pass after a tripped gate.

        Deadness is judged from the *published* corpus occupancy of
        ``snap`` (EMA counters can look healthy long after the published
        assignments collapsed — e.g. an injected all-equal codebook),
        dead codes are re-seeded from a keyed-uniform sample of the
        freshly published embeddings, and a short re-train burst
        (``lcfg.repair_steps``) settles the revived codes before the
        caller re-publishes."""
        from repro.core.rq_index import per_code_counts
        self.tel.counter("lifecycle.repair_bursts")
        all_codes = np.concatenate([snap.user_codes, snap.item_codes],
                                   axis=0)
        usage = per_code_counts(all_codes, snap.codebook_sizes)
        emb = np.concatenate([self._last_user_emb, self._last_item_emb],
                             axis=0)
        rng = np.random.default_rng((self.seed, 93, self.version))
        n = min(self.cfg.rq.reset_probe, len(emb))
        probe = emb[np.sort(rng.choice(len(emb), n, replace=False))]
        self.state, resets = T.reset_dead_codes(
            self.state, probe, self.cfg, seed=self.seed,
            step=self.version, usage=usage)
        train = self.train_burst(self.lcfg.repair_steps)
        return dict(resets=resets, train=train)

    def publish(self) -> IndexSnapshot:
        """Stage 3: materialize + gate + persist the next version.

        Gate-failed snapshots are *not* written to the store: the
        on-disk ``latest`` pointer (what a restarted server loads) must
        only ever name a snapshot that passed, and retention must never
        evict a known-good version in favor of rejected ones.
        """
        tel = self.tel
        with tel.span("lifecycle.publish",
                      version=int(self.version + 1)) as sp:
            self.embed_corpus()
            self.version += 1
            snap, recon = build_snapshot(
                self.version, self._last_user_emb, self._last_item_emb,
                self.state.params["rq"], self.cfg,
                i2i_k=self.lcfg.i2i_k, chunk=self.lcfg.encode_chunk,
                use_kernel=self.lcfg.use_kernel, want_user_recon=True)
            if self.world is not None:
                metrics = evaluate_snapshot(
                    snap, self._last_user_emb, recon, self.world,
                    recall_k=self.lcfg.recall_k,
                    n_queries=self.lcfg.recall_queries, seed=self.seed,
                    n_probe_factor=self.lcfg.n_probe_factor,
                    hitrate_pairs=self._hitrate_pairs(),
                    item_emb=self._last_item_emb)
            else:
                # ungated publication still carries first-class
                # index-health metrics (utilization + list balance need
                # no eval world)
                metrics = snapshot_health(snap)
            snap = dataclasses.replace(
                snap, gate_metrics=tuple(sorted(
                    (k, float(v)) for k, v in metrics.items())))
            self.faults.fire("gate.eval", version=int(self.version))
            passed = self.gate_passes(snap)
            if tel.enabled:
                for k, v in metrics.items():
                    if isinstance(v, (int, float)):
                        tel.gauge(f"publish.{k}", float(v))
                tel.counter("publish.snapshots")
                if not passed:
                    tel.counter("publish.gate_failures")
            sp.set("gate_passed", bool(passed))
            if self.store is not None and passed:
                self.store.publish(snap)
        return snap

    def _hitrate_pairs(self, n: int = 512) -> np.ndarray:
        """U-U positive pairs for the §5.2.3 index hitrate."""
        uu = self.g.uu
        if len(uu) == 0:
            return np.zeros((0, 2), np.int64)
        rng = np.random.default_rng(self.seed)
        idx = rng.integers(0, len(uu), min(n, len(uu)))
        return np.stack([uu.src[idx], uu.dst[idx]], axis=1)

    def swap(self, snap: IndexSnapshot, now: float) -> Dict[str, float]:
        """Stage 4: flip serving to ``snap`` (or bring serving up)."""
        if self.server is None:
            with self.tel.span("lifecycle.swap", bring_up=True,
                               to_version=int(snap.version)) as sp:
                self.server = SwapServer(
                    snap, queue_len=self.lcfg.queue_len,
                    recency_s=self.lcfg.recency_s,
                    ring_capacity=self.lcfg.ring_capacity,
                    n_shards=self.lcfg.n_shards,
                    delta_cap=self.lcfg.serving_delta_cap,
                    telemetry=self.tel, faults=self.faults)
            return dict(from_version=0.0,
                        to_version=float(snap.version),
                        build_ms=0.0, stall_ms=0.0, replayed_events=0.0,
                        dropped_stale=0.0, ring_dropped=0.0,
                        span_id=float(sp.span_id))
        return self.server.swap_to(snap, now)

    def _post_swap_health(self, snap: IndexSnapshot, now: float) -> bool:
        """Post-flip smoke probe: a small live retrieve must answer from
        the freshly flipped version.  Catches regressions that only
        manifest in the *serving* copy of the snapshot (store build,
        replay, id-space wiring) — the publication gate cannot see
        those.  Returns ``False`` on any probe failure."""
        n = min(self.lcfg.post_swap_probe, snap.n_users)
        if n <= 0 or self.server is None:
            return True
        try:
            self.faults.fire("health.post_swap",
                             version=int(snap.version))
            res, ver = self.server.retrieve_batch(
                np.arange(n), now, min(self.lcfg.recall_k, 8))
            ok = (ver == snap.version and res.shape[0] == n)
            # every serving partition must be wired and answering: a
            # mis-built shard (wrong range, dead sub-table) shows up
            # here even when the probed users all hash to healthy shards
            store = self.server.handle.acquire().store
            parts = store.partitions()
            ok = ok and len(parts) == max(self.lcfg.n_shards, 1)
            ok = ok and all(p.stats()["n_shards"] == 1 for p in parts)
        except InjectedCrash:
            raise
        except Exception as e:
            with self.tel.span("lifecycle.post_swap_probe_error",
                               error=str(e)):
                pass
            ok = False
        if not ok:
            self.tel.counter("lifecycle.post_swap_regressions")
        return ok

    def _rollback(self, now: float) -> Optional[Dict[str, float]]:
        """Roll serving back to the previous good snapshot after a
        post-swap health regression.  Returns the rollback swap report
        (``None`` when there is no previous good version to return to —
        serving stays on the regressed snapshot, degraded)."""
        prev = self._last_good
        if prev is None or self.server is None:
            return None
        with self.tel.span("lifecycle.rollback",
                           to_version=int(prev.version)):
            rep = self.server.swap_to(prev, now)
        self.tel.counter("lifecycle.rollbacks")
        return rep

    def recover_serving(self, now: float = 0.0) -> Optional[int]:
        """Crash recovery: bring serving up from the newest retained
        snapshot that verifies (corrupt versions are quarantined by the
        store walk).  Returns the recovered version, or ``None`` when
        the store is absent or holds no loadable snapshot."""
        if self.store is None:
            return None
        try:
            snap = self.store.load_latest_good()
        except (FileNotFoundError, SnapshotCorruptError):
            return None
        with self.tel.span("lifecycle.recover",
                           version=int(snap.version)):
            self.server = SwapServer(
                snap, queue_len=self.lcfg.queue_len,
                recency_s=self.lcfg.recency_s,
                ring_capacity=self.lcfg.ring_capacity,
                n_shards=self.lcfg.n_shards,
                delta_cap=self.lcfg.serving_delta_cap,
                telemetry=self.tel, faults=self.faults)
        self.version = max(self.version, snap.version)
        self._last_good = snap
        self.tel.counter("lifecycle.serving_recovered")
        return int(snap.version)

    # -- the loop -----------------------------------------------------------

    def run_cycle(self, delta_log: Optional[EngagementLog] = None, *,
                  now: float = 0.0,
                  user_feat: Optional[np.ndarray] = None,
                  item_feat: Optional[np.ndarray] = None,
                  backend: Optional[str] = None) -> Dict[str, Any]:
        """One full lifecycle cycle; returns a stage-by-stage report.

        Stage isolation (PR 9): each stage runs under ``_run_stage``
        (keyed-backoff retries + deadlines).  Once serving is live, a
        stage that exhausts its retries *degrades* the cycle — serving
        stays pinned on the last good snapshot, the failure lands in
        the report and the ``lifecycle.degraded`` gauge — instead of
        propagating.  Before serving exists (bring-up) there is nothing
        to degrade to, so :class:`StageFailed` raises to the caller.
        ``InjectedCrash`` always propagates (simulated process death).
        """
        tel = self.tel
        report: Dict[str, Any] = dict(cycle=self.cycle)
        with tel.span("lifecycle.cycle", cycle=int(self.cycle)):
            failed: Optional[StageFailed] = None
            if delta_log is not None:
                try:
                    r = self._run_stage("refresh", lambda: self.refresh(
                        delta_log, user_feat=user_feat,
                        item_feat=item_feat, backend=backend))
                    report["refresh"] = dict(
                        touched_users=len(r["touched_users"]),
                        touched_items=len(r["touched_items"]),
                        affected_nodes=len(r["affected_nodes"]),
                        refresh_seconds=r["refresh_seconds"])
                except StageFailed as e:
                    if self.server is None:
                        raise
                    failed = e
                    report["refresh"] = dict(failed=True, error=str(e))
            if failed is None:
                try:
                    report["train"] = self._run_stage(
                        "train", self.train_burst)
                except StageFailed as e:
                    if self.server is None:
                        raise
                    failed = e
                    report["train"] = dict(failed=True, error=str(e))
            if self.cycle % max(self.lcfg.publish_every, 1) == 0:
                if failed is not None:
                    # an upstream stage already failed: stay pinned on
                    # the last good snapshot, publish nothing
                    self._mark_degraded(failed.stage)
                    self._count_stale_cycle()
                    report["swap"] = dict(skipped=True, degraded=True,
                                          failed_stage=failed.stage)
                else:
                    report.update(self._publish_and_swap(now))
        self.cycle += 1
        report["degraded"] = self.degraded
        report["stale_cycles"] = self.stale_cycles
        return report

    def _publish_and_swap(self, now: float) -> Dict[str, Any]:
        """The publish-eligible tail of a cycle: publish (+ bounded
        self-healing repair), gate, swap, post-swap health probe with
        rollback.  Every failure path leaves serving pinned on the last
        good snapshot and says so in the returned report."""
        tel = self.tel
        out: Dict[str, Any] = {}
        try:
            snap = self._run_stage("publish", self.publish)
        except StageFailed as e:
            if self.server is None:
                raise
            self._mark_degraded("publish")
            self._count_stale_cycle()
            out["publish"] = dict(failed=True, error=str(e))
            out["swap"] = dict(skipped=True, degraded=True,
                               failed_stage="publish")
            return out
        # self-healing: a tripped gate triggers bounded repair bursts
        # (reset + short re-train + re-publish) so the cycle converges
        # to a publishable index instead of wedging.  The re-publish is
        # a direct call — its span parents under lifecycle.repair.
        attempts = 0
        repairs = []
        while (not self.gate_passes(snap)
               and attempts < self.lcfg.repair_attempts):
            attempts += 1
            trigger = ",".join(self._failing_gates(snap))
            with tel.span("lifecycle.repair",
                          attempt=attempts,
                          trigger=trigger) as rsp:
                rep = self.repair_burst(snap)
                snap = self.publish()
                healed = self.gate_passes(snap)
                n_reset = int(sum(rep["resets"].values()))
                rsp.set("resets", n_reset)
                rsp.set("healed", healed)
                if tel.enabled:
                    tel.counter("lifecycle.repair_resets",
                                float(n_reset))
                    if healed:
                        tel.counter("lifecycle.repair_healed")
            repairs.append(rep)
        if attempts:
            out["repair"] = dict(
                attempts=attempts,
                healed=self.gate_passes(snap),
                resets=[r["resets"] for r in repairs])
        out["publish"] = dict(version=snap.version, **snap.metrics)
        if not self.gate_passes(snap):
            # gate-blocked publish: the stale snapshot keeps serving
            self._count_stale_cycle()
            out["swap"] = dict(
                skipped=True,
                recall_ratio=snap.metrics.get("recall_ratio"),
                item_recall_ratio=snap.metrics.get(
                    "item_recall_ratio"),
                codebook_util_min=snap.metrics.get(
                    "codebook_util_min"),
                hitrate10_recon=snap.metrics.get(
                    "hitrate10_recon"))
            return out
        try:
            out["swap"] = self._run_stage(
                "swap", lambda: self.swap(snap, now))
        except StageFailed as e:
            if self.server is None:
                raise
            self._mark_degraded("swap")
            self._count_stale_cycle()
            out["swap"] = dict(skipped=True, degraded=True,
                               failed_stage="swap", error=str(e))
            return out
        if (self.lcfg.rollback_on_regression
                and not self._post_swap_health(snap, now)):
            rb = self._rollback(now)
            self._mark_degraded("post_swap_health")
            self._count_stale_cycle()
            out["swap"] = dict(out["swap"], rolled_back=True)
            if rb is not None:
                out["rollback"] = rb
            return out
        self._last_good = snap
        self._mark_healthy()
        return out
