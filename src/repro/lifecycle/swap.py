"""Atomic hot-swap of published index versions into live serving.

``SnapshotHandle`` is the double-buffer: two slots, each holding an
immutable ``ServingBundle`` (snapshot + its ``ClusterQueueStore`` + I2I
table), and one active-slot reference.  Every request path captures the
bundle reference exactly once at entry, so an in-flight
``retrieve_batch``/``serve_batch`` sees one version in full — never a
mix — and the flip itself is a single Python reference assignment
(atomic under the interpreter; the store/i2i/version triplet travels as
one object, so there is no window where a reader can pair version N's
queues with version N+1's I2I table).

Queue re-keying across versions: the store's ring buffers are keyed by
cluster id, and a user's cluster can change between snapshots, so queue
contents cannot be carried over by array copy.  Instead the engine
retains the recent raw event window in an ``EventRing`` and *replays*
it into the incoming snapshot's store before the flip — events land in
their users' *new* clusters by construction, and anything older than
the recency horizon (or past the ring capacity) is drained by
staleness, which the recency filter would have discarded anyway.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.serving import ClusterQueueStore
from repro.lifecycle.snapshot import IndexSnapshot


class EventRing:
    """Fixed-capacity ring of raw (user, item, ts) engagement events —
    the replay source for queue re-keying at swap time."""

    def __init__(self, capacity: int = 1 << 16):
        self.capacity = int(capacity)
        self.user = np.full(self.capacity, -1, np.int64)
        self.item = np.full(self.capacity, -1, np.int64)
        self.ts = np.full(self.capacity, -np.inf, np.float64)
        self.cursor = 0                   # total events ever pushed

    def push(self, user_ids: np.ndarray, item_ids: np.ndarray,
             timestamps: np.ndarray) -> None:
        u = np.asarray(user_ids, np.int64).ravel()
        if u.size == 0:
            return
        i = np.asarray(item_ids, np.int64).ravel()
        t = np.asarray(timestamps, np.float64).ravel()
        if u.size >= self.capacity:       # only the trailing window fits
            u, i, t = (a[-self.capacity:] for a in (u, i, t))
        slot = (self.cursor + np.arange(u.size)) % self.capacity
        self.user[slot] = u
        self.item[slot] = i
        self.ts[slot] = t
        self.cursor += u.size

    def window_since(self, start: int, min_ts: float
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
        """Events pushed at positions ``[start, cursor)`` (clamped to
        ring capacity) with ``ts >= min_ts``, oldest first.  Returns
        ``(users, items, ts, cursor_at_read)``."""
        end = self.cursor
        lo = max(start, end - self.capacity)
        if lo >= end:
            z = np.zeros(0, np.int64)
            return z, z.copy(), np.zeros(0, np.float64), end
        pos = np.arange(lo, end) % self.capacity
        u, i, t = self.user[pos], self.item[pos], self.ts[pos]
        keep = t >= min_ts
        return u[keep], i[keep], t[keep], end


@dataclasses.dataclass(frozen=True)
class ServingBundle:
    """Everything one snapshot version needs to serve — flipped as a
    single immutable unit."""
    version: int
    snapshot: IndexSnapshot
    store: ClusterQueueStore
    i2i: np.ndarray


class SnapshotHandle:
    """Double-buffered bundle holder with an atomic flip.

    Readers call ``acquire()`` once per request batch and use only the
    returned bundle; ``flip(bundle)`` installs a new version in the
    spare slot and swaps the active reference.  The previous bundle
    stays alive in the spare slot until the *next* flip, giving
    still-running readers a consistent view for their whole call.
    """

    def __init__(self, bundle: ServingBundle):
        self._slots = [bundle, None]
        self._active = bundle

    def acquire(self) -> ServingBundle:
        return self._active              # one atomic reference read

    def flip(self, bundle: ServingBundle) -> ServingBundle:
        """Install ``bundle`` and return the displaced one."""
        old = self._active
        spare = 1 if self._slots[0] is old else 0
        self._slots[spare] = bundle
        self._active = bundle            # THE atomic publication point
        return old

    @property
    def version(self) -> int:
        return self._active.version


class SwapServer:
    """The serving facade the lifecycle runtime drives: ingest + batched
    retrieval against whichever snapshot version is live, and
    ``swap_to`` for zero-downtime version changes.

    Every retrieval returns ``(results, version)`` so each response is
    attributable to exactly one published snapshot.
    """

    def __init__(self, snapshot: IndexSnapshot, *, queue_len: int = 256,
                 recency_s: float = 3600.0, ring_capacity: int = 1 << 16):
        self.queue_len = int(queue_len)
        self.recency_s = float(recency_s)
        self.ring = EventRing(ring_capacity)
        self.handle = SnapshotHandle(self._bundle(snapshot))
        self.swap_reports: list = []

    def _bundle(self, snapshot: IndexSnapshot) -> ServingBundle:
        store = ClusterQueueStore(snapshot.user_clusters,
                                  queue_len=self.queue_len,
                                  recency_s=self.recency_s,
                                  n_clusters=snapshot.n_clusters)
        return ServingBundle(version=snapshot.version, snapshot=snapshot,
                             store=store, i2i=snapshot.i2i)

    @property
    def version(self) -> int:
        return self.handle.version

    # -- request path -------------------------------------------------------

    def ingest(self, user_ids, item_ids, timestamps) -> None:
        self.ring.push(user_ids, item_ids, timestamps)
        self.handle.acquire().store.ingest(user_ids, item_ids, timestamps)

    def retrieve_batch(self, user_ids, now: float, k: int
                       ) -> Tuple[np.ndarray, int]:
        b = self.handle.acquire()
        return b.store.retrieve_batch(user_ids, now, k), b.version

    def serve_batch(self, user_ids, now: float, *, n_recent: int = 8,
                    k: int = 32, use_kernel: bool = False
                    ) -> Tuple[np.ndarray, np.ndarray, int]:
        b = self.handle.acquire()
        seeds, union = b.store.serve_batch(user_ids, now,
                                           n_recent=n_recent, k=k,
                                           i2i=b.i2i,
                                           use_kernel=use_kernel)
        return seeds, union, b.version

    # -- version flip -------------------------------------------------------

    def swap_to(self, snapshot: IndexSnapshot, now: float
                ) -> Dict[str, float]:
        """Hot-swap to ``snapshot``: build + warm its store off to the
        side (the old version keeps serving), replay the retained event
        window into the new clusters, catch up any events that raced in
        during the replay, then flip.

        The *stall* — the span in which a hypothetical concurrent
        request could observe the engine mid-transition — is only the
        catch-up + flip section; the bulk replay is off-path.
        """
        t0 = time.perf_counter()
        bundle = self._bundle(snapshot)
        cutoff = now - self.recency_s
        u, i, t, seen = self.ring.window_since(0, cutoff)
        bundle.store.ingest(u, i, t)                  # bulk re-key
        t_flip = time.perf_counter()
        u, i, t, seen = self.ring.window_since(seen, cutoff)
        if len(u):                                    # raced-in events
            bundle.store.ingest(u, i, t)
        old = self.handle.flip(bundle)
        t1 = time.perf_counter()
        report = dict(
            from_version=float(old.version),
            to_version=float(bundle.version),
            replayed_events=float(bundle.store.cursor.sum()),
            build_ms=(t_flip - t0) * 1e3,
            stall_ms=(t1 - t_flip) * 1e3)
        self.swap_reports.append(report)
        return report
