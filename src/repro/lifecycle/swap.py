"""Atomic hot-swap of published index versions into live serving.

``SnapshotHandle`` is the double-buffer: two slots, each holding an
immutable ``ServingBundle`` (snapshot + its ``ClusterQueueStore`` + I2I
table), and one active-slot reference.  Every request path captures the
bundle reference exactly once at entry, so an in-flight
``retrieve_batch``/``serve_batch`` sees one version in full — never a
mix — and the flip itself is a single Python reference assignment
(atomic under the interpreter; the store/i2i/version triplet travels as
one object, so there is no window where a reader can pair version N's
queues with version N+1's I2I table).

Queue re-keying across versions: the store's ring buffers are keyed by
cluster id, and a user's cluster can change between snapshots, so queue
contents cannot be carried over by array copy.  Instead the engine
retains the recent raw event window in an ``EventRing`` and *replays*
it into the incoming snapshot's store before the flip — events land in
their users' *new* clusters by construction, and anything older than
the recency horizon (or past the ring capacity) is drained by
staleness, which the recency filter would have discarded anyway.

Concurrency contract (the multithreaded serving tier):

* **Writers** go through ``SwapServer.ingest`` only.  The ring is the
  single serialization point — ``EventRing.push`` reserves a contiguous
  slot range with an atomic cursor fetch-add and writes it outside any
  lock; a committed watermark advances over finished reservations so
  readers of the ring never observe a half-written range.  Events then
  reach the live store by *draining the ring* into it (``_drain_into``)
  under a per-store watermark (``store.ring_seen``), which makes
  application exactly-once per bundle no matter how many writer threads
  race: whoever drains first applies the events, later drains skip
  them.
* **Readers** (``retrieve_batch``/``serve_batch``) acquire the bundle
  once and run lock-free against its store (MVCC snapshot on the store
  side: one atomic ``_state`` reference read per request batch).
* **The swap** closes the classic lost-event race — an ingest that
  lands between the catch-up read and the flip used to be written to
  the *old* bundle's store only.  Because every event is in the ring
  *before* any store sees it, draining the ring **again after the
  flip** (and on every subsequent ingest, via the watermark) guarantees
  the new bundle observes it exactly once.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.serving import ClusterQueueStore, ShardedQueueStore
from repro.faults import InjectedCrash, get_faults
from repro.lifecycle.snapshot import IndexSnapshot
from repro.obs import get_telemetry


class EventRing:
    """Fixed-capacity ring of raw (user, item, ts) engagement events —
    the replay source for queue re-keying at swap time.

    Multi-writer safe: ``push`` reserves ``[start, start+n)`` with an
    atomic cursor fetch-add (a two-op critical section under the ring
    lock), scatters the events into the reserved slots with no lock
    held, then commits.  ``committed`` is the contiguous prefix of
    reservations whose writes have finished — out-of-order completions
    park in a small heap until the gap before them closes — and bounds
    what ``window_since`` returns, so a half-written range is never
    visible.

    Wrap safety: once ``cursor`` exceeds ``capacity``, an in-flight
    write at reserved position ``q`` aliases the physical slot of the
    committed position ``q - capacity``.  All in-flight writes satisfy
    ``q >= committed`` (commit can't pass an unfinished reservation),
    so a reader is safe iff it never touches positions below
    ``cursor - capacity``: ``window_since`` clamps its lower bound by
    the *reserved* cursor, re-checks the cursor after copying (a
    reservation made mid-copy could reach back into the window), and
    retries — falling back to a copy under the ring lock, where no new
    reservation can start and the clamp makes pre-existing in-flight
    writes provably disjoint from the window.  Positions skipped by the
    clamp are events already being overwritten by newer pushes — the
    same overflow the capacity bound always implied.
    """

    _WINDOW_SPINS = 8

    def __init__(self, capacity: int = 1 << 16):
        self.capacity = int(capacity)
        self.user = np.full(self.capacity, -1, np.int64)
        self.item = np.full(self.capacity, -1, np.int64)
        self.ts = np.full(self.capacity, -np.inf, np.float64)
        self.cursor = 0                   # total slots ever reserved
        self.committed = 0                # contiguous fully-written prefix
        self._lock = threading.Lock()
        self._done: list = []             # (start, end) finished o-o-o

    def push(self, user_ids: np.ndarray, item_ids: np.ndarray,
             timestamps: np.ndarray) -> int:
        """Append a batch of events; returns how many were **dropped**
        (0 in steady state — only a single batch larger than the whole
        ring truncates to its trailing window, and callers must know).

        Reservation applies backpressure: a reservation is granted only
        while the total in-flight span (``cursor - committed + n``)
        fits the ring, so two concurrent reservations can never alias
        the same physical slots and stomp each other's unlocked
        scatters.  The wait is a yield-loop — committers need the same
        lock, so it cannot be held while waiting."""
        u = np.asarray(user_ids, np.int64).ravel()
        if u.size == 0:
            return 0
        i = np.asarray(item_ids, np.int64).ravel()
        t = np.asarray(timestamps, np.float64).ravel()
        dropped = 0
        if u.size > self.capacity:        # only the trailing window fits
            dropped = u.size - self.capacity
            u, i, t = (a[-self.capacity:] for a in (u, i, t))
        while True:                       # atomic fetch-add reservation
            with self._lock:
                if (self.cursor - self.committed + u.size
                        <= self.capacity):
                    start = self.cursor
                    self.cursor = start + u.size
                    break
            time.sleep(0)                 # let in-flight writers commit
        slot = (start + np.arange(u.size)) % self.capacity
        self.user[slot] = u               # slot writes: no lock held
        self.item[slot] = i
        self.ts[slot] = t
        with self._lock:                  # commit: close contiguous gaps
            heapq.heappush(self._done, (start, start + u.size))
            while self._done and self._done[0][0] <= self.committed:
                _, end = heapq.heappop(self._done)
                if end > self.committed:
                    self.committed = end
        return dropped

    def _copy_window(self, start: int):
        """One attempt at a consistent ``[lo, committed)`` copy; returns
        ``None`` when a reservation made during the copy may have
        scattered into the physical slots just read."""
        end = self.committed
        lo = max(start, self.cursor - self.capacity)   # wrap-safe bound
        if lo >= end:
            z = np.zeros(0, np.int64)
            return z, z.copy(), np.zeros(0, np.float64), end
        pos = np.arange(lo, end) % self.capacity
        u, i, t = self.user[pos], self.item[pos], self.ts[pos]
        if self.cursor > lo + self.capacity:           # mid-copy alias
            return None
        return u, i, t, end

    def window_since(self, start: int, min_ts: float
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
        """Events pushed at positions ``[start, committed)`` (clamped to
        the ring's wrap-safe trailing window) with ``ts >= min_ts``,
        oldest first.  Returns ``(users, items, ts, cursor_at_read)`` —
        feed ``cursor_at_read`` back as the next ``start`` for an
        incremental read that never delivers a position twice."""
        out = None
        for _ in range(self._WINDOW_SPINS):
            out = self._copy_window(start)
            if out is not None:
                break
        if out is None:
            with self._lock:       # freeze reservations; clamp does the rest
                out = self._copy_window(start)
        u, i, t, end = out
        keep = t >= min_ts
        return u[keep], i[keep], t[keep], end


@dataclasses.dataclass(frozen=True)
class ServingBundle:
    """Everything one snapshot version needs to serve — flipped as a
    single immutable unit.  ``store`` is a ``ClusterQueueStore`` or,
    when the server is sharded, a ``ShardedQueueStore`` (same API)."""
    version: int
    snapshot: IndexSnapshot
    store: "ClusterQueueStore | ShardedQueueStore"
    i2i: np.ndarray


class SnapshotHandle:
    """Double-buffered bundle holder with an atomic flip.

    Readers call ``acquire()`` once per request batch and use only the
    returned bundle; ``flip(bundle)`` installs a new version in the
    spare slot and swaps the active reference.  The previous bundle
    stays alive in the spare slot until the *next* flip, giving
    still-running readers a consistent view for their whole call.
    """

    def __init__(self, bundle: ServingBundle):
        self._slots = [bundle, None]
        self._active = bundle

    def acquire(self) -> ServingBundle:
        return self._active              # one atomic reference read

    def flip(self, bundle: ServingBundle) -> ServingBundle:
        """Install ``bundle`` and return the displaced one."""
        old = self._active
        spare = 1 if self._slots[0] is old else 0
        self._slots[spare] = bundle
        self._active = bundle            # THE atomic publication point
        return old

    @property
    def version(self) -> int:
        return self._active.version


class SwapServer:
    """The serving facade the lifecycle runtime drives: ingest + batched
    retrieval against whichever snapshot version is live, and
    ``swap_to`` for zero-downtime version changes.

    Every retrieval returns ``(results, version)`` so each response is
    attributable to exactly one published snapshot.
    """

    def __init__(self, snapshot: IndexSnapshot, *, queue_len: int = 256,
                 recency_s: float = 3600.0, ring_capacity: int = 1 << 16,
                 n_shards: int = 1, delta_cap: int = 0,
                 clock: Optional[Callable[[], float]] = None,
                 telemetry=None, faults=None):
        self.queue_len = int(queue_len)
        self.recency_s = float(recency_s)
        self.n_shards = max(int(n_shards), 1)
        self.delta_cap = int(delta_cap)
        self.tel = telemetry if telemetry is not None else get_telemetry()
        self.faults = faults if faults is not None else get_faults()
        # injectable so swap-report timings are replayable in tests —
        # the only clock-derived state this class retains
        self._clock = clock if clock is not None else self.tel.clock.perf
        self.ring = EventRing(ring_capacity)
        self.handle = SnapshotHandle(self._bundle(snapshot))
        self.swap_reports: list = []
        self._stats_lock = threading.Lock()
        self.ring_dropped = 0            # cumulative push-truncation drops
        # test seam: called between the pre-flip catch-up and the flip —
        # exactly the window of the historical lost-event race
        self._pre_flip_hook: Optional[Callable[[], None]] = None

    def _bundle(self, snapshot: IndexSnapshot) -> ServingBundle:
        if self.n_shards > 1:
            store = ShardedQueueStore(snapshot.user_clusters,
                                      n_shards=self.n_shards,
                                      queue_len=self.queue_len,
                                      recency_s=self.recency_s,
                                      n_clusters=snapshot.n_clusters,
                                      delta_cap=self.delta_cap,
                                      telemetry=self.tel)
        else:
            store = ClusterQueueStore(snapshot.user_clusters,
                                      queue_len=self.queue_len,
                                      recency_s=self.recency_s,
                                      n_clusters=snapshot.n_clusters,
                                      delta_cap=self.delta_cap,
                                      telemetry=self.tel)
        return ServingBundle(version=snapshot.version, snapshot=snapshot,
                             store=store, i2i=snapshot.i2i)

    @property
    def version(self) -> int:
        return self.handle.version

    # -- ring -> store application (exactly-once per bundle) ----------------

    def _drain_into(self, bundle: ServingBundle,
                    min_ts: float = -np.inf) -> Tuple[int, int]:
        """Apply every ring event the bundle has not seen yet to its
        store and advance the bundle's watermark.  Safe under writer
        races: the (read watermark -> ingest -> advance) section runs
        under the store's write lock, so each ring position is applied
        to this store exactly once.  Returns ``(applied, stale)``."""
        store = bundle.store
        with store.write_lock:
            u, i, t, end = self.ring.window_since(store.ring_seen, -np.inf)
            stale = 0
            if min_ts > -np.inf and len(t):
                keep = t >= min_ts
                stale = int((~keep).sum())
                u, i, t = u[keep], i[keep], t[keep]
            if len(u):
                store.ingest(u, i, t)
            store.ring_seen = end
        return len(u), stale

    # -- request path -------------------------------------------------------

    def ingest(self, user_ids, item_ids, timestamps) -> None:
        """Multi-writer ingest: the ring is written first (the source of
        truth), then drained into the live bundle.  Any concurrent swap
        that misses this batch in its catch-up pass will pick it up from
        the ring post-flip; any event another writer already drained is
        skipped by the watermark.

        Degradation contract: a failed ring push (the ``ring.push``
        fault site models reservation overload) **sheds the batch**
        instead of erroring the caller — serving stays up, the loss is
        surfaced through the ring-drop counters (``swap.ring_dropped``
        plus ``swap.ingest_shed_batches``), and the already-committed
        ring prefix stays intact for exactly-once replay."""
        n = np.asarray(user_ids).size
        try:
            self.faults.fire("ring.push", n=n)
            dropped = self.ring.push(user_ids, item_ids, timestamps)
        except InjectedCrash:
            raise                       # simulated process death
        except Exception:
            # overload shed: count the whole batch as dropped, keep serving
            with self._stats_lock:
                self.ring_dropped += n
            self.tel.counter("swap.ring_dropped", float(n))
            self.tel.counter("swap.ingest_shed_batches")
            return
        if dropped:
            with self._stats_lock:
                self.ring_dropped += dropped
            self.tel.counter("swap.ring_dropped", float(dropped))
        self._drain_into(self.handle.acquire())

    def retrieve_batch(self, user_ids, now: float, k: int
                       ) -> Tuple[np.ndarray, int]:
        b = self.handle.acquire()
        return b.store.retrieve_batch(user_ids, now, k), b.version

    def serve_batch(self, user_ids, now: float, *, n_recent: int = 8,
                    k: int = 32, use_kernel: bool = False
                    ) -> Tuple[np.ndarray, np.ndarray, int]:
        b = self.handle.acquire()
        seeds, union = b.store.serve_batch(user_ids, now,
                                           n_recent=n_recent, k=k,
                                           i2i=b.i2i,
                                           use_kernel=use_kernel)
        return seeds, union, b.version

    # -- version flip -------------------------------------------------------

    def swap_to(self, snapshot: IndexSnapshot, now: float
                ) -> Dict[str, float]:
        """Hot-swap to ``snapshot``: build + warm its store off to the
        side (the old version keeps serving), replay the retained event
        window into the new clusters, catch up events that raced in
        during the replay, flip, then drain the ring once more.

        The post-flip drain is what closes the lost-event race: a
        writer that acquired the old bundle between the catch-up read
        and the flip has already pushed its events to the ring (push
        happens-before acquire), so the new bundle's watermark drain
        observes them — and a writer that acquires the new bundle
        drains through the same watermark, so nothing is applied twice.

        The *stall* — the span in which a hypothetical concurrent
        request could observe the engine mid-transition — is only the
        catch-up + flip + post-flip drain; the bulk replay is off-path.
        """
        tel = self.tel
        with tel.span("lifecycle.swap",
                      to_version=int(snapshot.version)) as sp:
            t0 = self._clock()
            with tel.span("swap.build"):
                bundle = self._bundle(snapshot)
            cutoff = now - self.recency_s
            with tel.span("swap.replay"):        # off-path bulk replay
                applied, stale = self._drain_into(bundle, min_ts=cutoff)
            t_flip = self._clock()
            # -- stall window: catch-up + flip + post-flip drain --------
            with tel.span("swap.catchup"):
                a2, s2 = self._drain_into(bundle, min_ts=cutoff)
            if self._pre_flip_hook is not None:
                self._pre_flip_hook()
            # a fault here aborts BEFORE the reference assignment: the
            # old bundle keeps serving in full, nothing is half-flipped
            self.faults.fire("swap.flip",
                             to_version=int(snapshot.version))
            with tel.span("swap.flip"):
                old = self.handle.flip(bundle)
            with tel.span("swap.post_drain"):
                a3, _ = self._drain_into(bundle)
            t1 = self._clock()
            tel.counter("swap.replayed_events", float(applied + a2 + a3))
            tel.counter("swap.postflip_events", float(a3))
            tel.counter("swap.dropped_stale", float(stale + s2))
            report = dict(
                from_version=float(old.version),
                to_version=float(bundle.version),
                replayed_events=float(applied + a2 + a3),
                dropped_stale=float(stale + s2),
                ring_dropped=float(self.ring_dropped),
                build_ms=(t_flip - t0) * 1e3,
                stall_ms=(t1 - t_flip) * 1e3,
                span_id=float(sp.span_id))   # join key into the trace
        self.swap_reports.append(report)
        return report
