"""Publisher: materialize a versioned ``IndexSnapshot`` from training.

Publication is the offline half of the serving co-design: after a
training burst, every user/item embedding is pushed through the trained
RQ codebooks (``rq_assign_corpus`` — one jitted trace over the whole
corpus, bit-identical to the per-batch online assignment path), the
flat cluster ids are inverted into member lists, and the I2I KNN table
is rebuilt from the fresh item embeddings.  The result is gated before
it may be swapped into serving: cluster-routed retrieval must keep at
least ``min_ratio`` of exact-KNN recall on held-out engagements
(``evaluate_snapshot``), so a collapsed or stale index can never
replace a healthy one.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import RankGraph2Config
from repro.core import evaluation as E
from repro.core.rq_index import codes_utilization
from repro.core.serving import build_i2i_knn
from repro.kernels.rq_assign.ops import rq_assign_corpus, flat_codes_np
from repro.lifecycle.snapshot import IndexSnapshot, derive_members


def encode_corpus(rq_params: Dict, emb: np.ndarray,
                  codebook_sizes: Sequence[int], *,
                  chunk: int = 8192, use_kernel: bool = False
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Encode a full corpus through the trained codebooks.

    Returns ``(codes (N, L) int32, flat (N,) int64, recon (N, d) f32)``.
    """
    books = [np.asarray(rq_params["codebooks"][f"layer{l}"], np.float32)
             for l in range(len(codebook_sizes))]
    codes, recon = rq_assign_corpus(emb, books, chunk=chunk,
                                    use_kernel=use_kernel)
    return codes, flat_codes_np(codes, codebook_sizes), recon


def build_snapshot(version: int, user_emb: np.ndarray,
                   item_emb: np.ndarray, rq_params: Dict,
                   cfg: RankGraph2Config, *, i2i_k: int = 20,
                   chunk: int = 8192, use_kernel: bool = False,
                   metrics: Optional[Dict[str, float]] = None,
                   want_user_recon: bool = False):
    """One immutable snapshot from the current embeddings + codebooks.

    ``want_user_recon=True`` additionally returns the user-corpus RQ
    reconstruction from the *same* encode pass as ``(snap, recon)`` —
    the gate's index-hitrate metric needs it, and re-encoding the full
    user corpus just for that would double the dominant publication
    cost."""
    sizes = cfg.rq.codebook_sizes
    u_codes, u_flat, u_recon = encode_corpus(
        rq_params, user_emb, sizes, chunk=chunk, use_kernel=use_kernel)
    i_codes, _, _ = encode_corpus(rq_params, item_emb, sizes,
                                  chunk=chunk, use_kernel=use_kernel)
    n_clusters = int(np.prod(sizes))
    ptr, ids = derive_members(u_flat, n_clusters)
    i2i = build_i2i_knn(item_emb, k=i2i_k)
    coarse = np.asarray(rq_params["codebooks"]["layer0"], np.float32)
    snap = IndexSnapshot(
        user_codes=u_codes, item_codes=i_codes, user_clusters=u_flat,
        member_ptr=ptr, member_ids=ids, coarse_codebook=coarse,
        i2i=np.asarray(i2i, np.int64),
        version=int(version), n_users=len(user_emb),
        n_items=len(item_emb), codebook_sizes=tuple(sizes),
        gate_metrics=tuple(sorted((str(k), float(v))
                                  for k, v in (metrics or {}).items())))
    return (snap, u_recon) if want_user_recon else snap


# ---------------------------------------------------------------------------
# index health: metrics computable from the snapshot alone
# ---------------------------------------------------------------------------

def snapshot_health(snap: IndexSnapshot) -> Dict[str, float]:
    """First-class index-health metrics needing no eval world: per-layer
    utilization of the published user+item assignments (the collapse
    floor the gate thresholds), and the balance of the coarse inverted
    lists — ``coarse_list_balance`` is the normalized entropy of the
    layer-0 member-list sizes (1 = perfectly flat lists, -> 0 at
    collapse) and ``coarse_list_max_share`` the heaviest list's share of
    the user corpus (what bounds serving tail latency)."""
    all_codes = np.concatenate([snap.user_codes, snap.item_codes], axis=0)
    util = codes_utilization(all_codes, snap.codebook_sizes)
    out = {f"util_layer{l}": float(u) for l, u in enumerate(util)}
    out["codebook_util_min"] = float(min(util)) if util else 0.0
    k0 = snap.codebook_sizes[0]
    stride = max(snap.n_clusters // k0, 1)
    ptr = snap.member_ptr
    sizes0 = np.array([ptr[(c + 1) * stride] - ptr[c * stride]
                       for c in range(k0)], np.float64)
    tot = float(sizes0.sum())
    if tot <= 0 or k0 <= 1:
        out["coarse_list_balance"] = 0.0 if k0 > 1 else 1.0
        out["coarse_list_max_share"] = 0.0 if tot <= 0 else 1.0
        return out
    p = sizes0 / tot
    nz = p[p > 0]
    out["coarse_list_balance"] = float(-np.sum(nz * np.log(nz))
                                       / np.log(k0))
    out["coarse_list_max_share"] = float(p.max())
    return out


# ---------------------------------------------------------------------------
# recall gate: cluster-routed retrieval vs exact KNN
# ---------------------------------------------------------------------------

def cluster_neighbor_users(snap: IndexSnapshot, user_emb: np.ndarray,
                           queries: np.ndarray, k: int, *,
                           n_probe_factor: int = 4) -> np.ndarray:
    """Top-k neighbor *users* per query via the published index:
    multi-probe the coarse (layer-0) cells nearest the query embedding
    until ~``n_probe_factor * k`` candidates are gathered, then rank the
    candidates by cosine.  This is the IVF-style serving read the
    snapshot supports without any online KNN over the full pool.
    Returns ``(len(queries), k)`` user ids, ``-1``-padded.
    """
    e = user_emb / np.maximum(
        np.linalg.norm(user_emb, axis=1, keepdims=True), 1e-8)
    q = e[queries]
    C = snap.coarse_codebook
    # coarse routing: distance of the query embedding to layer-0 cells
    d2 = (np.sum(q * q, axis=1, keepdims=True) - 2.0 * q @ C.T
          + np.sum(C * C, axis=1)[None, :])
    probe_order = np.argsort(d2, axis=1, kind="stable")
    out = np.full((len(queries), k), -1, np.int64)
    want = n_probe_factor * k
    for qi in range(len(queries)):
        cand: list = []
        for k0 in probe_order[qi]:
            members = snap.coarse_members(int(k0))
            if len(members):
                cand.append(members)
            if sum(len(c) for c in cand) >= want:
                break
        if not cand:
            continue
        cm = np.concatenate(cand)
        cm = cm[cm != queries[qi]]               # self-exclusion
        if not len(cm):
            continue
        sims = e[cm] @ e[queries[qi]]
        kk = min(k, len(cm))
        top = np.argpartition(-sims, kk - 1)[:kk]
        top = top[np.argsort(-sims[top], kind="stable")]
        out[qi, :kk] = cm[top]
    return out


def cluster_user_recall(snap: IndexSnapshot, user_emb: np.ndarray,
                        world, *, ks: Sequence[int] = (100,),
                        n_queries: int = 500, seed: int = 0,
                        n_probe_factor: int = 4) -> Dict[int, float]:
    """``evaluation.user_recall`` with the exact KNN neighbor search
    replaced by the published cluster index (same query sampling, same
    next-day ground truth — the numbers are directly comparable)."""
    day1 = E._user_day1_items(world.day1, len(user_emb))
    rng = np.random.default_rng(seed)
    active = np.flatnonzero([len(s) > 0 for s in day1])
    if len(active) == 0:
        return {k: 0.0 for k in ks}
    queries = rng.choice(active, min(n_queries, len(active)),
                         replace=False)
    kmax = max(ks)
    nbrs = cluster_neighbor_users(snap, user_emb, queries, kmax,
                                  n_probe_factor=n_probe_factor)
    out = {}
    for k in ks:
        recs = []
        for qi, u in enumerate(queries):
            truth = day1[u]
            pred = set()
            for v in nbrs[qi, :k]:
                if v >= 0:
                    pred |= day1[v]
            recs.append(len(pred & truth) / max(len(truth), 1))
        out[k] = float(np.mean(recs))
    return out


def i2i_item_recall(snap: IndexSnapshot, world, *, n_edges: int = 500,
                    seed: int = 0) -> float:
    """§5.2.2 item-ranking recall *through the published index*: the
    fraction of sampled next-day co-engagement pairs ``(i, j)`` where
    ``j`` appears in the snapshot's I2I table row of ``i`` (the list
    serving actually unions at request time)."""
    pairs = E.day1_co_pairs(world.day1, n_edges=n_edges, seed=seed)
    if not len(pairs):
        return 0.0
    n = snap.i2i.shape[0]
    pairs = pairs[(pairs[:, 0] < n) & (pairs[:, 1] < n)]
    if not len(pairs):
        return 0.0
    hits = (snap.i2i[pairs[:, 0]] == pairs[:, 1][:, None]).any(axis=1)
    return float(hits.mean())


def evaluate_snapshot(snap: IndexSnapshot, user_emb: np.ndarray,
                      user_recon: np.ndarray, world, *,
                      recall_k: int = 100, n_queries: int = 500,
                      seed: int = 0, n_probe_factor: int = 4,
                      hitrate_pairs: Optional[np.ndarray] = None,
                      item_emb: Optional[np.ndarray] = None
                      ) -> Dict[str, float]:
    """The publication gate: cluster-index recall vs exact-KNN recall on
    the same held-out next-day engagements, the §5.2.2 item-ranking
    recall through the published I2I table vs exact embedding ranking
    (when ``item_emb`` is supplied), per-layer codebook utilization of
    the *published* assignments (a collapsed codebook cannot publish),
    and the §5.2.3 index hitrate (original vs RQ-reconstructed
    embeddings) when positive pairs are supplied.

    ``recall_ratio`` / ``item_recall_ratio`` / ``codebook_util_min``
    are the numbers the swap gate thresholds.
    """
    exact = E.user_recall(user_emb, world, ks=(recall_k,),
                          n_queries=n_queries, seed=seed)[recall_k]
    routed = cluster_user_recall(snap, user_emb, world, ks=(recall_k,),
                                 n_queries=n_queries, seed=seed,
                                 n_probe_factor=n_probe_factor)[recall_k]
    out = dict(recall_exact=float(exact), recall_index=float(routed),
               recall_ratio=float(routed / max(exact, 1e-12)),
               recall_k=float(recall_k))
    # §5.2.2 item side: exact ranking at the I2I table's own width, so
    # the index number has an apples-to-apples ceiling
    if item_emb is not None:
        k_i2i = int(snap.i2i.shape[1])
        exact_i = E.item_recall(item_emb, world, ks=(k_i2i,),
                                n_edges=n_queries, seed=seed)[k_i2i]
        routed_i = i2i_item_recall(snap, world, n_edges=n_queries,
                                   seed=seed)
        out["item_recall_exact"] = float(exact_i)
        out["item_recall_index"] = float(routed_i)
        out["item_recall_ratio"] = float(routed_i / max(exact_i, 1e-12))
        out["item_recall_k"] = float(k_i2i)
    # collapse floor + list balance: utilization of the published
    # user+item codes and the flatness of the coarse inverted lists
    out.update(snapshot_health(snap))
    if hitrate_pairs is not None and len(hitrate_pairs):
        hr_orig, hr_recon = E.index_hitrate(
            user_emb, user_recon, hitrate_pairs, ks=(10,), seed=seed)
        out["hitrate10_orig"] = hr_orig[10]
        out["hitrate10_recon"] = hr_recon[10]
    return out
