"""Versioned, immutable serving-index snapshots.

An ``IndexSnapshot`` is the publication artifact that crosses the
offline/online boundary: everything the serving tier needs to run
KNN-free retrieval — per-user RQ codes and flat cluster ids, the
cluster->member inverted lists, the coarse codebook (for multi-probe
candidate routing) and the offline I2I KNN table — frozen at one
version.  Serving never mutates a snapshot; the swap engine flips a
handle between whole versions (``lifecycle.swap``).

On disk a snapshot uses exactly the checkpointer's layout
(``step_<version>/{manifest.json, 000000.npy, ...}`` plus the atomic
``latest`` pointer), written *through* ``checkpoint.Checkpointer`` — a
snapshot directory is a checkpoint directory, with the snapshot's
scalar fields riding in the manifest metadata.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.checkpointer import (CheckpointCorruptError,
                                           Checkpointer)
from repro.faults import get_faults
from repro.obs import get_telemetry


class SnapshotCorruptError(RuntimeError):
    """A snapshot failed verification and no retained version is good."""


@dataclasses.dataclass(frozen=True)
class IndexSnapshot:
    """One published version of the co-learned cluster index.

    Flat cluster id = ``sum_l code_l * prod(sizes[l+1:])`` — with the
    production two-layer (5000, 50) codebooks the coarse (layer-0) code
    owns the contiguous flat range ``[k0*50, (k0+1)*50)``, which is what
    lets the member lists double as an IVF-style multi-probe index.
    """
    # array leaves (flatten order == field order; keep stable on disk)
    user_codes: np.ndarray       # (n_users, L) int32 per-layer codes
    item_codes: np.ndarray       # (n_items, L) int32
    user_clusters: np.ndarray    # (n_users,) int64 flat cluster ids
    member_ptr: np.ndarray       # (n_clusters + 1,) int64 CSR offsets
    member_ids: np.ndarray       # (n_users,) int64 users by cluster
    coarse_codebook: np.ndarray  # (sizes[0], d) f32 layer-0 centroids
    i2i: np.ndarray              # (n_items, k) int64 offline I2I KNN
    # manifest metadata (meta fields must stay hashable — they ride in
    # the pytree treedef; metrics is therefore a tuple of pairs)
    version: int
    n_users: int
    n_items: int
    codebook_sizes: Tuple[int, ...]
    gate_metrics: Tuple[Tuple[str, float], ...] = ()

    @property
    def metrics(self) -> Dict[str, float]:
        """Publication-time gate numbers as a dict."""
        return dict(self.gate_metrics)

    @property
    def n_clusters(self) -> int:
        return int(np.prod(self.codebook_sizes))

    def members_of(self, cluster: int) -> np.ndarray:
        lo, hi = self.member_ptr[cluster], self.member_ptr[cluster + 1]
        return self.member_ids[lo:hi]

    def coarse_members(self, k0: int) -> np.ndarray:
        """All users whose layer-0 code is ``k0`` (the contiguous flat
        range — the multi-probe candidate unit)."""
        stride = self.n_clusters // self.codebook_sizes[0]
        lo = self.member_ptr[k0 * stride]
        hi = self.member_ptr[(k0 + 1) * stride]
        return self.member_ids[lo:hi]


_DATA_FIELDS = ("user_codes", "item_codes", "user_clusters",
                "member_ptr", "member_ids", "coarse_codebook", "i2i")
_META_FIELDS = ("version", "n_users", "n_items", "codebook_sizes",
                "gate_metrics")

jax.tree_util.register_dataclass(
    IndexSnapshot, data_fields=list(_DATA_FIELDS),
    meta_fields=list(_META_FIELDS))


def derive_members(user_clusters: np.ndarray, n_clusters: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Cluster -> member-user inverted lists as CSR ``(ptr, ids)``;
    members ascend within each cluster."""
    user_clusters = np.asarray(user_clusters, np.int64)
    order = np.argsort(user_clusters, kind="stable")
    counts = np.bincount(user_clusters, minlength=n_clusters)
    ptr = np.zeros(n_clusters + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    return ptr, order.astype(np.int64)


class SnapshotStore:
    """Versioned snapshot directory on the checkpointer's manifest
    format: save goes through ``Checkpointer.save`` (atomic tmp+rename,
    retention, ``latest`` pointer), load reads the manifest + leaf files
    directly — no template tree needed, shapes come from the ``.npy``
    headers."""

    def __init__(self, directory: str, *, keep: int = 3, faults=None,
                 telemetry=None):
        self.dir = directory
        self.faults = faults if faults is not None else get_faults()
        self.tel = telemetry if telemetry is not None else get_telemetry()
        # Checkpointer.__init__ sweeps step_*.tmp partials from crashes
        self._ck = Checkpointer(directory, keep=keep, faults=self.faults)

    # -- publish ------------------------------------------------------------

    def publish(self, snap: IndexSnapshot, *, blocking: bool = True
                ) -> None:
        meta = dict(kind="index_snapshot",
                    version=int(snap.version),
                    n_users=int(snap.n_users),
                    n_items=int(snap.n_items),
                    codebook_sizes=list(snap.codebook_sizes),
                    metrics={k: float(v)
                             for k, v in snap.metrics.items()})
        self._ck.save(snap.version, snap, metadata=meta,
                      blocking=blocking)

    def wait(self) -> None:
        self._ck.wait()

    # -- load ---------------------------------------------------------------

    def versions(self) -> List[int]:
        return self._ck.all_steps()

    def latest_version(self) -> Optional[int]:
        return self._ck.latest_step()

    def load(self, version: Optional[int] = None, *,
             verify: bool = True) -> IndexSnapshot:
        """Load one version, verifying every leaf against the checksums
        recorded at publish time (``verify=False`` skips the re-hash).
        Raises :class:`CheckpointCorruptError` on a torn or bit-rotted
        snapshot — callers wanting automatic fallback through retained
        versions use :meth:`load_latest_good`."""
        version = (version if version is not None
                   else self.latest_version())
        if version is None:
            raise FileNotFoundError(f"no snapshots under {self.dir}")
        d = os.path.join(self.dir, f"step_{version}")
        # corrupt-at-load models on-disk rot discovered at read time
        self.faults.fire("snapshot.load", version=version,
                         path=os.path.join(d, "000000.npy"))
        if verify:
            self._ck.verify_step(version)
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        if meta.get("kind") != "index_snapshot":
            raise ValueError(f"{d} is not an index snapshot "
                             f"(kind={meta.get('kind')!r})")
        if meta["n_leaves"] != len(_DATA_FIELDS):
            raise ValueError(
                f"snapshot has {meta['n_leaves']} leaves, expected "
                f"{len(_DATA_FIELDS)} — incompatible format version")
        leaves: Dict[str, Any] = {}
        for i, name in enumerate(_DATA_FIELDS):
            leaves[name] = np.load(os.path.join(d, f"{i:06d}.npy"),
                                   allow_pickle=False)
        return IndexSnapshot(
            version=int(meta["version"]),
            n_users=int(meta["n_users"]),
            n_items=int(meta["n_items"]),
            codebook_sizes=tuple(int(s)
                                 for s in meta["codebook_sizes"]),
            gate_metrics=tuple(sorted(
                (str(k), float(v))
                for k, v in meta.get("metrics", {}).items())),
            **leaves)

    # -- corruption fallback ------------------------------------------------

    def quarantine(self, version: int) -> str:
        """Move a corrupt version out of the loadable set by renaming
        ``step_N`` -> ``step_N.corrupt`` (``all_steps`` skips it: the
        suffix fails int parsing) — evidence is kept for forensics
        instead of deleted.  Returns the quarantine dir name."""
        src = os.path.join(self.dir, f"step_{version}")
        dst = src + ".corrupt"
        k = 0
        while os.path.exists(dst):
            k += 1
            dst = f"{src}.corrupt{k}"
        os.rename(src, dst)
        self.tel.counter("snapshot.quarantined")
        return os.path.basename(dst)

    def load_latest_good(self) -> IndexSnapshot:
        """Walk retained versions newest-first, verifying each; corrupt
        ones are quarantined (and counted) and the walk continues.
        Raises :class:`SnapshotCorruptError` only when *no* retained
        version verifies — the fallback half of crash-safe publication."""
        last_err: Optional[Exception] = None
        for v in sorted(self.versions(), reverse=True):
            try:
                snap = self.load(v)
            except CheckpointCorruptError as e:
                # detected torn/rotted version: quarantine + keep walking
                last_err = e
                self.tel.counter("snapshot.corrupt_detected")
                with self.tel.span("snapshot.fallback", version=v,
                                   reason=str(e)):
                    self.quarantine(v)
                continue
            return snap
        raise SnapshotCorruptError(
            f"no loadable snapshot under {self.dir} "
            f"(last error: {last_err})")
