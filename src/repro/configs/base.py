"""Config dataclasses + the architecture/shape registry.

Every assigned architecture registers an ``ArchSpec`` mapping
``--arch <id>`` to (family, config, shape table).  Shapes are the
assigned input-shape sets; each shape names the step it lowers
(train_step / prefill / decode / serve).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None          # default d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    act: str = "silu"                        # silu (swiglu) | gelu (geglu)
    norm: str = "rmsnorm"                    # rmsnorm | layernorm_np (olmo)
    rope_theta: float = 500000.0
    tie_embeddings: bool = False
    # MoE (n_experts == 0 -> dense)
    n_experts: int = 0
    n_experts_per_tok: int = 2
    moe_d_ff: Optional[int] = None           # expert hidden dim
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # execution
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    unroll_chunks: bool = False               # cost-probe mode: no scans
    decode_chunk: int = 2048                  # KV chunk for long decode
    optimizer: str = "adamw"                  # adafactor for the giants

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        hd = self.resolved_head_dim
        attn = self.d_model * hd * (2 * self.n_heads + 2 * self.n_kv_heads)
        if self.n_experts:
            ff = 3 * self.d_model * (self.moe_d_ff or self.d_ff) * self.n_experts
            ff += self.d_model * self.n_experts  # router
        else:
            ff = 3 * self.d_model * self.d_ff
        per_layer = attn + ff + 2 * self.d_model
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + self.d_model

    def n_active_params(self) -> int:
        if not self.n_experts:
            return self.n_params()
        hd = self.resolved_head_dim
        attn = self.d_model * hd * (2 * self.n_heads + 2 * self.n_kv_heads)
        ff = 3 * self.d_model * (self.moe_d_ff or self.d_ff) * self.n_experts_per_tok
        per_layer = attn + ff + 2 * self.d_model
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + self.d_model


# ---------------------------------------------------------------------------
# GNN family (EquiformerV2 / eSCN)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    d_feat: int = 128          # raw node feature dim (overridden per shape)
    d_edge: int = 0
    n_radial: int = 8          # radial basis size
    edge_chunk: int = 65536    # lax.scan edge-block size (memory bound)
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    unroll: bool = False       # cost-probe mode: python loops, no scans

    @property
    def n_sph(self) -> int:
        return (self.l_max + 1) ** 2


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str = "dlrm-rm2"
    kind: str = "dlrm"          # dlrm | wide_deep | sasrec | bst
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab_sizes: Tuple[int, ...] = ()        # per sparse field
    default_vocab: int = 10_000_000
    multi_hot: int = 1                       # ids per field (bag size)
    bot_mlp: Tuple[int, ...] = (512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 512, 256, 1)
    interaction: str = "dot"                 # dot | concat | self_attn | transformer
    # sequence models (sasrec / bst)
    seq_len: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    def field_vocab(self, i: int) -> int:
        if self.vocab_sizes:
            return self.vocab_sizes[i % len(self.vocab_sizes)]
        return self.default_vocab


# ---------------------------------------------------------------------------
# RankGraph-2 (the paper's own architecture)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RQConfig:
    codebook_sizes: Tuple[int, ...] = (5000, 50)
    zeta1: float = 10.0
    zeta2: float = 0.01
    hist_len: int = 1000         # rolling batches for p-hat
    commit_coef: float = 0.25
    biased_selection: bool = True
    regularize: bool = True
    # utilization balancing + self-healing (dead-code reset)
    util_coef: float = 1.0       # weight of the soft-usage entropy gap
    usage_ema: float = 0.99      # decay of the per-code EMA usage counter
    dead_floor: float = 0.25     # dead if usage < dead_floor / n_codes
    reset_every: int = 0         # burst steps between reset passes (0=off)
    reset_probe: int = 512       # nodes embedded per reset/repair probe


@dataclasses.dataclass(frozen=True)
class RankGraph2Config:
    name: str = "rankgraph2"
    d_user_feat: int = 64
    d_item_feat: int = 64
    d_embed: int = 256
    n_heads: int = 4             # multi-head embeddings (neg augmentation)
    d_hidden: int = 512
    k_imp: int = 50              # pre-computed PPR neighbors
    k_train: int = 10            # sampled per training edge
    n_negatives: int = 100
    n_pool_neg: int = 32         # from rolling out-of-batch pool
    margin: float = 0.1
    tau: float = 0.06
    # training hot path
    use_fused_contrastive: bool = False   # Pallas fused loss (fwd + VJP)
    reuse_lprime_negatives: bool = True   # share negs between L and L'
    rq: RQConfig = dataclasses.field(default_factory=RQConfig)
    # graph construction
    alpha_pop: float = 0.3       # popularity bias exponent
    c_u: int = 2                 # min common items for U-U edge
    c_i: int = 2                 # min common users for I-I edge
    k_cap: int = 64              # top-K edges kept per node
    ppr_walks: int = 64
    ppr_len: int = 5
    ppr_restart: float = 0.15
    dtype: str = "bfloat16"
    param_dtype: str = "float32"


# ---------------------------------------------------------------------------
# Shapes + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    step: str                     # "train" | "prefill" | "decode" | "serve"
    dims: Dict[str, int]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                   # "lm" | "gnn" | "recsys" | "rankgraph2"
    config: Any
    shapes: Tuple[ShapeSpec, ...]
    source: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name!r}; "
                       f"have {[s.name for s in self.shapes]}")


_REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


LM_SHAPES = (
    ShapeSpec("train_4k", "train", dict(seq_len=4096, global_batch=256)),
    ShapeSpec("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
    ShapeSpec("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
    ShapeSpec("long_500k", "decode", dict(seq_len=524288, global_batch=1)),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "train",
              dict(n_nodes=2708, n_edges=10556, d_feat=1433)),
    ShapeSpec("minibatch_lg", "train",
              dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                   fanout1=15, fanout2=10, d_feat=602)),
    ShapeSpec("ogb_products", "train",
              dict(n_nodes=2449029, n_edges=61859140, d_feat=100)),
    ShapeSpec("molecule", "train",
              dict(n_nodes=30, n_edges=64, batch=128, d_feat=16)),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", dict(batch=65536)),
    ShapeSpec("serve_p99", "serve", dict(batch=512)),
    ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
    ShapeSpec("retrieval_cand", "serve", dict(batch=1, n_candidates=1_000_000)),
)

RANKGRAPH2_SHAPES = (
    ShapeSpec("train_batch", "train", dict(batch=32768)),
    ShapeSpec("serve_p99", "serve", dict(batch=512)),
    ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
    ShapeSpec("retrieval_cand", "serve", dict(batch=1, n_candidates=1_000_000)),
)


_LOADED = False


def _ensure_loaded() -> None:
    """Import all config modules so their register() calls run."""
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        olmo_1b, llama3_2_3b, gemma_2b, grok_1_314b, kimi_k2_1t_a32b,
        equiformer_v2, sasrec, wide_deep, dlrm_rm2, bst, rankgraph2,
    )
