"""gemma-2b [arXiv:2403.08295; hf]: 18L d=2048 8H MQA (kv=1) ff=16384
vocab=256000 — GeGLU, head_dim=256, embeddings tied + sqrt(d) scaling."""
from repro.configs.base import ArchSpec, LMConfig, LM_SHAPES, register

CONFIG = LMConfig(
    name="gemma-2b", n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    head_dim=256, d_ff=16384, vocab_size=256000, act="gelu",
    norm="rmsnorm_p1", rope_theta=10000.0, tie_embeddings=True,
    optimizer="adamw")

register(ArchSpec("gemma-2b", "lm", CONFIG, LM_SHAPES,
                  source="arXiv:2403.08295"))
