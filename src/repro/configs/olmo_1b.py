"""olmo-1b [arXiv:2402.00838; hf]: 16L d=2048 16H (GQA kv=16) ff=8192
vocab=50304 — non-parametric LayerNorm."""
from repro.configs.base import ArchSpec, LMConfig, LM_SHAPES, register

CONFIG = LMConfig(
    name="olmo-1b", n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=50304, act="silu", norm="layernorm_np",
    tie_embeddings=True, optimizer="adamw")

register(ArchSpec("olmo-1b", "lm", CONFIG, LM_SHAPES,
                  source="arXiv:2402.00838"))
