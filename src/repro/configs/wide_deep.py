"""wide-deep [arXiv:1606.07792]: 40 sparse fields, embed 32,
deep MLP 1024-512-256, concat interaction + linear wide part."""
from repro.configs.base import ArchSpec, RecsysConfig, RECSYS_SHAPES, register

CONFIG = RecsysConfig(
    name="wide-deep", kind="wide_deep", n_dense=0, n_sparse=40, embed_dim=32,
    default_vocab=10_000_000, bot_mlp=(1024, 512, 256),
    interaction="concat")

register(ArchSpec("wide-deep", "recsys", CONFIG, RECSYS_SHAPES,
                  source="arXiv:1606.07792"))
