"""dlrm-rm2 [arXiv:1906.00091]: 13 dense, 26 sparse, embed 64,
bot 13-512-256-64, top 512-512-256-1, dot interaction."""
from repro.configs.base import ArchSpec, RecsysConfig, RECSYS_SHAPES, register

CONFIG = RecsysConfig(
    name="dlrm-rm2", kind="dlrm", n_dense=13, n_sparse=26, embed_dim=64,
    default_vocab=10_000_000, bot_mlp=(512, 256, 64),
    top_mlp=(512, 512, 256, 1), interaction="dot")

register(ArchSpec("dlrm-rm2", "recsys", CONFIG, RECSYS_SHAPES,
                  source="arXiv:1906.00091"))
