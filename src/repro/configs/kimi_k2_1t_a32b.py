"""kimi-k2-1t-a32b [arXiv:2501.kimi2]: 61L d=7168 64H (GQA kv=8)
expert-ff=2048 vocab=163840, MoE 384 experts top-8 (~1T params, 32B
active).

384 % 16 == 0, so experts shard cleanly over the model axis (24 experts
per device, expert parallelism).  Adafactor: AdamW state for 1T params
(~12TB) cannot fit 512 x 16GB HBM; factored stats fit comfortably.
(The real K2 has a dense first layer + shared expert; we model the
uniform-MoE stack and note the deviation in DESIGN.md.)
"""
from repro.configs.base import ArchSpec, LMConfig, LM_SHAPES, register

CONFIG = LMConfig(
    name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
    n_kv_heads=8, d_ff=2048, moe_d_ff=2048, vocab_size=163840, act="silu",
    norm="rmsnorm", n_experts=384, n_experts_per_tok=8,
    capacity_factor=1.25, param_dtype="bfloat16", optimizer="adafactor")

register(ArchSpec("kimi-k2-1t-a32b", "lm", CONFIG, LM_SHAPES,
                  source="arXiv:2501.kimi2 (paper-table)"))
