"""llama3.2-3b [hf:meta-llama]: 28L d=3072 24H (GQA kv=8) ff=8192
vocab=128256."""
from repro.configs.base import ArchSpec, LMConfig, LM_SHAPES, register

CONFIG = LMConfig(
    name="llama3.2-3b", n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=128256, act="silu", norm="rmsnorm",
    rope_theta=500000.0, optimizer="adamw")

register(ArchSpec("llama3.2-3b", "lm", CONFIG, LM_SHAPES,
                  source="hf:meta-llama/Llama-3.2-3B"))
