"""rankgraph2 — the paper's own architecture (production hyperparameters
from §5.1: batch 32768, embed 256, RQ codebooks 5000 x 50, K_IMP=50,
K'=10, 100 negatives)."""
from repro.configs.base import (ArchSpec, RANKGRAPH2_SHAPES, RQConfig,
                                RankGraph2Config, register)

CONFIG = RankGraph2Config(
    name="rankgraph2", d_user_feat=256, d_item_feat=256, d_embed=256,
    n_heads=4, d_hidden=1024, k_imp=50, k_train=10, n_negatives=100,
    n_pool_neg=32,
    # self-healing index: utilization-balancing on by default plus an
    # in-burst dead-code reset cadence (EMA floor, keyed-uniform reseed)
    rq=RQConfig(codebook_sizes=(5000, 50), util_coef=1.0,
                usage_ema=0.99, dead_floor=0.25, reset_every=100))

register(ArchSpec("rankgraph2", "rankgraph2", CONFIG, RANKGRAPH2_SHAPES,
                  source="this paper"))
