"""bst [arXiv:1905.06874] (Alibaba Behavior Sequence Transformer):
embed 32, seq 20, 1 block, 8 heads, MLP 1024-512-256."""
from repro.configs.base import ArchSpec, RecsysConfig, RECSYS_SHAPES, register

CONFIG = RecsysConfig(
    name="bst", kind="bst", n_sparse=8, embed_dim=32, seq_len=20,
    n_blocks=1, n_heads=8, default_vocab=10_000_000,
    top_mlp=(1024, 512, 256, 1), interaction="transformer")

register(ArchSpec("bst", "recsys", CONFIG, RECSYS_SHAPES,
                  source="arXiv:1905.06874"))
