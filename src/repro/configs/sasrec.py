"""sasrec [arXiv:1808.09781]: embed 50, 2 blocks, 1 head, seq 50,
self-attention sequence interaction."""
from repro.configs.base import ArchSpec, RecsysConfig, RECSYS_SHAPES, register

CONFIG = RecsysConfig(
    name="sasrec", kind="sasrec", n_sparse=0, embed_dim=50, seq_len=50,
    n_blocks=2, n_heads=1, default_vocab=10_000_000,
    interaction="self_attn")

register(ArchSpec("sasrec", "recsys", CONFIG, RECSYS_SHAPES,
                  source="arXiv:1808.09781"))
