"""equiformer-v2 [arXiv:2306.12059]: 12L d_hidden=128 l_max=6 m_max=2
8 heads, SO(2)-eSCN equivariant graph attention.

Note: the assigned shapes (Cora-like / ogbn-products-like) are
topology+feature shapes; EquiformerV2 is geometric, so node positions
are part of input_specs (synthesized for non-geometric graphs — the
computational signature, which is what the dry-run measures, is
unchanged).  ``minibatch_lg`` uses the 2-hop fanout-(15,10) sampler with
fixed-size padded subgraphs.
"""
from repro.configs.base import ArchSpec, GNNConfig, GNN_SHAPES, register

CONFIG = GNNConfig(
    name="equiformer-v2", n_layers=12, d_hidden=128, l_max=6, m_max=2,
    n_heads=8, n_radial=8, edge_chunk=65536)

register(ArchSpec("equiformer-v2", "gnn", CONFIG, GNN_SHAPES,
                  source="arXiv:2306.12059"))
