"""grok-1-314b [hf:xai-org/grok-1]: 64L d=6144 48H (GQA kv=8) ff=32768
vocab=131072, MoE 8 experts top-2.

8 experts do not divide the 16-way model axis, so experts are
*replicated* and tensor parallelism runs inside each expert (d_ff
sharded) — see the rules override.  Adafactor keeps optimizer state
factored (314B params; AdamW would need ~3.8TB of state).
"""
from repro.configs.base import ArchSpec, LMConfig, LM_SHAPES, register

CONFIG = LMConfig(
    name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, moe_d_ff=32768, vocab_size=131072, act="gelu",
    norm="rmsnorm", n_experts=8, n_experts_per_tok=2,
    param_dtype="bfloat16", optimizer="adafactor")

RULES_OVERRIDE = {"expert": None, "expert_mlp": "model"}

register(ArchSpec("grok-1-314b", "lm", CONFIG, LM_SHAPES,
                  source="hf:xai-org/grok-1"))
