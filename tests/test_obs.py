"""Telemetry core: spans, sharded metrics, histograms, sinks, clock.

Covers the ISSUE-8 telemetry contract: multi-threaded counter/histogram
emission with no lost or torn records, span nesting/parentage, JSONL
schema round-trip, disabled-sink no-op semantics, injectable-clock
determinism (fixed clock -> byte-stable JSONL), sink rotation, and the
report renderer.
"""
import json
import os
import threading

import pytest

from repro.obs import (FixedClock, Histogram, JsonlSink, MemorySink,
                       MetricsRegistry, NullSink, Telemetry)
from repro.obs import report as report_mod
from repro.obs.metrics import HIST_BUCKETS, bucket_index, bucket_mid
from tests._hypothesis_fallback import given, settings, st


def make_tel(enabled=True):
    sink = MemorySink()
    tel = Telemetry(sink=sink, clock=FixedClock(), enabled=enabled)
    return tel, sink


def records(sink):
    return [json.loads(ln) for ln in sink.lines]


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

class TestSpans:
    def test_nesting_and_parentage(self):
        tel, sink = make_tel()
        with tel.span("outer") as outer:
            with tel.span("mid") as mid:
                with tel.span("inner") as inner:
                    pass
            with tel.span("mid2") as mid2:
                pass
        recs = {r["name"]: r for r in records(sink)}
        assert recs["outer"]["parent_id"] is None
        assert recs["mid"]["parent_id"] == outer.span_id
        assert recs["inner"]["parent_id"] == mid.span_id
        assert recs["mid2"]["parent_id"] == outer.span_id
        assert mid2.span_id != mid.span_id
        # children exit (and are emitted) before their parents
        names = [r["name"] for r in records(sink)]
        assert names == ["inner", "mid", "mid2", "outer"]

    def test_duration_and_attrs(self):
        tel, sink = make_tel()
        with tel.span("work", stage="x") as sp:
            sp.set("extra", 3)
        rec = records(sink)[0]
        assert rec["dur_s"] > 0
        assert rec["attrs"] == {"stage": "x", "extra": 3}
        assert sp.elapsed() == rec["dur_s"]   # cached after exit

    def test_exception_annotates_and_emits(self):
        tel, sink = make_tel()
        with pytest.raises(ValueError):
            with tel.span("boom"):
                raise ValueError("x")
        rec = records(sink)[0]
        assert rec["attrs"]["error"] == "ValueError"

    def test_elapsed_live_before_exit(self):
        tel, _ = make_tel()
        with tel.span("s") as sp:
            assert sp.elapsed() > 0

    def test_per_thread_stacks(self):
        """Parentage never crosses threads: a thread with no open span
        emits a root even while another thread is inside one."""
        tel, sink = make_tel()
        done = threading.Event()
        go = threading.Event()

        def other():
            go.wait(5)
            with tel.span("other_root"):
                pass
            done.set()

        t = threading.Thread(target=other)
        t.start()
        with tel.span("main_root"):
            go.set()
            assert done.wait(5)
        t.join()
        recs = {r["name"]: r for r in records(sink)}
        assert recs["other_root"]["parent_id"] is None
        assert recs["main_root"]["parent_id"] is None
        assert (recs["other_root"]["thread"]
                != recs["main_root"]["thread"])


# ---------------------------------------------------------------------------
# metrics: counters / gauges / histograms across threads
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_gauge_basic(self):
        reg = MetricsRegistry()
        reg.counter("a")
        reg.counter("a", 2.5)
        reg.gauge("g", 1.0)
        reg.gauge("g", 7.0)
        counters, gauges, _ = reg.merged()
        assert counters == {"a": 3.5}
        assert gauges == {"g": 7.0}

    def test_multithreaded_counters_no_lost_records(self):
        reg = MetricsRegistry()
        N_THREADS, N_INCR = 8, 5000

        def work():
            for _ in range(N_INCR):
                reg.counter("hits")
                reg.observe("lat", 0.001)

        threads = [threading.Thread(target=work)
                   for _ in range(N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        counters, _, hists = reg.merged()
        assert counters["hits"] == N_THREADS * N_INCR
        assert hists["lat"].n == N_THREADS * N_INCR

    def test_merged_readable_while_writing(self):
        """A scraper merging concurrently with writers sees monotonically
        growing, untorn state (never more than the true total)."""
        reg = MetricsRegistry()
        stop = threading.Event()

        def writer():
            while not stop.is_set():
                reg.counter("c")

        threads = [threading.Thread(target=writer) for _ in range(4)]
        for t in threads:
            t.start()
        last = 0.0
        for _ in range(50):
            counters, _, _ = reg.merged()
            cur = counters.get("c", 0.0)
            assert cur >= last
            last = cur
        stop.set()
        for t in threads:
            t.join()
        final = reg.merged()[0]["c"]
        assert final == int(final)     # whole number: no torn adds

    def test_gauge_last_write_wins_across_threads(self):
        reg = MetricsRegistry()
        barrier = threading.Barrier(2)

        def setter(v):
            barrier.wait(5)
            reg.gauge("g", v)

        t1 = threading.Thread(target=setter, args=(1.0,))
        t1.start()
        barrier.wait(5)
        t1.join()
        reg.gauge("g", 2.0)            # strictly later than thread 1
        assert reg.merged()[1]["g"] == 2.0


class TestHistogram:
    def test_bucket_monotone(self):
        idx = [bucket_index(v) for v in
               (0.0, 1e-7, 1e-6, 1e-5, 1e-3, 0.1, 10.0, 1e9)]
        assert idx == sorted(idx)
        assert idx[-1] == HIST_BUCKETS - 1
        assert bucket_mid(3) > bucket_mid(2)

    def test_percentiles_uniform(self):
        h = Histogram()
        for i in range(1000):
            h.observe(0.001 * (i + 1))     # 1ms .. 1s uniform
        p50 = h.percentile(0.5)
        p95 = h.percentile(0.95)
        p99 = h.percentile(0.99)
        assert 0.3 < p50 < 0.75            # log buckets: ~10% resolution
        assert p50 <= p95 <= p99 <= h.max
        assert h.percentile(0.0) >= h.min
        assert h.n == 1000
        assert abs(h.mean - 0.5005) < 1e-9

    def test_merge_matches_combined(self):
        a, b, c = Histogram(), Histogram(), Histogram()
        for i in range(100):
            v = 10.0 ** (-(i % 6))
            (a if i % 2 else b).observe(v)
            c.observe(v)
        a.merge(b)
        assert a.n == c.n
        assert a.counts == c.counts
        assert a.min == c.min and a.max == c.max
        assert a.percentile(0.5) == c.percentile(0.5)

    def test_round_trip_dict(self):
        h = Histogram()
        for v in (1e-6, 3e-4, 0.02, 5.0):
            h.observe(v)
        h2 = Histogram.from_dict(
            json.loads(json.dumps(h.to_dict())))
        assert h2.n == h.n and h2.counts == h.counts
        assert h2.min == h.min and h2.max == h.max
        assert h2.percentile(0.95) == h.percentile(0.95)

    def test_empty(self):
        h = Histogram()
        assert h.percentile(0.5) == 0.0
        assert h.mean == 0.0

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=1e-9, max_value=1e6,
                              allow_nan=False), min_size=1, max_size=200),
           st.floats(min_value=0.01, max_value=0.99))
    def test_percentile_within_range(self, values, q):
        h = Histogram()
        for v in values:
            h.observe(v)
        p = h.percentile(q)
        assert h.min <= p <= h.max


# ---------------------------------------------------------------------------
# telemetry facade: schema, flush, disabled semantics, determinism
# ---------------------------------------------------------------------------

class TestTelemetry:
    def test_jsonl_schema_round_trip(self):
        tel, sink = make_tel()
        with tel.span("s", k="v"):
            pass
        tel.counter("c", 2)
        tel.gauge("g", 1.5)
        tel.observe("h", 0.01)
        tel.flush()
        recs = records(sink)
        by_type = {}
        for r in recs:
            by_type.setdefault(r["type"], []).append(r)
        assert set(by_type) == {"span", "counter", "gauge", "hist"}
        sp = by_type["span"][0]
        assert set(sp) == {"type", "name", "span_id", "parent_id",
                           "thread", "t_wall", "dur_s", "attrs"}
        assert by_type["counter"][0]["value"] == 2.0
        assert by_type["gauge"][0]["value"] == 1.5
        h = Histogram.from_dict(by_type["hist"][0])
        assert h.n == 1

    def test_fixed_clock_byte_stable(self):
        def run():
            tel, sink = make_tel()
            with tel.span("a", k=1):
                with tel.span("b"):
                    pass
            tel.counter("c.x", 2)
            tel.observe("h.lat", 0.0123)
            tel.gauge("g", 4.0)
            tel.flush()
            return sink.text()

        assert run() == run()
        assert run()                       # non-empty

    def test_disabled_is_noop(self):
        tel, sink = make_tel(enabled=False)
        with tel.span("s") as sp:
            tel.counter("c")
            tel.gauge("g", 1.0)
            tel.observe("h", 0.5)
        tel.flush()
        assert sink.lines == []
        assert tel.snapshot() == {"counters": {}, "gauges": {},
                                  "hists": {}}
        # spans still measure even when not emitting
        assert sp.duration_s > 0

    def test_null_sink(self):
        tel = Telemetry(sink=NullSink(), clock=FixedClock())
        with tel.span("s"):
            tel.counter("c")
        tel.flush()                        # no crash, nowhere to look
        assert tel.snapshot()["counters"] == {"c": 1.0}

    def test_reconfigure_in_place(self):
        tel, _ = make_tel(enabled=False)
        tel.counter("c")
        sink2 = MemorySink()
        tel.reconfigure(sink=sink2, enabled=True)
        tel.counter("c")
        tel.flush()
        assert tel.snapshot()["counters"] == {"c": 1.0}   # pre-enable lost
        assert any(json.loads(ln)["type"] == "counter"
                   for ln in sink2.lines)

    def test_percentiles_api(self):
        tel, _ = make_tel()
        for i in range(100):
            tel.observe("lat", 0.001 * (i + 1))
        p = tel.percentiles("lat")
        assert set(p) == {"p50", "p95", "p99"}
        assert p["p50"] <= p["p95"] <= p["p99"]
        assert tel.percentiles("missing") == {"p50": 0.0, "p95": 0.0,
                                              "p99": 0.0}

    def test_reset_metrics(self):
        tel, _ = make_tel()
        tel.counter("c")
        tel.reset_metrics()
        assert tel.snapshot()["counters"] == {}
        tel.counter("c")                   # shard re-registers
        assert tel.snapshot()["counters"] == {"c": 1.0}

    def test_numpy_values_serialize(self):
        np = pytest.importorskip("numpy")
        tel, sink = make_tel()
        tel.counter("c", np.float32(2.0))
        tel.gauge("g", np.int64(3))
        with tel.span("s", n=np.int32(7)):
            pass
        tel.flush()
        for r in records(sink):            # default=float coerces all
            json.dumps(r)


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------

class TestJsonlSink:
    def test_write_flush_read_back(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        sink = JsonlSink(p)
        sink.write_line('{"a":1}')
        sink.flush()
        assert json.loads(open(p).read()) == {"a": 1}
        sink.close()

    def test_rotation_bounded(self, tmp_path):
        p = str(tmp_path / "r.jsonl")
        sink = JsonlSink(p, max_bytes=200, max_files=3)
        for i in range(100):
            sink.write_line(json.dumps({"i": i, "pad": "x" * 20}))
        sink.flush()
        files = sorted(os.listdir(tmp_path))
        assert "r.jsonl" in files
        assert len(files) <= 3
        total = sum(os.path.getsize(tmp_path / f) for f in files)
        assert total <= 3 * (200 + 64)     # bounded despite 100 writes
        # newest record is in the active file
        last = open(p).read().strip().splitlines()[-1]
        assert json.loads(last)["i"] == 99
        sink.close()

    def test_concurrent_writers_no_torn_lines(self, tmp_path):
        p = str(tmp_path / "c.jsonl")
        sink = JsonlSink(p, max_bytes=1 << 20)

        def work(tid):
            for i in range(500):
                sink.write_line(json.dumps({"t": tid, "i": i}))

        threads = [threading.Thread(target=work, args=(t,))
                   for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        sink.flush()
        lines = open(p).read().strip().splitlines()
        assert len(lines) == 2000
        seen = set()
        for ln in lines:
            r = json.loads(ln)             # every line parses: no tears
            seen.add((r["t"], r["i"]))
        assert len(seen) == 2000           # and none lost
        sink.close()


# ---------------------------------------------------------------------------
# report renderer
# ---------------------------------------------------------------------------

class TestReport:
    def _emit(self, tmp_path, name="t.jsonl"):
        p = str(tmp_path / name)
        tel = Telemetry(sink=JsonlSink(p), clock=FixedClock())
        with tel.span("lifecycle.cycle"):
            with tel.span("lifecycle.train"):
                pass
            with tel.span("lifecycle.swap"):
                with tel.span("swap.flip"):
                    pass
        tel.counter("serving.seqlock_retries", 5)
        tel.gauge("serving.queue_depth_max", 12.0)
        for i in range(50):
            tel.observe("serving.retrieve_latency_s", 0.001 * (i + 1))
        tel.flush()
        return p

    def test_render_tree_and_metrics(self, tmp_path):
        p = self._emit(tmp_path)
        out = report_mod.render([p])
        assert "lifecycle.cycle" in out
        # nested children are indented under their parents
        assert "\n  lifecycle.train" in out
        assert "\n    swap.flip" in out
        assert "serving.seqlock_retries" in out and "5" in out
        assert "serving.queue_depth_max" in out
        assert "p50=" in out and "p95=" in out
        assert "serving.retrieve_latency_s" in out

    def test_multi_file_counters_sum(self, tmp_path):
        p1 = self._emit(tmp_path, "a.jsonl")
        p2 = self._emit(tmp_path, "b.jsonl")
        counters, _, hists = report_mod.metric_summary(
            report_mod.load_records([p1, p2]))
        assert counters["serving.seqlock_retries"] == 10.0
        assert hists["serving.retrieve_latency_s"].n == 100

    def test_cli_main(self, tmp_path, capsys):
        p = self._emit(tmp_path)
        assert report_mod.main([p]) == 0
        assert "span tree" in capsys.readouterr().out

    def test_skips_garbage_lines(self, tmp_path):
        p = self._emit(tmp_path)
        with open(p, "a") as fh:
            fh.write("not json\n\n{\"type\":\"counter\",\"name\":\"x\","
                     "\"value\":1,\"t_wall\":0}\n")
        counters, _, _ = report_mod.metric_summary(
            report_mod.load_records([p]))
        assert counters["x"] == 1
