"""Real process-death tests (satellite 3): SIGKILL a child mid-publish
and mid-swap, then prove the snapshot store reopens clean and serving
resumes from the last good version.

The child holds itself inside the dangerous window with a ``delay``
fault whose ``on_inject`` hook drops a sentinel file; the parent waits
for the sentinel and sends SIGKILL — an un-catchable, un-flushable
death, unlike the in-process ``InjectedCrash`` simulation."""
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.lifecycle.snapshot import SnapshotStore
from repro.lifecycle.swap import SwapServer

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# shared child-side helper: a tiny valid IndexSnapshot, no jax needed
SNAP_HELPER = textwrap.dedent("""
    import os, sys
    import numpy as np
    from repro.faults import FaultInjector, FaultPlan, FaultSpec
    from repro.lifecycle.snapshot import (IndexSnapshot, SnapshotStore,
                                          derive_members)

    def snap(version, seed=0):
        rng = np.random.default_rng(seed)
        sizes, n_users, n_items, d, k = (4, 2), 40, 30, 8, 5
        flat = rng.integers(0, 8, n_users).astype(np.int64)
        ptr, ids = derive_members(flat, 8)
        codes = np.stack([flat // 2, flat % 2], axis=1).astype(np.int32)
        return IndexSnapshot(
            user_codes=codes,
            item_codes=rng.integers(0, 4, (n_items, 2)).astype(np.int32),
            user_clusters=flat, member_ptr=ptr, member_ids=ids,
            coarse_codebook=rng.normal(size=(4, d)).astype(np.float32),
            i2i=rng.integers(-1, n_items, (n_items, k)).astype(np.int64),
            version=version, n_users=n_users, n_items=n_items,
            codebook_sizes=sizes, gate_metrics=(("recall_ratio", 0.9),))

    def hold(site, occurrence, sentinel):
        return FaultInjector(FaultPlan(
            0, [FaultSpec(site, "delay", occurrences=(occurrence,),
                          delay_s=300.0)],
            on_inject=lambda rec: open(sentinel, "w").write("hit")))
""")

MID_PUBLISH = SNAP_HELPER + textwrap.dedent("""
    d, sentinel = sys.argv[1], sys.argv[2]
    inj = FaultInjector()
    store = SnapshotStore(d, faults=inj)
    store.publish(snap(1))                    # good version on disk
    # stall the *second* publish between manifest write and rename
    inj.install(hold("snapshot.finalize", 0, sentinel).plan)
    store.publish(snap(2))                    # parent kills us in here
    print("UNREACHABLE", flush=True)
""")

MID_SWAP = SNAP_HELPER + textwrap.dedent("""
    d, sentinel = sys.argv[1], sys.argv[2]
    store = SnapshotStore(d)
    store.publish(snap(1))
    store.publish(snap(2))
    from repro.lifecycle.swap import SwapServer
    server = SwapServer(store.load(1), faults=hold("swap.flip", 0,
                                                   sentinel))
    server.swap_to(store.load(2), 0.0)        # parent kills us mid-flip
    print("UNREACHABLE", flush=True)
""")


def _kill_in_window(script, tmp_path, timeout=120.0):
    sentinel = str(tmp_path / "in_window")
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    proc = subprocess.Popen(
        [sys.executable, "-c", script, str(tmp_path / "store"), sentinel],
        env=env, cwd=_REPO, stdout=subprocess.PIPE,
        stderr=subprocess.PIPE, text=True)
    deadline = time.monotonic() + timeout
    try:
        while not os.path.exists(sentinel):
            if proc.poll() is not None:
                raise AssertionError(
                    "child exited before the fault window:\n"
                    + proc.communicate()[1][-2000:])
            if time.monotonic() > deadline:
                raise AssertionError("child never reached the window")
            time.sleep(0.02)
    finally:
        proc.kill()                           # SIGKILL, not terminate
    proc.wait()
    out, _ = proc.communicate()
    assert "UNREACHABLE" not in out           # died inside the window
    assert proc.returncode == -9
    return str(tmp_path / "store")


def test_sigkill_mid_publish_store_reopens_clean(tmp_path):
    d = _kill_in_window(MID_PUBLISH, tmp_path)
    # the torn v2 is a .tmp partial: invisible, then swept on reopen
    assert "step_2.tmp" in os.listdir(d)
    store = SnapshotStore(d)
    assert "step_2.tmp" not in os.listdir(d)
    assert store.versions() == [1]
    snap = store.load_latest_good()
    assert snap.version == 1
    # serving resumes from the last good version
    server = SwapServer(snap)
    res, ver = server.retrieve_batch(np.arange(8), 0.0, 4)
    assert ver == 1 and res.shape == (8, 4)


def test_sigkill_mid_swap_serving_resumes_from_last_good(tmp_path):
    d = _kill_in_window(MID_SWAP, tmp_path)
    # both publishes completed before the swap: disk is fully intact
    store = SnapshotStore(d)
    assert store.versions() == [1, 2]
    snap = store.load_latest_good()
    assert snap.version == 2
    server = SwapServer(snap)
    res, ver = server.retrieve_batch(np.arange(8), 0.0, 4)
    assert ver == 2 and res.shape == (8, 4)
    # and the store kept no partials from the dead process
    assert not any(n.endswith(".tmp") for n in os.listdir(d))
