"""Core RankGraph-2 components: model, negatives, losses, PPR, serving."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import losses as L
from repro.core import model as M
from repro.core import negatives as N


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def test_embed_shapes_and_norm(tiny_cfg):
    params, specs = M.init_params(jax.random.key(0), tiny_cfg)
    B, K = 6, tiny_cfg.k_train
    key = jax.random.key(1)
    side = dict(
        feat=jax.random.normal(key, (B, tiny_cfg.d_user_feat)),
        unbr_feat=jax.random.normal(key, (B, K, tiny_cfg.d_user_feat)),
        unbr_mask=jnp.ones((B, K)),
        inbr_feat=jax.random.normal(key, (B, K, tiny_cfg.d_item_feat)),
        inbr_mask=jnp.ones((B, K)))
    heads, prim = M.embed_side(params, tiny_cfg, side, M.USER)
    assert heads.shape == (B, tiny_cfg.n_heads, tiny_cfg.d_embed)
    assert prim.shape == (B, tiny_cfg.d_embed)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(prim), axis=1),
                               1.0, atol=1e-4)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(heads), axis=-1), 1.0, atol=1e-4)


def test_padded_neighbors_do_not_affect_embedding(tiny_cfg):
    """Masked (padding) neighbors must not change the output — the
    correctness condition for fixed-shape edge-centric batches."""
    params, _ = M.init_params(jax.random.key(0), tiny_cfg)
    B, K = 4, tiny_cfg.k_train
    key = jax.random.key(2)
    base = dict(
        feat=jax.random.normal(key, (B, tiny_cfg.d_user_feat)),
        unbr_feat=jax.random.normal(key, (B, K, tiny_cfg.d_user_feat)),
        unbr_mask=jnp.ones((B, K)).at[:, -1].set(0.0),
        inbr_feat=jax.random.normal(key, (B, K, tiny_cfg.d_item_feat)),
        inbr_mask=jnp.ones((B, K)))
    _, p1 = M.embed_side(params, tiny_cfg, base, M.USER)
    poisoned = dict(base)
    poisoned["unbr_feat"] = base["unbr_feat"].at[:, -1].set(1e3)
    _, p2 = M.embed_side(params, tiny_cfg, poisoned, M.USER)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-5)


# ---------------------------------------------------------------------------
# negatives
# ---------------------------------------------------------------------------

def test_pool_fifo_and_wraparound():
    pool = N.init_pool(8, 4)
    e1 = jnp.ones((5, 4))
    pool = N.update_pool(pool, e1, e1 * 2)
    assert int(pool.user_fill) == 5 and int(pool.user_ptr) == 5
    pool = N.update_pool(pool, e1 * 3, e1 * 4)
    assert int(pool.user_fill) == 8          # capped
    assert int(pool.user_ptr) == 2           # wrapped
    # newest rows overwrote the oldest
    assert float(pool.user[1, 0]) == 3.0


def test_sample_negatives_shape_and_no_self():
    key = jax.random.key(0)
    B, d, H = 16, 8, 2
    dst = jax.random.normal(key, (B, d))
    heads = jax.random.normal(key, (B, H, d))
    pool = jax.random.normal(key, (32, d))
    negs = N.sample_negatives(key, dst, heads, pool, jnp.int32(32),
                              n_neg=20, n_pool=6)
    assert negs.shape == (B, 20, d)
    # in-batch negatives never equal the positive row itself
    for b in range(B):
        assert not np.any(np.all(np.asarray(negs[b]) ==
                                 np.asarray(dst[b]), axis=-1)[:12])


def test_sample_negatives_empty_pool_fallback():
    key = jax.random.key(1)
    dst = jax.random.normal(key, (8, 4))
    heads = jax.random.normal(key, (8, 2, 4))
    pool = jnp.zeros((16, 4))
    negs = N.sample_negatives(key, dst, heads, pool, jnp.int32(0),
                              n_neg=10, n_pool=4)
    # fallback must not produce zero vectors from the empty pool
    norms = np.linalg.norm(np.asarray(negs), axis=-1)
    assert (norms > 1e-6).all()


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def test_pair_losses_match_manual():
    key = jax.random.key(0)
    from repro.nn.core import l2_normalize
    src = l2_normalize(jax.random.normal(key, (4, 8)))
    dst = l2_normalize(jax.random.normal(jax.random.key(1), (4, 8)))
    negs = l2_normalize(jax.random.normal(jax.random.key(2), (4, 5, 8)))
    marg, info = L.pair_losses(src, dst, negs, margin=0.1, tau=0.06)
    s_pos = np.sum(np.asarray(src) * np.asarray(dst), -1)
    s_neg = np.einsum("bd,bnd->bn", np.asarray(src), np.asarray(negs))
    m_ref = np.maximum(s_neg - s_pos[:, None] + 0.1, 0).sum(-1)
    logits = np.concatenate([s_pos[:, None], s_neg], 1) / 0.06
    i_ref = (np.log(np.exp(logits - logits.max(1, keepdims=True)).sum(1))
             + logits.max(1) - logits[:, 0])
    np.testing.assert_allclose(np.asarray(marg), m_ref, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(info), i_ref, rtol=1e-4)


def test_uncertainty_combine_gradients():
    lv = L.init_uncertainty()
    tasks = {k: jnp.float32(1.0) for k in L.TASKS}
    g = jax.grad(lambda lv: L.uncertainty_combine(tasks, lv))(lv)
    # d/ds [e^-s L + s] at s=0, L=1 -> 0: stationary where weight matches
    for k in L.TASKS:
        np.testing.assert_allclose(float(g[k]), 0.0, atol=1e-6)
    tasks2 = dict(tasks, margin_uu=jnp.float32(5.0))
    g2 = jax.grad(lambda lv: L.uncertainty_combine(tasks2, lv))(lv)
    assert float(g2["margin_uu"]) < 0   # big loss -> raise its variance


@given(st.floats(0.01, 0.5), st.floats(0.01, 1.0))
@settings(max_examples=10, deadline=None)
def test_infonce_bounds_property(tau, margin):
    """InfoNCE >= 0 and increases as positives get worse."""
    key = jax.random.key(42)
    from repro.nn.core import l2_normalize
    src = l2_normalize(jax.random.normal(key, (8, 16)))
    negs = l2_normalize(jax.random.normal(jax.random.key(1), (8, 6, 16)))
    good = src                                   # sim = 1
    bad = l2_normalize(-src + 0.05)
    _, i_good = L.pair_losses(src, good, negs, margin=margin, tau=tau)
    _, i_bad = L.pair_losses(src, bad, negs, margin=margin, tau=tau)
    assert (np.asarray(i_good) >= -1e-5).all()
    assert float(i_bad.mean()) > float(i_good.mean())


# ---------------------------------------------------------------------------
# PPR
# ---------------------------------------------------------------------------

def test_ppr_neighbors_are_reachable(tiny_graph, tiny_tables):
    """PPR neighbors must be within walk-length hops in the backbone."""
    t = tiny_tables
    nu = tiny_graph.n_users
    # user 0's user-neighbors should never be user 0 itself
    for row in range(min(20, nu)):
        nbrs = t.user_nbrs[row]
        assert row not in nbrs[nbrs >= 0]
        assert (nbrs[nbrs >= 0] < nu).all()
        inbrs = t.item_nbrs[row]
        assert (inbrs[inbrs >= 0] >= nu).all()


def test_ppr_numpy_vs_jax_walkers_bit_identical(tiny_graph):
    """Shared uniform stream, same transition kernel: the jax walker's
    visit trace must equal the numpy walker's bit-for-bit."""
    from repro.core import ppr as P
    adj = P.build_padded_hetero_adj(tiny_graph, max_deg_per_type=8)
    starts = np.arange(0, 40, dtype=np.int64)
    vis_np, _ = P.ppr_visit_counts(adj, starts, n_walks=64, walk_len=4,
                                   seed=0, backend="numpy")
    vis_jx, _ = P.ppr_visit_counts(adj, starts, n_walks=64, walk_len=4,
                                   seed=0, backend="jax")
    np.testing.assert_array_equal(vis_np, vis_jx)
    # chunk layout must not change the stream (uniforms key by node id)
    vis_ck, _ = P.ppr_visit_counts(adj, starts, n_walks=64, walk_len=4,
                                   seed=0, backend="numpy", chunk=128)
    np.testing.assert_array_equal(vis_np, vis_ck)


def test_topk_by_count_correctness():
    from repro.core.ppr import topk_by_count
    visited = np.array([[3, 3, 3, 7, 7, 1, 12, 12, 12, 12]])
    starts = np.array([0])
    users, items = topk_by_count(visited, starts, 3, type_boundary=10,
                                 n_users=10)
    assert list(users[0]) == [3, 7, 1]       # by count desc
    assert items[0][0] == 12


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_cluster_queue_recency_and_dedup():
    from repro.core.serving import ClusterQueueStore
    clusters = np.array([0, 0, 1])
    store = ClusterQueueStore(clusters, queue_len=16, recency_s=100.0)
    store.ingest(np.array([0, 1, 0, 2]), np.array([10, 11, 10, 99]),
                 np.array([0.0, 50.0, 60.0, 70.0]))
    got = store.retrieve(0, now=100.0, k=10)
    assert got == [10, 11] or got == [11, 10]
    # recency filter drops stale entries
    got = store.retrieve(0, now=500.0, k=10)
    assert got == []
    # other cluster isolated
    assert store.retrieve(2, now=100.0, k=10) == [99]


def test_i2i_knn_and_u2i2i():
    from repro.core.serving import build_i2i_knn, u2i2i_retrieve
    rng = np.random.default_rng(0)
    emb = rng.normal(size=(30, 8)).astype(np.float32)
    emb[1] = emb[0] + 0.01      # items 0,1 nearly identical
    knn = build_i2i_knn(emb, k=5)
    assert knn.shape == (30, 5)
    assert knn[0][0] == 1 and knn[1][0] == 0
    out = u2i2i_retrieve(knn, [0], k=3)
    assert out[0] == 1 and len(out) == 3


def test_serving_cost_model_matches_paper_magnitude():
    from repro.core.serving import ServingCostModel
    cm = ServingCostModel()
    red = cm.cost_reduction()
    assert red > 0.8            # the paper's 83% regime
    assert cm.knn_flops_per_req() > 1e8
    assert cm.cluster_flops_per_req() < 1e4
