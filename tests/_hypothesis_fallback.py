"""Optional-hypothesis shim.

The property-based tests ride alongside plain pytest tests in the same
modules; importing this instead of ``hypothesis`` directly keeps those
modules collectable without the dependency — property tests skip with a
clear reason, everything else runs.  With hypothesis installed this is
a pure re-export.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover - dep present
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """st.<anything>(...) -> None; only ever fed to the skip mark."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
