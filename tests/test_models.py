"""Per-architecture reduced-config smoke tests (deliverable f) + family
behaviour tests.  Every assigned arch instantiates a *reduced* config of
its family and runs one forward/train step on CPU, asserting shapes and
finiteness; the full configs are exercised via the dry-run only.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (GNNConfig, LMConfig, RecsysConfig,
                                get_arch, list_archs)


def test_registry_has_all_assigned_archs():
    expected = {"olmo-1b", "llama3.2-3b", "gemma-2b", "grok-1-314b",
                "kimi-k2-1t-a32b", "equiformer-v2", "sasrec", "wide-deep",
                "dlrm-rm2", "bst", "rankgraph2"}
    assert expected.issubset(set(list_archs()))
    # 10 assigned x 4 shapes (+ rankgraph2's own 4) = 44 cells
    from repro.launch.steps import all_cells
    assert len(all_cells()) == 44


def _reduced_lm(cfg: LMConfig) -> LMConfig:
    n_exp = min(cfg.n_experts, 4)
    return dc.replace(
        cfg, n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 4), head_dim=16,
        d_ff=128, moe_d_ff=128 if cfg.n_experts else None,
        n_experts=n_exp,
        n_experts_per_tok=min(cfg.n_experts_per_tok, max(n_exp, 1)),
        vocab_size=128, dtype="float32", param_dtype="float32")


@pytest.mark.parametrize("arch_id", ["olmo-1b", "llama3.2-3b", "gemma-2b",
                                     "grok-1-314b", "kimi-k2-1t-a32b"])
def test_lm_arch_smoke(arch_id):
    from repro.models.lm import model as LM
    cfg = _reduced_lm(get_arch(arch_id).config)
    params, specs = LM.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    loss = LM.lm_loss(params, cfg, toks, block_q=8)
    assert np.isfinite(float(loss)) and float(loss) > 0
    logits, _ = LM.forward(params, cfg, toks)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # one SGD step moves the loss
    g = jax.grad(lambda p: LM.lm_loss(p, cfg, toks, block_q=8))(params)
    p2 = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    assert float(LM.lm_loss(p2, cfg, toks, block_q=8)) != float(loss)


def test_lm_full_configs_param_counts():
    # sanity: configured sizes land near the published scales
    assert abs(get_arch("olmo-1b").config.n_params() / 1.3e9 - 1) < 0.35
    assert abs(get_arch("llama3.2-3b").config.n_params() / 3.2e9 - 1) < 0.4
    assert abs(get_arch("gemma-2b").config.n_params() / 2.5e9 - 1) < 0.4
    assert abs(get_arch("grok-1-314b").config.n_params() / 314e9 - 1) < 0.25
    k = get_arch("kimi-k2-1t-a32b").config
    assert abs(k.n_params() / 1.0e12 - 1) < 0.3
    assert abs(k.n_active_params() / 32e9 - 1) < 0.7


def test_gemma_mqa_and_headdim():
    cfg = get_arch("gemma-2b").config
    assert cfg.n_kv_heads == 1 and cfg.resolved_head_dim == 256


@pytest.mark.parametrize("arch_id", ["sasrec", "wide-deep", "dlrm-rm2",
                                     "bst"])
def test_recsys_arch_smoke(arch_id):
    from repro.models.recsys import models as R
    cfg = dc.replace(get_arch(arch_id).config, default_vocab=200,
                     dtype="float32", param_dtype="float32")
    key = jax.random.key(0)
    B = 8
    if cfg.kind == "dlrm":
        p, _ = R.dlrm_init(key, cfg)
        out = R.dlrm_forward(p, cfg, jax.random.normal(key, (B, cfg.n_dense)),
                             jax.random.randint(key, (B, cfg.n_sparse), 0,
                                                200))
    elif cfg.kind == "wide_deep":
        p, _ = R.wide_deep_init(key, cfg)
        out = R.wide_deep_forward(p, cfg, None,
                                  jax.random.randint(key, (B, cfg.n_sparse),
                                                     0, 200))
    elif cfg.kind == "sasrec":
        p, _ = R.sasrec_init(key, cfg)
        u = R.sasrec_user_repr(p, cfg, jax.random.randint(
            key, (B, cfg.seq_len), -1, 200))
        out = R.sasrec_scores(p, cfg, u, jnp.arange(50))
        assert out.shape == (B, 50)
        out = out[:, 0]
    else:
        p, _ = R.bst_init(key, cfg)
        out = R.bst_forward(p, cfg,
                            jax.random.randint(key, (B, cfg.seq_len), -1,
                                               200),
                            jnp.arange(B),
                            jax.random.randint(key, (B, cfg.n_sparse), 0,
                                               200))
    assert out.shape == (B,)
    assert np.isfinite(np.asarray(out, np.float32)).all()


def test_recsys_train_step_decreases_bce():
    from repro.models.recsys import models as R
    from repro.optim.optimizers import adamw, apply_updates
    cfg = dc.replace(get_arch("dlrm-rm2").config, default_vocab=100,
                     embed_dim=16, bot_mlp=(32, 16), top_mlp=(32, 1),
                     dtype="float32", param_dtype="float32")
    p, _ = R.dlrm_init(jax.random.key(0), cfg)
    dense = jax.random.normal(jax.random.key(1), (64, cfg.n_dense))
    ids = jax.random.randint(jax.random.key(2), (64, cfg.n_sparse), 0, 100)
    labels = (jax.random.uniform(jax.random.key(3), (64,)) > 0.5
              ).astype(jnp.float32)
    opt = adamw(1e-2, weight_decay=0.0)
    st = opt.init(p)
    loss = lambda pp: R.bce_loss(R.dlrm_forward(pp, cfg, dense, ids), labels)
    l0 = float(loss(p))
    for _ in range(20):
        g = jax.grad(loss)(p)
        upd, st = opt.update(g, st, p)
        p = apply_updates(p, upd)
    assert float(loss(p)) < l0


def test_equiformer_smoke_and_equivariance():
    from repro.models.gnn import equiformer as EQ
    cfg = GNNConfig(n_layers=2, d_hidden=16, l_max=2, m_max=2, n_heads=4,
                    n_radial=4, edge_chunk=64, dtype="float32",
                    param_dtype="float32", remat=False)
    rng = np.random.default_rng(0)
    N, E, DF = 16, 40, 6
    params, _ = EQ.init_params(jax.random.key(0), cfg, DF)
    feats = jnp.asarray(rng.normal(size=(N, DF)).astype(np.float32))
    pos = jnp.asarray(rng.normal(size=(N, 3)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, N, E))
    dst = jnp.asarray(rng.integers(0, N, E))
    out = EQ.forward(params, cfg, feats, src, dst, pos)
    assert out.shape == (N, 1)
    assert np.isfinite(np.asarray(out)).all()
    A = rng.normal(size=(3, 3))
    Q, _ = np.linalg.qr(A)
    Q[:, 0] *= np.sign(np.linalg.det(Q))
    a, b = EQ.equivariance_check(params, cfg, feats, src, dst, pos,
                                 jnp.asarray(Q, jnp.float32))
    rel = float(jnp.abs(a - b).max()) / (float(jnp.abs(a).max()) + 1e-9)
    assert rel < 1e-3


def test_equiformer_grad_and_loss():
    from repro.models.gnn import equiformer as EQ
    cfg = GNNConfig(n_layers=1, d_hidden=8, l_max=1, m_max=1, n_heads=2,
                    n_radial=4, edge_chunk=32, dtype="float32",
                    param_dtype="float32", remat=True)
    rng = np.random.default_rng(1)
    N, E, DF = 12, 30, 4
    params, _ = EQ.init_params(jax.random.key(0), cfg, DF)
    args = (jnp.asarray(rng.normal(size=(N, DF)).astype(np.float32)),
            jnp.asarray(rng.integers(0, N, E)),
            jnp.asarray(rng.integers(0, N, E)),
            jnp.asarray(rng.normal(size=(N, 3)).astype(np.float32)),
            jnp.ones(N))
    g = jax.grad(lambda p: EQ.node_mse_loss(p, cfg, *args))(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_neighbor_sampler_shapes_and_masks():
    from repro.models.gnn.sampler import (CSRGraph, make_random_graph,
                                          sample_two_hop)
    src, dst = make_random_graph(500, 3000, seed=0)
    g = CSRGraph.from_edges(src, dst, 500)
    sub = sample_two_hop(g, np.arange(32), 5, 3)
    assert sub.node_ids.shape == (32 + 160 + 480,)
    assert sub.src.shape == sub.dst.shape == sub.edge_mask.shape
    # masked edges only point at valid local slots
    assert sub.src.max() < len(sub.node_ids)
    # sampled neighbors are real neighbors
    for i in range(10):
        if sub.edge_mask[i]:
            seed_gid = sub.node_ids[sub.dst[i]]
            nbr_gid = sub.node_ids[sub.src[i]]
            lo, hi = g.indptr[seed_gid], g.indptr[seed_gid + 1]
            assert nbr_gid in g.indices[lo:hi]


def test_moe_paths_agree(tmp_path):
    """dense vs scatter MoE agree when capacity doesn't drop."""
    from repro.models.lm import model as LM
    from repro.distributed.sharding import ShardingCtx
    cfg = LMConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                   d_ff=64, moe_d_ff=64, vocab_size=50, n_experts=4,
                   n_experts_per_tok=2, capacity_factor=8.0,
                   dtype="float32", param_dtype="float32")
    params, _ = LM.init_params(jax.random.key(0), cfg)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    o1, _ = LM._moe_scatter(lp, cfg, x, ShardingCtx())
    o2, _ = LM._moe_dense(lp, cfg, x, ShardingCtx())
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4,
                               atol=2e-5)
