"""Optimizers + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizers as O
from repro.distributed import compression as C


def _quadratic_converges(opt, steps=200, tol=1e-2):
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    target = jnp.array([1.0, 1.0, 1.0])
    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        upd, state = opt.update(g, state, params)
        params = O.apply_updates(params, upd)
    return float(jnp.max(jnp.abs(params["w"] - target))) < tol


@pytest.mark.parametrize("name", ["sgd", "adagrad", "adamw", "adafactor"])
def test_optimizers_converge_on_quadratic(name):
    lr = {"sgd": 0.1, "adagrad": 0.5, "adamw": 0.1, "adafactor": 0.3}[name]
    opt = O.make_optimizer(name, lr)
    if name == "adamw":
        opt = O.adamw(lr, weight_decay=0.0)
    # adafactor's relative-update clipping makes it deliberately slower
    steps, tol = (600, 5e-2) if name == "adafactor" else (200, 1e-2)
    assert _quadratic_converges(opt, steps=steps, tol=tol)


def test_adamw_matches_reference_step():
    opt = O.adamw(0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    state = opt.init(p)
    upd, _ = opt.update(g, state, p)
    # first step: mhat = g, vhat = g^2  => step = -lr * g/(|g|+eps)
    expect = -0.1 * np.array([0.5, -1.0]) / (np.abs([0.5, -1.0]) + 1e-8)
    np.testing.assert_allclose(np.asarray(upd["w"]), expect, rtol=1e-5)


def test_adafactor_state_is_factored():
    opt = O.adafactor(0.01)
    p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((7,))}
    st = opt.init(p)
    assert st.vr["w"].shape == (64,)
    assert st.vc["w"].shape == (32,)
    assert st.vr["b"].shape == (7,)      # <2D keeps full stats


def test_partition_routes_by_path():
    calls = {"t": 0, "f": 0}

    def spy(opt, tag):
        def update(g, s, p):
            calls[tag] += 1
            return opt.update(g, s, p)
        return O.Optimizer(opt.init, update)

    opt = O.partition(lambda path, leaf: "table" in str(path),
                      spy(O.adagrad(0.1), "t"), spy(O.adamw(0.1), "f"))
    p = {"table": jnp.ones((4, 2)), "dense": jnp.ones((3,))}
    g = jax.tree.map(jnp.ones_like, p)
    st = opt.init(p)
    upd, st = opt.update(g, st, p)
    assert calls == {"t": 1, "f": 1}
    # adagrad step on table: -0.1 * 1/sqrt(1) = -0.1
    np.testing.assert_allclose(np.asarray(upd["table"]), -0.1, rtol=1e-5)


def test_rankgraph2_optimizer_splits_sparse_dense():
    opt = O.rankgraph2_optimizer()
    p = {"rq": {"codebooks": {"layer0": jnp.ones((4, 2))}},
         "enc": {"w": jnp.ones((3, 3))}}
    g = jax.tree.map(jnp.ones_like, p)
    st = opt.init(p)
    upd, _ = opt.update(g, st, p)
    # codebooks routed to adagrad (lr .02): step -0.02; dense adamw -0.004
    np.testing.assert_allclose(np.asarray(upd["rq"]["codebooks"]["layer0"]),
                               -0.02, rtol=1e-4)
    assert abs(float(upd["enc"]["w"][0, 0]) + 0.004) < 2e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0)}
    clipped, norm = O.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 6.0, rtol=1e-6)
    np.testing.assert_allclose(
        float(O.global_norm(clipped)), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound():
    key = jax.random.key(0)
    x = jax.random.normal(key, (256,)) * 5
    y = C.int8_roundtrip(x)
    assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(jnp.abs(x))) / 127


def test_error_feedback_preserves_convergence():
    base = O.sgd(0.2)
    comp = C.compressed(base, scheme="int8")
    params = {"w": jnp.array([4.0, -3.0])}
    state = comp.init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
        upd, state = comp.update(g, state, params)
        params = O.apply_updates(params, upd)
    np.testing.assert_allclose(np.asarray(params["w"]), 1.0, atol=1e-2)


def test_powersgd_low_rank_and_ratio():
    key = jax.random.key(1)
    x = jax.random.normal(key, (32, 16))
    y = C.powersgd_roundtrip(x, rank=4, key=jax.random.key(2))
    assert y.shape == x.shape
    assert int(np.linalg.matrix_rank(np.asarray(y), tol=1e-4)) <= 4
    ratio = C.compression_ratio({"w": x}, "powersgd", rank=4)
    assert ratio < 0.5


def test_compression_ratio_int8():
    ratio = C.compression_ratio({"w": jnp.zeros((1000, 1000))}, "int8")
    assert 0.24 < ratio < 0.26
