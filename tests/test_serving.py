"""Batched serving engine: store semantics, batched-vs-legacy-loop
equivalence, I2I KNN construction, the fused Pallas queue_gather kernel
vs its oracle, and the production cost model."""
from collections import deque

import numpy as np
import pytest

from repro.core.serving import (ClusterQueueStore, ServingCostModel,
                                build_i2i_knn, dedup_topk_rows,
                                u2i2i_retrieve, u2i2i_retrieve_batch)


# ---------------------------------------------------------------------------
# legacy (seed) per-request implementations — the equivalence reference
# ---------------------------------------------------------------------------

class _LegacyDequeStore:
    """The seed implementation: dict of per-cluster deques, scanned
    newest-first per request with a Python set for dedup."""

    def __init__(self, user_clusters, queue_len, recency_s):
        self.user_clusters = user_clusters
        self.queue_len = queue_len
        self.recency_s = recency_s
        self.queues = {}

    def ingest(self, user_ids, item_ids, timestamps):
        cl = self.user_clusters[user_ids]
        order = np.argsort(timestamps, kind="stable")
        for c, it, ts in zip(cl[order], item_ids[order], timestamps[order]):
            q = self.queues.setdefault(int(c), deque(maxlen=self.queue_len))
            q.append((float(ts), int(it)))

    def retrieve(self, user_id, now, k):
        q = self.queues.get(int(self.user_clusters[user_id]))
        if not q:
            return []
        cutoff = now - self.recency_s
        out, seen = [], set()
        for ts, it in reversed(q):
            if ts < cutoff:
                break
            if it not in seen:
                seen.add(it)
                out.append(it)
            if len(out) >= k:
                break
        return out


def _legacy_u2i2i(i2i, recent_items, k):
    out = []
    seen = set(int(i) for i in recent_items)
    for rank in range(i2i.shape[1]):
        for it in recent_items:
            cand = int(i2i[int(it), rank])
            if cand >= 0 and cand not in seen:
                seen.add(cand)
                out.append(cand)
                if len(out) >= k:
                    return out
    return out


def _row_list(row):
    return [int(i) for i in row if i >= 0]


# ---------------------------------------------------------------------------
# ClusterQueueStore semantics
# ---------------------------------------------------------------------------

def test_recency_cutoff_and_dedup():
    store = ClusterQueueStore(np.array([0, 0, 1]), queue_len=16,
                              recency_s=100.0)
    store.ingest(np.array([0, 1, 0, 2]), np.array([10, 11, 10, 99]),
                 np.array([0.0, 50.0, 60.0, 70.0]))
    assert store.retrieve(0, now=100.0, k=10) == [10, 11]  # newest first
    assert store.retrieve(0, now=500.0, k=10) == []        # all stale
    assert store.retrieve(2, now=100.0, k=10) == [99]      # isolation
    assert store.retrieve(0, now=100.0, k=1) == [10]       # k cap


def test_eviction_ring_wrap():
    store = ClusterQueueStore(np.array([0]), queue_len=4, recency_s=1e9)
    store.ingest(np.zeros(10, int), np.arange(10),
                 np.arange(10, dtype=float))
    # only the last queue_len events survive, newest first
    assert store.retrieve(0, 10.0, 10) == [9, 8, 7, 6]
    # a second ingest keeps wrapping
    store.ingest(np.zeros(2, int), np.array([20, 21]),
                 np.array([11.0, 12.0]))
    assert store.retrieve(0, 12.0, 10) == [21, 20, 9, 8]


def test_stats_and_empty_clusters():
    store = ClusterQueueStore(np.array([0, 5]), queue_len=8,
                              recency_s=10.0, n_clusters=7)
    assert store.retrieve(1, 0.0, 4) == []                 # never ingested
    store.ingest(np.array([0]), np.array([3]), np.array([1.0]))
    s = store.stats()
    assert s["n_clusters_active"] == 1 and s["mean_queue"] == 1.0


def test_epoch_relative_times_survive_unix_scale():
    """Absolute unix timestamps must not lose recency resolution to the
    float32 queue storage."""
    t0 = 1.7e9
    store = ClusterQueueStore(np.array([0, 0]), queue_len=8, recency_s=5.0)
    store.ingest(np.array([0, 1]), np.array([1, 2]),
                 np.array([t0, t0 + 4.0]))
    assert store.retrieve(0, now=t0 + 6.0, k=4) == [2]     # 1 is 6s stale
    assert store.retrieve(0, now=t0 + 4.5, k=4) == [2, 1]


def test_batched_retrieve_matches_legacy_loop():
    rng = np.random.default_rng(0)
    n_users, n_items, C = 300, 400, 24
    clusters = rng.integers(0, C, n_users)
    store = ClusterQueueStore(clusters, queue_len=32, recency_s=300.0)
    legacy = _LegacyDequeStore(clusters, queue_len=32, recency_s=300.0)
    ev = (rng.integers(0, n_users, 4000), rng.integers(0, n_items, 4000),
          rng.integers(0, 1000, 4000).astype(float))
    store.ingest(*ev)
    legacy.ingest(*ev)
    for now in (400.0, 900.0, 1500.0):
        users = rng.integers(0, n_users, 256)
        batched = store.retrieve_batch(users, now, 16)
        for row, u in zip(batched, users):
            assert _row_list(row) == legacy.retrieve(int(u), now, 16), \
                (now, int(u))


def test_batched_u2i2i_matches_legacy_loop():
    rng = np.random.default_rng(1)
    n_items = 200
    i2i = rng.integers(-1, n_items, (n_items, 10))
    recent = np.where(rng.random((64, 6)) < 0.2, -1,
                      rng.integers(0, n_items, (64, 6)))
    batched = u2i2i_retrieve_batch(i2i, recent, 20)
    for row, rec in zip(batched, recent):
        assert _row_list(row) == _legacy_u2i2i(i2i, _row_list(rec), 20)
    # single-request wrapper == legacy loop too
    for rec in recent[:8]:
        assert (u2i2i_retrieve(i2i, _row_list(rec), 20)
                == _legacy_u2i2i(i2i, _row_list(rec), 20))


def test_u2i2i_round_robin_order_and_padding():
    # seeds 0 and 1; rank-0 of both come before rank-1 of either
    i2i = np.array([[10, 11], [20, 21], [30, 31]])
    out = u2i2i_retrieve_batch(i2i, np.array([[0, 1]]), 6)[0]
    assert out.tolist() == [10, 20, 11, 21, -1, -1]
    # -1 pads in both the seed list and the table are skipped
    i2i2 = np.array([[10, -1], [20, 21], [30, 31]])
    out = u2i2i_retrieve_batch(i2i2, np.array([[0, -1, 1]]), 6)[0]
    assert out.tolist() == [10, 20, 21, -1, -1, -1]
    # seeds themselves are masked out of the union
    i2i3 = np.array([[1, 11], [0, 21], [30, 31]])
    out = u2i2i_retrieve_batch(i2i3, np.array([[0, 1]]), 4)[0]
    assert out.tolist() == [11, 21, -1, -1]


def test_u2i2i_seed_beyond_i2i_table_is_skipped():
    """Queues can hold items newer than the last offline I2I refresh;
    those seeds must contribute no neighbors (and not crash) on every
    path — batched numpy, kernel, and oracle."""
    from repro.kernels.queue_gather.ops import queue_gather
    from repro.kernels.queue_gather.ref import queue_gather_ref
    i2i = np.array([[1, 2], [0, 2], [0, 1]])           # covers items 0..2
    out = u2i2i_retrieve_batch(i2i, np.array([[7, 0]]), 4)[0]
    assert out.tolist() == [1, 2, -1, -1]              # seed 7 skipped
    # an uncovered seed is still masked when the table emits its id
    out = u2i2i_retrieve_batch(np.array([[7, 1], [0, 2], [0, 1]]),
                               np.array([[0, 7]]), 4)[0]
    assert out.tolist() == [1, -1, -1, -1]             # 7 is a seed: masked
    store = ClusterQueueStore(np.array([0]), queue_len=4, recency_s=1e9)
    store.ingest(np.zeros(2, int), np.array([7, 0]), np.array([0.0, 1.0]))
    s_k, u_k = store.serve_batch(np.array([0]), 1.0, n_recent=4, k=4,
                                 i2i=i2i, use_kernel=True)
    s_r, u_r = queue_gather_ref(store.items, store.times, store.cursor,
                                np.array([0]), i2i,
                                cutoff=store.rel_cutoff(1.0),
                                n_recent=4, k=4)
    assert s_k[0].tolist() == [0, 7, -1, -1] == s_r[0].tolist()
    assert u_k[0].tolist() == [1, 2, -1, -1] == u_r[0].tolist()


def test_dedup_topk_rows_direct():
    cand = np.array([[7, 5, 7, 5, 9]])
    prio = np.array([[4, 1, 0, 3, 2]], np.int32)
    valid = np.array([[True, True, True, True, False]])
    out = dedup_topk_rows(cand, prio, valid, 3, 5)
    assert out.tolist() == [[7, 5, -1]]        # 7@0 beats 7@4, 5@1 beats 5@3


# ---------------------------------------------------------------------------
# I2I KNN construction
# ---------------------------------------------------------------------------

def test_i2i_knn_self_exclusion_and_neighbors():
    rng = np.random.default_rng(0)
    emb = rng.normal(size=(30, 8)).astype(np.float32)
    emb[1] = emb[0] + 0.01
    knn = build_i2i_knn(emb, k=5)
    assert knn.shape == (30, 5)
    assert knn[0][0] == 1 and knn[1][0] == 0
    assert all(i not in knn[i] for i in range(30))


def test_i2i_knn_padding_when_k_exceeds_items():
    rng = np.random.default_rng(2)
    emb = rng.normal(size=(4, 8)).astype(np.float32)
    knn = build_i2i_knn(emb, k=6)
    assert knn.shape == (4, 6)
    assert (knn[:, 3:] == -1).all()            # only n-1=3 real neighbors
    assert (knn[:, :3] >= 0).all()


def test_i2i_knn_tiny_corpora():
    """n in {1, 2, k+1}: a 1-item corpus has no neighbors at all (the
    old code fed ``top_k(..., 0)`` and crashed), a 2-item corpus has
    exactly one, and n = k+1 fills every column."""
    rng = np.random.default_rng(4)
    k = 5
    knn1 = build_i2i_knn(rng.normal(size=(1, 8)).astype(np.float32), k=k)
    assert knn1.shape == (1, k) and (knn1 == -1).all()
    knn2 = build_i2i_knn(rng.normal(size=(2, 8)).astype(np.float32), k=k)
    assert knn2.shape == (2, k)
    assert knn2[:, 0].tolist() == [1, 0]       # each other's only neighbor
    assert (knn2[:, 1:] == -1).all()
    knn6 = build_i2i_knn(rng.normal(size=(k + 1, 8)).astype(np.float32),
                         k=k)
    assert knn6.shape == (k + 1, k) and (knn6 >= 0).all()
    assert all(i not in knn6[i] for i in range(k + 1))
    # the empty corpus keeps its shape contract too
    knn0 = build_i2i_knn(np.zeros((0, 8), np.float32), k=k)
    assert knn0.shape == (0, k)


def test_i2i_knn_chunking_invariant():
    rng = np.random.default_rng(3)
    emb = rng.normal(size=(100, 16)).astype(np.float32)
    np.testing.assert_array_equal(build_i2i_knn(emb, k=8, chunk=7),
                                  build_i2i_knn(emb, k=8, chunk=100))


# ---------------------------------------------------------------------------
# Pallas queue_gather kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,Q,R,k", [(0, 16, 4, 8), (1, 32, 8, 24),
                                        (2, 8, 3, 40), (3, 64, 1, 4)])
def test_queue_gather_kernel_matches_oracle(seed, Q, R, k):
    from repro.kernels.queue_gather.ops import queue_gather
    from repro.kernels.queue_gather.ref import queue_gather_ref
    rng = np.random.default_rng(seed)
    C, n_users, n_items = 12, 150, 250
    store = ClusterQueueStore(rng.integers(0, C, n_users), queue_len=Q,
                              recency_s=float(rng.integers(100, 1500)))
    for _ in range(2):
        n_ev = int(rng.integers(50, 3000))
        store.ingest(rng.integers(0, n_users, n_ev),
                     rng.integers(0, n_items, n_ev),
                     rng.integers(0, 1000, n_ev).astype(float))
    i2i = rng.integers(-1, n_items, (n_items, int(rng.integers(2, 10))))
    cl = store.user_clusters[rng.integers(0, n_users, 48)]
    cutoff = store.rel_cutoff(1000.0)
    s_k, u_k = queue_gather(store.items, store.times, store.cursor, cl,
                            i2i, cutoff=cutoff, n_recent=R, k=k)
    s_r, u_r = queue_gather_ref(store.items, store.times, store.cursor,
                                cl, i2i, cutoff=cutoff, n_recent=R, k=k)
    np.testing.assert_array_equal(np.asarray(s_k), s_r)
    np.testing.assert_array_equal(np.asarray(u_k), u_r)


def test_serve_batch_kernel_and_numpy_paths_agree():
    rng = np.random.default_rng(7)
    store = ClusterQueueStore(rng.integers(0, 20, 200), queue_len=32,
                              recency_s=500.0)
    store.ingest(rng.integers(0, 200, 5000), rng.integers(0, 300, 5000),
                 rng.integers(0, 1000, 5000).astype(float))
    emb = rng.normal(size=(300, 16)).astype(np.float32)
    i2i = build_i2i_knn(emb, k=8)
    users = rng.integers(0, 200, 64)
    s_np, u_np = store.serve_batch(users, 1000.0, n_recent=6, k=24, i2i=i2i)
    s_k, u_k = store.serve_batch(users, 1000.0, n_recent=6, k=24, i2i=i2i,
                                 use_kernel=True)
    np.testing.assert_array_equal(s_np, s_k)
    np.testing.assert_array_equal(u_np, u_k)
    # seeds row == retrieve_batch row; union row == u2i2i of those seeds
    np.testing.assert_array_equal(s_np,
                                  store.retrieve_batch(users, 1000.0, 6))
    np.testing.assert_array_equal(u_np, u2i2i_retrieve_batch(i2i, s_np, 24))


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_cost_model_hits_paper_claim_at_scale():
    cm = ServingCostModel()
    assert cm.cost_reduction() >= 0.83         # the paper's 83% regime
    assert cm.knn_flops_per_req() > 1e8
    assert cm.cluster_flops_per_req() < 1e6


def test_cost_model_batch_amortization():
    cm = ServingCostModel()
    b1 = cm.cluster_bytes_per_req(1)
    b1024 = cm.cluster_bytes_per_req(1024)
    assert b1024 < b1                           # launch cost amortizes
    assert b1024 >= 8.0 * cm.queue_read_items   # per-request floor stays
    assert cm.cost_reduction(1024) > cm.cost_reduction(1)
    assert cm.cluster_flops_per_req(1024) < cm.cluster_flops_per_req(1)
    # dataclass default batch_size is used when no override is given
    assert (ServingCostModel(batch_size=1024).cost_reduction()
            == cm.cost_reduction(1024))
