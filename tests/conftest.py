import os

# tests run single-device (the dry-run alone uses 512 host devices);
# keep CPU determinism and silence accidental x64 drift.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def tiny_world():
    from repro.data.synthetic import make_world
    return make_world(n_users=300, n_items=400, events_per_user=25.0,
                      seed=0)


@pytest.fixture(scope="session")
def tiny_graph(tiny_world):
    from repro.core.graph_builder import build_graph
    return build_graph(tiny_world.day0, k_cap=16, hub_cap=12)


@pytest.fixture(scope="session")
def tiny_tables(tiny_graph):
    from repro.data.edge_dataset import build_neighbor_tables
    return build_neighbor_tables(tiny_graph, k_imp=10, n_walks=12,
                                 walk_len=3)


@pytest.fixture(scope="session")
def tiny_cfg():
    from repro.configs.base import RankGraph2Config, RQConfig
    return RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=24, n_heads=2, d_hidden=48,
        k_imp=10, k_train=4, n_negatives=16, n_pool_neg=4,
        rq=RQConfig(codebook_sizes=(16, 8), hist_len=20), dtype="float32")


@pytest.fixture(scope="session")
def tiny_dataset(tiny_world, tiny_graph, tiny_tables, tiny_cfg):
    from repro.data.edge_dataset import EdgeDataset
    return EdgeDataset(tiny_graph, tiny_tables, tiny_world.user_feat,
                       tiny_world.item_feat, k_train=tiny_cfg.k_train)
