"""Failure / straggler / elastic simulations for the runtime layer."""
import numpy as np
import pytest

from repro.distributed.runtime import (ElasticPlan, HeartbeatMonitor,
                                       StragglerTracker,
                                       recovery_cost_model)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_detects_dead_host():
    clock = FakeClock()
    mon = HeartbeatMonitor(["h0", "h1"], deadline_s=10.0, clock=clock)
    for step in range(3):
        clock.t += 2.0
        mon.beat("h0", step)
        mon.beat("h1", step)
    assert mon.healthy()
    # h1 dies
    for step in range(3, 8):
        clock.t += 3.0
        mon.beat("h0", step)
    assert mon.suspects() == ["h1"]


def test_straggler_tracker_flags_slow_host():
    clock = FakeClock()
    mon = HeartbeatMonitor([f"h{i}" for i in range(4)], clock=clock)
    for step in range(10):
        for i in range(4):
            clock.t += 0.0
            mon.beat(f"h{i}", step)
        clock.t += 1.0          # h3 beats 1s later each step
        mon.beat("h3", step)
    # rebuild with controlled timings instead: simulate ewma directly
    mon.hosts["h0"].ewma_step_s = 1.0
    mon.hosts["h1"].ewma_step_s = 1.1
    mon.hosts["h2"].ewma_step_s = 0.9
    mon.hosts["h3"].ewma_step_s = 2.5
    st = StragglerTracker(mon, tolerance=1.5)
    assert st.stragglers() == ["h3"]


def test_elastic_plan_shapes():
    p = ElasticPlan.plan(512, model_axis=16)
    assert p.mesh_shape() == (32, 16)
    p = ElasticPlan.plan(256, model_axis=16)
    assert p.mesh_shape() == (16, 16)
    # capacity loss: 192 chips -> model axis still divides
    p = ElasticPlan.plan(192, model_axis=16)
    assert p.mesh_shape() == (12, 16)
    # awkward count degrades the model axis rather than failing
    p = ElasticPlan.plan(24, model_axis=16)
    assert p.model_axis in (8, 4, 2, 1)
    with pytest.raises(ValueError):
        ElasticPlan.plan(8, model_axis=16, min_data=2)


def test_recovery_cost_model_monotonic():
    a = recovery_cost_model(100, 1.0, 60.0, mtbf_hours=1000.0,
                            n_hosts=1000)
    b = recovery_cost_model(1000, 1.0, 60.0, mtbf_hours=1000.0,
                            n_hosts=1000)
    assert b["expected_lost_frac"] > a["expected_lost_frac"]
    assert a["failures_per_hour"] == 1.0
