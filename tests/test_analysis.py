"""Tests for the repro.analysis static checker.

Each rule gets a seeded true-positive fixture (the finding must land at
the exact file:line) and a clean negative; plus suppression semantics,
the JSON output schema, the VMEM report, and the integration bar: the
repo's own ``src/`` tree is clean.
"""
import json
import os
import textwrap

import numpy as np

from repro.analysis import (active, analyze_file, default_rules,
                            format_json, run_analysis, rules_by_name)
from repro.analysis.rules.determinism import DeterminismRule
from repro.analysis.rules.donation import DonationSafetyRule
from repro.analysis.rules.lock_discipline import LockDisciplineRule
from repro.analysis.rules.vmem_budget import VmemBudgetRule

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _write(tmp_path, rel, source):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return str(path)


def _line_of(path, needle):
    with open(path) as f:
        for i, line in enumerate(f, start=1):
            if needle in line:
                return i
    raise AssertionError(f"{needle!r} not in {path}")


def _findings(path, rule):
    return [f for f in analyze_file(path, [rule])
            if f.rule == rule.name]


# ---------------------------------------------------------------- lock


STORE_HEADER = """\
    import threading

    class Store:
        def __init__(self, n):
            self.items = [0] * n
            self.times = [0.0] * n
            self.cursor = [0] * n
            self.gen = [0] * n
            self.write_lock = threading.RLock()
"""


def test_lock_discipline_flags_unlocked_write(tmp_path):
    path = _write(tmp_path, "repro/core/store.py", STORE_HEADER + """\

        def bad(self, i, v):
            self.items[i] = v
""")
    found = _findings(path, LockDisciplineRule())
    assert len(found) == 1
    assert found[0].line == _line_of(path, "self.items[i] = v")
    assert "write_lock" in found[0].message


def test_lock_discipline_clean_when_locked_and_bracketed(tmp_path):
    path = _write(tmp_path, "repro/core/store.py", STORE_HEADER + """\

        def good(self, i, v, t):
            with self.write_lock:
                self.gen[i] += 1
                self.items[i] = v
                self.times[i] = t
                self.gen[i] += 1
                self.cursor[i] += 1
""")
    assert _findings(path, LockDisciplineRule()) == []


def test_lock_discipline_flags_missing_gen_bracket(tmp_path):
    path = _write(tmp_path, "repro/core/store.py", STORE_HEADER + """\

        def torn(self, i, v):
            with self.write_lock:
                self.items[i] = v
""")
    found = _findings(path, LockDisciplineRule())
    assert len(found) == 1
    assert "generation bump" in found[0].message


DEVICE_STORE_HEADER = """\
    import threading

    class DeviceStore:
        def __init__(self, n):
            self._state = dict(items=[0] * n, total=[0] * n)
            self._cursor_host = [0] * n
            self.epoch = None
            self.ring_seen = 0
            self.d_count = 0
            self.write_lock = threading.RLock()
"""


def test_lock_discipline_flags_unlocked_state_rebind(tmp_path):
    path = _write(tmp_path, "repro/core/dstore.py",
                  DEVICE_STORE_HEADER + """\

        def bad(self, new_state, ucl, cnt):
            self._state = new_state
            self._cursor_host[ucl] = cnt
""")
    found = _findings(path, LockDisciplineRule())
    assert len(found) == 2
    assert found[0].line == _line_of(path, "self._state = new_state")
    assert "_state" in found[0].message
    assert "write_lock" in found[0].message
    assert found[1].line == _line_of(path,
                                     "self._cursor_host[ucl] = cnt")


def test_lock_discipline_device_store_clean_when_locked(tmp_path):
    path = _write(tmp_path, "repro/core/dstore.py",
                  DEVICE_STORE_HEADER + """\

        def good(self, new_state, ts, ucl, cnt):
            with self.write_lock:
                if self.epoch is None:
                    self.epoch = ts
                self._state = new_state
                self._cursor_host[ucl] = cnt
                self.d_count += cnt
                self.ring_seen += 1

        def reader(self, cl):
            st = self._state          # snapshot read: no lock needed
            return st["items"], self._cursor_host
""")
    assert _findings(path, LockDisciplineRule()) == []


def test_lock_discipline_device_store_no_gen_bracket_demand(tmp_path):
    # the device store has no seqlock: a locked _state rebind must NOT
    # be asked for generation bumps
    path = _write(tmp_path, "repro/core/dstore.py",
                  DEVICE_STORE_HEADER + """\

        def ingest(self, new_state):
            with self.write_lock:
                self._state = new_state
""")
    assert _findings(path, LockDisciplineRule()) == []


def test_lock_discipline_flags_order_inversion(tmp_path):
    path = _write(tmp_path, "repro/core/ring.py", """\
        import threading

        class Ring:
            def __init__(self):
                self._lock = threading.Lock()
                self.cursor = 0
                self.committed = 0

            def inverted(self, store):
                with self._lock:
                    with store.write_lock:
                        pass

            def calls_write_path(self, store, u, i, t):
                with self._lock:
                    store.ingest(u, i, t)
    """)
    found = _findings(path, LockDisciplineRule())
    msgs = "\n".join(f.message for f in found)
    assert len(found) == 2
    assert "inversion" in msgs and "ingest" in msgs


def test_lock_discipline_ring_state_needs_lock(tmp_path):
    path = _write(tmp_path, "repro/core/ring.py", """\
        import threading

        class Ring:
            def __init__(self):
                self._lock = threading.Lock()
                self.cursor = 0
                self.committed = 0
                self.slots = [0] * 8

            def reserve(self, n):
                self.cursor += n

            def write_slot(self, i, v):
                self.slots[i] = v
    """)
    found = _findings(path, LockDisciplineRule())
    # cursor moves unlocked -> flagged; slot arrays are deliberately
    # lock-free -> not protected
    assert len(found) == 1
    assert found[0].line == _line_of(path, "self.cursor += n")


# ------------------------------------------------------------ donation


def test_donation_flags_read_after_donate(tmp_path):
    path = _write(tmp_path, "repro/core/loop.py", """\
        def loop(cfg, opt, batches, key):
            step = make_train_step(cfg, opt)
            state = init_state(key)
            for b in batches:
                m = step(state, b, key)
                print(state.params)
    """)
    found = _findings(path, DonationSafetyRule())
    assert len(found) >= 1
    assert found[0].line == _line_of(path, "print(state.params)")
    assert "donated" in found[0].message


def test_donation_clean_when_rebound(tmp_path):
    path = _write(tmp_path, "repro/core/loop.py", """\
        def loop(cfg, opt, batches, key):
            step = make_train_step(cfg, opt)
            state = init_state(key)
            for b in batches:
                state, m = step(state, b, key)
            return state
    """)
    assert _findings(path, DonationSafetyRule()) == []


def test_donation_ignores_undonated_step(tmp_path):
    path = _write(tmp_path, "repro/core/loop.py", """\
        def loop(cfg, opt, batches, key):
            step = make_train_step(cfg, opt, jit=False)
            state = init_state(key)
            for b in batches:
                m = step(state, b, key)
                print(state.params)
    """)
    assert _findings(path, DonationSafetyRule()) == []


def test_donation_tracks_self_attr_step(tmp_path):
    path = _write(tmp_path, "repro/lifecycle/rt.py", """\
        class Runtime:
            def __init__(self, cfg, opt):
                self._step_fn = make_train_step(cfg, opt)
                self.state = None

            def tick(self, batch, key):
                m = self._step_fn(self.state, batch, key)
                return self.state
    """)
    found = _findings(path, DonationSafetyRule())
    assert len(found) == 1
    assert found[0].line == _line_of(path, "return self.state")


def test_donation_flags_jax_jit_donate_argnums(tmp_path):
    path = _write(tmp_path, "repro/core/loop.py", """\
        import jax

        def loop(fn, state, batches):
            step = jax.jit(fn, donate_argnums=(0,))
            for b in batches:
                out = step(state, b)
            return state
    """)
    found = _findings(path, DonationSafetyRule())
    # two reads of the dead state: re-passing it to `step` on the next
    # loop iteration (loop-carried), and the trailing `return state`
    assert {f.line for f in found} == {_line_of(path, "out = step"),
                                      _line_of(path, "return state")}


# --------------------------------------------------------- determinism


def test_determinism_flags_global_rng_and_bare_seed(tmp_path):
    path = _write(tmp_path, "repro/data/gen.py", """\
        import numpy as np

        def draw(n):
            a = np.random.rand(n)
            r = np.random.default_rng(0)
            good = np.random.default_rng((0, 7))
            return a, r, good
    """)
    found = _findings(path, DeterminismRule())
    assert len(found) == 2
    assert found[0].line == _line_of(path, "np.random.rand(n)")
    assert found[1].line == _line_of(path, "np.random.default_rng(0)")


def test_determinism_flags_wall_clock(tmp_path):
    path = _write(tmp_path, "repro/core/mod.py", """\
        import time

        def stamp():
            return time.time()
    """)
    found = _findings(path, DeterminismRule())
    assert len(found) == 1
    assert found[0].line == _line_of(path, "time.time()")
    assert "repro.obs" in found[0].message


def test_determinism_sanctions_clock_in_obs_module(tmp_path):
    """``src/repro/obs/`` is the single sanctioned raw-clock site (the
    injectable ``SystemClock`` lives there) — in scope for every other
    determinism check, but exempt from the wall-clock one."""
    src = """\
        import time

        def stamp():
            return time.perf_counter()
    """
    obs_path = _write(tmp_path, "repro/obs/clock2.py", src)
    rule = DeterminismRule()
    assert rule.applies(obs_path)              # still a scoped module
    assert not _findings(obs_path, rule)       # ...but the clock is allowed
    core_path = _write(tmp_path, "repro/core/clock2.py", src)
    assert len(_findings(core_path, rule)) == 1


def test_determinism_obs_module_still_checked_for_rng(tmp_path):
    """The obs exemption covers *only* the clock — unkeyed RNG in an
    obs module still fails."""
    path = _write(tmp_path, "repro/obs/sample.py", """\
        import numpy as np

        def jitter(n):
            return np.random.rand(n)
    """)
    found = _findings(path, DeterminismRule())
    assert len(found) == 1
    assert found[0].line == _line_of(path, "np.random.rand(n)")


def test_determinism_flags_host_effect_in_jit(tmp_path):
    path = _write(tmp_path, "repro/core/mod.py", """\
        import jax

        @jax.jit
        def step(x):
            print(x)
            return x * 2

        def outer(x):
            print(x)
            return x
    """)
    found = _findings(path, DeterminismRule())
    # only the traced function's print is flagged
    assert len(found) == 1
    assert found[0].line == _line_of(path, "    print(x)")


def test_determinism_scoped_to_library_code(tmp_path):
    path = _write(tmp_path, "repro/launch/mod.py", """\
        import time

        def stamp():
            return time.time()
    """)
    rule = DeterminismRule()
    assert not rule.applies(path)


# ---------------------------------------------------------------- vmem


def test_vmem_flags_oversized_resident_block(tmp_path):
    path = _write(tmp_path, "repro/kernels/fake/fake.py", """\
        from jax.experimental import pallas as pl

        def _run(x):
            return pl.pallas_call(
                _kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((8192, 1024), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                out_shape=None)(x)
    """)
    rule = VmemBudgetRule()
    found = _findings(path, rule)
    assert len(found) == 1
    assert found[0].line == _line_of(path, "pl.pallas_call(")
    assert "MiB" in found[0].message
    entry = rule.entries[0]
    # 8192*1024*4 resident + 8*128*4 double-buffered out
    assert entry["vmem_bytes"] == 8192 * 1024 * 4 + 8 * 128 * 4 * 2


def test_vmem_clean_small_blocks_and_scratch(tmp_path):
    path = _write(tmp_path, "repro/kernels/fake/fake.py", """\
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu
        import jax.numpy as jnp

        def _run(x):
            return pl.pallas_call(
                _kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((128, 128), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((128, 128), lambda i: (i, 0)),
                scratch_shapes=[pltpu.VMEM((128, 128), jnp.float32)],
                out_shape=None)(x)
    """)
    rule = VmemBudgetRule()
    assert _findings(path, rule) == []
    entry = rule.entries[0]
    assert entry["vmem_bytes"] == 128 * 128 * 4 * (2 + 2 + 1)
    assert not entry["over_budget"]


def test_vmem_report_written(tmp_path):
    path = _write(tmp_path, "repro/kernels/fake/fake.py", """\
        from jax.experimental import pallas as pl

        def _run(x):
            return pl.pallas_call(
                _kernel, grid=(4,),
                in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                out_shape=None)(x)
    """)
    report_path = str(tmp_path / "vmem_report.json")
    rule = VmemBudgetRule(report_path=report_path)
    run_analysis([path], rules=[rule])
    report = json.load(open(report_path))
    assert report["n_kernels"] == 1
    assert report["n_over_budget"] == 0
    assert report["kernels"][0]["specs"]


# --------------------------------------------------------- suppression


def test_suppression_with_reason_is_honored(tmp_path):
    path = _write(tmp_path, "repro/core/mod.py", """\
        import time

        def stamp():
            # repro: disable=determinism — benign timing for a report
            return time.time()
    """)
    found = analyze_file(path, [DeterminismRule()])
    assert len(found) == 1
    assert found[0].suppressed
    assert found[0].reason == "benign timing for a report"
    assert active(found) == []


def test_suppression_without_reason_is_flagged(tmp_path):
    path = _write(tmp_path, "repro/core/mod.py", """\
        import time

        def stamp():
            return time.time()  # repro: disable=determinism
    """)
    found = analyze_file(path, [DeterminismRule()])
    supp = [f for f in found if f.rule == "suppression"]
    assert len(supp) == 1
    assert "no written reason" in supp[0].message
    # the original finding is suppressed, but the run still fails
    assert [f.rule for f in active(found)] == ["suppression"]


def test_suppression_only_matches_named_rule(tmp_path):
    path = _write(tmp_path, "repro/core/mod.py", """\
        import time

        def stamp():
            # repro: disable=donation-safety — wrong rule on purpose
            return time.time()
    """)
    found = analyze_file(path, [DeterminismRule()])
    assert len(active(found)) == 1


# --------------------------------------------------------- CLI plumbing


def test_json_output_schema(tmp_path):
    path = _write(tmp_path, "repro/core/mod.py", """\
        import time

        def stamp():
            return time.time()
    """)
    findings = analyze_file(path, [DeterminismRule()])
    doc = json.loads(format_json(findings))
    assert set(doc) == {"findings", "summary"}
    f = doc["findings"][0]
    assert set(f) == {"rule", "path", "line", "col", "message",
                      "suppressed", "reason"}
    assert doc["summary"]["active"] == 1
    assert doc["summary"]["by_rule"] == {"determinism": 1}


def test_rule_registry_names():
    names = set(rules_by_name())
    assert names == {"lock-discipline", "donation-safety",
                     "determinism", "error-handling", "vmem-budget"}


def test_parse_error_is_reported(tmp_path):
    path = _write(tmp_path, "repro/core/mod.py", "def broken(:\n")
    found = analyze_file(path, default_rules())
    assert [f.rule for f in found] == ["parse-error"]


# --------------------------------------------------------- integration


def test_whole_src_tree_is_clean():
    """The acceptance bar: the repo's own src/ has no unsuppressed
    findings, and every suppression carries a reason."""
    findings = run_analysis([os.path.join(REPO, "src")])
    assert active(findings) == [], "\n".join(
        f.render() for f in active(findings))
    for f in findings:
        assert f.suppressed and f.reason


def test_src_vmem_only_known_exception():
    """Exactly one kernel (ppr_walk's resident adjacency) exceeds the
    budget at production dims, and it is explicitly suppressed."""
    rule = VmemBudgetRule()
    findings = run_analysis([os.path.join(REPO, "src", "repro",
                                          "kernels")], rules=[rule])
    over = [e["kernel"] for e in rule.entries if e["over_budget"]]
    assert over == ["ppr_walk:_run"]
    assert all(e["unresolved_specs"] == 0 for e in rule.entries)
    assert all(f.suppressed for f in findings)


# ----------------------------------------------- sampler determinism


def test_sampler_default_rng_is_tuple_keyed():
    from repro.models.gnn.sampler import (CSRGraph, make_random_graph,
                                          sample_two_hop)
    src, dst = make_random_graph(200, 1200, seed=0)
    g = CSRGraph.from_edges(src, dst, 200)
    seeds = np.arange(16)
    a = sample_two_hop(g, seeds, 4, 3, seed=7)
    b = sample_two_hop(g, seeds, 4, 3, seed=7)
    c = sample_two_hop(g, seeds, 4, 3, seed=8)
    assert np.array_equal(a.node_ids, b.node_ids)        # replayable
    assert not np.array_equal(a.node_ids, c.node_ids)    # keyed by seed
    # an explicit generator still wins over the seed key
    d = sample_two_hop(g, seeds, 4, 3,
                       rng=np.random.default_rng((7, 0x2B0)))
    assert np.array_equal(a.node_ids, d.node_ids)


# ------------------------------------------------------ error handling


def test_error_handling_flags_bare_except(tmp_path):
    from repro.analysis.rules.error_handling import ErrorHandlingRule
    path = _write(tmp_path, "repro/lifecycle/mod.py", """\
        def f(x):
            try:
                return 1 / x
            except:
                return 0.0
    """)
    found = _findings(path, ErrorHandlingRule())
    assert len(found) == 1
    assert found[0].line == _line_of(path, "except:")
    assert "KeyboardInterrupt" in found[0].message


def test_error_handling_flags_silent_broad_swallow(tmp_path):
    from repro.analysis.rules.error_handling import ErrorHandlingRule
    path = _write(tmp_path, "repro/core/mod.py", """\
        def f(x):
            try:
                work(x)
            except Exception:
                pass
            try:
                work(x)
            except (ValueError, BaseException):
                '''tolerated'''
    """)
    found = _findings(path, ErrorHandlingRule())
    assert len(found) == 2
    assert found[0].line == _line_of(path, "except Exception:")
    assert "swallows" in found[0].message


def test_error_handling_allows_broad_catch_that_degrades(tmp_path):
    """Broad handlers that do real work — count, shed, re-raise — are
    the degradation contract, not a violation."""
    from repro.analysis.rules.error_handling import ErrorHandlingRule
    path = _write(tmp_path, "repro/lifecycle/mod.py", """\
        def f(tel, x):
            try:
                work(x)
            except Exception:
                tel.counter("shed")
            try:
                work(x)
            except ValueError:
                pass
    """)
    assert not _findings(path, ErrorHandlingRule())


def test_error_handling_scoped_and_suppressible(tmp_path):
    from repro.analysis.rules.error_handling import ErrorHandlingRule
    src = """\
        def f(x):
            try:
                work(x)
            except Exception:  # repro: disable=error-handling — probe teardown is best-effort
                pass
    """
    rule = ErrorHandlingRule()
    out_path = _write(tmp_path, "repro/launch/mod.py", src)
    assert not rule.applies(out_path)          # launch/ is out of scope
    in_path = _write(tmp_path, "repro/data/mod.py", src)
    found = analyze_file(in_path, [rule])
    assert len(found) == 1 and found[0].suppressed
    assert not active(found)


def test_error_handling_rule_is_registered():
    assert "error-handling" in rules_by_name()
    assert any(r.name == "error-handling" for r in default_rules())
