"""Lifecycle runtime: full-corpus encode vs the per-batch oracle,
snapshot save/load round-trips (and TrainState round-trips incl. the
RQState ring buffers), publication artifacts, and swap atomicity under
an interleaved version-flip storm."""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RQConfig
from repro.core.serving import ClusterQueueStore, build_i2i_knn
from repro.kernels.rq_assign.ops import (flat_codes_np, rq_assign,
                                         rq_assign_corpus)
from repro.lifecycle.snapshot import (IndexSnapshot, SnapshotStore,
                                      derive_members)
from repro.lifecycle.swap import EventRing, SnapshotHandle, SwapServer


# ---------------------------------------------------------------------------
# full-corpus RQ encode == per-batch oracle, bit for bit
# ---------------------------------------------------------------------------

def _books(rng, d=24, sizes=(16, 8)):
    return [rng.normal(size=(n, d)).astype(np.float32) * s
            for n, s in zip(sizes, (0.3, 0.1))]


def test_rq_corpus_encode_matches_per_batch_oracle():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1037, 24)).astype(np.float32)
    books = _books(rng)
    codes, recon = rq_assign_corpus(x, books, chunk=256)
    # arbitrary batch splits through the online assignment path
    for splits in ([0, 1037], [0, 13, 700, 1037], [0, 512, 1037]):
        for lo, hi in zip(splits[:-1], splits[1:]):
            c, r = rq_assign(jnp.asarray(x[lo:hi]),
                             [jnp.asarray(b) for b in books],
                             use_kernel=False)
            np.testing.assert_array_equal(np.asarray(c), codes[lo:hi])
            np.testing.assert_array_equal(np.asarray(r), recon[lo:hi])


def test_rq_corpus_encode_kernel_path_bitwise():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(300, 24)).astype(np.float32)
    books = _books(rng)
    ck, rk = rq_assign_corpus(x, books, chunk=128, use_kernel=True,
                              block_b=64)
    cr, rr = rq_assign_corpus(x, books, chunk=300)
    np.testing.assert_array_equal(ck, cr)
    np.testing.assert_array_equal(rk, rr)


def test_corpus_flat_codes_match_online_assignment():
    """Publication (corpus encode -> flat ids) must agree with the
    online serving-side assignment path (``rq_index.assign_codes``)."""
    from repro.core import rq_index as RQ
    rng = np.random.default_rng(2)
    sizes = (16, 8)
    books = _books(rng, sizes=sizes)
    params = {"codebooks": {f"layer{l}": jnp.asarray(b)
                            for l, b in enumerate(books)}}
    emb = rng.normal(size=(257, 24)).astype(np.float32)
    codes, _ = rq_assign_corpus(emb, books, chunk=100)
    flat = flat_codes_np(codes, sizes)
    online = np.asarray(RQ.assign_codes(
        params, jnp.asarray(emb), RQConfig(codebook_sizes=sizes)))
    np.testing.assert_array_equal(flat, online)


def test_rq_corpus_encode_empty_and_tiny():
    rng = np.random.default_rng(3)
    books = _books(rng)
    c, r = rq_assign_corpus(np.zeros((0, 24), np.float32), books)
    assert c.shape == (0, 2) and r.shape == (0, 24)
    x = rng.normal(size=(3, 24)).astype(np.float32)
    c, r = rq_assign_corpus(x, books, chunk=4096)
    cr, rr = rq_assign(jnp.asarray(x), [jnp.asarray(b) for b in books],
                       use_kernel=False)
    np.testing.assert_array_equal(c, np.asarray(cr))


# ---------------------------------------------------------------------------
# checkpoint round-trips: TrainState (RQ ring buffers) + IndexSnapshot
# ---------------------------------------------------------------------------

def test_train_state_roundtrip_preserves_rq_ring_buffers(
        tmp_path, tiny_cfg, tiny_dataset):
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.core import trainer as T
    state, _, opt = T.init_state(jax.random.key(0), tiny_cfg, pool_size=64)
    step = T.make_train_step(tiny_cfg, opt)     # jitted, donated
    for t in range(4):
        batch = jax.tree.map(jnp.asarray, tiny_dataset.sample_batch(
            t, 0, {"uu": 8, "ui": 8, "ii": 8}))
        state, _ = step(state, batch, jax.random.key(t))
    assert int(state.rq_state.ptr) == 4          # buffers actually moved
    assert any(float(jnp.sum(h)) > 0 for h in state.rq_state.hists)
    ck = Checkpointer(str(tmp_path))
    ck.save(int(state.step), state)
    restored, _ = ck.restore(jax.tree.map(jnp.zeros_like, state))
    for a, b in zip(state.rq_state.hists, restored.rq_state.hists):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(restored.rq_state.ptr) == int(state.rq_state.ptr)
    assert int(restored.rq_state.filled) == int(state.rq_state.filled)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _random_snapshot(rng, version=1, n_users=40, n_items=30,
                     sizes=(4, 2), d=8, k=5):
    n_clusters = int(np.prod(sizes))
    flat = rng.integers(0, n_clusters, n_users).astype(np.int64)
    ptr, ids = derive_members(flat, n_clusters)
    codes = np.stack([flat // sizes[1], flat % sizes[1]],
                     axis=1).astype(np.int32)
    return IndexSnapshot(
        user_codes=codes,
        item_codes=rng.integers(0, sizes[0], (n_items, 2)).astype(np.int32),
        user_clusters=flat, member_ptr=ptr, member_ids=ids,
        coarse_codebook=rng.normal(size=(sizes[0], d)).astype(np.float32),
        i2i=rng.integers(-1, n_items, (n_items, k)).astype(np.int64),
        version=version, n_users=n_users, n_items=n_items,
        codebook_sizes=sizes,
        gate_metrics=(("recall_ratio", 0.93),))


def test_index_snapshot_store_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    store = SnapshotStore(str(tmp_path), keep=2)
    snap = _random_snapshot(rng, version=3)
    store.publish(snap)
    back = store.load()
    assert back.version == 3
    assert back.codebook_sizes == (4, 2)
    assert back.metrics == {"recall_ratio": 0.93}
    for f in ("user_codes", "item_codes", "user_clusters", "member_ptr",
              "member_ids", "coarse_codebook", "i2i"):
        np.testing.assert_array_equal(getattr(snap, f), getattr(back, f))
    # retention + latest pointer behave like the checkpointer's
    for v in (4, 5, 6):
        store.publish(_random_snapshot(rng, version=v))
    assert store.versions() == [5, 6]
    assert store.latest_version() == 6
    assert store.load(5).version == 5


def test_snapshot_store_rejects_non_snapshot_dir(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    Checkpointer(str(tmp_path)).save(1, {"w": jnp.zeros(3)})
    with pytest.raises(ValueError, match="index snapshot"):
        SnapshotStore(str(tmp_path)).load()


def test_derive_members_csr():
    rng = np.random.default_rng(7)
    flat = rng.integers(0, 6, 50).astype(np.int64)
    ptr, ids = derive_members(flat, 6)
    assert ptr[-1] == 50 and len(ids) == 50
    for c in range(6):
        members = ids[ptr[c]:ptr[c + 1]]
        np.testing.assert_array_equal(np.sort(members),
                                      np.flatnonzero(flat == c))


def test_snapshot_coarse_members():
    rng = np.random.default_rng(8)
    snap = _random_snapshot(rng)
    for k0 in range(snap.codebook_sizes[0]):
        got = np.sort(snap.coarse_members(k0))
        want = np.flatnonzero(snap.user_codes[:, 0] == k0)
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# serving guard: users minted after the snapshot
# ---------------------------------------------------------------------------

def test_store_unknown_user_guard():
    store = ClusterQueueStore(np.array([0, 1, 0]), queue_len=8,
                              recency_s=1e9)
    # unknown-user events are dropped, known ones land
    store.ingest(np.array([0, 7, 1]), np.array([10, 11, 12]),
                 np.array([1.0, 2.0, 3.0]))
    assert store.retrieve(0, 3.0, 4) == [10]
    assert store.retrieve(1, 3.0, 4) == [12]
    # unknown users retrieve nothing (and never alias cluster 0's queue)
    out = store.retrieve_batch(np.array([0, 7, -2]), 3.0, 4)
    assert out[0].tolist()[0] == 10
    assert (out[1] == -1).all() and (out[2] == -1).all()
    # kernel serve path masks unknown rows too
    i2i = np.array([[1, 2]] * 13)
    s, u = store.serve_batch(np.array([0, 7]), 3.0, n_recent=2, k=2,
                             i2i=i2i, use_kernel=True)
    assert (s[1] == -1).all() and (u[1] == -1).all()
    assert s[0, 0] == 10


# ---------------------------------------------------------------------------
# swap engine: event ring, handle, atomicity
# ---------------------------------------------------------------------------

def test_event_ring_window_and_wrap():
    ring = EventRing(capacity=8)
    ring.push(np.arange(5), np.arange(5) + 100, np.arange(5, dtype=float))
    u, i, t, seen = ring.window_since(0, -1.0)
    assert u.tolist() == [0, 1, 2, 3, 4] and seen == 5
    ring.push(np.arange(6), np.arange(6) + 200, np.arange(6, dtype=float))
    u, i, t, seen = ring.window_since(0, -1.0)      # capacity clamps
    assert len(u) == 8 and seen == 11
    assert i.tolist()[-6:] == [200, 201, 202, 203, 204, 205]
    # staleness drain
    u, i, t, _ = ring.window_since(0, 3.0)
    assert (t >= 3.0).all()
    # incremental read: nothing new
    u, i, t, seen2 = ring.window_since(seen, -1.0)
    assert len(u) == 0 and seen2 == seen


def _mk_snapshot(rng, version, n_users, n_items, flip):
    """Two snapshot families with disjoint cluster layouts + i2i tables
    so any cross-version mixing is detectable in the output."""
    sizes = (4, 2)
    n_clusters = 8
    flat = ((np.arange(n_users) + (3 * flip)) % n_clusters).astype(np.int64)
    ptr, ids = derive_members(flat, n_clusters)
    codes = np.stack([flat // 2, flat % 2], axis=1).astype(np.int32)
    i2i = ((np.arange(n_items)[:, None] + 1 + flip * 7)
           % n_items).astype(np.int64).repeat(3, axis=1)
    i2i[:, 1] = (i2i[:, 1] + 1 + flip) % n_items
    i2i[:, 2] = (i2i[:, 2] + 3 + flip) % n_items
    return IndexSnapshot(
        user_codes=codes, item_codes=np.zeros((n_items, 2), np.int32),
        user_clusters=flat, member_ptr=ptr, member_ids=ids,
        coarse_codebook=np.zeros((4, 4), np.float32), i2i=i2i,
        version=version, n_users=n_users, n_items=n_items,
        codebook_sizes=sizes)


def test_swap_atomicity_under_interleaved_flips():
    """Interleave retrieve/serve with a background flip storm: every
    response must be bit-equal to the output of exactly the version it
    reports — never a mix of two snapshots' stores/i2i tables."""
    n_users, n_items, n_ev = 60, 40, 3000
    rng = np.random.default_rng(0)
    ev = (rng.integers(0, n_users, n_ev), rng.integers(0, n_items, n_ev),
          np.sort(rng.random(n_ev) * 1000.0))
    snap_a = _mk_snapshot(rng, 1, n_users, n_items, flip=0)
    snap_b = _mk_snapshot(rng, 2, n_users, n_items, flip=1)

    server = SwapServer(snap_a, queue_len=32, recency_s=1e9,
                        ring_capacity=1 << 13)
    server.ingest(*ev)
    now = 1000.0

    # per-version oracles: standalone stores fed the same event stream
    expected = {}
    for snap in (snap_a, snap_b):
        st = ClusterQueueStore(snap.user_clusters, queue_len=32,
                               recency_s=1e9,
                               n_clusters=snap.n_clusters)
        st.ingest(*ev)
        expected[snap.version] = (st, snap.i2i)

    users = rng.integers(0, n_users, 64)
    stop = threading.Event()
    flips = dict(n=0)

    def flipper():
        v = 2
        while not stop.is_set():
            snap = snap_b if v % 2 == 0 else snap_a
            server.swap_to(dataclasses.replace(snap, version=snap.version),
                           now)
            flips["n"] += 1
            v += 1

    th = threading.Thread(target=flipper, daemon=True)
    th.start()
    seen_versions = set()
    try:
        for _ in range(150):
            res, ver = server.retrieve_batch(users, now, 16)
            st, _ = expected[ver]
            np.testing.assert_array_equal(
                res, st.retrieve_batch(users, now, 16))
            seeds, union, ver2 = server.serve_batch(
                users[:16], now, n_recent=4, k=8)
            st, i2i = expected[ver2]
            from repro.core.serving import u2i2i_retrieve_batch
            es = st.retrieve_batch(users[:16], now, 4)
            np.testing.assert_array_equal(seeds, es)
            np.testing.assert_array_equal(
                union, u2i2i_retrieve_batch(i2i, es, 8))
            seen_versions.add(ver)
    finally:
        stop.set()
        th.join(timeout=10)
    assert flips["n"] > 0
    assert len(seen_versions) >= 1      # both under normal scheduling


def test_swap_rekeys_queues_to_new_clusters():
    """After a flip, retrieval reflects the *new* user->cluster map:
    replayed events land in the clusters the new snapshot assigns."""
    rng = np.random.default_rng(4)
    n_users, n_items = 30, 20
    snap_a = _mk_snapshot(rng, 1, n_users, n_items, flip=0)
    snap_b = _mk_snapshot(rng, 2, n_users, n_items, flip=1)
    ev = (rng.integers(0, n_users, 500), rng.integers(0, n_items, 500),
          np.sort(rng.random(500) * 100.0))
    server = SwapServer(snap_a, queue_len=16, recency_s=1e9)
    server.ingest(*ev)
    server.swap_to(snap_b, now=100.0)
    fresh = ClusterQueueStore(snap_b.user_clusters, queue_len=16,
                              recency_s=1e9,
                              n_clusters=snap_b.n_clusters)
    fresh.ingest(*ev)
    users = np.arange(n_users)
    got, ver = server.retrieve_batch(users, 100.0, 8)
    assert ver == 2
    np.testing.assert_array_equal(got,
                                  fresh.retrieve_batch(users, 100.0, 8))


def test_snapshot_handle_flip_returns_displaced():
    rng = np.random.default_rng(5)
    from repro.lifecycle.swap import ServingBundle
    snap = _mk_snapshot(rng, 1, 10, 10, flip=0)

    def bundle(v):
        return ServingBundle(
            version=v, snapshot=snap,
            store=ClusterQueueStore(snap.user_clusters, queue_len=4,
                                    recency_s=1.0,
                                    n_clusters=snap.n_clusters),
            i2i=snap.i2i)

    h = SnapshotHandle(bundle(1))
    assert h.version == 1
    b2 = bundle(2)
    old = h.flip(b2)
    assert old.version == 1 and h.acquire() is b2
    b3 = bundle(3)
    old = h.flip(b3)
    assert old.version == 2 and h.version == 3


# ---------------------------------------------------------------------------
# publisher: artifacts + recall gate plumbing (cheap, untrained RQ)
# ---------------------------------------------------------------------------

def test_build_and_evaluate_snapshot_smoke(tiny_world):
    from repro.lifecycle.publish import (build_snapshot,
                                         cluster_neighbor_users,
                                         evaluate_snapshot)
    rng = np.random.default_rng(0)
    d, sizes = 16, (8, 4)
    nu, ni = tiny_world.n_users, tiny_world.n_items
    user_emb = np.ascontiguousarray(
        tiny_world.user_latent @ rng.normal(size=(
            tiny_world.user_latent.shape[1], d))).astype(np.float32)
    item_emb = rng.normal(size=(ni, d)).astype(np.float32)
    params = {"codebooks": {
        "layer0": user_emb[rng.choice(nu, sizes[0], replace=False)],
        "layer1": rng.normal(size=(sizes[1], d)).astype(np.float32) * .1}}
    snap = build_snapshot(1, user_emb, item_emb, params,
                          _cfg_for(sizes), i2i_k=6)
    assert snap.n_clusters == 32
    assert snap.member_ptr[-1] == nu
    assert snap.i2i.shape == (ni, 6)
    # multi-probe neighbors: valid ids, self-excluded
    q = np.arange(12)
    nbrs = cluster_neighbor_users(snap, user_emb, q, 10)
    assert nbrs.shape == (12, 10)
    for qi, row in zip(q, nbrs):
        vals = row[row >= 0]
        assert qi not in vals
        assert (vals < nu).all()
    m = evaluate_snapshot(snap, user_emb, user_emb.copy(), tiny_world,
                          recall_k=20, n_queries=50)
    assert 0.0 <= m["recall_index"] <= 1.0
    assert m["recall_ratio"] >= 0.0


def _cfg_for(sizes):
    from repro.configs.base import RankGraph2Config
    return RankGraph2Config(rq=RQConfig(codebook_sizes=sizes),
                            d_embed=16, dtype="float32")


def test_gate_breadth_collapsed_codebook_cannot_publish(tiny_world):
    """ROADMAP 'Gate breadth': a deliberately collapsed codebook (every
    row identical -> every embedding assigned code 0) must trip the
    published-code utilization floor, and the item-side §5.2.2 recall
    must ride in the gate metrics."""
    from types import SimpleNamespace
    from repro.lifecycle.publish import build_snapshot, evaluate_snapshot
    from repro.lifecycle.runtime import LifecycleConfig, LifecycleRuntime
    rng = np.random.default_rng(0)
    d, sizes = 16, (8, 4)
    nu, ni = tiny_world.n_users, tiny_world.n_items
    user_emb = rng.normal(size=(nu, d)).astype(np.float32)
    item_emb = rng.normal(size=(ni, d)).astype(np.float32)
    healthy = {"codebooks": {
        "layer0": user_emb[rng.choice(nu, sizes[0], replace=False)],
        "layer1": rng.normal(size=(sizes[1], d)).astype(np.float32) * .1}}
    collapsed = {"codebooks": {
        "layer0": np.zeros((sizes[0], d), np.float32),   # all rows equal
        "layer1": np.zeros((sizes[1], d), np.float32)}}

    def metrics_for(params):
        snap, recon = build_snapshot(1, user_emb, item_emb, params,
                                     _cfg_for(sizes), i2i_k=6,
                                     want_user_recon=True)
        m = evaluate_snapshot(snap, user_emb, recon, tiny_world,
                              recall_k=20, n_queries=50,
                              item_emb=item_emb)
        return dataclasses.replace(snap, gate_metrics=tuple(sorted(
            (k, float(v)) for k, v in m.items()))), m

    snap_h, m_h = metrics_for(healthy)
    snap_c, m_c = metrics_for(collapsed)
    # the new gate metrics are present on both
    for m in (m_h, m_c):
        assert {"item_recall_exact", "item_recall_index",
                "item_recall_ratio", "codebook_util_min",
                "util_layer0", "util_layer1"} <= set(m)
    assert m_h["codebook_util_min"] > m_c["codebook_util_min"]
    # argmin over identical rows is index 0 everywhere -> 1/size per layer
    assert m_c["util_layer0"] == 1.0 / sizes[0]
    assert m_c["util_layer1"] == 1.0 / sizes[1]

    gate = LifecycleConfig(min_codebook_util=0.5)
    rt = SimpleNamespace(lcfg=gate)            # gate_passes uses lcfg only
    assert LifecycleRuntime.gate_passes(rt, snap_h)
    assert not LifecycleRuntime.gate_passes(rt, snap_c)
    # item-side floor is enforced independently of the user-side one
    rt_item = SimpleNamespace(lcfg=LifecycleConfig(
        min_item_recall_ratio=2.0))            # unsatisfiable
    assert not LifecycleRuntime.gate_passes(rt_item, snap_h)
    rt_off = SimpleNamespace(lcfg=LifecycleConfig())   # all floors off
    assert LifecycleRuntime.gate_passes(rt_off, snap_c)


def test_gate_failed_snapshot_is_not_persisted_or_swapped(
        tmp_path, tiny_world, tiny_cfg, tiny_graph):
    """A snapshot below the recall floor must neither reach the on-disk
    store (a restart would load it via ``latest``) nor serving."""
    from repro.data.edge_dataset import build_neighbor_tables
    from repro.lifecycle.runtime import LifecycleConfig, LifecycleRuntime
    import repro.core.graph_builder as GB
    g = GB.build_graph(tiny_world.day0, k_cap=16, hub_cap=12,
                       keep_state=True)
    tables = build_neighbor_tables(g, k_imp=10, n_walks=12, walk_len=3,
                                   keep_state=True)
    lcfg = LifecycleConfig(steps_per_cycle=1, batch_per_type=8,
                           recall_queries=40, recall_k=20,
                           min_recall_ratio=2.0)   # unsatisfiable
    rt = LifecycleRuntime(tiny_cfg, lcfg, g, tables,
                          tiny_world.user_feat, tiny_world.item_feat,
                          world=tiny_world, snapshot_dir=str(tmp_path),
                          seed=0)
    rep = rt.run_cycle(now=86400.0)
    assert rep["swap"].get("skipped") is True
    assert rt.server is None                       # never came up
    assert rt.store.versions() == []               # nothing persisted
    with pytest.raises(FileNotFoundError):
        rt.store.load()
    # feature validation fires BEFORE graph/tables mutate
    from repro.core.graph_builder import EngagementLog
    g_before, t_before = rt.g, rt.tables
    delta = EngagementLog(np.array([0]), np.array([0]),
                          np.array([0], np.int32), np.array([86401.0]),
                          tiny_world.n_users + 3, tiny_world.n_items)
    with pytest.raises(ValueError, match="user features"):
        rt.refresh(delta)
    assert rt.g is g_before and rt.tables is t_before


# ---------------------------------------------------------------------------
# self-healing index: publish stability + collapse-injection recovery
# ---------------------------------------------------------------------------

def _healing_runtime(tiny_world, *, steps=40, seed=0):
    """A runtime with the full self-healing loop on: utilization-
    balanced co-training, in-burst dead-code resets and a gate-triggered
    repair burst."""
    from repro.configs.base import RankGraph2Config
    from repro.data.edge_dataset import build_neighbor_tables
    from repro.lifecycle.runtime import LifecycleConfig, LifecycleRuntime
    import repro.core.graph_builder as GB
    g = GB.build_graph(tiny_world.day0, k_cap=16, hub_cap=12,
                       keep_state=True)
    tables = build_neighbor_tables(g, k_imp=10, n_walks=12, walk_len=3,
                                   keep_state=True)
    cfg = RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=24, n_heads=2,
        d_hidden=48, k_imp=10, k_train=4, n_negatives=16, n_pool_neg=4,
        rq=RQConfig(codebook_sizes=(8, 4), hist_len=20, util_coef=1.0,
                    usage_ema=0.9, dead_floor=0.25, reset_every=10),
        dtype="float32")
    lcfg = LifecycleConfig(steps_per_cycle=steps, batch_per_type=16,
                           recall_queries=60, recall_k=20,
                           min_codebook_util=0.5, repair_attempts=1,
                           repair_steps=10)
    return LifecycleRuntime(cfg, lcfg, g, tables, tiny_world.user_feat,
                            tiny_world.item_feat, world=tiny_world,
                            seed=seed)


def test_publish_stability_across_consecutive_publishes(tiny_world):
    """Regression for the seed's collapse signature: hitrate10_recon
    flapping 1.0 -> 0.0 and utilization decaying cycle over cycle.  Two
    consecutive train+publish rounds must both clear the utilization
    floor and neither health metric may flap."""
    rt = _healing_runtime(tiny_world)
    rt.train_burst()
    m1 = rt.publish().metrics
    rt.train_burst()
    m2 = rt.publish().metrics
    for m in (m1, m2):
        assert m["codebook_util_min"] >= 0.375      # vs 1/8 at collapse
        assert m["recall_ratio"] >= 0.8
    assert abs(m1["hitrate10_recon"] - m2["hitrate10_recon"]) < 0.9
    for l in (0, 1):
        assert abs(m1[f"util_layer{l}"] - m2[f"util_layer{l}"]) <= 0.5
    # health metrics are first-class snapshot metadata on every publish
    assert {"util_layer0", "util_layer1", "codebook_util_min",
            "coarse_list_balance", "coarse_list_max_share",
            "hitrate10_recon"} <= set(m2)


@pytest.mark.slow
def test_collapse_injection_one_repair_burst_recovers(tiny_world):
    """Artificially collapse the coarse codebook (all centroids equal)
    after a healthy burst: the publish gate must refuse it, and ONE
    bounded repair burst (corpus-occupancy reset + short re-train) must
    restore ``util_layer0`` above the gate floor with recall held."""
    import jax.numpy as jnp
    rt = _healing_runtime(tiny_world)
    rt.train_burst()
    base = rt.publish().metrics
    books = dict(rt.state.params["rq"]["codebooks"])
    books["layer0"] = jnp.zeros_like(books["layer0"])   # all rows equal
    rt.state.params["rq"] = {"codebooks": books}
    snap_bad = rt.publish()
    assert snap_bad.metrics["util_layer0"] == 1.0 / 8
    assert not rt.gate_passes(snap_bad)
    rep = rt.repair_burst(snap_bad)
    assert sum(rep["resets"].values()) > 0
    snap_fixed = rt.publish()
    m = snap_fixed.metrics
    assert m["util_layer0"] >= rt.lcfg.min_codebook_util
    assert rt.gate_passes(snap_fixed)
    assert m["recall_ratio"] >= 0.8 * min(base["recall_ratio"], 1.0)


def _runtime_with_telemetry(tiny_world, tiny_cfg, tmp_path=None, **lkw):
    from repro.data.edge_dataset import build_neighbor_tables
    from repro.lifecycle.runtime import LifecycleConfig, LifecycleRuntime
    from repro.obs import FixedClock, MemorySink, Telemetry
    import repro.core.graph_builder as GB
    sink = MemorySink()
    tel = Telemetry(sink=sink, clock=FixedClock())
    g = GB.build_graph(tiny_world.day0, k_cap=16, hub_cap=12,
                       keep_state=True)
    tables = build_neighbor_tables(g, k_imp=10, n_walks=12, walk_len=3,
                                   keep_state=True)
    lcfg = LifecycleConfig(steps_per_cycle=1, batch_per_type=8,
                           recall_queries=40, recall_k=20, **lkw)
    rt = LifecycleRuntime(tiny_cfg, lcfg, g, tables,
                          tiny_world.user_feat, tiny_world.item_feat,
                          world=tiny_world,
                          snapshot_dir=(str(tmp_path) if tmp_path
                                        else None),
                          seed=0, telemetry=tel)
    return rt, tel, sink


def _trace(sink):
    import json
    return [json.loads(ln) for ln in sink.lines]


def test_run_cycle_emits_lifecycle_spans_and_counters(tiny_world,
                                                      tiny_cfg):
    """One successful cycle under a private telemetry instance: the
    stage spans (cycle -> train/publish/swap) land in the trace with
    correct parentage, the stage counters move, and the swap report's
    ``span_id`` joins back to the trace."""
    rt, tel, sink = _runtime_with_telemetry(tiny_world, tiny_cfg)
    rep = rt.run_cycle(now=86400.0)
    assert not rep["swap"].get("skipped")

    spans = {r["name"]: r for r in _trace(sink) if r["type"] == "span"}
    for name in ("lifecycle.cycle", "lifecycle.train",
                 "lifecycle.publish", "lifecycle.swap"):
        assert name in spans, name
    cyc = spans["lifecycle.cycle"]
    assert cyc["parent_id"] is None
    for name in ("lifecycle.train", "lifecycle.publish",
                 "lifecycle.swap"):
        assert spans[name]["parent_id"] == cyc["span_id"]
    assert spans["lifecycle.publish"]["attrs"]["gate_passed"] is True
    assert spans["lifecycle.swap"]["attrs"]["bring_up"] is True
    assert rep["swap"]["span_id"] == float(
        spans["lifecycle.swap"]["span_id"])

    snap = tel.snapshot()
    assert snap["counters"]["train.steps"] == 1.0
    assert snap["counters"]["publish.snapshots"] == 1.0
    assert "publish.gate_failures" not in snap["counters"]
    assert snap["hists"]["train.step_latency_s"]["n"] == 1
    # every numeric publish metric surfaces as a publish.* gauge
    for key in ("recall_ratio", "codebook_util_min"):
        assert f"publish.{key}" in snap["gauges"]


def test_repair_burst_outcome_surfaces_as_span_and_counters(tiny_world,
                                                            tiny_cfg):
    """A tripped gate with repair enabled: the repair attempt appears
    as a ``lifecycle.repair`` span naming its trigger gate and outcome,
    and the burst/reset counters move (the unsatisfiable floor keeps
    the outcome deterministic: not healed, swap skipped)."""
    rt, tel, sink = _runtime_with_telemetry(
        tiny_world, tiny_cfg, min_recall_ratio=2.0,  # unsatisfiable
        repair_attempts=1, repair_steps=1)
    rep = rt.run_cycle(now=86400.0)
    assert rep["swap"].get("skipped") is True
    assert rep["repair"]["attempts"] == 1
    assert rep["repair"]["healed"] is False

    spans = [r for r in _trace(sink) if r["type"] == "span"]
    repair = [s for s in spans if s["name"] == "lifecycle.repair"]
    assert len(repair) == 1
    assert "recall_ratio" in repair[0]["attrs"]["trigger"]
    assert repair[0]["attrs"]["healed"] is False
    assert repair[0]["attrs"]["attempt"] == 1
    # the repair re-publish nests under the repair span
    publishes = [s for s in spans if s["name"] == "lifecycle.publish"]
    assert len(publishes) == 2
    assert publishes[1]["parent_id"] == repair[0]["span_id"]

    counters = tel.snapshot()["counters"]
    assert counters["lifecycle.repair_bursts"] == 1.0
    assert counters["publish.gate_failures"] == 2.0
    assert counters["publish.snapshots"] == 2.0
    assert "lifecycle.repair_healed" not in counters


@pytest.mark.slow
def test_run_cycle_repairs_gate_failure_end_to_end(tiny_world):
    """``run_cycle`` with an injected collapse converges to a published,
    swapped version instead of wedging on the tripped gate."""
    import jax.numpy as jnp
    rt = _healing_runtime(tiny_world, steps=20)
    # collapse before the cycle: the burst's own in-burst resets plus
    # (if still needed) the gate-triggered repair must recover
    books = dict(rt.state.params["rq"]["codebooks"])
    books["layer0"] = jnp.zeros_like(books["layer0"])
    rt.state.params["rq"] = {"codebooks": books}
    rep = rt.run_cycle(now=86400.0)
    assert not rep["swap"].get("skipped"), rep["publish"]
    assert rep["publish"]["codebook_util_min"] >= 0.5
    assert rt.server is not None


# ---------------------------------------------------------------------------
# fault tolerance: stage retries, pinned serving, rollback, recovery
# ---------------------------------------------------------------------------

def _faulted_runtime(tiny_world, tiny_cfg, specs, tmp_path=None, **lkw):
    """A runtime wired to a private FaultPlan + FixedClock telemetry;
    backoff sleeps advance the fixed clock instead of blocking."""
    from repro.data.edge_dataset import build_neighbor_tables
    from repro.faults import FaultInjector, FaultPlan
    from repro.lifecycle.runtime import LifecycleConfig, LifecycleRuntime
    from repro.obs import FixedClock, MemorySink, Telemetry
    import repro.core.graph_builder as GB
    sink = MemorySink()
    clock = FixedClock()
    tel = Telemetry(sink=sink, clock=clock)
    faults = FaultInjector(FaultPlan(0, list(specs), telemetry=tel,
                                     sleep=clock.advance))
    g = GB.build_graph(tiny_world.day0, k_cap=16, hub_cap=12,
                       keep_state=True)
    tables = build_neighbor_tables(g, k_imp=10, n_walks=12, walk_len=3,
                                   keep_state=True)
    lcfg = LifecycleConfig(steps_per_cycle=1, batch_per_type=8,
                           recall_queries=40, recall_k=20,
                           retry_backoff_s=0.01, **lkw)
    rt = LifecycleRuntime(tiny_cfg, lcfg, g, tables,
                          tiny_world.user_feat, tiny_world.item_feat,
                          world=tiny_world,
                          snapshot_dir=(str(tmp_path) if tmp_path
                                        else None),
                          seed=0, telemetry=tel, faults=faults,
                          sleep=clock.advance)
    return rt, tel, sink


def test_transient_swap_fault_is_retried(tiny_world, tiny_cfg):
    from repro.faults import FaultSpec
    rt, tel, sink = _faulted_runtime(
        tiny_world, tiny_cfg,
        [FaultSpec("swap.flip", "raise", occurrences=(0,))],
        stage_retries=1)
    rt.run_cycle(now=86400.0)                 # bring-up: no flip
    rep = rt.run_cycle(now=90000.0)           # flip attempt 1 faulted
    assert not rep["swap"].get("skipped") and not rep["degraded"]
    assert rt.server.version == 2
    c = tel.snapshot()["counters"]
    assert c["lifecycle.stage_failures"] == 1.0
    assert c["lifecycle.stage_retries"] == 1.0
    # the failure is visible as a stage_failure span naming the stage
    fails = [r for r in _trace(sink) if r["type"] == "span"
             and r["name"] == "lifecycle.stage_failure"]
    assert fails and fails[0]["attrs"]["stage"] == "swap"


def test_exhausted_retries_pin_serving_and_recover_later(tiny_world,
                                                         tiny_cfg):
    """Both swap attempts of cycle 2 fail: serving stays pinned on v1,
    the cycle reports degraded + stale, and the next clean cycle flips
    forward and clears the degradation."""
    from repro.faults import FaultSpec
    rt, tel, sink = _faulted_runtime(
        tiny_world, tiny_cfg,
        [FaultSpec("swap.flip", "raise", occurrences=(0, 1),
                   max_injections=2)],
        stage_retries=1)
    rt.run_cycle(now=86400.0)
    rep = rt.run_cycle(now=90000.0)
    assert rep["swap"]["skipped"] is True
    assert rep["swap"]["degraded"] is True
    assert rep["swap"]["failed_stage"] == "swap"
    assert "swap.flip#1" in rep["swap"]["error"]
    assert rep["degraded"] is True and rep["stale_cycles"] == 1
    assert rt.server.version == 1             # pinned on last good
    snap = tel.snapshot()
    assert snap["gauges"]["lifecycle.degraded"] == 1.0
    assert snap["counters"]["lifecycle.stale_cycles"] == 1.0
    # clean cycle 3: forward progress + health restored
    rep = rt.run_cycle(now=93600.0)
    assert not rep["swap"].get("skipped")
    assert rt.server.version == 3 and rep["degraded"] is False
    snap = tel.snapshot()
    assert snap["gauges"]["lifecycle.degraded"] == 0.0
    assert snap["counters"]["lifecycle.recoveries"] == 1.0


def test_post_swap_regression_rolls_back(tiny_world, tiny_cfg):
    from repro.faults import FaultSpec
    rt, tel, sink = _faulted_runtime(
        tiny_world, tiny_cfg,
        [FaultSpec("health.post_swap", "raise", occurrences=(1,))])
    rt.run_cycle(now=86400.0)                 # v1: healthy
    rep = rt.run_cycle(now=90000.0)           # v2 regresses post-swap
    assert rep["swap"]["rolled_back"] is True
    assert rep["degraded"] is True
    assert rt.server.version == 1             # back on last good
    c = tel.snapshot()["counters"]
    assert c["lifecycle.rollbacks"] == 1.0
    assert c["lifecycle.post_swap_regressions"] == 1.0
    rb = [r for r in _trace(sink) if r["type"] == "span"
          and r["name"] == "lifecycle.rollback"]
    assert rb and rb[0]["attrs"]["to_version"] == 1


def test_rollback_can_be_disabled(tiny_world, tiny_cfg):
    from repro.faults import FaultSpec
    rt, tel, _ = _faulted_runtime(
        tiny_world, tiny_cfg,
        [FaultSpec("health.post_swap", "raise", occurrences=(1,))],
        rollback_on_regression=False)
    rt.run_cycle(now=86400.0)
    rep = rt.run_cycle(now=90000.0)
    assert "rolled_back" not in rep["swap"]
    assert rt.server.version == 2
    assert "lifecycle.rollbacks" not in tel.snapshot()["counters"]


def test_injected_crash_is_never_retried(tiny_world, tiny_cfg):
    from repro.faults import FaultSpec, InjectedCrash
    rt, tel, _ = _faulted_runtime(
        tiny_world, tiny_cfg,
        [FaultSpec("train.step", "crash", occurrences=(0,))],
        stage_retries=3)
    with pytest.raises(InjectedCrash):
        rt.run_cycle(now=86400.0)
    assert "lifecycle.stage_retries" not in tel.snapshot()["counters"]


def test_recover_serving_falls_back_through_corruption(tiny_world,
                                                       tiny_cfg,
                                                       tmp_path):
    """Crash-restart with bit-rot on the newest on-disk version: the
    corrupt snapshot is quarantined and serving resumes one version
    back."""
    import os
    from repro.faults import corrupt_file
    rt, tel, _ = _faulted_runtime(tiny_world, tiny_cfg, [],
                                  tmp_path=tmp_path)
    rt.run_cycle(now=86400.0)
    rt.run_cycle(now=90000.0)
    assert rt.store.versions() == [1, 2]
    corrupt_file(str(tmp_path / "step_2" / "000000.npy"), (0,))

    rt2, tel2, sink2 = _faulted_runtime(tiny_world, tiny_cfg, [],
                                        tmp_path=tmp_path)
    v = rt2.recover_serving(now=93600.0)
    assert v == 1 and rt2.server is not None
    assert rt2.server.version == 1
    res, ver = rt2.server.retrieve_batch(np.arange(8), 93600.0, 4)
    assert ver == 1 and res.shape == (8, 4)
    assert "step_2.corrupt" in os.listdir(tmp_path)
    c = tel2.snapshot()["counters"]
    assert c["snapshot.corrupt_detected"] == 1.0
    assert c["snapshot.quarantined"] == 1.0
    assert c["lifecycle.serving_recovered"] == 1.0
    # the fallback walk is visible in the trace
    fb = [r for r in _trace(sink2) if r["type"] == "span"
          and r["name"] == "snapshot.fallback"]
    assert fb and fb[0]["attrs"]["version"] == 2


def test_recover_serving_with_empty_store_returns_none(tiny_world,
                                                       tiny_cfg,
                                                       tmp_path):
    rt, _, _ = _faulted_runtime(tiny_world, tiny_cfg, [],
                                tmp_path=tmp_path)
    assert rt.recover_serving(now=0.0) is None
    assert rt.server is None
